"""Batched serving example — the paper's "AI-optimized" runtime configuration.

Continuous batching over a small model with per-request latency stats, plus
the end-to-end INT8 decode path (weight-only int8 projections + int8 paged
KV pool — the 15 TOPS INT8 NPU datapath) for comparison.

  PYTHONPATH=src python examples/serve_batched.py
"""

import sys
import time

sys.path.insert(0, "src")

import jax                                   # noqa: E402
import numpy as np                           # noqa: E402

from repro.configs import get_config         # noqa: E402
from repro.models import ExecOptions, build_model  # noqa: E402
from repro.serve.engine import ServeEngine   # noqa: E402


def run(params, model, label, **engine_kw):
    eng = ServeEngine(model, n_slots=4, max_len=96, params=params,
                      **engine_kw)
    rng = np.random.default_rng(0)
    reqs = []
    for i in range(10):
        plen = int(rng.integers(8, 24))
        prompt = rng.integers(0, model.cfg.vocab_size, plen).astype(np.int32)
        reqs.append(eng.submit(prompt, max_new_tokens=8))
    t0 = time.time()
    stats = eng.run_to_completion()
    wall = time.time() - t0
    ttft = [r.t_first_token - r.t_enqueue for r in reqs]
    print(f"\n[{label}] {stats.summary()}")
    print(f"[{label}] wall {wall:.2f}s  "
          f"decode throughput {stats.tokens_out / wall:.1f} tok/s  "
          f"mean slots busy {stats.occupancy_sum / max(stats.decode_steps,1):.2f}")
    print(f"[{label}] sample output: {reqs[0].out_tokens}")
    print(f"[{label}] kv cache {eng.kv_cache_bytes() / 2**20:.2f} MiB")
    return reqs


def main():
    cfg = get_config("smollm-360m").smoke()
    model = build_model(cfg, ExecOptions(attn_impl="reference", ce_chunk=32))
    params = model.init(jax.random.key(0))
    print(f"model: {cfg.name} ({cfg.n_layers}L d={cfg.d_model}) — "
          f"continuous batching, 4 slots, 10 requests")
    a = run(params, model, "f32 weights + f32 KV")
    b = run(params, model, "int8 weights + int8 KV (NPU path)",
            wdtype="int8", kv_dtype="int8")
    same = sum(x.out_tokens == y.out_tokens for x, y in zip(a, b))
    print(f"\nint8 vs full precision: {same}/10 requests decode identically "
          f"(greedy; small models amplify quantization flips)")


if __name__ == "__main__":
    main()
