"""Batched serving example — the paper's "AI-optimized" runtime configuration.

Continuous batching over a small model with per-request latency stats, plus
the end-to-end INT8 decode path (weight-only int8 projections + int8 paged
KV pool — the 15 TOPS INT8 NPU datapath) for comparison.

  PYTHONPATH=src python examples/serve_batched.py
"""

import sys
import time

sys.path.insert(0, "src")

import jax                                   # noqa: E402
import numpy as np                           # noqa: E402

from repro.configs import get_config         # noqa: E402
from repro.models import ExecOptions, build_model  # noqa: E402
from repro.serve.engine import ServeEngine   # noqa: E402


def run(params, model, label, sample_params=None, sharded=False, **engine_kw):
    if sharded:
        # the sharded multi-chiplet engine on this host's devices (a 1-shard
        # mesh on plain CPU — token-identical to the single-host engine;
        # force more fake devices via XLA_FLAGS to see real sharding)
        from repro.launch.mesh import make_serve_mesh
        from repro.serve.sharded import ShardedServeEngine
        mesh = make_serve_mesh()
        n_shards = mesh.shape["data"]
        eng = ShardedServeEngine(model, mesh=mesh,
                                 n_slots=4 * n_shards, max_len=96,
                                 params=params, page_size=32, **engine_kw)
    else:
        eng = ServeEngine(model, n_slots=4, max_len=96, params=params,
                          **engine_kw)
    rng = np.random.default_rng(0)
    reqs = []
    for i in range(10):
        # mixed traffic: a few long prompts exercise the chunked prefill
        plen = int(rng.integers(40, 80)) if i % 4 == 0 \
            else int(rng.integers(8, 24))
        prompt = rng.integers(0, model.cfg.vocab_size, plen).astype(np.int32)
        reqs.append(eng.submit(prompt, max_new_tokens=8,
                               sample_params=sample_params, seed=i))
    t0 = time.time()
    stats = eng.run_to_completion()
    wall = time.time() - t0
    s = stats.summary()
    print(f"\n[{label}] {s}")
    print(f"[{label}] wall {wall:.2f}s  "
          f"decode throughput {stats.tokens_out / wall:.1f} tok/s  "
          f"mean slots busy {s['mean_occupancy'] * eng.n_slots:.2f}  "
          f"prefill chunks {stats.prefill_chunks}  "
          f"stall ticks {stats.decode_stall_ticks}  "
          f"pad waste {s['pad_waste_ratio']:.2f}")
    print(f"[{label}] sample output: {reqs[0].out_tokens}")
    print(f"[{label}] kv cache {eng.kv_cache_bytes() / 2**20:.2f} MiB")
    return reqs


def run_shared_prefix(params, model):
    """Prefix-cache leg: one warmup registers a shared system prompt, then a
    wave of requests reusing it decodes off ref-counted shared pages with a
    copy-on-write tail — same tokens, fewer pages, faster first token."""
    eng = ServeEngine(model, n_slots=4, max_len=96, params=params,
                      page_size=8)
    rng = np.random.default_rng(7)
    system = rng.integers(0, model.cfg.vocab_size, 48).astype(np.int32)
    warm = eng.submit(system, max_new_tokens=4)
    eng.run_to_completion()
    reqs = [eng.submit(np.concatenate(
                [system, rng.integers(0, model.cfg.vocab_size,
                                      int(rng.integers(4, 12)))
                 .astype(np.int32)]), max_new_tokens=8, seed=i)
            for i in range(6)]
    stats = eng.run_to_completion()
    s = stats.summary()
    print(f"\n[shared-prefix] 6 requests share a 48-token system prompt: "
          f"hits {s['prefix_hits']}  hit tokens {s['prefix_hit_tokens']}  "
          f"cow copies {s['cow_copies']}  "
          f"peak pages {s['peak_pages_in_use']}  "
          f"ttft p50 {1e3 * s['ttft_p50_s']:.1f} ms")
    assert warm.done and all(r.done for r in reqs)
    eng.assert_accounting()
    return reqs


def run_migration(params, model):
    """Live-migration leg: a hot sensor walks one shard into DRAINING; its
    live slots re-home by moving KV pages over the modeled UCIe link (no
    re-prefill) and the streams stay token-identical to a fault-free run.
    Degenerates gracefully on a single device (1 shard = nowhere to move:
    the drain falls back to replay)."""
    from repro.launch.mesh import make_serve_mesh
    from repro.serve.faults import FaultEvent, FaultPlan
    from repro.serve.sharded import ShardedServeEngine
    mesh = make_serve_mesh()
    n_shards = mesh.shape["data"]
    # drain shard 0: with 2N-1 requests the one FREE slot lands on the last
    # shard, so the displaced work has somewhere to migrate
    plan = FaultPlan(events=(FaultEvent(
        tick=4, kind="sensor_hot", shard=0, delta_c=60.0, ticks=8),))
    rng = np.random.default_rng(3)
    prompts = [rng.integers(0, model.cfg.vocab_size,
                            int(rng.integers(8, 24))).astype(np.int32)
               for _ in range(2 * n_shards - 1)]
    runs = []
    for p in (None, plan):
        eng = ShardedServeEngine(model, mesh=mesh, n_slots=2 * n_shards,
                                 max_len=96, params=params, page_size=8,
                                 fault_plan=p)
        reqs = [eng.submit(pr.copy(), max_new_tokens=8, seed=i)
                for i, pr in enumerate(prompts)]
        eng.run_to_completion()
        eng.assert_pool_accounting()
        runs.append((eng, reqs))
    (_, base), (eng, faulted) = runs
    st = eng.stats
    par = sum(a.out_tokens == b.out_tokens for a, b in zip(base, faulted))
    print(f"\n[migration] sensor-drained shard over {n_shards} shard(s): "
          f"migrations {st.migrations}  pages {st.migrated_pages}  "
          f"wire bytes {st.migrated_bytes_compressed:.0f}  "
          f"recoveries {st.recoveries}  "
          f"{par}/{len(base)} streams identical to fault-free")


def main():
    cfg = get_config("smollm-360m").smoke()
    model = build_model(cfg, ExecOptions(attn_impl="reference", ce_chunk=32))
    params = model.init(jax.random.key(0))
    print(f"model: {cfg.name} ({cfg.n_layers}L d={cfg.d_model}) — "
          f"continuous batching, 4 slots, 10 requests (mixed long/short)")
    a = run(params, model, "f32 weights + f32 KV (chunked prefill)")
    m = run(params, model, "f32, monolithic prefill (baseline)",
            chunked_prefill=False)
    b = run(params, model, "int8 weights + int8 KV (NPU path)",
            wdtype="int8", kv_dtype="int8")
    s = run(params, model, "f32, sampled (T=0.8 top_k=40 top_p=0.95)",
            sample_params=(0.8, 40, 0.95))
    d = run(params, model, "f32, sharded multi-chiplet engine", sharded=True)
    same = sum(x.out_tokens == y.out_tokens for x, y in zip(a, b))
    print(f"\nint8 vs full precision: {same}/10 requests decode identically "
          f"(greedy; small models amplify quantization flips)")
    exact = sum(x.out_tokens == y.out_tokens for x, y in zip(a, m))
    print(f"chunked vs monolithic: {exact}/10 requests identical "
          f"(token-exact scheduler change)")
    diff = sum(x.out_tokens != y.out_tokens for x, y in zip(a, s))
    print(f"sampled vs greedy: {diff}/10 requests differ "
          f"(deterministic per seed)")
    par = sum(x.out_tokens == y.out_tokens for x, y in zip(a, d))
    print(f"sharded vs single-host: {par}/10 requests identical "
          f"(device-partitioned pool, token-exact)")
    run_shared_prefix(params, model)
    run_migration(params, model)


if __name__ == "__main__":
    main()
