"""Batched serving example — the paper's "AI-optimized" runtime configuration.

Continuous batching over a small model with per-request latency stats, plus
the int8 weight-only path (the 15 TOPS INT8 NPU datapath) for comparison.

  PYTHONPATH=src python examples/serve_batched.py
"""

import sys
import time

sys.path.insert(0, "src")

import jax                                   # noqa: E402
import jax.numpy as jnp                      # noqa: E402
import numpy as np                           # noqa: E402

from repro.configs import get_config         # noqa: E402
from repro.kernels import ops as kops        # noqa: E402
from repro.models import ExecOptions, build_model  # noqa: E402
from repro.serve.engine import ServeEngine   # noqa: E402


def quantize_params_int8(params):
    """Weight-only int8 QDQ on every big matmul weight (NPU numerics)."""
    def qdq(p):
        if p.ndim == 2 and min(p.shape) >= 64:
            q, s = kops.quantize_weight(p.astype(jnp.float32))
            return (q.astype(jnp.float32) * s[None, :]).astype(p.dtype)
        return p
    return jax.tree.map(qdq, params)


def run(params, model, label):
    eng = ServeEngine(model, n_slots=4, max_len=96, params=params)
    rng = np.random.default_rng(0)
    reqs = []
    for i in range(10):
        plen = int(rng.integers(8, 24))
        prompt = rng.integers(0, model.cfg.vocab_size, plen).astype(np.int32)
        reqs.append(eng.submit(prompt, max_new_tokens=8))
    t0 = time.time()
    stats = eng.run_to_completion()
    wall = time.time() - t0
    ttft = [r.t_first_token - r.t_enqueue for r in reqs]
    print(f"\n[{label}] {stats.summary()}")
    print(f"[{label}] wall {wall:.2f}s  "
          f"decode throughput {stats.tokens_out / wall:.1f} tok/s  "
          f"mean slots busy {stats.occupancy_sum / max(stats.decode_steps,1):.2f}")
    print(f"[{label}] sample output: {reqs[0].out_tokens}")
    return reqs


def main():
    cfg = get_config("smollm-360m").smoke()
    model = build_model(cfg, ExecOptions(attn_impl="reference", ce_chunk=32))
    params = model.init(jax.random.key(0))
    print(f"model: {cfg.name} ({cfg.n_layers}L d={cfg.d_model}) — "
          f"continuous batching, 4 slots, 10 requests")
    a = run(params, model, "bf16/f32 weights")
    b = run(quantize_params_int8(params), model, "int8 weights (NPU path)")
    same = sum(x.out_tokens == y.out_tokens for x, y in zip(a, b))
    print(f"\nint8 vs full precision: {same}/10 requests decode identically "
          f"(greedy; small models amplify quantization flips)")


if __name__ == "__main__":
    main()
