"""End-to-end training driver example (deliverable b).

Trains a reduced smollm-family model for a few hundred steps on CPU with the
full production substrate engaged: sharded data pipeline, jitted train step,
gradient clipping + AdamW + cosine schedule, integrity-hashed checkpoints
every 50 steps, resume-on-restart, straggler telemetry.

  PYTHONPATH=src python examples/train_e2e.py                # ~2 min on CPU
  PYTHONPATH=src python examples/train_e2e.py --steps 300 --compress-grads

Kill it mid-run and start it again: it resumes from the last checkpoint
(verify the `resumed from step N (root …)` line). On a real pod the same
driver runs per-host with a bigger mesh (see repro/launch/train.py).
"""

import argparse
import sys

sys.path.insert(0, "src")

from repro.launch.train import train_loop  # noqa: E402


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_train_e2e")
    ap.add_argument("--compress-grads", action="store_true",
                    help="int8 error-feedback gradient compression (I2)")
    args = ap.parse_args()

    losses, _ = train_loop(
        arch="smollm-360m", smoke=True, steps=args.steps,
        global_batch=args.batch, seq_len=args.seq, ckpt_dir=args.ckpt_dir,
        ckpt_every=50, compress_grads=args.compress_grads)
    import math
    first = sum(losses[:10]) / max(len(losses[:10]), 1)
    last = sum(losses[-10:]) / max(len(losses[-10:]), 1)
    print(f"\nmean loss: first-10 {first:.4f} → last-10 {last:.4f} "
          f"({'improving ✓' if last < first else 'check config'})")


if __name__ == "__main__":
    main()
