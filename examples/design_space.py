"""Beyond-paper: design-space exploration over chiplet SoC configurations.

The reconstructed simulator is pure JAX, so it vmaps over thousands of
candidate designs and differentiates w.r.t. continuous design parameters —
capabilities the paper's Python simulator does not have.

  PYTHONPATH=src python examples/design_space.py
"""

import sys
import time

sys.path.insert(0, "src")

import jax                                    # noqa: E402
import jax.numpy as jnp                       # noqa: E402

from repro.core import perf_model as pm      # noqa: E402
from repro.core.scenarios import (            # noqa: E402
    AI_OPTIMIZED, SCENARIO_ORDER, SCENARIOS, Scenario)
from repro.core.soc import build_soc, simulate_batch  # noqa: E402
from repro.core.workloads import MOBILENET_V2, WORKLOADS  # noqa: E402

FIELDS = Scenario.vector_fields()


def sweep_time_stepped():
    """Every integration scenario × a 16-point load grid through the full
    time-stepped simulator (I1–I4 composed) as ONE jitted call — the seed's
    Python loop re-traced one lax.scan per point."""
    socs = [build_soc(SCENARIOS[s]) for s in SCENARIO_ORDER]
    rates = jnp.linspace(25.0, 1500.0, 16)
    t0 = time.perf_counter()
    grid = simulate_batch(socs, MOBILENET_V2, rates, duration_ms=200.0)
    jax.block_until_ready(grid["throughput_ips"])
    dt = time.perf_counter() - t0
    print(f"time-stepped sweep: {len(socs)}x{rates.shape[0]} grid points "
          f"in {dt:.2f}s (single compiled program)")
    i150 = int(jnp.argmin(jnp.abs(rates - 150.0)))
    print(f"{'scenario':18s} {'knee_ips':>9s} {'peak_thpt':>10s} "
          f"{'E/inf@' + f'{float(rates[i150]):.0f}':>10s} {'peakT':>6s}")
    for i, name in enumerate(SCENARIO_ORDER):
        lat = grid["latency_ms"][i]
        ok = jnp.where(lat <= 5.0, rates, 0.0)
        knee = float(jnp.max(ok))            # max load meeting the 5 ms SLO
        print(f"{name:18s} {knee:9.0f} "
              f"{float(jnp.max(grid['throughput_ips'][i])):10.0f} "
              f"{float(grid['energy_mj_per_inf'][i, i150]):10.2f} "
              f"{float(jnp.max(grid['peak_temp_c'][i])):6.1f}")
    return grid


def main():
    base = AI_OPTIMIZED.as_vector()
    wv = MOBILENET_V2.as_vector()

    # --- 0. time-stepped scenario × load sweep (one compiled program) ------
    sweep_time_stepped()

    # --- 1. vmapped Monte-Carlo sweep -------------------------------------
    n = 20_000
    key = jax.random.key(0)
    cand = base[None, :] * jax.random.uniform(
        key, (n, base.shape[0]), minval=0.7, maxval=1.3)

    @jax.jit
    def eval_all(c):
        r = jax.vmap(lambda v: pm.predict_vec(v, wv, jnp.float32(1.0)))(c)
        return r.tops_per_w, r.latency_ms

    eff, lat = eval_all(cand)
    feasible = lat <= 5.0                      # the paper's real-time budget
    eff_feasible = jnp.where(feasible, eff, -jnp.inf)
    best = int(jnp.argmax(eff_feasible))
    print(f"swept {n} candidate SoCs (vmapped, one jit call)")
    print(f"feasible (≤5 ms): {int(jnp.sum(feasible))} / {n}")
    print(f"best feasible TOPS/W: {float(eff[best]):.3f} "
          f"(paper AI-optimized: 0.284)")
    print("best design deltas vs AI-optimized:")
    for i, f in enumerate(FIELDS):
        ratio = float(cand[best, i] / jnp.maximum(base[i], 1e-9))
        if abs(ratio - 1) > 0.02 and base[i] > 0:
            print(f"  {f:22s} ×{ratio:.2f}")

    # --- 2. gradient co-design with a latency constraint -------------------
    lo, hi = base * 0.75, base * 1.25

    @jax.jit
    def step(v):
        def objective(v):
            r = pm.predict_vec(v, wv, jnp.float32(1.0))
            penalty = 10.0 * jnp.maximum(r.latency_ms - 5.0, 0.0)
            return -(r.tops_per_w - penalty)
        g = jax.grad(objective)(v)
        mask = jnp.zeros_like(v).at[jnp.asarray([0, 1, 2, 4, 10])].set(1.0)
        v = v - 0.05 * g * mask * jnp.abs(v)
        return jnp.clip(v, jnp.minimum(lo, hi), jnp.maximum(lo, hi))

    v = base
    r0 = pm.predict_vec(v, wv, jnp.float32(1.0))
    for _ in range(300):
        v = step(v)
    r1 = pm.predict_vec(v, wv, jnp.float32(1.0))
    print(f"\ngradient co-design (±25% box, latency ≤ 5 ms):")
    print(f"  TOPS/W  {float(r0.tops_per_w):.4f} → {float(r1.tops_per_w):.4f}")
    print(f"  latency {float(r0.latency_ms):.2f} → {float(r1.latency_ms):.2f} ms")
    for i, f in enumerate(FIELDS):
        if base[i] > 0 and abs(float(v[i] / base[i]) - 1) > 0.02:
            print(f"  {f:22s} ×{float(v[i]/base[i]):.2f}")

    # --- 3. robustness: the AI-optimized ordering across every workload ----
    print("\nordering robustness across workloads (AI-opt vs basic):")
    from repro.core.scenarios import BASIC_CHIPLET
    for name, w in WORKLOADS.items():
        a = pm.predict(AI_OPTIMIZED, w, 1)
        b = pm.predict(BASIC_CHIPLET, w, 1)
        print(f"  {name:16s} Δlatency {100*(1-float(a.latency_ms)/float(b.latency_ms)):+5.1f}%  "
              f"ΔTOPS/W {100*(float(a.tops_per_w)/float(b.tops_per_w)-1):+5.1f}%")


if __name__ == "__main__":
    main()
