"""Quickstart: reproduce the paper in ~30 seconds on CPU.

  PYTHONPATH=src python examples/quickstart.py

1. Closed-form chiplet model → Table III + headline improvements.
2. Time-stepped SoC simulator (DVFS + UCIe + AuthenTree + thermal migration).
3. The chiplet-aware planner pricing a TPU-pod configuration (the bridge
   from the paper's SoC to this framework's pod runtime).
"""

import sys

sys.path.insert(0, "src")

import jax.numpy as jnp                      # noqa: E402

from repro.core import (  # noqa: E402
    SCENARIOS, SCENARIO_ORDER, WORKLOADS, build_soc, perf_model, simulate,
)
from repro.core.planner import RooflineTerms, plan

MNV2 = WORKLOADS["mobilenetv2"]

print("=" * 72)
print("1. Paper Table III — MobileNetV2 INT8, batch 1")
print("=" * 72)
paper = {"monolithic": (4.7, 213, 1284), "basic_chiplet": (4.8, 208, 1026),
         "ai_optimized": (4.1, 244, 860), "poor_integration": (6.2, 163, 1776)}
print(f"{'scenario':20s} {'latency':>16s} {'throughput':>16s} {'power':>16s}")
for name in SCENARIO_ORDER:
    r = perf_model.predict(SCENARIOS[name], MNV2, 1)
    p = paper[name]
    print(f"{name:20s} {float(r.latency_ms):5.2f} (paper {p[0]:4.1f}) "
          f"{float(r.throughput_ips):6.0f} (paper {p[1]:4d}) "
          f"{float(r.power_mw):7.0f} (paper {p[2]:4d})")

b = perf_model.predict(SCENARIOS["basic_chiplet"], MNV2, 1)
a = perf_model.predict(SCENARIOS["ai_optimized"], MNV2, 1)
print(f"\nAI-optimized vs basic chiplet: "
      f"latency −{100*(1-float(a.latency_ms)/float(b.latency_ms)):.1f}% "
      f"(paper −14.7%), throughput +"
      f"{100*(float(a.throughput_ips)/float(b.throughput_ips)-1):.1f}% "
      f"(paper +17.3%), power −{100*(1-float(a.power_mw)/float(b.power_mw)):.1f}% "
      f"(paper −16.2%), TOPS/W +"
      f"{100*(float(a.tops_per_w)/float(b.tops_per_w)-1):.1f}% (paper +40.1%)")
print(f"Energy/inference: {float(a.energy_mj):.2f} mJ (paper ≈3.5 mJ)")

print()
print("=" * 72)
print("2. Time-stepped SoC (I1 DVFS + I2 UCIe + I3 AuthenTree + I4 thermal)")
print("=" * 72)
for name in ("basic_chiplet", "ai_optimized"):
    soc = build_soc(SCENARIOS[name])
    out = simulate(soc, MNV2, arrival_rate_ips=200.0, duration_ms=200.0)
    print(f"{name:20s} throughput {float(out['throughput_ips']):5.0f} img/s  "
          f"energy {float(out['energy_mj_per_inf']):.2f} mJ/inf  "
          f"peak {float(out['peak_temp_c']):.1f} °C  "
          f"attestation {float(out['attestation_us']):.0f} µs")

print()
print("=" * 72)
print("3. Chiplet-aware planner on a pod cell (gemma-7b × train_4k baseline)")
print("=" * 72)
terms = RooflineTerms(flops=3.08e15, hbm_bytes=5.4e13, collective_bytes=3.5e13,
                      chips=256, model_flops=5.35e16 / 10)
decision = plan(terms, is_training=True,
                resident_bytes_per_chip=10.2 * 2**30)
print(f"bottleneck: {terms.dominant};  plan: {decision.as_dict()}")
print("\n(run `python -m repro.launch.dryrun --all` for the full 40-cell "
      "dry-run and `python -m repro.launch.roofline` for the table)")
