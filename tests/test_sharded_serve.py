"""Sharded multi-chiplet serving (PR 5): token parity vs the single-host
engine on a multi-device CPU mesh, plus the device-locality and
pool-accounting invariants.

The sharded engine partitions slots and the paged KV pool across the mesh's
data axis (shard_map; device-local page tables) — these tests pin:
  * same submissions + same seeds ⇒ IDENTICAL tokens to the single-host
    `ServeEngine` on an 8-device mesh, for dense/moe × {f32, int8} KV,
    greedy and seeded-sampled, a windowed config, and mid-stream
    retirements (different budgets + an explicit cancel);
  * zero cross-device page-table references (every table entry is a LOCAL
    page id into its own shard's pool partition);
  * exact pool accounting after every retirement path, including a
    mid-prefill cancel that must drain the slot's chunk queue.

Multi-device runs fork a subprocess with
XLA_FLAGS=--xla_force_host_platform_device_count=8 (the repo-wide idiom —
device count is fixed at jax import). The single-device-mesh test runs
in-process: a 1-shard sharded engine must degenerate to the single-host
engine exactly.
"""

import os
import subprocess
import sys

import jax
import numpy as np
import pytest

from repro.configs import get_config
from repro.models import ExecOptions, build_model
from repro.launch.mesh import make_serve_mesh
from repro.serve.engine import ServeEngine
from repro.serve.sharded import ShardedServeEngine

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))

_PRELUDE = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import dataclasses
import jax, numpy as np
from repro.configs import get_config
from repro.models import ExecOptions, build_model
from repro.serve.engine import ServeEngine
from repro.serve.sharded import ShardedServeEngine
from repro.launch.mesh import make_serve_mesh

mesh = make_serve_mesh(8)
assert mesh.shape["data"] == 8, dict(mesh.shape)

def prompt(seed, n, vocab=512):
    return np.asarray(jax.random.randint(
        jax.random.key(seed), (n,), 0, vocab), np.int32)

def parity(model, params, lens, *, kw=None, sample=None, new_tokens=None,
           max_len=64, ps=8, n_slots=8):
    # same submissions, same seeds, both engines; returns the sharded engine
    kw = kw or {}
    new_tokens = new_tokens or [4] * len(lens)
    single = ServeEngine(model, n_slots=n_slots, max_len=max_len,
                         params=params, page_size=ps, **kw)
    sr = [single.submit(prompt(i, n), max_new_tokens=m, sample_params=sample,
                        seed=100 + i) for i, (n, m) in
          enumerate(zip(lens, new_tokens))]
    single.run_to_completion()
    eng = ShardedServeEngine(model, mesh=mesh, n_slots=n_slots,
                             max_len=max_len, params=params, page_size=ps,
                             **kw)
    rr = [eng.submit(prompt(i, n), max_new_tokens=m, sample_params=sample,
                     seed=100 + i) for i, (n, m) in
          enumerate(zip(lens, new_tokens))]
    eng.run_to_completion()
    eng.assert_local_page_tables()
    for a, b in zip(sr, rr):
        assert a.done and b.done
        assert a.out_tokens == b.out_tokens, (a.out_tokens, b.out_tokens)
    assert eng.stats.pages_in_use == 0
    assert all(s.allocatable() == eng.n_pages - 1
               for s in eng._sched.shards)
    # pages are physically partitioned over the data axis
    spec = eng._pools["k"].sharding.spec
    assert spec[1] == "data", spec
    return eng
"""


def _run(script: str):
    env = {**os.environ, "PYTHONPATH": "src"}
    r = subprocess.run([sys.executable, "-c", _PRELUDE + script], env=env,
                       capture_output=True, text=True, cwd=REPO)
    assert r.returncode == 0, (r.stdout[-2000:], r.stderr[-4000:])
    return r.stdout


def test_sharded_parity_dense_8dev():
    """dense × {f32, int8} parity, seeded sampling, a windowed config, and
    mid-stream retirements (mixed budgets + an explicit mid-prefill cancel)
    on an 8-device mesh."""
    out = _run(r"""
cfg = get_config("smollm-360m").smoke()
model = build_model(cfg, ExecOptions(attn_impl="reference", ce_chunk=32))
params = model.init(jax.random.key(1))

# greedy f32, mixed budgets: short-budget slots retire mid-stream while
# long ones keep decoding
parity(model, params, [9, 17, 6, 23, 13, 31],
       new_tokens=[2, 8, 4, 1, 6, 3])
print("DENSE_F32_OK")
parity(model, params, [9, 17, 6], kw=dict(wdtype="int8", kv_dtype="int8"))
print("DENSE_INT8_OK")
parity(model, params, [9, 17, 6], sample=(0.8, 20, 0.9))
print("DENSE_SAMPLED_OK")

# windowed config: prompts longer than the window, O(window) occupancy
cfgw = dataclasses.replace(cfg, window=16)
mw = build_model(cfgw, ExecOptions(attn_impl="reference", ce_chunk=32))
pw = mw.init(jax.random.key(2))
eng = parity(mw, pw, [40, 30], new_tokens=[8, 8])
assert eng.stats.peak_pages_in_use <= 8 * eng._sched._window_pages()
print("WINDOWED_OK")

# explicit mid-prefill cancel: the drained slot's pages return to its
# shard's free list and the survivor stays token-exact
eng = ShardedServeEngine(model, mesh=mesh, n_slots=8, max_len=64,
                         params=params, page_size=8)
r_long = eng.submit(prompt(0, 40), max_new_tokens=4)
r_short = eng.submit(prompt(1, 9), max_new_tokens=4)
eng.step()                     # admits; first chunk of the long prompt
eng.cancel(r_long)             # mid-prefill retirement
eng.run_to_completion()
eng.assert_local_page_tables()
assert eng.stats.pages_in_use == 0
assert all(s.allocatable() == eng.n_pages - 1 for s in eng._sched.shards)
single = ServeEngine(model, n_slots=2, max_len=64, params=params, page_size=8)
s_short = single.submit(prompt(1, 9), max_new_tokens=4)
single.run_to_completion()
assert r_short.out_tokens == s_short.out_tokens
print("CANCEL_OK")
""")
    for tag in ("DENSE_F32_OK", "DENSE_INT8_OK", "DENSE_SAMPLED_OK",
                "WINDOWED_OK", "CANCEL_OK"):
        assert tag in out, out[-2000:]


def test_sharded_parity_moe_8dev():
    """moe × {f32, int8} parity on an 8-device mesh (per-expert int8 weights
    + int8 KV pool ride the shard_map'd decode step unchanged)."""
    out = _run(r"""
cfg = get_config("qwen2-moe-a2.7b").smoke()
model = build_model(cfg, ExecOptions(attn_impl="reference", ce_chunk=32))
params = model.init(jax.random.key(3))
parity(model, params, [9, 17], new_tokens=[3, 3])
print("MOE_F32_OK")
parity(model, params, [17], kw=dict(wdtype="int8", kv_dtype="int8"),
       new_tokens=[3])
print("MOE_INT8_OK")
""")
    assert "MOE_F32_OK" in out and "MOE_INT8_OK" in out, out[-2000:]


def test_sharded_single_shard_degenerates_to_single_host():
    """A 1-shard sharded engine on the host's own device must reproduce the
    single-host engine exactly (fast in-process sanity: no XLA_FLAGS fork)."""
    cfg = get_config("smollm-360m").smoke()
    model = build_model(cfg, ExecOptions(attn_impl="reference", ce_chunk=32))
    params = model.init(jax.random.key(1))

    def prompt(seed, n):
        return np.asarray(jax.random.randint(
            jax.random.key(seed), (n,), 0, 512), np.int32)

    single = ServeEngine(model, n_slots=2, max_len=64, params=params,
                         page_size=8)
    sr = [single.submit(prompt(i, n), max_new_tokens=4)
          for i, n in enumerate((9, 17, 6))]
    single.run_to_completion()
    eng = ShardedServeEngine(model, mesh=make_serve_mesh(1), n_slots=2,
                             max_len=64, params=params, page_size=8)
    rr = [eng.submit(prompt(i, n), max_new_tokens=4)
          for i, n in enumerate((9, 17, 6))]
    eng.run_to_completion()
    eng.assert_local_page_tables()
    for a, b in zip(sr, rr):
        assert a.out_tokens == b.out_tokens
    assert eng.stats.pages_in_use == 0
    assert eng.shard_tokens == [12]


def test_sharded_validation():
    cfg = get_config("smollm-360m").smoke()
    model = build_model(cfg, ExecOptions(attn_impl="reference", ce_chunk=32))
    params = model.init(jax.random.key(0))
    mesh = make_serve_mesh(1)
    with pytest.raises(ValueError):          # pages must tile max_len
        ShardedServeEngine(model, mesh=mesh, n_slots=2, max_len=60,
                           params=params, page_size=8)
    with pytest.raises(ValueError):          # recurrent families don't shard
        cfg2 = get_config("mamba2-780m").smoke()
        m2 = build_model(cfg2, ExecOptions(attn_impl="reference", ce_chunk=32))
        ShardedServeEngine(m2, mesh=mesh, params=m2.init(jax.random.key(0)))
    with pytest.raises(ValueError):          # unknown mesh axis
        ShardedServeEngine(model, mesh=mesh, axis="model", params=params)
