"""End-to-end training-loop integration: learn, checkpoint, crash, resume."""

import numpy as np
import pytest

from repro.launch.train import train_loop


@pytest.fixture(scope="module")
def run_dir(tmp_path_factory):
    return str(tmp_path_factory.mktemp("train_loop"))


def test_loop_runs_and_checkpoints(run_dir):
    losses, state = train_loop(
        arch="smollm-360m", smoke=True, steps=12, global_batch=4, seq_len=32,
        ckpt_dir=run_dir, ckpt_every=5, log_every=100)
    assert len(losses) == 12
    assert all(np.isfinite(l) for l in losses)
    from repro.train.checkpoint import CheckpointManager
    mgr = CheckpointManager(run_dir)
    assert mgr.latest_step() == 11


def test_resume_continues_stream(run_dir):
    """Resume must pick up at step latest+1 and keep training."""
    losses, state = train_loop(
        arch="smollm-360m", smoke=True, steps=18, global_batch=4, seq_len=32,
        ckpt_dir=run_dir, ckpt_every=5, log_every=100)
    # resumed from 11 → trains steps 12..17 = 6 losses
    assert len(losses) == 6
    from repro.train.checkpoint import CheckpointManager
    mgr = CheckpointManager(run_dir)
    assert mgr.latest_step() == 17


def test_compressed_grads_path(tmp_path):
    """I2 compression in the real loop: finite losses, comparable scale."""
    plain, _ = train_loop(
        arch="smollm-360m", smoke=True, steps=8, global_batch=4, seq_len=32,
        ckpt_dir=str(tmp_path / "a"), ckpt_every=0, log_every=100)
    comp, _ = train_loop(
        arch="smollm-360m", smoke=True, steps=8, global_batch=4, seq_len=32,
        ckpt_dir=str(tmp_path / "b"), ckpt_every=0, log_every=100,
        compress_grads=True)
    assert all(np.isfinite(l) for l in comp)
    assert abs(np.mean(comp) - np.mean(plain)) < 0.5
