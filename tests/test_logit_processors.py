"""Repetition penalty + per-slot logit bias (PR 7).

Both processors ride the SAME vmapped sampled-decode jit as temperature /
top-k / top-p: per-slot arrays (penalty (B,), seen-token mask (B, V),
additive bias (B, V)) applied to the logits BEFORE `sample_tokens`, so a
batch mixing greedy, penalized and biased requests still runs one compiled
decode step. Slots with penalty 1 and zero bias pass through bit-identical
— the greedy-equivalence contract every other sampling feature pins.
"""

import math

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.models import ExecOptions, build_model
from repro.serve.engine import ServeEngine, generate_greedy
from repro.serve.sampling import apply_logit_processors, clamp_rep_penalty


def _prompt(seed, n, vocab=512):
    return np.asarray(
        jax.random.randint(jax.random.key(seed), (n,), 0, vocab), np.int32)


@pytest.fixture(scope="module")
def smol():
    cfg = get_config("smollm-360m").smoke()
    model = build_model(cfg, ExecOptions(attn_impl="reference", ce_chunk=32))
    return cfg, model, model.init(jax.random.key(1))


# ------------------------------------------------------------------ unit level
def test_clamp_rep_penalty_edges():
    """NaN and non-positive penalties clamp to the identity (1.0); values in
    (0, 1) are legal (they REWARD repetition, the HF convention)."""
    assert clamp_rep_penalty(float("nan")) == 1.0
    assert clamp_rep_penalty(0.0) == 1.0
    assert clamp_rep_penalty(-2.5) == 1.0
    assert clamp_rep_penalty(0.5) == 0.5
    assert clamp_rep_penalty(1.3) == pytest.approx(1.3)
    assert clamp_rep_penalty(1) == 1.0 and isinstance(clamp_rep_penalty(1),
                                                      float)


def test_apply_logit_processors_semantics():
    """CTRL/HF penalty semantics on crafted logits: seen positive logits are
    DIVIDED by the penalty, seen negative MULTIPLIED (both push seen tokens
    down for penalty > 1), unseen logits untouched; the additive bias lands
    AFTER the penalty (bias itself is never penalized); identity rows
    (penalty 1, zero bias) are bit-exact."""
    logits = jnp.asarray([[2.0, -2.0, 4.0, -4.0],
                          [2.0, -2.0, 4.0, -4.0]], jnp.float32)
    seen = jnp.asarray([[True, True, False, False]] * 2)
    pen = jnp.asarray([2.0, 1.0], jnp.float32)
    bias = jnp.zeros((2, 4), jnp.float32).at[0, 3].set(10.0)
    out = np.asarray(apply_logit_processors(logits, pen, seen, bias))
    np.testing.assert_allclose(out[0], [1.0, -4.0, 4.0, 6.0])
    np.testing.assert_array_equal(out[1], np.asarray(logits[1]))
    # penalty in (0, 1) rewards repetition: seen logits move UP
    out_r = np.asarray(apply_logit_processors(
        logits, jnp.asarray([0.5, 0.5]), seen, jnp.zeros((2, 4))))
    np.testing.assert_allclose(out_r[0], [4.0, -1.0, 4.0, -4.0])


# ---------------------------------------------------------------- engine level
def test_identity_processors_stay_greedy_exact(smol):
    """Submissions that widen dispatch into the sampled jit but whose
    processors are identities must stay bit-identical to the plain greedy
    engine: rep_penalty=NaN clamps to 1.0 host-side, and 1.0 + 1e-12 rounds
    to exactly 1.0f on device."""
    cfg, model, params = smol
    greedy = generate_greedy(model, params, _prompt(3, 9), n_tokens=6,
                             max_len=64)
    eng = ServeEngine(model, n_slots=2, max_len=64, params=params,
                      page_size=8)
    r_nan = eng.submit(_prompt(3, 9), max_new_tokens=6,
                       rep_penalty=float("nan"))
    r_eps = eng.submit(_prompt(3, 9), max_new_tokens=6,
                       rep_penalty=1.0 + 1e-12)
    eng.run_to_completion()
    assert r_nan.out_tokens == greedy
    assert r_eps.out_tokens == greedy


def test_rep_penalty_changes_repeating_output(smol):
    """A strong penalty must actually break repetition: prompt seed 9's
    greedy continuation stutters (it repeats one token three times running
    AND re-emits a prompt token); with penalty→huge every emitted token is
    fresh — never a prompt token, never a repeat of an earlier output token
    (greedy path, so this is deterministic)."""
    cfg, model, params = smol
    p = _prompt(9, 9)
    eng = ServeEngine(model, n_slots=2, max_len=64, params=params,
                      page_size=8)
    r = eng.submit(p, max_new_tokens=8, rep_penalty=1e9)
    eng.run_to_completion()
    out = r.out_tokens
    assert len(out) == 8
    assert len(set(out)) == len(out), f"penalized stream repeated: {out}"
    assert not set(out) & set(int(t) for t in p), \
        f"penalized stream re-emitted prompt tokens: {out}"
    # ... and the baseline it fixed really was degenerate
    greedy = generate_greedy(model, params, p, n_tokens=8, max_len=64)
    assert len(set(greedy)) < len(greedy), "baseline no longer repeats"
    assert out != greedy


def test_logit_bias_forces_and_bans_tokens(smol):
    """+1e9 bias forces a token on every step (greedy AND sampled paths);
    NEG-scale bias bans one — the banned id never appears even when it is
    the greedy argmax."""
    cfg, model, params = smol
    p = _prompt(3, 9)
    greedy = generate_greedy(model, params, p, n_tokens=4, max_len=64)
    eng = ServeEngine(model, n_slots=2, max_len=64, params=params,
                      page_size=8)
    r_force = eng.submit(p, max_new_tokens=4, logit_bias={42: 1e9})
    r_force_s = eng.submit(p, max_new_tokens=4, logit_bias={42: 1e9},
                           sample_params=(0.8, 5, 0.9), seed=7)
    r_ban = eng.submit(p, max_new_tokens=4, logit_bias={greedy[0]: -1e9})
    eng.run_to_completion()
    assert r_force.out_tokens == [42] * 4
    assert r_force_s.out_tokens == [42] * 4
    assert greedy[0] not in r_ban.out_tokens


def test_rep_penalty_sampled_determinism(smol):
    """Penalty composes with sampling: same (seed, penalty) → same stream,
    engine-run to engine-run."""
    cfg, model, params = smol
    outs = []
    for _ in range(2):
        eng = ServeEngine(model, n_slots=1, max_len=64, params=params,
                          page_size=8)
        r = eng.submit(_prompt(3, 9), max_new_tokens=6,
                       sample_params=(0.9, 20, 0.95), seed=11,
                       rep_penalty=1.4)
        eng.run_to_completion()
        outs.append(r.out_tokens)
    assert outs[0] == outs[1]


def test_logit_bias_validation(smol):
    """Malformed bias dicts fail at submit, not inside the jit: ids outside
    [0, vocab) and non-finite values raise ValueError."""
    cfg, model, params = smol
    eng = ServeEngine(model, n_slots=1, max_len=64, params=params,
                      page_size=8)
    for bad in ({-1: 1.0}, {cfg.vocab_size: 1.0},
                {3: float("inf")}, {3: float("nan")}):
        with pytest.raises(ValueError):
            eng.submit(_prompt(3, 9), max_new_tokens=2, logit_bias=bad)
    # a clamp, not an error: degenerate penalties submit fine
    r = eng.submit(_prompt(3, 9), max_new_tokens=2, rep_penalty=-3.0)
    eng.run_to_completion()
    assert r.done and math.isfinite(sum(r.out_tokens))
