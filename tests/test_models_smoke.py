"""Per-arch smoke tests: reduced same-family config, one forward/train step on
CPU, asserting output shapes + finiteness (assignment deliverable f)."""

import jax
import jax.numpy as jnp
import pytest

from repro.configs import ARCH_ORDER, get_config
from repro.configs.base import ShapeConfig
from repro.models import ExecOptions, build_model, make_inputs

SMOKE_TRAIN = ShapeConfig("smoke_train", "train", 64, 2)
SMOKE_PREFILL = ShapeConfig("smoke_prefill", "prefill", 64, 2)


def _model(arch, **opt_kw):
    cfg = get_config(arch).smoke()
    opts = ExecOptions(attn_impl="reference", ce_chunk=32, moe_group=32, **opt_kw)
    return cfg, build_model(cfg, opts)


@pytest.mark.parametrize("arch", ARCH_ORDER)
def test_train_step_smoke(arch):
    cfg, model = _model(arch)
    params = model.init(jax.random.key(0))
    batch = make_inputs(cfg, SMOKE_TRAIN, jax.random.key(1), dtype=jnp.float32)
    (loss, metrics) = jax.jit(model.train_loss)(params, batch)
    assert loss.shape == ()
    assert bool(jnp.isfinite(loss)), f"{arch}: loss={loss}"
    # an untrained model on uniform-random labels should sit near ln(V)
    import math
    assert 0.2 * math.log(cfg.vocab_size) < float(loss) < 3.0 * math.log(
        cfg.padded_vocab), f"{arch}: loss={float(loss)}"


@pytest.mark.parametrize("arch", ARCH_ORDER)
def test_train_grads_finite(arch):
    cfg, model = _model(arch)
    params = model.init(jax.random.key(0))
    batch = make_inputs(cfg, SMOKE_TRAIN, jax.random.key(1), dtype=jnp.float32)

    def loss_fn(p):
        return model.train_loss(p, batch)[0]

    grads = jax.jit(jax.grad(loss_fn))(params)
    flat = jax.tree.leaves(grads)
    assert all(bool(jnp.all(jnp.isfinite(g))) for g in flat), arch
    # something must actually flow
    assert any(float(jnp.max(jnp.abs(g))) > 0 for g in flat), arch


@pytest.mark.parametrize("arch", ARCH_ORDER)
def test_prefill_decode_smoke(arch):
    cfg, model = _model(arch)
    params = model.init(jax.random.key(0))
    batch = make_inputs(cfg, SMOKE_PREFILL, jax.random.key(1), dtype=jnp.float32)
    logits, cache = jax.jit(model.prefill)(params, batch)
    assert logits.shape == (2, 1, cfg.padded_vocab)
    assert bool(jnp.all(jnp.isfinite(logits))), arch
    # pad the kv cache out to a longer max_len before decoding
    cache = _grow_cache(cfg, cache, max_len=96)
    tok = jnp.argmax(logits[:, -1, :cfg.vocab_size], axis=-1)[:, None].astype(jnp.int32)
    for _ in range(3):
        logits, cache = jax.jit(model.decode)(params, {"tokens": tok}, cache)
        assert logits.shape == (2, 1, cfg.padded_vocab)
        assert bool(jnp.all(jnp.isfinite(logits))), arch
        tok = jnp.argmax(logits[:, -1, :cfg.vocab_size], axis=-1)[:, None].astype(jnp.int32)


def _grow_cache(cfg, cache, max_len):
    """Pad prefill KV caches (seq axis) up to max_len where applicable."""
    if cfg.family in ("dense", "moe", "vlm"):
        pad = max_len - cache["k"].shape[2]
        cache = dict(cache)
        for k in ("k", "v"):
            cache[k] = jnp.pad(cache[k], [(0, 0), (0, 0), (0, pad), (0, 0), (0, 0)])
        return cache
    if cfg.family == "encdec":
        pad = max_len - cache["k"].shape[2]
        cache = dict(cache)
        for k in ("k", "v"):
            cache[k] = jnp.pad(cache[k], [(0, 0), (0, 0), (0, pad), (0, 0), (0, 0)])
        return cache
    return cache  # ssm / hybrid state is O(1) in context


@pytest.mark.parametrize("arch", ["smollm-360m", "mamba2-780m",
                                  "recurrentgemma-2b"])
def test_decode_matches_prefill(arch):
    """Teacher-forced decode must reproduce prefill's last-position logits."""
    cfg, model = _model(arch)
    params = model.init(jax.random.key(0))
    toks = jax.random.randint(jax.random.key(2), (2, 16), 0, cfg.vocab_size,
                              jnp.int32)
    full_logits, _ = jax.jit(model.prefill)(params, {"tokens": toks})
    # prefill the first 15 tokens, then decode token 15 and compare
    _, cache = jax.jit(model.prefill)(params, {"tokens": toks[:, :15]})
    cache = _grow_cache(cfg, cache, max_len=32)
    step_logits, _ = jax.jit(model.decode)(
        params, {"tokens": toks[:, 15:16]}, cache)
    assert jnp.allclose(step_logits[:, 0], full_logits[:, -1], atol=2e-2,
                        rtol=2e-2), arch
