"""MoE dispatch/combine correctness: GShard capacity semantics, equivalence
to a direct gather implementation, load-balance loss."""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.models import moe as moe_mod
from repro.models.common import init_params


def _cfg(**kw):
    base = get_config("qwen2-moe-a2.7b").smoke()
    return dataclasses.replace(base, **kw) if kw else base


def test_router_topk_normalized():
    logits = jax.random.normal(jax.random.key(0), (2, 8, 8))
    p, idx = moe_mod.router_topk(logits, 2)
    np.testing.assert_allclose(np.asarray(jnp.sum(p, -1)), 1.0, rtol=1e-5)
    assert int(jnp.max(idx)) < 8


def test_dispatch_combine_shapes_and_capacity():
    g, s, e, k, cap = 2, 16, 4, 2, 8
    logits = jax.random.normal(jax.random.key(1), (g, s, e))
    top_p, top_idx = moe_mod.router_topk(logits, k)
    dispatch, combine = moe_mod.make_dispatch(top_p, top_idx, e, cap)
    assert dispatch.shape == (g, s, e, cap)
    # each (expert, slot) holds at most one token
    assert float(jnp.max(jnp.sum(dispatch, axis=1))) <= 1.0 + 1e-6
    # each token occupies at most k slots
    assert float(jnp.max(jnp.sum(dispatch, axis=(2, 3)))) <= k + 1e-6
    # combine weights match gates where dispatched
    sel = jnp.sum(combine, axis=(2, 3))
    assert float(jnp.max(sel)) <= 1.0 + 1e-6


def test_no_drops_when_capacity_ample():
    """With cap ≥ s·k every token must land exactly k slots."""
    g, s, e, k = 1, 8, 4, 2
    logits = jax.random.normal(jax.random.key(2), (g, s, e))
    top_p, top_idx = moe_mod.router_topk(logits, k)
    dispatch, combine = moe_mod.make_dispatch(top_p, top_idx, e, cap=s * k)
    np.testing.assert_allclose(np.asarray(jnp.sum(dispatch, axis=(2, 3))),
                               k, rtol=1e-6)
    np.testing.assert_allclose(np.asarray(jnp.sum(combine, axis=(2, 3))),
                               1.0, rtol=1e-5)


def test_moe_ffn_matches_direct_gather():
    """Grouped-einsum MoE == per-token direct expert evaluation (ample cap)."""
    cfg = dataclasses.replace(_cfg(), capacity_factor=100.0, moe_group=16,
                              d_ff_shared=0)
    sch = moe_mod.moe_schema(cfg, 1)
    params = init_params(sch, jax.random.key(3), jnp.float32)
    lp = jax.tree.map(lambda t: t[0], params)
    x = jax.random.normal(jax.random.key(4), (2, 16, cfg.d_model), jnp.float32)

    got = moe_mod.moe_ffn(x, lp, cfg)

    # direct: for each token evaluate its top-k experts
    from repro.models.common import act_fn, glu_act
    act = act_fn(glu_act(cfg.activation))
    logits = jnp.einsum("bsd,de->bse", x, lp["router"])
    top_p, top_idx = moe_mod.router_topk(logits, cfg.moe_top_k)
    want = jnp.zeros_like(x)
    for j in range(cfg.moe_top_k):
        idx = top_idx[..., j]                                   # (B,S)
        w1 = lp["w1"][idx]                                      # (B,S,d,f)
        w3 = lp["w3"][idx]
        w2 = lp["w2"][idx]
        h = act(jnp.einsum("bsd,bsdf->bsf", x, w1)) \
            * jnp.einsum("bsd,bsdf->bsf", x, w3)
        y = jnp.einsum("bsf,bsfd->bsd", h, w2)
        want = want + top_p[..., j:j + 1] * y
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=5e-4, atol=5e-4)


def test_shared_expert_contributes():
    cfg = _cfg()
    assert cfg.d_ff_shared > 0
    sch = moe_mod.moe_schema(cfg, 1)
    params = init_params(sch, jax.random.key(5), jnp.float32)
    lp = jax.tree.map(lambda t: t[0], params)
    x = jax.random.normal(jax.random.key(6), (1, 16, cfg.d_model), jnp.float32)
    full = moe_mod.moe_ffn(x, lp, cfg)
    lp_zero = dict(lp, shared_w2=jnp.zeros_like(lp["shared_w2"]))
    no_shared = moe_mod.moe_ffn(x, lp_zero, cfg)
    assert float(jnp.max(jnp.abs(full - no_shared))) > 1e-6


def test_capacity_drops_are_graceful():
    """Tiny capacity must drop tokens (output ↓) but stay finite."""
    cfg = dataclasses.replace(_cfg(), capacity_factor=0.05, moe_group=16,
                              d_ff_shared=0)
    sch = moe_mod.moe_schema(cfg, 1)
    params = init_params(sch, jax.random.key(7), jnp.float32)
    lp = jax.tree.map(lambda t: t[0], params)
    x = jax.random.normal(jax.random.key(8), (1, 64, cfg.d_model), jnp.float32)
    y = moe_mod.moe_ffn(x, lp, cfg)
    assert bool(jnp.all(jnp.isfinite(y)))


def test_load_balance_loss_behaviour():
    """Uniform router → loss ≈ 1; collapsed router → loss ≈ E·(1/1)·1 = E-ish."""
    e = 8
    uniform = jnp.zeros((4, 32, e))
    _, idx_u = moe_mod.router_topk(uniform + jax.random.normal(
        jax.random.key(9), uniform.shape) * 1e-3, 1)
    l_u = float(moe_mod.load_balance_loss(uniform, idx_u, e))
    collapsed = jnp.zeros((4, 32, e)).at[..., 0].set(20.0)
    _, idx_c = moe_mod.router_topk(collapsed, 1)
    l_c = float(moe_mod.load_balance_loss(collapsed, idx_c, e))
    assert l_u == pytest.approx(1.0, rel=0.1)
    assert l_c > 4.0
