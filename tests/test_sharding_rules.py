"""Logical-axis sharding rules: divisibility back-off, schema specs, cache
specs — pure logic, no devices needed (mesh built on 1 CPU device is fine
for spec resolution since rules read mesh.shape)."""

import jax
from jax.sharding import PartitionSpec as P

from repro.configs import get_config
from repro.launch import steps as steps_mod
from repro.models import build_model
from repro.parallel import sharding as sh


class FakeMesh:
    """Spec resolution only reads .shape / .size."""

    def __init__(self, **axes):
        self.shape = dict(axes)
        self.size = 1
        for v in axes.values():
            self.size *= v


MESH = FakeMesh(data=16, model=16)
MESH3 = FakeMesh(pod=2, data=16, model=16)


def test_divisible_dims_shard():
    spec = sh.spec_for((256, 4096), ("batchlike", "embed"), MESH)
    assert spec == P("data", None)  # embed falls back: data already used
    spec = sh.spec_for((4096, 24576), ("embed", "ff"), MESH)
    assert spec == P("data", "model")


def test_indivisible_dims_replicate():
    # 15 heads don't divide 16 → replicated
    assert sh.spec_for((15, 64), ("heads", None), MESH) == P(None, None)
    # 60 experts don't divide 16 → replicated, ff picks up model
    assert sh.spec_for((60, 2048, 1408), ("experts", "embed", "ff"), MESH) \
        == P(None, "data", "model")


def test_batchlike_uses_pod_and_data():
    assert sh.spec_for((256, 128), ("batchlike", None), MESH3) \
        == P(("pod", "data"), None)
    # batch=8 divides data(16)? no → falls through to None? 8 % 32 != 0,
    # 8 % 16 != 0 → replicate
    assert sh.spec_for((8, 128), ("batchlike", None), MESH3) == P(None, None)


def test_axis_used_once_per_tensor():
    # both dims want 'model' → second one must back off
    spec = sh.spec_for((256, 512), ("ff", "vocab"), MESH)
    assert spec == P("model", None)


def test_schema_pspecs_match_structure():
    cfg = steps_mod.arch_for_mesh(get_config("gemma-7b"), MESH)
    model = build_model(cfg)
    specs = sh.schema_pspecs(model.schema, MESH)
    flat_s = jax.tree.leaves(specs, is_leaf=lambda x: isinstance(x, P))
    from repro.models.common import is_schema_leaf
    flat_d = jax.tree.leaves(model.schema, is_leaf=is_schema_leaf)
    assert len(flat_s) == len(flat_d)
    # embed (V, d): vocab→model, embed→data
    assert specs["embed"] == P("model", "data")
    # stacked FFN weight (L, d, f)
    assert specs["layers"]["w1"] == P(None, "data", "model")
    # gemma heads = 16 → sharded
    assert specs["layers"]["wq"] == P(None, "data", "model", None)


def test_padded_heads_shard_for_awkward_archs():
    for arch in ("qwen2.5-32b", "smollm-360m", "recurrentgemma-2b"):
        cfg = steps_mod.arch_for_mesh(get_config(arch), MESH)
        assert cfg.n_heads_padded % 16 == 0
        model = build_model(cfg)
        specs = sh.schema_pspecs(model.schema, MESH)
        wq = specs["layers"]["wq"] if "layers" in specs else specs["attn"]["wq"]
        assert wq[2] == "model", (arch, wq)


def test_cache_specs_kv_vs_seq():
    # gemma kv=16 → kv-head sharding
    cfg = steps_mod.arch_for_mesh(get_config("gemma-7b"), MESH)
    model = build_model(cfg)
    cache = model.cache_shape(128, 32768)
    specs = sh.cache_pspecs(cfg, cache, MESH)
    assert specs["k"] == P(None, "data", None, "model", None)
    # mistral kv=8 → sequence sharding (flash-decoding split-K)
    cfg = steps_mod.arch_for_mesh(get_config("llava-next-mistral-7b"), MESH)
    model = build_model(cfg)
    cache = model.cache_shape(128, 32768)
    specs = sh.cache_pspecs(cfg, cache, MESH)
    assert specs["k"] == P(None, "data", "model", None, None)
    assert specs["pos"] == P("data")


def test_suggest_n_micro_monotone_in_model_size():
    from repro.configs.base import SHAPES
    small = steps_mod.suggest_n_micro(get_config("smollm-360m"),
                                      SHAPES["train_4k"], MESH)
    big = steps_mod.suggest_n_micro(get_config("dbrx-132b"),
                                    SHAPES["train_4k"], MESH)
    assert small == 1 and big >= 4
