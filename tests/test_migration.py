"""Live cross-shard KV page migration over compression-aware UCIe (PR 9).

The sharded engine can now re-home a live slot by MOVING its physical pages
between device-local pool partitions (gather → all_gather → scatter under
shard_map) instead of re-prefilling, with the transfer priced through the
SAME `core/ucie.transfer` closed form the time-stepped simulator drains.
These tests pin:

  * mid-decode migration is TOKEN-EXACT vs a stay-put twin across
    dense/moe/mla × {f32, int8} — the data path moves pool-native bytes
    (an int8 pool's int8 rows + f16 scales ARE its block-compressed wire
    format), so migrated streams are bit-identical;
  * drain-via-migration emits the same tokens as drain-via-replay AND the
    fault-free twin, at ZERO extra prefill chunks (the O(bytes) vs O(FLOPs)
    claim), with exact pool accounting on both shards after every move;
  * refcounted shared/COW pages migrate intact: the mover gets fresh
    copies, the stayer keeps the originals;
  * an 8-device chaos run (deaths + sensor storms + squeezes) with
    migration on keeps token divergence at zero;
  * elastic rebalancing moves load back onto a rejoined shard without
    changing any token, and starvation rescue admits a page-starved head
    with fewer preemptions;
  * hot prefix pages replicate across shards over the same move primitive;
  * identical prompts submitted together coalesce (in-flight dedup);
  * the serving stack owns NO link math: `ucie.migration_ticks` /
    `ucie.transfer` is the single call path shared with the simulator.
"""

import inspect
import os
import subprocess
import sys

import numpy as np

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))

_PRELUDE = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import jax, numpy as np
from repro.configs import get_config
from repro.models import ExecOptions, build_model
from repro.launch.mesh import make_serve_mesh
from repro.serve.faults import FaultEvent, FaultPlan, chaos_plan
from repro.serve.sharded import ShardedServeEngine

mesh4 = make_serve_mesh(4)

def prompt(seed, n, vocab=512):
    return np.asarray(jax.random.randint(
        jax.random.key(seed), (n,), 0, vocab), np.int32)

def build(arch, **exec_kw):
    cfg = get_config(arch).smoke()
    model = build_model(cfg, ExecOptions(attn_impl="reference", ce_chunk=32,
                                         **exec_kw))
    return model, model.init(jax.random.key(1))

def run_traffic(eng, lens, max_new=4, migrate_after=None):
    # optional mid-decode migration: after `migrate_after` ticks pick the
    # first active slot and re-home it to the scheduler's target shard,
    # asserting exact accounting on BOTH shards right after the move
    reqs = [eng.submit(prompt(i, n), max_new_tokens=max_new, seed=100 + i)
            for i, n in enumerate(lens)]
    moved = 0
    ticks = 0
    while (eng._sched.queue or any(r is not None for r in eng._slots)) \
            and ticks < 400:
        eng.step()
        ticks += 1
        if migrate_after is not None and ticks >= migrate_after \
                and moved == 0:
            live = [g for g in range(eng.n_slots) if eng._active[g]]
            for g in live:
                shard, slot = divmod(g, eng.slots_per_shard)
                dst = eng._sched.migration_target(shard, slot)
                if dst is not None:
                    eng._migrate_slot(shard, slot, dst)
                    eng.assert_pool_accounting()
                    eng.assert_local_page_tables()
                    moved += 1
                    break
    assert all(r.done for r in reqs)
    eng.assert_pool_accounting()
    return reqs, moved
"""


def _run(script: str):
    env = {**os.environ, "PYTHONPATH": "src"}
    r = subprocess.run([sys.executable, "-c", _PRELUDE + script], env=env,
                       capture_output=True, text=True, cwd=REPO)
    assert r.returncode == 0, (r.stdout[-2000:], r.stderr[-4000:])
    return r.stdout


def test_migration_exactness_dense_moe_8dev():
    """Mid-decode migration vs stay-put twin: dense/moe × {f32, int8}.
    The migrated stream must be bit-identical — pool-native byte moves
    cannot perturb schedule-independent KV rounding."""
    out = _run(r"""
for arch, kw in (("smollm-360m", {}),
                 ("smollm-360m", {"wdtype": "int8", "kv_dtype": "int8"}),
                 ("qwen2-moe-a2.7b", {}),
                 ("qwen2-moe-a2.7b", {"wdtype": "int8", "kv_dtype": "int8"})):
    model, params = build(arch)
    lens = [9, 17, 6]
    def eng():
        return ShardedServeEngine(model, mesh=mesh4, n_slots=8, max_len=64,
                                  params=params, page_size=8, **kw)
    stay, _ = run_traffic(eng(), lens)
    roam, moved = run_traffic(eng(), lens, migrate_after=2)
    assert moved == 1, (arch, kw, moved)
    for a, b in zip(stay, roam):
        assert a.out_tokens == b.out_tokens, (arch, kw, a.rid,
                                              a.out_tokens, b.out_tokens)
    print("OK", arch, kw.get("kv_dtype", "f32"))
print("MATRIX_DM_OK")
""")
    assert "MATRIX_DM_OK" in out, out[-2000:]


def test_migration_exactness_mla_8dev():
    """Mid-decode migration on the MLA latent-KV pool (deepseek-v2-lite:
    moe family + attn_kind='mla') × {f32, int8}: the latent rows move as
    pool-native bytes like any other pool entry."""
    out = _run(r"""
for kw in ({}, {"wdtype": "int8", "kv_dtype": "int8"}):
    model, params = build("deepseek-v2-lite")
    lens = [9, 17]
    def eng():
        return ShardedServeEngine(model, mesh=mesh4, n_slots=8, max_len=64,
                                  params=params, page_size=8, **kw)
    stay, _ = run_traffic(eng(), lens, max_new=3)
    roam, moved = run_traffic(eng(), lens, max_new=3, migrate_after=2)
    assert moved == 1, (kw, moved)
    for a, b in zip(stay, roam):
        assert a.out_tokens == b.out_tokens, (a.out_tokens, b.out_tokens)
    print("OK mla", kw.get("kv_dtype", "f32"))
print("MATRIX_MLA_OK")
""")
    assert "MATRIX_MLA_OK" in out, out[-2000:]


def test_drain_migration_vs_replay_8dev():
    """A sensor-driven DRAINING shard re-homes its live slots by page moves:
    tokens identical to BOTH the replay path and the fault-free twin, and —
    the O(bytes) vs O(FLOPs) point — at ZERO extra prefill chunks, where
    replay recomputes every displaced prompt."""
    out = _run(r"""
model, params = build("smollm-360m")
plan = FaultPlan(events=(
    FaultEvent(tick=4, kind="sensor_hot", shard=1, delta_c=60.0, ticks=8),))
lens = [5 + (i * 7) % 23 for i in range(5)]
runs = []
for p, mig in ((None, True), (plan, True), (plan, False)):
    eng = ShardedServeEngine(model, mesh=mesh4, n_slots=8, max_len=64,
                             params=params, page_size=8, n_pages=24,
                             fault_plan=p, migration=mig)
    reqs = [eng.submit(prompt(i, n), max_new_tokens=12, seed=100 + i)
            for i, n in enumerate(lens)]
    eng.run_to_completion()
    eng.assert_pool_accounting()
    eng.assert_local_page_tables()
    runs.append((eng, reqs))
(free, fr), (mig, mr), (rep, rr) = runs
for a, b, c in zip(fr, mr, rr):
    assert a.out_tokens == b.out_tokens == c.out_tokens, \
        (a.rid, a.out_tokens, b.out_tokens, c.out_tokens)
st = mig.stats
assert st.migrations >= 1 and st.migrated_pages >= 1, st.summary()
assert st.migrated_bytes_compressed > 0
assert st.recoveries >= 1                       # drain displaced work
assert st.recovery_ticks_sum >= st.recoveries   # link latency was charged
# zero re-prefilled chunks: the migration run prefills EXACTLY what the
# fault-free twin does, while replay recomputes the displaced prompts
assert st.prefill_chunks == free.stats.prefill_chunks, \
    (st.prefill_chunks, free.stats.prefill_chunks)
assert rep.stats.prefill_chunks > free.stats.prefill_chunks
assert rep.stats.migrations == 0
print("DRAIN_MIG_OK", st.migrations, st.migrated_pages)
""")
    assert "DRAIN_MIG_OK" in out, out[-2000:]


def test_migration_shared_cow_pages_8dev():
    """Refcounted prefix-shared pages migrate intact: the moving slot gets
    fresh physical copies on the destination, the staying sharer keeps the
    originals (ref drops by one, never corrupts), and both streams stay
    exact. Accounting is asserted on both shards right after the move."""
    out = _run(r"""
model, params = build("smollm-360m")
sysp = prompt(0, 16)

def traffic(eng, migrate):
    r0 = eng.submit(sysp.copy(), max_new_tokens=2)
    eng.run_to_completion()           # registers the 2-page prefix
    tails = [prompt(9, 5), prompt(10, 7)]
    rs = [eng.submit(np.concatenate([sysp, t]), max_new_tokens=10,
                     seed=50 + i) for i, t in enumerate(tails)]
    moved = 0
    for _ in range(200):
        eng.step()
        if migrate and not moved:
            # both sharers decode on the prefix home shard; move ONE
            for g in range(eng.n_slots):
                if eng._active[g] and eng._slots[g] in rs:
                    shard, slot = divmod(g, eng.slots_per_shard)
                    s = eng._sched.shards[shard]
                    if not any(s.ref[p] > 1
                               for p in s.slot_pages[slot].values()):
                        continue      # wait for a genuinely shared mapping
                    dst = eng._sched.migration_target(shard, slot)
                    if dst is not None:
                        eng._migrate_slot(shard, slot, dst)
                        eng.assert_pool_accounting()
                        eng.assert_local_page_tables()
                        moved = 1
                        break
        if all(r.done for r in rs):
            break
    assert all(r.done for r in rs)
    eng.assert_pool_accounting()
    return [list(r.out_tokens) for r in rs], moved

def eng():
    return ShardedServeEngine(model, mesh=mesh4, n_slots=8, max_len=64,
                              params=params, page_size=8)
base, _ = traffic(eng(), migrate=False)
roam, moved = traffic(eng(), migrate=True)
assert moved == 1
assert base == roam, (base, roam)
print("COW_MIG_OK")
""")
    assert "COW_MIG_OK" in out, out[-2000:]


def test_chaos_with_migration_8dev():
    """Full chaos geometry — deaths, rejoins, squeezes AND sensor storms —
    on an 8-shard mesh with migration on: token divergence vs the
    fault-free twin stays ZERO, and the sensor-driven drains actually take
    the migration path (DEAD shards still replay: their bytes are gone)."""
    out = _run(r"""
mesh8 = make_serve_mesh(8)
model, params = build("smollm-360m")
plan = chaos_plan(3, n_shards=8, n_ticks=48, deaths=1, death_dwell=12,
                  squeezes=2, squeeze_pages=6, squeeze_dwell=8,
                  sensor_storms=2, sensor_delta_c=60.0, sensor_ticks=8)
assert plan.counts()["sensor_hot"] >= 1
lens = [5 + (i * 7) % 23 for i in range(6)]
runs = []
for p in (None, plan):
    eng = ShardedServeEngine(model, mesh=mesh8, n_slots=8, max_len=64,
                             params=params, page_size=8, n_pages=16,
                             fault_plan=p)
    reqs = [eng.submit(prompt(i, n), max_new_tokens=12, seed=100 + i)
            for i, n in enumerate(lens)]
    eng.run_to_completion()
    eng.assert_pool_accounting()
    eng.assert_local_page_tables()
    runs.append((eng, reqs))
(base, br), (eng, cr) = runs
div = sum(a.out_tokens != b.out_tokens for a, b in zip(br, cr))
assert div == 0, div
st = eng.stats
assert st.faults_injected >= 3, st.faults_injected
assert st.migrations >= 1, st.summary()     # a drain went over the link
assert st.recoveries >= 1
print("CHAOS_MIG_OK", st.migrations, st.recoveries)
""")
    assert "CHAOS_MIG_OK" in out, out[-2000:]


def test_rebalance_and_rescue_8dev():
    """Elastic rebalancing: after a drained shard rejoins empty, the
    busy-slot gap pulls live slots back onto it — occupancy imbalance drops
    and NO token changes. Starvation rescue: a page-starved queue head is
    admitted by migrating a victim away instead of preempting it (fewer
    preemptions, same tokens)."""
    out = _run(r"""
model, params = build("smollm-360m")

# -- rebalance: drain empties shard 0; with threshold=1 the post-rejoin
#    busy gap (2 vs 0) migrates work back
plan = FaultPlan(events=(
    FaultEvent(tick=4, kind="sensor_hot", shard=0, delta_c=60.0, ticks=8),))
lens = [9, 12, 15, 18, 11, 14]
out_toks, imb, rebal = {}, {}, {}
for thr in (0, 1):
    eng = ShardedServeEngine(model, mesh=mesh4, n_slots=8, max_len=96,
                             params=params, page_size=8, n_pages=36,
                             fault_plan=plan, rebalance_threshold=thr)
    reqs = [eng.submit(prompt(i, n), max_new_tokens=24, seed=100 + i)
            for i, n in enumerate(lens)]
    eng.run_to_completion()
    eng.assert_pool_accounting()
    eng.assert_local_page_tables()
    out_toks[thr] = [list(r.out_tokens) for r in reqs]
    imb[thr] = eng.shard_summary()["occupancy_imbalance"]
    rebal[thr] = eng.stats.rebalance_events
assert out_toks[0] == out_toks[1], "rebalancing changed tokens"
assert rebal[0] == 0 and rebal[1] >= 1, rebal
assert imb[1] < imb[0], imb
assert imb[1] < 0.67, imb
print("REBALANCE_OK", rebal[1], round(imb[0], 3), "->", round(imb[1], 3))
""")
    assert "REBALANCE_OK" in out, out[-2000:]


def test_ucie_single_call_path():
    """The serving stack and the time-stepped simulator consume ONE link
    cost model: `core/ucie.transfer` (via `ucie.migration_ticks`). No
    serving module re-derives bandwidth/flit/latency math of its own, and
    the tick conversion is pinned numerically against transfer()."""
    from repro.core import soc, ucie
    from repro.serve import migration, scheduler, sharded

    # the ONE coupling point exists and routes through transfer()
    mig_src = inspect.getsource(migration)
    assert "ucie.migration_ticks(" in mig_src
    tick_src = inspect.getsource(ucie.migration_ticks)
    assert "transfer(" in tick_src
    # the simulator drains through the same closed form
    assert "ucie_mod.transfer(" in inspect.getsource(soc)
    # no serving module owns link math — enforced by contract rule R1
    # (analysis/contracts): link fields, wire constants, hard-coded
    # bandwidth numbers and direct transfer() calls outside the sanctioned
    # migration_cost wrapper are all findings
    import pathlib

    from repro.analysis.contracts import run_rules

    repo_root = pathlib.Path(__file__).resolve().parents[1]
    findings = run_rules(repo_root, rules=["R1"])
    assert findings == [], "\n".join(str(f) for f in findings)
    del scheduler, sharded  # imported to prove the modules still load
    # numeric pin: ticks == ceil(transfer_time_us / tick_us), never 0
    cfg = ucie.UCIeConfig()
    for payload, tick_us in ((4096.0, 1000.0), (262144.0, 50.0),
                             (1.0, 1000.0)):
        t_us, _, _ = ucie.transfer(payload, cfg)
        want = max(1, int(-(-float(t_us) // tick_us)))
        got = ucie.migration_ticks(payload, cfg, tick_us=tick_us)
        assert got == want, (payload, tick_us, got, want)
    # compressed wire bytes are what migration accounts
    ticks, wire = migration.migration_cost(
        4096.0, migration.MigrationConfig())
    _, _, want_wire = ucie.transfer(4096.0, cfg)
    assert ticks >= 1 and wire == float(want_wire)


def test_inflight_prefix_dedup_single_host():
    """Identical prompts submitted together coalesce: the second holds at
    admission while the first prefills, then rides its registered pages —
    the PAIR costs exactly one cold prefill's chunks. The claim dies with
    its owner (cancel mid-prefill ⇒ the twin proceeds alone)."""
    import jax
    from repro.configs import get_config
    from repro.models import ExecOptions, build_model
    from repro.serve.engine import ServeEngine

    cfg = get_config("smollm-360m").smoke()
    model = build_model(cfg, ExecOptions(attn_impl="reference", ce_chunk=32))
    params = model.init(jax.random.key(1))
    pr = np.asarray(jax.random.randint(
        jax.random.key(0), (32,), 0, 512), np.int32)

    def eng():
        return ServeEngine(model, n_slots=4, max_len=64, params=params,
                           page_size=8, prefix_cache=True)

    solo = eng()
    sr = solo.submit(pr.copy(), max_new_tokens=4)
    solo.run_to_completion()

    pair = eng()
    a = pair.submit(pr.copy(), max_new_tokens=4)
    b = pair.submit(pr.copy(), max_new_tokens=4)
    pair.run_to_completion()
    pair.assert_accounting()
    assert a.out_tokens == b.out_tokens
    st = pair.stats
    # the deferred twin full-hits (shared run + COW tail): zero extra chunks
    assert st.prefill_chunks == solo.stats.prefill_chunks, \
        (st.prefill_chunks, solo.stats.prefill_chunks)
    assert st.prefix_hits == 1 and st.prefix_hit_tokens >= 32, \
        (st.prefix_hits, st.prefix_hit_tokens)
    assert not pair._pending_digest and not pair._pending_by_rid

    # owner cancelled mid-prefill: the claim clears, the twin prefills
    canc = eng()
    a = canc.submit(pr.copy(), max_new_tokens=4)
    b = canc.submit(pr.copy(), max_new_tokens=4)
    canc.step()
    canc.cancel(a)
    canc.run_to_completion()
    canc.assert_accounting()
    assert b.done and not b.timed_out
    assert b.out_tokens == sr.out_tokens, (b.out_tokens, sr.out_tokens)
    assert not canc._pending_digest and not canc._pending_by_rid


def test_prefix_replication_8dev():
    """Cross-shard prefix reuse: when the hot-prefix home shard is full,
    the registered pages replicate to an admitting shard over the move
    primitive — the new request hits the cache THERE (no re-prefill) and
    its tokens match a cold twin's exactly."""
    out = _run(r"""
model, params = build("smollm-360m")
sysp = prompt(0, 16)            # 2 full pages of shared prefix

def traffic(eng):
    r0 = eng.submit(sysp.copy(), max_new_tokens=2)
    eng.run_to_completion()     # register on the home shard
    # two same-prefix admissions make the prefix HOT (min_prefix_hits=2)
    # and pin BOTH home-shard slots with long decodes
    rs = [eng.submit(np.concatenate([sysp, prompt(9 + i, 5 + i)]),
                     max_new_tokens=40, seed=50 + i) for i in range(2)]
    for _ in range(6):
        eng.step()
    # home shard full ⇒ the next same-prefix head must admit elsewhere
    r3 = eng.submit(np.concatenate([sysp, prompt(20, 6)]),
                    max_new_tokens=6, seed=70)
    eng.run_to_completion()
    eng.assert_pool_accounting()
    eng.assert_local_page_tables()
    return r3, eng

r3, eng = traffic(ShardedServeEngine(model, mesh=mesh4, n_slots=8,
                                     max_len=96, params=params, page_size=8))
assert eng.stats.migrated_pages >= 2, eng.stats.summary()   # pages flew
assert r3.cached_prompt_tokens >= 16, r3.cached_prompt_tokens

# replication off: same traffic, same tokens, but the prefix re-prefills
r3_off, eng_off = traffic(ShardedServeEngine(
    model, mesh=mesh4, n_slots=8, max_len=96, params=params, page_size=8,
    migration=False))
assert eng_off.stats.migrated_pages == 0
assert r3.out_tokens == r3_off.out_tokens, (r3.out_tokens, r3_off.out_tokens)
assert eng.stats.prefill_chunks < eng_off.stats.prefill_chunks, \
    (eng.stats.prefill_chunks, eng_off.stats.prefill_chunks)
print("REPLICATION_OK", eng.stats.migrated_pages)
""")
    assert "REPLICATION_OK" in out, out[-2000:]
