"""Prefix caching with copy-on-write pages (PR 8).

The ref-counted, content-addressed page allocator lets requests that share
a page-aligned prompt prefix decode off the SAME physical pages: a warmup
request registers its prompt pages at finalize (sha1 digest chain over
page-aligned token bytes), later requests point their page-table rows at
the hits, bump refcounts, and resume chunked prefill mid-prompt. The page
the first-token replay writes is NEVER shared — a fully-cached tail is
copy-on-write cloned into a private page — so decode always lands on
private storage. Invariants pinned here:

  * token parity — cached engines emit IDENTICAL streams to cache-off
    twins on the same submissions (PR 4's schedule-independent KV rounding
    makes shared prefixes token-exact), greedy and sampled;
  * a full-page-aligned duplicate prompt is a FULL HIT: zero prefill
    chunks, one COW clone, first token on the next tick;
  * sharing is prefix-contiguous: divergence inside the first page shares
    nothing; prompts shorter than one page never register;
  * every retirement path (done / cancel mid-prefill / TTL) decrefs
    through the allocator — pages return to the LRU at refcount zero and
    the partition invariant free + live + lru + stolen == n_pages - 1
    holds at every boundary (`assert_accounting`);
  * LRU eviction under pool pressure steals cached pages oldest-first and
    page_squeeze faults dip into the LRU after the free list, with chaos
    parity intact;
  * sliding-window configs silently disable the cache (window recycling
    rewrites remapped pages in place — incompatible with sharing);
  * the sharded engine shares shard-locally with cache-aware placement,
    token-identical to the single-host engine on an 8-device mesh.
"""

import os
import subprocess
import sys

import jax
import numpy as np
import pytest

from repro.configs import get_config
from repro.models import ExecOptions, build_model
from repro.launch.mesh import make_serve_mesh
from repro.serve.engine import ServeEngine
from repro.serve.faults import FaultEvent, FaultPlan
from repro.serve.sharded import ShardedServeEngine

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


@pytest.fixture(scope="module")
def smol():
    cfg = get_config("smollm-360m").smoke()
    model = build_model(cfg, ExecOptions(attn_impl="reference", ce_chunk=32))
    params = model.init(jax.random.key(0))
    return cfg, model, params


def _prompt(seed, n=12, vocab=512):
    return np.asarray(
        jax.random.randint(jax.random.key(seed), (n,), 0, vocab), np.int32)


def _engine(model, params, cache=None, **kw):
    kw.setdefault("n_slots", 4)
    kw.setdefault("max_len", 96)
    kw.setdefault("page_size", 8)
    kw.setdefault("chunk_pages", 1)
    return ServeEngine(model, params=params, prefix_cache=cache, **kw)


def _shared_wave(eng, sysp, n=4, new=6, sample=False):
    """Warmup registers `sysp`; returns (warmup, wave) after completion."""
    warm = eng.submit(sysp, max_new_tokens=4)
    eng.run_to_completion()
    wave = []
    for i in range(n):
        tail = _prompt(100 + i, 4 + 3 * i)
        sp = (0.8, 40, 0.95) if sample and i % 2 else None
        wave.append(eng.submit(np.concatenate([sysp, tail]),
                               max_new_tokens=new, sample_params=sp,
                               seed=50 + i))
    eng.run_to_completion()
    return warm, wave


# ------------------------------------------------------------- token parity
def test_shared_prefix_parity_and_page_savings(smol):
    """Cached vs cache-off twins on the same warmup + shared-prefix wave
    (greedy AND sampled): identical streams, strictly lower peak pool
    pages, hit counters advance, pool balances to the page."""
    _, model, params = smol
    sysp = _prompt(7, 48)
    legs = {}
    for cache in (True, False):
        eng = _engine(model, params, cache)
        warm, wave = _shared_wave(eng, sysp, sample=True)
        eng.assert_accounting()
        legs[cache] = (eng, [list(r.out_tokens) for r in [warm] + wave])
    eng_c, toks_c = legs[True]
    eng_u, toks_u = legs[False]
    assert toks_c == toks_u
    assert eng_c.stats.peak_pages_in_use < eng_u.stats.peak_pages_in_use
    assert eng_c.stats.prefix_hits == 4
    # every wave request shares the pages before the replay-written tail:
    # tail = (plen-1)//8 >= 6, warmup registered 48//8 = 6 pages
    assert eng_c.stats.prefix_hit_tokens == 4 * 48
    assert eng_u.stats.prefix_hits == eng_u.stats.prefix_misses == 0
    # fewer prompt tokens actually prefilled on the cached engine
    assert eng_c.stats.prefill_tokens < eng_u.stats.prefill_tokens
    for eng in (eng_c, eng_u):
        assert eng.stats.pages_in_use == 0
        assert eng.pages_allocatable() == eng.n_pages - 1


def test_full_hit_skips_prefill_entirely(smol):
    """A page-aligned duplicate prompt hits every page: the last one is COW
    cloned (the replay write must not touch shared storage), NO prefill
    chunks run, and the first token arrives on the next tick."""
    _, model, params = smol
    eng = _engine(model, params, True)
    sysp = _prompt(3, 32)                      # 32 % 8 == 0: full-hit shape
    warm = eng.submit(sysp, max_new_tokens=4)
    eng.run_to_completion()
    chunks0 = eng.stats.prefill_chunks
    dup = eng.submit(sysp.copy(), max_new_tokens=4)
    eng.run_to_completion()
    assert dup.out_tokens == warm.out_tokens
    assert eng.stats.prefill_chunks == chunks0          # zero chunks
    assert eng.stats.cow_copies == 1
    assert eng.stats.prefix_hit_tokens == 32
    assert dup.first_token_tick - dup.submit_tick == 1  # next tick
    eng.assert_accounting()


def test_divergence_inside_first_page_shares_nothing(smol):
    """Prompts that differ inside page 0 have no common page-aligned
    prefix: zero hits, yet both decode exactly as a fresh engine would."""
    _, model, params = smol
    a = _prompt(11, 24)
    b = a.copy()
    b[2] = (b[2] + 1) % 512                    # diverge at token 2
    eng = _engine(model, params, True)
    ra = eng.submit(a, max_new_tokens=4)
    eng.run_to_completion()
    rb = eng.submit(b, max_new_tokens=4)
    eng.run_to_completion()
    assert eng.stats.prefix_hits == 0 and eng.stats.prefix_hit_tokens == 0
    fresh = _engine(model, params, False)
    fa = fresh.submit(a, max_new_tokens=4)
    fb = fresh.submit(b, max_new_tokens=4)
    fresh.run_to_completion()
    assert ra.out_tokens == fa.out_tokens
    assert rb.out_tokens == fb.out_tokens
    eng.assert_accounting()


def test_prompt_shorter_than_one_page(smol):
    """A sub-page prompt has no page-aligned prefix to register or hit —
    its only page is the replay-written tail. Twice the same short prompt:
    identical tokens, zero hits, zero registrations."""
    _, model, params = smol
    p = _prompt(5, 5)
    eng = _engine(model, params, True)
    r1 = eng.submit(p, max_new_tokens=4)
    eng.run_to_completion()
    r2 = eng.submit(p.copy(), max_new_tokens=4)
    eng.run_to_completion()
    assert r1.out_tokens == r2.out_tokens
    assert eng.stats.prefix_hits == 0
    assert eng.stats.prefix_cached_pages == 0   # nothing ever registered
    eng.assert_accounting()
    assert eng.pages_allocatable() == eng.n_pages - 1


# -------------------------------------------------------- retirement paths
def test_cancel_mid_prefill_on_shared_pages(smol):
    """Cancelling a sharer mid-prefill decrefs its shared pages without
    freeing the registry copy other requests still read."""
    _, model, params = smol
    eng = _engine(model, params, True, n_slots=2)
    sysp = _prompt(9, 48)
    warm = eng.submit(sysp, max_new_tokens=4)
    eng.run_to_completion()
    # two sharers; each still prefills its private tail over several ticks
    tail_a, tail_b = _prompt(201, 17), _prompt(202, 17)
    ra = eng.submit(np.concatenate([sysp, tail_a]), max_new_tokens=4)
    rb = eng.submit(np.concatenate([sysp, tail_b]), max_new_tokens=4)
    eng.step()                      # admitted, first chunk ran
    assert eng.stats.prefix_hits == 2
    eng.cancel(ra)                  # mid-prefill on shared pages
    eng.assert_accounting()
    eng.run_to_completion()
    assert not ra.out_tokens and rb.done
    # the survivor decodes exactly what it would have without the cancel
    twin = _engine(model, params, True, n_slots=2)
    tw = twin.submit(sysp, max_new_tokens=4)
    twin.run_to_completion()
    tb = twin.submit(np.concatenate([sysp, tail_b]), max_new_tokens=4)
    twin.run_to_completion()
    assert tw.out_tokens == warm.out_tokens
    assert tb.out_tokens == rb.out_tokens
    eng.assert_accounting()
    assert eng.pages_allocatable() == eng.n_pages - 1


def test_lru_eviction_under_pool_pressure(smol):
    """A tight pool evicts cached (refcount-zero) pages oldest-first to
    serve new traffic; the evicted prefix stops hitting but decodes
    correctly when resubmitted."""
    _, model, params = smol
    eng = _engine(model, params, True, n_slots=2, max_len=64, n_pages=7)
    sysp = _prompt(13, 24)                     # 3 registered pages
    warm = eng.submit(sysp, max_new_tokens=4)
    eng.run_to_completion()
    assert eng.stats.prefix_cached_pages == 3
    big = eng.submit(_prompt(14, 40), max_new_tokens=4)   # needs 6 pages
    eng.run_to_completion()
    assert big.done
    assert eng.stats.prefix_evictions > 0
    again = eng.submit(sysp.copy(), max_new_tokens=4)
    eng.run_to_completion()
    assert again.out_tokens == warm.out_tokens   # correct, just cold(er)
    eng.assert_accounting()
    assert eng.pages_allocatable() == eng.n_pages - 1


def test_squeeze_steals_cached_pages_with_parity(smol):
    """page_squeeze dips into the LRU once the free list is dry: cached
    pages are sacrificed (counted as evictions), tokens stay IDENTICAL to
    a fault-free twin, and the restore returns every stolen page."""
    _, model, params = smol
    sysp = _prompt(17, 32)
    kw = dict(n_slots=2, max_len=64, n_pages=11)

    def leg(plan):
        eng = _engine(model, params, True, fault_plan=plan, **kw)
        warm, wave = _shared_wave(eng, sysp, n=3, new=4)
        eng.assert_accounting()
        return eng, [list(r.out_tokens) for r in [warm] + wave]

    # probe the (deterministic) tick at which the warmup's pages reach the
    # LRU, so the squeeze provably has only 6 free pages for its 8 — the
    # 2-page remainder MUST come from evicting registered cache pages
    probe = _engine(model, params, True, **kw)
    probe.submit(sysp, max_new_tokens=4)
    probe.run_to_completion()
    t = probe._tick + 1
    assert probe.stats.prefix_cached_pages == 4   # 32 // 8 registered
    plan = FaultPlan(events=(
        FaultEvent(tick=t, kind="page_squeeze", pages=8),
        FaultEvent(tick=t + 6, kind="page_restore")))
    eng_b, toks_b = leg(None)
    eng_f, toks_f = leg(plan)
    assert eng_f.stats.faults_injected == 2
    assert eng_f.stats.prefix_evictions == 2      # LRU sacrificed 8 - 6
    assert toks_b == toks_f
    assert not eng_f._stolen_pages               # restore returned them
    assert eng_f.pages_allocatable() == eng_f.n_pages - 1


# ------------------------------------------------------------ configuration
def test_window_silently_disables_prefix_cache(smol):
    """Sliding-window recycling rewrites remapped pages in place — sharing
    them would corrupt other readers, so windowed engines run cache-off
    even when asked (silently: the flag is a hint, the window a config)."""
    import dataclasses
    cfg, _, _ = smol
    wcfg = dataclasses.replace(cfg, window=16)
    wmodel = build_model(wcfg, ExecOptions(attn_impl="reference",
                                           ce_chunk=32))
    wparams = wmodel.init(jax.random.key(2))
    eng = ServeEngine(wmodel, n_slots=2, max_len=96, params=wparams,
                      page_size=8, prefix_cache=True)
    assert eng.prefix_cache is False
    p = _prompt(19, 40)
    r1 = eng.submit(p, max_new_tokens=4)
    eng.run_to_completion()
    r2 = eng.submit(p.copy(), max_new_tokens=4)
    eng.run_to_completion()
    assert r1.out_tokens == r2.out_tokens
    assert eng.stats.prefix_hits == 0
    eng.assert_accounting()


def test_explicit_prefix_cache_needs_paged_chunked(smol):
    """prefix_cache=True names the paged+chunked datapath — asking for it
    on an engine without one is a config error, not a silent no-op."""
    _, model, params = smol
    with pytest.raises(ValueError):
        ServeEngine(model, n_slots=2, max_len=64, params=params,
                    paged=False, prefix_cache=True)
    with pytest.raises(ValueError):
        ServeEngine(model, n_slots=2, max_len=64, params=params,
                    page_size=8, chunked_prefill=False, prefix_cache=True)
    # opting OUT is always legal, and the refcount machinery still balances
    eng = ServeEngine(model, n_slots=2, max_len=64, params=params,
                      page_size=8, prefix_cache=False)
    r = eng.submit(_prompt(1, 20), max_new_tokens=4)
    eng.run_to_completion()
    assert r.done
    eng.assert_accounting()


def test_ttft_tpot_percentiles_in_summary(smol):
    """EngineStats.summary() emits per-request TTFT/TPOT p50/p99 (wall) —
    the SLO surface roadmap item 4 consumes."""
    _, model, params = smol
    eng = _engine(model, params)
    for i in range(3):
        eng.submit(_prompt(30 + i, 10 + 5 * i), max_new_tokens=6)
    eng.run_to_completion()
    s = eng.stats.summary()
    for k in ("ttft_p50_s", "ttft_p99_s", "tpot_p50_s", "tpot_p99_s"):
        assert k in s and s[k] >= 0.0
    assert s["ttft_p50_s"] > 0.0 and s["ttft_p99_s"] >= s["ttft_p50_s"]


# ------------------------------------------------------------------ sharded
def test_sharded_prefix_cache_single_shard_parity(smol):
    """A 1-shard sharded engine with the cache on degenerates exactly to
    the single-host cached engine — placement, COW, full hits and all."""
    _, model, params = smol
    sysp = _prompt(23, 32)
    single = _engine(model, params, True)
    sw, swave = _shared_wave(single, sysp)
    sdup = single.submit(sysp.copy(), max_new_tokens=4)   # full hit
    single.run_to_completion()
    eng = ShardedServeEngine(model, mesh=make_serve_mesh(1), n_slots=4,
                             max_len=96, params=params, page_size=8,
                             chunk_pages=1, prefix_cache=True)
    w, wave = _shared_wave(eng, sysp)
    dup = eng.submit(sysp.copy(), max_new_tokens=4)
    eng.run_to_completion()
    assert [list(r.out_tokens) for r in [w] + wave + [dup]] \
        == [list(r.out_tokens) for r in [sw] + swave + [sdup]]
    assert eng.stats.prefix_hits == single.stats.prefix_hits
    assert eng.stats.cow_copies == single.stats.cow_copies >= 1
    eng.assert_pool_accounting()
    eng.assert_local_page_tables()


_PRELUDE = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import jax, numpy as np
from repro.configs import get_config
from repro.models import ExecOptions, build_model
from repro.launch.mesh import make_serve_mesh
from repro.serve.engine import ServeEngine
from repro.serve.sharded import ShardedServeEngine

cfg = get_config("smollm-360m").smoke()
model = build_model(cfg, ExecOptions(attn_impl="reference", ce_chunk=32))
params = model.init(jax.random.key(1))

def prompt(seed, n, vocab=512):
    return np.asarray(jax.random.randint(
        jax.random.key(seed), (n,), 0, vocab), np.int32)
"""


def _run(script: str):
    env = {**os.environ, "PYTHONPATH": "src"}
    r = subprocess.run([sys.executable, "-c", _PRELUDE + script], env=env,
                       capture_output=True, text=True, cwd=REPO)
    assert r.returncode == 0, (r.stdout[-2000:], r.stderr[-4000:])
    return r.stdout


def test_sharded_prefix_parity_8dev():
    """8-device mesh: cache-aware placement routes sharers to the shard
    holding the prefix (shard-local registries, device-local page ids);
    cached and cache-off engines emit identical streams, and a sequential
    aligned duplicate is a full hit with a COW clone."""
    _run(r"""
mesh = make_serve_mesh()
sysp = prompt(7, 24)

def leg(cache):
    eng = ShardedServeEngine(model, mesh=mesh, n_slots=16, max_len=64,
                             params=params, page_size=8, chunk_pages=1,
                             prefix_cache=cache)
    warm = eng.submit(sysp, max_new_tokens=4)
    eng.run_to_completion()
    reqs = [eng.submit(np.concatenate([sysp, prompt(100 + i, 5 + i)]),
                       max_new_tokens=6, seed=50 + i) for i in range(4)]
    reqs.append(eng.submit(prompt(40, 5), max_new_tokens=6))
    eng.run_to_completion()
    dup = eng.submit(sysp.copy(), max_new_tokens=4)   # 24 % 8 == 0
    eng.run_to_completion()
    eng.assert_pool_accounting()
    eng.assert_local_page_tables()
    return eng, [list(r.out_tokens) for r in [warm] + reqs + [dup]]

eng_c, toks_c = leg(True)
eng_u, toks_u = leg(False)
assert toks_c == toks_u, (toks_c, toks_u)
assert toks_c[-1] == toks_c[0], toks_c        # dup replays the warmup
assert eng_c.stats.prefix_hits >= 3, eng_c.stats.prefix_hits
assert eng_c.stats.cow_copies >= 1
assert eng_c.stats.peak_pages_in_use < eng_u.stats.peak_pages_in_use, \
    (eng_c.stats.peak_pages_in_use, eng_u.stats.peak_pages_in_use)
assert eng_u.stats.prefix_hits == 0
print("OK")
""")
