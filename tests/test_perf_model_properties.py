"""Property-based tests (hypothesis) on the reconstructed simulator's
invariants — the system-level contracts the paper's design arguments rest on."""

import dataclasses
import math

import jax
import jax.numpy as jnp
import pytest

pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st

from repro.core import perf_model as pm
from repro.core.scenarios import AI_OPTIMIZED, BASIC_CHIPLET, Scenario
from repro.core.workloads import Workload

settings.register_profile("ci", max_examples=40, deadline=None)
settings.load_profile("ci")

scenario_st = st.builds(
    Scenario,
    name=st.just("prop"),
    link_latency_us=st.floats(0.0, 20.0),
    link_bandwidth_gbps=st.floats(1.0, 128.0),
    base_power_mw=st.floats(300.0, 3000.0),
    comm_power_mw_per_ms=st.floats(0.0, 100.0),
    efficiency_factor=st.floats(0.5, 1.5),
    throttle_threshold=st.floats(0.5, 1.0),
    static_power_ratio=st.floats(0.1, 0.8),
    voltage_scale=st.floats(0.8, 1.2),
    protocol_overhead=st.floats(1.0, 1.5),
)

workload_st = st.builds(
    Workload,
    name=st.just("w"),
    base_compute_ms=st.floats(0.5, 20.0),
    input_size_mb=st.floats(0.05, 5.0),
    complexity_factor=st.floats(0.3, 2.0),
    batch_efficiency=st.floats(0.5, 1.0),
    gops_per_inference=st.floats(0.1, 10.0),
)

batch_st = st.sampled_from([1, 2, 4, 8, 16, 32])


@given(scenario_st, workload_st, batch_st)
def test_outputs_positive_and_finite(s, w, b):
    r = pm.predict(s, w, b)
    for f in ("latency_ms", "throughput_ips", "power_mw", "tops_per_w",
              "energy_mj"):
        v = float(getattr(r, f))
        assert math.isfinite(v) and v > 0.0, (f, v)


@given(scenario_st, workload_st, batch_st)
def test_throughput_identity(s, w, b):
    r = pm.predict(s, w, b)
    assert float(r.throughput_ips) == pytest.approx(
        1000.0 * b / float(r.latency_ms), rel=1e-4)


@given(scenario_st, workload_st, batch_st)
def test_more_bandwidth_never_hurts(s, w, b):
    fast = dataclasses.replace(s, link_bandwidth_gbps=s.link_bandwidth_gbps * 2)
    assert float(pm.predict(fast, w, b).latency_ms) \
        <= float(pm.predict(s, w, b).latency_ms) + 1e-5


@given(scenario_st, workload_st, batch_st)
def test_lower_link_latency_never_hurts(s, w, b):
    snappy = dataclasses.replace(s, link_latency_us=s.link_latency_us * 0.5)
    assert float(pm.predict(snappy, w, b).latency_ms) \
        <= float(pm.predict(s, w, b).latency_ms) + 1e-5


@given(scenario_st, workload_st, batch_st)
def test_prefetch_overlap_never_hurts(s, w, b):
    ov = dataclasses.replace(s, prefetch_overlap=True)
    assert float(pm.predict(ov, w, b).latency_ms) \
        <= float(pm.predict(s, w, b).latency_ms) + 1e-5


@given(scenario_st, workload_st, batch_st)
def test_compression_reduces_comm_time(s, w, b):
    comp = dataclasses.replace(s, compression_ratio=0.5)
    assert float(pm.predict(comp, w, b).t_comm_ms) \
        <= float(pm.predict(s, w, b).t_comm_ms) + 1e-6


@given(scenario_st, workload_st)
def test_batching_amortizes(s, w):
    """Per-image latency at batch 32 ≤ at batch 1 when batching is efficient
    and the design never throttles (throttle_threshold ≥ 1)."""
    s = dataclasses.replace(s, throttle_threshold=1.0)
    r1 = pm.predict(s, w, 1)
    r32 = pm.predict(s, w, 32)
    assert float(r32.latency_ms) / 32 <= float(r1.latency_ms) * 1.02


@given(workload_st, batch_st)
def test_paper_scenarios_ordering_robust_across_workloads(w, b):
    """AI-optimized ≥ basic chiplet for any plausible workload (the paper's
    central claim is not MobileNetV2-specific)."""
    ai = pm.predict(AI_OPTIMIZED, w, b)
    basic = pm.predict(BASIC_CHIPLET, w, b)
    assert float(ai.latency_ms) <= float(basic.latency_ms) * 1.001
    assert float(ai.power_mw) <= float(basic.power_mw) * 1.001


@given(scenario_st, workload_st, batch_st)
def test_grid_matches_pointwise(s, w, b):
    grid = pm.predict_grid([s], [w], [b])
    point = pm.predict(s, w, b)
    assert float(grid.latency_ms[0, 0, 0]) == pytest.approx(
        float(point.latency_ms), rel=1e-5)


@given(scenario_st, workload_st)
def test_gradients_finite_everywhere(s, w):
    def lat(v):
        return pm.predict_vec(v, w.as_vector(), jnp.float32(4.0)).latency_ms

    g = jax.grad(lat)(s.as_vector())
    assert bool(jnp.all(jnp.isfinite(g)))
