"""Chunked page-granular prefill + per-slot sampling (PR 4).

The chunked engine streams fixed-size prefill chunks straight into the page
pool, interleaved with the decode batch. These tests pin:
  * token-exactness vs the dense `generate_greedy` oracle for all four
    attention families × {f32, bf16, int8} KV, at chunk sizes that do and
    don't divide the prompt length — plus the `mla` latent-KV family (PR 7),
    which rides the SAME unified `attn_block` chunk mode with a single
    latent pool;
  * the mirror-drift guard (PR 7): no `_project_qkv` / `apply_rope` call
    sites outside the shared attention core;
  * the capacity edges under chunked admission (page-boundary prompt
    lengths ±1, plen == max_len, max_new_tokens = 1) — no extra page
    reserved, none leaked;
  * pool reuse under pressure while chunks are still queued (slots that
    retire mid-prefill-of-others must free pages the queue can take without
    corrupting the in-flight chunk stream);
  * windowed slots hold O(window) pages while PREFILLING a prompt longer
    than the window;
  * sampling determinism (same seed → same tokens; temperature=0 ≡ greedy)
    and the head-of-line-blocking metrics (chunked stall ticks = 0, pad
    waste ≤ one chunk per prompt).
"""

import dataclasses

import jax
import numpy as np
import pytest

from repro.configs import get_config
from repro.models import ExecOptions, build_model
from repro.serve.engine import ServeEngine, generate_greedy


def _prompt(seed, n, vocab=512):
    return np.asarray(
        jax.random.randint(jax.random.key(seed), (n,), 0, vocab), np.int32)


def _build(arch, key=1):
    cfg = get_config(arch).smoke()
    model = build_model(cfg, ExecOptions(attn_impl="reference", ce_chunk=32))
    params = model.init(jax.random.key(key))
    extras = None
    if cfg.family == "encdec":
        extras = {"frames": np.asarray(jax.random.normal(
            jax.random.key(9), (cfg.cross_len, cfg.d_model)), np.float32)}
    if cfg.family == "vlm":
        extras = {"patch_embeds": np.asarray(jax.random.normal(
            jax.random.key(8), (cfg.n_image_tokens, cfg.d_model)),
            np.float32)}
    return cfg, model, params, extras


@pytest.fixture(scope="module")
def smol():
    return _build("smollm-360m")


# ---------------------------------------------------------------- equivalence
def test_chunked_exact_across_chunk_divisibility(smol):
    """Chunk sizes that do (8 | 16) and don't (8 ∤ 13, 16 ∤ 17) divide the
    prompt must all reproduce the dense oracle exactly, with ONE chunk
    compile regardless of how many prompts/chunks ran."""
    cfg, model, params, _ = smol
    lengths = (8, 13, 16, 17, 31, 33)
    solo = {n: generate_greedy(model, params, _prompt(n, n), n_tokens=4,
                               max_len=64)
            for n in lengths}
    for chunk_pages in (1, 2):
        eng = ServeEngine(model, n_slots=2, max_len=64, params=params,
                          page_size=8, chunk_pages=chunk_pages)
        assert eng.chunked and eng.chunk_tokens == 8 * chunk_pages
        reqs = {n: eng.submit(_prompt(n, n), max_new_tokens=4)
                for n in lengths}
        eng.run_to_completion()
        for n in lengths:
            assert reqs[n].done
            assert reqs[n].out_tokens == solo[n], \
                (chunk_pages, n, reqs[n].out_tokens, solo[n])
        assert eng.stats.chunk_compiles == 1
        assert eng.stats.prefill_compiles == 0
        assert eng.stats.pages_in_use == 0
        assert eng.pages_allocatable() == eng.n_pages - 1


@pytest.mark.parametrize("kv_dtype", [None, "bf16", "int8"])
def test_chunked_dense_family_kv_dtypes(smol, kv_dtype):
    """f32 / bf16 / int8 KV pools all stay token-exact: prefill attends the
    rounded values the cache stores (models/transformer._round_kv), so the
    chunk path (which reads the pool) and the monolithic oracle see
    identical numerics."""
    cfg, model, params, _ = smol
    for n in (9, 17):
        solo = generate_greedy(model, params, _prompt(n, n), n_tokens=4,
                               max_len=64, kv_dtype=kv_dtype)
        eng = ServeEngine(model, n_slots=2, max_len=64, params=params,
                          page_size=8, kv_dtype=kv_dtype)
        r = eng.submit(_prompt(n, n), max_new_tokens=4)
        eng.run_to_completion()
        assert r.out_tokens == solo, (kv_dtype, n, r.out_tokens, solo)


@pytest.mark.parametrize("arch", ["qwen2-moe-a2.7b", "llava-next-mistral-7b",
                                  "seamless-m4t-medium"])
def test_chunked_families_exact(arch):
    """moe / vlm / encdec chunked engines == their dense oracles, across a
    chunk boundary (prompt 17 > chunk 16). vlm chunks slice the patch
    embeddings per chunk; encdec computes cross K/V once at admission."""
    cfg, model, params, extras = _build(arch)
    for n in (9, 17):
        solo = generate_greedy(model, params, _prompt(n, n), n_tokens=3,
                               max_len=64, extras=extras)
        eng = ServeEngine(model, n_slots=2, max_len=64, params=params,
                          page_size=8)
        assert eng.chunked
        r = eng.submit(_prompt(n, n), max_new_tokens=3, extras=extras)
        eng.run_to_completion()
        assert r.out_tokens == solo, (arch, n, r.out_tokens, solo)
        assert eng.stats.pages_in_use == 0


@pytest.mark.slow
@pytest.mark.parametrize("arch", ["qwen2-moe-a2.7b", "llava-next-mistral-7b",
                                  "seamless-m4t-medium"])
@pytest.mark.parametrize("kv_dtype", ["bf16", "int8"])
def test_chunked_families_kv_matrix(arch, kv_dtype):
    """Full family × KV-dtype matrix (the tier-1 run carries the f32 legs
    and the dense-family dtype legs; this sweep completes the grid)."""
    cfg, model, params, extras = _build(arch)
    solo = generate_greedy(model, params, _prompt(17, 17), n_tokens=3,
                           max_len=64, kv_dtype=kv_dtype, extras=extras)
    eng = ServeEngine(model, n_slots=2, max_len=64, params=params,
                      page_size=8, kv_dtype=kv_dtype)
    r = eng.submit(_prompt(17, 17), max_new_tokens=3, extras=extras)
    eng.run_to_completion()
    assert r.out_tokens == solo, (arch, kv_dtype, r.out_tokens, solo)


# ------------------------------------------------------- MLA latent KV (PR 7)
@pytest.fixture(scope="module")
def mla():
    return _build("deepseek-v2-lite")


@pytest.mark.parametrize("kv_dtype", [None, "bf16", "int8"])
def test_chunked_mla_kv_dtypes(mla, kv_dtype):
    """MLA latent-KV rides the unified chunk mode unchanged: the pool holds
    ONE latent row per token (single 'k' pool, KV-head dim 1, width
    kv_lora_rank + qk_rope_dim) and the absorbed-attention chunk/decode
    reads stay token-exact vs the dense oracle for f32 / bf16 / int8 latent
    pools, across a chunk boundary (17 > 16)."""
    cfg, model, params, _ = mla
    assert cfg.attn_kind == "mla"
    for n in (9, 17):
        solo = generate_greedy(model, params, _prompt(n, n), n_tokens=4,
                               max_len=64, kv_dtype=kv_dtype)
        eng = ServeEngine(model, n_slots=2, max_len=64, params=params,
                          page_size=8, kv_dtype=kv_dtype)
        assert eng.chunked
        r = eng.submit(_prompt(n, n), max_new_tokens=4)
        eng.run_to_completion()
        assert r.out_tokens == solo, (kv_dtype, n, r.out_tokens, solo)
        assert eng.stats.pages_in_use == 0
        assert eng.pages_allocatable() == eng.n_pages - 1


def test_mla_sampled_and_int8_weights(mla):
    """The latent cache composes with the rest of the serving stack: the
    paged sampled stream matches the dense engine's under the same seed
    (PRNG is keyed by (seed, token index), so layout can't shift it), and
    int8 WEIGHT quantization (`quantized._MLA_AXES`) stays token-exact vs
    its own dense-oracle leg."""
    cfg, model, params, _ = mla
    p = _prompt(23, 13)
    sp = dict(max_new_tokens=5, sample_params=(0.8, 5, 0.9), seed=7)
    eng_paged = ServeEngine(model, n_slots=2, max_len=64, params=params,
                            page_size=8)
    eng_dense = ServeEngine(model, n_slots=2, max_len=64, params=params,
                            paged=False)
    r_p, r_d = eng_paged.submit(p, **sp), eng_dense.submit(p, **sp)
    eng_paged.run_to_completion()
    eng_dense.run_to_completion()
    assert r_p.out_tokens == r_d.out_tokens
    solo = generate_greedy(model, params, p, n_tokens=4, max_len=64,
                           wdtype="int8", kv_dtype="int8")
    eng8 = ServeEngine(model, n_slots=2, max_len=64, params=params,
                       page_size=8, wdtype="int8", kv_dtype="int8")
    r8 = eng8.submit(p, max_new_tokens=4)
    eng8.run_to_completion()
    assert r8.out_tokens == solo


def test_no_attention_mirrors_outside_core():
    """Mirror-drift guard: PR 7 deleted the three mirrored QKV/rope
    prefill-chunk bodies; this keeps them deleted. Enforcement lives in the
    contract linter (rule R2, `analysis/contracts`): `_project_qkv` /
    `apply_rope` call sites outside the shared core (`attn_block`) and its
    sanctioned plug-ins are findings. Here: the whole tree is R2-clean AND
    the core still positively contains the primitives (so the rule can't
    pass vacuously against a gutted core)."""
    import inspect
    import pathlib

    from repro.analysis.contracts import run_rules
    from repro.models import transformer

    repo_root = pathlib.Path(__file__).resolve().parents[1]
    findings = run_rules(repo_root, rules=["R2"])
    assert findings == [], "\n".join(str(f) for f in findings)
    core = inspect.getsource(transformer.attn_block)
    assert "_project_qkv(" in core and "apply_rope(" in core


# -------------------------------------------------- capacity / page-boundary
def test_chunked_page_boundary_reservation_exact(smol):
    """Satellite 1: prompts whose last chunk exactly fills its final page
    (±1) must reserve exactly ceil(min(max_len, plen+max_new)/ps) pages —
    no extra page for chunk padding — and leak none on retirement."""
    cfg, model, params, _ = smol
    ps, max_new = 8, 4
    eng = ServeEngine(model, n_slots=1, max_len=64, params=params,
                      page_size=ps)
    for plen in (15, 16, 17, 23, 24, 25):
        want_pages = -(-min(64, plen + max_new) // ps)
        solo = generate_greedy(model, params, _prompt(plen, plen),
                               n_tokens=max_new, max_len=64)
        r = eng.submit(_prompt(plen, plen), max_new_tokens=max_new)
        eng._admit()                      # reserve-only under chunking
        assert eng.stats.pages_in_use == want_pages, \
            (plen, eng.stats.pages_in_use, want_pages)
        eng.run_to_completion()
        assert r.out_tokens == solo, (plen, r.out_tokens, solo)
        assert eng.stats.pages_in_use == 0
        assert eng.pages_allocatable() == eng.n_pages - 1
    assert eng.stats.chunk_compiles == 1


def test_chunked_capacity_edges(smol):
    """plen == max_len still yields exactly one (replayed) token; chunked
    max_new_tokens=1 yields exactly one token; capacity stays
    max_len - plen + 1 on the chunked path."""
    cfg, model, params, _ = smol
    p = _prompt(99, 32)
    solo = generate_greedy(model, params, p, n_tokens=4, max_len=32)
    eng = ServeEngine(model, n_slots=1, max_len=32, params=params,
                      page_size=8)
    assert eng.chunked
    r = eng.submit(p, max_new_tokens=4)
    eng.run_to_completion()
    assert r.done and len(r.out_tokens) == 1 and r.out_tokens == solo
    # max_new_tokens=1 through the chunk queue
    eng = ServeEngine(model, n_slots=1, max_len=64, params=params,
                      page_size=8)
    r = eng.submit(_prompt(3, 9), max_new_tokens=1)
    eng.run_to_completion()
    assert r.done and len(r.out_tokens) == 1
    # capacity fill: max_len - plen + 1 tokens, token-exact
    for plen in (15, 16):
        max_len = 16
        want_n = max_len - plen + 1
        solo = generate_greedy(model, params, _prompt(plen, plen),
                               n_tokens=32, max_len=max_len)
        eng = ServeEngine(model, n_slots=1, max_len=max_len, params=params,
                          page_size=8)
        r = eng.submit(_prompt(plen, plen), max_new_tokens=32)
        eng.run_to_completion()
        assert len(r.out_tokens) == want_n == len(solo)
        assert r.out_tokens == solo


# --------------------------------------------- retire-while-chunks-queued
def test_pool_reuse_while_chunks_queued(smol):
    """Satellite 2: a slot that retires while another slot still has chunks
    queued must free its pages for the waiting queue WITHOUT perturbing the
    in-flight chunk stream; the mid-prefill slot's frozen pos / null table
    row keep the batched decode step's garbage writes off its pages."""
    cfg, model, params, _ = smol
    long_p = _prompt(50, 40)              # 3 chunks at chunk_tokens=16
    solo = {
        "short": generate_greedy(model, params, _prompt(51, 6), n_tokens=2,
                                 max_len=64),
        "long": generate_greedy(model, params, long_p, n_tokens=4,
                                max_len=64),
        "third": generate_greedy(model, params, _prompt(52, 6), n_tokens=2,
                                 max_len=64),
    }
    # pool: long needs ceil(44/8)=6 pages, short/third 1 each; 7 usable
    # pages force the third request to wait for the short one's page
    eng = ServeEngine(model, n_slots=2, max_len=64, params=params,
                      page_size=8, n_pages=8)
    r_short = eng.submit(_prompt(51, 6), max_new_tokens=2)
    r_long = eng.submit(long_p, max_new_tokens=4)
    r_third = eng.submit(_prompt(52, 6), max_new_tokens=2)
    saw_reuse = False
    for _ in range(200):
        if not eng.step() and not eng._queue:
            break
        # the third request admits only after the short one's retirement,
        # while the long prompt is still mid-prefill
        if r_third in eng._slots and not r_long.done \
                and eng._prefill_fifo:
            saw_reuse = True
    assert r_short.out_tokens == solo["short"]
    assert r_long.out_tokens == solo["long"]
    assert r_third.out_tokens == solo["third"]
    assert saw_reuse, "third request never overlapped the long prefill"
    assert eng.stats.pages_in_use == 0
    assert eng.pages_allocatable() == eng.n_pages - 1


# ------------------------------------------------------- windowed + chunked
def test_windowed_chunked_holds_o_window_pages(smol):
    """Satellite 3: a prompt LONGER than the attention window prefills in
    O(window) pages — out-of-window pages recycle forward between chunks —
    and stays token-exact; occupancy never exceeds ceil(window/page)+2."""
    cfg, model, params, _ = smol
    cfgw = dataclasses.replace(cfg, window=16)
    mw = build_model(cfgw, ExecOptions(attn_impl="reference", ce_chunk=32))
    pw = mw.init(jax.random.key(2))
    p = _prompt(21, 48)                   # prompt 3x the window
    solo = generate_greedy(mw, pw, p, n_tokens=8, max_len=64)
    eng = ServeEngine(mw, n_slots=1, max_len=64, params=pw, page_size=8)
    assert eng.chunked and eng.chunk_tokens == eng.page_size  # 1-page chunks
    r = eng.submit(p, max_new_tokens=8)
    while not r.done:
        eng.step()
        assert eng.stats.pages_in_use <= eng._window_pages(), \
            "windowed prefill held more than O(window) pages"
    assert r.out_tokens == solo
    assert eng.stats.peak_pages_in_use <= eng._window_pages() < 8
    assert eng.stats.pages_in_use == 0


@pytest.mark.slow
def test_windowed_chunked_int8(smol):
    """Window recycling composes with the int8 pool under chunked prefill."""
    cfg, model, params, _ = smol
    cfgw = dataclasses.replace(cfg, window=16)
    mw = build_model(cfgw, ExecOptions(attn_impl="reference", ce_chunk=32))
    pw = mw.init(jax.random.key(4))
    p = _prompt(33, 40)
    solo = generate_greedy(mw, pw, p, n_tokens=8, max_len=64,
                           kv_dtype="int8")
    eng = ServeEngine(mw, n_slots=1, max_len=64, params=pw, page_size=8,
                      kv_dtype="int8")
    r = eng.submit(p, max_new_tokens=8)
    eng.run_to_completion()
    assert r.out_tokens == solo
    assert eng.stats.peak_pages_in_use <= eng._window_pages()


# ------------------------------------------------------------------ sampling
def test_sampling_deterministic_and_temp0_is_greedy(smol):
    """Same seed → same tokens (engine-run to engine-run); temperature=0 ≡
    the greedy oracle bit-for-bit; a hot sampled stream actually diverges
    from greedy (deterministic for a fixed seed)."""
    cfg, model, params, _ = smol
    greedy = generate_greedy(model, params, _prompt(3, 9), n_tokens=6,
                             max_len=64)
    eng = ServeEngine(model, n_slots=2, max_len=64, params=params,
                      page_size=8)
    r1 = eng.submit(_prompt(3, 9), max_new_tokens=6,
                    sample_params=(0.8, 20, 0.9), seed=7)
    r2 = eng.submit(_prompt(3, 9), max_new_tokens=6,
                    sample_params=(0.8, 20, 0.9), seed=7)
    r0 = eng.submit(_prompt(3, 9), max_new_tokens=6,
                    sample_params=(0.0, 0, 1.0), seed=3)
    eng.run_to_completion()
    assert r1.out_tokens == r2.out_tokens          # same seed, same stream
    assert r0.out_tokens == greedy                 # temp 0 == greedy argmax
    assert r1.out_tokens != greedy                 # fixed-seed divergence
    # sampling lives in-jit: at most the greedy + sampled decode variants
    # trace, never one compile per request/step
    assert eng.stats.decode_compiles <= 2
    # a fresh engine reproduces the same sampled stream (PRNG is keyed by
    # (request seed, token index), not slot/batch state)
    eng2 = ServeEngine(model, n_slots=1, max_len=64, params=params,
                       page_size=8)
    r3 = eng2.submit(_prompt(3, 9), max_new_tokens=6,
                     sample_params=(0.8, 20, 0.9), seed=7)
    eng2.run_to_completion()
    assert r3.out_tokens == r1.out_tokens


def test_sampling_recurrent_first_token_path():
    """ssm engines sample their FIRST token from the prefill logits (the
    non-replay admission path) — deterministic under the same seed, greedy
    when temperature=0."""
    cfg = get_config("mamba2-780m").smoke()
    model = build_model(cfg, ExecOptions(attn_impl="reference", ce_chunk=32))
    params = model.init(jax.random.key(0))
    greedy = generate_greedy(model, params, _prompt(7, 7), n_tokens=4,
                             max_len=64)
    outs = []
    for _ in range(2):
        eng = ServeEngine(model, n_slots=1, max_len=64, params=params)
        r = eng.submit(_prompt(7, 7), max_new_tokens=4,
                       sample_params=(1.2, 0, 1.0), seed=11)
        eng.run_to_completion()
        outs.append(r.out_tokens)
    assert outs[0] == outs[1]
    eng = ServeEngine(model, n_slots=1, max_len=64, params=params)
    r0 = eng.submit(_prompt(7, 7), max_new_tokens=4)
    eng.run_to_completion()
    assert r0.out_tokens == greedy


# ------------------------------------------------------- scheduling metrics
def test_chunked_eliminates_decode_stall(smol):
    """Mixed long/short traffic: the monolithic engine stalls the decode
    batch on long prefills (stall ticks > 0); the chunked engine never
    exceeds its one-chunk budget (stall ticks == 0) and wastes at most one
    chunk of padding per prompt."""
    cfg, model, params, _ = smol
    def traffic(eng):
        reqs = [eng.submit(_prompt(60, 6), max_new_tokens=12)]
        eng.step()                        # short request starts decoding
        for i, n in enumerate((60, 9, 50, 7)):
            reqs.append(eng.submit(_prompt(61 + i, n), max_new_tokens=4))
        eng.run_to_completion()
        return reqs
    mono = ServeEngine(model, n_slots=4, max_len=64, params=params,
                       page_size=8, chunked_prefill=False)
    traffic(mono)
    chunked = ServeEngine(model, n_slots=4, max_len=64, params=params,
                          page_size=8)
    reqs = traffic(chunked)
    assert mono.stats.decode_stall_ticks > 0
    assert chunked.stats.decode_stall_ticks == 0
    assert chunked.stats.decode_stall_ticks < mono.stats.decode_stall_ticks
    # pad waste: at most chunk_tokens-1 padded rows per prompt
    n_prompts = len(reqs)
    assert chunked.stats.prefill_pad_tokens \
        <= n_prompts * (chunked.chunk_tokens - 1)


def test_chunked_validation(smol):
    cfg, model, params, _ = smol
    with pytest.raises(ValueError):
        ServeEngine(model, params=params, paged=False, chunked_prefill=True)
    cfg2 = get_config("mamba2-780m").smoke()
    m2 = build_model(cfg2, ExecOptions(attn_impl="reference", ce_chunk=32))
    p2 = m2.init(jax.random.key(0))
    with pytest.raises(ValueError):
        ServeEngine(m2, params=p2, chunked_prefill=True)


def test_degenerate_sample_params_clamp(smol):
    """Satellite (PR 5): degenerate sampling params clamp to well-defined
    behavior instead of raising / NaN-ing — temperature < 0 is greedy,
    top_k >= vocab disables the filter, top_p = 0 is the filtered argmax."""
    cfg, model, params, _ = smol
    greedy = generate_greedy(model, params, _prompt(3, 9), n_tokens=4,
                             max_len=64)
    eng = ServeEngine(model, n_slots=2, max_len=64, params=params,
                      page_size=8)
    # negative temperature → clamped to the greedy fast path
    r_neg = eng.submit(_prompt(3, 9), max_new_tokens=4,
                       sample_params=(-1.0, 0, 1.0))
    # top_p = 0 with temperature > 0 → argmax of the (unfiltered, scaled)
    # distribution — same tokens as greedy, but through the sampler
    r_p0 = eng.submit(_prompt(3, 9), max_new_tokens=4,
                      sample_params=(0.8, 0, 0.0), seed=5)
    # top_k >= vocab ≡ top_k off: same stream as the top_k=0 submission
    r_kbig = eng.submit(_prompt(3, 9), max_new_tokens=4,
                        sample_params=(0.8, cfg.vocab_size + 7, 1.0), seed=9)
    r_k0 = eng.submit(_prompt(3, 9), max_new_tokens=4,
                      sample_params=(0.8, 0, 1.0), seed=9)
    eng.run_to_completion()
    assert r_neg.out_tokens == greedy
    assert r_p0.out_tokens == greedy
    assert r_kbig.out_tokens == r_k0.out_tokens
    assert all(0 <= t < cfg.vocab_size for t in r_p0.out_tokens)


def test_sample_tokens_vmapped_edge_cases():
    """The vmapped sampler itself: one batch mixing every degenerate corner
    must emit finite in-range tokens — top_p=0 rows take the argmax of the
    top-k-filtered distribution (never an all-NEG_INF categorical)."""
    import jax.numpy as jnp
    from repro.serve.sampling import clamp_sample_params, sample_tokens
    v = 64
    logits = jax.random.normal(jax.random.key(0), (5, v), jnp.float32)
    params = [clamp_sample_params(*p) for p in
              [(-2.0, 0, 1.0),        # negative temp → greedy
               (0.7, v + 9, 1.0),     # top_k >= vocab → filter off
               (0.7, 0, 0.0),         # top_p = 0 → argmax
               (0.7, 3, 0.0),         # top_p = 0 under top-k → argmax
               (1e-9, 1, 1e-9)]]      # everything tiny at once
    temps = jnp.asarray([p[0] for p in params], jnp.float32)
    ks = jnp.asarray([p[1] for p in params], jnp.int32)
    ps = jnp.asarray([p[2] for p in params], jnp.float32)
    seeds = jnp.zeros((5,), jnp.int32)
    ctr = jnp.zeros((5,), jnp.int32)
    toks = np.asarray(sample_tokens(logits, temps, ks, ps, seeds, ctr))
    arg = np.argmax(np.asarray(logits), axis=-1)
    assert ((toks >= 0) & (toks < v)).all(), toks
    assert toks[0] == arg[0]          # greedy row
    assert toks[2] == arg[2]          # top_p=0 → argmax
    assert toks[3] == arg[3]          # top_p=0 survives the top-k filter
    assert toks[4] == arg[4]


def test_cancel_drains_reservations_at_every_stage(smol):
    """Satellite (PR 5): retiring a request mid-prefill must drain its chunk
    queue and return EVERY reserved page; queued and decoding cancels keep
    the same exact accounting, and survivors stay token-exact."""
    cfg, model, params, _ = smol
    solo = generate_greedy(model, params, _prompt(51, 9), n_tokens=4,
                           max_len=64)
    eng = ServeEngine(model, n_slots=2, max_len=64, params=params,
                      page_size=8)
    long_p = _prompt(50, 40)                   # several chunks of prefill
    r_long = eng.submit(long_p, max_new_tokens=4)
    r_short = eng.submit(_prompt(51, 9), max_new_tokens=4)
    r_queued = eng.submit(_prompt(52, 9), max_new_tokens=4)
    eng.step()                                 # long admits, first chunk runs
    assert eng._prefill_fifo, "long prompt should be mid-prefill"
    held = eng.stats.pages_in_use
    assert held > 0
    eng.cancel(r_long)                         # mid-prefill retirement
    assert r_long.done
    assert eng._prefill_fifo == [] or 0 not in eng._prefill_fifo
    eng.cancel(r_queued)                       # queued: nothing was reserved
    eng.run_to_completion()
    assert r_short.out_tokens == solo
    assert eng.stats.pages_in_use == 0
    assert eng.pages_allocatable() == eng.n_pages - 1
    # cancel while decoding releases the slot's pages too
    r = eng.submit(_prompt(53, 9), max_new_tokens=30)
    for _ in range(6):
        eng.step()
    assert len(r.out_tokens) > 0 and not r.done
    eng.cancel(r)
    assert eng.stats.pages_in_use == 0
    assert eng.pages_allocatable() == eng.n_pages - 1
