"""Validation of the faithful reproduction against the paper's own claims.

Every assertion cites the paper artifact it checks (Table III, Fig 2, abstract).
Tolerances are the paper's own reported noise bars (±0.2–0.3 ms on ~4–6 ms,
i.e. ~5 %); the paper used "a single simulation run per measurement point".
"""

import jax
import jax.numpy as jnp
import pytest

from repro.core import perf_model as pm
from repro.core.scenarios import SCENARIOS, SCENARIO_ORDER
from repro.core.workloads import WORKLOADS, WORKLOAD_ORDER

MNV2 = WORKLOADS["mobilenetv2"]


def _predict(scenario_name, batch=1, workload=MNV2):
    return pm.predict(SCENARIOS[scenario_name], workload, batch)


# --- Table III: mean latency / throughput / power, MobileNetV2 INT8 batch=1 ---

TABLE3 = {
    #                 latency_ms  thpt_ips  power_mw   (±0.2–0.3 ms reported)
    "monolithic":       (4.7,      213.0,    1284.0),
    "basic_chiplet":    (4.8,      208.0,    1026.0),
    "ai_optimized":     (4.1,      244.0,     860.0),
    "poor_integration": (6.2,      163.0,    1776.0),
}


@pytest.mark.parametrize("scenario", SCENARIO_ORDER)
def test_table3_reproduction(scenario):
    lat, thpt, power = TABLE3[scenario]
    r = _predict(scenario)
    assert float(r.latency_ms) == pytest.approx(lat, rel=0.06), scenario
    assert float(r.throughput_ips) == pytest.approx(thpt, rel=0.06), scenario
    assert float(r.power_mw) == pytest.approx(power, rel=0.06), scenario


def test_table3_ordering():
    """AI-optimized beats all; poor integration loses to all (Table III)."""
    lats = {s: float(_predict(s).latency_ms) for s in SCENARIO_ORDER}
    pows = {s: float(_predict(s).power_mw) for s in SCENARIO_ORDER}
    assert lats["ai_optimized"] == min(lats.values())
    assert lats["poor_integration"] == max(lats.values())
    assert pows["ai_optimized"] == min(pows.values())
    assert pows["poor_integration"] == max(pows.values())


# --- Abstract / §V: headline improvement percentages (AI-opt vs basic) -------

def test_headline_improvements():
    basic = _predict("basic_chiplet")
    ai = _predict("ai_optimized")
    lat_drop = 100.0 * (1.0 - float(ai.latency_ms) / float(basic.latency_ms))
    thpt_gain = 100.0 * (float(ai.throughput_ips) / float(basic.throughput_ips) - 1)
    pow_drop = 100.0 * (1.0 - float(ai.power_mw) / float(basic.power_mw))
    eff_gain = 100.0 * (float(ai.tops_per_w) / float(basic.tops_per_w) - 1)
    assert lat_drop == pytest.approx(14.7, abs=2.0)    # paper: ~14.7 %
    assert thpt_gain == pytest.approx(17.3, abs=2.0)   # paper: 17.3 %
    assert pow_drop == pytest.approx(16.2, abs=3.0)    # paper: 16.2 %
    assert eff_gain == pytest.approx(40.1, abs=5.0)    # paper: 40.1 %


def test_tops_per_w_absolute():
    """§V: 0.203 → 0.284 TOPS/W (paper normalizes MobileNetV2 to 1 GOP)."""
    assert float(_predict("basic_chiplet").tops_per_w) == pytest.approx(0.203, abs=0.01)
    assert float(_predict("ai_optimized").tops_per_w) == pytest.approx(0.284, abs=0.012)


def test_energy_per_inference():
    """Abstract: ≈3.5 mJ per MobileNetV2 inference (860 mW / 244 img/s)."""
    r = _predict("ai_optimized")
    assert float(r.energy_mj) == pytest.approx(3.5, abs=0.2)


# --- Fig 2(b): throughput scaling with batch size ----------------------------

def test_fig2b_batch_scaling():
    batches = [1, 2, 4, 8, 16, 32]
    grid = pm.predict_grid(
        [SCENARIOS[s] for s in SCENARIO_ORDER], [MNV2], batches
    )
    thpt = grid.throughput_ips[:, 0, :]  # (scenario, batch)
    # batching amortizes: batch-32 throughput beats batch-1 for every scenario
    assert bool(jnp.all(thpt[:, -1] > thpt[:, 0]))
    # AI-optimized scales monotonically (I4 migration defers the thermal derate
    # that makes the reactive designs sag past their utilization sweet spot)
    ai = SCENARIO_ORDER.index("ai_optimized")
    assert bool(jnp.all(thpt[ai, 1:] >= thpt[ai, :-1]))
    # AI-optimized consistently achieves the highest images/sec (paper Fig 2b)
    for s in range(len(SCENARIO_ORDER)):
        if s != ai:
            assert bool(jnp.all(thpt[ai] >= thpt[s]))


# --- Fig 2(d,f): workload comparison + sub-5 ms real-time capability ---------

def test_fig2d_ai_opt_fastest_per_workload():
    for w in WORKLOAD_ORDER:
        lats = {
            s: float(_predict(s, workload=WORKLOADS[w]).latency_ms)
            for s in SCENARIO_ORDER
        }
        assert lats["ai_optimized"] == min(lats.values()), w


def test_fig2f_realtime_capability():
    """Sub-5 ms on AI-optimized for MobileNetV2 + video; ResNet-50's 12 ms base
    compute (Table II) cannot meet 5 ms — Fig 2(f) 'shows WHICH workloads meet'
    the requirement (the abstract's 'all workloads' refers to the sub-5 ms
    capable set; see DESIGN.md §10)."""
    assert bool(_predict("ai_optimized", workload=MNV2).realtime_ok)
    assert bool(_predict("ai_optimized", workload=WORKLOADS["realtime_video"]).realtime_ok)
    assert not bool(_predict("ai_optimized", workload=WORKLOADS["resnet50"]).realtime_ok)


# --- model identities ---------------------------------------------------------

def test_throughput_latency_identity():
    for s in SCENARIO_ORDER:
        for b in (1, 4, 32):
            r = _predict(s, batch=b)
            assert float(r.throughput_ips) == pytest.approx(
                1000.0 * b / float(r.latency_ms), rel=1e-5
            )


def test_monolithic_has_no_comm():
    r = _predict("monolithic")
    assert float(r.t_comm_ms) < 1e-6  # '—' in Table I (inf bandwidth encoding)


def test_prefetch_overlap_hides_comm():
    """I2: AI-optimized overlaps transfers; latency == compute only."""
    ai = _predict("ai_optimized")
    assert float(ai.latency_ms) == pytest.approx(float(ai.t_compute_ms), rel=1e-5)
    assert float(ai.t_comm_ms) > 0.0  # the transfer still happens (power accounts)


def test_model_is_differentiable():
    """Beyond-paper: the reconstructed simulator admits gradient-based co-design."""
    sv = SCENARIOS["basic_chiplet"].as_vector()
    wv = MNV2.as_vector()

    def lat(v):
        return pm.predict_vec(v, wv, jnp.float32(1.0)).latency_ms

    g = jax.grad(lat)(sv)
    assert bool(jnp.all(jnp.isfinite(g)))
    # more link bandwidth must not increase latency
    assert float(g[1]) <= 0.0
