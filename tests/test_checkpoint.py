"""Fault-tolerance: atomic checkpoints, integrity (I3 analogue), retention,
resume, elastic re-shard."""

import json
import pathlib

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.train.checkpoint import CheckpointManager


def _tree(seed=0):
    k = jax.random.key(seed)
    return {
        "params": {"w": jax.random.normal(k, (8, 16)),
                   "layers": {"stack": jnp.arange(24.0).reshape(2, 3, 4)}},
        "opt": {"m": jnp.zeros((8, 16)), "step": jnp.int32(7)},
    }


def test_roundtrip(tmp_path):
    mgr = CheckpointManager(str(tmp_path))
    tree = _tree()
    mgr.save(5, tree, extra={"loss": 1.25})
    out, manifest = mgr.restore(tree)
    assert manifest["step"] == 5
    assert manifest["extra"]["loss"] == 1.25
    for a, b in zip(jax.tree.leaves(tree), jax.tree.leaves(out)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_latest_and_retention(tmp_path):
    mgr = CheckpointManager(str(tmp_path), keep=2)
    for s in (1, 2, 3, 4):
        mgr.save(s, _tree(s))
    assert mgr.latest_step() == 4
    kept = sorted(p.name for p in pathlib.Path(tmp_path).iterdir())
    assert kept == ["step_00000003", "step_00000004"]


def test_corruption_detected(tmp_path):
    """I3: a tampered shard must fail verification on restore."""
    mgr = CheckpointManager(str(tmp_path))
    tree = _tree()
    path = pathlib.Path(mgr.save(3, tree))
    manifest = json.loads((path / "manifest.json").read_text())
    victim = path / next(iter(manifest["leaves"].values()))["file"]
    raw = bytearray(victim.read_bytes())
    raw[-1] ^= 0xFF
    victim.write_bytes(bytes(raw))
    with pytest.raises(IOError, match="integrity"):
        mgr.restore(tree)


def test_restore_without_verify_skips_hashing(tmp_path):
    mgr = CheckpointManager(str(tmp_path))
    tree = _tree()
    mgr.save(1, tree)
    out, _ = mgr.restore(tree, verify=False)
    assert jax.tree.structure(out) == jax.tree.structure(tree)


def test_atomic_publish_no_tmp_left(tmp_path):
    mgr = CheckpointManager(str(tmp_path))
    mgr.save(9, _tree())
    assert not any(p.name.endswith(".tmp")
                   for p in pathlib.Path(tmp_path).iterdir())


def test_elastic_reshard_subprocess(tmp_path):
    """Save on an 8-device (4,2) mesh, restore onto a 4-device (2,2) mesh —
    the device-loss recovery path."""
    import subprocess
    import sys
    script = f"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=" + os.environ["NDEV"]
import jax, jax.numpy as jnp, numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P
from repro.launch.mesh import make_mesh
from repro.train.checkpoint import CheckpointManager
ndev = len(jax.devices())
mesh = make_mesh((ndev // 2, 2), ("data", "model"))
sh = NamedSharding(mesh, P("data", "model"))
mgr = CheckpointManager({str(tmp_path)!r})
tree = {{"w": jnp.arange(64.0).reshape(8, 8)}}
if os.environ["MODE"] == "save":
    tree = {{"w": jax.device_put(tree["w"], sh)}}
    mgr.save(1, tree)
else:
    out, _ = mgr.restore(tree, shardings={{"w": sh}})
    assert out["w"].sharding.mesh.shape["data"] == ndev // 2
    np.testing.assert_array_equal(np.asarray(out["w"]),
                                  np.arange(64.0).reshape(8, 8))
    print("RESHARD_OK")
"""
    env = dict(NDEV="8", MODE="save")
    import os
    env = {**os.environ, "PYTHONPATH": "src", **env}
    r = subprocess.run([sys.executable, "-c", script], env=env,
                       capture_output=True, text=True, cwd="/root/repo")
    assert r.returncode == 0, r.stderr[-2000:]
    env["NDEV"], env["MODE"] = "4", "restore"
    r = subprocess.run([sys.executable, "-c", script], env=env,
                       capture_output=True, text=True, cwd="/root/repo")
    assert r.returncode == 0, r.stderr[-2000:]
    assert "RESHARD_OK" in r.stdout


# --- property-based: arbitrary pytrees roundtrip --------------------------

try:
    from hypothesis import given, settings, strategies as st
except ImportError:          # container lacks hypothesis: fixed examples
    st = None


def _roundtrip(tree):
    import tempfile
    with tempfile.TemporaryDirectory() as d:
        mgr = CheckpointManager(d)
        mgr.save(1, tree)
        out, _ = mgr.restore(tree)
        for a, b in zip(jax.tree.leaves(tree), jax.tree.leaves(out)):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
            assert np.asarray(a).dtype == np.asarray(b).dtype


_LEAVES = [
    jnp.arange(6.0).reshape(2, 3),
    jnp.ones((4,), jnp.int32),
    jnp.zeros((1, 2, 2), jnp.float16),
    jnp.float32(3.5),
]

if st is not None:
    settings.register_profile("ci", max_examples=15, deadline=None)
    settings.load_profile("ci")

    _leaf = st.sampled_from(_LEAVES)
    _tree_st = st.recursive(
        _leaf, lambda kids: st.dictionaries(
            st.sampled_from(["a", "b", "c", "w"]), kids, min_size=1, max_size=3),
        max_leaves=6)

    @given(tree=_tree_st)
    def test_roundtrip_arbitrary_pytrees(tree):
        _roundtrip(tree)
else:
    @pytest.mark.parametrize("tree", [
        _LEAVES[0],
        {"a": _LEAVES[1], "b": _LEAVES[2]},
        {"w": {"a": _LEAVES[3], "c": _LEAVES[0]}, "b": _LEAVES[1]},
    ])
    def test_roundtrip_arbitrary_pytrees(tree):
        _roundtrip(tree)
