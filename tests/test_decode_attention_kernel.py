"""Pallas decode-attention kernel vs the pure-jnp reference (interpret mode):
GQA grouping, ragged per-sequence kv_len, sliding windows, storage dtypes,
and the paged page-table gather path."""

import jax
import jax.numpy as jnp
import pytest

from repro.kernels.decode_attention import decode_attention as pallas_decode
from repro.kernels.flash_attention import flash_attention_paged
from repro.models.attention import chunk_attention_paged, decode_attention


def _inputs(seed, b, kv, g, d, smax, dtype=jnp.float32):
    ks = jax.random.split(jax.random.key(seed), 4)
    q = jax.random.normal(ks[0], (b, 1, kv, g, d), jnp.float32).astype(dtype)
    k = jax.random.normal(ks[1], (b, smax, kv, d), jnp.float32).astype(dtype)
    v = jax.random.normal(ks[2], (b, smax, kv, d), jnp.float32).astype(dtype)
    kv_len = jax.random.randint(ks[3], (b,), 1, smax + 1)
    return q, k, v, kv_len


def _paged_inputs(seed, b, kv, g, d, ps, pages_per_seq, dtype=jnp.float32):
    """Page pools + a shuffled (non-identity) page table, and the dense
    per-sequence view obtained by gathering the table — the exactness oracle."""
    n_pages = 1 + b * pages_per_seq         # page 0 reserved as null
    ks = jax.random.split(jax.random.key(seed), 4)
    q = jax.random.normal(ks[0], (b, 1, kv, g, d), jnp.float32).astype(dtype)
    pk = jax.random.normal(ks[1], (n_pages, ps, kv, d), jnp.float32).astype(dtype)
    pv = jax.random.normal(ks[2], (n_pages, ps, kv, d), jnp.float32).astype(dtype)
    perm = jax.random.permutation(ks[3], jnp.arange(1, n_pages))
    pt = perm.reshape(b, pages_per_seq).astype(jnp.int32)
    kd = pk[pt].reshape(b, pages_per_seq * ps, kv, d)
    vd = pv[pt].reshape(b, pages_per_seq * ps, kv, d)
    return q, pk, pv, pt, kd, vd


@pytest.mark.parametrize("b,kv,g,d,smax", [
    (2, 2, 4, 64, 256),     # GQA, multi-block sweep
    pytest.param(3, 1, 1, 64, 128,      # MQA single head, one block
                 marks=pytest.mark.slow),
    pytest.param(1, 4, 2, 32, 512,      # many kv heads, deep cache
                 marks=pytest.mark.slow),
])
def test_matches_reference(b, kv, g, d, smax):
    q, k, v, kv_len = _inputs(b * smax + d, b, kv, g, d, smax)
    want = decode_attention(q, k, v, kv_len, impl="reference")
    got = pallas_decode(q, k, v, kv_len, interpret=True)
    assert jnp.max(jnp.abs(want - got)) < 2e-5


@pytest.mark.parametrize("window", [
    32, pytest.param(128, marks=pytest.mark.slow)])
def test_sliding_window(window):
    q, k, v, kv_len = _inputs(7, 2, 2, 2, 64, 256)
    want = decode_attention(q, k, v, kv_len, window=window, impl="reference")
    got = pallas_decode(q, k, v, kv_len, window=window, interpret=True)
    assert jnp.max(jnp.abs(want - got)) < 2e-5


def test_scalar_kv_len_broadcasts():
    q, k, v, _ = _inputs(3, 2, 2, 2, 64, 256)
    want = decode_attention(q, k, v, jnp.int32(100), impl="reference")
    got = pallas_decode(q, k, v, jnp.int32(100), interpret=True)
    assert jnp.max(jnp.abs(want - got)) < 2e-5


def test_bf16_cache_stays_in_storage_dtype():
    q, k, v, kv_len = _inputs(11, 2, 2, 4, 64, 256, dtype=jnp.bfloat16)
    want = decode_attention(q, k, v, kv_len, impl="reference")
    got = pallas_decode(q, k, v, kv_len, interpret=True)
    assert got.dtype == jnp.bfloat16
    assert jnp.max(jnp.abs(want.astype(jnp.float32)
                           - got.astype(jnp.float32))) < 2e-2


def test_partial_tail_block_masked():
    """kv_len one past / one short of a block edge must flip exactly the
    edge position's contribution."""
    q, k, v, _ = _inputs(5, 1, 1, 1, 32, 256)
    for kv_len in (127, 128, 129):
        want = decode_attention(q, k, v, jnp.asarray([kv_len]),
                                impl="reference")
        got = pallas_decode(q, k, v, jnp.asarray([kv_len]), interpret=True)
        assert jnp.max(jnp.abs(want - got)) < 2e-5, kv_len


def test_empty_sequence_yields_zeros():
    """kv_len == 0 ("no valid keys") must produce zeros from BOTH impls —
    not softmax's uniform mean over masked positions."""
    q, k, v, _ = _inputs(9, 2, 1, 2, 32, 128)
    kv_len = jnp.asarray([0, 64], jnp.int32)
    ref = decode_attention(q, k, v, kv_len, impl="reference")
    pal = pallas_decode(q, k, v, kv_len, interpret=True)
    assert jnp.all(ref[0] == 0.0) and jnp.all(pal[0] == 0.0)
    assert jnp.max(jnp.abs(ref[1] - pal[1])) < 2e-5


@pytest.mark.parametrize("window,block_k", [
    (300, 64),    # kv_len can be < window: full prefix attends
    (40, 32),     # window not a multiple of block_k: partial leading tile
    (64, 128),    # window smaller than one block
])
def test_sliding_window_edges(window, block_k):
    q, k, v, _ = _inputs(13, 2, 2, 2, 32, 256)
    kv_len = jnp.asarray([17, 256], jnp.int32)    # < window and == smax
    want = decode_attention(q, k, v, kv_len, window=window, impl="reference")
    got = pallas_decode(q, k, v, kv_len, window=window, block_k=block_k,
                        interpret=True)
    assert jnp.max(jnp.abs(want - got)) < 2e-5


def test_empty_sequence_with_window_yields_zeros():
    """kv_len == 0 under a sliding window must still emit zeros, not the
    softmax of an all-masked row."""
    q, k, v, _ = _inputs(15, 2, 1, 2, 32, 128)
    kv_len = jnp.asarray([0, 100], jnp.int32)
    ref = decode_attention(q, k, v, kv_len, window=32, impl="reference")
    pal = pallas_decode(q, k, v, kv_len, window=32, interpret=True)
    assert jnp.all(ref[0] == 0.0) and jnp.all(pal[0] == 0.0)
    assert jnp.max(jnp.abs(ref[1] - pal[1])) < 2e-5


# ------------------------------------------------------------------ paged path
def test_paged_matches_dense_gather():
    """Kernel reading through a shuffled page table == the same rows laid out
    densely."""
    q, pk, pv, pt, kd, vd = _paged_inputs(21, 2, 2, 4, 32, ps=16,
                                          pages_per_seq=4)
    kv_len = jnp.asarray([37, 61], jnp.int32)
    want = decode_attention(q, kd, vd, kv_len, impl="reference")
    ref = decode_attention(q, pk, pv, kv_len, page_table=pt, impl="reference")
    pal = pallas_decode(q, pk, pv, kv_len, page_table=pt, interpret=True)
    assert jnp.max(jnp.abs(want - ref)) < 2e-5
    assert jnp.max(jnp.abs(want - pal)) < 2e-5


def test_paged_null_pages_are_dead():
    """Table entries past kv_len may point anywhere (the engine points them
    at the null page); they must not contribute."""
    q, pk, pv, pt, kd, vd = _paged_inputs(23, 2, 1, 2, 32, ps=16,
                                          pages_per_seq=4)
    kv_len = jnp.asarray([16, 33], jnp.int32)
    # kill every entry beyond the live prefix: seq0 keeps page 0 only,
    # seq1 keeps three pages
    pt_null = pt.at[0, 1:].set(0).at[1, 3:].set(0)
    want = decode_attention(q, kd, vd, kv_len, impl="reference")
    pal = pallas_decode(q, pk, pv, kv_len, page_table=pt_null, interpret=True)
    ref = decode_attention(q, pk, pv, kv_len, page_table=pt_null,
                           impl="reference")
    assert jnp.max(jnp.abs(want - pal)) < 2e-5
    assert jnp.max(jnp.abs(want - ref)) < 2e-5


@pytest.mark.parametrize("window", [24, 40])
def test_paged_sliding_window(window):
    q, pk, pv, pt, kd, vd = _paged_inputs(25, 2, 2, 2, 32, ps=16,
                                          pages_per_seq=4)
    kv_len = jnp.asarray([29, 64], jnp.int32)
    want = decode_attention(q, kd, vd, kv_len, window=window,
                            impl="reference")
    pal = pallas_decode(q, pk, pv, kv_len, page_table=pt, window=window,
                        block_k=8, interpret=True)
    assert jnp.max(jnp.abs(want - pal)) < 2e-5


def test_paged_bf16_pool_stays_in_storage_dtype():
    q, pk, pv, pt, kd, vd = _paged_inputs(27, 2, 2, 2, 32, ps=16,
                                          pages_per_seq=2, dtype=jnp.bfloat16)
    kv_len = jnp.asarray([9, 30], jnp.int32)
    want = decode_attention(q, kd, vd, kv_len, impl="reference")
    got = pallas_decode(q, pk, pv, kv_len, page_table=pt, interpret=True)
    assert got.dtype == jnp.bfloat16
    assert jnp.max(jnp.abs(want.astype(jnp.float32)
                           - got.astype(jnp.float32))) < 2e-2


# ------------------------------------------------------------------- int8 path
def _int8_inputs(seed, b, kv, g, d, smax):
    """int8 cache + per-row f16 scales, from quantizing an f32 cache — the
    jnp reference with the same operands is the dequant oracle."""
    from repro.models.quantized import quantize_kv_rows
    q, k, v, kv_len = _inputs(seed, b, kv, g, d, smax)
    k8, ks = quantize_kv_rows(k)
    v8, vs = quantize_kv_rows(v)
    return q, k8, ks, v8, vs, kv_len


def test_int8_dense_fused_dequant_matches_reference():
    """Dense int8 cache: the kernel's fused (tile * scale) dequant must match
    the jnp path's materialized dequant."""
    q, k8, ks, v8, vs, kv_len = _int8_inputs(31, 2, 2, 4, 64, 256)
    want = decode_attention(q, k8, v8, kv_len, k_scale=ks, v_scale=vs,
                            impl="reference")
    got = pallas_decode(q, k8, v8, kv_len, k_scale=ks, v_scale=vs,
                        interpret=True)
    assert jnp.max(jnp.abs(want - got)) < 2e-5


def test_int8_paged_fused_dequant_matches_reference():
    """Paged int8 pools: scales gather through the same page-table entries as
    their K/V tiles; kernel == jnp gather-then-dequant oracle."""
    from repro.models.quantized import quantize_kv_rows
    q, pk, pv, pt, kd, vd = _paged_inputs(33, 2, 2, 2, 32, ps=16,
                                          pages_per_seq=4)
    pk8, pks = quantize_kv_rows(pk)
    pv8, pvs = quantize_kv_rows(pv)
    kv_len = jnp.asarray([37, 61], jnp.int32)
    want = decode_attention(q, pk8, pv8, kv_len, page_table=pt,
                            k_scale=pks, v_scale=pvs, impl="reference")
    got = pallas_decode(q, pk8, pv8, kv_len, page_table=pt,
                        k_scale=pks, v_scale=pvs, interpret=True)
    assert got.dtype == q.dtype
    assert jnp.max(jnp.abs(want - got)) < 2e-5


@pytest.mark.parametrize("window", [24, 40])
def test_int8_paged_sliding_window(window):
    from repro.models.quantized import quantize_kv_rows
    q, pk, pv, pt, kd, vd = _paged_inputs(35, 2, 2, 2, 32, ps=16,
                                          pages_per_seq=4)
    pk8, pks = quantize_kv_rows(pk)
    pv8, pvs = quantize_kv_rows(pv)
    kv_len = jnp.asarray([29, 64], jnp.int32)
    want = decode_attention(q, pk8, pv8, kv_len, page_table=pt, window=window,
                            k_scale=pks, v_scale=pvs, impl="reference")
    got = pallas_decode(q, pk8, pv8, kv_len, page_table=pt, window=window,
                        k_scale=pks, v_scale=pvs, block_k=8, interpret=True)
    assert jnp.max(jnp.abs(want - got)) < 2e-5


def test_int8_quantized_cache_close_to_f32_cache():
    """End-to-end numerics: attention over the quantized cache stays within
    the int8 grid error of attention over the original f32 cache."""
    q, k, v, kv_len = _inputs(37, 2, 2, 2, 64, 128)
    from repro.models.quantized import quantize_kv_rows
    k8, ks = quantize_kv_rows(k)
    v8, vs = quantize_kv_rows(v)
    exact = decode_attention(q, k, v, kv_len, impl="reference")
    quant = pallas_decode(q, k8, v8, kv_len, k_scale=ks, v_scale=vs,
                          interpret=True)
    assert jnp.max(jnp.abs(exact - quant)) < 0.05


def test_dispatch_stays_reference_off_tpu():
    """On CPU/GPU the model-level entry point keeps the jnp path (the kernel
    is opt-in via impl='pallas' with interpret)."""
    assert jax.default_backend() != "tpu" or True
    q, k, v, kv_len = _inputs(1, 1, 2, 2, 64, 128)
    a = decode_attention(q, k, v, kv_len)            # impl='auto'
    b = decode_attention(q, k, v, kv_len, impl="reference")
    assert jnp.array_equal(a, b) or jax.default_backend() == "tpu"


# ------------------------------------------- chunk-prefill kernel (paged)
def _chunk_inputs(seed, b, kv, g, d, ps, pages_per_seq, cq, dtype=jnp.float32):
    n_pages = 1 + b * pages_per_seq
    ks = jax.random.split(jax.random.key(seed), 4)
    q = jax.random.normal(ks[0], (b, cq, kv, g, d), jnp.float32).astype(dtype)
    pk = jax.random.normal(ks[1], (n_pages, ps, kv, d),
                           jnp.float32).astype(dtype)
    pv = jax.random.normal(ks[2], (n_pages, ps, kv, d),
                           jnp.float32).astype(dtype)
    perm = jax.random.permutation(ks[3], jnp.arange(1, n_pages))
    pt = perm.reshape(b, pages_per_seq).astype(jnp.int32)
    return q, pk, pv, pt


def test_chunk_prefill_kernel_matches_reference():
    """flash_attention_paged (interpret) == the jnp gather reference at a
    mid-stream chunk offset: causal masking by GLOBAL position, live-length
    masking of stale pool rows beyond kv_len."""
    b, kv, g, d, ps, pps, cq = 2, 2, 2, 32, 16, 6, 32
    q, pk, pv, pt = _chunk_inputs(11, b, kv, g, d, ps, pps, cq)
    off = jnp.asarray([16, 40], jnp.int32)
    kv_len = off + jnp.asarray([cq, 20], jnp.int32)   # partial final chunk
    want = chunk_attention_paged(q, pk, pv, pt, off, kv_len=kv_len,
                                 impl="reference")
    got = flash_attention_paged(q, pk, pv, pt, off, kv_len, interpret=True)
    assert jnp.max(jnp.abs(want - got)) < 2e-5


def test_chunk_prefill_kernel_first_chunk_and_window():
    """Offset-0 chunks and sliding windows: rows with no in-window keys
    below their own position must not pick up garbage (the all-masked-tile
    guard), matching the reference bit-for-bit in structure."""
    b, kv, g, d, ps, pps, cq = 1, 2, 2, 32, 16, 6, 32
    q, pk, pv, pt = _chunk_inputs(12, b, kv, g, d, ps, pps, cq)
    off = jnp.zeros((1,), jnp.int32)
    kv_len = jnp.asarray([cq], jnp.int32)
    want = chunk_attention_paged(q, pk, pv, pt, off, kv_len=kv_len,
                                 impl="reference")
    got = flash_attention_paged(q, pk, pv, pt, off, kv_len, interpret=True)
    assert jnp.max(jnp.abs(want - got)) < 2e-5
    off = jnp.asarray([48], jnp.int32)
    kv_len = off + cq
    for window in (8, 24):
        want = chunk_attention_paged(q, pk, pv, pt, off, kv_len=kv_len,
                                     window=window, impl="reference")
        got = flash_attention_paged(q, pk, pv, pt, off, kv_len,
                                    window=window, interpret=True)
        assert jnp.max(jnp.abs(want - got)) < 2e-5, window


def test_chunk_prefill_kernel_int8_fused_dequant():
    """int8 pools: dequant fused into the chunk kernel's tile loads == the
    dequantized-gather reference."""
    from repro.models.quantized import quantize_kv_rows
    b, kv, g, d, ps, pps, cq = 1, 2, 2, 32, 16, 4, 16
    q, pk, pv, pt = _chunk_inputs(13, b, kv, g, d, ps, pps, cq)
    k8, ks = quantize_kv_rows(pk)
    v8, vs = quantize_kv_rows(pv)
    off = jnp.asarray([24], jnp.int32)
    kv_len = off + cq
    want = chunk_attention_paged(q, k8, v8, pt, off, kv_len=kv_len,
                                 k_scale=ks, v_scale=vs, impl="reference")
    got = flash_attention_paged(q, k8, v8, pt, off, kv_len,
                                k_scale=ks, v_scale=vs, interpret=True)
    assert jnp.max(jnp.abs(want - got)) < 2e-5
