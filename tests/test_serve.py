"""Serving engine: continuous batching correctness + scheduling behaviour."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.models import ExecOptions, build_model
from repro.serve.engine import ServeEngine, generate_greedy


@pytest.fixture(scope="module")
def smol():
    cfg = get_config("smollm-360m").smoke()
    model = build_model(cfg, ExecOptions(attn_impl="reference", ce_chunk=32))
    params = model.init(jax.random.key(0))
    return cfg, model, params


def _prompt(seed, n=12, vocab=512):
    return np.asarray(
        jax.random.randint(jax.random.key(seed), (n,), 0, vocab), np.int32)


def test_single_request_generates(smol):
    cfg, model, params = smol
    toks = generate_greedy(model, params, _prompt(1), n_tokens=6, max_len=64)
    assert len(toks) == 6
    assert all(0 <= t < cfg.vocab_size for t in toks)


def test_continuous_batching_matches_single(smol):
    """Tokens from a shared-engine run must equal isolated greedy runs."""
    cfg, model, params = smol
    prompts = [_prompt(s, n=8 + s) for s in (2, 3, 4)]
    solo = [generate_greedy(model, params, p, n_tokens=5, max_len=64)
            for p in prompts]
    eng = ServeEngine(model, n_slots=2, max_len=64, params=params)
    reqs = [eng.submit(p, max_new_tokens=5) for p in prompts]
    eng.run_to_completion()
    for r, want in zip(reqs, solo):
        assert r.done
        assert r.out_tokens == want, (r.out_tokens, want)


def test_slot_reuse_and_occupancy(smol):
    cfg, model, params = smol
    eng = ServeEngine(model, n_slots=2, max_len=64, params=params)
    for s in range(5):
        eng.submit(_prompt(10 + s), max_new_tokens=3)
    stats = eng.run_to_completion()
    assert stats.tokens_out == 5 * 3
    assert stats.prefills == 5           # every request admitted
    assert stats.decode_steps >= 3       # slots turned over, not 5× serial


def test_request_latency_fields(smol):
    cfg, model, params = smol
    eng = ServeEngine(model, n_slots=1, max_len=64, params=params)
    r = eng.submit(_prompt(42), max_new_tokens=4)
    eng.run_to_completion()
    assert r.t_first_token is not None and r.t_done is not None
    assert r.t_done >= r.t_first_token >= r.t_enqueue


@pytest.mark.parametrize("arch", ["mamba2-780m", "recurrentgemma-2b"])
def test_engine_state_families(arch):
    """Continuous batching over O(1)-state families (ssm / hybrid)."""
    cfg = get_config(arch).smoke()
    model = build_model(cfg, ExecOptions(attn_impl="reference", ce_chunk=32))
    params = model.init(jax.random.key(0))
    solo = generate_greedy(model, params, _prompt(7), n_tokens=4, max_len=64)
    eng = ServeEngine(model, n_slots=2, max_len=64, params=params)
    r1 = eng.submit(_prompt(7), max_new_tokens=4)
    r2 = eng.submit(_prompt(8), max_new_tokens=4)
    eng.run_to_completion()
    assert r1.out_tokens == solo
    assert len(r2.out_tokens) == 4


def test_int8_weight_path_close(smol):
    """Weight-only int8 (the 15 TOPS NPU datapath) perturbs logits only
    mildly. Token streams CAN'T be the yardstick here: random smoke-config
    weights give near-uniform logits, so per-channel quantization noise
    legitimately flips the argmax (the old token-prefix comparison sat
    unused — F841 — and the test asserted nothing about numerics). Compare
    the prefill logits directly instead."""
    from repro.kernels import ops as kops
    cfg, model, params = smol
    # quantize+dequantize every 2-D matmul weight (simulating the int8 path
    # numerics end-to-end through the model)
    def qdq(p):
        if p.ndim == 2 and p.shape[0] >= 64:
            q, s = kops.quantize_weight(p.astype(jnp.float32))
            return (q.astype(jnp.float32) * s[None, :]).astype(p.dtype)
        return p
    params_q = jax.tree.map(qdq, params)
    toks = _prompt(5)[None, :]
    logits, _ = model.prefill(params, {"tokens": toks})
    logits_q, _ = model.prefill(params_q, {"tokens": toks})
    a = np.asarray(logits, np.float64).ravel()
    b = np.asarray(logits_q, np.float64).ravel()
    corr = np.corrcoef(a, b)[0, 1]
    assert corr > 0.8, corr      # measured ~0.92 on the smoke config
    b = generate_greedy(model, params_q, _prompt(5), n_tokens=4, max_len=64)
    assert len(b) == 4  # quantized path still generates
