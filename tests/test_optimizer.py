"""Optimizer unit tests: schedule shape, AdamW semantics, clipping."""

import jax
import jax.numpy as jnp
import pytest

from repro.train import optimizer as opt


def test_lr_schedule_shape():
    cfg = opt.OptimizerConfig(peak_lr=1e-3, warmup_steps=10, total_steps=100,
                              end_lr_frac=0.1)
    lrs = [float(opt.lr_at(cfg, jnp.int32(s))) for s in range(0, 101, 5)]
    assert lrs[0] == 0.0
    assert max(lrs) == pytest.approx(1e-3, rel=1e-3)
    assert lrs[-1] == pytest.approx(1e-4, rel=0.05)      # cosine floor
    # warmup is monotone up, decay monotone down
    assert all(a <= b + 1e-12 for a, b in zip(lrs[:2], lrs[1:3]))
    assert all(a >= b - 1e-12 for a, b in zip(lrs[4:-1], lrs[5:]))


def test_clip_by_global_norm():
    g = {"a": jnp.ones((4,)) * 3.0, "b": jnp.ones((4,)) * 4.0}  # norm 10
    clipped, norm = opt.clip_by_global_norm(g, 1.0)
    assert float(norm) == pytest.approx(10.0, rel=1e-5)
    assert float(opt.global_norm(clipped)) == pytest.approx(1.0, rel=1e-5)
    small, norm2 = opt.clip_by_global_norm(
        {"a": jnp.ones((4,)) * 0.01}, 1.0)
    assert float(opt.global_norm(small)) == pytest.approx(0.02, rel=1e-4)


def test_adamw_reduces_quadratic():
    cfg = opt.OptimizerConfig(peak_lr=0.05, warmup_steps=0, total_steps=200,
                              weight_decay=0.0, clip_norm=1e9)
    target = jnp.linspace(-1, 1, 16)
    params = {"w": jnp.zeros((16,))}
    state = opt.init_opt_state(params)

    def loss(p):
        return jnp.sum((p["w"] - target) ** 2)

    for _ in range(200):
        g = jax.grad(loss)(params)
        params, state, lr = opt.adamw_update(params, g, state, cfg)
    assert float(loss(params)) < 1e-2
    assert int(state["step"]) == 200


def test_weight_decay_pulls_to_zero():
    cfg = opt.OptimizerConfig(peak_lr=0.01, warmup_steps=0, total_steps=100,
                              weight_decay=1.0, clip_norm=1e9)
    params = {"w": jnp.ones((8,))}
    state = opt.init_opt_state(params)
    zero_g = {"w": jnp.zeros((8,))}
    for _ in range(100):
        params, state, _ = opt.adamw_update(params, zero_g, state, cfg)
    assert float(jnp.max(jnp.abs(params["w"]))) < 0.7  # decayed, no grad signal


def test_param_dtype_preserved():
    cfg = opt.OptimizerConfig()
    params = {"w": jnp.ones((4,), jnp.bfloat16)}
    state = opt.init_opt_state(params)
    g = {"w": jnp.ones((4,), jnp.float32)}
    params, state, _ = opt.adamw_update(params, g, state, cfg)
    assert params["w"].dtype == jnp.bfloat16
    assert state["m"]["w"].dtype == jnp.float32
