"""Unit tests for the paper's four innovation models (I1–I4) + time-stepped SoC."""

import jax.numpy as jnp
import pytest

from repro.core import build_soc, simulate
from repro.core import dvfs as dvfs_mod
from repro.core import security as sec_mod
from repro.core import thermal as thermal_mod
from repro.core import ucie as ucie_mod
from repro.core.scenarios import SCENARIOS
from repro.core.workloads import WORKLOADS

MNV2 = WORKLOADS["mobilenetv2"]


# --- I1 DVFS -------------------------------------------------------------------

def test_dvfs_tracks_demand():
    cfg = dvfs_mod.DVFSConfig(power_budget_mw=1e9)  # budget not binding
    st = dvfs_mod.init_state(2, cfg)
    peak = jnp.asarray([300.0, 300.0])
    static = jnp.asarray([50.0, 50.0])
    for _ in range(50):
        st, (freq, power, util) = dvfs_mod.step(
            st, jnp.asarray([1.0, 0.1]), cfg, peak, static, 0.1)
    assert float(freq[0]) > float(freq[1])  # loaded chiplet clocks higher
    assert float(power[0]) > float(power[1])


def test_dvfs_respects_power_budget():
    cfg = dvfs_mod.DVFSConfig(power_budget_mw=400.0)
    st = dvfs_mod.init_state(2, cfg)
    peak = jnp.asarray([300.0, 300.0])
    static = jnp.asarray([50.0, 50.0])
    for _ in range(50):
        st, (freq, power, util) = dvfs_mod.step(
            st, jnp.asarray([1.0, 1.0]), cfg, peak, static, 0.1)
    assert float(jnp.sum(power)) <= 400.0 * 1.02


def test_dvfs_nonadaptive_stays_nominal():
    cfg = dvfs_mod.DVFSConfig(adaptive=False)
    st = dvfs_mod.init_state(3, cfg)
    peak = jnp.full((3,), 200.0)
    static = jnp.full((3,), 40.0)
    st, (freq, _, _) = dvfs_mod.step(st, jnp.asarray([0.1, 0.5, 1.0]), cfg,
                                     peak, static, 0.1)
    assert jnp.allclose(freq, 1.0)


# --- I2 UCIe --------------------------------------------------------------------

def test_ucie_streaming_reduces_overhead():
    base = ucie_mod.UCIeConfig(streaming=False, compression_ratio=1.0)
    stream = ucie_mod.UCIeConfig(streaming=True, compression_ratio=1.0)
    t_base, _, wire_base = ucie_mod.transfer(jnp.float32(1e6), base)
    t_stream, _, wire_stream = ucie_mod.transfer(jnp.float32(1e6), stream)
    assert float(wire_stream) < float(wire_base)
    assert float(t_stream) < float(t_base)


def test_ucie_compression_tradeoff():
    """Compression shrinks wire time but adds engine time; for large payloads
    on a slow link it must win."""
    slow = ucie_mod.UCIeConfig(bandwidth_gbps=8.0, compression_ratio=1.0)
    slow_c = ucie_mod.UCIeConfig(bandwidth_gbps=8.0, compression_ratio=0.5)
    t_plain, _, _ = ucie_mod.transfer(jnp.float32(5e6), slow)
    t_comp, _, _ = ucie_mod.transfer(jnp.float32(5e6), slow_c)
    assert float(t_comp) < float(t_plain)


def test_ucie_link_tick_conserves_bytes():
    cfg = ucie_mod.UCIeConfig(bandwidth_gbps=16.0)
    st = ucie_mod.init_link()
    total_in = 0.0
    drained_total = 0.0
    for _ in range(100):
        st, (drained, occ) = ucie_mod.link_tick(st, 5e4, cfg, 0.1)
        total_in += 5e4
        drained_total += float(drained)
    assert drained_total <= total_in + 1e-3
    assert drained_total > 0.5 * total_in  # link actually moves data


# --- I3 security ----------------------------------------------------------------

def test_attestation_scales_log():
    cfg = sec_mod.SecurityConfig()
    t4 = float(sec_mod.attestation_latency_us(4, cfg))
    t64 = float(sec_mod.attestation_latency_us(64, cfg))
    assert t64 == pytest.approx(3 * t4)  # log2(64)=6 vs log2(4)=2


def test_merkle_attestation_detects_tamper():
    payloads = {f"chiplet-{i}": f"fw-blob-{i}".encode() for i in range(5)}
    key = b"interposer-session-key"
    manifest = sec_mod.attest_manifest(payloads, key)
    assert sec_mod.verify_manifest(payloads, key, manifest)
    bad = dict(payloads, **{"chiplet-2": b"counterfeit"})
    assert not sec_mod.verify_manifest(bad, key, manifest)
    assert not sec_mod.verify_manifest(payloads, b"wrong-key", manifest)


def test_merkle_proofs():
    leaves = [sec_mod.leaf_digest(f"c{i}", bytes([i])) for i in range(7)]
    root = sec_mod.merkle_root(leaves)
    for i in (0, 3, 6):
        proof = sec_mod.merkle_proof(leaves, i)
        assert sec_mod.verify_proof(leaves[i], proof, root)
    assert not sec_mod.verify_proof(leaves[0], sec_mod.merkle_proof(leaves, 1),
                                    root)


def test_tree_vs_centralized_scaling():
    cfg = sec_mod.SecurityConfig()
    n = 64
    tree = float(sec_mod.attestation_latency_us(n, cfg))
    central = float(sec_mod.centralized_attestation_latency_us(n, cfg))
    assert tree < central  # the paper's scalability argument


def test_aead_overhead_zero_when_disabled():
    t, e = sec_mod.aead_overhead(1e6, sec_mod.SecurityConfig(enabled=False))
    assert float(t) == 0.0 and float(e) == 0.0


# --- I4 thermal -----------------------------------------------------------------

def _thermal_cfg(predictive):
    # small C → RC ≈ 16 ms so 400 ticks (40 ms) reach steady state
    return thermal_mod.ThermalConfig(
        r_k_per_w=(8.0, 8.0), c_j_per_k=(0.002, 0.002), predictive=predictive,
        t_migrate_c=60.0, t_throttle_c=70.0)


def test_thermal_heats_and_cools():
    cfg = _thermal_cfg(False)
    st = thermal_mod.init_state(cfg)
    q = jnp.asarray([0.0, 0.0])
    npu = jnp.asarray([True, True])
    for _ in range(200):
        st, (clock, q) = thermal_mod.step(st, jnp.asarray([5000.0, 0.0]),
                                          npu, q, cfg, 0.1)
    assert float(st.temp_c[0]) > float(st.temp_c[1]) > cfg.t_ambient_c - 1e-3


def test_predictive_migration_moves_load():
    cfg = _thermal_cfg(True)
    st = thermal_mod.init_state(cfg)
    q = jnp.asarray([50.0, 0.0])    # all work queued on NPU 0
    npu = jnp.asarray([True, True])
    migrated = False
    for _ in range(400):
        st, (clock, q) = thermal_mod.step(st, jnp.asarray([5000.0, 100.0]),
                                          npu, q, cfg, 0.1)
        if float(st.migrations) > 0:
            migrated = True
            break
    assert migrated
    assert float(q[1]) > 0.0        # load actually moved to the cool NPU


def test_reactive_throttles_instead():
    cfg = _thermal_cfg(False)
    st = thermal_mod.init_state(cfg)
    q = jnp.asarray([50.0, 0.0])
    npu = jnp.asarray([True, True])
    clock_min = 1.0
    for _ in range(400):
        st, (clock, q) = thermal_mod.step(st, jnp.asarray([5000.0, 100.0]),
                                          npu, q, cfg, 0.1)
        clock_min = min(clock_min, float(jnp.min(clock)))
    assert float(st.migrations) == 0
    assert clock_min < 1.0          # derated


# --- time-stepped SoC ----------------------------------------------------------

def test_soc_steady_state_matches_closed_form_ordering():
    out = {}
    for s in ("basic_chiplet", "ai_optimized"):
        soc = build_soc(SCENARIOS[s])
        out[s] = simulate(soc, MNV2, arrival_rate_ips=150.0, duration_ms=100.0)
    assert float(out["ai_optimized"]["energy_mj_per_inf"]) \
        < float(out["basic_chiplet"]["energy_mj_per_inf"])
    assert float(out["ai_optimized"]["latency_ms"]) \
        < float(out["basic_chiplet"]["latency_ms"])


def test_soc_overload_saturates_not_explodes():
    soc = build_soc(SCENARIOS["ai_optimized"])
    out = simulate(soc, MNV2, arrival_rate_ips=5000.0, duration_ms=100.0)
    assert float(out["throughput_ips"]) < 5000.0
    assert float(out["peak_temp_c"]) < 120.0
    assert float(out["npu_utilization"]) > 0.5
