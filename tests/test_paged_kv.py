"""Paged KV cache: the page-pool engine must be token-exact against the
dense single-request oracle at prompt lengths spanning page boundaries, for
every attention family — plus the serve-engine correctness fixes that ride
along (capacity off-by-one, idle-slot drift, stats summary)."""

import jax
import numpy as np
import pytest

from repro.configs import get_config
from repro.models import ExecOptions, build_model
from repro.serve.engine import EngineStats, ServeEngine, generate_greedy


@pytest.fixture(scope="module")
def smol():
    cfg = get_config("smollm-360m").smoke()
    model = build_model(cfg, ExecOptions(attn_impl="reference", ce_chunk=32))
    params = model.init(jax.random.key(0))
    return cfg, model, params


def _prompt(seed, n, vocab=512):
    return np.asarray(
        jax.random.randint(jax.random.key(seed), (n,), 0, vocab), np.int32)


# ---------------------------------------------------------------- equivalence
def test_paged_matches_dense_oracle_across_page_boundaries(smol):
    """Prompt lengths straddling page edges (page_size=8), including
    prompt_len == page_size, must match the dense-oracle tokens exactly."""
    cfg, model, params = smol
    lengths = (7, 8, 9, 15, 16, 17, 31)
    solo = {n: generate_greedy(model, params, _prompt(n, n), n_tokens=4,
                               max_len=64)
            for n in lengths}
    eng = ServeEngine(model, n_slots=2, max_len=64, params=params,
                      page_size=8)
    assert eng.paged
    reqs = {n: eng.submit(_prompt(n, n), max_new_tokens=4) for n in lengths}
    eng.run_to_completion()
    for n in lengths:
        assert reqs[n].done
        assert reqs[n].out_tokens == solo[n], (n, reqs[n].out_tokens, solo[n])
    # pool occupancy: every reserved page returned on retirement
    assert eng.stats.pages_in_use == 0
    assert eng.pages_allocatable() == eng.n_pages - 1


def test_prompt_len_equals_max_len(smol):
    """A prompt that fills the cache exactly still yields one token (the
    replayed last-prompt position) and matches the oracle."""
    cfg, model, params = smol
    p = _prompt(99, 32)
    solo = generate_greedy(model, params, p, n_tokens=4, max_len=32)
    eng = ServeEngine(model, n_slots=1, max_len=32, params=params,
                      page_size=8)
    r = eng.submit(p, max_new_tokens=4)
    eng.run_to_completion()
    assert r.done
    assert len(r.out_tokens) == 1
    assert r.out_tokens == solo


@pytest.mark.parametrize("arch", ["qwen2-moe-a2.7b", "llava-next-mistral-7b"])
def test_paged_families_match_oracle(arch):
    """moe and vlm ride the transformer decode path; the paged pool must stay
    token-exact for them too."""
    cfg = get_config(arch).smoke()
    model = build_model(cfg, ExecOptions(attn_impl="reference", ce_chunk=32))
    params = model.init(jax.random.key(1))
    solo = {n: generate_greedy(model, params, _prompt(n, n), n_tokens=3,
                               max_len=64)
            for n in (7, 9)}
    eng = ServeEngine(model, n_slots=2, max_len=64, params=params,
                      page_size=8)
    reqs = {n: eng.submit(_prompt(n, n), max_new_tokens=3) for n in (7, 9)}
    eng.run_to_completion()
    for n, r in reqs.items():
        assert r.out_tokens == solo[n], (n, r.out_tokens, solo[n])


def test_paged_encdec_matches_oracle():
    """encdec: paged decoder self-attention KV + dense cross K/V; frames ride
    the new `extras=` prefill input."""
    cfg = get_config("seamless-m4t-medium").smoke()
    model = build_model(cfg, ExecOptions(attn_impl="reference", ce_chunk=32))
    params = model.init(jax.random.key(2))
    frames = np.asarray(jax.random.normal(
        jax.random.key(9), (cfg.cross_len, cfg.d_model)), np.float32)
    p = _prompt(4, 9)
    solo = generate_greedy(model, params, p, n_tokens=4, max_len=64,
                           extras={"frames": frames})
    eng = ServeEngine(model, n_slots=2, max_len=64, params=params,
                      page_size=8)
    r = eng.submit(p, max_new_tokens=4, extras={"frames": frames})
    eng.run_to_completion()
    assert r.out_tokens == solo, (r.out_tokens, solo)


def test_pool_smaller_than_dense_worst_case(smol):
    """A pool sized well below n_slots × max_len must serve the whole queue
    exactly (admission control blocks on the free list) and report a peak
    page usage within the pool."""
    cfg, model, params = smol
    # dense worst case would be 2 slots × 8 pages; give the pool 7 + null
    eng = ServeEngine(model, n_slots=2, max_len=64, params=params,
                      page_size=8, n_pages=8)
    solo = {}
    reqs = {}
    for n in (6, 10, 14, 18):
        solo[n] = generate_greedy(model, params, _prompt(n, n), n_tokens=4,
                                  max_len=64)
        reqs[n] = eng.submit(_prompt(n, n), max_new_tokens=4)
    stats = eng.run_to_completion()
    for n, r in reqs.items():
        assert r.done
        assert r.out_tokens == solo[n], (n, r.out_tokens, solo[n])
    assert stats.peak_pages_in_use <= 7
    assert stats.pages_in_use == 0          # everything returned
    assert eng.pages_allocatable() == 7


def test_auto_page_size_adapts_to_max_len(smol):
    """Auto (paged=None) engines must accept any max_len the dense engine
    took: page_size shrinks to fit, or falls back to dense when pages would
    degenerate; explicit paged=True with a misfit raises."""
    cfg, model, params = smol
    eng = ServeEngine(model, n_slots=1, max_len=48, params=params)  # 48 % 32 != 0
    assert eng.paged and eng.page_size == 16
    r = eng.submit(_prompt(2, 9), max_new_tokens=3)
    eng.run_to_completion()
    assert r.out_tokens == generate_greedy(model, params, _prompt(2, 9),
                                           n_tokens=3, max_len=48)
    assert not ServeEngine(model, n_slots=1, max_len=100, params=params).paged
    with pytest.raises(ValueError):
        ServeEngine(model, n_slots=1, max_len=100, params=params, paged=True)


def test_oversized_request_rejected(smol):
    cfg, model, params = smol
    eng = ServeEngine(model, n_slots=1, max_len=64, params=params,
                      page_size=8, n_pages=4)   # 3 usable pages = 24 rows
    with pytest.raises(ValueError):
        eng.submit(_prompt(0, 30), max_new_tokens=16)


# ----------------------------------------------------- capacity off-by-one
def test_capacity_fills_cache_exactly(smol):
    """Retirement happens when the NEXT write would overflow — the engine
    must emit max_len - plen + 1 tokens (not one fewer), identically on the
    replay (bucketed) and non-replay paths, and match the oracle."""
    cfg, model, params = smol
    max_len = 16
    for plen in (8, 15, 16):
        want_n = max_len - plen + 1
        p = _prompt(plen, plen)
        solo = generate_greedy(model, params, p, n_tokens=32, max_len=max_len)
        assert len(solo) == want_n, (plen, len(solo))
        eng = ServeEngine(model, n_slots=1, max_len=max_len, params=params,
                          page_size=8)
        r = eng.submit(p, max_new_tokens=32)
        eng.run_to_completion()
        assert len(r.out_tokens) == want_n, (plen, len(r.out_tokens))
        assert r.out_tokens == solo
        # dense engine, same capacity semantics
        engd = ServeEngine(model, n_slots=1, max_len=max_len, params=params,
                           paged=False)
        rd = engd.submit(p, max_new_tokens=32)
        engd.run_to_completion()
        assert rd.out_tokens == solo


def test_single_token_budget_consistent_across_paths(smol):
    """max_new_tokens=1 must yield exactly one token on both the replay
    (bucketed) and non-replay admission paths."""
    cfg, model, params = smol
    p = _prompt(3, 9)
    for kw in (dict(), dict(bucket_prompts=False), dict(paged=False)):
        eng = ServeEngine(model, n_slots=1, max_len=64, params=params, **kw)
        r = eng.submit(p, max_new_tokens=1)
        eng.run_to_completion()
        assert r.done and len(r.out_tokens) == 1, (kw, r.out_tokens)


# ------------------------------------------------------------ idle-slot drift
def test_idle_slot_tick_is_noop(smol):
    """After a slot retires, further engine ticks must not advance its
    stream position or perturb the surviving request's tokens."""
    cfg, model, params = smol
    solo = generate_greedy(model, params, _prompt(5, 10), n_tokens=20,
                           max_len=64)
    eng = ServeEngine(model, n_slots=2, max_len=64, params=params,
                      page_size=8)
    r_long = eng.submit(_prompt(5, 10), max_new_tokens=20)
    r_short = eng.submit(_prompt(6, 6), max_new_tokens=2)
    idle_pos = []
    idle_table = []
    while not r_long.done:
        eng.step()
        if r_short.done and not r_long.done:
            idle_pos.append(int(np.asarray(eng._cache["pos"])[1]))
            idle_table.append(np.asarray(eng._cache["page_table"])[1].copy())
    assert r_long.out_tokens == solo
    assert len(set(idle_pos)) == 1, idle_pos          # pos frozen, no drift
    assert all((t == 0).all() for t in idle_table)    # row points at null page


def test_idle_slot_never_corrupts_pool_pages(smol):
    """Freed pages get re-issued to new requests while the freed slot keeps
    ticking; its masked writes must land on the null page, never on the
    reallocated pages."""
    cfg, model, params = smol
    eng = ServeEngine(model, n_slots=2, max_len=64, params=params,
                      page_size=8, n_pages=6)        # tight pool forces reuse
    solo = {}
    reqs = {}
    for i, n in enumerate((6, 9, 12, 7)):
        solo[(i, n)] = generate_greedy(model, params, _prompt(20 + i, n),
                                       n_tokens=5, max_len=64)
        reqs[(i, n)] = eng.submit(_prompt(20 + i, n), max_new_tokens=5)
    eng.run_to_completion()
    for key, r in reqs.items():
        assert r.out_tokens == solo[key], (key, r.out_tokens, solo[key])
    assert eng.stats.pages_in_use == 0
    assert eng.pages_allocatable() == eng.n_pages - 1


# ------------------------------------------------------------------- summary
def test_summary_always_emits_mean_occupancy():
    assert EngineStats().summary()["mean_occupancy"] == 0.0
    s = EngineStats(decode_steps=4, occupancy_sum=2.0)
    assert s.summary()["mean_occupancy"] == 0.5


def test_prefill_only_engine_summary(smol):
    """An engine that admitted but never decoded must still summarize."""
    cfg, model, params = smol
    eng = ServeEngine(model, n_slots=1, max_len=64, params=params,
                      page_size=8)
    eng.submit(_prompt(1, 5), max_new_tokens=2)
    eng._admit()                 # prefill happened, zero decode steps
    d = eng.stats.summary()
    assert d["mean_occupancy"] == 0.0 and d["prefills"] == 1


# ---------------------------------------------------------------- memory math
def test_paged_cache_smaller_than_dense(smol):
    """The whole point: pool bytes scale with n_pages, not slots × max_len."""
    cfg, model, params = smol
    dense = ServeEngine(model, n_slots=4, max_len=64, params=params,
                        paged=False)
    paged = ServeEngine(model, n_slots=4, max_len=64, params=params,
                        page_size=8, n_pages=9)      # 64 usable rows vs 256
    assert paged.kv_cache_bytes() < 0.4 * dense.kv_cache_bytes()
