"""End-to-end INT8 decode path (weights + KV), quality-guarded.

Layering of the guards:
  * STRUCTURE  — quantize_params quantizes exactly the projection weights,
    per output channel (per expert for MoE), within the int8 grid's error
    bound.
  * KERNEL     — qeinsum's Pallas dispatch (interpret mode) is bit-identical
    to its jnp dequant-matmul reference for both the 2-D and the vmapped
    expert patterns.
  * ENGINE     — an int8 engine (paged + bucketed + batched) is TOKEN-EXACT
    against the dense int8 oracle for all four attention families across
    page-boundary prompt lengths: row quantization is layout-independent, so
    any drift is an engine bug, not quantization noise.
  * QUALITY    — vs the f32 oracle the guard is numeric (prefill logits RMS
    relative error) plus a token-divergence tolerance. Smoke models are
    RANDOM-INIT, so greedy logits sit near ties and a sub-percent
    perturbation can flip argmax — the divergence tolerance is therefore
    loose (mean prefix divergence <= 0.7 for the bench config, <= 0.9 per
    family); the tight guarantees live in the exactness layers above.
"""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.models import ExecOptions, build_model
from repro.models.quantized import (
    is_quantized, qeinsum, quantize_kv_rows, quantize_params,
    quantize_weight_channelwise, token_divergence,
)
from repro.serve.engine import ServeEngine, generate_greedy


def _prompt(seed, n, vocab=512):
    return np.asarray(
        jax.random.randint(jax.random.key(seed), (n,), 0, vocab), np.int32)


def _build(arch):
    cfg = get_config(arch).smoke()
    model = build_model(cfg, ExecOptions(attn_impl="reference", ce_chunk=32))
    params = model.init(jax.random.key(1))
    extras = None
    if cfg.family == "encdec":
        extras = {"frames": np.asarray(jax.random.normal(
            jax.random.key(9), (cfg.cross_len, cfg.d_model)), np.float32)}
    return cfg, model, params, extras


@pytest.fixture(scope="module")
def smol():
    return _build("smollm-360m")


# ------------------------------------------------------------------ structure
def test_quantize_params_structure_and_bounds(smol):
    cfg, model, params, _ = smol
    qp = quantize_params(params, cfg)
    for key in ("wq", "wk", "wv", "wo", "w1", "w2", "w3"):
        assert is_quantized(qp["layers"][key]), key
    for key in ("attn_norm", "ffn_norm"):
        assert not is_quantized(qp["layers"][key]), key
    assert not is_quantized(qp["embed"])
    # per-channel reconstruction within half an int8 grid step
    w = params["layers"]["wq"]
    q = qp["layers"]["wq"]
    back = q["int8_q"].astype(jnp.float32) * q["s"]
    err = jnp.max(jnp.abs(w.astype(jnp.float32) - back))
    assert float(err) <= float(jnp.max(q["s"])) * 0.5 + 1e-6


def test_quantize_params_moe_per_expert():
    cfg, model, params, _ = _build("qwen2-moe-a2.7b")
    qp = quantize_params(params, cfg)
    w1 = qp["layers"]["w1"]
    assert is_quantized(w1)
    L, e = params["layers"]["w1"].shape[:2]
    # scale keeps (layer, expert, 1, channel): per-expert channels
    assert w1["s"].shape[:2] == (L, e) and w1["s"].shape[2] == 1
    assert not is_quantized(qp["layers"]["router"])


def test_quantize_params_rejects_recurrent_families():
    cfg, model, params, _ = _build("mamba2-780m")
    with pytest.raises(ValueError):
        quantize_params(params, cfg)


# -------------------------------------------------------------------- qeinsum
def test_qeinsum_passthrough_plain_weights():
    x = jax.random.normal(jax.random.key(0), (2, 3, 64), jnp.float32)
    w = jax.random.normal(jax.random.key(1), (64, 32), jnp.float32)
    np.testing.assert_array_equal(np.asarray(qeinsum("bsd,df->bsf", x, w)),
                                  np.asarray(jnp.einsum("bsd,df->bsf", x, w)))


@pytest.mark.parametrize("eq,xs,wshape,axes", [
    ("bsd,dhk->bshk", (2, 4, 128), (128, 4, 32), (0,)),     # qkv projection
    ("bshk,hkd->bsd", (2, 4, 4, 32), (4, 32, 128), (0, 1)), # output proj
    ("bsf,fd->bsd", (2, 4, 256), (256, 128), (0,)),         # ffn down
])
def test_qeinsum_pallas_matches_jnp_reference(eq, xs, wshape, axes):
    """Forced-kernel (interpret) dispatch must agree with the jnp dequant
    path bit-for-bit — both accumulate f32 and scale in the epilogue."""
    x = jax.random.normal(jax.random.key(2), xs, jnp.float32)
    w = quantize_weight_channelwise(
        jax.random.normal(jax.random.key(3), wshape, jnp.float32), axes)
    got = qeinsum(eq, x, w, impl="pallas", interpret=True)
    want = qeinsum(eq, x, w, impl="jnp")
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-6, atol=1e-6)


def test_qeinsum_pallas_vmaps_expert_weights():
    """The MoE pattern (shared leading expert dim) rides jax.vmap over the
    kernel — one grid batch dim per expert."""
    xe = jax.random.normal(jax.random.key(4), (4, 2, 64, 128), jnp.float32)
    we = quantize_weight_channelwise(
        jax.random.normal(jax.random.key(5), (4, 128, 128), jnp.float32), (1,))
    got = qeinsum("egcd,edf->egcf", xe, we, impl="pallas", interpret=True)
    want = qeinsum("egcd,edf->egcf", xe, we, impl="jnp")
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-6, atol=1e-6)


def test_qeinsum_pallas_falls_back_on_unfit_shapes():
    """N not divisible by the clamped block must fall back to jnp (not
    crash inside the kernel's asserts)."""
    x = jax.random.normal(jax.random.key(6), (2, 4, 128), jnp.float32)
    w = quantize_weight_channelwise(
        jax.random.normal(jax.random.key(7), (128, 4, 40), jnp.float32), (0,))
    got = qeinsum("bsd,dhk->bshk", x, w, impl="pallas", interpret=True)
    want = qeinsum("bsd,dhk->bshk", x, w, impl="jnp")
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-6, atol=1e-6)


# ----------------------------------------------------------- KV rows / bounds
def test_quantize_kv_rows_roundtrip_bound():
    kv = jax.random.normal(jax.random.key(8), (3, 17, 2, 32), jnp.float32)
    q, s = quantize_kv_rows(kv)
    assert q.dtype == jnp.int8 and s.shape == kv.shape[:-1]
    back = q.astype(jnp.float32) * s.astype(jnp.float32)[..., None]
    err = np.abs(np.asarray(kv) - np.asarray(back))
    bound = np.asarray(s, np.float32)[..., None] * 0.5 + 1e-6
    assert (err <= bound).all()


# ---------------------------------------------------- engine: exact vs oracle
@pytest.mark.parametrize("wdtype,kv_dtype", [
    ("int8", None), (None, "int8"), ("int8", "int8")])
def test_int8_engine_token_exact_vs_int8_oracle(smol, wdtype, kv_dtype):
    """Paged + bucketed int8 engine == dense int8 oracle, token for token,
    at prompt lengths straddling page edges (page_size=8). Quantization is
    per-row and layout-independent, so these must be EXACT."""
    cfg, model, params, _ = smol
    lengths = (7, 8, 9, 16, 17)
    solo = {n: generate_greedy(model, params, _prompt(n, n), n_tokens=4,
                               max_len=64, wdtype=wdtype, kv_dtype=kv_dtype)
            for n in lengths}
    eng = ServeEngine(model, n_slots=2, max_len=64, params=params,
                      page_size=8, wdtype=wdtype, kv_dtype=kv_dtype)
    reqs = {n: eng.submit(_prompt(n, n), max_new_tokens=4) for n in lengths}
    eng.run_to_completion()
    for n in lengths:
        assert reqs[n].done
        assert reqs[n].out_tokens == solo[n], (n, reqs[n].out_tokens, solo[n])
    assert eng.stats.pages_in_use == 0      # pool fully returned


@pytest.mark.slow
@pytest.mark.parametrize("arch", ["qwen2-moe-a2.7b", "llava-next-mistral-7b",
                                  "seamless-m4t-medium"])
def test_int8_engine_families_exact(arch):
    """moe / vlm / encdec: full-int8 paged engines stay token-exact against
    their dense int8 oracles across a page boundary."""
    cfg, model, params, extras = _build(arch)
    solo = {n: generate_greedy(model, params, _prompt(n, n), n_tokens=3,
                               max_len=64, wdtype="int8", kv_dtype="int8",
                               extras=extras)
            for n in (7, 9)}
    eng = ServeEngine(model, n_slots=2, max_len=64, params=params,
                      page_size=8, wdtype="int8", kv_dtype="int8")
    reqs = {n: eng.submit(_prompt(n, n), max_new_tokens=3, extras=extras)
            for n in (7, 9)}
    eng.run_to_completion()
    for n, r in reqs.items():
        assert r.out_tokens == solo[n], (arch, n, r.out_tokens, solo[n])
    assert eng.stats.pages_in_use == 0


# ------------------------------------------------------- quality vs f32 oracle
@pytest.mark.slow
@pytest.mark.parametrize("arch,tol", [
    ("smollm-360m", 0.5), ("qwen2-moe-a2.7b", 0.5),
    ("llava-next-mistral-7b", 0.6),
    # random-init enc+dec stacks with cross attention compound the per-layer
    # quantization error; still an order of magnitude under a scale bug
    ("seamless-m4t-medium", 1.0),
])
def test_int8_prefill_logits_close_to_f32(arch, tol):
    """Numeric quality guard: weight-only int8 perturbs prefill logits by a
    bounded RMS relative error. (A mis-applied or dropped per-channel scale
    fails this at O(10)-O(100).)"""
    cfg, model, params, extras = _build(arch)
    batch = {"tokens": jnp.asarray(_prompt(3, 9)[None])}
    if extras:
        batch["frames"] = jnp.asarray(extras["frames"])[None]
    lf, _ = model.prefill(params, batch)
    lq, _ = model.prefill(quantize_params(params, cfg), batch)
    rms = float(jnp.sqrt(jnp.mean((lq - lf) ** 2))
                / jnp.sqrt(jnp.mean(lf ** 2)))
    assert rms < tol, (arch, rms)


@pytest.mark.slow
@pytest.mark.parametrize("arch,tol", [
    ("smollm-360m", 0.7),            # the serve-bench config: tighter
    ("qwen2-moe-a2.7b", 0.9),
    ("llava-next-mistral-7b", 0.9),
    ("seamless-m4t-medium", 0.95),   # random frames + random weights: the
])                                   # greedy argmax sits nearest to ties
def test_int8_token_divergence_bounded(arch, tol):
    """Greedy streams vs the f32 dense oracle stay within the stated mean
    prefix-divergence tolerance over page-boundary prompt lengths. Loose by
    necessity on random-init smoke models (see module docstring); the exact
    guarantees are the int8-oracle equivalence tests above."""
    cfg, model, params, extras = _build(arch)
    divs = []
    for n in (7, 8, 9, 16, 17):
        base = generate_greedy(model, params, _prompt(n, n), n_tokens=6,
                               max_len=64, extras=extras)
        q8 = generate_greedy(model, params, _prompt(n, n), n_tokens=6,
                             max_len=64, wdtype="int8", kv_dtype="int8",
                             extras=extras)
        divs.append(token_divergence(base, q8))
    mean = sum(divs) / len(divs)
    assert mean <= tol, (arch, divs)


# -------------------------------------------------------------- memory + API
def test_int8_kv_pool_bytes_vs_bf16(smol):
    """The acceptance ratio: int8 pool (int8 rows + f16 row scales + table)
    <= ~0.55x the bf16 pool, same paging geometry."""
    cfg, model, params, _ = smol
    kw = dict(n_slots=4, max_len=64, params=params, page_size=8)
    bf = ServeEngine(model, **kw, kv_dtype="bf16")
    i8 = ServeEngine(model, **kw, kv_dtype="int8")
    ratio = i8.kv_cache_bytes() / bf.kv_cache_bytes()
    assert ratio <= 0.55, ratio


def test_int8_dtype_validation(smol):
    cfg, model, params, _ = smol
    with pytest.raises(ValueError):
        ServeEngine(model, params=params, wdtype="fp4")
    with pytest.raises(ValueError):
        ServeEngine(model, params=params, kv_dtype="int4")
    cfg2, model2, params2, _ = _build("mamba2-780m")
    with pytest.raises(ValueError):
        ServeEngine(model2, params=params2, wdtype="int8")
    with pytest.raises(ValueError):
        ServeEngine(model2, params=params2, kv_dtype="int8")


# ------------------------------------------------- sliding-window page slots
def test_window_slots_hold_o_window_pages(smol):
    """A window-attention config generating far past its window must hold
    O(window) pages — freed/unmapped mid-flight — and stay token-exact
    against the dense oracle (whose window mask hides the same rows)."""
    cfg, model, params, _ = smol
    cfgw = dataclasses.replace(cfg, window=16)
    mw = build_model(cfgw, ExecOptions(attn_impl="reference", ce_chunk=32))
    pw = mw.init(jax.random.key(2))
    p = _prompt(21, 12)
    solo = generate_greedy(mw, pw, p, n_tokens=48, max_len=64)
    eng = ServeEngine(mw, n_slots=1, max_len=64, params=pw, page_size=8)
    assert eng._window == 16
    r = eng.submit(p, max_new_tokens=48)
    eng.run_to_completion()
    assert r.out_tokens == solo
    # O(window): ceil((W-1)/ps) + 3 pages, NOT the 8-page full span
    assert eng.stats.peak_pages_in_use <= eng._window_pages() < 8
    assert eng.stats.pages_in_use == 0 \
        and eng.pages_allocatable() == eng.n_pages - 1


def test_window_pool_frees_pages_for_queued_requests(smol):
    """Mid-flight frees must reach the shared pool: two long window requests
    through a pool far smaller than their combined span, exact tokens."""
    cfg, model, params, _ = smol
    cfgw = dataclasses.replace(cfg, window=8)
    mw = build_model(cfgw, ExecOptions(attn_impl="reference", ce_chunk=32))
    pw = mw.init(jax.random.key(3))
    solo = {s: generate_greedy(mw, pw, _prompt(s, 10), n_tokens=30,
                               max_len=64) for s in (31, 32)}
    # full span would be 2 slots x 5 pages; window needs only 3+1 each
    eng = ServeEngine(mw, n_slots=2, max_len=64, params=pw, page_size=8,
                      n_pages=9)
    reqs = {s: eng.submit(_prompt(s, 10), max_new_tokens=30) for s in (31, 32)}
    eng.run_to_completion()
    for s, r in reqs.items():
        assert r.done and r.out_tokens == solo[s], (s, r.out_tokens, solo[s])
    assert eng.stats.pages_in_use == 0


def test_window_int8_combined(smol):
    """Window recycling composes with the int8 pool: same exactness vs the
    dense int8 oracle."""
    cfg, model, params, _ = smol
    cfgw = dataclasses.replace(cfg, window=16)
    mw = build_model(cfgw, ExecOptions(attn_impl="reference", ce_chunk=32))
    pw = mw.init(jax.random.key(4))
    p = _prompt(33, 20)
    solo = generate_greedy(mw, pw, p, n_tokens=30, max_len=64,
                           wdtype="int8", kv_dtype="int8")
    eng = ServeEngine(mw, n_slots=1, max_len=64, params=pw, page_size=8,
                      wdtype="int8", kv_dtype="int8")
    r = eng.submit(p, max_new_tokens=30)
    eng.run_to_completion()
    assert r.out_tokens == solo
    assert eng.stats.peak_pages_in_use <= eng._window_pages()
