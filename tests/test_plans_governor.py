"""Execution plans (tp16 / dp_heavy / serve_ws) + the I1 governor bridge."""

import os
import subprocess
import sys

import pytest

from repro.core.planner import RooflineTerms, plan
from repro.parallel.sharding import PLAN_RULES, rules_for_plan
from repro.train.governor import GovernorState, govern, step_governor


def test_plan_registry():
    assert set(PLAN_RULES) == {"tp16", "dp_heavy", "serve_ws",
                               "serve_sharded"}
    for p in PLAN_RULES:
        rules = rules_for_plan(p)
        assert "batchlike" in rules and "ff" in rules


def test_planner_picks_bottleneck_features():
    coll_bound = RooflineTerms(flops=1e15, hbm_bytes=1e12,
                               collective_bytes=1e15, chips=256,
                               model_flops=5e14)
    d = plan(coll_bound, is_training=True)
    assert d.compress_grads and not d.int8_weights
    mem_bound = RooflineTerms(flops=1e13, hbm_bytes=1e15,
                              collective_bytes=1e11, chips=256,
                              model_flops=5e12)
    d = plan(mem_bound, is_training=False)
    assert d.int8_weights and not d.compress_grads


def test_governor_translates_plan():
    terms = RooflineTerms(flops=1e15, hbm_bytes=1e12, collective_bytes=1e15,
                          chips=256, model_flops=5e14)
    ov = govern(terms, is_training=True)
    assert ov.get("grad_compression") == "int8"
    st = GovernorState(power_budget_w=300.0)
    for _ in range(50):
        st = step_governor(st, simulated_power_w=150.0)
    assert st.headroom_ema > 0.3
    ov = govern(terms, is_training=True, state=st)
    assert ov.get("n_micro_bias") == -1  # headroom → spend it on throughput


@pytest.mark.slow
def test_dp_heavy_plan_trains_multidevice():
    """dp_heavy on an 8-device mesh: lowering + one real step, loss finite."""
    script = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import dataclasses, jax, jax.numpy as jnp, numpy as np
from repro.configs import get_config
from repro.configs.base import ShapeConfig
from repro.launch.mesh import make_mesh
from repro.launch import steps
from repro.models import build_model
from repro.models.registry import make_inputs

mesh = make_mesh((4, 2), ("data", "model"))
cfg = dataclasses.replace(get_config("smollm-360m").smoke(), dtype="bfloat16")
shape = ShapeConfig("t", "train", 64, 8)
for plan in ("tp16", "dp_heavy"):
    jitted, abs_args = steps.build_cell(cfg, shape, mesh, {"plan": plan})
    # materialize params exactly the way build_cell shapes them
    import repro.parallel.sharding as sh
    rules = sh.rules_for_plan(plan)
    mcfg = cfg if plan == "dp_heavy" else steps.arch_for_mesh(cfg, mesh)
    opts = steps.exec_options_for(mcfg, shape, mesh, None, rules)
    model = build_model(mcfg, opts)
    params = model.init(jax.random.key(0))
    from repro.train import optimizer as opt_mod
    state = {"params": params, "opt": opt_mod.init_opt_state(params)}
    batch = make_inputs(cfg, shape, jax.random.key(1))
    state, metrics = jitted(state, batch)
    loss = float(metrics["loss"])
    assert np.isfinite(loss) and loss > 1.0, (plan, loss)
    print(plan, "loss", loss)
print("PLANS_OK")
"""
    env = {**os.environ, "PYTHONPATH": "src"}
    r = subprocess.run([sys.executable, "-c", script], env=env,
                       capture_output=True, text=True, cwd="/root/repo",
                       timeout=560)
    assert r.returncode == 0, r.stderr[-3000:]
    assert "PLANS_OK" in r.stdout