"""Vmapped time-stepped sweeps: `simulate_batch` must agree with per-scenario
`simulate`, run as one compiled program, and never rank a stalled design
best (inf latency at zero throughput)."""

import jax
import jax.numpy as jnp
import pytest

from repro.core import build_soc, simulate, simulate_batch
from repro.core.scenarios import SCENARIO_ORDER, SCENARIOS
from repro.core.soc import _batch_fn, soc_params
from repro.core.workloads import WORKLOADS

MNV2 = WORKLOADS["mobilenetv2"]
RATES = jnp.asarray([25., 50., 100., 150., 200., 300., 500., 1000.])
DUR = 50.0


@pytest.fixture(scope="module")
def grid():
    socs = [build_soc(SCENARIOS[s]) for s in SCENARIO_ORDER]
    return simulate_batch(socs, MNV2, RATES, duration_ms=DUR)


def test_shapes_cover_full_grid(grid):
    for key in ("throughput_ips", "latency_ms", "energy_mj", "peak_temp_c"):
        assert grid[key].shape == (len(SCENARIO_ORDER), RATES.shape[0]), key


@pytest.mark.parametrize("i_scen,i_rate", [(0, 0), (1, 3), (2, 4), (3, 7)])
def test_matches_per_scenario_simulate(grid, i_scen, i_rate):
    soc = build_soc(SCENARIOS[SCENARIO_ORDER[i_scen]])
    one = simulate(soc, MNV2, arrival_rate_ips=float(RATES[i_rate]),
                   duration_ms=DUR)
    for key in ("throughput_ips", "latency_ms", "avg_power_mw",
                "peak_temp_c", "energy_mj", "npu_utilization"):
        a = float(one[key])
        b = float(grid[key][i_scen, i_rate])
        assert a == pytest.approx(b, rel=1e-4, abs=1e-6), (key, a, b)


def test_single_compiled_program():
    """The whole scenario×rate grid lowers through ONE cached jit — repeat
    sweeps with the same static config must not re-lower."""
    socs = [build_soc(SCENARIOS[s]) for s in SCENARIO_ORDER]
    _batch_fn.cache_clear()
    simulate_batch(socs, MNV2, RATES, duration_ms=DUR)
    simulate_batch(socs, MNV2, RATES * 1.1, duration_ms=DUR)
    info = _batch_fn.cache_info()
    assert info.misses == 1 and info.hits == 1, info


def test_stalled_config_reports_inf_latency():
    soc = build_soc(SCENARIOS["ai_optimized"])
    out = simulate(soc, MNV2, arrival_rate_ips=0.0, duration_ms=20.0)
    assert float(out["throughput_ips"]) == 0.0
    assert float(out["latency_ms"]) == float("inf")
    # and a sweep containing it never ranks it best
    grid = simulate_batch([soc], MNV2, jnp.asarray([0.0, 100.0]),
                          duration_ms=20.0)
    best = int(jnp.argmin(grid["latency_ms"][0]))
    assert best == 1


def test_params_roundtrip_pytree():
    p = soc_params(build_soc(SCENARIOS["ai_optimized"]))
    leaves, treedef = jax.tree.flatten(p)
    assert all(isinstance(l, jnp.ndarray) for l in leaves)
    p2 = jax.tree.unflatten(treedef, leaves)
    assert float(p2.efficiency_factor) == pytest.approx(0.90)
    assert float(p2.dvfs_adaptive) == 1.0
