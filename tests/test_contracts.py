"""Contract linter + runtime sanitizer (PR 10).

Each rule gets three fixture legs written into a tmp mini-tree that mirrors
the scoped paths: a POSITIVE snippet the rule must flag, a NEGATIVE snippet
(the sanctioned spelling) it must pass, and the `# contract: allow(ID)`
escape hatch suppressing the positive. A meta-test then runs every rule
over the LIVE tree and requires zero findings — the linter is only useful
if the repo it guards is clean under it.

The sanitizer half is tested against real jits: fresh-compile counting,
warm-cache zero, per-entry-point attribution, budget enforcement, and the
engine-level steady-state guarantee (a warm ServeEngine re-running
identical traffic compiles NOTHING).
"""

import pathlib
import subprocess
import sys
import textwrap

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.analysis import sanitizer
from repro.analysis.contracts import RULES, Finding, run_rules

REPO_ROOT = pathlib.Path(__file__).resolve().parents[1]


def _lint(tmp_path, rel, source, rules):
    """Write one file into a tmp mini-tree and run `rules` over it."""
    p = tmp_path / rel
    p.parent.mkdir(parents=True, exist_ok=True)
    p.write_text(textwrap.dedent(source))
    return run_rules(tmp_path, rules=rules, files=[p])


def _hits(findings, rule_id):
    return [f for f in findings if f.rule == rule_id]


# --------------------------------------------------------------------------
# R1 — UCIe cost isolation


def test_r1_flags_link_math_in_serve(tmp_path):
    fs = _lint(tmp_path, "src/repro/serve/rogue.py", """
        def price(nbytes, cfg):
            link_bandwidth_gbps = 16.0
            ticks = nbytes * 8 / cfg.bandwidth_gbps
            return ticks + FLIT_BYTES
        """, rules=["R1"])
    msgs = " ".join(f.message for f in fs)
    assert len(_hits(fs, "R1")) == 3, fs
    assert "bandwidth_gbps" in msgs and "FLIT_BYTES" in msgs


def test_r1_flags_direct_transfer_call(tmp_path):
    fs = _lint(tmp_path, "src/repro/serve/rogue.py", """
        from repro.core import ucie

        def cost(n):
            return ucie.transfer(n)
        """, rules=["R1"])
    assert len(fs) == 1 and "ucie.transfer" in fs[0].message


def test_r1_passes_migration_ticks_and_config_build(tmp_path):
    fs = _lint(tmp_path, "benchmarks/rogue.py", """
        from repro.core.ucie import UCIeConfig, migration_ticks

        def cost(n, link):
            cfg = UCIeConfig(bandwidth_gbps=32.0, latency_us=0.25)
            return migration_ticks(n, link)
        """, rules=["R1"])
    assert fs == []


def test_r1_out_of_scope_files_not_scanned(tmp_path):
    # core/ucie itself obviously names its own fields
    fs = _lint(tmp_path, "src/repro/core/ucie.py", """
        def transfer(n, cfg):
            return n * 8 / cfg.bandwidth_gbps
        """, rules=["R1"])
    assert fs == []


# --------------------------------------------------------------------------
# R2 — attention-core unification


def test_r2_flags_projection_mirror(tmp_path):
    fs = _lint(tmp_path, "src/repro/serve/rogue.py", """
        from repro.models.common import apply_rope

        def my_attn(x, params):
            q, k, v = _project_qkv(params, x)
            return apply_rope(q, 0)
        """, rules=["R2"])
    assert len(fs) == 3, fs  # import + _project_qkv call + apply_rope call


def test_r2_passes_attn_block_wrapper(tmp_path):
    fs = _lint(tmp_path, "src/repro/serve/rogue.py", """
        from repro.models.transformer import attn_block

        def step(params, x, cache):
            return attn_block(params, x, cache, mode="decode")
        """, rules=["R2"])
    assert fs == []


def test_r2_allowlist_covers_core_and_plugins(tmp_path):
    # the core's own module-scope import of the primitives is sanctioned
    fs = _lint(tmp_path, "src/repro/models/transformer.py", """
        from repro.models.common import apply_rope
        """, rules=["R2"])
    assert fs == []
    # ...but a NEW function in a non-allowlisted model file is not
    fs = _lint(tmp_path, "src/repro/models/newfam.py", """
        def attn(x):
            return apply_rope(x, 0)
        """, rules=["R2"])
    assert len(fs) == 1


# --------------------------------------------------------------------------
# R3 — replay determinism


def test_r3_flags_clocks_and_ambient_rng(tmp_path):
    fs = _lint(tmp_path, "src/repro/serve/faults.py", """
        import time
        import numpy as np

        def jitter():
            t = time.time()
            x = np.random.rand()
            rng = np.random.default_rng()
            return t + x
        """, rules=["R3"])
    assert len(fs) >= 4, fs  # import time, time.time, np.random.rand, rng()


def test_r3_passes_seeded_rng(tmp_path):
    fs = _lint(tmp_path, "src/repro/serve/sampling.py", """
        import numpy as np

        def draw(seed):
            rng = np.random.default_rng(seed)
            return rng.integers(0, 10)
        """, rules=["R3"])
    assert fs == []


def test_r3_scope_excludes_engine(tmp_path):
    # engine.py legitimately stamps wall-clock TTFT stats — out of scope
    fs = _lint(tmp_path, "src/repro/serve/engine.py", """
        import time

        def stamp():
            return time.time()
        """, rules=["R3"])
    assert fs == []


# --------------------------------------------------------------------------
# R4 — host authority


def test_r4_flags_jax_in_planner(tmp_path):
    fs = _lint(tmp_path, "src/repro/serve/scheduler.py", """
        import jax
        import jax.numpy as jnp

        def plan(pages):
            return jnp.argmax(pages)
        """, rules=["R4"])
    assert len(fs) == 3, fs  # import jax, import jnp, jnp use


def test_r4_flags_device_get_and_item(tmp_path):
    fs = _lint(tmp_path, "src/repro/serve/rogue.py", """
        import jax

        def peek(x):
            a = jax.device_get(x)
            return x.sum().item()
        """, rules=["R4"])
    assert len(fs) == 2, fs


def test_r4_passes_numpy_planner(tmp_path):
    fs = _lint(tmp_path, "src/repro/serve/scheduler.py", """
        import numpy as np

        def plan(pages):
            return int(np.argmax(pages))
        """, rules=["R4"])
    assert fs == []


# --------------------------------------------------------------------------
# R5 — donation safety


def test_r5_flags_read_after_donation(tmp_path):
    fs = _lint(tmp_path, "src/repro/launch/rogue.py", """
        import jax

        step = jax.jit(_step, donate_argnums=(0,))

        def run(state, batch):
            out = step(state, batch)
            return state.params, out
        """, rules=["R5"])
    assert len(fs) == 1 and "donated" in fs[0].message


def test_r5_passes_rebind(tmp_path):
    fs = _lint(tmp_path, "src/repro/launch/rogue.py", """
        import jax

        step = jax.jit(_step, donate_argnums=(0,))

        def run(state, batch):
            state = step(state, batch)
            return state
        """, rules=["R5"])
    assert fs == []


def test_r5_tracks_self_attributes(tmp_path):
    fs = _lint(tmp_path, "src/repro/serve/rogue.py", """
        import jax

        class Eng:
            def __init__(self):
                self._decode = jax.jit(_d, donate_argnums=(2,))

            def step(self, tok, pos, cache):
                new = self._decode(tok, pos, cache)
                stale = cache["k"]
                return new, stale
        """, rules=["R5"])
    assert len(fs) == 1 and "cache" in fs[0].message


# --------------------------------------------------------------------------
# R6 — pool-key genericity


def test_r6_flags_literal_kv_tuple(tmp_path):
    fs = _lint(tmp_path, "src/repro/serve/rogue.py", """
        def paste(cache, pf):
            for key in ("k", "v"):
                cache[key] = pf[key]
        """, rules=["R6"])
    assert len(fs) == 1 and "pool_data_keys" in fs[0].message


def test_r6_passes_generic_iteration(tmp_path):
    fs = _lint(tmp_path, "src/repro/serve/rogue.py", """
        from repro.models.transformer import pool_data_keys

        def paste(cache, pf):
            for key in pool_data_keys(pf):
                cache[key] = pf[key]
        """, rules=["R6"])
    assert fs == []


# --------------------------------------------------------------------------
# R7 — Pallas hygiene


def test_r7_flags_host_calls_in_kernel(tmp_path):
    fs = _lint(tmp_path, "src/repro/kernels/rogue.py", """
        import numpy as np

        def _bad_kernel(x_ref, o_ref):
            print("tracing")
            o_ref[...] = x_ref[...] * np.float32(2)
        """, rules=["R7"])
    msgs = " ".join(f.message for f in fs)
    assert len(fs) == 2 and "print" in msgs and "np.float32" in msgs


def test_r7_flags_impure_index_map(tmp_path):
    fs = _lint(tmp_path, "src/repro/kernels/rogue.py", """
        import numpy as np
        from jax.experimental import pallas as pl

        spec = pl.BlockSpec((8, 8), lambda i: (np.random.randint(2), 0))
        """, rules=["R7"])
    assert len(fs) == 1 and "index map" in fs[0].message


def test_r7_passes_pure_kernel(tmp_path):
    fs = _lint(tmp_path, "src/repro/kernels/rogue.py", """
        import jax.numpy as jnp

        def _ok_kernel(x_ref, o_ref):
            o_ref[...] = jnp.maximum(x_ref[...], 0.0)
        """, rules=["R7"])
    assert fs == []


# --------------------------------------------------------------------------
# escape hatch + engine plumbing


def test_allow_comment_suppresses_and_is_counted(tmp_path):
    src = """
        def paste(cache, pf):
            for key in ("k", "v"):  # contract: allow(R6)
                cache[key] = pf[key]
        """
    p = tmp_path / "src/repro/serve/rogue.py"
    p.parent.mkdir(parents=True, exist_ok=True)
    p.write_text(textwrap.dedent(src))
    suppressed = []
    fs = run_rules(tmp_path, rules=["R6"], files=[p],
                   collect_suppressed=suppressed)
    assert fs == []
    assert len(suppressed) == 1 and suppressed[0].rule == "R6"


def test_allow_comment_is_rule_specific(tmp_path):
    fs = _lint(tmp_path, "src/repro/serve/rogue.py", """
        def paste(cache, pf):
            for key in ("k", "v"):  # contract: allow(R1)
                cache[key] = pf[key]
        """, rules=["R6"])
    assert len(fs) == 1  # allow(R1) does not silence R6


def test_unknown_rule_id_raises():
    with pytest.raises(ValueError, match="R99"):
        run_rules(REPO_ROOT, rules=["R99"], files=[])


def test_finding_str_and_dict():
    f = Finding(rule="R1", path="src/x.py", line=3, message="m")
    assert "R1 src/x.py:3" in str(f)
    assert f.as_dict() == {"rule": "R1", "path": "src/x.py", "line": 3,
                           "message": "m"}


# --------------------------------------------------------------------------
# the live tree is clean, and the CLI agrees


def test_live_tree_has_zero_findings():
    """Every rule, whole repo. A finding here means a contract regressed —
    the message says which invariant and why it exists."""
    fs = run_rules(REPO_ROOT)
    assert fs == [], "\n".join(str(f) for f in fs)
    assert len(RULES) >= 7


def test_cli_strict_exits_zero():
    proc = subprocess.run(
        [sys.executable, str(REPO_ROOT / "tools" / "check_contracts.py"),
         "--strict", "--json"],
        cwd=REPO_ROOT, capture_output=True, text=True)
    assert proc.returncode == 0, proc.stdout + proc.stderr
    import json
    out = json.loads(proc.stdout)
    assert out["findings"] == []
    assert len(out["rules"]) >= 7


# --------------------------------------------------------------------------
# runtime sanitizer


def test_watch_counts_fresh_compile_then_cached():
    @jax.jit
    def f(x):
        return x * 2 + 1

    x = jnp.arange(8, dtype=jnp.float32)
    with sanitizer.watch() as log:
        f(x).block_until_ready()
    assert log.compiles >= 1 and log.traces >= 1
    with sanitizer.watch() as log2:
        f(x).block_until_ready()
    assert log2.compiles == 0 and log2.traces == 0


def test_watch_counts_explicit_host_syncs():
    x = jnp.arange(4)
    with sanitizer.watch() as log:
        np.asarray(x)
        jax.device_get(x)
        np.asarray(np.zeros(3))     # numpy->numpy: NOT a sync
    assert log.host_syncs == 2


def test_entry_point_attribution():
    @jax.jit
    def g(x):
        return x + 1

    sanitizer.register_entry_point("g_test", g)
    with sanitizer.watch() as log:
        g(jnp.ones(4)).block_until_ready()
        g(jnp.ones((2, 2))).block_until_ready()   # second shape variant
    assert log.entry_compiles["g_test"] == 2
    assert "g_test_compiles" in log.summary()


def test_register_rejects_unjitted():
    with pytest.raises(TypeError):
        sanitizer.register_entry_point("nope", lambda x: x)


def test_compile_budget_enforced():
    @jax.jit
    def h(x):
        return x - 1

    sanitizer.register_entry_point("h_test", h)
    with sanitizer.compile_budget(h_test=2):
        h(jnp.ones(3)).block_until_ready()
    with pytest.raises(sanitizer.CompileBudgetExceeded, match="h_test"):
        with sanitizer.compile_budget(h_test=0):
            h(jnp.ones(7)).block_until_ready()   # fresh shape: 1 > 0


def test_compile_budget_unknown_label():
    with pytest.raises(ValueError, match="not_registered"):
        with sanitizer.compile_budget(not_registered=1):
            pass


def test_compile_budget_total_and_syncs():
    @jax.jit
    def k(x):
        return x * x

    with pytest.raises(sanitizer.CompileBudgetExceeded, match="host_syncs"):
        with sanitizer.compile_budget(host_syncs=0):
            np.asarray(k(jnp.ones(5)))


# --------------------------------------------------------------------------
# engine-level steady state


@pytest.fixture(scope="module")
def smol():
    from repro.configs import get_config
    from repro.models import ExecOptions, build_model
    cfg = get_config("smollm-360m").smoke()
    model = build_model(cfg, ExecOptions(attn_impl="reference", ce_chunk=32))
    params = model.init(jax.random.key(0))
    return cfg, model, params


def _wave(eng, cfg, n=4):
    rng = np.random.default_rng(3)
    for i in range(n):
        eng.submit(np.asarray(rng.integers(0, cfg.vocab_size, 6 + 5 * i),
                              np.int32), max_new_tokens=4)
    return eng.run_to_completion()


def test_engine_meets_declared_compile_budgets(smol):
    """A chunked engine's first full wave stays inside COMPILE_BUDGETS, and
    an identical second wave against the warm engine compiles NOTHING —
    the steady_state_retraces == 0 gate, as a unit test."""
    from repro.serve.engine import ServeEngine
    cfg, model, params = smol
    eng = ServeEngine(model, n_slots=2, max_len=64, params=params,
                      page_size=16)
    with sanitizer.compile_budget(**ServeEngine.COMPILE_BUDGETS):
        _wave(eng, cfg)
    with sanitizer.compile_budget(total=0):
        _wave(eng, cfg)
    assert eng.stats.chunk_compiles == 1
