"""fp8 (e5m2) KV cache rounding, dense layout (PR 7).

fp8 is the third lossy KV storage mode after bf16 and int8 — a bare cast
round trip through `float8_e5m2` with NO scale tensors (e5m2 keeps f32's
exponent range, so per-row scales buy little; e4m3 would need them). The
same token-exactness contract as every other KV dtype applies: prefill
attends the rounded values the cache stores (`transformer._round_kv`), so
the engine must match a `generate_greedy` oracle running the identical
dequant path. Paged fp8 pools are a recorded follow-on — the engine must
refuse them loudly rather than silently densify.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.models import ExecOptions, build_model
from repro.models.transformer import _round_rows, cache_shape
from repro.serve.engine import ServeEngine, generate_greedy


def _prompt(seed, n, vocab=512):
    return np.asarray(
        jax.random.randint(jax.random.key(seed), (n,), 0, vocab), np.int32)


@pytest.fixture(scope="module")
def smol():
    cfg = get_config("smollm-360m").smoke()
    model = build_model(cfg, ExecOptions(attn_impl="reference", ce_chunk=32))
    return cfg, model, model.init(jax.random.key(1))


def test_round_rows_e5m2_is_cast_roundtrip():
    """`_round_rows` with an fp8 storage dtype is exactly the dequant
    oracle: cast to e5m2 and back, no scales involved."""
    rows = jax.random.normal(jax.random.key(0), (2, 5, 2, 8),
                             jnp.float32) * 7.0
    got = _round_rows(rows, jnp.float8_e5m2)
    want = rows.astype(jnp.float8_e5m2).astype(jnp.float32)
    assert got.dtype == jnp.float32
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))
    assert not np.array_equal(np.asarray(got), np.asarray(rows)), \
        "e5m2 round trip should actually lose mantissa bits"


def test_cache_shape_fp8_has_no_scale_tensors(smol):
    """The dense fp8 cache layout is the bf16 layout at 1 byte/element —
    same keys (no 'ks'/'vs' scale pools), same shapes."""
    cfg, _, _ = smol
    fp8 = cache_shape(cfg, 2, 32, dtype=jnp.float8_e5m2)
    bf16 = cache_shape(cfg, 2, 32, dtype=jnp.bfloat16)
    assert set(fp8) == set(bf16)
    assert not any(k.endswith("s") and k != "pos" for k in fp8), fp8.keys()
    assert fp8["k"].shape == bf16["k"].shape
    assert fp8["k"].dtype == jnp.float8_e5m2


@pytest.mark.parametrize("kv_dtype", ["fp8", "e5m2"])
def test_fp8_dense_engine_token_exact(smol, kv_dtype):
    """Dense fp8 engine == the fp8 `generate_greedy` oracle, token for
    token ('fp8' and 'e5m2' are aliases for the same storage dtype)."""
    cfg, model, params = smol
    for n in (9, 17):
        solo = generate_greedy(model, params, _prompt(n, n), n_tokens=4,
                               max_len=64, kv_dtype=kv_dtype)
        eng = ServeEngine(model, n_slots=2, max_len=64, params=params,
                          paged=False, kv_dtype=kv_dtype)
        r = eng.submit(_prompt(n, n), max_new_tokens=4)
        eng.run_to_completion()
        assert r.out_tokens == solo, (kv_dtype, n, r.out_tokens, solo)


def test_fp8_actually_rounds(smol):
    """The fp8 stream must DIVERGE from the f32 stream on a long enough
    horizon — otherwise the cast round trip silently became a no-op."""
    cfg, model, params = smol
    p = _prompt(5, 13)
    f32 = generate_greedy(model, params, p, n_tokens=8, max_len=64)
    fp8 = generate_greedy(model, params, p, n_tokens=8, max_len=64,
                          kv_dtype="fp8")
    assert fp8 != f32, "e5m2 KV produced the f32 token stream bit-for-bit"


def test_fp8_paged_pool_refused(smol):
    """Paged fp8 pools are a follow-on: the engine raises instead of
    silently falling back to a dense or bf16 layout."""
    cfg, model, params = smol
    with pytest.raises(ValueError, match="fp8|e5m2"):
        ServeEngine(model, n_slots=2, max_len=64, params=params,
                    page_size=8, kv_dtype="fp8")
