def pytest_configure(config):
    config.addinivalue_line(
        "markers",
        "slow: long interpret-mode kernel sweeps and wide engine matrices — "
        "excluded from the tier-1 run (pytest -m 'not slow'); the CI "
        "int8-interpret job runs the full suite including them")
