"""Dry-run machinery: HLO collective parsing, probe extrapolation math,
cell lowering on a small fake-device mesh (subprocess)."""

import os
import subprocess
import sys

import pytest

from repro.launch.hlo_analysis import collective_bytes, _shape_bytes


def test_shape_bytes():
    assert _shape_bytes("bf16[128,1024]") == 128 * 1024 * 2
    assert _shape_bytes("f32[16]{0}") == 64
    assert _shape_bytes("(s8[256,128], f32[256])") == 256 * 128 + 1024
    assert _shape_bytes("pred[]") == 1


def test_collective_parsing():
    hlo = """
  %ag = bf16[64,512]{1,0} all-gather(%x), dimensions={0}
  %ar.1 = f32[1024]{0} all-reduce(%y), to_apply=%add
  %rs = (f32[32,32]{1,0}) reduce-scatter(%z), dimensions={0}
  %cp = s8[2048]{0} collective-permute-start(%w), source_target_pairs={{0,1}}
  %nn = f32[9999]{0} add(%a, %b)
"""
    cb = collective_bytes(hlo)
    assert cb["all-gather"] == 64 * 512 * 2
    assert cb["all-reduce"] == 4096
    assert cb["reduce-scatter"] == 32 * 32 * 4
    assert cb["collective-permute"] == 2048
    assert cb["total"] == sum(
        cb[k] for k in ("all-gather", "all-reduce", "reduce-scatter",
                        "all-to-all", "collective-permute"))


def test_probe_extrapolation_math():
    """total(L) = a + (L-La)·(b-a)/(Lb-La) must recover a linear layer cost."""
    outside, per_layer = 7.0, 3.0
    la, lb, L = 1, 2, 64
    pa = outside + la * per_layer
    pb = outside + lb * per_layer
    total = pa + (pb - pa) / (lb - la) * (L - la)
    assert total == outside + L * per_layer


@pytest.mark.slow
def test_cell_machinery_small_mesh():
    """run_cell-style lowering works end-to-end on 8 fake devices."""
    script = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import dataclasses, jax
from repro.configs import get_config
from repro.configs.base import ShapeConfig
from repro.launch.mesh import make_mesh
from repro.launch import steps
from repro.launch.hlo_analysis import analyze_compiled

mesh = make_mesh((4, 2), ("data", "model"))
cfg = dataclasses.replace(get_config("gemma-7b").smoke(), dtype="bfloat16")
for kind, overrides in [("train", {"unroll_scans": True}),
                        ("decode", None)]:
    shape = ShapeConfig("t", kind, 64, 8)
    jitted, abs_args = steps.build_cell(cfg, shape, mesh, overrides)
    a = analyze_compiled(jitted.lower(*abs_args).compile())
    assert a.flops_per_dev > 0
    assert a.peak_bytes > 0
print("MACHINERY_OK")
"""
    env = {**os.environ, "PYTHONPATH": "src"}
    r = subprocess.run([sys.executable, "-c", script], env=env,
                       capture_output=True, text=True, cwd="/root/repo")
    assert r.returncode == 0, r.stderr[-3000:]
    assert "MACHINERY_OK" in r.stdout
