"""Chunked RG-LRU recurrence == full associative scan (the 109 GiB fix)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.models.rglru import rg_lru_scan, rg_lru_scan_chunked


@pytest.mark.parametrize("chunk", [8, 16, 64])
def test_chunked_matches_full(chunk):
    b, s, w = 2, 64, 8
    a = jax.nn.sigmoid(jax.random.normal(jax.random.key(0), (b, s, w)))
    gx = jax.random.normal(jax.random.key(1), (b, s, w))
    full = rg_lru_scan(a, gx)
    got = rg_lru_scan_chunked(a, gx, chunk=chunk)
    np.testing.assert_allclose(np.asarray(got), np.asarray(full),
                               rtol=1e-5, atol=1e-5)


def test_chunked_with_initial_state():
    b, s, w = 1, 32, 4
    a = jax.nn.sigmoid(jax.random.normal(jax.random.key(2), (b, s, w)))
    gx = jax.random.normal(jax.random.key(3), (b, s, w))
    h0 = jnp.ones((b, w)) * 0.3
    full = rg_lru_scan(a, gx, h0)
    got = rg_lru_scan_chunked(a, gx, h0, chunk=8)
    np.testing.assert_allclose(np.asarray(got), np.asarray(full),
                               rtol=1e-5, atol=1e-5)


def test_chunked_unroll_identical():
    b, s, w = 1, 32, 4
    a = jax.nn.sigmoid(jax.random.normal(jax.random.key(4), (b, s, w)))
    gx = jax.random.normal(jax.random.key(5), (b, s, w))
    x = rg_lru_scan_chunked(a, gx, chunk=8, unroll=False)
    y = rg_lru_scan_chunked(a, gx, chunk=8, unroll=True)
    np.testing.assert_allclose(np.asarray(x), np.asarray(y), rtol=1e-6,
                               atol=1e-6)


def test_gradients_match():
    b, s, w = 1, 32, 4
    a = jax.nn.sigmoid(jax.random.normal(jax.random.key(6), (b, s, w)))
    gx = jax.random.normal(jax.random.key(7), (b, s, w))

    g_full = jax.grad(lambda g: jnp.sum(rg_lru_scan(a, g) ** 2))(gx)
    g_chunk = jax.grad(
        lambda g: jnp.sum(rg_lru_scan_chunked(a, g, chunk=8) ** 2))(gx)
    np.testing.assert_allclose(np.asarray(g_chunk), np.asarray(g_full),
                               rtol=1e-4, atol=1e-4)
