"""Elastic runtime policies: failure detection, straggler mitigation (I4)."""

import pytest

from repro.train.elastic import (
    ElasticPolicy, HeartbeatRegistry, detect_stragglers,
    elastic_mesh_shape, plan_migration, rebalanced_batch_split,
)


def _registry(n=4, timeout=10.0):
    return HeartbeatRegistry(n, ElasticPolicy(heartbeat_timeout_s=timeout,
                                              straggler_patience=4))


def test_dead_host_detected():
    reg = _registry()
    t0 = 1000.0
    for h in range(4):
        reg.beat(h, 1.0, now=t0)
    # host 2 goes silent
    for h in (0, 1, 3):
        reg.beat(h, 1.0, now=t0 + 30)
    dec = plan_migration(reg, now=t0 + 30)
    assert dec.kind == "reshard"
    assert dec.drop_hosts == (2,)


def test_healthy_fleet_no_action():
    reg = _registry()
    t = 0.0
    for step in range(6):
        t += 1.0
        for h in range(4):
            reg.beat(h, 1.0, now=t)
    assert plan_migration(reg, now=t).kind == "none"


def test_straggler_detected_and_rebalanced():
    reg = _registry()
    t = 0.0
    for step in range(8):
        t += 1.0
        for h in range(4):
            reg.beat(h, 5.0 if h == 3 else 1.0, now=t)
    slow = detect_stragglers(reg)
    assert slow == [3]
    dec = plan_migration(reg, now=t)
    assert dec.kind == "rebalance" and dec.drop_hosts == (3,)


def test_transient_slowness_tolerated():
    """One slow step must not trigger migration (patience)."""
    reg = _registry()
    t = 0.0
    for step in range(8):
        t += 1.0
        for h in range(4):
            slow = (h == 3 and step == 5)
            reg.beat(h, 9.0 if slow else 1.0, now=t)
    assert detect_stragglers(reg) == []


def test_min_hosts_guard():
    reg = HeartbeatRegistry(2, ElasticPolicy(heartbeat_timeout_s=1.0,
                                             min_hosts=2))
    reg.beat(0, now=100.0)
    reg.beat(1, now=0.0)  # dead
    dec = plan_migration(reg, now=100.0)
    assert dec.kind == "none" and "min_hosts" in dec.reason


def test_elastic_mesh_shape():
    assert elastic_mesh_shape(512, 16) == (32, 16)
    assert elastic_mesh_shape(480, 16) == (30, 16)  # lost 2 hosts of 4 chips
    with pytest.raises(AssertionError):
        elastic_mesh_shape(8, 16)


def test_rebalanced_batch_split_sums_and_orders():
    split = rebalanced_batch_split(256, {0: 1.0, 1: 1.0, 2: 0.5})
    assert sum(split.values()) == 256
    assert split[2] < min(split[0], split[1])          # straggler gets less
    assert abs(split[0] - split[1]) <= 1               # equals split evenly
