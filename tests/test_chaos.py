"""Fault-tolerant serving (PR 6): deterministic chaos, health, backpressure.

The invariants pinned here:
  * a seeded `chaos_plan` is pure data — same seed → the SAME plan,
    bit-for-bit, and the engine replays it to the same event schedule;
  * chaos parity — shard death/drain/rejoin, page squeezes and preemption
    recover every displaced request by token-exact re-prefill replay, so
    the surviving engine emits IDENTICAL token streams to a fault-free
    twin on the same submissions (schedule-independence, PR 4);
  * exact pool accounting through every fault path: per shard,
    free + mapped + stolen == n_pages - 1, zero page leak;
  * backpressure is graceful: malformed submits raise ValueError with
    nothing enqueued, a full queue raises EngineOverloaded, TTL retires
    stale requests through the normal release path, and page-pool
    exhaustion at admission queues FIFO instead of crashing;
  * the sensor-driven health machine (core/thermal + core/dvfs) walks
    HEALTHY → DEGRADED → DRAINING → REJOINING → HEALTHY deterministically.

Multi-device chaos runs fork a subprocess with
XLA_FLAGS=--xla_force_host_platform_device_count=8 (the repo-wide idiom —
device count is fixed at jax import) and shard over a 4-device prefix.
Everything else runs in-process on the single-host engine or a 1-shard mesh.
"""

import math
import os
import subprocess
import sys

import jax
import numpy as np
import pytest

from repro.configs import get_config
from repro.models import ExecOptions, build_model
from repro.launch.mesh import make_serve_mesh
from repro.serve.engine import EngineOverloaded, EngineStats, ServeEngine
from repro.serve.faults import FaultEvent, FaultPlan, chaos_plan
from repro.serve.health import Health, HealthConfig, ShardHealthMonitor
from repro.serve.sharded import ShardedServeEngine

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


@pytest.fixture(scope="module")
def smol():
    cfg = get_config("smollm-360m").smoke()
    model = build_model(cfg, ExecOptions(attn_impl="reference", ce_chunk=32))
    params = model.init(jax.random.key(0))
    return cfg, model, params


def _prompt(seed, n=12, vocab=512):
    return np.asarray(
        jax.random.randint(jax.random.key(seed), (n,), 0, vocab), np.int32)


# ------------------------------------------------------------------ FaultPlan
def test_chaos_plan_replays_bit_for_bit():
    kw = dict(n_shards=4, n_ticks=48, deaths=2, squeezes=4, sensor_storms=2)
    a, b = chaos_plan(7, **kw), chaos_plan(7, **kw)
    assert a == b and a.events == b.events
    assert a != chaos_plan(8, **kw)
    # sorted by tick, indexable per tick, counted per kind
    ticks = [e.tick for e in a.events]
    assert ticks == sorted(ticks)
    assert sum(len(a.events_at(t)) for t in set(ticks)) == len(a.events)
    c = a.counts()
    # every death is paired with a rejoin; every squeeze with a restore
    assert c["shard_death"] == c["shard_rejoin"] >= 1
    assert c["page_squeeze"] == c["page_restore"] >= 1
    assert c["sensor_hot"] == 2
    assert a.max_tick <= 48 + max(8, 6)  # dwell can run past n_ticks


def test_fault_plan_validation():
    with pytest.raises(ValueError):
        FaultEvent(tick=1, kind="meteor_strike")
    with pytest.raises(ValueError):
        chaos_plan(0, n_shards=1, n_ticks=16, deaths=1)  # nowhere to recover
    # events arrive unsorted, plan stores them sorted
    p = FaultPlan(events=(FaultEvent(tick=9, kind="page_restore"),
                          FaultEvent(tick=2, kind="page_squeeze", pages=4)))
    assert [e.tick for e in p.events] == [2, 9]


# ------------------------------------------------------------- health machine
def test_health_machine_sensor_walks_drain_then_rejoin():
    """A hot sensor bias walks shard 0 HEALTHY → DEGRADED → DRAINING; once
    the bias expires it cools back through REJOINING to HEALTHY. Shard 1
    never leaves HEALTHY. Deterministic: the same trace twice."""
    def trace():
        mon = ShardHealthMonitor(2, HealthConfig())
        mon.inject_sensor(0, delta_c=60.0, ticks=6)
        out = []
        for _ in range(14):
            for s, old, new in mon.step(np.array([1.0, 0.2])):
                out.append((mon._tick, s, old.value, new.value))
        return out, mon.state

    out, state = trace()
    assert state == [Health.HEALTHY, Health.HEALTHY]
    assert all(s == 0 for _, s, _, _ in out)  # shard 1 untouched
    path = [(old, new) for _, _, old, new in out]
    assert path == [("healthy", "degraded"), ("degraded", "draining"),
                    ("draining", "rejoining"), ("rejoining", "healthy")]
    assert trace()[0] == out  # bit-for-bit replay


def test_health_machine_force_dead_and_rejoin():
    mon = ShardHealthMonitor(3, HealthConfig(rejoin_ticks=2))
    assert mon.force_dead(1) and not mon.force_dead(1)  # idempotent
    assert mon.placeable() == [True, False, True]
    assert not mon.begin_rejoin(0)          # only DEAD shards rejoin
    assert mon.begin_rejoin(1)
    occ = np.zeros(3)
    for _ in range(3):
        mon.step(occ)
    assert mon.state[1] == Health.HEALTHY
    assert mon.n_placeable() == 3


# ------------------------------------------------------- validation + summary
def test_submit_validation_rejects_cleanly(smol):
    _, model, params = smol
    eng = ServeEngine(model, n_slots=2, max_len=32, params=params,
                      page_size=8)
    with pytest.raises(ValueError, match="1-D"):
        eng.submit(np.zeros((2, 4), np.int32))
    with pytest.raises(ValueError, match="at least one token"):
        eng.submit(np.zeros((0,), np.int32))
    with pytest.raises(ValueError, match="exceeds engine max_len"):
        eng.submit(_prompt(0, n=33))
    with pytest.raises(ValueError, match="max_new_tokens"):
        eng.submit(_prompt(0), max_new_tokens=0)
    assert not eng._queue and eng.stats.pages_in_use == 0  # nothing enqueued
    # NaN sampling params clamp to safe ends instead of poisoning the jit
    r = eng.submit(_prompt(0), sample_params=(float("nan"), 5, float("nan")))
    assert r.temperature == 0.0 and r.top_p == 1.0


def test_queue_cap_overload(smol):
    _, model, params = smol
    eng = ServeEngine(model, n_slots=1, max_len=64, params=params,
                      page_size=8, max_queue=2)
    for i in range(2):
        eng.submit(_prompt(i), max_new_tokens=2)
    with pytest.raises(EngineOverloaded):
        eng.submit(_prompt(9), max_new_tokens=2)
    assert eng.stats.rejected == 1
    eng.run_to_completion()  # the accepted two still complete


def test_zero_run_summary_is_finite():
    """A run that never decoded (only rejected/timed out) must summarize to
    well-defined zeros, not ZeroDivisionError/NaN."""
    s = EngineStats().summary()
    assert s["mean_occupancy"] == 0.0
    assert s["pad_waste_ratio"] == 0.0
    assert s["mean_recovery_ticks"] == 0.0
    assert all(math.isfinite(v) for v in s.values()
               if isinstance(v, (int, float)))


# ------------------------------------------------------------- TTL + faults
def test_ttl_retires_stale_requests(smol):
    _, model, params = smol
    eng = ServeEngine(model, n_slots=1, max_len=64, params=params,
                      page_size=8)
    keep = eng.submit(_prompt(0), max_new_tokens=6)
    stale = [eng.submit(_prompt(1 + i), max_new_tokens=6, ttl_ticks=2)
             for i in range(2)]
    eng.run_to_completion()
    assert keep.done and not keep.timed_out and len(keep.out_tokens) == 6
    assert all(r.done and r.timed_out and not r.out_tokens for r in stale)
    assert eng.stats.timeouts == 2
    assert eng.stats.pages_in_use == 0
    assert eng.pages_allocatable() == eng.n_pages - 1  # zero page leak


def test_single_host_squeeze_parity_and_zero_leak(smol):
    """A page squeeze starves admission mid-run; after the restore, every
    request completes with tokens IDENTICAL to a fault-free twin, and the
    pool balances to the page."""
    _, model, params = smol
    lens, new = [9, 17, 6, 23, 13, 11], [6, 4, 8, 3, 5, 6]

    def leg(plan):
        eng = ServeEngine(model, n_slots=2, max_len=64, params=params,
                          page_size=8, n_pages=9, fault_plan=plan)
        reqs = [eng.submit(_prompt(i, n), max_new_tokens=m, seed=100 + i)
                for i, (n, m) in enumerate(zip(lens, new))]
        eng.run_to_completion()
        return eng, reqs

    plan = FaultPlan(events=(
        FaultEvent(tick=3, kind="page_squeeze", pages=6),
        FaultEvent(tick=12, kind="page_restore")))
    base_eng, base = leg(None)
    eng, chaos = leg(plan)
    assert eng.stats.faults_injected == 2
    for a, b in zip(base, chaos):
        assert a.done and b.done and not b.timed_out
        assert a.out_tokens == b.out_tokens
    assert eng.pages_allocatable() == eng.n_pages - 1
    assert not eng._stolen_pages
    assert eng.stats.pages_in_use == 0


# -------------------------------------------- pool exhaustion at admission
def _fifo_exhaustion(eng, n_req=4):
    """Submit more work than the pool can hold at once: admission must
    queue (not crash) and drain strictly FIFO."""
    reqs = [eng.submit(_prompt(i), max_new_tokens=4, seed=100 + i)
            for i in range(n_req)]
    finished = []
    for _ in range(400):
        live = eng.step()
        for r in reqs:
            if r.done and r.rid not in finished:
                finished.append(r.rid)
        if not live:
            break
    assert all(r.done and not r.timed_out for r in reqs)
    assert finished == sorted(finished)  # FIFO drain
    assert all(len(r.out_tokens) == 4 for r in reqs)
    return reqs


def test_pool_exhaustion_queues_fifo_single_host(smol):
    _, model, params = smol
    # each request reserves 2 pages; 3 usable pages -> one live at a time
    eng = ServeEngine(model, n_slots=4, max_len=64, params=params,
                      page_size=8, n_pages=4)
    _fifo_exhaustion(eng)
    assert eng.pages_allocatable() == eng.n_pages - 1
    assert eng.stats.pages_in_use == 0


def test_pool_exhaustion_queues_fifo_sharded(smol):
    _, model, params = smol
    eng = ShardedServeEngine(model, mesh=make_serve_mesh(1), n_slots=4,
                             max_len=64, params=params, page_size=8,
                             n_pages=4)
    _fifo_exhaustion(eng)
    eng.assert_pool_accounting()
    eng.assert_local_page_tables()


# ------------------------------------------------------- multi-device chaos
_PRELUDE = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import jax, numpy as np
from repro.configs import get_config
from repro.models import ExecOptions, build_model
from repro.launch.mesh import make_serve_mesh
from repro.serve.faults import FaultEvent, FaultPlan, chaos_plan
from repro.serve.sharded import ShardedServeEngine

# a 4-shard prefix of the 8 fake devices: the bench-tuned chaos geometry
mesh = make_serve_mesh(4)

cfg = get_config("smollm-360m").smoke()
model = build_model(cfg, ExecOptions(attn_impl="reference", ce_chunk=32))
params = model.init(jax.random.key(1))

def prompt(seed, n, vocab=512):
    return np.asarray(jax.random.randint(
        jax.random.key(seed), (n,), 0, vocab), np.int32)

def chaos_parity(plan, *, n_req=16, max_new=16, n_pages=13, kw=None,
                 health_cfg=None):
    # fault-free twin vs chaos engine, identical submissions; returns the
    # chaos engine's (stats, engine)
    kw = kw or {}
    lens = [5 + (i * 7) % 23 for i in range(n_req)]
    runs = []
    for p in (None, plan):
        eng = ShardedServeEngine(model, mesh=mesh, n_slots=8, max_len=64,
                                 params=params, page_size=8, n_pages=n_pages,
                                 fault_plan=p, health_cfg=health_cfg, **kw)
        reqs = [eng.submit(prompt(i, n), max_new_tokens=max_new,
                           seed=100 + i) for i, n in enumerate(lens)]
        eng.run_to_completion()
        eng.assert_pool_accounting()
        eng.assert_local_page_tables()
        runs.append((eng, reqs))
    (base, br), (eng, cr) = runs
    for a, b in zip(br, cr):
        assert a.done and b.done and not b.timed_out
        assert a.out_tokens == b.out_tokens, (a.rid, a.out_tokens,
                                              b.out_tokens)
    return eng
"""


def _run(script: str):
    env = {**os.environ, "PYTHONPATH": "src"}
    r = subprocess.run([sys.executable, "-c", _PRELUDE + script], env=env,
                       capture_output=True, text=True, cwd=REPO)
    assert r.returncode == 0, (r.stdout[-2000:], r.stderr[-4000:])
    return r.stdout


def test_chaos_parity_seed_matrix_8dev():
    """The bench-tuned chaos geometry over a fixed seed matrix: shard
    deaths, rejoins and page squeezes on a tight pool must yield ZERO token
    divergence, with deaths actually displacing work (recoveries) and the
    free-list starvation actually preempting decoding slots."""
    out = _run(r"""
tot_preempt = tot_recov = 0
for seed in (2, 3):
    plan = chaos_plan(seed, n_shards=4, n_ticks=56, deaths=2,
                      death_dwell=16, squeezes=8, squeeze_pages=10,
                      squeeze_dwell=14)
    c = plan.counts()
    assert c["shard_death"] >= 1 and c["shard_rejoin"] >= 1, c
    eng = chaos_parity(plan)
    st = eng.stats
    assert st.faults_injected >= 4, st.faults_injected
    assert st.recoveries >= 1, st.recoveries
    assert st.recovery_ticks_sum >= st.recoveries
    tot_preempt += st.preemptions
    tot_recov += st.recoveries
    # replaying the SAME plan reproduces the same scheduler arithmetic
    twin = chaos_parity(plan)
    assert (twin.stats.preemptions, twin.stats.recoveries,
            twin.stats.recovery_ticks_sum) == \
           (st.preemptions, st.recoveries, st.recovery_ticks_sum)
assert tot_preempt >= 3, tot_preempt
assert tot_recov >= 2, tot_recov
print("CHAOS_PARITY_OK", tot_preempt, tot_recov)
""")
    assert "CHAOS_PARITY_OK" in out


def test_chaos_parity_moe_int8_8dev():
    """Same chaos geometry on the moe × int8-KV datapath: recovery
    re-prefill must be token-exact through the quantized pool too."""
    out = _run(r"""
cfg = get_config("qwen2-moe-a2.7b").smoke()
model = build_model(cfg, ExecOptions(attn_impl="reference", ce_chunk=32))
params = model.init(jax.random.key(1))
plan = chaos_plan(2, n_shards=4, n_ticks=40, deaths=1, death_dwell=12,
                  squeezes=4, squeeze_pages=10, squeeze_dwell=10)
eng = chaos_parity(plan, n_req=8, max_new=8,
                   kw={"wdtype": "int8", "kv_dtype": "int8"})
assert eng.stats.faults_injected >= 2
print("MOE_INT8_CHAOS_OK")
""")
    assert "MOE_INT8_CHAOS_OK" in out


def test_sensor_drain_parity_8dev():
    """A hot-sensor fault (no hard death) walks a shard through the health
    machine's DRAINING state: its live slots migrate off via re-prefill and
    the shard rejoins — token streams still exactly match the fault-free
    twin and every shard ends placeable."""
    out = _run(r"""
from repro.serve.health import Health
plan = FaultPlan(events=(
    FaultEvent(tick=4, kind="sensor_hot", shard=1, delta_c=60.0, ticks=8),))
eng = chaos_parity(plan, n_req=12, max_new=12, n_pages=16)
st = eng.stats
assert st.faults_injected == 1
assert st.recoveries >= 1, st.recoveries          # drain displaced work
assert all(s == Health.HEALTHY for s in eng._monitor.state), \
    eng.health_summary()
print("SENSOR_DRAIN_OK", st.recoveries)
""")
    assert "SENSOR_DRAIN_OK" in out
