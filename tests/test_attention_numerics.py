"""Chunked (flash-style) attention vs reference; rope properties; GQA; cache
write paths. These are the oracles behind the big-shape execution paths."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st

from repro.models.attention import (
    chunked_attention, decode_attention, reference_attention,
)
from repro.models.common import apply_rope

settings.register_profile("ci", max_examples=20, deadline=None)
settings.load_profile("ci")


def _qkv(key, b, s, kv, g, d, sk=None):
    sk = sk or s
    kq, kk, kv_ = jax.random.split(key, 3)
    q = jax.random.normal(kq, (b, s, kv, g, d), jnp.float32)
    k = jax.random.normal(kk, (b, sk, kv, d), jnp.float32)
    v = jax.random.normal(kv_, (b, sk, kv, d), jnp.float32)
    return q, k, v


@pytest.mark.parametrize("s,qc,kc", [(256, 64, 64), (256, 128, 32),
                                     (512, 256, 128), (384, 128, 128)])
@pytest.mark.parametrize("causal", [True, False])
def test_chunked_matches_reference(s, qc, kc, causal):
    q, k, v = _qkv(jax.random.key(s + qc), 2, s, 2, 2, 32)
    got = chunked_attention(q, k, v, causal=causal, q_chunk=qc, kv_chunk=kc)
    want = reference_attention(q, k, v, causal=causal)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-4, atol=2e-4)


@pytest.mark.parametrize("window", [32, 100, 256])
def test_chunked_window_matches_reference(window):
    q, k, v = _qkv(jax.random.key(window), 1, 512, 1, 4, 32)
    got = chunked_attention(q, k, v, causal=True, window=window,
                            q_chunk=128, kv_chunk=64)
    want = reference_attention(q, k, v, causal=True, window=window)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-4, atol=2e-4)


def test_chunked_unrolled_identical():
    """The dry-run probe path (unroll=True) must be numerically identical."""
    q, k, v = _qkv(jax.random.key(0), 1, 256, 2, 1, 32)
    a = chunked_attention(q, k, v, q_chunk=64, kv_chunk=64, unroll=False)
    b = chunked_attention(q, k, v, q_chunk=64, kv_chunk=64, unroll=True)
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=1e-6,
                               atol=1e-6)


def test_decode_matches_reference_last_row():
    """decode_attention over a cache == last row of full reference attention."""
    b, s, kv, g, d = 2, 64, 2, 3, 16
    q, k, v = _qkv(jax.random.key(1), b, s, kv, g, d)
    full = reference_attention(q, k, v, causal=True)
    got = decode_attention(q[:, -1:], k, v,
                           jnp.full((b,), s, jnp.int32))
    np.testing.assert_allclose(np.asarray(got[:, 0]), np.asarray(full[:, -1]),
                               rtol=1e-5, atol=1e-5)


def test_decode_respects_cur_len():
    """Entries past cur_len must not影响 the result."""
    b, s, kv, g, d = 1, 32, 1, 1, 16
    q, k, v = _qkv(jax.random.key(2), b, s, kv, g, d)
    short = decode_attention(q[:, :1], k, v, jnp.asarray([20]))
    k_junk = k.at[:, 20:].set(999.0)
    v_junk = v.at[:, 20:].set(-999.0)
    with_junk = decode_attention(q[:, :1], k_junk, v_junk, jnp.asarray([20]))
    np.testing.assert_allclose(np.asarray(short), np.asarray(with_junk),
                               rtol=1e-6, atol=1e-6)


# --- RoPE properties ----------------------------------------------------------

@given(st.integers(2, 6), st.integers(0, 100))
def test_rope_relative_position_invariance(shift_halved, offset):
    """⟨rope(q,i), rope(k,j)⟩ depends only on i−j (the defining property)."""
    d = 32
    q = jax.random.normal(jax.random.key(3), (1, 1, 1, d))
    k = jax.random.normal(jax.random.key(4), (1, 1, 1, d))
    i, j = offset + 7, offset + 3

    def dot(i, j):
        qi = apply_rope(q, jnp.asarray([[i]]))
        kj = apply_rope(k, jnp.asarray([[j]]))
        return float(jnp.sum(qi * kj))

    assert dot(i, j) == pytest.approx(dot(i + 11, j + 11), rel=1e-4, abs=1e-4)


def test_rope_preserves_norm():
    x = jax.random.normal(jax.random.key(5), (2, 8, 4, 64))
    y = apply_rope(x, jnp.arange(8)[None, :])
    np.testing.assert_allclose(np.asarray(jnp.linalg.norm(y, axis=-1)),
                               np.asarray(jnp.linalg.norm(x, axis=-1)),
                               rtol=1e-5)


def test_partial_rope_passthrough():
    """ChatGLM 2D rope: the un-rotated half must pass through unchanged."""
    d = 64
    x = jax.random.normal(jax.random.key(6), (1, 4, 2, d))
    y = apply_rope(x, jnp.arange(4)[None, :], fraction=0.5)
    np.testing.assert_array_equal(np.asarray(y[..., d // 2:]),
                                  np.asarray(x[..., d // 2:]))
    assert not np.allclose(np.asarray(y[..., :d // 2]),
                           np.asarray(x[..., :d // 2]))
