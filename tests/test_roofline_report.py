"""Roofline report logic: analytic MODEL_FLOPS, term math, plan suggestion."""

import pytest

from repro.configs import get_config
from repro.configs.base import SHAPES
from repro.core.planner import RooflineTerms
from repro.launch.roofline import cell_terms, model_flops
from repro.launch.steps import suggest_plan


class FakeMesh:
    size = 256
    shape = {"data": 16, "model": 16}


def test_model_flops_scaling():
    """6·N·D train vs 2·N·D prefill vs 2·N_active·B decode."""
    t = model_flops("gemma-7b", "train_4k")
    p = model_flops("gemma-7b", "prefill_32k")
    d = model_flops("gemma-7b", "decode_32k")
    tokens_t = 256 * 4096
    tokens_p = 32 * 32768
    assert t / p == pytest.approx(3.0 * tokens_t / tokens_p, rel=1e-6)
    assert d / p == pytest.approx(128 / tokens_p, rel=1e-6)


def test_moe_uses_active_params():
    cfg = get_config("dbrx-132b")
    assert cfg.active_param_count() < 0.4 * cfg.param_count_analytic()
    t = model_flops("dbrx-132b", "train_4k")
    assert t == pytest.approx(6.0 * cfg.active_param_count() * 256 * 4096,
                              rel=1e-6)


def test_param_counts_sane():
    """Analytic N within ~25 % of the architecture's nameplate."""
    expect = {"gemma-7b": 8.5e9, "qwen2.5-32b": 32.5e9, "smollm-360m": 3.6e8,
              "chatglm3-6b": 6.2e9, "llava-next-mistral-7b": 7.2e9,
              "mamba2-780m": 7.8e8, "dbrx-132b": 132e9,
              "recurrentgemma-2b": 2.7e9}
    for arch, n in expect.items():
        got = get_config(arch).param_count_analytic()
        assert 0.7 * n < got < 1.35 * n, (arch, got, n)


def test_roofline_terms_math():
    t = RooflineTerms(flops=197e12 * 256, hbm_bytes=819e9 * 256,
                      collective_bytes=50e9 * 256 * 2, chips=256,
                      model_flops=197e12 * 128)
    assert t.compute_s == pytest.approx(1.0)
    assert t.memory_s == pytest.approx(1.0)
    assert t.collective_s == pytest.approx(2.0)
    assert t.dominant == "collective"
    assert t.useful_flops_ratio == pytest.approx(0.5)
    assert t.roofline_fraction == pytest.approx(0.25)  # 0.5s ideal / 2s bound


def test_cell_terms_from_record():
    rec = {"status": "ok", "arch": "gemma-7b", "shape": "train_4k",
           "single_pod": {"chips": 256, "memory": {}},
           "totals_per_dev": {"flops": 1e12, "bytes": 1e10,
                              "coll_bytes": 1e9, "coll_kinds": {}}}
    t = cell_terms(rec)
    assert t.flops == 1e12 * 256
    assert t.compute_s == pytest.approx(1e12 / 197e12)


def test_suggest_plan_matches_hillclimb_findings():
    mesh = FakeMesh()
    assert suggest_plan(get_config("smollm-360m"), SHAPES["train_4k"], mesh) \
        == "dp_heavy"
    assert suggest_plan(get_config("dbrx-132b"), SHAPES["train_4k"], mesh) \
        == "tp16"
    assert suggest_plan(get_config("dbrx-132b"), SHAPES["decode_32k"], mesh) \
        == "serve_ws"
    # replicated-expert MoE must NOT get weight-stationary decode (measured
    # ×10.8 flops regression on qwen2-moe — EXPERIMENTS.md §Perf #3 control)
    assert suggest_plan(get_config("qwen2-moe-a2.7b"), SHAPES["decode_32k"],
                        mesh) == "tp16"
    assert suggest_plan(get_config("gemma-7b"), SHAPES["prefill_32k"], mesh) \
        == "tp16"
