"""GPipe pipeline (shard_map + ppermute) vs sequential reference."""

import os
import subprocess
import sys

import pytest

from repro.parallel.pipeline import bubble_fraction


def test_bubble_fraction():
    assert bubble_fraction(4, 4) == pytest.approx(3 / 7)
    assert bubble_fraction(1, 8) == 0.0
    assert bubble_fraction(4, 32) < 0.09


def test_pipeline_matches_sequential_multidevice():
    script = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
import jax, jax.numpy as jnp, numpy as np
from repro.launch.mesh import make_mesh
from repro.parallel.pipeline import run_pipeline

mesh = make_mesh((4,), ("stage",))
n_stage, d, batch, n_micro = 4, 16, 8, 4
key = jax.random.key(0)
params = {"w": jax.random.normal(key, (n_stage, d, d)) / jnp.sqrt(d),
          "b": jnp.zeros((n_stage, d))}

def stage_fn(p, x):
    return jnp.tanh(x @ p["w"] + p["b"])

x = jax.random.normal(jax.random.key(1), (batch, d))
got = run_pipeline(mesh, stage_fn, params, x, n_micro=n_micro)

ref = x
for s in range(n_stage):
    ref = jnp.tanh(ref @ params["w"][s] + params["b"][s])
np.testing.assert_allclose(np.asarray(got), np.asarray(ref), rtol=2e-5,
                           atol=2e-5)
print("PIPELINE_OK")
"""
    env = {**os.environ, "PYTHONPATH": "src"}
    r = subprocess.run([sys.executable, "-c", script], env=env,
                       capture_output=True, text=True, cwd="/root/repo")
    assert r.returncode == 0, (r.stdout[-800:], r.stderr[-3000:])
    assert "PIPELINE_OK" in r.stdout
