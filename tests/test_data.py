"""Data pipeline: determinism (checkpoint-replay invariant), host sharding,
prefetch correctness."""

import numpy as np

from repro.data.pipeline import DataConfig, PrefetchIterator, TokenSource


def _cfg(**kw):
    base = dict(vocab_size=1000, seq_len=32, global_batch=8)
    base.update(kw)
    return DataConfig(**base)


def test_deterministic_across_instances():
    a = TokenSource(_cfg()).batch_at(7)
    b = TokenSource(_cfg()).batch_at(7)
    np.testing.assert_array_equal(a["tokens"], b["tokens"])
    np.testing.assert_array_equal(a["labels"], b["labels"])


def test_labels_are_shifted_tokens():
    b = TokenSource(_cfg()).batch_at(3)
    np.testing.assert_array_equal(b["tokens"][:, 1:], b["labels"][:, :-1])


def test_distinct_steps_differ():
    src = TokenSource(_cfg())
    assert not np.array_equal(src.batch_at(0)["tokens"],
                              src.batch_at(1)["tokens"])


def test_host_sharding_partitions_global_batch():
    full = TokenSource(_cfg(n_hosts=1)).batch_at(5)["tokens"]
    h0 = TokenSource(_cfg(n_hosts=2, host_id=0)).batch_at(5)["tokens"]
    h1 = TokenSource(_cfg(n_hosts=2, host_id=1)).batch_at(5)["tokens"]
    np.testing.assert_array_equal(np.concatenate([h0, h1]), full)


def test_tokens_in_vocab():
    b = TokenSource(_cfg(vocab_size=257)).batch_at(0)
    assert b["tokens"].min() >= 0 and b["tokens"].max() < 257


def test_prefetch_matches_source_and_resumes():
    src = TokenSource(_cfg())
    it = PrefetchIterator(src, start_step=4)
    try:
        for want_step in (4, 5, 6):
            step, batch = next(it)
            assert step == want_step
            np.testing.assert_array_equal(batch["tokens"],
                                          src.batch_at(want_step)["tokens"])
    finally:
        it.close()


def test_file_backed_source(tmp_path):
    path = tmp_path / "toks.bin"
    arr = (np.arange(10_000) % 500).astype(np.uint16)
    arr.tofile(path)
    src = TokenSource(_cfg(path=str(path), vocab_size=500))
    b = src.batch_at(0)
    assert b["tokens"].shape == (8, 32)
    # window 0 must reproduce the file prefix
    np.testing.assert_array_equal(b["tokens"][0], arr[:32].astype(np.int32))
