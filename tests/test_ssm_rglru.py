"""SSD (mamba2) and RG-LRU numerics: chunked/associative forms vs naive
sequential recurrences — the correctness core of the sub-quadratic families."""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.models import rglru as rglru_mod
from repro.models import ssm as ssm_mod


# --- mamba2 SSD ----------------------------------------------------------------

def _naive_ssd(xh, bt, ct, dt, a):
    """Sequential reference: h_t = exp(a·dt_t)·h_{t-1} + dt_t·B_t⊗x_t ;
    y_t = C_t·h_t."""
    b, s, h, p = xh.shape
    n = bt.shape[-1]
    hstate = np.zeros((b, h, p, n), np.float64)
    ys = np.zeros((b, s, h, p), np.float64)
    xh, bt, ct, dt, a = map(lambda t: np.asarray(t, np.float64),
                            (xh, bt, ct, dt, a))
    for t in range(s):
        decay = np.exp(dt[:, t] * a[None, :])               # (b,h)
        outer = np.einsum("bn,bh,bhp->bhpn", bt[:, t], dt[:, t], xh[:, t])
        hstate = decay[:, :, None, None] * hstate + outer
        ys[:, t] = np.einsum("bn,bhpn->bhp", ct[:, t], hstate)
    return ys, hstate


def _ssd_inputs(key, b=2, s=64, h=3, p=8, n=4):
    ks = jax.random.split(key, 5)
    xh = jax.random.normal(ks[0], (b, s, h, p), jnp.float32)
    bt = jax.random.normal(ks[1], (b, s, n), jnp.float32) * 0.5
    ct = jax.random.normal(ks[2], (b, s, n), jnp.float32) * 0.5
    dt = jax.nn.softplus(jax.random.normal(ks[3], (b, s, h), jnp.float32))
    a = -jnp.exp(jax.random.normal(ks[4], (h,), jnp.float32) * 0.3)
    return xh, bt, ct, dt, a


@pytest.mark.parametrize("chunk", [8, 16, 64])
def test_ssd_chunked_matches_naive(chunk):
    cfg = dataclasses.replace(get_config("mamba2-780m").smoke(),
                              ssm_chunk=chunk)
    xh, bt, ct, dt, a = _ssd_inputs(jax.random.key(chunk))
    y, hf = ssm_mod._ssd_chunked(xh, bt, ct, dt, a, cfg, None, lambda t, *_: t)
    y_ref, h_ref = _naive_ssd(xh, bt, ct, dt, a)
    np.testing.assert_allclose(np.asarray(y, np.float64), y_ref,
                               rtol=2e-3, atol=2e-3)
    np.testing.assert_allclose(np.asarray(hf, np.float64), h_ref,
                               rtol=2e-3, atol=2e-3)


def test_ssd_chunk_invariance():
    cfg8 = dataclasses.replace(get_config("mamba2-780m").smoke(), ssm_chunk=8)
    cfg32 = dataclasses.replace(get_config("mamba2-780m").smoke(), ssm_chunk=32)
    xh, bt, ct, dt, a = _ssd_inputs(jax.random.key(42))
    y8, _ = ssm_mod._ssd_chunked(xh, bt, ct, dt, a, cfg8, None, lambda t, *_: t)
    y32, _ = ssm_mod._ssd_chunked(xh, bt, ct, dt, a, cfg32, None, lambda t, *_: t)
    np.testing.assert_allclose(np.asarray(y8), np.asarray(y32), rtol=1e-3,
                               atol=1e-3)


def test_ssd_decode_continues_prefill():
    """Sequential decode from the prefilled state == full-sequence output."""
    cfg = dataclasses.replace(get_config("mamba2-780m").smoke(), ssm_chunk=8)
    xh, bt, ct, dt, a = _ssd_inputs(jax.random.key(7), s=40)
    y_full, _ = ssm_mod._ssd_chunked(
        xh[:, :40], bt[:, :40], ct[:, :40], dt[:, :40], a, cfg, None,
        lambda t, *_: t)
    # prefill 32, then 8 decode steps via the naive recurrence equations
    y_pre, h = ssm_mod._ssd_chunked(
        xh[:, :32], bt[:, :32], ct[:, :32], dt[:, :32], a, cfg, None,
        lambda t, *_: t)
    hs = np.asarray(h, np.float64)
    for t in range(32, 40):
        decay = np.exp(np.asarray(dt[:, t], np.float64)
                       * np.asarray(a)[None, :])
        outer = np.einsum("bn,bh,bhp->bhpn", np.asarray(bt[:, t], np.float64),
                          np.asarray(dt[:, t], np.float64),
                          np.asarray(xh[:, t], np.float64))
        hs = decay[:, :, None, None] * hs + outer
        y_t = np.einsum("bn,bhpn->bhp", np.asarray(ct[:, t], np.float64), hs)
        np.testing.assert_allclose(y_t, np.asarray(y_full[:, t], np.float64),
                                   rtol=3e-3, atol=3e-3)


# --- RG-LRU ---------------------------------------------------------------------

def test_rg_lru_scan_matches_sequential():
    b, s, w = 2, 33, 8
    a = jax.nn.sigmoid(jax.random.normal(jax.random.key(1), (b, s, w)))
    gx = jax.random.normal(jax.random.key(2), (b, s, w))
    got = rglru_mod.rg_lru_scan(a, gx)
    h = np.zeros((b, w))
    want = np.zeros((b, s, w))
    an, gn = np.asarray(a, np.float64), np.asarray(gx, np.float64)
    for t in range(s):
        h = an[:, t] * h + gn[:, t]
        want[:, t] = h
    np.testing.assert_allclose(np.asarray(got, np.float64), want,
                               rtol=1e-4, atol=1e-4)


def test_rg_lru_initial_state():
    b, s, w = 1, 16, 4
    a = jax.nn.sigmoid(jax.random.normal(jax.random.key(3), (b, s, w)))
    gx = jax.random.normal(jax.random.key(4), (b, s, w))
    h0 = jnp.ones((b, w)) * 2.0
    got = rglru_mod.rg_lru_scan(a, gx, h0)
    h = np.asarray(h0, np.float64).copy()
    for t in range(s):
        h = np.asarray(a)[:, t] * h + np.asarray(gx)[:, t]
        np.testing.assert_allclose(np.asarray(got)[:, t], h, rtol=1e-4,
                                   atol=1e-4)


def test_rg_lru_stability():
    """|a|<1 ⇒ bounded state even over long sequences (long_500k safety)."""
    b, s, w = 1, 4096, 4
    a = jnp.full((b, s, w), 0.999)
    gx = jnp.ones((b, s, w)) * 0.01
    out = rglru_mod.rg_lru_scan(a, gx)
    assert bool(jnp.all(jnp.isfinite(out)))
    assert float(jnp.max(jnp.abs(out))) < 11.0  # ≤ gx/(1-a) = 10


def test_griffin_pattern():
    cfg = get_config("recurrentgemma-2b")
    pat = cfg.layer_pattern()
    assert len(pat) == 26
    assert pat[:6] == ("rec", "rec", "attn", "rec", "rec", "attn")
    assert sum(1 for x in pat if x == "attn") == 8
