"""Per-kernel validation: shape/dtype sweeps, interpret=True vs pure-jnp ref."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ops, ref


def _rand(key, shape, dtype):
    return jax.random.normal(key, shape, jnp.float32).astype(dtype)


# --- int8 matmul -------------------------------------------------------------

@pytest.mark.parametrize("m,k,n", [
    (128, 512, 128),
    pytest.param(256, 1024, 384, marks=pytest.mark.slow),
    pytest.param(128, 2048, 256, marks=pytest.mark.slow),
    pytest.param(384, 512, 512, marks=pytest.mark.slow)])
@pytest.mark.parametrize("dtype", [jnp.bfloat16, jnp.float32])
def test_int8_matmul_sweep(m, k, n, dtype):
    kx, kw = jax.random.split(jax.random.key(m * k + n))
    x = _rand(kx, (m, k), dtype)
    w = _rand(kw, (k, n), jnp.float32)
    w_q, scales = ops.quantize_weight(w)
    got = ops.int8_matmul(x, w_q, scales, interpret=True)
    want = ref.int8_matmul_ref(x, w_q, scales)
    np.testing.assert_allclose(got.astype(np.float32), want.astype(np.float32),
                               rtol=2e-2, atol=2e-2 * float(jnp.std(want)))


def test_int8_matmul_block_shapes():
    """Kernel must be invariant to the BlockSpec tiling."""
    x = _rand(jax.random.key(0), (256, 1024), jnp.bfloat16)
    w = _rand(jax.random.key(1), (1024, 256), jnp.float32)
    w_q, s = ops.quantize_weight(w)
    base = ops.int8_matmul(x, w_q, s, interpret=True)
    for bm, bn, bk in [(128, 128, 512), (256, 128, 256), (128, 256, 1024)]:
        got = ops.int8_matmul(x, w_q, s, bm=bm, bn=bn, bk=bk, interpret=True)
        np.testing.assert_allclose(np.asarray(got, np.float32),
                                   np.asarray(base, np.float32),
                                   rtol=1e-2, atol=1e-2)


@pytest.mark.parametrize("m,k,n", [
    (64, 256, 64),      # all three dims below the default blocks: bm=m,
    (32, 128, 384),     # bn=n, bk=k clamp paths
    (8, 512, 128),      # tiny M (decode batch), exact default bk
    (128, 384, 256),    # K below bk and not a multiple of 512
    (4, 1024, 128),     # decode-shaped: batch-4 row block, deep K
])
def test_int8_matmul_clamped_blocks(m, k, n):
    """M,N,K off the 128/128/512 default grid exercise the bm/bn/bk
    clamping paths (block = min(default, dim)); kernel == jnp dequant ref."""
    kx, kw = jax.random.split(jax.random.key(m + k + n))
    x = _rand(kx, (m, k), jnp.float32)
    w = _rand(kw, (k, n), jnp.float32)
    w_q, scales = ops.quantize_weight(w)
    got = ops.int8_matmul(x, w_q, scales, interpret=True)
    want = ref.int8_matmul_ref(x, w_q, scales)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-5, atol=1e-5 * float(jnp.std(want)))


def test_int8_quantization_error_bounded():
    w = _rand(jax.random.key(2), (512, 128), jnp.float32)
    w_q, s = ops.quantize_weight(w)
    w_back = w_q.astype(jnp.float32) * s[None, :]
    err = jnp.max(jnp.abs(w - w_back))
    assert float(err) <= float(jnp.max(s)) * 0.5 + 1e-6  # half-ULP of int8 grid


# --- flash attention ---------------------------------------------------------

@pytest.mark.parametrize("b,h,s,d", [
    (1, 2, 256, 64),
    pytest.param(2, 1, 512, 128, marks=pytest.mark.slow),
    pytest.param(1, 4, 384, 64, marks=pytest.mark.slow)])
@pytest.mark.parametrize("causal", [True, False])
def test_flash_attention_sweep(b, h, s, d, causal):
    kq, kk, kv = jax.random.split(jax.random.key(b + s), 3)
    q = _rand(kq, (b, h, s, d), jnp.float32)
    k = _rand(kk, (b, h, s, d), jnp.float32)
    v = _rand(kv, (b, h, s, d), jnp.float32)
    got = ops.flash_attention(q, k, v, causal=causal, block_q=128, block_k=128,
                              interpret=True)
    want = ref.flash_attention_ref(q, k, v, causal=causal)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-3, atol=2e-3)


@pytest.mark.parametrize("window", [
    64, pytest.param(128, marks=pytest.mark.slow),
    pytest.param(256, marks=pytest.mark.slow)])
def test_flash_attention_window(window):
    q = _rand(jax.random.key(1), (1, 2, 512, 64), jnp.float32)
    k = _rand(jax.random.key(2), (1, 2, 512, 64), jnp.float32)
    v = _rand(jax.random.key(3), (1, 2, 512, 64), jnp.float32)
    got = ops.flash_attention(q, k, v, causal=True, window=window,
                              block_q=128, block_k=64, interpret=True)
    want = ref.flash_attention_ref(q, k, v, causal=True, window=window)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-3, atol=2e-3)


def test_flash_attention_bf16():
    q = _rand(jax.random.key(4), (2, 2, 256, 128), jnp.bfloat16)
    k = _rand(jax.random.key(5), (2, 2, 256, 128), jnp.bfloat16)
    v = _rand(jax.random.key(6), (2, 2, 256, 128), jnp.bfloat16)
    got = ops.flash_attention(q, k, v, interpret=True)
    want = ref.flash_attention_ref(q, k, v)
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32),
                               rtol=2e-2, atol=2e-2)


def test_flash_matches_model_chunked_attention():
    """Kernel ↔ the pure-JAX chunked attention used by the big shapes."""
    from repro.models.attention import chunked_attention
    q = _rand(jax.random.key(7), (2, 256, 4, 64), jnp.float32)
    k = _rand(jax.random.key(8), (2, 256, 4, 64), jnp.float32)
    v = _rand(jax.random.key(9), (2, 256, 4, 64), jnp.float32)
    # model layout (B,S,KV,G=1,D) vs kernel layout (B,H,S,D)
    got_model = chunked_attention(q[:, :, :, None, :], k, v, causal=True,
                                  q_chunk=128, kv_chunk=128)[:, :, :, 0, :]
    got_kernel = ops.flash_attention(
        jnp.transpose(q, (0, 2, 1, 3)), jnp.transpose(k, (0, 2, 1, 3)),
        jnp.transpose(v, (0, 2, 1, 3)), causal=True, interpret=True)
    np.testing.assert_allclose(
        np.asarray(jnp.transpose(got_kernel, (0, 2, 1, 3))),
        np.asarray(got_model), rtol=2e-3, atol=2e-3)


# --- quantize / dequantize ----------------------------------------------------

@pytest.mark.parametrize("shape", [(1024,), (333,), (64, 129), (7, 11, 13)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_quantize_roundtrip(shape, dtype):
    x = _rand(jax.random.key(hash(shape) % 2**31), shape, dtype)
    q, s, n = ops.quantize_blocks(x, block=256, interpret=True)
    back = ops.dequantize_blocks(q, s, n, shape, dtype=jnp.float32,
                                 interpret=True)
    # per-block error ≤ scale/2
    per_elem_bound = np.repeat(np.asarray(s), 256)[:n].reshape(shape) * 0.5
    err = np.abs(np.asarray(x, np.float32) - np.asarray(back))
    assert (err <= per_elem_bound + 1e-6).all()


def test_quantize_matches_ref():
    x = _rand(jax.random.key(11), (2048,), jnp.float32)
    q, s, n = ops.quantize_blocks(x, block=256, interpret=True)
    qr, sr, nr = ref.quantize_blocks_ref(x, block=256)
    np.testing.assert_array_equal(np.asarray(q), np.asarray(qr))
    np.testing.assert_allclose(np.asarray(s), np.asarray(sr), rtol=1e-6)


@pytest.mark.parametrize("shape", [(255,), (257,), (256 * 8,), (256 * 8 + 1,)])
def test_quantize_blocks_grid_pad_edges(shape):
    """Flat sizes straddling the (block x rows_per_tile) grid-tile boundary:
    the pad rows must not leak into the reconstructed prefix, and the error
    stays within half a grid step per block."""
    x = _rand(jax.random.key(sum(shape)), shape, jnp.float32)
    q, s, n = ops.quantize_blocks(x, block=256, interpret=True)
    assert n == shape[0]
    back = ops.dequantize_blocks(q, s, n, shape, dtype=jnp.float32,
                                 interpret=True)
    bound = np.repeat(np.asarray(s), 256)[:n].reshape(shape) * 0.5
    assert (np.abs(np.asarray(x) - np.asarray(back)) <= bound + 1e-6).all()


def test_quantize_weight_channelwise_bound():
    """Per-output-channel weight quantization (the serving wdtype='int8'
    pass): each channel reconstructs within scale/2 OF ITS OWN scale."""
    from repro.models.quantized import quantize_weight_channelwise
    w = _rand(jax.random.key(12), (256, 96), jnp.float32)
    qw = quantize_weight_channelwise(w, (0,))
    back = qw["int8_q"].astype(jnp.float32) * qw["s"]
    err = np.abs(np.asarray(w) - np.asarray(back))
    bound = np.asarray(qw["s"]) * 0.5 + 1e-6   # (1, 96) broadcasts per channel
    assert (err <= bound).all()
