"""I2 compression-aware gradient sync: QDQ error bounds, error feedback,
int8 ring all-reduce correctness + payload accounting."""

import subprocess
import sys
import os

import jax
import jax.numpy as jnp
import numpy as np

from repro.train import compression as comp


def test_qdq_error_bounded():
    g = jax.random.normal(jax.random.key(0), (1000,)) * 0.01
    ghat, err = comp.compress_decompress({"g": g})
    diff = np.abs(np.asarray(ghat["g"] - g))
    # int8 grid: error ≤ scale/2 per block; scale ≈ absmax/127
    assert diff.max() <= float(jnp.max(jnp.abs(g))) / 127.0 * 0.51 + 1e-8
    np.testing.assert_allclose(np.asarray(err["g"]),
                               np.asarray(g - ghat["g"]), atol=1e-7)


def test_error_feedback_unbiased_over_time():
    """With error feedback, the累 accumulated compressed signal tracks the true
    accumulated gradient (1-bit-Adam-style guarantee)."""
    key = jax.random.key(1)
    err = {"g": jnp.zeros((512,), jnp.float32)}
    total_true = jnp.zeros((512,))
    total_sent = jnp.zeros((512,))
    for i in range(20):
        key, k = jax.random.split(key)
        g = jax.random.normal(k, (512,)) * 0.1
        ghat, err = comp.compress_decompress({"g": g}, err)
        total_true += g
        total_sent += ghat["g"]
    resid = np.abs(np.asarray(total_sent + err["g"] - total_true))
    assert resid.max() < 1e-4  # exact up to float round-off


def test_error_feedback_sgd_converges():
    """Toy quadratic: compressed-with-feedback SGD reaches the same loss."""
    w_true = jnp.linspace(-1, 1, 64)

    def loss(w, x):
        return jnp.mean((x @ (w - w_true)) ** 2)

    def run(compressed: bool):
        w = jnp.zeros((64,))
        err = {"w": jnp.zeros((64,))} if compressed else None
        key = jax.random.key(2)
        for i in range(150):
            key, k = jax.random.split(key)
            x = jax.random.normal(k, (16, 64))
            g = jax.grad(loss)(w, x)
            if compressed:
                ghat, err = comp.compress_decompress({"w": g}, err)
                g = ghat["w"]
            w = w - 0.1 * g
        return float(loss(w, jnp.eye(64)))

    assert run(True) < 1e-3
    assert abs(run(True) - run(False)) < 1e-3


def test_payload_ratio():
    r = comp.payload_ratio((1024, 1024), block=256)
    assert 0.25 < r < 0.27  # int8 + f32/block ≈ 3.94× reduction


def test_compressed_ring_allreduce_multidevice():
    """shard_map int8 ring all-reduce ≈ psum on 8 fake devices, and its HLO
    moves int8 (not f32) over the wire."""
    script = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import jax, jax.numpy as jnp, numpy as np
from jax.sharding import PartitionSpec as P
from repro.launch.mesh import make_mesh
from repro.train.compression import compressed_ring_allreduce
from repro.parallel.shmap import shard_map

mesh = make_mesh((8,), ("data",))
x = jax.random.normal(jax.random.key(0), (8, 1024), jnp.float32) * 0.1

def f(xs):
    return compressed_ring_allreduce(xs[0], "data")[None]

y = jax.jit(shard_map(f, mesh=mesh, in_specs=P("data", None),
                          out_specs=P("data", None), check_vma=False))(x)
want = jnp.sum(x, axis=0)
got = np.asarray(y[0])
scale = float(jnp.max(jnp.abs(x)))
assert np.abs(got - np.asarray(want)).max() < scale / 127.0 * 8 * 1.5, \
    np.abs(got - np.asarray(want)).max()
txt = jax.jit(shard_map(f, mesh=mesh, in_specs=P("data", None),
                            out_specs=P("data", None), check_vma=False)).lower(x).compile().as_text()
import re
perms = re.findall(r"(s8|f32|bf16)\[([0-9,]+)\][^\n]*collective-permute", txt)
assert any(dt == "s8" for dt, _ in perms), perms
print("RING_OK")
"""
    env = {**os.environ, "PYTHONPATH": "src"}
    r = subprocess.run([sys.executable, "-c", script], env=env,
                       capture_output=True, text=True, cwd="/root/repo")
    assert r.returncode == 0, (r.stdout[-1000:], r.stderr[-3000:])
    assert "RING_OK" in r.stdout
