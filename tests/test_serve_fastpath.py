"""Serving fast path: prompt bucketing keeps prefill compiles O(log max_len)
while staying token-exact with the single-request oracle at lengths that
straddle bucket boundaries — across cache (dense) and state (ssm/hybrid)
model families."""

import math

import jax
import numpy as np
import pytest

from repro.configs import get_config
from repro.models import ExecOptions, build_model
from repro.serve.engine import ServeEngine, bucket_length, generate_greedy


@pytest.fixture(scope="module")
def smol():
    cfg = get_config("smollm-360m").smoke()
    model = build_model(cfg, ExecOptions(attn_impl="reference", ce_chunk=32))
    params = model.init(jax.random.key(0))
    return cfg, model, params


def _prompt(seed, n, vocab=512):
    return np.asarray(
        jax.random.randint(jax.random.key(seed), (n,), 0, vocab), np.int32)


def test_bucket_length():
    assert [bucket_length(n, 64) for n in (1, 2, 3, 8, 9, 33, 64)] \
        == [1, 2, 4, 8, 16, 64, 64]
    assert bucket_length(100, 64) == 64   # clipped at max_len


def test_prefill_compiles_log_in_max_len(smol):
    """Compile-count hierarchy over N requests of distinct prompt lengths:
    chunked prefill (the paged default) traces ONE chunk program total;
    monolithic bucketed prefill traces at most ceil(log2(max_len)) buckets;
    the seed path (no bucketing, no chunking) retraces per length."""
    cfg, model, params = smol
    max_len = 64
    lengths = list(range(3, 21))          # 18 distinct lengths
    eng = ServeEngine(model, n_slots=2, max_len=max_len, params=params)
    assert eng.chunked
    for i, n in enumerate(lengths):
        eng.submit(_prompt(i, n), max_new_tokens=2)
    eng.run_to_completion()
    assert eng.stats.chunk_compiles == 1, eng.stats.summary()
    assert eng.stats.prefill_compiles == 0
    assert eng.stats.prefills == len(lengths)
    # monolithic bucketed: one trace per power-of-two bucket
    engb = ServeEngine(model, n_slots=2, max_len=max_len, params=params,
                       chunked_prefill=False)
    for i, n in enumerate(lengths):
        engb.submit(_prompt(i, n), max_new_tokens=2)
    engb.run_to_completion()
    budget = math.ceil(math.log2(max_len))
    assert engb.stats.prefill_compiles <= budget, engb.stats.summary()
    # the seed path retraces per length
    eng0 = ServeEngine(model, n_slots=2, max_len=max_len, params=params,
                       bucket_prompts=False, chunked_prefill=False)
    for i, n in enumerate(lengths):
        eng0.submit(_prompt(i, n), max_new_tokens=2)
    eng0.run_to_completion()
    assert eng0.stats.prefill_compiles == len(lengths)


def test_decode_compiles_once(smol):
    cfg, model, params = smol
    eng = ServeEngine(model, n_slots=2, max_len=64, params=params)
    for i, n in enumerate((5, 9, 13, 17)):
        eng.submit(_prompt(i, n), max_new_tokens=4)
    eng.run_to_completion()
    assert eng.stats.decode_compiles == 1


def test_bucketed_engine_matches_oracle_at_boundaries(smol):
    """Padded prefill + last-token replay must be token-exact at prompt
    lengths straddling power-of-two bucket boundaries."""
    cfg, model, params = smol
    lengths = (7, 8, 9, 15, 16, 17)
    solo = {n: generate_greedy(model, params, _prompt(n, n), n_tokens=4,
                               max_len=64)
            for n in lengths}
    eng = ServeEngine(model, n_slots=2, max_len=64, params=params)
    reqs = {n: eng.submit(_prompt(n, n), max_new_tokens=4) for n in lengths}
    eng.run_to_completion()
    for n in lengths:
        assert reqs[n].done
        assert reqs[n].out_tokens == solo[n], (n, reqs[n].out_tokens, solo[n])


@pytest.mark.parametrize("arch", ["mamba2-780m", "recurrentgemma-2b"])
def test_state_families_stay_exact(arch):
    """Recurrent families skip bucketing (state carries through pads) but
    share the jitted-paste/one-sync step machinery; tokens must still match
    the isolated oracle."""
    cfg = get_config(arch).smoke()
    model = build_model(cfg, ExecOptions(attn_impl="reference", ce_chunk=32))
    params = model.init(jax.random.key(0))
    eng = ServeEngine(model, n_slots=2, max_len=64, params=params)
    assert not eng.bucket_prompts
    solo = {n: generate_greedy(model, params, _prompt(n, n), n_tokens=3,
                               max_len=64)
            for n in (7, 12)}
    reqs = {n: eng.submit(_prompt(n, n), max_new_tokens=3) for n in (7, 12)}
    eng.run_to_completion()
    for n, r in reqs.items():
        assert r.out_tokens == solo[n], (n, r.out_tokens, solo[n])
