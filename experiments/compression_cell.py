import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=256"

"""§Perf beyond-paper cell: compression-aware gradient sync (paper I2 → ICI).

Lowers the data-parallel gradient synchronization of a gemma-7b-sized shard
on the production mesh three ways and counts the HLO collective bytes:
  a) XLA all-reduce (psum) in fp32
  b) XLA all-reduce (psum) in bf16
  c) int8+scales ring all-reduce (shard_map + ppermute, Pallas quantize)

Run: PYTHONPATH=src python experiments/compression_cell.py
"""

import json
import pathlib

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.launch.hlo_analysis import collective_bytes
from repro.launch.mesh import make_production_mesh
from repro.train.compression import compressed_ring_allreduce

GRAD_ELEMS = 8_500_000 // 16          # one 16-way-TP shard of ~8.5B/1000 ≈ layer group
SHAPE = (2048, 260)                   # ≈531k elems per device → global 8.5M


def main():
    mesh = make_production_mesh()     # (data=16, model=16)
    out = {}

    def sync_psum(dtype):
        def f(g):
            return jax.lax.psum(g.astype(dtype), "data").astype(jnp.float32)
        return f

    def sync_ring(g):
        return compressed_ring_allreduce(g, "data")

    g_abs = jax.ShapeDtypeStruct((16,) + SHAPE, jnp.float32)

    for name, fn in [("allreduce_f32", sync_psum(jnp.float32)),
                     ("allreduce_bf16", sync_psum(jnp.bfloat16)),
                     ("ring_int8", sync_ring)]:
        mapped = jax.shard_map(
            lambda gs, fn=fn: fn(gs[0])[None],
            mesh=mesh, in_specs=P("data", None, None),
            out_specs=P("data", None, None), check_vma=False)
        compiled = jax.jit(mapped).lower(g_abs).compile()
        cb = collective_bytes(compiled.as_text())
        out[name] = {k: v for k, v in cb.items() if k != "counts"}
        print(f"{name:16s} coll_bytes/dev = {cb['total']:.3e} "
              f"({ {k: f'{v:.2e}' for k, v in cb.items() if k not in ('counts','total') and v} })")

    base = out["allreduce_f32"]["total"]
    for name in out:
        out[name]["ratio_vs_f32"] = out[name]["total"] / base if base else 0
    print(f"\nint8 ring vs f32 all-reduce: ×{out['ring_int8']['ratio_vs_f32']:.3f} "
          f"payload; vs bf16: ×{out['ring_int8']['total']/out['allreduce_bf16']['total']:.3f}")
    path = pathlib.Path(__file__).parent / "compression_cell.json"
    path.write_text(json.dumps(out, indent=1))
    print(f"wrote {path}")


if __name__ == "__main__":
    main()
