"""Roofline report (deliverable g) — reads experiments/dryrun/*.json.

Terms per (arch × shape) on the single-pod mesh (per the brief; dry-run
numbers are per-device, global = ×chips, so the per-chip formulas divide out):

  compute_s    = HLO_FLOPs_global   / (chips · 197e12)   = flops_per_dev / 197e12
  memory_s     = HLO_bytes_global   / (chips · 819e9)    = bytes_per_dev / 819e9
  collective_s = coll_bytes_global  / (chips · 50e9)     = coll_per_dev  / 50e9

MODEL_FLOPS: 6·N·D train (N = analytic params, D = tokens), 6·N_active·D MoE,
2·N·D forward-only (prefill), 2·N_active·B per decode step.
"""

from __future__ import annotations

import argparse
import json
import pathlib
from typing import Dict, Optional

from repro.configs import ARCH_ORDER, SHAPES, SHAPE_ORDER, get_config
from repro.core.planner import RooflineTerms

DRYRUN_DIR = pathlib.Path(__file__).resolve().parents[3] / "experiments" / "dryrun"


def model_flops(arch: str, shape_name: str) -> float:
    """Analytic 'useful' FLOPs for one step (global)."""
    cfg = get_config(arch)
    shape = SHAPES[shape_name]
    n_active = cfg.active_param_count()
    tokens = shape.global_batch * shape.seq_len
    if shape.kind == "train":
        return 6.0 * n_active * tokens
    if shape.kind == "prefill":
        return 2.0 * n_active * tokens
    return 2.0 * n_active * shape.global_batch        # one decoded token


def load_cell(arch: str, shape_name: str, tag: str = "") -> Optional[Dict]:
    safe = arch.replace(".", "_")
    sfx = f"__{tag}" if tag else ""
    path = DRYRUN_DIR / f"{safe}__{shape_name}{sfx}.json"
    if not path.exists():
        return None
    return json.loads(path.read_text())


def cell_terms(rec: Dict) -> Optional[RooflineTerms]:
    if rec.get("status") != "ok" or "totals_per_dev" not in rec:
        return None
    t = rec["totals_per_dev"]
    chips = rec["single_pod"]["chips"]
    return RooflineTerms(
        flops=t["flops"] * chips,
        hbm_bytes=t["bytes"] * chips,
        collective_bytes=t["coll_bytes"] * chips,
        chips=chips,
        model_flops=model_flops(rec["arch"], rec["shape"]),
    )


def one_line_fix(terms: RooflineTerms, rec: Dict) -> str:
    dom = terms.dominant
    if dom == "collective":
        return ("shrink the TP/SP reshard traffic (fewer model-axis hops, "
                "compressed or reduce-scattered grads)")
    if dom == "memory":
        return ("raise arithmetic intensity: fuse/flash the attention reads, "
                "int8 weights halve the stream")
    if terms.useful_flops_ratio < 0.5:
        return ("cut non-useful FLOPs: lighter remat policy, tighter causal "
                "block pruning, less head padding")
    return "already compute-bound; overlap remaining collectives"


def build_table(tag: str = "") -> Dict[str, Dict]:
    out = {}
    for arch in ARCH_ORDER:
        for shape in SHAPE_ORDER:
            rec = load_cell(arch, shape, tag)
            key = f"{arch} × {shape}"
            if rec is None:
                out[key] = {"status": "missing"}
                continue
            if rec["status"] == "skipped":
                out[key] = {"status": "skipped", "reason": rec["reason"]}
                continue
            if rec["status"] == "failed":
                out[key] = {"status": "failed", "error": rec.get("error", "")}
                continue
            terms = cell_terms(rec)
            mem = rec["single_pod"]["memory"]
            out[key] = {
                "status": "ok",
                "compute_s": terms.compute_s,
                "memory_s": terms.memory_s,
                "collective_s": terms.collective_s,
                "dominant": terms.dominant,
                "model_flops": terms.model_flops,
                "hlo_flops": terms.flops,
                "useful_ratio": terms.useful_flops_ratio,
                "roofline_fraction": terms.roofline_fraction,
                "peak_gib": mem["peak_gib"],
                "fits": mem["fits_16gib_hbm"],
                "multi_pod_fits": rec["multi_pod"]["memory"]["fits_16gib_hbm"],
                "fix": one_line_fix(terms, rec),
            }
    return out


def render_markdown(table: Dict[str, Dict]) -> str:
    lines = [
        "| arch × shape | compute s | memory s | collective s | bound | "
        "useful | roofline | peak GiB | fits |",
        "|---|---|---|---|---|---|---|---|---|",
    ]
    for key, row in table.items():
        if row["status"] != "ok":
            lines.append(f"| {key} | — | — | — | {row['status']} "
                         f"| | | | |")
            continue
        lines.append(
            f"| {key} | {row['compute_s']:.3f} | {row['memory_s']:.3f} | "
            f"{row['collective_s']:.3f} | **{row['dominant']}** | "
            f"{row['useful_ratio']:.2f} | {row['roofline_fraction']:.2f} | "
            f"{row['peak_gib']:.1f} | {'✓' if row['fits'] else '✗'} |")
    return "\n".join(lines)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--tag", default="")
    ap.add_argument("--json", action="store_true")
    ap.add_argument("--write-experiments", action="store_true",
                    help="inject the table at <!-- ROOFLINE_TABLE --> in "
                         "EXPERIMENTS.md")
    args = ap.parse_args()
    table = build_table(args.tag)
    if args.json:
        print(json.dumps(table, indent=1))
        return
    md = render_markdown(table)
    if args.write_experiments:
        exp = DRYRUN_DIR.parents[1] / "EXPERIMENTS.md"
        marker = "<!-- ROOFLINE_TABLE -->"
        text = exp.read_text()
        start = text.index(marker)
        # replace marker (and any previously injected table right after it)
        rest = text[start + len(marker):]
        if rest.lstrip().startswith("|"):
            tbl_end = rest.index("\n\n")
            rest = rest[tbl_end:]
        text = text[:start] + marker + "\n" + md + rest
        exp.write_text(text)
        print(f"wrote table into {exp}")
    print(md)
    ok = [r for r in table.values() if r["status"] == "ok"]
    if ok:
        worst = min(ok, key=lambda r: r["roofline_fraction"])
        print(f"\ncells ok={len(ok)}; worst roofline fraction "
              f"{worst['roofline_fraction']:.3f}")


if __name__ == "__main__":
    main()
