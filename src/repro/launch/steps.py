"""Step builders: jit-able train / prefill / decode steps with explicit
in/out shardings for a given (arch × shape × mesh) cell.

This is what the multi-pod dry-run lowers and what `launch/train.py` runs on
real hosts — a single code path, mesh-parameterized.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Callable, Dict, Optional

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.configs.base import ArchConfig, ShapeConfig
from repro.models import ExecOptions, ModelApi, build_model
from repro.models import registry as registry_mod
from repro.parallel import sharding as sh
from repro.train import optimizer as opt_mod


# ---------------------------------------------------------------------------
# Exec options per (shape × variant)
# ---------------------------------------------------------------------------

def _train_carry_gib(cfg: ArchConfig, shape: ShapeConfig, mesh: Mesh) -> float:
    """Remat-saved residual stream across the layer scan, per device, GiB."""
    data = mesh.shape.get("data", 1) * mesh.shape.get("pod", 1)
    b_loc = max(shape.global_batch // data, 1)
    return cfg.n_layers * b_loc * shape.seq_len * cfg.d_model * 2 / 2**30


def exec_options_for(cfg: ArchConfig, shape: ShapeConfig, mesh: Mesh,
                     overrides: Optional[Dict[str, Any]] = None,
                     rules=None) -> ExecOptions:
    """Baseline execution strategy; `overrides` is the hillclimb hook."""
    kw: Dict[str, Any] = dict(constrain=sh.make_constrain(mesh, rules))
    if shape.kind == "train":
        # remat='full' (save only layer boundaries): the 'dots' policy keeps
        # every matmul output alive across the layer scan — measured 36.9 GiB
        # temp/device on gemma-7b train_4k vs 16 GiB HBM (EXPERIMENTS.md §Perf).
        # Sequence-parallel residuals only when the saved carry would crowd
        # HBM — SP costs ~4 activation-sized all-gathers per layer (the
        # planner trade-off recorded in EXPERIMENTS.md §Perf).
        sp = _train_carry_gib(cfg, shape, mesh) > 4.0
        kw.update(attn_impl="chunked", q_chunk=min(1024, shape.seq_len),
                  kv_chunk=min(1024, shape.seq_len), ce_chunk=512,
                  remat="full", act_seq_shard=sp)
    elif shape.kind == "prefill":
        kw.update(attn_impl="chunked", q_chunk=2048, kv_chunk=2048,
                  ce_chunk=512, remat="none", act_seq_shard=False)
    else:  # decode
        kw.update(attn_impl="reference", ce_chunk=512, remat="none",
                  act_seq_shard=False)
    if overrides:
        kw.update(overrides)
    return ExecOptions(**kw)


def arch_for_mesh(cfg: ArchConfig, mesh: Mesh) -> ArchConfig:
    """Apply distribution-time head padding for the mesh's TP size."""
    tp = mesh.shape.get("model", 1)
    return dataclasses.replace(cfg, tp_pad=tp)


def suggest_plan(cfg: ArchConfig, shape: ShapeConfig, mesh: Mesh) -> str:
    """The chiplet-aware planner's topology decision (§Perf hillclimbs #2/#3).

    * tiny models on a big mesh: 16-way TP leaves <~8 M params per model
      shard and the per-layer TP collectives dwarf the compute (measured
      15.2× collective reduction on smollm-360m) → 'dp_heavy';
    * MoE/dense decode: FSDP-gathered weights dominate the step (measured
      28× collective reduction on dbrx-132b decode) → 'serve_ws';
    * everything else → the default 'tp16'.
    """
    tp = mesh.shape.get("model", 1)
    params_per_shard = cfg.param_count_analytic() / max(tp, 1)
    if shape.is_train and params_per_shard < 128e6 \
            and shape.global_batch % mesh.size == 0:
        return "dp_heavy"
    if shape.kind == "decode":
        # weight-stationary decode needs the experts to actually shard (EP);
        # with replicated experts (E % tp != 0, e.g. qwen2-moe's 60) the
        # decode token-replication multiplies replicated expert compute
        # (measured ×10.8 flops, ×2.8 collectives — EXPERIMENTS.md §Perf #3)
        if cfg.family == "moe" and cfg.n_experts % tp != 0:
            return "tp16"
        return "serve_ws"
    return "tp16"


# ---------------------------------------------------------------------------
# Train step
# ---------------------------------------------------------------------------

def train_state_specs(model: ModelApi, mesh: Mesh, rules=None):
    pspec = sh.schema_pspecs(model.schema, mesh, rules)
    return {
        "params": pspec,
        "opt": {"m": pspec, "v": pspec, "step": P()},
    }


def abstract_train_state(model: ModelApi):
    params = model.abstract()
    f32 = lambda s: jax.ShapeDtypeStruct(s.shape, jnp.float32)  # noqa: E731
    return {
        "params": params,
        "opt": {"m": jax.tree.map(f32, params),
                "v": jax.tree.map(f32, params),
                "step": jax.ShapeDtypeStruct((), jnp.int32)},
    }


def suggest_n_micro(cfg: ArchConfig, shape: ShapeConfig, mesh: Mesh,
                    hbm_gib: float = 12.0) -> int:
    """Gradient-accumulation factor from a napkin memory model (validated on
    the dry-run: gemma-7b ≈ 12 activation units + states; dbrx-132b 30.4 GiB
    at n_micro=1). activation_unit = one fp32 (B_loc, S, d) tensor."""
    chips = mesh.size
    data = mesh.shape.get("data", 1) * mesh.shape.get("pod", 1)
    b_loc = max(shape.global_batch // data, 1)
    unit = b_loc * shape.seq_len * cfg.d_model * 4 / 2**30
    carry = _train_carry_gib(cfg, shape, mesh)
    if _train_carry_gib(cfg, shape, mesh) > 4.0:   # SP shards the carry
        carry /= mesh.shape.get("model", 1)
    fixed = cfg.param_count_analytic() * 14 / chips / 2**30  # p+m+v+g
    units = 14
    if cfg.family == "moe":
        # grouped dispatch adds ~top_k·cf·(2d+f)/d activation units
        # (dispatch/combine + expert slot tensors; qwen2-moe measured
        # 22.5 GiB at n_micro=1 without this term)
        units += 8
    need = units * unit + carry
    avail = hbm_gib - fixed
    if "pod" in mesh.shape:
        # cross-pod gradient staging + larger collective buffers: calibrated
        # on the two cells the plain model missed (dbrx-132b 16.4 GiB,
        # qwen2-moe 19.2 GiB at the un-reserved choice — EXPERIMENTS §Dry-run)
        avail -= 6.0
    avail = max(avail, 2.0)
    n = 1
    while need / n > avail and n < b_loc:
        n *= 2
    return n


def make_train_step(model: ModelApi, opt_cfg: opt_mod.OptimizerConfig,
                    grad_transform: Optional[Callable] = None,
                    n_micro: int = 1, unroll: bool = False):
    """(state, batch) → (state, metrics). Pure; jit with shardings outside.

    n_micro > 1 runs gradient accumulation over microbatches (fp32 grad
    buffer) — the memory lever that avoids SP's per-layer collective cost.
    """

    def grad_of(params, mb):
        def loss_fn(p):
            return model.train_loss(p, mb)

        return jax.value_and_grad(loss_fn, has_aux=True)(params)

    def step(state, batch):
        params = state["params"]
        if n_micro == 1:
            (loss, _), grads = grad_of(params, batch)
            grads = jax.tree.map(lambda g: g.astype(jnp.float32), grads)
        else:
            micro = jax.tree.map(
                lambda t: t.reshape((n_micro, t.shape[0] // n_micro)
                                    + t.shape[1:]), batch)

            def body(acc, mb):
                (l, _), g = grad_of(params, mb)
                acc_g = jax.tree.map(
                    lambda a, b: a + b.astype(jnp.float32) / n_micro,
                    acc[0], g)
                return (acc_g, acc[1] + l / n_micro), None

            zeros = jax.tree.map(
                lambda p: jnp.zeros(p.shape, jnp.float32), params)
            from repro.models.common import scan_or_unroll
            (grads, loss), _ = scan_or_unroll(
                body, (zeros, jnp.float32(0.0)), micro, unroll=unroll)
        if grad_transform is not None:  # e.g. compression-aware DP sync
            grads = grad_transform(grads)
        grads, gnorm = opt_mod.clip_by_global_norm(grads, opt_cfg.clip_norm)
        params, opt, lr = opt_mod.adamw_update(params, grads,
                                               state["opt"], opt_cfg)
        new_state = {"params": params, "opt": opt}
        return new_state, {"loss": loss, "grad_norm": gnorm, "lr": lr}

    return step


def jit_train_step(model: ModelApi, mesh: Mesh, shape: ShapeConfig,
                   opt_cfg: Optional[opt_mod.OptimizerConfig] = None,
                   grad_transform: Optional[Callable] = None,
                   n_micro: int = 1, rules=None):
    """Returns (jitted_step, abstract_args) ready to .lower() or call."""
    opt_cfg = opt_cfg or opt_mod.OptimizerConfig()
    step = make_train_step(model, opt_cfg, grad_transform, n_micro=n_micro,
                           unroll=model.opts.unroll_scans)
    state_specs = train_state_specs(model, mesh, rules)
    abs_state = abstract_train_state(model)
    abs_batch = registry_mod.input_specs(model.cfg, shape)
    batch_specs = sh.batch_pspecs(abs_batch, mesh, rules)
    metrics_specs = {"loss": P(), "grad_norm": P(), "lr": P()}
    jitted = jax.jit(
        step,
        in_shardings=(sh.named(mesh, state_specs), sh.named(mesh, batch_specs)),
        out_shardings=(sh.named(mesh, state_specs),
                       sh.named(mesh, metrics_specs)),
        donate_argnums=(0,),
    )
    return jitted, (abs_state, abs_batch)


# ---------------------------------------------------------------------------
# Serve steps
# ---------------------------------------------------------------------------

def jit_prefill_step(model: ModelApi, mesh: Mesh, shape: ShapeConfig,
                     rules=None):
    pspec = sh.schema_pspecs(model.schema, mesh, rules)
    abs_params = model.abstract()
    abs_batch = registry_mod.input_specs(model.cfg, shape)
    batch_specs = sh.batch_pspecs(abs_batch, mesh, rules)
    out_abs = jax.eval_shape(model.prefill, abs_params, abs_batch)
    logits_spec = sh.logits_pspec(mesh, shape.global_batch,
                                  model.cfg.padded_vocab, rules)
    cache_specs = sh.cache_pspecs(model.cfg, out_abs[1], mesh, rules)
    jitted = jax.jit(
        model.prefill,
        in_shardings=(sh.named(mesh, pspec), sh.named(mesh, batch_specs)),
        out_shardings=(NamedSharding(mesh, logits_spec),
                       sh.named(mesh, cache_specs)),
    )
    return jitted, (abs_params, abs_batch)


def jit_decode_step(model: ModelApi, mesh: Mesh, shape: ShapeConfig,
                    cache_dtype=jnp.bfloat16, rules=None):
    pspec = sh.schema_pspecs(model.schema, mesh, rules)
    abs_params = model.abstract()
    abs_batch = registry_mod.input_specs(model.cfg, shape)
    batch_specs = sh.batch_pspecs(abs_batch, mesh, rules)
    abs_cache = model.cache_shape(shape.global_batch, shape.seq_len,
                                  cache_dtype)
    cache_specs = sh.cache_pspecs(model.cfg, abs_cache, mesh, rules)
    out_abs = jax.eval_shape(model.decode, abs_params, abs_batch, abs_cache)
    logits_spec = sh.logits_pspec(mesh, shape.global_batch,
                                  model.cfg.padded_vocab, rules)
    out_cache_specs = sh.cache_pspecs(model.cfg, out_abs[1], mesh, rules)
    jitted = jax.jit(
        model.decode,
        in_shardings=(sh.named(mesh, pspec), sh.named(mesh, batch_specs),
                      sh.named(mesh, cache_specs)),
        out_shardings=(NamedSharding(mesh, logits_spec),
                       sh.named(mesh, out_cache_specs)),
        donate_argnums=(2,),
    )
    return jitted, (abs_params, abs_batch, abs_cache)


# ---------------------------------------------------------------------------
# One-call cell lowering (dry-run entry)
# ---------------------------------------------------------------------------

def build_cell(arch_cfg: ArchConfig, shape: ShapeConfig, mesh: Mesh,
               overrides: Optional[Dict[str, Any]] = None,
               opt_cfg: Optional[opt_mod.OptimizerConfig] = None):
    """Returns (jitted_fn, abstract_args) for one (arch × shape × mesh) cell.

    `overrides` may carry step-level keys (n_micro) alongside ExecOptions
    fields — the hillclimb hook tunes both from one dict.
    """
    overrides = dict(overrides or {})
    plan = overrides.pop("plan", "tp16")
    if plan == "auto":  # the chiplet-aware planner decides (§Perf findings)
        plan = suggest_plan(arch_cfg, shape, mesh)
    rules = sh.rules_for_plan(plan)
    if plan == "dp_heavy":
        # TP retired → no head padding needed
        cfg = arch_cfg
    else:
        cfg = arch_for_mesh(arch_cfg, mesh)
    n_micro = overrides.pop("n_micro", None)
    opts = exec_options_for(cfg, shape, mesh, overrides, rules)
    model = build_model(cfg, opts)
    if shape.kind == "train":
        if n_micro is None:
            n_micro = suggest_n_micro(cfg, shape, mesh)
        return jit_train_step(model, mesh, shape, opt_cfg, n_micro=n_micro,
                              rules=rules)
    if shape.kind == "prefill":
        return jit_prefill_step(model, mesh, shape, rules=rules)
    return jit_decode_step(model, mesh, shape, rules=rules)
