"""Production meshes (assignment brief: 16×16 single pod, 2×16×16 multi-pod).

`make_production_mesh` is a FUNCTION (not a module constant) so importing this
module never touches jax device state; the dry-run sets
XLA_FLAGS=--xla_force_host_platform_device_count=512 before any jax import.
"""

from __future__ import annotations

import jax

try:  # jax >= 0.5: explicit axis types on make_mesh
    from jax.sharding import AxisType

    def _mk(shape, axes):
        return jax.make_mesh(shape, axes,
                             axis_types=(AxisType.Auto,) * len(axes))
except ImportError:  # older jax: every axis is Auto already
    def _mk(shape, axes):
        return jax.make_mesh(shape, axes)


def make_production_mesh(*, multi_pod: bool = False) -> jax.sharding.Mesh:
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return _mk(shape, axes)


def make_mesh(shape, axes) -> jax.sharding.Mesh:
    """Arbitrary mesh (tests / small-host runs / elastic re-shard)."""
    return _mk(tuple(shape), tuple(axes))


def make_host_mesh(model: int = 1) -> jax.sharding.Mesh:
    """Mesh over whatever devices this host actually has."""
    n = len(jax.devices())
    assert n % model == 0, (n, model)
    return make_mesh((n // model, model), ("data", "model"))


def make_serve_mesh(n_shards: int = 0,
                    axis: str = "data") -> jax.sharding.Mesh:
    """1-D slot-sharding mesh for the sharded serving engine.

    One shard per device along `axis` (the production mesh's data axis);
    n_shards=0 takes every local device. Built directly (not via make_mesh)
    so a PREFIX of the host's devices can back a smaller serving tier —
    CPU parity tests force 8 fake devices and shard over all of them."""
    import numpy as np
    devs = jax.devices()
    n = n_shards or len(devs)
    assert 1 <= n <= len(devs), (n, len(devs))
    return jax.sharding.Mesh(np.asarray(devs[:n]), (axis,))
