"""Production meshes (assignment brief: 16×16 single pod, 2×16×16 multi-pod).

`make_production_mesh` is a FUNCTION (not a module constant) so importing this
module never touches jax device state; the dry-run sets
XLA_FLAGS=--xla_force_host_platform_device_count=512 before any jax import.
"""

from __future__ import annotations

import jax
from jax.sharding import AxisType


def make_production_mesh(*, multi_pod: bool = False) -> jax.sharding.Mesh:
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes,
                         axis_types=(AxisType.Auto,) * len(axes))


def make_mesh(shape, axes) -> jax.sharding.Mesh:
    """Arbitrary mesh (tests / small-host runs / elastic re-shard)."""
    return jax.make_mesh(tuple(shape), tuple(axes),
                         axis_types=(AxisType.Auto,) * len(axes))


def make_host_mesh(model: int = 1) -> jax.sharding.Mesh:
    """Mesh over whatever devices this host actually has."""
    n = len(jax.devices())
    assert n % model == 0, (n, model)
    return make_mesh((n // model, model), ("data", "model"))
