import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run (deliverable e) — proves the distribution config is
coherent without hardware.

For every (architecture × input shape) cell:
  1. REAL program (layer-scanned) on the single-pod 16×16 mesh AND the
     multi-pod 2×16×16 mesh: .lower().compile() must succeed;
     memory_analysis() proves the per-device footprint fits.
  2. COST PROBES (single-pod): two small programs with every lax.scan
     statically unrolled (XLA's cost_analysis counts while bodies once —
     measured, see EXPERIMENTS.md §Dry-run) at layer counts L_a < L_b; exact
     per-layer Δ-costs extrapolate to the full depth:
         total(L) = probe(L_a) + (L - L_a) · (probe(L_b) - probe(L_a)) / (L_b - L_a)
     This gives exact HLO FLOPs / bytes / collective bytes for §Roofline.

Results cache to experiments/dryrun/<cell>.json (re-runs skip finished cells).

Usage:
  python -m repro.launch.dryrun --arch gemma-7b --shape train_4k
  python -m repro.launch.dryrun --all [--multipod-only] [--force]
"""

import argparse
import dataclasses
import json
import pathlib
import time
import traceback

from repro.configs import ARCH_ORDER, SHAPES, SHAPE_ORDER, get_config
from repro.configs.base import cell_is_runnable
from repro.launch import hlo_analysis, steps
from repro.launch.mesh import make_production_mesh

OUT_DIR = pathlib.Path(__file__).resolve().parents[3] / "experiments" / "dryrun"


def _probe_layer_counts(cfg):
    """(L_a, L_b, n_units, unit_desc) for the Δ-cost extrapolation."""
    if cfg.family == "hybrid":
        # pattern (rec,rec,attn): probe 2 (rec,rec) and 5 (+ attn,rec,rec);
        # total(26) = probe(2) + 8 · Δ
        return 2, 5, (cfg.n_layers - 2) // 3, "3-layer griffin group"
    if cfg.family == "encdec":
        return 1, 2, cfg.n_enc_layers - 1, "enc+dec layer pair"
    return 1, 2, cfg.n_layers - 1, "layer"


def _with_layers(cfg, n):
    kw = {"n_layers": n}
    if cfg.family == "encdec":
        kw.update(n_enc_layers=n, n_dec_layers=n)
    return dataclasses.replace(cfg, **kw)


def _compile(cfg, shape, mesh, overrides=None):
    jitted, abs_args = steps.build_cell(cfg, shape, mesh, overrides)
    lowered = jitted.lower(*abs_args)
    compiled = lowered.compile()
    return compiled


def run_cell(arch: str, shape_name: str, *, skip_probes=False,
             overrides=None, verbose=True):
    """Returns the result dict for one cell (also used by roofline/perf)."""
    cfg = get_config(arch)
    shape = SHAPES[shape_name]
    ok, why = cell_is_runnable(cfg, shape)
    if not ok:
        return {"arch": arch, "shape": shape_name, "status": "skipped",
                "reason": why}
    rec = {"arch": arch, "shape": shape_name, "status": "ok",
           "overrides": overrides or {}, "timings_s": {}}

    # --- 1. real program, single-pod -------------------------------------
    mesh1 = make_production_mesh(multi_pod=False)
    t0 = time.time()
    compiled = _compile(cfg, shape, mesh1, overrides)
    rec["timings_s"]["compile_single_pod"] = round(time.time() - t0, 1)
    a = hlo_analysis.analyze_compiled(compiled)
    # XLA CPU ignores buffer donation, so `peak` double-counts the donated
    # state/cache (train state, decode KV). On the TPU target the out buffer
    # aliases the donated arg: effective peak = args + temp.
    donated = shape.kind in ("train", "decode")
    eff_peak = a.arg_bytes + a.temp_bytes if donated else a.peak_bytes
    rec["single_pod"] = {
        "chips": mesh1.size,
        "memory": {"argument_bytes": a.arg_bytes, "output_bytes": a.out_bytes,
                   "temp_bytes": a.temp_bytes, "peak_bytes": a.peak_bytes,
                   "peak_gib": round(eff_peak / 2**30, 3),
                   "peak_gib_no_donation": round(a.peak_bytes / 2**30, 3),
                   "fits_16gib_hbm": eff_peak < 16 * 2**30},
        "scan_body_once": {  # per-iteration numbers (while bodies count once)
            "flops_per_dev": a.flops_per_dev,
            "bytes_per_dev": a.bytes_per_dev,
            "coll_bytes_per_dev": a.coll_bytes_per_dev,
            "coll_breakdown": a.coll_breakdown,
        },
    }
    del compiled

    # --- 2. real program, multi-pod (512 chips) ---------------------------
    mesh2 = make_production_mesh(multi_pod=True)
    t0 = time.time()
    compiled = _compile(cfg, shape, mesh2, overrides)
    rec["timings_s"]["compile_multi_pod"] = round(time.time() - t0, 1)
    a2 = hlo_analysis.analyze_compiled(compiled)
    eff_peak2 = a2.arg_bytes + a2.temp_bytes if donated else a2.peak_bytes
    rec["multi_pod"] = {
        "chips": mesh2.size,
        "memory": {"peak_bytes": a2.peak_bytes,
                   "peak_gib": round(eff_peak2 / 2**30, 3),
                   "fits_16gib_hbm": eff_peak2 < 16 * 2**30},
        "coll_breakdown": a2.coll_breakdown,
    }
    del compiled

    # --- 3. cost probes (single-pod, unrolled) -----------------------------
    if not skip_probes:
        la, lb, units, desc = _probe_layer_counts(cfg)
        probe_overrides = dict(overrides or {}, unroll_scans=True)
        if shape.kind == "train" and "n_micro" not in probe_overrides:
            # pin the probes to the REAL cell's grad-accumulation factor —
            # re-deriving it from the 1–2 layer probe configs picks a
            # different n_micro and skews the collective extrapolation
            probe_overrides["n_micro"] = steps.suggest_n_micro(
                steps.arch_for_mesh(cfg, mesh1), shape, mesh1)
        t0 = time.time()
        pa = hlo_analysis.analyze_compiled(
            _compile(_with_layers(cfg, la), shape, mesh1, probe_overrides))
        pb = hlo_analysis.analyze_compiled(
            _compile(_with_layers(cfg, lb), shape, mesh1, probe_overrides))
        rec["timings_s"]["probes"] = round(time.time() - t0, 1)

        def tot(field_a, field_b):
            per_unit = (field_b - field_a) / (lb - la)
            if cfg.family == "hybrid":
                n_units = (cfg.n_layers - la) // 3
                return field_a + n_units * (field_b - field_a)
            n_full = cfg.n_enc_layers if cfg.family == "encdec" else cfg.n_layers
            return field_a + per_unit * (n_full - la)

        rec["probe"] = {
            "layer_counts": [la, lb], "unit": desc,
            "a": {"flops": pa.flops_per_dev, "bytes": pa.bytes_per_dev,
                  "coll": pa.coll_bytes_per_dev},
            "b": {"flops": pb.flops_per_dev, "bytes": pb.bytes_per_dev,
                  "coll": pb.coll_bytes_per_dev},
        }
        rec["totals_per_dev"] = {
            "flops": tot(pa.flops_per_dev, pb.flops_per_dev),
            "bytes": tot(pa.bytes_per_dev, pb.bytes_per_dev),
            "coll_bytes": tot(pa.coll_bytes_per_dev, pb.coll_bytes_per_dev),
        }
        coll_kinds = {}
        for k in pa.coll_breakdown:
            if k == "total":
                continue
            coll_kinds[k] = tot(pa.coll_breakdown.get(k, 0.0),
                                pb.coll_breakdown.get(k, 0.0))
        rec["totals_per_dev"]["coll_kinds"] = coll_kinds
    if verbose:
        m = rec["single_pod"]["memory"]
        t = rec.get("totals_per_dev", {})
        print(f"[dryrun] {arch} × {shape_name}: peak={m['peak_gib']}GiB "
              f"fits={m['fits_16gib_hbm']} flops/dev={t.get('flops', 0):.3e} "
              f"coll/dev={t.get('coll_bytes', 0):.3e}B", flush=True)
    return rec


def cell_path(arch, shape_name, tag=""):
    safe = arch.replace(".", "_")
    sfx = f"__{tag}" if tag else ""
    return OUT_DIR / f"{safe}__{shape_name}{sfx}.json"


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--force", action="store_true")
    ap.add_argument("--skip-probes", action="store_true")
    ap.add_argument("--tag", default="")
    ap.add_argument("--overrides", default=None,
                    help="JSON ExecOptions overrides (hillclimb variants)")
    args = ap.parse_args()
    OUT_DIR.mkdir(parents=True, exist_ok=True)

    cells = []
    if args.all:
        cells = [(a, s) for a in ARCH_ORDER for s in SHAPE_ORDER]
    else:
        assert args.arch and args.shape, "--arch/--shape or --all"
        cells = [(args.arch, args.shape)]

    overrides = json.loads(args.overrides) if args.overrides else None
    n_ok = n_skip = n_fail = 0
    for arch, shape_name in cells:
        path = cell_path(arch, shape_name, args.tag)
        if path.exists() and not args.force:
            print(f"[dryrun] cached: {path.name}", flush=True)
            n_ok += 1
            continue
        try:
            rec = run_cell(arch, shape_name, skip_probes=args.skip_probes,
                           overrides=overrides)
            if rec["status"] == "skipped":
                n_skip += 1
            else:
                n_ok += 1
        except Exception as e:  # noqa: BLE001 — record the failure, keep going
            rec = {"arch": arch, "shape": shape_name, "status": "failed",
                   "error": f"{type(e).__name__}: {e}",
                   "traceback": traceback.format_exc()[-4000:]}
            n_fail += 1
            print(f"[dryrun] FAILED {arch} × {shape_name}: {e}", flush=True)
        path.write_text(json.dumps(rec, indent=1))
    print(f"[dryrun] done: {n_ok} ok, {n_skip} skipped, {n_fail} failed",
          flush=True)


if __name__ == "__main__":
    main()
