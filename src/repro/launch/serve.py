"""Serving driver: continuous-batching engine over a chosen architecture.

  PYTHONPATH=src python -m repro.launch.serve --arch smollm-360m --smoke \
      --requests 16 --slots 4 [--wdtype int8] [--kv-dtype int8]

`--wdtype int8 --kv-dtype int8` is the paper's "AI-optimized" serving
numerics: weight-only int8 projections (Pallas int8_matmul on TPU) plus an
int8 paged KV pool with dequant fused into the decode-attention kernel —
the 15 TOPS INT8 NPU datapath (§II) as the measured configuration.

`--shards N` serves through the sharded multi-chiplet engine instead
(serve/sharded.py): slots and the paged KV pool partition over a 1-D data
mesh of N local devices — one shard per chiplet — with device-local page
tables and one shard_map'd global decode step. Token streams are identical
to the single-host engine. On CPU, force fake devices first:
XLA_FLAGS=--xla_force_host_platform_device_count=N.

On a pod the same engine runs against the mesh-sharded prefill/decode steps
from `launch/steps.py`; on CPU it serves the reduced configs (examples +
tests exercise this path).
"""

from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.models import ExecOptions, build_model
from repro.serve.engine import ServeEngine


def quantize_params_int8(params):
    """Weight-only int8 QDQ over generic 2-D weights.

    Kept for f32-datapath experiments that only want int8 NUMERICS; real
    int8 serving goes through `ServeEngine(wdtype="int8")`, which stores the
    projections as (int8, scale) and dispatches the Pallas int8_matmul."""
    from repro.kernels import ops as kops

    def qdq(p):
        if p.ndim == 2 and min(p.shape) >= 64:
            q, s = kops.quantize_weight(p.astype(jnp.float32))
            return (q.astype(jnp.float32) * s[None, :]).astype(p.dtype)
        return p

    return jax.tree.map(qdq, params)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="smollm-360m")
    ap.add_argument("--smoke", action="store_true", default=True)
    ap.add_argument("--requests", type=int, default=16)
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--max-len", type=int, default=96)
    ap.add_argument("--new-tokens", type=int, default=8)
    ap.add_argument("--int8", action="store_true",
                    help="shorthand for --wdtype int8 --kv-dtype int8")
    ap.add_argument("--wdtype", choices=["bf16", "int8"], default=None,
                    help="weight datapath (int8 = Pallas int8_matmul on TPU)")
    ap.add_argument("--kv-dtype", choices=["f32", "bf16", "int8", "fp8"],
                    default=None,
                    help="KV-cache storage (int8 = fused-dequant decode; "
                         "fp8 = e5m2 cast, dense layout / --page-size 0)")
    ap.add_argument("--page-size", type=int, default=32,
                    help="KV page size (0 = dense per-slot cache)")
    ap.add_argument("--pages", type=int, default=0,
                    help="pool pages incl. the null page (0 = worst case); "
                         "with --shards this is PER-SHARD (each shard owns "
                         "its own pool + local null page)")
    ap.add_argument("--no-chunked-prefill", action="store_true",
                    help="monolithic bucketed prefill instead of the "
                         "chunked page-granular default (paged engines)")
    ap.add_argument("--prefix-cache", dest="prefix_cache",
                    action="store_true", default=None,
                    help="force the ref-counted prefix cache on (default: "
                    "auto — on for paged+chunked engines, off under a "
                    "sliding window)")
    ap.add_argument("--no-prefix-cache", dest="prefix_cache",
                    action="store_false",
                    help="disable prefix caching / copy-on-write pages")
    ap.add_argument("--chunk-pages", type=int, default=2,
                    help="prefill chunk size in pages (chunk = "
                         "chunk_pages x page_size tokens)")
    ap.add_argument("--shards", type=int, default=0,
                    help="shard slots + KV pages over N local devices "
                         "(sharded multi-chiplet engine; 0 = single-host)")
    ap.add_argument("--migration", dest="migration", action="store_true",
                    default=True,
                    help="live page migration over the modeled UCIe link "
                         "(default on, --shards only): DRAINING shards "
                         "re-home live slots by O(bytes) page moves and "
                         "hot prefixes replicate cross-shard")
    ap.add_argument("--no-migration", dest="migration",
                    action="store_false",
                    help="fall back to re-prefill replay for every "
                         "displaced slot")
    ap.add_argument("--rebalance-threshold", type=int, default=0,
                    help="busy-slot gap that triggers an elastic slot "
                         "migration between shards (0 = rebalancing off; "
                         "drain migration is governed by --migration)")
    ap.add_argument("--fault-seed", type=int, default=None,
                    help="inject a seeded chaos FaultPlan (serve/faults."
                         "chaos_plan): shard death/rejoin + page squeezes; "
                         "same seed replays the same schedule bit-for-bit")
    ap.add_argument("--fault-rate", type=float, default=1.0,
                    help="chaos intensity multiplier: scales the plan's "
                         "death and page-squeeze counts")
    ap.add_argument("--ttl-ticks", type=int, default=None,
                    help="retire requests older than this many engine ticks "
                         "(graceful timeout instead of unbounded waiting)")
    ap.add_argument("--max-queue", type=int, default=None,
                    help="admission-queue cap; submits beyond it raise "
                         "EngineOverloaded (graceful backpressure)")
    ap.add_argument("--temperature", type=float, default=0.0,
                    help="sampling temperature (0 = greedy argmax)")
    ap.add_argument("--top-k", type=int, default=0,
                    help="top-k filter (0 = off)")
    ap.add_argument("--top-p", type=float, default=1.0,
                    help="nucleus top-p filter (1.0 = off)")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    cfg = get_config(args.arch)
    if args.smoke:
        cfg = cfg.smoke()
    model = build_model(cfg, ExecOptions(attn_impl="reference", ce_chunk=32))
    params = model.init(jax.random.key(args.seed))
    wdtype = args.wdtype or ("int8" if args.int8 else None)
    kv_dtype = args.kv_dtype or ("int8" if args.int8 else None)
    if cfg.family not in ("dense", "moe", "vlm", "encdec"):
        # recurrent families (ssm/hybrid) have no int8 engine datapath —
        # keep the old behavior: generic QDQ for int8 NUMERICS, f32 compute
        if wdtype == "int8":
            params = quantize_params_int8(params)
            wdtype = None
        kv_dtype = None if kv_dtype in ("int8", "bf16", "fp8") else kv_dtype
    if kv_dtype == "fp8" and args.page_size != 0:
        ap.error("--kv-dtype fp8 is dense-layout only (paged e5m2 pools are "
                 "a recorded follow-on); pass --page-size 0")
    fault_plan = None
    if args.fault_seed is not None:
        from repro.serve.faults import chaos_plan
        n_shards = args.shards or 1
        # single-host engines honor only the page events ("shard 0" of a
        # one-shard fleet), so chaos there is squeezes only
        # spread events over the run's expected tick span (decode ticks ≈
        # requests × new_tokens / slots, plus prefill) so they actually land
        n_ticks = max(16, args.requests * args.new_tokens
                      // max(1, args.slots) + 8)
        fault_plan = chaos_plan(
            args.fault_seed, n_shards=n_shards, n_ticks=n_ticks,
            deaths=max(1, round(args.fault_rate)) if n_shards > 1 else 0,
            death_dwell=max(2, n_ticks // 4),
            squeezes=max(1, round(3 * args.fault_rate)))
        print(f"[serve] fault plan seed={args.fault_seed}: "
              f"{fault_plan.counts()}")
    ft_kw = {"fault_plan": fault_plan, "ttl_ticks": args.ttl_ticks,
             "max_queue": args.max_queue}
    if args.shards:
        # the sharded engine is paged + chunked by construction — reject the
        # flags that name a different engine instead of reinterpreting them
        if args.page_size == 0:
            ap.error("--shards requires a paged cache; --page-size 0 (dense "
                     "rows) only exists on the single-host engine")
        if args.no_chunked_prefill:
            ap.error("--shards prefills in per-shard interleaved chunks; "
                     "--no-chunked-prefill only exists on the single-host "
                     "engine")
        from repro.launch.mesh import make_serve_mesh
        from repro.serve.sharded import ShardedServeEngine
        n_slots = args.slots
        if n_slots % args.shards:
            n_slots = args.shards * max(1, n_slots // args.shards)
            print(f"[serve] rounding slots to {n_slots} "
                  f"({args.shards} shards)")
        eng = ShardedServeEngine(
            model, mesh=make_serve_mesh(args.shards), n_slots=n_slots,
            max_len=args.max_len, params=params, wdtype=wdtype,
            kv_dtype=kv_dtype, page_size=args.page_size,
            n_pages=args.pages or None, chunk_pages=args.chunk_pages,
            prefix_cache=args.prefix_cache, migration=args.migration,
            rebalance_threshold=args.rebalance_threshold or None, **ft_kw)
    else:
        paged_kw = {"paged": False} if args.page_size == 0 else {
            "page_size": args.page_size,
            "n_pages": args.pages or None,
            "chunked_prefill": False if args.no_chunked_prefill else None,
            "chunk_pages": args.chunk_pages,
            "prefix_cache": args.prefix_cache,
        }
        eng = ServeEngine(model, n_slots=args.slots, max_len=args.max_len,
                          params=params, wdtype=wdtype, kv_dtype=kv_dtype,
                          **paged_kw, **ft_kw)
    sample = None if args.temperature == 0 else (
        args.temperature, args.top_k, args.top_p)
    rng = np.random.default_rng(args.seed)
    reqs = []
    for i in range(args.requests):
        plen = int(rng.integers(8, 24))
        prompt = rng.integers(0, cfg.vocab_size, plen).astype(np.int32)
        reqs.append(eng.submit(prompt, max_new_tokens=args.new_tokens,
                               sample_params=sample, seed=args.seed + i))
    t0 = time.time()
    stats = eng.run_to_completion()
    wall = time.time() - t0
    done = sum(r.done for r in reqs)
    ttft = [r.t_first_token - r.t_enqueue for r in reqs if r.t_first_token]
    print(f"[serve] {done}/{len(reqs)} done  {stats.summary()}")
    print(f"[serve] {stats.tokens_out / wall:.1f} tok/s  "
          f"mean TTFT {1e3 * sum(ttft) / len(ttft):.0f} ms  wall {wall:.1f}s")
    if args.shards:
        ss = eng.shard_summary()
        print(f"[serve] shards={args.shards}  "
              f"tokens/shard={ss['shard_tokens']}  "
              f"occupancy_imbalance={ss['occupancy_imbalance']:.3f}")
        s = stats
        print(f"[serve] migrations={s.migrations} "
              f"migrated_pages={s.migrated_pages} "
              f"wire_bytes={s.migrated_bytes_compressed:.0f} "
              f"rebalance_events={s.rebalance_events}")
    if args.fault_seed is not None or args.ttl_ticks is not None:
        s = stats
        print(f"[serve] faults={s.faults_injected} recoveries={s.recoveries} "
              f"preemptions={s.preemptions} retries={s.retries} "
              f"timeouts={s.timeouts} "
              f"mean_recovery_ticks={s.summary()['mean_recovery_ticks']:.1f}")
        hs = getattr(eng, "health_summary", lambda: None)()
        if hs is not None:
            print(f"[serve] shard health: {hs['state']}")


if __name__ == "__main__":
    main()
