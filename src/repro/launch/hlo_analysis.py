"""Compiled-HLO analysis: collective bytes, per-device cost, roofline terms.

collective_bytes is NOT in cost_analysis() — we parse the post-SPMD optimized
HLO (compiled.as_text()) and sum the *output* operand sizes of every
all-gather / all-reduce / reduce-scatter / all-to-all / collective-permute.
Sizes are per-device; ×chips gives the global collective traffic estimate
used by the ICI roofline term.
"""

from __future__ import annotations

import dataclasses
import re
from typing import Dict

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8, "c64": 8,
    "c128": 16, "f8e4m3fn": 1, "f8e5m2": 1, "s4": 1, "u4": 1,
}

_COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                "collective-permute")

# e.g.  %all-reduce.5 = bf16[128,1024]{1,0} all-reduce(...)
_OP_RE = re.compile(
    r"=\s*(?:\()?\s*([a-z0-9\[\],\s{}()]*?)\s*"
    r"(all-gather-start|all-gather|all-reduce-start|all-reduce|"
    r"reduce-scatter|all-to-all|collective-permute-start|collective-permute)"
    r"\(", re.IGNORECASE)

_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")


def _shape_bytes(shape_str: str) -> int:
    total = 0
    for dt, dims in _SHAPE_RE.findall(shape_str):
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def collective_bytes(hlo_text: str) -> Dict[str, float]:
    """Per-device bytes moved by each collective kind (output-size convention;
    '-start' variants counted once, '-done' skipped)."""
    out: Dict[str, float] = {k: 0.0 for k in _COLLECTIVES}
    counts: Dict[str, int] = {k: 0 for k in _COLLECTIVES}
    for m in _OP_RE.finditer(hlo_text):
        shape_str, op = m.group(1), m.group(2).lower()
        kind = op.replace("-start", "")
        b = _shape_bytes(shape_str)
        out[kind] += b
        counts[kind] += 1
    out["total"] = sum(out[k] for k in _COLLECTIVES)
    out["counts"] = counts  # type: ignore[assignment]
    return out


def while_trip_counts(hlo_text: str) -> Dict[str, int]:
    """Best-effort: collectives inside while loops execute trip_count times.
    XLA's optimized HLO unrolls nothing, so we scale loop-body collectives by
    the scan length when it is statically known from the induction bound."""
    # jax lax.scan lowers to while with a constant trip count visible as
    # s32[] constant(<N>) compared in the condition; robustly extracting it
    # per-loop is brittle, so we expose the raw text hook for callers.
    return {}


@dataclasses.dataclass(frozen=True)
class CellAnalysis:
    """Everything the roofline needs for one compiled cell (per-device)."""
    flops_per_dev: float
    bytes_per_dev: float
    coll_bytes_per_dev: float
    coll_breakdown: Dict[str, float]
    arg_bytes: int
    out_bytes: int
    temp_bytes: int
    peak_bytes: int
    generated_code_bytes: int


def analyze_compiled(compiled) -> CellAnalysis:
    ca = compiled.cost_analysis() or {}
    if isinstance(ca, (list, tuple)):  # older jax: one dict per device
        ca = ca[0] if ca else {}
    ma = compiled.memory_analysis()
    txt = compiled.as_text()
    coll = collective_bytes(txt)
    peak = (ma.argument_size_in_bytes + ma.output_size_in_bytes
            + ma.temp_size_in_bytes - ma.alias_size_in_bytes)
    return CellAnalysis(
        flops_per_dev=float(ca.get("flops", 0.0)),
        bytes_per_dev=float(ca.get("bytes accessed", 0.0)),
        coll_bytes_per_dev=float(coll["total"]),
        coll_breakdown={k: float(v) for k, v in coll.items()
                        if k != "counts"},
        arg_bytes=ma.argument_size_in_bytes,
        out_bytes=ma.output_size_in_bytes,
        temp_bytes=ma.temp_size_in_bytes,
        peak_bytes=peak,
        generated_code_bytes=ma.generated_code_size_in_bytes,
    )
