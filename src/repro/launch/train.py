"""End-to-end training driver (deliverable b: the runnable e2e example calls
this; real pods would launch the same file per host).

Wires every substrate layer together:
  data pipeline → jitted train step (mesh-sharded) → checkpoint manager
  (atomic, integrity-hashed, retention-k) → elastic heartbeat/straggler
  governor → resume-on-restart.

Usage (CPU, reduced config):
  PYTHONPATH=src python -m repro.launch.train --arch smollm-360m --smoke \
      --steps 200 --batch 8 --seq 128 --ckpt-dir /tmp/ckpt
"""

from __future__ import annotations

import argparse
import time

import jax

from repro.configs import get_config
from repro.configs.base import ShapeConfig
from repro.data.pipeline import DataConfig, PrefetchIterator, TokenSource
from repro.launch import steps as steps_mod
from repro.launch.mesh import make_host_mesh
from repro.models import build_model
from repro.parallel import sharding as sh
from repro.train import optimizer as opt_mod
from repro.train.checkpoint import CheckpointManager
from repro.train.elastic import ElasticPolicy, HeartbeatRegistry, plan_migration


def train_loop(*, arch: str, smoke: bool, steps: int, global_batch: int,
               seq_len: int, ckpt_dir: str, ckpt_every: int = 50,
               model_parallel: int = 1, peak_lr: float = 3e-4,
               log_every: int = 10, resume: bool = True, seed: int = 0,
               n_micro: int = 1, compress_grads: bool = False):
    cfg = get_config(arch)
    if smoke:
        cfg = cfg.smoke()
    mesh = make_host_mesh(model=model_parallel)
    cfg = steps_mod.arch_for_mesh(cfg, mesh)
    shape = ShapeConfig("train_loop", "train", seq_len, global_batch)
    opts = steps_mod.exec_options_for(cfg, shape, mesh,
                                      {"attn_impl": "reference",
                                       "ce_chunk": min(128, seq_len),
                                       "act_seq_shard": False,
                                       "moe_group": min(64, seq_len)})
    model = build_model(cfg, opts)
    opt_cfg = opt_mod.OptimizerConfig(peak_lr=peak_lr, warmup_steps=20,
                                      total_steps=steps)

    grad_transform = None
    if compress_grads:
        from repro.train import compression
        grad_transform = lambda g: compression.compress_decompress(g)[0]  # noqa: E731

    step_fn = steps_mod.make_train_step(model, opt_cfg,
                                        grad_transform=grad_transform,
                                        n_micro=n_micro)
    state_specs = steps_mod.train_state_specs(model, mesh)
    state_shardings = sh.named(mesh, state_specs)
    jitted = jax.jit(step_fn, in_shardings=(state_shardings, None),
                     out_shardings=(state_shardings, None),
                     donate_argnums=(0,))

    mgr = CheckpointManager(ckpt_dir)
    start_step = 0
    if resume and mgr.latest_step() is not None:
        template = steps_mod.abstract_train_state(model)
        state, manifest = mgr.restore(template, shardings=state_shardings)
        start_step = manifest["step"] + 1
        print(f"[train] resumed from step {manifest['step']} "
              f"(root {manifest['root_hash'][:12]}…)", flush=True)
    else:
        params = model.init(jax.random.key(seed))
        state = {"params": params,
                 "opt": opt_mod.init_opt_state(params)}

    data_cfg = DataConfig(vocab_size=cfg.vocab_size, seq_len=seq_len,
                          global_batch=global_batch, seed=seed)
    it = PrefetchIterator(TokenSource(data_cfg), start_step=start_step)
    registry = HeartbeatRegistry(n_hosts=1, policy=ElasticPolicy())

    losses = []
    t_last = time.time()
    try:
        for step, batch in it:
            if step >= steps:
                break
            state, metrics = jitted(state, batch)
            dt = time.time() - t_last
            t_last = time.time()
            registry.beat(0, step_time_s=dt)
            losses.append(float(metrics["loss"]))
            decision = plan_migration(registry)
            if decision.kind != "none":
                print(f"[elastic] {decision.kind}: {decision.reason}", flush=True)
            if step % log_every == 0:
                print(f"[train] step {step:5d} loss {losses[-1]:.4f} "
                      f"gnorm {float(metrics['grad_norm']):.3f} "
                      f"lr {float(metrics['lr']):.2e} {dt*1e3:.0f} ms", flush=True)
            if ckpt_every and step and step % ckpt_every == 0:
                path = mgr.save(step, state, extra={"loss": losses[-1]})
                print(f"[ckpt] saved {path}", flush=True)
    finally:
        it.close()
    if losses:
        mgr.save(min(steps - 1, start_step + len(losses) - 1), state,
                 extra={"loss": losses[-1]})
    return losses, state


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="smollm-360m")
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_ckpt")
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--model-parallel", type=int, default=1)
    ap.add_argument("--n-micro", type=int, default=1)
    ap.add_argument("--compress-grads", action="store_true")
    ap.add_argument("--lr", type=float, default=3e-4)
    args = ap.parse_args()
    losses, _ = train_loop(
        arch=args.arch, smoke=args.smoke, steps=args.steps,
        global_batch=args.batch, seq_len=args.seq, ckpt_dir=args.ckpt_dir,
        ckpt_every=args.ckpt_every, model_parallel=args.model_parallel,
        peak_lr=args.lr, n_micro=args.n_micro,
        compress_grads=args.compress_grads)
    print(f"[train] done. loss {losses[0]:.3f} → {losses[-1]:.3f} "
          f"over {len(losses)} steps")


if __name__ == "__main__":
    main()
