"""Deterministic sharded data pipeline with background prefetch.

Synthetic + memory-mapped binary token sources behind one iterator:
  * per-host sharding: host h of H reads example stream indices ≡ h (mod H)
  * deterministic: (seed, step) → batch, independent of restart point, so
    checkpoint/resume replays the exact stream (fault-tolerance invariant,
    tested in tests/test_data.py)
  * double-buffered prefetch thread keeps the accelerator fed.
"""

from __future__ import annotations

import dataclasses
import queue
import threading
from typing import Dict, Iterator, Optional

import numpy as np


@dataclasses.dataclass(frozen=True)
class DataConfig:
    vocab_size: int
    seq_len: int
    global_batch: int
    host_id: int = 0
    n_hosts: int = 1
    seed: int = 0
    path: Optional[str] = None     # memmapped .bin of uint16/uint32 tokens
    token_dtype: str = "uint16"

    @property
    def host_batch(self) -> int:
        assert self.global_batch % self.n_hosts == 0
        return self.global_batch // self.n_hosts


class TokenSource:
    """step → (host_batch, seq_len+1) tokens, deterministically."""

    def __init__(self, cfg: DataConfig):
        self.cfg = cfg
        self._mm = None
        if cfg.path:
            self._mm = np.memmap(cfg.path, dtype=cfg.token_dtype, mode="r")
            self._n_tokens = self._mm.shape[0]

    def batch_at(self, step: int) -> Dict[str, np.ndarray]:
        cfg = self.cfg
        # global example index space: step-major, host-sharded
        base = step * cfg.global_batch + cfg.host_id * cfg.host_batch
        idx = base + np.arange(cfg.host_batch, dtype=np.int64)
        if self._mm is not None:
            toks = self._window_from_file(idx)
        else:
            toks = self._synthetic(idx)
        return {"tokens": toks[:, :-1].astype(np.int32),
                "labels": toks[:, 1:].astype(np.int32)}

    def _synthetic(self, idx: np.ndarray) -> np.ndarray:
        cfg = self.cfg
        out = np.empty((len(idx), cfg.seq_len + 1), np.int64)
        for r, i in enumerate(idx):
            rng = np.random.Generator(np.random.Philox(key=cfg.seed, counter=i))
            # zipf-ish synthetic text: heavy-tailed token distribution
            u = rng.random(cfg.seq_len + 1)
            out[r] = (cfg.vocab_size * u ** 3).astype(np.int64) % cfg.vocab_size
        return out

    def _window_from_file(self, idx: np.ndarray) -> np.ndarray:
        cfg = self.cfg
        span = cfg.seq_len + 1
        n_windows = (self._n_tokens - 1) // span
        out = np.empty((len(idx), span), np.int64)
        for r, i in enumerate(idx):
            w = int(i % n_windows)
            out[r] = np.asarray(self._mm[w * span:(w + 1) * span], np.int64)
        return out


class PrefetchIterator:
    """Background-thread double buffering over a TokenSource."""

    def __init__(self, source: TokenSource, start_step: int = 0, depth: int = 2):
        self.source = source
        self.step = start_step
        self.q: "queue.Queue" = queue.Queue(maxsize=depth)
        self._stop = threading.Event()
        self._thread = threading.Thread(target=self._worker, daemon=True)
        self._thread.start()

    def _worker(self):
        s = self.step
        while not self._stop.is_set():
            batch = self.source.batch_at(s)
            while not self._stop.is_set():
                try:
                    self.q.put((s, batch), timeout=0.1)
                    break
                except queue.Full:
                    continue
            s += 1

    def __iter__(self) -> Iterator:
        return self

    def __next__(self):
        step, batch = self.q.get()
        self.step = step + 1
        return step, batch

    def close(self):
        self._stop.set()
        try:
            while True:
                self.q.get_nowait()
        except queue.Empty:
            pass
        self._thread.join(timeout=2)
