"""Per-shard health state machine driven by chiplet sensor readings (PR 6).

The paper's §II serving-side story — sensor-driven load migration,
power/thermal-aware management — only matters when a chiplet can actually
stall, overheat or starve. This module is the serving-side consumer of those
sensors: each shard (one NPU chiplet) is one RC node in `core/thermal`'s
compact model, its serving occupancy rides through `core/dvfs`'s P-state
controller as the load demand, and the resulting *predicted* temperature
(`core/thermal.predict` — the same extrapolated reading the simulator's
migration policy uses) drives a five-state machine:

    HEALTHY ──hot──▶ DEGRADED ──sustained hot──▶ DRAINING ─┐
       ▲                 │cool                      │      │death
       │                 ▼                          ▼      ▼
       └──cooldown── REJOINING ◀──rejoin fault──── DEAD ◀──┘

  * HEALTHY   — in placement.
  * DEGRADED  — sensor hot: new admissions avoid the shard, existing slots
    keep decoding (soft avoidance). Cools back to HEALTHY.
  * DRAINING  — sustained hot (or an injected stall): the shard's pool
    bytes are still alive, so the engine re-homes every live slot by LIVE
    PAGE MIGRATION over the modeled UCIe link (serve/migration — O(bytes),
    no re-prefill), falling back to re-prefill replay for slots that fit
    nowhere; once cool, the shard returns to HEALTHY through REJOINING's
    cooldown.
  * DEAD      — hard failure (fault-injected): the pool bytes are GONE, so
    slots recover by re-prefill replay only; the shard is inert until a
    rejoin event.
  * REJOINING — free list has been reset; after `rejoin_ticks` the shard
    re-enters placement.

Transitions are deterministic functions of (occupancy history, injected
sensor biases): the thermal/DVFS math is jitted once and stepped per engine
tick, so a seeded `FaultPlan` replays the same transition schedule
bit-for-bit. Token streams are schedule-independent (PR 4), so none of this
can change WHAT a request generates — only where and when.
"""

from __future__ import annotations

import dataclasses
import enum
from typing import Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import dvfs as dvfs_mod
from repro.core import thermal as thermal_mod


class Health(enum.Enum):
    HEALTHY = "healthy"
    DEGRADED = "degraded"
    DRAINING = "draining"
    DEAD = "dead"
    REJOINING = "rejoining"


# states the scheduler may place new work on
PLACEABLE = (Health.HEALTHY,)
# states whose live slots must be recovered onto other shards
EVACUATED = (Health.DRAINING, Health.DEAD)


@dataclasses.dataclass(frozen=True)
class HealthConfig:
    degrade_after: int = 1     # consecutive hot sensor ticks → DEGRADED
    drain_after: int = 3       # consecutive hot ticks → DRAINING (migrate off)
    cool_after: int = 2        # consecutive cool ticks → leave DEGRADED/DRAINING
    rejoin_ticks: int = 2      # REJOINING dwell before placement resumes
    tick_ms: float = 1.0       # engine tick, for the RC/DVFS integration
    # power model per shard-chiplet; sized so full serving occupancy stays
    # comfortably below t_migrate without an injected sensor fault — only a
    # hot/stuck sensor (FaultPlan) or a genuinely pathological thermal
    # config degrades a shard
    peak_dyn_mw: float = 400.0
    static_mw: float = 40.0
    r_k_per_w: float = 60.0    # junction->ambient resistance per chiplet
    c_j_per_k: float = 0.005


class ShardHealthMonitor:
    """Holds the per-shard thermal/DVFS state and the health machine.

    `step(occupancy)` advances one engine tick and returns the transitions
    that fired; the engine reacts to entries into DRAINING/DEAD (recover the
    shard's live slots) and reads `placeable()` for the scheduler."""

    def __init__(self, n_shards: int, cfg: Optional[HealthConfig] = None):
        self.n = n_shards
        self.cfg = cfg or HealthConfig()
        self.state: List[Health] = [Health.HEALTHY] * n_shards
        self._hot = np.zeros((n_shards,), np.int32)   # consecutive hot ticks
        self._cool = np.zeros((n_shards,), np.int32)  # consecutive cool ticks
        self._rejoin_at: Dict[int, int] = {}          # shard -> healthy tick
        self._bias_c = np.zeros((n_shards,), np.float64)
        self._bias_until = np.zeros((n_shards,), np.int64)
        self._tick = 0
        c = self.cfg
        self._tcfg = thermal_mod.ThermalConfig(
            r_k_per_w=(c.r_k_per_w,) * n_shards,
            c_j_per_k=(c.c_j_per_k,) * n_shards)
        self._dcfg = dvfs_mod.DVFSConfig()
        self._tstate = thermal_mod.init_state(self._tcfg)
        self._dstate = dvfs_mod.init_state(n_shards, self._dcfg)
        peak, static = dvfs_mod.uniform_power_model(
            n_shards, c.peak_dyn_mw, c.static_mw)
        npu_mask = jnp.ones((n_shards,), bool)

        def _sense(dstate, tstate, load):
            # occupancy → P-state/power (core/dvfs) → RC node heat + the
            # extrapolated sensor reading (core/thermal.predict)
            dstate, (freq, power_mw, _) = dvfs_mod.step(
                dstate, load, self._dcfg, peak, static, c.tick_ms)
            predicted = thermal_mod.predict(tstate, power_mw, self._tcfg,
                                            c.tick_ms)
            tstate, (clock, _) = thermal_mod.step(
                tstate, power_mw, npu_mask, load, self._tcfg, c.tick_ms)
            return dstate, tstate, predicted, freq * clock

        self._sense = jax.jit(_sense)
        self.sensor_c = np.full((n_shards,), self._tcfg.t_ambient_c)
        self.clock_scale = np.ones((n_shards,))

    # --------------------------------------------------------------- injection
    def inject_sensor(self, shard: int, delta_c: float, ticks: int) -> None:
        """A hot/stuck sensor: bias the shard's reading for `ticks` ticks."""
        self._bias_c[shard] = delta_c
        self._bias_until[shard] = self._tick + max(1, ticks)

    def force_dead(self, shard: int) -> bool:
        """Hard shard failure. Returns True if the shard held recoverable
        state (was not already dead)."""
        was = self.state[shard]
        self.state[shard] = Health.DEAD
        self._hot[shard] = self._cool[shard] = 0
        return was != Health.DEAD

    def begin_rejoin(self, shard: int) -> bool:
        """Dead shard comes back: REJOINING for `rejoin_ticks`, then
        HEALTHY. No-op unless the shard is DEAD."""
        if self.state[shard] != Health.DEAD:
            return False
        self.state[shard] = Health.REJOINING
        self._rejoin_at[shard] = self._tick + self.cfg.rejoin_ticks
        return True

    # -------------------------------------------------------------------- step
    def step(self, occupancy: np.ndarray) -> List[Tuple[int, Health, Health]]:
        """One tick: integrate sensors from per-shard occupancy, then run
        the state machine. Returns [(shard, old, new)] transitions."""
        self._tick += 1
        load = jnp.asarray(np.clip(occupancy, 0.0, 1.0), jnp.float32)
        self._dstate, self._tstate, predicted, clock = self._sense(
            self._dstate, self._tstate, load)
        bias = np.where(self._bias_until >= self._tick, self._bias_c, 0.0)
        self.sensor_c = np.asarray(predicted, np.float64) + bias
        self.clock_scale = np.asarray(clock, np.float64)
        hot = self.sensor_c > self._tcfg.t_migrate_c
        self._hot = np.where(hot, self._hot + 1, 0).astype(np.int32)
        self._cool = np.where(~hot, self._cool + 1, 0).astype(np.int32)

        out: List[Tuple[int, Health, Health]] = []

        def move(shard: int, new: Health):
            out.append((shard, self.state[shard], new))
            self.state[shard] = new

        cfg = self.cfg
        for s in range(self.n):
            st = self.state[s]
            if st == Health.HEALTHY and self._hot[s] >= cfg.degrade_after:
                move(s, Health.DEGRADED)
                st = Health.DEGRADED
            if st == Health.DEGRADED:
                if self._hot[s] >= cfg.drain_after:
                    move(s, Health.DRAINING)
                elif self._cool[s] >= cfg.cool_after:
                    move(s, Health.HEALTHY)
            elif st == Health.DRAINING:
                if self._cool[s] >= cfg.cool_after:
                    # drained and cool: come back through the rejoin cooldown
                    move(s, Health.REJOINING)
                    self._rejoin_at[s] = self._tick + cfg.rejoin_ticks
            elif st == Health.REJOINING \
                    and self._tick >= self._rejoin_at.get(s, self._tick):
                move(s, Health.HEALTHY)
        return out

    # ------------------------------------------------------------------- views
    def placeable(self) -> List[bool]:
        return [st in PLACEABLE for st in self.state]

    def n_placeable(self) -> int:
        return sum(self.placeable())

    def summary(self) -> Dict[str, object]:
        return {"state": [st.value for st in self.state],
                "sensor_c": [round(float(t), 2) for t in self.sensor_c],
                "clock_scale": [round(float(s), 3)
                                for s in self.clock_scale]}
