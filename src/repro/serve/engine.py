"""Serving engine: prefill + continuous-batching decode.

The "AI-optimized" configuration of the paper, as a serving runtime:
  * slot-based continuous batching: a fixed decode batch of N slots; finished
    requests free their slot, queued requests prefill into it (their KV/state
    pasted into the slot's cache rows) while other slots keep decoding.
  * int8 weight-only path (kernels/int8_matmul) — the 15 TOPS INT8 NPU
    datapath — available to the serve example/benches via `quantize_params`.
  * the faithful chiplet perf model (core/) prices batching decisions the way
    the paper's CPU chiplet dispatches to its two NPUs (see benches).

INT8 serving configuration (PR 3 — the paper's 15 TOPS INT8 datapath as the
measured serving numerics):
  * `wdtype="int8"`: weight-only int8 — the params pytree's projection
    weights become (int8, per-output-channel f32 scale) leaves via
    `models.quantized.quantize_params`; every projection einsum in the
    prefill/decode steps dispatches through `qeinsum` (Pallas int8_matmul on
    TPU, jnp dequant-matmul reference elsewhere; MoE experts quantized per
    expert). Halves weight HBM traffic per decode step — the bound at small
    batch.
  * `kv_dtype="int8"`: K/V stored int8 with per-(token, kv head) f16 dequant
    scales ('ks'/'vs' tensors riding next to 'k'/'v' in either cache
    layout). Quantization happens at write time (prefill paste + decode
    write); dequant is fused into the decode-attention kernel's K/V tile
    loads, so cache bytes/token drop ~2× vs bf16 (~(D+2)/2D) on top of the
    paged pool's live-token scaling. The quantized bytes are identical in
    the dense and paged layouts, so an int8 paged engine is token-exact
    against the dense int8 oracle — the equivalence the tests pin. encdec
    cross K/V stay f32 (written once; see encdec.cache_shape).
  * `kv_dtype="bf16"` is also accepted (the comparison baseline the int8
    serve bench reports its byte-shrink against).

Sliding-window paged slots (PR 3): window-attention configs (cfg.window > 0)
hold O(window) pages instead of O(position): admission reserves only
ceil(window/page)+2 pages past the live floor, and every tick the engine
frees pages that fell fully out of the attention window — remapping them to
the slot's next logical page (zero pool traffic) or returning them to the
free list once the request's span is covered. Out-of-window prompt pages are
never backed at all (their paste rows land on the null page, which the
window mask already makes unreadable).

Cache layout (PR 2 — paged KV):
  * Attention families default to a PAGED KV cache: one shared page pool of
    (n_layers, n_pages, page_size, KV, D) K/V blocks plus a per-slot
    (n_slots, max_len // page_size) page table. Physical page 0 is the NULL
    page — never allocated, it absorbs writes from retired slots and backs
    unmapped table entries so every gather/DMA has a valid source. Admission
    reserves ceil(min(max_len, prompt + max_new) / page_size) pages up front
    (so a request can never starve mid-decode) and retirement returns them to
    the free list and re-points the slot's table row at the null page. When
    the free list can't cover the queue head, admission waits — the pool is
    the admission controller. Peak KV memory therefore scales with LIVE
    tokens, not n_slots × max_len: long-context engines no longer reserve the
    worst case per slot (paper §serving: 16 GB HBM3 + streaming block-granular
    UCIe transfers — a page is one FLIT-sized stream unit).
  * `paged=False` keeps the dense per-slot (n_slots, max_len) rows — the
    oracle configuration for equivalence tests (`generate_greedy` runs it).
  * ssm/hybrid families keep their O(1) dense recurrent state; paging does
    not apply.

Fast-path design (PR 1):
  * power-of-two prompt bucketing — prefill compiles once per bucket, not once
    per distinct prompt length, so compile count is O(log max_len) in steady
    state. Padded prefills are made exact by *replaying* the last prompt token
    through the decode step (causal attention leaves rows [0, plen) untouched
    by trailing pads; the replay recomputes position plen-1 and yields the
    first output token from the shared decode path). Recurrent families
    (ssm/hybrid) carry their state through padding, so they keep exact-length
    prefill.
  * the KV cache is donated through the decode jit (in-place update instead of
    a full-cache copy per step) and through the jitted slot-paste program.
  * slot pastes run as ONE jitted scatter program per family instead of a
    Python chain of `.at[].set()` dispatches.
  * `pos` is fetched from device once per step (one host sync), not once per
    active slot.
  * freed slots are masked out of the batched decode step: an `active` mask
    freezes their stream position, so an idle tick is a no-op per freed slot
    (their stale-token writes land on the null page / an overwritten dense
    row, and `pos` cannot drift past the cache).

Pure-python orchestration over jitted model fns; runs on CPU for tests and
examples, mesh-parameterized for pods.
"""

from __future__ import annotations

import dataclasses
import math
import time
from typing import Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.models.quantized import quantize_kv_rows

_ATTN_FAMILIES = ("dense", "moe", "vlm", "encdec")

_KV_DTYPES = {None: jnp.float32, "f32": jnp.float32, "float32": jnp.float32,
              "bf16": jnp.bfloat16, "bfloat16": jnp.bfloat16,
              "int8": jnp.int8}


def bucket_length(plen: int, max_len: int) -> int:
    """Next power of two ≥ plen, clipped to max_len."""
    b = 1
    while b < plen:
        b <<= 1
    return min(b, max_len)


@dataclasses.dataclass
class Request:
    rid: int
    prompt: np.ndarray                  # (prompt_len,) int32
    max_new_tokens: int = 16
    # extra prefill inputs (e.g. encdec 'frames': (S_enc, d_model)); batched
    # with a leading axis of 1 at admission
    extras: Optional[Dict[str, np.ndarray]] = None
    out_tokens: List[int] = dataclasses.field(default_factory=list)
    done: bool = False
    t_enqueue: float = 0.0
    t_first_token: Optional[float] = None
    t_done: Optional[float] = None


@dataclasses.dataclass
class EngineStats:
    prefills: int = 0
    decode_steps: int = 0
    tokens_out: int = 0
    occupancy_sum: float = 0.0
    prefill_compiles: int = 0   # actual jit traces (bucketing keeps this flat)
    decode_compiles: int = 0
    paste_compiles: int = 0
    pages_in_use: int = 0       # paged engines: currently reserved pages
    peak_pages_in_use: int = 0

    def summary(self) -> Dict[str, float]:
        d = dataclasses.asdict(self)
        # always emitted: an engine that only prefilled has no decode steps,
        # and bench/report consumers index this key unconditionally
        d["mean_occupancy"] = (self.occupancy_sum / self.decode_steps
                               if self.decode_steps else 0.0)
        return d


def _make_paste(fam: str):
    """One jitted scatter program per family: copy request-0's prefill cache
    into engine-cache slot `slot` and stamp the slot's stream position `pos`.

    Row counts come from the prefill cache's static shapes, so the program
    retraces once per prefill bucket, not per request. The engine cache is
    donated — the paste updates in place instead of copying every tensor.
    """

    def paste(cache, pf, slot, pos):
        c = dict(cache)
        if fam in _ATTN_FAMILIES:
            plen = pf["k"].shape[2]
            int8_kv = "ks" in c
            for key in ("k", "v"):
                if int8_kv:
                    # quantize prompt rows per (position, kv head) — the same
                    # map the decode write path applies, so dense and paged
                    # int8 caches hold identical bytes
                    qr, sr = quantize_kv_rows(pf[key][:, 0, :plen])
                    c[key] = c[key].at[:, slot, :plen].set(qr)
                    c[key + "s"] = c[key + "s"].at[:, slot, :plen].set(sr)
                else:
                    c[key] = c[key].at[:, slot, :plen].set(
                        pf[key][:, 0, :plen].astype(c[key].dtype))
            for key in ("ck", "cv"):
                if key in c:
                    c[key] = c[key].at[:, slot].set(
                        pf[key][:, 0].astype(c[key].dtype))
        elif fam == "ssm":
            c["h"] = c["h"].at[:, slot].set(pf["h"][:, 0])
            c["conv"] = {
                k: c["conv"][k].at[:, slot].set(
                    pf["conv"][k][:, 0].astype(c["conv"][k].dtype))
                for k in c["conv"]}
        elif fam == "hybrid":
            new_layers = []
            for dst, src in zip(c["layers"], pf["layers"]):
                new_layers.append({
                    k: dst[k].at[slot].set(src[k][0].astype(dst[k].dtype))
                    for k in dst})
            c["layers"] = new_layers
        else:
            raise ValueError(f"unknown family {fam!r}")
        c["pos"] = c["pos"].at[slot].set(pos)
        return c

    return paste


def _make_paste_paged(fam: str):
    """Paged paste: scatter the dense prefill rows page-by-page into the
    shared pool and stamp the slot's page-table row.

    `page_row` is the slot's full (pages_per_seq,) table row — reserved
    physical pages first, null page (0) for the rest. Prefill-bucket pad rows
    that spill past the reservation land on the null page; pad rows inside it
    sit at logical positions ≥ kv_len, masked until decode overwrites them —
    the same invariant the dense replay path relies on."""
    assert fam in _ATTN_FAMILIES, fam

    def paste(cache, pf, slot, pos, page_row):
        c = dict(cache)
        ps = c["k"].shape[2]
        blen = pf["k"].shape[2]
        n_prompt_pages = -(-blen // ps)    # static per prefill bucket
        int8_kv = "ks" in c
        for key in ("k", "v"):
            pool = c[key]
            if int8_kv:
                qrows, srows = quantize_kv_rows(pf[key][:, 0])  # (L,blen,KV,·)
                spool = c[key + "s"]
            for j in range(n_prompt_pages):
                rows = min(ps, blen - j * ps)
                if int8_kv:
                    pool = pool.at[:, page_row[j], :rows].set(
                        qrows[:, j * ps:j * ps + rows])
                    spool = spool.at[:, page_row[j], :rows].set(
                        srows[:, j * ps:j * ps + rows])
                else:
                    src = pf[key][:, 0, j * ps:j * ps + rows].astype(pool.dtype)
                    pool = pool.at[:, page_row[j], :rows].set(src)
            c[key] = pool
            if int8_kv:
                c[key + "s"] = spool
        for key in ("ck", "cv"):           # encdec cross K/V stay dense
            if key in c:
                c[key] = c[key].at[:, slot].set(
                    pf[key][:, 0].astype(c[key].dtype))
        c["page_table"] = c["page_table"].at[slot].set(page_row)
        c["pos"] = c["pos"].at[slot].set(pos)
        return c

    return paste


class ServeEngine:
    def __init__(self, model, *, n_slots: int = 4, max_len: int = 128,
                 params=None, bucket_prompts: bool = True,
                 paged: Optional[bool] = None, page_size: int = 32,
                 n_pages: Optional[int] = None,
                 wdtype: Optional[str] = None,
                 kv_dtype: Optional[str] = None):
        self.model = model
        self.cfg = model.cfg
        self.n_slots = n_slots
        self.max_len = max_len
        if wdtype not in (None, "bf16", "int8"):
            raise ValueError(f"wdtype must be None/'bf16'/'int8', got {wdtype!r}")
        if wdtype == "int8":
            if self.cfg.family not in _ATTN_FAMILIES:
                raise ValueError(
                    f"wdtype='int8' applies to attention families, not "
                    f"{self.cfg.family!r}")
            from repro.models.quantized import quantize_params
            params = quantize_params(params, self.cfg)
        elif wdtype == "bf16":
            params = jax.tree.map(
                lambda p: p.astype(jnp.bfloat16)
                if jnp.issubdtype(p.dtype, jnp.floating) else p, params)
        self.wdtype = wdtype
        if kv_dtype not in _KV_DTYPES:
            raise ValueError(f"unknown kv_dtype {kv_dtype!r}")
        self.kv_dtype = _KV_DTYPES[kv_dtype]
        if self.kv_dtype != jnp.float32 \
                and self.cfg.family not in _ATTN_FAMILIES:
            raise ValueError(
                f"kv_dtype={kv_dtype!r} applies to attention-family KV "
                f"caches, not {self.cfg.family!r} recurrent state")
        self.params = params
        self.stats = EngineStats()
        self._queue: List[Request] = []
        self._slots: List[Optional[Request]] = [None] * n_slots
        self._fresh: List[bool] = [False] * n_slots  # replaying last prompt tok
        self._active = np.zeros((n_slots,), bool)
        self._next_rid = 0
        # Padded prefill + replay is only exact when trailing pads cannot
        # reach earlier positions — true for causal-attention KV caches, false
        # for recurrent state (ssm/hybrid), which keeps exact-length prefill.
        self._replay = self.cfg.family in _ATTN_FAMILIES
        self.bucket_prompts = bucket_prompts and self._replay
        if paged and self.cfg.family not in _ATTN_FAMILIES:
            raise ValueError(
                f"paged KV applies to attention families, not {self.cfg.family!r}")
        self.paged = (self.cfg.family in _ATTN_FAMILIES) if paged is None \
            else bool(paged)
        if self.paged and max_len % page_size != 0:
            if paged is None:
                # auto mode must not reject a max_len the dense engine took:
                # shrink to the largest compatible page size, or go dense if
                # pages would degenerate below 8 rows
                fit = math.gcd(min(page_size, max_len), max_len)
                if fit >= 8 or fit == max_len:
                    page_size = fit
                else:
                    self.paged = False
            else:
                raise ValueError(
                    f"max_len {max_len} is not a multiple of page_size "
                    f"{page_size}")
        # sliding-window page recycling: attention configs with a window hold
        # O(window) live pages — out-of-window pages are freed mid-flight.
        # (encdec self-attention ignores cfg.window, so it stays full-span.)
        self._window = self.cfg.window \
            if self.paged and self.cfg.family != "encdec" else 0
        if self.paged:
            self.page_size = page_size
            self.pages_per_seq = max_len // page_size
            # page 0 is the reserved null page
            self.n_pages = (1 + n_slots * self.pages_per_seq
                            if n_pages is None else n_pages)
            assert self.n_pages >= 2, self.n_pages
            self._free_pages = list(range(self.n_pages - 1, 0, -1))
            # logical page index -> physical page, per slot
            self._slot_pages: List[Dict[int, int]] = [
                {} for _ in range(n_slots)]
            # highest logical page the request may ever write (exclusive)
            self._slot_cap = [0] * n_slots
        # donation is unimplemented on CPU (harmless but warns per compile)
        donate = {} if jax.default_backend() == "cpu" else \
            {"donate_argnums": (2,)}
        paste_donate = {} if jax.default_backend() == "cpu" else \
            {"donate_argnums": (0,)}

        # Replay admissions discard prefill logits — use the cache-only
        # prefill (no LM-head matmul) when the family provides one.
        cache_only = self._replay and model.prefill_cache is not None

        def _prefill(params, batch):
            self.stats.prefill_compiles += 1   # runs at trace time only
            if cache_only:
                return None, model.prefill_cache(params, batch)
            return model.prefill(params, batch)

        def _decode(params, batch, cache, active):
            self.stats.decode_compiles += 1
            logits, new_cache = model.decode(params, batch, cache)
            # freeze freed slots' stream position: their garbage advance would
            # otherwise drift past max_len tick by tick (idle tick == no-op)
            new_cache["pos"] = jnp.where(active, new_cache["pos"],
                                         cache["pos"])
            return logits, new_cache

        if self.paged:
            def _paste(cache, pf, slot, pos, page_row):
                self.stats.paste_compiles += 1
                return _make_paste_paged(self.cfg.family)(
                    cache, pf, slot, pos, page_row)

            def _unmap(cache, slot):
                # retired slot: point its whole table row at the null page so
                # freed physical pages can be re-issued without aliasing
                return dict(cache, page_table=cache["page_table"]
                            .at[slot].set(0))

            def _remap_entry(cache, slot, j_dead, j_new, phys):
                # window recycling: a page that fell out of the attention
                # window becomes the slot's next logical page (its stale rows
                # sit at positions >= kv_len until overwritten — masked, the
                # same invariant pad rows rely on)
                pt = cache["page_table"].at[slot, j_dead].set(0)
                return dict(cache, page_table=pt.at[slot, j_new].set(phys))

            def _unmap_entry(cache, slot, j_dead):
                return dict(cache, page_table=cache["page_table"]
                            .at[slot, j_dead].set(0))

            self._unmap_jit = jax.jit(_unmap, **paste_donate)
            self._remap_entry_jit = jax.jit(_remap_entry, **paste_donate)
            self._unmap_entry_jit = jax.jit(_unmap_entry, **paste_donate)
        else:
            def _paste(cache, pf, slot, pos):
                self.stats.paste_compiles += 1
                return _make_paste(self.cfg.family)(cache, pf, slot, pos)

        self._prefill_jit = jax.jit(_prefill)
        self._decode_jit = jax.jit(_decode, **donate)
        self._paste_jit = jax.jit(_paste, **paste_donate)
        self._next_tok = np.zeros((n_slots, 1), np.int32)
        if self.paged:
            abs_cache = model.cache_shape(n_slots, max_len, self.kv_dtype,
                                          page_size=self.page_size,
                                          n_pages=self.n_pages)
        else:
            abs_cache = model.cache_shape(n_slots, max_len, self.kv_dtype)
        self._cache = jax.tree.map(
            lambda s: jnp.zeros(s.shape, s.dtype), abs_cache)

    # ------------------------------------------------------------- lifecycle
    def submit(self, prompt: np.ndarray, max_new_tokens: int = 16,
               extras: Optional[Dict[str, np.ndarray]] = None) -> Request:
        prompt = np.asarray(prompt, np.int32)
        assert 1 <= prompt.shape[0] <= self.max_len, prompt.shape
        assert max_new_tokens >= 1, max_new_tokens
        if self.paged:
            need = self._pages_for(prompt.shape[0], max_new_tokens)
            if need > self.n_pages - 1:
                raise ValueError(
                    f"request needs {need} pages; pool has {self.n_pages - 1}")
        self._next_rid += 1
        req = Request(rid=self._next_rid, prompt=prompt,
                      max_new_tokens=max_new_tokens, extras=extras,
                      t_enqueue=time.time())
        self._queue.append(req)
        return req

    def _pages_for(self, plen: int, max_new: int) -> int:
        """Pages reserved at admission: every row the request can ever write
        (prompt + generated, one row per generated token, capacity-capped).

        Window configs reserve only the live span: pages below the attention
        window's floor are never backed, and ceil(window/page)+2 pages are
        enough to slide the window to the end of the request (out-of-window
        pages are recycled forward every tick — see `_recycle_window_pages`),
        so occupancy is O(window), not O(position)."""
        rows = min(self.max_len, plen + max_new)
        full = -(-rows // self.page_size)
        if not self._window:
            return full
        return min(full - self._live_lo(plen), self._window_pages())

    def _live_lo(self, plen: int) -> int:
        """First logical page a window request can still read or write at its
        first decode step (the replay writes position plen-1)."""
        return max(0, plen - 1 - self._window) // self.page_size

    def _window_pages(self) -> int:
        """Mapped pages that always cover [pos-window, pos] plus one page of
        write-ahead slack while the window slides."""
        return (self._window - 1) // self.page_size + 3

    def kv_cache_bytes(self) -> int:
        return sum(x.size * x.dtype.itemsize
                   for x in jax.tree.leaves(self._cache))

    def _admit(self):
        """Prefill queued requests into free slots.

        Paged engines additionally reserve the request's worst-case page
        count up front; if the free list can't cover the queue head, admission
        stalls (FIFO — no small-request overtaking) until retirements return
        pages."""
        for slot in [i for i, r in enumerate(self._slots) if r is None]:
            if not self._queue:
                return
            r = self._queue[0]
            plen = r.prompt.shape[0]
            page_row = None
            if self.paged:
                need = self._pages_for(plen, r.max_new_tokens)
                if len(self._free_pages) < need:
                    return
                pages = [self._free_pages.pop() for _ in range(need)]
                lo = self._live_lo(plen) if self._window else 0
                self._slot_pages[slot] = {lo + i: p
                                          for i, p in enumerate(pages)}
                self._slot_cap[slot] = -(-min(self.max_len,
                                              plen + r.max_new_tokens)
                                         // self.page_size)
                self.stats.pages_in_use += need
                self.stats.peak_pages_in_use = max(
                    self.stats.peak_pages_in_use, self.stats.pages_in_use)
                page_row = np.zeros((self.pages_per_seq,), np.int32)
                page_row[lo:lo + need] = pages
            self._queue.pop(0)
            blen = bucket_length(plen, self.max_len) if self.bucket_prompts \
                else plen
            toks = np.zeros((1, blen), np.int32)
            toks[0, :plen] = r.prompt
            batch = {"tokens": jnp.asarray(toks)}
            for key, val in (r.extras or {}).items():
                batch[key] = jnp.asarray(val)[None]
            logits, pf_cache = self._prefill_jit(self.params, batch)
            self.stats.prefills += 1
            paste_args = () if page_row is None else (jnp.asarray(page_row),)
            if self._replay:
                # Cache rows [0, plen) are exact under trailing padding; the
                # next decode step replays prompt[-1] at position plen-1,
                # producing the first output token through the decode path
                # (pad rows ≥ plen are masked by kv_len until overwritten).
                self._cache = self._paste_jit(
                    self._cache, pf_cache, jnp.int32(slot),
                    jnp.int32(plen - 1), *paste_args)
                self._next_tok[slot, 0] = int(r.prompt[-1])
            else:
                first = int(np.argmax(np.asarray(
                    logits[0, -1, :self.cfg.vocab_size])))
                self._cache = self._paste_jit(
                    self._cache, pf_cache, jnp.int32(slot), jnp.int32(plen),
                    *paste_args)
                r.out_tokens.append(first)
                r.t_first_token = time.time()
                self._next_tok[slot, 0] = first
                self.stats.tokens_out += 1
                if plen >= self.max_len \
                        or len(r.out_tokens) >= r.max_new_tokens:
                    # done at admission: the cache is already full (no
                    # writable row for a decode step) or the prefill token
                    # exhausted the budget — never occupy a decode slot
                    r.done = True
                    r.t_done = time.time()
                    self._release(slot)
                    continue
            self._fresh[slot] = self._replay
            self._slots[slot] = r
            self._active[slot] = True

    def _release(self, slot: int):
        """Return a finished slot to the pool (called with the request
        already removed from / never placed in `_slots`)."""
        self._slots[slot] = None
        self._active[slot] = False
        if self.paged:
            freed = self._slot_pages[slot]
            if freed:
                self._free_pages.extend(freed.values())
                self.stats.pages_in_use -= len(freed)
                self._slot_pages[slot] = {}
            self._cache = self._unmap_jit(self._cache, jnp.int32(slot))

    # ----------------------------------------------------------------- decode
    def step(self) -> bool:
        """One engine tick: admit new work, then one batched decode step."""
        self._admit()
        active = [i for i, r in enumerate(self._slots) if r is not None]
        if not active:
            return False
        logits, self._cache = self._decode_jit(
            self.params, {"tokens": jnp.asarray(self._next_tok)}, self._cache,
            jnp.asarray(self._active))
        self.stats.decode_steps += 1
        self.stats.occupancy_sum += len(active) / self.n_slots
        nxt = np.asarray(jnp.argmax(
            logits[:, -1, :self.cfg.vocab_size], axis=-1), np.int32)
        pos = np.asarray(self._cache["pos"])   # ONE host sync per step
        for slot in active:
            r = self._slots[slot]
            r.out_tokens.append(int(nxt[slot]))
            self._next_tok[slot, 0] = nxt[slot]
            self.stats.tokens_out += 1
            if self._fresh[slot]:
                r.t_first_token = time.time()
                self._fresh[slot] = False
            # retire when out of budget OR out of cache: `pos` is the next
            # write index, so the slot can take another decode step iff
            # pos < max_len (the seed's `max_len - 1` retired one writable
            # row early, and one row earlier still on the replay path)
            if len(r.out_tokens) >= r.max_new_tokens \
                    or int(pos[slot]) >= self.max_len:
                r.done = True
                r.t_done = time.time()
                self._release(slot)
        if self._window:
            self._recycle_window_pages(pos)
        return True

    def _recycle_window_pages(self, pos):
        """Free pages that fell fully out of the attention window.

        A freed page either becomes the slot's next logical page (the table
        entry moves forward, no pool traffic — the window slides in place) or,
        once the request's whole span is mapped, returns to the free list so
        queued requests can admit. Runs on the already-synced `pos`; at most
        one page transitions per slot per page_size ticks."""
        ps = self.page_size
        for slot, r in enumerate(self._slots):
            if r is None or not self._slot_pages[slot]:
                continue
            m = self._slot_pages[slot]
            p = int(pos[slot])                   # next write index
            dead = sorted(j for j in m if (j + 1) * ps <= p - self._window)
            if not dead:
                continue
            nxt = max(m) + 1
            for j in dead:
                phys = m.pop(j)
                if nxt < self._slot_cap[slot]:
                    m[nxt] = phys
                    self._cache = self._remap_entry_jit(
                        self._cache, jnp.int32(slot), jnp.int32(j),
                        jnp.int32(nxt), jnp.int32(phys))
                    nxt += 1
                else:
                    self._free_pages.append(phys)
                    self.stats.pages_in_use -= 1
                    self._cache = self._unmap_entry_jit(
                        self._cache, jnp.int32(slot), jnp.int32(j))

    def run_to_completion(self, max_ticks: int = 10_000) -> EngineStats:
        ticks = 0
        while (self._queue or any(r is not None for r in self._slots)) \
                and ticks < max_ticks:
            self.step()
            ticks += 1
        return self.stats


def generate_greedy(model, params, prompt: np.ndarray, n_tokens: int,
                    max_len: int = 128, paged: bool = False,
                    wdtype: Optional[str] = None,
                    kv_dtype: Optional[str] = None,
                    extras: Optional[Dict[str, np.ndarray]] = None) -> List[int]:
    """Single-request reference path (the oracle for engine equivalence).

    Runs with bucketing OFF — exact-length prefill — and a DENSE cache by
    default, so equivalence tests against a bucketed/paged engine actually
    exercise the padded-prefill + replay and page-table paths instead of
    comparing them to themselves. With wdtype/kv_dtype this is the dense
    INT8 oracle: row quantization is layout-independent, so a paged int8
    engine must reproduce its tokens exactly."""
    eng = ServeEngine(model, n_slots=1, max_len=max_len, params=params,
                      bucket_prompts=False, paged=paged, wdtype=wdtype,
                      kv_dtype=kv_dtype)
    req = eng.submit(prompt, max_new_tokens=n_tokens, extras=extras)
    eng.run_to_completion()
    return req.out_tokens
