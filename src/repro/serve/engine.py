"""Serving engine: prefill + continuous-batching decode.

The "AI-optimized" configuration of the paper, as a serving runtime:
  * slot-based continuous batching: a fixed decode batch of N slots; finished
    requests free their slot, queued requests prefill into it (their KV/state
    pasted into the slot's cache rows) while other slots keep decoding.
  * int8 weight-only path (kernels/int8_matmul) — the 15 TOPS INT8 NPU
    datapath — available to the serve example/benches via `quantize_params`.
  * the faithful chiplet perf model (core/) prices batching decisions the way
    the paper's CPU chiplet dispatches to its two NPUs (see benches).

Fast-path design (PR 1):
  * power-of-two prompt bucketing — prefill compiles once per bucket, not once
    per distinct prompt length, so compile count is O(log max_len) in steady
    state. Padded prefills are made exact by *replaying* the last prompt token
    through the decode step (causal attention leaves rows [0, plen) untouched
    by trailing pads; the replay recomputes position plen-1 and yields the
    first output token from the shared decode path). Recurrent families
    (ssm/hybrid) carry their state through padding, so they keep exact-length
    prefill.
  * the KV cache is donated through the decode jit (in-place update instead of
    a full-cache copy per step) and through the jitted slot-paste program.
  * slot pastes run as ONE jitted scatter program per family instead of a
    Python chain of `.at[].set()` dispatches.
  * `pos` is fetched from device once per step (one host sync), not once per
    active slot.

Pure-python orchestration over jitted model fns; runs on CPU for tests and
examples, mesh-parameterized for pods.
"""

from __future__ import annotations

import dataclasses
import time
from typing import Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

_ATTN_FAMILIES = ("dense", "moe", "vlm", "encdec")


def bucket_length(plen: int, max_len: int) -> int:
    """Next power of two ≥ plen, clipped to max_len."""
    b = 1
    while b < plen:
        b <<= 1
    return min(b, max_len)


@dataclasses.dataclass
class Request:
    rid: int
    prompt: np.ndarray                  # (prompt_len,) int32
    max_new_tokens: int = 16
    out_tokens: List[int] = dataclasses.field(default_factory=list)
    done: bool = False
    t_enqueue: float = 0.0
    t_first_token: Optional[float] = None
    t_done: Optional[float] = None


@dataclasses.dataclass
class EngineStats:
    prefills: int = 0
    decode_steps: int = 0
    tokens_out: int = 0
    occupancy_sum: float = 0.0
    prefill_compiles: int = 0   # actual jit traces (bucketing keeps this flat)
    decode_compiles: int = 0
    paste_compiles: int = 0

    def summary(self) -> Dict[str, float]:
        d = dataclasses.asdict(self)
        if self.decode_steps:
            d["mean_occupancy"] = self.occupancy_sum / self.decode_steps
        return d


def _make_paste(fam: str):
    """One jitted scatter program per family: copy request-0's prefill cache
    into engine-cache slot `slot` and stamp the slot's stream position `pos`.

    Row counts come from the prefill cache's static shapes, so the program
    retraces once per prefill bucket, not per request. The engine cache is
    donated — the paste updates in place instead of copying every tensor.
    """

    def paste(cache, pf, slot, pos):
        c = dict(cache)
        if fam in _ATTN_FAMILIES:
            plen = pf["k"].shape[2]
            for key in ("k", "v"):
                c[key] = c[key].at[:, slot, :plen].set(
                    pf[key][:, 0, :plen].astype(c[key].dtype))
            for key in ("ck", "cv"):
                if key in c:
                    c[key] = c[key].at[:, slot].set(
                        pf[key][:, 0].astype(c[key].dtype))
        elif fam == "ssm":
            c["h"] = c["h"].at[:, slot].set(pf["h"][:, 0])
            c["conv"] = {
                k: c["conv"][k].at[:, slot].set(
                    pf["conv"][k][:, 0].astype(c["conv"][k].dtype))
                for k in c["conv"]}
        elif fam == "hybrid":
            new_layers = []
            for dst, src in zip(c["layers"], pf["layers"]):
                new_layers.append({
                    k: dst[k].at[slot].set(src[k][0].astype(dst[k].dtype))
                    for k in dst})
            c["layers"] = new_layers
        else:
            raise ValueError(f"unknown family {fam!r}")
        c["pos"] = c["pos"].at[slot].set(pos)
        return c

    return paste


class ServeEngine:
    def __init__(self, model, *, n_slots: int = 4, max_len: int = 128,
                 params=None, bucket_prompts: bool = True):
        self.model = model
        self.cfg = model.cfg
        self.n_slots = n_slots
        self.max_len = max_len
        self.params = params
        self.stats = EngineStats()
        self._queue: List[Request] = []
        self._slots: List[Optional[Request]] = [None] * n_slots
        self._fresh: List[bool] = [False] * n_slots  # replaying last prompt tok
        self._next_rid = 0
        # Padded prefill + replay is only exact when trailing pads cannot
        # reach earlier positions — true for causal-attention KV caches, false
        # for recurrent state (ssm/hybrid), which keeps exact-length prefill.
        self._replay = self.cfg.family in _ATTN_FAMILIES
        self.bucket_prompts = bucket_prompts and self._replay
        # donation is unimplemented on CPU (harmless but warns per compile)
        donate = {} if jax.default_backend() == "cpu" else \
            {"donate_argnums": (2,)}
        paste_donate = {} if jax.default_backend() == "cpu" else \
            {"donate_argnums": (0,)}

        # Replay admissions discard prefill logits — use the cache-only
        # prefill (no LM-head matmul) when the family provides one.
        cache_only = self._replay and model.prefill_cache is not None

        def _prefill(params, batch):
            self.stats.prefill_compiles += 1   # runs at trace time only
            if cache_only:
                return None, model.prefill_cache(params, batch)
            return model.prefill(params, batch)

        def _decode(params, batch, cache):
            self.stats.decode_compiles += 1
            return model.decode(params, batch, cache)

        def _paste(cache, pf, slot, pos):
            self.stats.paste_compiles += 1
            return _make_paste(self.cfg.family)(cache, pf, slot, pos)

        self._prefill_jit = jax.jit(_prefill)
        self._decode_jit = jax.jit(_decode, **donate)
        self._paste_jit = jax.jit(_paste, **paste_donate)
        self._next_tok = np.zeros((n_slots, 1), np.int32)
        abs_cache = model.cache_shape(n_slots, max_len, jnp.float32)
        self._cache = jax.tree.map(
            lambda s: jnp.zeros(s.shape, s.dtype), abs_cache)

    # ------------------------------------------------------------- lifecycle
    def submit(self, prompt: np.ndarray, max_new_tokens: int = 16) -> Request:
        prompt = np.asarray(prompt, np.int32)
        assert 1 <= prompt.shape[0] <= self.max_len, prompt.shape
        self._next_rid += 1
        req = Request(rid=self._next_rid, prompt=prompt,
                      max_new_tokens=max_new_tokens, t_enqueue=time.time())
        self._queue.append(req)
        return req

    def _admit(self):
        """Prefill queued requests into free slots."""
        for slot in [i for i, r in enumerate(self._slots) if r is None]:
            if not self._queue:
                return
            r = self._queue.pop(0)
            plen = r.prompt.shape[0]
            blen = bucket_length(plen, self.max_len) if self.bucket_prompts \
                else plen
            toks = np.zeros((1, blen), np.int32)
            toks[0, :plen] = r.prompt
            logits, pf_cache = self._prefill_jit(self.params,
                                                 {"tokens": jnp.asarray(toks)})
            self.stats.prefills += 1
            if self._replay:
                # Cache rows [0, plen) are exact under trailing padding; the
                # next decode step replays prompt[-1] at position plen-1,
                # producing the first output token through the decode path
                # (pad rows ≥ plen are masked by kv_len until overwritten).
                self._cache = self._paste_jit(
                    self._cache, pf_cache, jnp.int32(slot),
                    jnp.int32(plen - 1))
                self._next_tok[slot, 0] = int(r.prompt[-1])
            else:
                first = int(np.argmax(np.asarray(
                    logits[0, -1, :self.cfg.vocab_size])))
                self._cache = self._paste_jit(
                    self._cache, pf_cache, jnp.int32(slot), jnp.int32(plen))
                r.out_tokens.append(first)
                r.t_first_token = time.time()
                self._next_tok[slot, 0] = first
                self.stats.tokens_out += 1
            self._fresh[slot] = self._replay
            self._slots[slot] = r

    # ----------------------------------------------------------------- decode
    def step(self) -> bool:
        """One engine tick: admit new work, then one batched decode step."""
        self._admit()
        active = [i for i, r in enumerate(self._slots) if r is not None]
        if not active:
            return False
        logits, self._cache = self._decode_jit(
            self.params, {"tokens": jnp.asarray(self._next_tok)}, self._cache)
        self.stats.decode_steps += 1
        self.stats.occupancy_sum += len(active) / self.n_slots
        nxt = np.asarray(jnp.argmax(
            logits[:, -1, :self.cfg.vocab_size], axis=-1), np.int32)
        pos = np.asarray(self._cache["pos"])   # ONE host sync per step
        for slot in active:
            r = self._slots[slot]
            r.out_tokens.append(int(nxt[slot]))
            self._next_tok[slot, 0] = nxt[slot]
            self.stats.tokens_out += 1
            if self._fresh[slot]:
                r.t_first_token = time.time()
                self._fresh[slot] = False
            if len(r.out_tokens) >= r.max_new_tokens \
                    or int(pos[slot]) >= self.max_len - 1:
                r.done = True
                r.t_done = time.time()
                self._slots[slot] = None
        return True

    def run_to_completion(self, max_ticks: int = 10_000) -> EngineStats:
        ticks = 0
        while (self._queue or any(r is not None for r in self._slots)) \
                and ticks < max_ticks:
            self.step()
            ticks += 1
        return self.stats


def generate_greedy(model, params, prompt: np.ndarray, n_tokens: int,
                    max_len: int = 128) -> List[int]:
    """Single-request reference path (the oracle for engine equivalence).

    Runs with bucketing OFF — exact-length prefill — so equivalence tests
    against a bucketed engine actually exercise the padded-prefill + replay
    path instead of comparing it to itself."""
    eng = ServeEngine(model, n_slots=1, max_len=max_len, params=params,
                      bucket_prompts=False)
    req = eng.submit(prompt, max_new_tokens=n_tokens)
    eng.run_to_completion()
    return req.out_tokens
