"""Serving engine: prefill + continuous-batching decode.

The "AI-optimized" configuration of the paper, as a serving runtime:
  * slot-based continuous batching: a fixed decode batch of N slots; finished
    requests free their slot, queued requests prefill into it (their KV/state
    pasted into the slot's cache rows) while other slots keep decoding.
  * int8 weight-only path (kernels/int8_matmul) — the 15 TOPS INT8 NPU
    datapath — available to the serve example/benches via `quantize_params`.
  * the faithful chiplet perf model (core/) prices batching decisions the way
    the paper's CPU chiplet dispatches to its two NPUs (see benches).

Pure-python orchestration over jitted model fns; runs on CPU for tests and
examples, mesh-parameterized for pods.
"""

from __future__ import annotations

import dataclasses
import time
from typing import Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np


@dataclasses.dataclass
class Request:
    rid: int
    prompt: np.ndarray                  # (prompt_len,) int32
    max_new_tokens: int = 16
    out_tokens: List[int] = dataclasses.field(default_factory=list)
    done: bool = False
    t_enqueue: float = 0.0
    t_first_token: Optional[float] = None
    t_done: Optional[float] = None


@dataclasses.dataclass
class EngineStats:
    prefills: int = 0
    decode_steps: int = 0
    tokens_out: int = 0
    occupancy_sum: float = 0.0

    def summary(self) -> Dict[str, float]:
        d = dataclasses.asdict(self)
        if self.decode_steps:
            d["mean_occupancy"] = self.occupancy_sum / self.decode_steps
        return d


class ServeEngine:
    def __init__(self, model, *, n_slots: int = 4, max_len: int = 128,
                 params=None):
        self.model = model
        self.cfg = model.cfg
        self.n_slots = n_slots
        self.max_len = max_len
        self.params = params
        self.stats = EngineStats()
        self._queue: List[Request] = []
        self._slots: List[Optional[Request]] = [None] * n_slots
        self._next_rid = 0
        self._prefill_jit = jax.jit(model.prefill)
        self._decode_jit = jax.jit(model.decode)
        self._next_tok = np.zeros((n_slots, 1), np.int32)
        abs_cache = model.cache_shape(n_slots, max_len, jnp.float32)
        self._cache = jax.tree.map(
            lambda s: jnp.zeros(s.shape, s.dtype), abs_cache)

    # ------------------------------------------------------------- lifecycle
    def submit(self, prompt: np.ndarray, max_new_tokens: int = 16) -> Request:
        self._next_rid += 1
        req = Request(rid=self._next_rid, prompt=np.asarray(prompt, np.int32),
                      max_new_tokens=max_new_tokens, t_enqueue=time.time())
        self._queue.append(req)
        return req

    def _admit(self):
        """Prefill queued requests into free slots."""
        for slot in [i for i, r in enumerate(self._slots) if r is None]:
            if not self._queue:
                return
            r = self._queue.pop(0)
            toks = r.prompt[None, :]
            logits, pf_cache = self._prefill_jit(self.params,
                                                 {"tokens": toks})
            self.stats.prefills += 1
            first = int(np.argmax(np.asarray(
                logits[0, -1, :self.cfg.vocab_size])))
            self._paste_slot(slot, pf_cache, plen=toks.shape[1])
            r.out_tokens.append(first)
            r.t_first_token = time.time()
            self._next_tok[slot, 0] = first
            self._slots[slot] = r
            self.stats.tokens_out += 1

    # ------------------------------------------------------------ cache mgmt
    def _paste_slot(self, slot: int, pf, plen: int):
        """Copy request-0's prefill cache into engine cache slot (by family)."""
        c = dict(self._cache) if isinstance(self._cache, dict) else self._cache
        fam = self.cfg.family
        if fam in ("dense", "moe", "vlm", "encdec"):
            for key in ("k", "v"):
                c[key] = c[key].at[:, slot, :plen].set(
                    pf[key][:, 0, :plen].astype(c[key].dtype))
            for key in ("ck", "cv"):
                if key in c:
                    c[key] = c[key].at[:, slot].set(
                        pf[key][:, 0].astype(c[key].dtype))
        elif fam == "ssm":
            c["h"] = c["h"].at[:, slot].set(pf["h"][:, 0])
            c["conv"] = {
                k: c["conv"][k].at[:, slot].set(
                    pf["conv"][k][:, 0].astype(c["conv"][k].dtype))
                for k in c["conv"]}
        elif fam == "hybrid":
            new_layers = []
            for dst, src in zip(c["layers"], pf["layers"]):
                new_layers.append({
                    k: dst[k].at[slot].set(src[k][0].astype(dst[k].dtype))
                    for k in dst})
            c["layers"] = new_layers
        c["pos"] = c["pos"].at[slot].set(pf["pos"][0])
        self._cache = c

    # ----------------------------------------------------------------- decode
    def step(self) -> bool:
        """One engine tick: admit new work, then one batched decode step."""
        self._admit()
        active = [i for i, r in enumerate(self._slots) if r is not None]
        if not active:
            return False
        logits, self._cache = self._decode_jit(
            self.params, {"tokens": jnp.asarray(self._next_tok)}, self._cache)
        self.stats.decode_steps += 1
        self.stats.occupancy_sum += len(active) / self.n_slots
        nxt = np.asarray(jnp.argmax(
            logits[:, -1, :self.cfg.vocab_size], axis=-1), np.int32)
        for slot in active:
            r = self._slots[slot]
            r.out_tokens.append(int(nxt[slot]))
            self._next_tok[slot, 0] = nxt[slot]
            self.stats.tokens_out += 1
            if len(r.out_tokens) >= r.max_new_tokens \
                    or int(self._cache["pos"][slot]) >= self.max_len - 1:
                r.done = True
                r.t_done = time.time()
                self._slots[slot] = None
        return True

    def run_to_completion(self, max_ticks: int = 10_000) -> EngineStats:
        ticks = 0
        while (self._queue or any(r is not None for r in self._slots)) \
                and ticks < max_ticks:
            self.step()
            ticks += 1
        return self.stats


def generate_greedy(model, params, prompt: np.ndarray, n_tokens: int,
                    max_len: int = 128) -> List[int]:
    """Single-request reference path (the oracle for engine equivalence)."""
    eng = ServeEngine(model, n_slots=1, max_len=max_len, params=params)
    req = eng.submit(prompt, max_new_tokens=n_tokens)
    eng.run_to_completion()
    return req.out_tokens
