"""Serving engine: prefill + continuous-batching decode.

The "AI-optimized" configuration of the paper, as a serving runtime:
  * slot-based continuous batching: a fixed decode batch of N slots; finished
    requests free their slot, queued requests prefill into it (their KV/state
    pasted into the slot's cache rows) while other slots keep decoding.
  * int8 weight-only path (kernels/int8_matmul) — the 15 TOPS INT8 NPU
    datapath — available to the serve example/benches via `quantize_params`.
  * the faithful chiplet perf model (core/) prices batching decisions the way
    the paper's CPU chiplet dispatches to its two NPUs (see benches).

INT8 serving configuration (PR 3 — the paper's 15 TOPS INT8 datapath as the
measured serving numerics):
  * `wdtype="int8"`: weight-only int8 — the params pytree's projection
    weights become (int8, per-output-channel f32 scale) leaves via
    `models.quantized.quantize_params`; every projection einsum in the
    prefill/decode steps dispatches through `qeinsum` (Pallas int8_matmul on
    TPU, jnp dequant-matmul reference elsewhere; MoE experts quantized per
    expert). Halves weight HBM traffic per decode step — the bound at small
    batch.
  * `kv_dtype="int8"`: K/V stored int8 with per-(token, kv head) f16 dequant
    scales ('ks'/'vs' tensors riding next to 'k'/'v' in either cache
    layout). Quantization happens at write time (prefill paste + decode
    write); dequant is fused into the decode-attention kernel's K/V tile
    loads, so cache bytes/token drop ~2× vs bf16 (~(D+2)/2D) on top of the
    paged pool's live-token scaling. The quantized bytes are identical in
    the dense and paged layouts, so an int8 paged engine is token-exact
    against the dense int8 oracle — the equivalence the tests pin. encdec
    cross K/V stay f32 (written once; see encdec.cache_shape).
  * `kv_dtype="bf16"` is also accepted (the comparison baseline the int8
    serve bench reports its byte-shrink against).

Sliding-window paged slots (PR 3): window-attention configs (cfg.window > 0)
hold O(window) pages instead of O(position): admission reserves only
ceil(window/page)+2 pages past the live floor, and every tick the engine
frees pages that fell fully out of the attention window — remapping them to
the slot's next logical page (zero pool traffic) or returning them to the
free list once the request's span is covered. Out-of-window prompt pages are
never backed at all (their paste rows land on the null page, which the
window mask already makes unreadable).

Cache layout (PR 2 — paged KV):
  * Attention families default to a PAGED KV cache: one shared page pool of
    (n_layers, n_pages, page_size, KV, D) K/V blocks plus a per-slot
    (n_slots, max_len // page_size) page table. Physical page 0 is the NULL
    page — never allocated, it absorbs writes from retired slots and backs
    unmapped table entries so every gather/DMA has a valid source. Admission
    reserves ceil(min(max_len, prompt + max_new) / page_size) pages up front
    (so a request can never starve mid-decode) and retirement returns them to
    the free list and re-points the slot's table row at the null page. When
    the free list can't cover the queue head, admission waits — the pool is
    the admission controller. Peak KV memory therefore scales with LIVE
    tokens, not n_slots × max_len: long-context engines no longer reserve the
    worst case per slot (paper §serving: 16 GB HBM3 + streaming block-granular
    UCIe transfers — a page is one FLIT-sized stream unit).
  * `paged=False` keeps the dense per-slot (n_slots, max_len) rows — the
    oracle configuration for equivalence tests (`generate_greedy` runs it).
  * ssm/hybrid families keep their O(1) dense recurrent state; paging does
    not apply.

Chunked page-granular prefill (PR 4) — paged attention-family engines
default to it:
  * `_admit` only RESERVES the request's pages and (encdec) computes the
    cross K/V once; no prompt compute happens at admission. Each engine tick
    then runs AT MOST ONE fixed-size prefill chunk (chunk = chunk_pages ×
    page_size tokens) before the decode batch steps: the chunk computes its
    K/V, streams them straight into the page pool through the slot's page
    row, and runs chunk attention against the slot's already-pasted pages
    (kernels/flash_attention.flash_attention_paged on TPU; the jnp gather
    path is the CPU oracle). Head-of-line blocking is gone — a 4k-token
    prompt costs ceil(4k/C) bounded ticks interleaved with decode instead of
    one monolithic stall — and padding waste is capped at ONE CHUNK per
    prompt (vs ~2x worst-case under pow2 bucketing). One chunk compile total
    (C is fixed), instead of one prefill compile per bucket.
  * Mid-prefill slots keep their cache page-table row on the null page and
    their `active` mask off: the batched decode step's garbage writes for
    them can only land on the null page (the PR 2 idle-slot guard, extended
    to admission). The slot's REAL page row rides the chunk call as an
    explicit argument and is stamped into the cache — with pos = plen-1 for
    the replay — only after the final chunk.
  * Windowed configs chunk one page at a time and recycle out-of-window
    pages BETWEEN chunks (host-side bookkeeping only — the cache table row
    is still null), so a prompt longer than the window holds O(window)
    pages while prefilling, not O(plen).
  * Lossy KV storage (bf16/int8) engines pass a `kv_round` marker into the
    monolithic prefill so it attends the SAME rounded values the cache
    stores (models/transformer._round_kv). Chunk attention reads the pool —
    already rounded — so chunked and monolithic prefill see identical
    numerics and the chunked engine stays token-exact against the dense
    oracle for every KV dtype.

Per-slot sampling (PR 4): `submit(..., sample_params=(temperature, top_k,
top_p), seed=...)` threads per-slot sampling state through ONE jitted
sampled-decode step (serve/sampling.py, vmapped over slots): each slot's
PRNG key for its i-th token is fold_in(key(seed), i) — deterministic under
re-runs, slot reassignment and chunk-size changes. All-greedy ticks (the
default) dispatch to a separate argmax-only decode jit — bit-identical
tokens, none of the sampler's per-vocab sort/cumsum work, and the sampled
variant never even traces unless a request asks for it.

Fast-path design (PR 1):
  * power-of-two prompt bucketing — prefill compiles once per bucket, not once
    per distinct prompt length, so compile count is O(log max_len) in steady
    state. Padded prefills are made exact by *replaying* the last prompt token
    through the decode step (causal attention leaves rows [0, plen) untouched
    by trailing pads; the replay recomputes position plen-1 and yields the
    first output token from the shared decode path). Recurrent families
    (ssm/hybrid) carry their state through padding, so they keep exact-length
    prefill.
  * the KV cache is donated through the decode jit (in-place update instead of
    a full-cache copy per step) and through the jitted slot-paste program.
  * slot pastes run as ONE jitted scatter program per family instead of a
    Python chain of `.at[].set()` dispatches.
  * `pos` is fetched from device once per step (one host sync), not once per
    active slot.
  * freed slots are masked out of the batched decode step: an `active` mask
    freezes their stream position, so an idle tick is a no-op per freed slot
    (their stale-token writes land on the null page / an overwritten dense
    row, and `pos` cannot drift past the cache).

Pure-python orchestration over jitted model fns; runs on CPU for tests and
examples, mesh-parameterized for pods.
"""

from __future__ import annotations

import dataclasses
import hashlib
import math
import time
from collections import OrderedDict
from typing import Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.analysis.sanitizer import register_entry_point
from repro.models.quantized import quantize_kv_rows
from repro.models.transformer import copy_pool_page, pool_data_keys
from repro.serve.faults import FaultPlan
from repro.serve.sampling import (
    apply_logit_processors, clamp_rep_penalty, clamp_sample_params,
    sample_tokens)

_ATTN_FAMILIES = ("dense", "moe", "vlm", "encdec")


class EngineOverloaded(RuntimeError):
    """Graceful backpressure: submit() refused because the admission queue
    is at its cap. Callers shed load (retry later / route elsewhere)
    instead of growing an unbounded queue."""

_KV_DTYPES = {None: jnp.float32, "f32": jnp.float32, "float32": jnp.float32,
              "bf16": jnp.bfloat16, "bfloat16": jnp.bfloat16,
              "int8": jnp.int8,
              # fp8 KV: bare e5m2 rows, no scale tensors (dense layout only —
              # paged fp8 pools are a recorded follow-on)
              "fp8": jnp.float8_e5m2, "e5m2": jnp.float8_e5m2}


def bucket_length(plen: int, max_len: int) -> int:
    """Next power of two ≥ plen, clipped to max_len."""
    b = 1
    while b < plen:
        b <<= 1
    return min(b, max_len)


# ---------------------------------------------------------------------------
# Paged-pool bookkeeping shared by the single-host engine and the sharded
# scheduler (serve/scheduler.py) — ONE copy of the reservation and
# sliding-window recycle math, so a fix in either engine cannot silently
# break the other's token-parity invariant.
# ---------------------------------------------------------------------------

def window_page_budget(window: int, page_size: int) -> int:
    """Mapped pages that always cover [pos-window, pos] plus one page of
    write-ahead slack while the window slides."""
    return (window - 1) // page_size + 3


def reserve_page_count(plen: int, max_new: int, *, max_len: int,
                       page_size: int, window: int, lo: int = 0) -> int:
    """Pages reserved at admission: every row the request can ever write,
    or — for window configs — the O(window) live span from logical page `lo`
    (0 under chunked prefill: the first chunk writes row 0 and recycling
    slides the mapping forward)."""
    rows = min(max_len, plen + max_new)
    full = -(-rows // page_size)
    if not window:
        return full
    return min(full - lo, window_page_budget(window, page_size))


def recycle_dead_pages(mapping: Dict[int, int], cap: int, page_size: int,
                       window: int, progress: int):
    """Sliding-window recycle core: pages fully below `progress - window`
    either become the slot's next logical page (remap forward while the
    request still has unwritten pages below `cap`) or leave the mapping once
    its span is covered. Mutates `mapping` in place; returns
    ([(j_dead, j_new, phys)] remaps, [(j_dead, phys)] unmaps) — the caller
    mirrors both into its page table and RELEASES the unmapped physical
    pages through its own (ref-counted, PR 8) allocator. Remapped pages get
    rewritten, so window engines never share pages — the prefix cache is
    disabled under a sliding window and every page here is exclusively
    owned."""
    dead = sorted(j for j in mapping
                  if (j + 1) * page_size <= progress - window)
    remaps, unmaps = [], []
    if not dead:
        return remaps, unmaps
    nxt = max(mapping) + 1
    for j in dead:
        phys = mapping.pop(j)
        if nxt < cap:
            mapping[nxt] = phys
            remaps.append((j, nxt, phys))
            nxt += 1
        else:
            unmaps.append((j, phys))
    return remaps, unmaps


def page_row_of(mapping: Dict[int, int], pages_per_seq: int) -> np.ndarray:
    """(pages_per_seq,) physical-page row: mapped pages, null page 0 rest."""
    row = np.zeros((pages_per_seq,), np.int32)
    for j, p in mapping.items():
        row[j] = p
    return row


# ---------------------------------------------------------------------------
# Prefix cache (PR 8): content addressing for page-aligned prompt prefixes.
# A page's K/V bytes are a pure function of the token prefix up to its end
# (attention context included) AND any non-token prefill inputs (vlm patch
# embeds overwrite leading embeddings; encdec cross-attention threads the
# frames through every decoder layer) — so the content key for logical page
# j is a digest CHAIN: sha1(extras) -> sha1(prev || page-j tokens). Two
# requests share page j iff their whole prefixes up to (j+1)*page_size
# match, which with schedule-independent KV rounding (PR 4) means the pool
# bytes match exactly.
# ---------------------------------------------------------------------------

def request_seed_digest(extras: Optional[Dict[str, np.ndarray]]) -> bytes:
    """Chain seed covering every non-token prefill input. Empty extras hash
    to b'' so the common text-only case costs nothing."""
    if not extras:
        return b""
    h = hashlib.sha1()
    for key in sorted(extras):
        arr = np.ascontiguousarray(np.asarray(extras[key]))
        h.update(key.encode())
        h.update(str(arr.dtype).encode())
        h.update(str(arr.shape).encode())
        h.update(arr.tobytes())
    return h.digest()


def prefix_digests(lp: np.ndarray, page_size: int, n_pages: int,
                   seed: bytes = b"") -> List[bytes]:
    """Digest chain for the first `n_pages` FULL pages of token prefix `lp`:
    digests[j] keys the pool content of logical page j."""
    out, d = [], seed
    for j in range(n_pages):
        d = hashlib.sha1(
            d + lp[j * page_size:(j + 1) * page_size].tobytes()).digest()
        out.append(d)
    return out


def lookup_prefix_hits(by_hash: Dict[bytes, int], lp: np.ndarray,
                       page_size: int, seed: bytes = b"") -> List[int]:
    """Longest cached run over lp's FULL prompt pages — the hit physical
    pages, in logical order. The scan stops at the first miss: page j+1's
    digest chains through page j's, and chunk resume needs a CONTIGUOUS
    cached prefix anyway."""
    n_cand = lp.shape[0] // page_size
    hits: List[int] = []
    if not n_cand:
        return hits
    for d in prefix_digests(lp, page_size, n_cand, seed=seed):
        p = by_hash.get(d)
        if p is None:
            break
        hits.append(p)
    return hits


def prefix_share_plan(plen: int, hits: List[int], page_size: int):
    """(n_shared, cow_src): hit pages shared outright vs the one COW-cloned.
    tail = (plen-1)//page_size is the page the replay decode WRITES — never
    shared; a full-page hit there (plen % page_size == 0 only) is cloned
    into a private page instead of recomputed."""
    tail = (plen - 1) // page_size
    n_shared = min(len(hits), tail)
    cow_src = hits[tail] if len(hits) > tail else None
    return n_shared, cow_src


def register_prefix_pages(mapping: Dict[int, int], lp: np.ndarray,
                          page_size: int, seed: bytes,
                          page_hash: Dict[int, bytes],
                          by_hash: Dict[bytes, int]) -> None:
    """Content-register a fully-prefilled slot's FULL prompt pages in the
    (page_hash, by_hash) registry. First registration of a content key wins;
    a page already keying another prefix keeps its key."""
    n_full = lp.shape[0] // page_size
    if not n_full:
        return
    digests = prefix_digests(lp, page_size, n_full, seed=seed)
    for j in range(n_full):
        phys = mapping.get(j)
        if phys is None or digests[j] in by_hash or phys in page_hash:
            continue
        page_hash[phys] = digests[j]
        by_hash[digests[j]] = phys


@dataclasses.dataclass
class Request:
    rid: int
    prompt: np.ndarray                  # (prompt_len,) int32
    max_new_tokens: int = 16
    # extra prefill inputs (e.g. encdec 'frames': (S_enc, d_model)); batched
    # with a leading axis of 1 at admission
    extras: Optional[Dict[str, np.ndarray]] = None
    # sampling: temperature 0 = greedy argmax (the exactness-test oracle);
    # top_k 0 and top_p 1.0 disable their filters
    temperature: float = 0.0
    top_k: int = 0
    top_p: float = 1.0
    seed: int = 0
    # logit processors (PR 7): repetition penalty over prompt + emitted
    # tokens (1.0 = off, HF convention) and an additive per-token logit bias
    rep_penalty: float = 1.0
    logit_bias: Optional[Dict[int, float]] = None
    out_tokens: List[int] = dataclasses.field(default_factory=list)
    done: bool = False
    t_enqueue: float = 0.0
    t_first_token: Optional[float] = None
    t_done: Optional[float] = None
    # tick-domain latency (deterministic twin of the wall-clock fields: the
    # bench gates cache-hit TTFT on ticks, which replay bit-for-bit)
    first_token_tick: Optional[int] = None
    # prompt tokens served from the prefix cache at (last) admission
    cached_prompt_tokens: int = 0
    # ---- fault tolerance (PR 6) ----------------------------------------
    preemptions: int = 0            # times this request was preempted
    timed_out: bool = False         # retired by TTL, not by completion
    submit_tick: int = 0            # engine tick at submit (TTL clock)
    ttl_ticks: Optional[int] = None  # per-request TTL override

    def live_prompt(self) -> np.ndarray:
        """The token prefix a resumed request re-prefills: prompt plus every
        already-emitted token. Schedule-independent KV rounding (PR 4) makes
        the re-prefilled cache byte-identical to the one the decode steps
        wrote, and the fold_in(seed, token_index) sampling streams continue
        at counter=len(out_tokens) — so a preempted/recovered stream is
        token-exact with its uninterrupted twin."""
        if not self.out_tokens:
            return self.prompt
        return np.concatenate(
            [self.prompt, np.asarray(self.out_tokens, np.int32)])

    def remaining_new(self) -> int:
        return self.max_new_tokens - len(self.out_tokens)


@dataclasses.dataclass
class EngineStats:
    prefills: int = 0           # requests admitted into prefill
    decode_steps: int = 0
    tokens_out: int = 0
    occupancy_sum: float = 0.0
    prefill_compiles: int = 0   # actual jit traces (bucketing keeps this flat)
    decode_compiles: int = 0
    paste_compiles: int = 0
    chunk_compiles: int = 0     # chunked prefill: ONE total (fixed shapes)
    prefill_chunks: int = 0     # chunk-prefill invocations
    pages_in_use: int = 0       # paged engines: currently reserved pages
    peak_pages_in_use: int = 0
    # head-of-line blocking: ticks the decode batch waited on prefill work
    # beyond the per-tick one-chunk budget (monolithic prefill of a long
    # prompt counts ceil(blen/chunk)-1; chunked prefill counts 0)
    decode_stall_ticks: int = 0
    prefill_tokens: int = 0     # real prompt tokens prefilled
    prefill_pad_tokens: int = 0  # padded prefill rows (bucket or chunk waste)
    # ---- fault tolerance & backpressure (PR 6) -------------------------
    preemptions: int = 0        # decoding slots evicted for a starving head
    retries: int = 0            # re-admissions (preempted or recovered work)
    timeouts: int = 0           # requests retired by TTL
    rejected: int = 0           # submits refused at the queue cap
    faults_injected: int = 0    # FaultPlan events applied
    recoveries: int = 0         # slots migrated off a draining/dead shard
    recovery_ticks_sum: int = 0  # requeue -> back-live latency, summed
    # ---- live page migration over UCIe (PR 9) --------------------------
    migrations: int = 0         # live slots re-homed by page moves
    migrated_pages: int = 0     # physical pages moved across shards
    migrated_bytes_compressed: float = 0.0  # UCIe wire bytes (post-compress)
    rebalance_events: int = 0   # elastic-rebalance slot moves
    # ---- prefix cache & copy-on-write (PR 8) ---------------------------
    prefix_hits: int = 0        # admissions that reused >=1 cached page
    prefix_misses: int = 0      # admissions with zero cached pages
    prefix_hit_tokens: int = 0  # prompt tokens served from cached pages
    prefix_evictions: int = 0   # refcount-zero cached pages reclaimed
    cow_copies: int = 0         # tail pages cloned instead of recomputed
    prefix_cached_pages: int = 0  # gauge: refcount-zero pages retained
    # ---- per-request latency samples (ROADMAP item 4 pre-work) ---------
    # raw seconds, one entry per COMPLETED request; summary() collapses
    # them to p50/p99 and drops the lists from the flat metric dict
    ttft_s: List[float] = dataclasses.field(default_factory=list, repr=False)
    tpot_s: List[float] = dataclasses.field(default_factory=list, repr=False)

    def record_request(self, r: "Request") -> None:
        """Fold a completed request's latencies into the TTFT/TPOT samples
        (timed-out / cancelled requests never report — their latencies
        describe the TTL policy, not the serving path)."""
        if r.t_first_token is not None:
            self.ttft_s.append(r.t_first_token - r.t_enqueue)
            if r.t_done is not None and len(r.out_tokens) > 1:
                self.tpot_s.append((r.t_done - r.t_first_token)
                                   / (len(r.out_tokens) - 1))

    def summary(self) -> Dict[str, float]:
        d = dataclasses.asdict(self)
        # Every derived metric is guarded: zero-tick / zero-token runs (an
        # engine that only rejected or timed out, an early-return bench leg)
        # must report well-defined zeros, never a ZeroDivisionError or NaN.
        # Consumers index these keys unconditionally.
        d["mean_occupancy"] = (self.occupancy_sum / self.decode_steps
                               if self.decode_steps else 0.0)
        d["pad_waste_ratio"] = (self.prefill_pad_tokens / self.prefill_tokens
                                if self.prefill_tokens else 0.0)
        d["mean_recovery_ticks"] = (self.recovery_ticks_sum / self.recoveries
                                    if self.recoveries else 0.0)
        # SLO percentiles over completed requests — the flat dict stays
        # {metric: number} (the raw sample lists are dropped)
        for name in ("ttft_s", "tpot_s"):
            samples = d.pop(name)
            d[f"{name[:-2]}_p50_s"] = (
                float(np.percentile(samples, 50)) if samples else 0.0)
            d[f"{name[:-2]}_p99_s"] = (
                float(np.percentile(samples, 99)) if samples else 0.0)
        assert all(math.isfinite(v) for v in d.values()
                   if isinstance(v, (int, float))), d
        return d


def _make_paste(fam: str):
    """One jitted scatter program per family: copy request-0's prefill cache
    into engine-cache slot `slot` and stamp the slot's stream position `pos`.

    Row counts come from the prefill cache's static shapes, so the program
    retraces once per prefill bucket, not per request. The engine cache is
    donated — the paste updates in place instead of copying every tensor.
    """

    def paste(cache, pf, slot, pos):
        c = dict(cache)
        if fam in _ATTN_FAMILIES:
            plen = pf["k"].shape[2]
            int8_kv = "ks" in c
            # pools present in the prefill cache: ('k', 'v') for GQA,
            # ('k',) for MLA's single latent pool (models/mla.py)
            for key in pool_data_keys(pf):
                if int8_kv:
                    # quantize prompt rows per (position, kv head) — the same
                    # map the decode write path applies, so dense and paged
                    # int8 caches hold identical bytes
                    qr, sr = quantize_kv_rows(pf[key][:, 0, :plen])
                    c[key] = c[key].at[:, slot, :plen].set(qr)
                    c[key + "s"] = c[key + "s"].at[:, slot, :plen].set(sr)
                else:
                    c[key] = c[key].at[:, slot, :plen].set(
                        pf[key][:, 0, :plen].astype(c[key].dtype))
            for key in ("ck", "cv"):
                if key in c:
                    c[key] = c[key].at[:, slot].set(
                        pf[key][:, 0].astype(c[key].dtype))
        elif fam == "ssm":
            c["h"] = c["h"].at[:, slot].set(pf["h"][:, 0])
            c["conv"] = {
                k: c["conv"][k].at[:, slot].set(
                    pf["conv"][k][:, 0].astype(c["conv"][k].dtype))
                for k in c["conv"]}
        elif fam == "hybrid":
            new_layers = []
            for dst, src in zip(c["layers"], pf["layers"]):
                new_layers.append({
                    k: dst[k].at[slot].set(src[k][0].astype(dst[k].dtype))
                    for k in dst})
            c["layers"] = new_layers
        else:
            raise ValueError(f"unknown family {fam!r}")
        c["pos"] = c["pos"].at[slot].set(pos)
        return c

    return paste


def _make_paste_paged(fam: str):
    """Paged paste: scatter the dense prefill rows page-by-page into the
    shared pool and stamp the slot's page-table row.

    `page_row` is the slot's full (pages_per_seq,) table row — reserved
    physical pages first, null page (0) for the rest. Prefill-bucket pad rows
    that spill past the reservation land on the null page; pad rows inside it
    sit at logical positions ≥ kv_len, masked until decode overwrites them —
    the same invariant the dense replay path relies on."""
    assert fam in _ATTN_FAMILIES, fam

    def paste(cache, pf, slot, pos, page_row):
        c = dict(cache)
        ps = c["k"].shape[2]
        blen = pf["k"].shape[2]
        n_prompt_pages = -(-blen // ps)    # static per prefill bucket
        int8_kv = "ks" in c
        # ('k', 'v') for GQA, ('k',) for MLA's single latent pool
        for key in pool_data_keys(pf):
            pool = c[key]
            if int8_kv:
                qrows, srows = quantize_kv_rows(pf[key][:, 0])  # (L,blen,KV,·)
                spool = c[key + "s"]
            for j in range(n_prompt_pages):
                rows = min(ps, blen - j * ps)
                if int8_kv:
                    pool = pool.at[:, page_row[j], :rows].set(
                        qrows[:, j * ps:j * ps + rows])
                    spool = spool.at[:, page_row[j], :rows].set(
                        srows[:, j * ps:j * ps + rows])
                else:
                    src = pf[key][:, 0, j * ps:j * ps + rows].astype(pool.dtype)
                    pool = pool.at[:, page_row[j], :rows].set(src)
            c[key] = pool
            if int8_kv:
                c[key + "s"] = spool
        for key in ("ck", "cv"):           # encdec cross K/V stay dense
            if key in c:
                c[key] = c[key].at[:, slot].set(
                    pf[key][:, 0].astype(c[key].dtype))
        c["page_table"] = c["page_table"].at[slot].set(page_row)
        c["pos"] = c["pos"].at[slot].set(pos)
        return c

    return paste


class ServeEngine:
    # Declared hot-loop compile budgets for a FIXED engine config (ROADMAP
    # contract: every serving subsystem declares its budgets). "decode" is
    # the greedy + lazily-traced sampled variants; "chunk" is the ONE
    # fixed-shape chunk-prefill compile; "prefill" is per pow2 bucket so it
    # scales O(log max_len) with traffic, not a constant — it is asserted
    # by the fastpath tests against the bucket count, not here. Enforced at
    # runtime via analysis/sanitizer.compile_budget(**COMPILE_BUDGETS).
    COMPILE_BUDGETS = {"decode": 2, "chunk": 1}

    def __init__(self, model, *, n_slots: int = 4, max_len: int = 128,
                 params=None, bucket_prompts: bool = True,
                 paged: Optional[bool] = None, page_size: int = 32,
                 n_pages: Optional[int] = None,
                 wdtype: Optional[str] = None,
                 kv_dtype: Optional[str] = None,
                 chunked_prefill: Optional[bool] = None,
                 chunk_pages: int = 2,
                 prefix_cache: Optional[bool] = None,
                 max_queue: Optional[int] = None,
                 ttl_ticks: Optional[int] = None,
                 preempt_after: int = 2,
                 max_preemptions: int = 3,
                 fault_plan: Optional[FaultPlan] = None):
        self.model = model
        self.cfg = model.cfg
        self.n_slots = n_slots
        self.max_len = max_len
        if wdtype not in (None, "bf16", "int8"):
            raise ValueError(f"wdtype must be None/'bf16'/'int8', got {wdtype!r}")
        if wdtype == "int8":
            if self.cfg.family not in _ATTN_FAMILIES:
                raise ValueError(
                    f"wdtype='int8' applies to attention families, not "
                    f"{self.cfg.family!r}")
            from repro.models.quantized import quantize_params
            params = quantize_params(params, self.cfg)
        elif wdtype == "bf16":
            params = jax.tree.map(
                lambda p: p.astype(jnp.bfloat16)
                if jnp.issubdtype(p.dtype, jnp.floating) else p, params)
        self.wdtype = wdtype
        if kv_dtype not in _KV_DTYPES:
            raise ValueError(f"unknown kv_dtype {kv_dtype!r}")
        self.kv_dtype = _KV_DTYPES[kv_dtype]
        if self.kv_dtype != jnp.float32 \
                and self.cfg.family not in _ATTN_FAMILIES:
            raise ValueError(
                f"kv_dtype={kv_dtype!r} applies to attention-family KV "
                f"caches, not {self.cfg.family!r} recurrent state")
        self.params = params
        self.stats = EngineStats()
        self._queue: List[Request] = []
        # ---- fault tolerance & backpressure (PR 6) -------------------------
        self.max_queue = max_queue
        self.ttl_ticks = ttl_ticks
        self.preempt_after = max(1, int(preempt_after))
        self.max_preemptions = max(0, int(max_preemptions))
        self.fault_plan = fault_plan
        self._tick = 0               # engine tick counter (fault/TTL clock)
        self._starved = 0            # consecutive page-starved ticks
        self._page_blocked = False   # head blocked on pages w/ a free slot
        self._stolen_pages: List[int] = []   # page_squeeze stash (shard 0)
        self._any_ttl = ttl_ticks is not None
        self._slots: List[Optional[Request]] = [None] * n_slots
        self._fresh: List[bool] = [False] * n_slots  # replaying last prompt tok
        self._active = np.zeros((n_slots,), bool)
        self._next_rid = 0
        # Padded prefill + replay is only exact when trailing pads cannot
        # reach earlier positions — true for causal-attention KV caches, false
        # for recurrent state (ssm/hybrid), which keeps exact-length prefill.
        self._replay = self.cfg.family in _ATTN_FAMILIES
        self.bucket_prompts = bucket_prompts and self._replay
        if paged and self.cfg.family not in _ATTN_FAMILIES:
            raise ValueError(
                f"paged KV applies to attention families, not {self.cfg.family!r}")
        self.paged = (self.cfg.family in _ATTN_FAMILIES) if paged is None \
            else bool(paged)
        if self.paged and max_len % page_size != 0:
            if paged is None:
                # auto mode must not reject a max_len the dense engine took:
                # shrink to the largest compatible page size, or go dense if
                # pages would degenerate below 8 rows
                fit = math.gcd(min(page_size, max_len), max_len)
                if fit >= 8 or fit == max_len:
                    page_size = fit
                else:
                    self.paged = False
            else:
                raise ValueError(
                    f"max_len {max_len} is not a multiple of page_size "
                    f"{page_size}")
        if self.kv_dtype == jnp.float8_e5m2 and self.paged:
            raise ValueError(
                "kv_dtype fp8/e5m2 supports the dense cache layout only; "
                "pass paged=False (paged fp8 pools are a follow-on)")
        # sliding-window page recycling: attention configs with a window hold
        # O(window) live pages — out-of-window pages are freed mid-flight.
        # (encdec self-attention ignores cfg.window, so it stays full-span.)
        self._window = self.cfg.window \
            if self.paged and self.cfg.family != "encdec" else 0
        if self.paged:
            self.page_size = page_size
            self.pages_per_seq = max_len // page_size
            # page 0 is the reserved null page
            self.n_pages = (1 + n_slots * self.pages_per_seq
                            if n_pages is None else n_pages)
            assert self.n_pages >= 2, self.n_pages
            self._free_pages = list(range(self.n_pages - 1, 0, -1))
            # logical page index -> physical page, per slot
            self._slot_pages: List[Dict[int, int]] = [
                {} for _ in range(n_slots)]
            # highest logical page the request may ever write (exclusive)
            self._slot_cap = [0] * n_slots
            # ---- ref-counted, content-addressed allocator (PR 8) -----------
            # Every physical page is in exactly ONE of: the free list
            # (ref 0, unregistered), mapped by >=1 slot (ref >= 1), the
            # cached LRU (ref 0 but content-registered — evictable on
            # demand), or a page_squeeze stash. Slots hold REFERENCES, not
            # pages: release decrements, and a page only leaves the live set
            # at refcount zero.
            self._ref = np.zeros((self.n_pages,), np.int32)
            self._page_hash: Dict[int, bytes] = {}    # phys -> content key
            self._by_hash: Dict[bytes, int] = {}      # content key -> phys
            self._lru: "OrderedDict[int, None]" = OrderedDict()
        # in-flight prefix dedup (PR 9): page digests a mid-prefill slot
        # will register, so identical prompts submitted together wait for
        # the first's pages instead of prefilling twice. (Unconditional:
        # release/registration clear it on every engine flavour.)
        self._pending_digest: Dict[bytes, int] = {}       # digest -> rid
        self._pending_by_rid: Dict[int, List[bytes]] = {}
        # ---- chunked page-granular prefill (PR 4) --------------------------
        can_chunk = self.paged and model.prefill_chunk is not None
        if chunked_prefill is None:
            self.chunked = can_chunk
        else:
            self.chunked = bool(chunked_prefill)
            if self.chunked and not can_chunk:
                raise ValueError(
                    "chunked_prefill requires a paged attention-family "
                    f"engine (family {self.cfg.family!r}, paged={self.paged})")
        self.chunk_pages = max(1, int(chunk_pages))
        if self.chunked and self._window:
            # windowed slots chunk ONE page at a time so the existing
            # ceil(window/page)+2 reservation also covers the chunk's
            # write-ahead — occupancy stays O(window) during prefill
            self.chunk_pages = 1
        # chunk token budget; also the stall-metric unit for monolithic
        # engines (a monolithic prefill of blen tokens counts as
        # ceil(blen/chunk_tokens) chunk-equivalents of decode stall)
        self.chunk_tokens = (self.chunk_pages * page_size if self.paged
                             else min(64, max_len))
        # ---- prefix cache (PR 8) -------------------------------------------
        # Content-addressed sharing of page-aligned prompt prefixes. Needs
        # the paged pool (pages to share) AND chunked prefill (the resume
        # contract that skips cached pages). Sliding-window engines disable
        # it silently: window recycling REWRITES remapped pages in place,
        # which is incompatible with sharing — and the window engine is
        # already the O(window) memory optimization.
        can_cache = self.paged and self.chunked and not self._window
        if prefix_cache is None:
            self.prefix_cache = can_cache
        else:
            self.prefix_cache = bool(prefix_cache)
            if self.prefix_cache and not (self.paged and self.chunked):
                raise ValueError(
                    "prefix_cache requires a paged chunked-prefill engine "
                    f"(family {self.cfg.family!r}, paged={self.paged}, "
                    f"chunked={self.chunked})")
            if self.prefix_cache and self._window:
                self.prefix_cache = False
        self._prefill_fifo: List[int] = []     # slots mid-prefill, FIFO
        self._chunk_next = [0] * n_slots       # next chunk start per slot
        self._tick_prefill_tokens = 0
        # ---- per-slot sampling state (PR 4) --------------------------------
        self._temp = np.zeros((n_slots,), np.float32)
        self._topk = np.zeros((n_slots,), np.int32)
        self._topp = np.ones((n_slots,), np.float32)
        self._sseed = np.zeros((n_slots,), np.int32)
        # ---- per-slot logit processors (PR 7) ------------------------------
        # host-maintained, riding the same sampled-decode jit: rep_penalty
        # (1 = off), seen tokens (prompt + emitted), additive logit bias
        self._rep_pen = np.ones((n_slots,), np.float32)
        self._seen = np.zeros((n_slots, self.cfg.vocab_size), bool)
        self._bias = np.zeros((n_slots, self.cfg.vocab_size), np.float32)
        self._bias_on = np.zeros((n_slots,), bool)
        # donation is unimplemented on CPU (harmless but warns per compile)
        donate = {} if jax.default_backend() == "cpu" else \
            {"donate_argnums": (2,)}
        paste_donate = {} if jax.default_backend() == "cpu" else \
            {"donate_argnums": (0,)}

        # Replay admissions discard prefill logits — use the cache-only
        # prefill (no LM-head matmul) when the family provides one.
        cache_only = self._replay and model.prefill_cache is not None

        def _prefill(params, batch):
            self.stats.prefill_compiles += 1   # runs at trace time only
            if cache_only:
                return None, model.prefill_cache(params, batch)
            return model.prefill(params, batch)

        def _decode_core(params, batch, cache, active):
            logits, new_cache = model.decode(params, batch, cache)
            # freeze freed slots' stream position: their garbage advance would
            # otherwise drift past max_len tick by tick (idle tick == no-op)
            new_cache["pos"] = jnp.where(active, new_cache["pos"],
                                         cache["pos"])
            return logits[:, -1, :self.cfg.vocab_size], new_cache

        def _decode(params, batch, cache, active):
            # all-greedy fast path (the default): plain argmax, no sampling
            # pipeline — the pre-sampling engine's hot loop, unchanged
            self.stats.decode_compiles += 1
            logits, new_cache = _decode_core(params, batch, cache, active)
            return jnp.argmax(logits, axis=-1).astype(jnp.int32), new_cache

        def _decode_sample(params, batch, cache, active, sample):
            # per-slot sampling inside the decode jit: greedy (temperature 0)
            # rows still take the raw argmax; only (B,) tokens leave device.
            # Compiled lazily — engines that never sample never trace it.
            # Logit processors (rep penalty / bias) run first — identity for
            # slots with rep_penalty=1 and zero bias, so plain-sampled and
            # greedy rows are bit-identical to the processor-free engine.
            self.stats.decode_compiles += 1
            logits, new_cache = _decode_core(params, batch, cache, active)
            logits = apply_logit_processors(
                logits.astype(jnp.float32),
                sample["rep_penalty"], sample["seen"], sample["bias"])
            toks = sample_tokens(
                logits,
                sample["temperature"], sample["top_k"], sample["top_p"],
                sample["seed"], sample["counter"])
            return toks, new_cache

        if self.paged:
            def _paste(cache, pf, slot, pos, page_row):
                self.stats.paste_compiles += 1
                return _make_paste_paged(self.cfg.family)(
                    cache, pf, slot, pos, page_row)

            def _unmap(cache, slot):
                # retired slot: point its whole table row at the null page so
                # freed physical pages can be re-issued without aliasing
                return dict(cache, page_table=cache["page_table"]
                            .at[slot].set(0))

            def _remap_entry(cache, slot, j_dead, j_new, phys):
                # window recycling: a page that fell out of the attention
                # window becomes the slot's next logical page (its stale rows
                # sit at positions >= kv_len until overwritten — masked, the
                # same invariant pad rows rely on)
                pt = cache["page_table"].at[slot, j_dead].set(0)
                return dict(cache, page_table=pt.at[slot, j_new].set(phys))

            def _unmap_entry(cache, slot, j_dead):
                return dict(cache, page_table=cache["page_table"]
                            .at[slot, j_dead].set(0))

            self._unmap_jit = jax.jit(_unmap, **paste_donate)
            self._remap_entry_jit = jax.jit(_remap_entry, **paste_donate)
            self._unmap_entry_jit = jax.jit(_unmap_entry, **paste_donate)

            if self.chunked:
                chunk_donate = {} if jax.default_backend() == "cpu" else \
                    {"donate_argnums": (2,)}

                def _chunk(params, batch, cache):
                    self.stats.chunk_compiles += 1   # trace time only
                    return model.prefill_chunk(params, batch, cache)

                def _finalize(cache, slot, pos, page_row):
                    # last chunk done: stamp the slot's REAL page row and its
                    # replay position — only now does the slot become visible
                    # to the batched decode step
                    c = dict(cache)
                    c["page_table"] = c["page_table"].at[slot].set(page_row)
                    c["pos"] = c["pos"].at[slot].set(pos)
                    return c

                self._chunk_jit = jax.jit(_chunk, **chunk_donate)
                self._finalize_jit = jax.jit(_finalize, **paste_donate)
                # COW tail clone: duplicate one physical page across every
                # pool (models/transformer.copy_pool_page), cache donated
                self._cow_jit = jax.jit(copy_pool_page, **paste_donate)
                if model.prefill_cross is not None:
                    self._cross_jit = jax.jit(model.prefill_cross)

                    def _paste_cross(cache, ck, cv, slot):
                        c = dict(cache)
                        c["ck"] = c["ck"].at[:, slot].set(
                            ck[:, 0].astype(c["ck"].dtype))
                        c["cv"] = c["cv"].at[:, slot].set(
                            cv[:, 0].astype(c["cv"].dtype))
                        return c

                    self._paste_cross_jit = jax.jit(_paste_cross,
                                                    **paste_donate)
        else:
            def _paste(cache, pf, slot, pos):
                self.stats.paste_compiles += 1
                return _make_paste(self.cfg.family)(cache, pf, slot, pos)

        self._prefill_jit = jax.jit(_prefill)
        self._decode_jit = jax.jit(_decode, **donate)
        self._decode_sample_jit = jax.jit(_decode_sample, **donate)
        self._paste_jit = jax.jit(_paste, **paste_donate)
        # non-replay first-token sampler (recurrent families sample their
        # first output from the prefill logits, counter 0)
        self._sample1_jit = jax.jit(sample_tokens)
        self._proc1_jit = jax.jit(apply_logit_processors)
        # label the hot-loop jits for the retrace sanitizer: compile counts
        # per label back COMPILE_BUDGETS and the bench's
        # steady_state_retraces == 0 gate (analysis/sanitizer)
        register_entry_point("prefill", self._prefill_jit)
        register_entry_point("decode", self._decode_jit)
        register_entry_point("decode", self._decode_sample_jit)
        register_entry_point("paste", self._paste_jit)
        if getattr(self, "_chunk_jit", None) is not None:
            register_entry_point("chunk", self._chunk_jit)
        self._next_tok = np.zeros((n_slots, 1), np.int32)
        if self.paged:
            abs_cache = model.cache_shape(n_slots, max_len, self.kv_dtype,
                                          page_size=self.page_size,
                                          n_pages=self.n_pages)
        else:
            abs_cache = model.cache_shape(n_slots, max_len, self.kv_dtype)
        self._cache = jax.tree.map(
            lambda s: jnp.zeros(s.shape, s.dtype), abs_cache)
        if self.prefix_cache and getattr(self, "_cow_jit", None) is not None:
            # Warm the COW tail-clone NOW: its first use is the first
            # prefix-cache HIT, which otherwise pays the XLA compile
            # mid-serving — a latency spike on exactly the path whose point
            # is to be fast (caught by the steady-state retrace gate).
            # Cloning the null page onto itself is a no-op by construction.
            self._cache = self._cow_jit(self._cache, jnp.int32(0),
                                        jnp.int32(0))

    # ------------------------------------------------------------- lifecycle
    def submit(self, prompt: np.ndarray, max_new_tokens: int = 16,
               extras: Optional[Dict[str, np.ndarray]] = None,
               sample_params: Optional[tuple] = None,
               seed: int = 0, ttl_ticks: Optional[int] = None,
               rep_penalty: float = 1.0,
               logit_bias: Optional[Dict[int, float]] = None) -> Request:
        """Queue a request. sample_params=(temperature, top_k, top_p) turns
        on per-slot sampling for this request (None = greedy argmax, the
        temperature=0 fast path); `seed` keys its PRNG stream; `ttl_ticks`
        overrides the engine TTL for this request.

        rep_penalty != 1 applies the CTRL/HF repetition penalty over the
        request's prompt + emitted tokens; `logit_bias` ({token_id: bias})
        adds a per-token bias — both ride the sampled-decode jit and compose
        with greedy decoding (serve/sampling.apply_logit_processors).
        Degenerate penalties clamp to 1 (off); bias keys must be in-vocab
        and values finite.

        Malformed requests raise ValueError (nothing is enqueued, no state
        changes); a full admission queue raises EngineOverloaded — graceful
        backpressure instead of unbounded queue growth."""
        prompt = np.asarray(prompt, np.int32)
        if prompt.ndim != 1:
            raise ValueError(
                f"prompt must be a 1-D token array, got shape {prompt.shape}")
        if prompt.shape[0] < 1:
            raise ValueError("prompt must hold at least one token")
        if prompt.shape[0] > self.max_len:
            raise ValueError(
                f"prompt length {prompt.shape[0]} exceeds engine max_len "
                f"{self.max_len}")
        if max_new_tokens < 1:
            raise ValueError(
                f"max_new_tokens must be >= 1, got {max_new_tokens}")
        if self.paged:
            need = self._pages_for(prompt.shape[0], max_new_tokens)
            if need > self.n_pages - 1:
                raise ValueError(
                    f"request needs {need} pages; pool has {self.n_pages - 1}")
        if self.max_queue is not None and len(self._queue) >= self.max_queue:
            self.stats.rejected += 1
            raise EngineOverloaded(
                f"admission queue at cap ({self.max_queue}); retry later")
        temperature, top_k, top_p = 0.0, 0, 1.0
        if sample_params is not None:
            # degenerate params clamp to well-defined behavior (PR 5):
            # temperature < 0 → greedy, top_p=0 → filtered argmax, top_k out
            # of range → filter off — see serve/sampling.clamp_sample_params
            temperature, top_k, top_p = clamp_sample_params(*sample_params)
        rep_penalty = clamp_rep_penalty(rep_penalty)
        if logit_bias:
            for tok, bias in logit_bias.items():
                if not 0 <= int(tok) < self.cfg.vocab_size:
                    raise ValueError(
                        f"logit_bias token {tok} outside vocab "
                        f"[0, {self.cfg.vocab_size})")
                if not math.isfinite(float(bias)):
                    raise ValueError(
                        f"logit_bias[{tok}] must be finite, got {bias}")
        self._next_rid += 1
        req = Request(rid=self._next_rid, prompt=prompt,
                      max_new_tokens=max_new_tokens, extras=extras,
                      temperature=float(temperature), top_k=int(top_k),
                      top_p=float(top_p), seed=int(seed),
                      rep_penalty=rep_penalty,
                      logit_bias=dict(logit_bias) if logit_bias else None,
                      t_enqueue=time.time(),
                      submit_tick=self._tick, ttl_ticks=ttl_ticks)
        if ttl_ticks is not None:
            self._any_ttl = True
        self._queue.append(req)
        return req

    def _pages_for(self, plen: int, max_new: int) -> int:
        """Pages reserved at admission: every row the request can ever write
        (prompt + generated, one row per generated token, capacity-capped).

        Window configs reserve only the live span: pages below the attention
        window's floor are never backed, and ceil(window/page)+2 pages are
        enough to slide the window to the end of the request (out-of-window
        pages are recycled forward every tick — see `_recycle_window_pages`),
        so occupancy is O(window), not O(position). Chunked windowed prefill
        starts its mapping at logical page 0 (the first chunk writes row 0)
        and recycles forward between chunks, so it needs the same
        ceil(window/page)+2 budget but no live_lo offset."""
        lo = 0 if (self.chunked or not self._window) else self._live_lo(plen)
        return reserve_page_count(plen, max_new, max_len=self.max_len,
                                  page_size=self.page_size,
                                  window=self._window, lo=lo)

    def _live_lo(self, plen: int) -> int:
        """First logical page a window request can still read or write at its
        first decode step (the replay writes position plen-1)."""
        return max(0, plen - 1 - self._window) // self.page_size

    def _window_pages(self) -> int:
        return window_page_budget(self._window, self.page_size)

    def kv_cache_bytes(self) -> int:
        return sum(x.size * x.dtype.itemsize
                   for x in jax.tree.leaves(self._cache))

    def _sample_state(self, slot: int, r: Request):
        self._temp[slot] = r.temperature
        self._topk[slot] = r.top_k
        self._topp[slot] = r.top_p
        self._sseed[slot] = r.seed
        self._rep_pen[slot] = r.rep_penalty
        self._bias[slot] = 0.0
        for tok, bias in (r.logit_bias or {}).items():
            self._bias[slot, int(tok)] = bias
        self._bias_on[slot] = bool(r.logit_bias)
        # the penalty's "seen" set covers the whole live prompt — on resume
        # that already includes the emitted tokens, so a preempted stream's
        # penalties are identical to its uninterrupted twin's
        self._seen[slot] = False
        self._seen[slot, r.live_prompt()] = True

    def _admit(self):
        """Admit queued requests into free slots.

        Paged engines additionally reserve the request's worst-case page
        count up front; if the free list can't cover the queue head, admission
        stalls (FIFO — no small-request overtaking) until retirements return
        pages. Chunked engines only reserve + (encdec) compute cross K/V
        here — the prompt itself prefills one chunk per tick in
        `_prefill_tick`, so admission never stalls the decode batch.

        Resumed requests (preempted with emitted tokens) admit on their
        `live_prompt()` — prompt + out_tokens — and `remaining_new()` budget;
        the page reservation is invariant under resume
        (min(max_len, (plen+k) + (max_new-k)) == min(max_len, plen+max_new)),
        so a preempted request never needs more pages than it first did."""
        self._page_blocked = False
        for slot in [i for i, r in enumerate(self._slots) if r is None]:
            if not self._queue:
                return
            r = self._queue[0]
            lp = r.live_prompt()
            plen = lp.shape[0]
            rem = r.remaining_new()
            page_row = None
            r.cached_prompt_tokens = 0
            if self.paged:
                need = self._pages_for(plen, rem)
                hits, _ = self._prefix_lookup(r, lp)
                digs = None
                n_cand = plen // self.page_size if self.prefix_cache else 0
                if len(hits) < n_cand:
                    digs = prefix_digests(lp, self.page_size, n_cand,
                                          request_seed_digest(r.extras))
                    owner = self._pending_digest.get(digs[len(hits)])
                    if owner is not None and owner != r.rid:
                        # in-flight dedup: the head's first missing page is
                        # being prefilled by a live slot right now — hold
                        # admission (FIFO) and hit the registry once it
                        # lands instead of prefilling the same bytes twice.
                        # NOT a page starvation: no preemption pressure.
                        return
                n_shared, cow_src = self._share_plan(plen, hits)
                shared = hits[:n_shared]
                n_private = need - n_shared
                # hit pages resident in the LRU leave the allocatable set
                # the instant we incref them — account for that BEFORE
                # committing (cow_src is pinned during the clone, so it
                # counts too)
                pinned = sum(1 for p in shared if self._ref[p] == 0)
                if cow_src is not None and self._ref[cow_src] == 0:
                    pinned += 1
                if self._allocatable() - pinned < n_private:
                    # head starved on pages while a slot sits free: the
                    # signal step() counts toward preemption
                    self._page_blocked = True
                    return
                # commit order: protect the hit pages FIRST (incref pulls
                # them out of the eviction set), then allocate privates
                for p in shared:
                    self._incref(p)
                if cow_src is not None:
                    self._incref(cow_src)
                pages = [self._alloc_page() for _ in range(n_private)]
                if cow_src is not None:
                    # copy-on-write: the replay decode WRITES position
                    # plen-1, so a fully-cached tail page is cloned into
                    # the slot's first private page instead of recomputed
                    self.stats.cow_copies += 1
                    self._cache = self._cow_jit(
                        self._cache, jnp.int32(cow_src),
                        jnp.int32(pages[0]))
                    self._decref_page(cow_src)
                lo = self._live_lo(plen) \
                    if (self._window and not self.chunked) else 0
                mapping = {j: p for j, p in enumerate(shared)}
                for i, p in enumerate(pages):
                    mapping[lo + n_shared + i] = p
                self._slot_pages[slot] = mapping
                self._slot_cap[slot] = -(-min(self.max_len, plen + rem)
                                         // self.page_size)
                cached = (n_shared + (cow_src is not None)) * self.page_size
                r.cached_prompt_tokens = cached
                if self.prefix_cache:
                    if cached:
                        self.stats.prefix_hits += 1
                        self.stats.prefix_hit_tokens += cached
                    else:
                        self.stats.prefix_misses += 1
                page_row = np.zeros((self.pages_per_seq,), np.int32)
                for j, p in mapping.items():
                    page_row[j] = p
            self._queue.pop(0)
            self.stats.prefills += 1
            self.stats.prefill_tokens += plen - r.cached_prompt_tokens
            self._sample_state(slot, r)
            if self.chunked:
                # reserve-only admission: the slot's cache table row stays on
                # the null page (decode's garbage writes can't touch reserved
                # pages) until the final chunk stamps it in _prefill_tick
                self._slots[slot] = r
                self._active[slot] = False
                self._fresh[slot] = False
                self._chunk_next[slot] = r.cached_prompt_tokens
                if self.model.prefill_cross is not None:
                    cross = self._cross_jit(self.params, {
                        "frames": jnp.asarray(r.extras["frames"])[None]})
                    self._cache = self._paste_cross_jit(
                        self._cache, cross["ck"], cross["cv"],
                        jnp.int32(slot))
                if r.cached_prompt_tokens >= plen:
                    # FULL hit: every prompt page is already in the pool
                    # (shared run + COW-cloned tail) — zero prefill chunks.
                    # The slot goes live immediately and its first token
                    # arrives from THIS tick's decode: TTFT collapses to
                    # one decode step
                    self._register_prefix(slot, r, lp)
                    self._cache = self._finalize_jit(
                        self._cache, jnp.int32(slot), jnp.int32(plen - 1),
                        jnp.asarray(page_row))
                    self._next_tok[slot, 0] = int(lp[-1])
                    self._fresh[slot] = True
                    self._active[slot] = True
                else:
                    self._prefill_fifo.append(slot)
                    if digs is not None:
                        # claim the pages this slot will register, so
                        # identical prompts behind it wait for the cache
                        mine = self._pending_by_rid.setdefault(r.rid, [])
                        for d in digs[len(hits):]:
                            if d not in self._pending_digest:
                                self._pending_digest[d] = r.rid
                                mine.append(d)
                continue
            blen = bucket_length(plen, self.max_len) if self.bucket_prompts \
                else plen
            toks = np.zeros((1, blen), np.int32)
            toks[0, :plen] = lp
            batch = {"tokens": jnp.asarray(toks)}
            if self.kv_dtype != jnp.float32:
                # lossy KV storage: prefill attends the rounded values the
                # cache will hold (zero-size marker, dtype carries the info)
                batch["kv_round"] = jnp.zeros((0,), self.kv_dtype)
            for key, val in (r.extras or {}).items():
                batch[key] = jnp.asarray(val)[None]
            logits, pf_cache = self._prefill_jit(self.params, batch)
            self.stats.prefill_pad_tokens += blen - plen
            self._tick_prefill_tokens += blen
            paste_args = () if page_row is None else (jnp.asarray(page_row),)
            if self._replay:
                # Cache rows [0, plen) are exact under trailing padding; the
                # next decode step replays prompt[-1] at position plen-1,
                # producing the first output token through the decode path
                # (pad rows ≥ plen are masked by kv_len until overwritten).
                self._cache = self._paste_jit(
                    self._cache, pf_cache, jnp.int32(slot),
                    jnp.int32(plen - 1), *paste_args)
                self._next_tok[slot, 0] = int(lp[-1])
            else:
                lv = jnp.asarray(logits[:, -1, :self.cfg.vocab_size],
                                 jnp.float32)
                if r.rep_penalty != 1.0 or r.logit_bias:
                    # non-replay first token: processors apply here too —
                    # _sample_state already loaded this slot's seen/bias rows
                    lv = self._proc1_jit(
                        lv, jnp.full((1,), r.rep_penalty, jnp.float32),
                        jnp.asarray(self._seen[slot][None]),
                        jnp.asarray(self._bias[slot][None]))
                if r.temperature > 0:
                    first = int(np.asarray(self._sample1_jit(
                        lv, jnp.full((1,), r.temperature, jnp.float32),
                        jnp.full((1,), r.top_k, jnp.int32),
                        jnp.full((1,), r.top_p, jnp.float32),
                        jnp.full((1,), r.seed, jnp.int32),
                        jnp.zeros((1,), jnp.int32)))[0])
                else:
                    first = int(np.argmax(np.asarray(lv[0])))
                self._cache = self._paste_jit(
                    self._cache, pf_cache, jnp.int32(slot), jnp.int32(plen),
                    *paste_args)
                r.out_tokens.append(first)
                if r.t_first_token is None:
                    r.t_first_token = time.time()
                    r.first_token_tick = self._tick
                self._next_tok[slot, 0] = first
                self._seen[slot, first] = True
                self.stats.tokens_out += 1
                if plen >= self.max_len \
                        or len(r.out_tokens) >= r.max_new_tokens:
                    # done at admission: the cache is already full (no
                    # writable row for a decode step) or the prefill token
                    # exhausted the budget — never occupy a decode slot
                    r.done = True
                    r.t_done = time.time()
                    self.stats.record_request(r)
                    self._release(slot)
                    continue
            self._fresh[slot] = self._replay
            self._slots[slot] = r
            self._active[slot] = True

    def cancel(self, req: Request) -> None:
        """Retire a request at ANY lifecycle stage with exact pool
        accounting: queued → dequeue (nothing reserved yet); mid-prefill →
        drain its remaining chunk queue and return EVERY reserved page to
        the pool (the reservation-leak path this fixes: a slot released with
        chunks still queued used to be assumed unreachable); decoding →
        release the slot like a normal retirement."""
        if req.done:
            return
        if req in self._queue:
            self._queue.remove(req)
        elif req in self._slots:
            self._release(self._slots.index(req))
        req.done = True
        req.t_done = time.time()

    def _release(self, slot: int):
        """Return a finished slot to the pool and drain any queued prefill
        work it still holds (mid-prefill retirement must leak nothing)."""
        if self._slots[slot] is not None:
            self._clear_pending(self._slots[slot].rid)
        self._slots[slot] = None
        self._active[slot] = False
        self._fresh[slot] = False
        self._temp[slot], self._topk[slot] = 0.0, 0
        self._topp[slot], self._sseed[slot] = 1.0, 0
        self._rep_pen[slot] = 1.0
        self._bias[slot], self._bias_on[slot] = 0.0, False
        self._seen[slot] = False
        if slot in self._prefill_fifo:          # mid-prefill: drain chunks
            self._prefill_fifo.remove(slot)
        if self.chunked:
            self._chunk_next[slot] = 0
        if self.paged:
            freed = self._slot_pages[slot]
            if freed:
                # slots hold REFERENCES: a shared page survives its
                # releasing slot and only leaves the live set at refcount 0
                for phys in freed.values():
                    self._decref_page(phys)
                self._slot_pages[slot] = {}
            self._cache = self._unmap_jit(self._cache, jnp.int32(slot))

    # ------------------------------------- ref-counted page allocator (PR 8)
    def _allocatable(self) -> int:
        """Pages an admission can obtain right now: the free list plus every
        refcount-zero cached page (evictable on demand)."""
        return len(self._free_pages) + len(self._lru)

    def pages_allocatable(self) -> int:
        """Public twin of the classic free-list length: pages obtainable by
        new work. With the prefix cache off (or cold) this equals
        len(_free_pages); after cache traffic, refcount-zero cached pages
        parked in the LRU count too — they are one eviction away from
        free."""
        return self._allocatable()

    def _unregister(self, phys: int):
        h = self._page_hash.pop(phys, None)
        if h is not None and self._by_hash.get(h) == phys:
            del self._by_hash[h]

    def _page_live(self, d: int):
        self.stats.pages_in_use += d
        if d > 0:
            self.stats.peak_pages_in_use = max(
                self.stats.peak_pages_in_use, self.stats.pages_in_use)
        self.stats.prefix_cached_pages = len(self._lru)

    def _alloc_page(self) -> int:
        """One private page: pop the free list, else evict the
        least-recently-used refcount-zero cached page. Callers check
        `_allocatable()` BEFORE committing an admission."""
        if self._free_pages:
            p = self._free_pages.pop()
        else:
            p, _ = self._lru.popitem(last=False)    # oldest first
            self._unregister(p)
            self.stats.prefix_evictions += 1
        self._ref[p] = 1
        self._page_live(+1)
        return p

    def _incref(self, phys: int):
        if self._ref[phys] == 0:
            # cached page comes back live: out of the LRU, safe from
            # eviction for as long as any slot maps it
            self._lru.pop(phys, None)
            self._page_live(+1)
        self._ref[phys] += 1

    def _decref_page(self, phys: int):
        self._ref[phys] -= 1
        assert self._ref[phys] >= 0, int(phys)
        if self._ref[phys] == 0:
            self._page_live(-1)
            if self.prefix_cache and phys in self._page_hash:
                # registered content survives at refcount zero — parked in
                # the LRU until a future admission hits it or evicts it
                self._lru[phys] = None
            else:
                self._unregister(phys)
                self._free_pages.append(phys)
        self.stats.prefix_cached_pages = len(self._lru)

    def _prefix_lookup(self, r: Request, lp: np.ndarray):
        """Longest cached run over lp's FULL prompt pages (module-level
        lookup_prefix_hits — ONE shared copy with the shard scheduler)."""
        if not self.prefix_cache:
            return [], []
        hits = lookup_prefix_hits(self._by_hash, lp, self.page_size,
                                  seed=request_seed_digest(r.extras))
        return hits, []

    def _share_plan(self, plen: int, hits: List[int]):
        return prefix_share_plan(plen, hits, self.page_size)

    def _register_prefix(self, slot: int, r: Request, lp: np.ndarray):
        """Content-register the slot's fully-prefilled FULL prompt pages so
        later admissions can share them. Valid because decode only writes
        positions >= plen-1: pages strictly below the tail are never touched
        again, and a plen%page_size==0 tail page only takes the replay's
        byte-identical rewrite (schedule-independent KV rounding, PR 4)."""
        self._clear_pending(r.rid)
        if not self.prefix_cache:
            return
        register_prefix_pages(self._slot_pages[slot], lp, self.page_size,
                              request_seed_digest(r.extras),
                              self._page_hash, self._by_hash)

    def _clear_pending(self, rid: int) -> None:
        """Drop a request's in-flight dedup claims (registration landed, or
        the slot died mid-prefill) so deferred twins stop waiting on it."""
        for d in self._pending_by_rid.pop(rid, ()):
            if self._pending_digest.get(d) == rid:
                del self._pending_digest[d]

    def assert_accounting(self):
        """Ref-counted pool invariant: every non-null physical page is in
        EXACTLY one of {free list, live (mapped by >=1 slot), cached LRU,
        stolen stash}; per-page mapping references equal the refcounts; the
        pages_in_use gauge equals the unique live count."""
        assert self.paged
        free, lru = set(self._free_pages), set(self._lru)
        live = {p for m in self._slot_pages for p in m.values()}
        stolen = set(self._stolen_pages)
        assert len(free) == len(self._free_pages), "free list duplicates"
        sets = (free, lru, live, stolen)
        for i, a in enumerate(sets):
            assert 0 not in a, "null page leaked into the pool"
            for b in sets[i + 1:]:
                assert not (a & b), (free, lru, live, stolen)
        assert len(free) + len(lru) + len(live) + len(stolen) \
            == self.n_pages - 1, (len(free), len(lru), len(live),
                                  len(stolen), self.n_pages)
        refs = np.zeros_like(self._ref)
        for m in self._slot_pages:
            for p in m.values():
                refs[p] += 1
        assert np.array_equal(refs, self._ref), (refs, self._ref)
        assert self.stats.pages_in_use == len(live), \
            (self.stats.pages_in_use, len(live))
        for p in self._lru:
            assert p in self._page_hash, p

    # ---------------------------------------------------------------- prefill
    def _page_row(self, slot: int) -> np.ndarray:
        return page_row_of(self._slot_pages[slot], self.pages_per_seq)

    def _prefill_tick(self) -> bool:
        """Run AT MOST ONE fixed-size prefill chunk (FIFO over mid-prefill
        slots; the head slot finishes all its chunks first — shortest time
        to first token for the oldest admitted request)."""
        if not self._prefill_fifo:
            return False
        slot = self._prefill_fifo[0]
        r = self._slots[slot]
        s = self._chunk_next[slot]
        # resumed requests re-prefill prompt + already-emitted tokens; stable
        # across chunks because a mid-prefill slot is inactive (no decode
        # appends to out_tokens until finalize)
        lp = r.live_prompt()
        plen = lp.shape[0]
        C = self.chunk_tokens
        if self._window and s:
            # free/remap pages that no chunk row >= s can still read — a
            # prompt longer than the window holds O(window) pages while
            # prefilling; the cache table row is still null, so this is pure
            # host bookkeeping until finalize stamps the row
            self._recycle_slot_pages(slot, s, in_cache=False)
        n = min(C, plen - s)
        toks = np.zeros((1, C), np.int32)
        toks[0, :n] = lp[s:s + n]
        page_row = self._page_row(slot)
        batch = {"tokens": jnp.asarray(toks),
                 "start": jnp.full((1,), s, jnp.int32),
                 "length": jnp.full((1,), n, jnp.int32),
                 "page_row": jnp.asarray(page_row)}
        if self.cfg.family == "vlm":
            pe = np.asarray((r.extras or {}).get(
                "patch_embeds", np.zeros((0, self.cfg.d_model), np.float32)))
            rows = np.zeros((1, C, self.cfg.d_model), np.float32)
            if s < pe.shape[0]:
                m = min(C, pe.shape[0] - s)
                rows[0, :m] = pe[s:s + m]
            batch["patch_rows"] = jnp.asarray(rows)
            batch["n_patch"] = jnp.full((1,), pe.shape[0], jnp.int32)
        if self.cfg.family == "encdec":
            batch["slot"] = jnp.int32(slot)
        self._cache = self._chunk_jit(self.params, batch, self._cache)
        self.stats.prefill_chunks += 1
        self.stats.prefill_pad_tokens += C - n
        self._tick_prefill_tokens += C
        if s + C >= plen:                      # final chunk — slot goes live
            self._prefill_fifo.pop(0)
            # the slot's full prompt pages are now byte-final: register them
            # for prefix sharing before decode starts appending
            self._register_prefix(slot, r, lp)
            self._cache = self._finalize_jit(
                self._cache, jnp.int32(slot), jnp.int32(plen - 1),
                jnp.asarray(page_row))
            self._next_tok[slot, 0] = int(lp[-1])
            self._fresh[slot] = True
            self._active[slot] = True
        else:
            self._chunk_next[slot] = s + C
        return True

    # ----------------------------------------------------------------- decode
    def step(self) -> bool:
        """One engine tick: apply scheduled faults, expire TTLs, admit new
        work (preempting a young decoding slot if the head has starved on
        pages), run at most one prefill chunk, then one batched decode step
        over the live slots."""
        self._tick += 1
        if self.fault_plan is not None:
            self._apply_faults()
        if self._any_ttl:
            self._expire_ttl()
        had_decode = bool(np.any(self._active))
        self._tick_prefill_tokens = 0
        self._admit()
        if self._page_blocked:
            self._starved += 1
            if self._starved >= self.preempt_after and self._preempt_once():
                self._admit()
        else:
            self._starved = 0
        chunk_ran = self._prefill_tick() if self.chunked else False
        if had_decode and self._tick_prefill_tokens > self.chunk_tokens:
            # decode batch waited on more than one chunk's worth of prefill
            # this tick — the head-of-line blocking chunking eliminates
            self.stats.decode_stall_ticks += \
                -(-self._tick_prefill_tokens // self.chunk_tokens) - 1
        decoding = [i for i, r in enumerate(self._slots)
                    if r is not None and self._active[i]]
        if not decoding:
            return chunk_ran
        if any(self._temp[i] > 0 or self._rep_pen[i] != 1.0
               or self._bias_on[i] for i in decoding):
            counter = np.asarray(
                [len(r.out_tokens) if r is not None else 0
                 for r in self._slots], np.int32)
            sample = {"temperature": jnp.asarray(self._temp),
                      "top_k": jnp.asarray(self._topk),
                      "top_p": jnp.asarray(self._topp),
                      "seed": jnp.asarray(self._sseed),
                      "counter": jnp.asarray(counter),
                      "rep_penalty": jnp.asarray(self._rep_pen),
                      "seen": jnp.asarray(self._seen),
                      "bias": jnp.asarray(self._bias)}
            toks, self._cache = self._decode_sample_jit(
                self.params, {"tokens": jnp.asarray(self._next_tok)},
                self._cache, jnp.asarray(self._active), sample)
        else:
            toks, self._cache = self._decode_jit(
                self.params, {"tokens": jnp.asarray(self._next_tok)},
                self._cache, jnp.asarray(self._active))
        self.stats.decode_steps += 1
        self.stats.occupancy_sum += len(decoding) / self.n_slots
        nxt = np.asarray(toks, np.int32)
        pos = np.asarray(self._cache["pos"])   # ONE host sync per step
        for slot in decoding:
            r = self._slots[slot]
            r.out_tokens.append(int(nxt[slot]))
            self._next_tok[slot, 0] = nxt[slot]
            self._seen[slot, int(nxt[slot])] = True   # rep-penalty tracking
            self.stats.tokens_out += 1
            if self._fresh[slot]:
                if r.t_first_token is None:   # resumed slots keep the original
                    r.t_first_token = time.time()
                    r.first_token_tick = self._tick
                self._fresh[slot] = False
            # retire when out of budget OR out of cache: `pos` is the next
            # write index, so the slot can take another decode step iff
            # pos < max_len (the seed's `max_len - 1` retired one writable
            # row early, and one row earlier still on the replay path)
            if len(r.out_tokens) >= r.max_new_tokens \
                    or int(pos[slot]) >= self.max_len:
                r.done = True
                r.t_done = time.time()
                self.stats.record_request(r)
                self._release(slot)
        if self._window:
            self._recycle_window_pages(pos)
        return True

    def _recycle_window_pages(self, pos):
        """Free pages that fell fully out of the attention window.

        A freed page either becomes the slot's next logical page (the table
        entry moves forward, no pool traffic — the window slides in place) or,
        once the request's whole span is mapped, returns to the free list so
        queued requests can admit. Runs on the already-synced `pos`; at most
        one page transitions per slot per page_size ticks. Mid-prefill slots
        are SKIPPED — their cache `pos` is stale (chunk progress drives their
        recycling in `_prefill_tick` instead)."""
        for slot, r in enumerate(self._slots):
            if r is None or not self._active[slot] \
                    or not self._slot_pages[slot]:
                continue
            self._recycle_slot_pages(slot, int(pos[slot]), in_cache=True)

    def _recycle_slot_pages(self, slot: int, progress: int, *, in_cache: bool):
        """Recycle one slot's dead pages given `progress` = the next write
        index (decode: synced pos; chunked prefill: the next chunk's start).
        `in_cache` mirrors the remap/unmap into the cache's page-table row —
        False while the slot is mid-prefill and its row is still null."""
        remaps, unmaps = recycle_dead_pages(
            self._slot_pages[slot], self._slot_cap[slot],
            self.page_size, self._window, progress)
        for _, phys in unmaps:
            # window pages are exclusively owned (prefix cache is off under
            # a sliding window) — the decref drops them straight to free
            self._decref_page(phys)
        if in_cache:
            for j, nxt, phys in remaps:
                self._cache = self._remap_entry_jit(
                    self._cache, jnp.int32(slot), jnp.int32(j),
                    jnp.int32(nxt), jnp.int32(phys))
            for j, _ in unmaps:
                self._cache = self._unmap_entry_jit(
                    self._cache, jnp.int32(slot), jnp.int32(j))

    # ------------------------------------------- fault tolerance (PR 6)
    def _apply_faults(self):
        """Apply this tick's FaultPlan events. The single-host engine is
        "shard 0" of a one-shard fleet: it honors the page-pool events and
        ignores shard-level ones (death/rejoin/sensor need a fleet — see
        serve/sharded)."""
        for e in self.fault_plan.events_at(self._tick):
            if not self.paged or e.shard != 0:
                continue
            if e.kind == "page_squeeze":
                # steal free pages first; once the free list is dry, evict
                # refcount-zero cached pages (LRU) — capacity pressure
                # reclaims the prefix cache before it blocks live work
                take = min(e.pages, self._allocatable())
                for _ in range(take):
                    if self._free_pages:
                        p = self._free_pages.pop()
                    else:
                        p, _ = self._lru.popitem(last=False)
                        self._unregister(p)
                        self.stats.prefix_evictions += 1
                    self._stolen_pages.append(p)
                self.stats.prefix_cached_pages = len(self._lru)
                self.stats.faults_injected += 1
            elif e.kind == "page_restore":
                self._free_pages.extend(self._stolen_pages)
                self._stolen_pages.clear()
                self.stats.faults_injected += 1

    def _expire_ttl(self):
        """Retire queued and live requests past their TTL (ticks since
        submit). Timed-out requests release their pages/slot exactly like a
        completed one; `timed_out` marks them for the caller."""
        def expired(r: Request) -> bool:
            ttl = r.ttl_ticks if r.ttl_ticks is not None else self.ttl_ticks
            return ttl is not None and self._tick - r.submit_tick > ttl

        for r in [q for q in self._queue if expired(q)]:
            self._queue.remove(r)
            r.done = True
            r.timed_out = True
            r.t_done = time.time()
            self.stats.timeouts += 1
        for slot, r in enumerate(self._slots):
            if r is not None and expired(r):
                r.done = True
                r.timed_out = True
                r.t_done = time.time()
                self.stats.timeouts += 1
                self._release(slot)

    def _requeue(self, r: Request):
        """Re-enqueue a preempted request in rid order — it rejoins the FIFO
        exactly where its age puts it, ahead of anything younger."""
        i = 0
        while i < len(self._queue) and self._queue[i].rid < r.rid:
            i += 1
        self._queue.insert(i, r)

    def _preempt_once(self) -> bool:
        """Evict ONE decoding slot so the starving queue head can admit.

        Victim: the YOUNGEST (max rid) active decoding slot that is strictly
        younger than the head, still under its preemption budget, and whose
        pages (plus the free list) actually cover the head's need. Strict
        rid ordering makes progress monotone — a preempted request that
        becomes head can never preempt something older, so there is no
        preemption livelock. The victim's emitted tokens ride along in
        out_tokens and re-enter as prefill (see live_prompt), so its stream
        resumes token-exact."""
        if not self._queue:
            return False
        head = self._queue[0]
        hlp = head.live_prompt()
        need = self._pages_for(hlp.shape[0], head.remaining_new())
        # pages the head would actually have to ALLOCATE: shared hits stay
        # resident through the preemption, so only the private remainder
        # must come out of the victim + free/LRU
        hits, _ = self._prefix_lookup(head, hlp)
        n_shared, cow_src = self._share_plan(hlp.shape[0], hits)
        need -= n_shared
        # hit pages sitting in the LRU count as allocatable but get pinned
        # at admission — mirror _admit's availability math
        need += sum(1 for p in hits[:n_shared] if self._ref[p] == 0)
        if cow_src is not None and self._ref[cow_src] == 0:
            need += 1
        best = None
        for slot, r in enumerate(self._slots):
            if r is None or not self._active[slot] \
                    or slot in self._prefill_fifo:
                continue
            if r.rid <= head.rid or r.preemptions >= self.max_preemptions:
                continue
            # only the victim's EXCLUSIVELY-owned pages (ref 1) become
            # allocatable at release; shared pages just drop a reference
            exclusive = sum(1 for p in self._slot_pages[slot].values()
                            if self._ref[p] == 1)
            if exclusive + self._allocatable() < need:
                continue
            if best is None or r.rid > self._slots[best].rid:
                best = slot
        if best is None:
            return False
        victim = self._slots[best]
        victim.preemptions += 1
        self._release(best)
        self._requeue(victim)
        self.stats.preemptions += 1
        self.stats.retries += 1
        self._starved = 0
        return True

    def run_to_completion(self, max_ticks: int = 10_000) -> EngineStats:
        ticks = 0
        while (self._queue or any(r is not None for r in self._slots)) \
                and ticks < max_ticks:
            self.step()
            ticks += 1
        return self.stats


def generate_greedy(model, params, prompt: np.ndarray, n_tokens: int,
                    max_len: int = 128, paged: bool = False,
                    wdtype: Optional[str] = None,
                    kv_dtype: Optional[str] = None,
                    extras: Optional[Dict[str, np.ndarray]] = None) -> List[int]:
    """Single-request reference path (the oracle for engine equivalence).

    Runs with bucketing OFF — exact-length prefill — and a DENSE cache by
    default, so equivalence tests against a bucketed/paged/chunked engine
    actually exercise the padded-prefill + replay, page-table and
    chunk-streaming paths instead of comparing them to themselves. With
    wdtype/kv_dtype this is the dense INT8 oracle: row quantization is
    layout-independent AND schedule-independent (prefill attends the rounded
    rows the cache stores — models/transformer._round_kv), so a paged or
    chunked int8 engine must reproduce its tokens exactly."""
    eng = ServeEngine(model, n_slots=1, max_len=max_len, params=params,
                      bucket_prompts=False, paged=paged, wdtype=wdtype,
                      kv_dtype=kv_dtype)
    req = eng.submit(prompt, max_new_tokens=n_tokens, extras=extras)
    eng.run_to_completion()
    return req.out_tokens
