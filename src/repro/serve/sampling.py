"""Per-slot token sampling for the serving engine's jitted decode step.

One vmapped sampler over the decode batch: every slot carries its own
(temperature, top_k, top_p) parameters and its own PRNG stream, all as plain
arrays, so the whole batch samples inside the SINGLE decode jit — no retrace
when requests with different sampling configs share the batch, no extra host
sync (only the sampled (B,) tokens cross the device boundary, exactly like
the old argmax path).

PRNG determinism: a slot's key for its i-th output token is
`fold_in(key(seed), i)` — a pure function of the REQUEST's (seed, token
index), independent of slot assignment, batch composition, or how prefill
was chunked. Same seed → same tokens, re-run to re-run and engine to engine.

Greedy is the `temperature <= 0` fast path: those rows take `argmax` of the
RAW logits (not the masked/scaled ones), bit-identical to the pre-sampling
engine — the equivalence the temperature=0 ≡ greedy tests pin.

One descending argsort serves both filters (sorting twice — logits for
top-k, probs for top-p — would double the sampler's dominant O(V log V)
cost): top-k keeps the first k sorted positions (ties at the k-th value
resolve by the stable sort's token-id order), and top-p keeps the smallest
sorted prefix whose softmax mass reaches p (the top token always
survives). top_k=0 and top_p>=1 disable their filters.

Degenerate parameters clamp to well-defined behavior (PR 5) instead of
producing NaN / all-NEG_INF rows:
  * top_k >= vocab: no rank can be filtered — identical to top_k=0 (off);
  * top_p == 0.0: the exclusive-prefix-mass rule would drop EVERY rank
    (rank 0's prefix mass is 0, and 0 < 0 is false) leaving an all-NEG_INF
    categorical → the top sorted token is always kept, so top_p=0 is the
    argmax of the top-k-filtered, temperature-scaled distribution;
  * temperature < 0: treated as 0 — the greedy raw-argmax fast path
    (`clamp_sample_params` normalizes host-side params the same way so
    engine validation and the in-jit sampler agree).
"""

from __future__ import annotations

import math

import jax
import jax.numpy as jnp

from repro.models.attention import NEG_INF


def clamp_sample_params(temperature, top_k, top_p):
    """Host-side normalization of degenerate sampling params to the
    well-defined behaviors `_sample_one` implements: negative temperature →
    0 (greedy), negative top_k → 0 (off; >= vocab is equivalent to off
    in-kernel), top_p clipped into [0, 1] (0 = argmax of the filtered
    distribution, 1 = off). NaNs map to the same safe ends (temperature →
    greedy, top_p → filter off) instead of poisoning the device-side
    softmax/cumsum — max/min comparisons against NaN would otherwise leak
    it straight through the clamps."""
    temperature = float(temperature)
    top_p = float(top_p)
    if math.isnan(temperature):
        temperature = 0.0
    if math.isnan(top_p):
        top_p = 1.0
    return (max(0.0, temperature), max(0, int(top_k)),
            min(1.0, max(0.0, top_p)))


def clamp_rep_penalty(penalty) -> float:
    """Host-side normalization of a repetition penalty: 1.0 is the identity,
    NaN and non-positive values clamp to it (a penalty of 0 would divide
    positive logits by zero device-side; negative would flip signs). Values
    in (0, 1) are legal — they *reward* repetition, the HF convention."""
    penalty = float(penalty)
    if math.isnan(penalty) or penalty <= 0.0:
        return 1.0
    return penalty


def apply_logit_processors(logits, rep_penalty, seen, bias):
    """Per-slot logit processors, applied before `sample_tokens` inside the
    sampled-decode jit (and to the raw logits of greedy rows — a repetition
    penalty with temperature 0 is still meaningful, and rows with
    rep_penalty=1 / zero bias pass through bit-identical).

    logits (B, V) f32; rep_penalty (B,) f32; seen (B, V) bool — tokens in
    the slot's prompt or already emitted; bias (B, V) f32 additive per-token
    bias. Repetition penalty follows the CTRL/HF convention: seen tokens
    with positive logits are divided by the penalty, negative multiplied —
    both directions push seen tokens down for penalty > 1.
    """
    pen = rep_penalty[:, None]
    penalized = jnp.where(logits > 0, logits / pen, logits * pen)
    logits = jnp.where(seen, penalized, logits)
    return logits + bias


def _sample_one(logits, temperature, top_k, top_p, seed, counter):
    """logits (V,) f32 → sampled token () int32."""
    v = logits.shape[-1]
    greedy = jnp.argmax(logits).astype(jnp.int32)
    # ONE descending sort; both filters run in rank space, and the sampled
    # rank maps back to a token id through `order`
    order = jnp.argsort(-logits)
    ld = logits[order]
    ranks = jnp.arange(v)
    lk = jnp.where((top_k > 0) & (ranks >= jnp.clip(top_k, 1, v)),
                   NEG_INF, ld)
    lt = lk / jnp.maximum(temperature, 1e-6)
    probs = jax.nn.softmax(lt)                    # already descending
    # exclusive prefix mass; rank 0 is ALWAYS kept so top_p=0 degrades to
    # the argmax of the filtered distribution instead of an all-NEG_INF row
    keep = ((jnp.cumsum(probs) - probs) < top_p) | (ranks == 0)
    lt = jnp.where((top_p < 1.0) & ~keep, NEG_INF, lt)
    key = jax.random.fold_in(jax.random.key(seed), counter)
    sampled = order[jax.random.categorical(key, lt)].astype(jnp.int32)
    return jnp.where(temperature > 0, sampled, greedy)


def sample_tokens(logits, temperature, top_k, top_p, seed, counter):
    """Batched per-slot sampling.

    logits (B, V) f32; temperature/top_p (B,) f32; top_k/seed/counter (B,)
    int32 — `counter` is the slot's output-token index (engine-maintained),
    which keys the per-token PRNG stream. Returns (B,) int32 tokens.
    """
    return jax.vmap(_sample_one)(logits, temperature, top_k, top_p,
                                 seed, counter)
