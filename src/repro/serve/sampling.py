"""Per-slot token sampling for the serving engine's jitted decode step.

One vmapped sampler over the decode batch: every slot carries its own
(temperature, top_k, top_p) parameters and its own PRNG stream, all as plain
arrays, so the whole batch samples inside the SINGLE decode jit — no retrace
when requests with different sampling configs share the batch, no extra host
sync (only the sampled (B,) tokens cross the device boundary, exactly like
the old argmax path).

PRNG determinism: a slot's key for its i-th output token is
`fold_in(key(seed), i)` — a pure function of the REQUEST's (seed, token
index), independent of slot assignment, batch composition, or how prefill
was chunked. Same seed → same tokens, re-run to re-run and engine to engine.

Greedy is the `temperature <= 0` fast path: those rows take `argmax` of the
RAW logits (not the masked/scaled ones), bit-identical to the pre-sampling
engine — the equivalence the temperature=0 ≡ greedy tests pin.

One descending argsort serves both filters (sorting twice — logits for
top-k, probs for top-p — would double the sampler's dominant O(V log V)
cost): top-k keeps the first k sorted positions (ties at the k-th value
resolve by the stable sort's token-id order), and top-p keeps the smallest
sorted prefix whose softmax mass reaches p (the top token always
survives). top_k=0 and top_p>=1 disable their filters.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.attention import NEG_INF


def _sample_one(logits, temperature, top_k, top_p, seed, counter):
    """logits (V,) f32 → sampled token () int32."""
    v = logits.shape[-1]
    greedy = jnp.argmax(logits).astype(jnp.int32)
    # ONE descending sort; both filters run in rank space, and the sampled
    # rank maps back to a token id through `order`
    order = jnp.argsort(-logits)
    ld = logits[order]
    ranks = jnp.arange(v)
    lk = jnp.where((top_k > 0) & (ranks >= jnp.clip(top_k, 1, v)),
                   NEG_INF, ld)
    lt = lk / jnp.maximum(temperature, 1e-6)
    probs = jax.nn.softmax(lt)                    # already descending
    keep = (jnp.cumsum(probs) - probs) < top_p    # exclusive prefix mass
    lt = jnp.where((top_p < 1.0) & ~keep, NEG_INF, lt)
    key = jax.random.fold_in(jax.random.key(seed), counter)
    sampled = order[jax.random.categorical(key, lt)].astype(jnp.int32)
    return jnp.where(temperature > 0, sampled, greedy)


def sample_tokens(logits, temperature, top_k, top_p, seed, counter):
    """Batched per-slot sampling.

    logits (B, V) f32; temperature/top_p (B,) f32; top_k/seed/counter (B,)
    int32 — `counter` is the slot's output-token index (engine-maintained),
    which keys the per-token PRNG stream. Returns (B,) int32 tokens.
    """
    return jax.vmap(_sample_one)(logits, temperature, top_k, top_p,
                                 seed, counter)
