"""Live cross-shard KV page migration over compression-aware UCIe (PR 9).

PR 6 recovers displaced slots by re-prefill replay: correct and token-exact,
but a drain recomputes prefill — O(FLOPs) in prompt length. The paper's §II
budgets the opposite: sensor-driven load migration moves STATE over the
die-to-die link, paying O(bytes) at the UCIe's compression-aware transfer
cost. This module is the host-side planner for that path; the device data
plane is `serve/sharded`'s move program (gather → all_gather → scatter built
from `models.transformer.gather_pool_pages` / `set_pool_page`), and the host
bookkeeping re-homes atomically in `ShardScheduler.migrate_slot`.

Three triggers share the one primitive:

  * **drain**   — a DRAINING shard's live slots re-home instead of being
    released + replayed (DEAD shards still replay: their pool bytes are
    gone, there is nothing to move).
  * **rebalance** — elastic load balancing: when the queue head starves on
    one shard's free list while another idles, or the busy-slot gap between
    shards exceeds `rebalance_threshold`, a young decoding slot moves.
  * **prefix replication** — a registry hit that only exists on a remote
    shard copies the hot prefix's pages instead of re-prefilling locally
    (guarded by `min_prefix_hits`).

Exactness contract: the data path moves POOL-NATIVE bytes, verbatim. An
int8 KV pool's int8 rows + f16 scale rows *are* its block-compressed wire
format — exactly half the bf16 bytes, produced by `kernels/quantize`'s
block quantization at write time and decompressed by decode's fused dequant
on the receiving shard — so "gather → block-compress → move → decompress →
scatter" is what every int8 migration does, at zero extra loss. Float pools
move their float bytes unchanged rather than round-tripping through
`quantize_blocks` (that WOULD be lossy and would break the schedule-
independent KV rounding contract the tests pin: migrated tokens must be
bit-exact). `UCIeConfig.compression_ratio` still prices wire compression in
the COST model, which is where the paper's claim lives.

Cost model: `migration_cost` charges every move through `core/ucie`'s
`transfer()` closed form — the SAME function the time-stepped simulator
drains through `link_tick`. `ucie.migration_ticks` turns that time into
engine ticks, and the engine holds a migrated slot's next decode step for
exactly that long. A guard test pins that no serving module re-derives link
math outside this call path.
"""

from __future__ import annotations

import dataclasses
from typing import Callable, List, Optional, Tuple

from repro.core import ucie
from repro.serve.engine import prefix_digests, request_seed_digest


@dataclasses.dataclass(frozen=True)
class MigrationConfig:
    """Knobs for the migration planner.

    `tick_us` maps link time onto engine ticks (1 tick ≙ 1 ms, the same
    scale `serve/health.HealthConfig.tick_ms` uses for thermal integration).
    `rebalance_threshold` is the busy-slot gap that triggers an elastic
    move; 0 disables rebalancing (drain migration stays on — it replaces a
    strictly more expensive replay)."""
    ucie: ucie.UCIeConfig = dataclasses.field(default_factory=ucie.UCIeConfig)
    tick_us: float = 1000.0
    wave_moves: int = 4              # pages per shard_map'd move wave
    rebalance_threshold: int = 0
    min_prefix_hits: int = 2         # replication guard: prefix hotness


def page_payload_bytes(pools) -> int:
    """Bytes ONE physical page occupies across every pool array (the page
    axis is axis 1). Pool-native: an int8 pool contributes its int8 rows
    plus f16 block scales — the block-compressed wire format — so int8
    migrations genuinely ship about half the bf16 bytes."""
    return int(sum(x.size // x.shape[1] * x.dtype.itemsize
                   for x in pools.values()))


def migration_cost(payload_bytes: float,
                   cfg: MigrationConfig) -> Tuple[int, float]:
    """(hold_ticks, wire_bytes) of one migration transfer — both straight
    out of `core/ucie.transfer`'s closed form (via `ucie.migration_ticks`);
    the serving stack owns NO link math of its own."""
    ticks = ucie.migration_ticks(payload_bytes, cfg.ucie, tick_us=cfg.tick_us)
    _, _, wire = ucie.transfer(float(payload_bytes), cfg.ucie)
    return ticks, float(wire)


# --------------------------------------------------------------- planners
#
# Pure policy over ShardScheduler state: each returns WHAT to move; the
# engine executes (device waves + `migrate_slot` + hold accounting).
# `movable(shard, slot)` is the engine's veto — decoding, not held, not
# mid-prefill — so policy here never has to know about engine tick state.

def plan_rebalance(sched, threshold: int, placeable: List[bool],
                   movable: Callable[[int, int], bool]
                   ) -> Optional[Tuple[int, int, int]]:
    """One busy-gap move: when some shard runs more than `threshold` live
    slots above the idlest placeable shard, its youngest movable slot
    re-homes there. Deterministic (max busy, then max rid victim; min busy,
    then lowest id destination). Returns (src_shard, src_slot, dst_shard)."""
    if threshold <= 0:
        return None
    busy = [sum(r is not None for r in s.slots) for s in sched.shards]
    dst = None
    for i, s in enumerate(sched.shards):
        if not placeable[i] or None not in s.slots:
            continue
        if dst is None or (busy[i], i) < (busy[dst], dst):
            dst = i
    if dst is None:
        return None
    best = None
    for i, s in enumerate(sched.shards):
        if i == dst or busy[i] - busy[dst] <= threshold:
            continue
        for slot, r in enumerate(s.slots):
            if r is None or slot in s.prefill_fifo or not movable(i, slot):
                continue
            if sched.shards[dst].allocatable() < len(s.slot_pages[slot]):
                continue
            key = (busy[i], r.rid)
            if best is None or key > best[0]:
                best = (key, i, slot)
    return None if best is None else (best[1], best[2], dst)


def plan_starvation_rescue(sched, need: int, placeable: List[bool],
                           movable: Callable[[int, int], bool]
                           ) -> Optional[Tuple[int, int, int]]:
    """Migration-instead-of-preemption: a decoding slot whose re-homing
    (a) frees its source shard enough that the starved queue head can admit
    there (the victim's exclusive pages plus the shard's allocatable set
    cover `need`, and its slot frees up) and (b) fits whole on a
    destination shard. The head unblocks WITHOUT any decoded work being
    thrown away — preemption stays the fallback when no such pair exists.
    Victim choice mirrors `preempt_candidate` (youngest rid)."""
    best = None
    for i, s in enumerate(sched.shards):
        if not placeable[i]:          # the head must admit on the source
            continue
        for slot, r in enumerate(s.slots):
            if r is None or slot in s.prefill_fifo or not movable(i, slot):
                continue
            exclusive = sum(1 for p in s.slot_pages[slot].values()
                            if s.ref[p] == 1)
            if exclusive + s.allocatable() < need:
                continue
            n_pages = len(s.slot_pages[slot])
            dst = None
            for k, d in enumerate(sched.shards):
                if k == i or not placeable[k] or None not in d.slots:
                    continue
                if d.allocatable() < n_pages:
                    continue
                busy_k = sum(x is not None for x in d.slots)
                key = (d.pages_in_use, busy_k, k)
                if dst is None or key < dst[0]:
                    dst = (key, k)
            if dst is None:
                continue
            if best is None or r.rid > best[0]:
                best = (r.rid, i, slot, dst[1])
    return None if best is None else (best[1], best[2], best[3])


def plan_prefix_replication(sched, r, cfg: MigrationConfig,
                            placeable: List[bool]
                            ) -> Optional[Tuple[int, int, List[bytes]]]:
    """Cross-shard prefix reuse: if the longest cached run of the queue
    head's prompt lives on a shard it cannot admit on, and the prefix is
    hot (`min_prefix_hits` admissions have hit its first page), replicate
    the missing run onto the best admitting shard — compressed-UCIe page
    moves instead of re-prefill. Returns (src_shard, dst_shard, digests to
    copy, in chain order) or None."""
    if not sched.prefix_cache:
        return None
    lp = r.live_prompt()
    n_cand = lp.shape[0] // sched.page_size
    if n_cand == 0:
        return None
    digs = prefix_digests(lp, sched.page_size, n_cand,
                          request_seed_digest(r.extras))
    runs = []
    for s in sched.shards:
        n = 0
        while n < n_cand and digs[n] in s.by_hash:
            n += 1
        runs.append(n)
    local = [i for i in range(sched.n_shards)
             if placeable[i] and None in sched.shards[i].slots]
    if not local:
        return None
    dst = min(local, key=lambda i: (-runs[i], sched.shards[i].pages_in_use, i))
    src = min(range(sched.n_shards), key=lambda i: (-runs[i], i))
    gain = runs[src] - runs[dst]
    if gain <= 0:
        return None
    if sched.digest_hits.get(digs[0], 0) < cfg.min_prefix_hits:
        return None
    if sched.shards[dst].allocatable() < gain:
        return None
    return src, dst, digs[runs[dst]:runs[src]]
