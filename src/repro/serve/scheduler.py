"""Async shard scheduler: admission, placement and prefill interleaving for
the sharded serving engine (PR 5).

The single-host `ServeEngine` folds admission control into the engine tick:
one free list, one FIFO of mid-prefill slots, one chunk per tick. Sharded
serving over `make_production_mesh`'s data axis breaks that shape in three
ways, and this object is where the differences live:

  * **Per-shard free lists.** Every shard owns a private page pool (local
    page ids; page 0 is the shard's null page) — a request's reservation must
    come from ONE shard's pool so its page-table row stays device-local and
    `decode_attention`'s scalar-prefetch gathers never cross devices. The
    scheduler never mixes pages across shards.
  * **Least-loaded placement.** The queue head admits onto the shard with a
    free slot, enough free pages, and the least load (fewest pages in use,
    then fewest busy slots, then lowest shard id — a deterministic total
    order, so identical traffic schedules identically run-to-run). Admission
    stays FIFO: if no shard can take the head, nothing overtakes it.
  * **Interleaved prefill ticks.** Each shard advances AT MOST ONE chunk of
    its own oldest mid-prefill slot per engine tick, independently of every
    other shard — a 4k-token prompt admitted to shard 3 costs shard 3 a
    chunk per tick and costs shards 0-2 nothing, so one long prompt can
    never stall decode on another shard (the multi-chiplet analog of PR 4's
    head-of-line fix: chiplets prefill behind their own FCU queues while the
    others keep streaming decode traffic).

Token streams are schedule-independent (PR 4 pinned chunk/batch-composition
invariance), so none of these policies can change WHAT a request generates —
only when. That is what makes the sharded engine token-identical to the
single-host one under completely different admission orders.

Retirement — including mid-prefill retirement (`cancel`) — drains the slot's
chunk queue and returns EVERY reserved page to its shard's free list in one
step; the pool-accounting regression tests pin that no reservation survives
a retirement at any lifecycle stage (queued / mid-prefill / decoding).
"""

from __future__ import annotations

import dataclasses
from collections import OrderedDict
from typing import Dict, List, Optional, Tuple

import numpy as np

from repro.serve.engine import (
    Request, lookup_prefix_hits, page_row_of, prefix_digests,
    prefix_share_plan, recycle_dead_pages, register_prefix_pages,
    request_seed_digest, reserve_page_count, window_page_budget)


@dataclasses.dataclass
class ShardState:
    """Host-side bookkeeping for one shard's slots and page pool.

    PR 8 makes the pool ref-counted and content-addressed PER SHARD: page
    ids are device-local, so each shard keeps its own prefix registry and
    LRU — a cached page can only be shared by slots on the SAME shard
    (cross-shard sharing would put a foreign page id in a device-local
    table; placement instead PREFERS the shard already holding the prefix).
    `pages_in_use` counts UNIQUE live pages (ref >= 1), so the occupancy
    a shard reports shrinks by the sharing factor."""
    free_pages: List[int]                 # LOCAL ids, 1..n_pages-1 (0 = null)
    slots: List[Optional[Request]]
    prefill_fifo: List[int]               # local slot ids mid-prefill, FIFO
    chunk_next: List[int]                 # next chunk start per local slot
    slot_pages: List[Dict[int, int]]      # logical page -> LOCAL physical
    slot_cap: List[int]                   # highest writable logical page (excl)
    pages_in_use: int = 0                 # unique pages with ref >= 1
    ref: Optional[np.ndarray] = None      # (n_pages,) int32 refcounts
    page_hash: Dict[int, bytes] = dataclasses.field(default_factory=dict)
    by_hash: Dict[bytes, int] = dataclasses.field(default_factory=dict)
    # refcount-zero pages whose content is still registered — evictable,
    # oldest first
    lru: "OrderedDict[int, None]" = dataclasses.field(
        default_factory=OrderedDict)

    def allocatable(self) -> int:
        """Pages an admission can obtain: free + evictable cached."""
        return len(self.free_pages) + len(self.lru)


@dataclasses.dataclass
class ChunkWork:
    """One shard's prefill work for this tick."""
    shard: int
    slot: int                             # local slot id
    req: Request
    start: int                            # chunk's first global position
    length: int                           # real rows in this chunk
    final: bool                           # last chunk — slot goes live after


@dataclasses.dataclass
class Placement:
    """One admission decision (PR 8: placements carry the prefix-cache
    outcome so the engine can clone COW tails and fast-path full hits)."""
    shard: int
    slot: int                             # local slot id
    req: Request
    cached_tokens: int = 0                # page-aligned tokens served cached
    cow: Optional[Tuple[int, int]] = None  # (src, dst) LOCAL page clone
    full_hit: bool = False                # whole prompt cached: zero chunks


class ShardScheduler:
    def __init__(self, *, n_shards: int, slots_per_shard: int, n_pages: int,
                 page_size: int, pages_per_seq: int, max_len: int,
                 chunk_tokens: int, window: int = 0,
                 prefix_cache: bool = True):
        assert n_pages >= 2, n_pages     # local null page + ≥1 usable
        self.n_shards = n_shards
        self.slots_per_shard = slots_per_shard
        self.n_pages = n_pages           # per shard, incl. the local null page
        self.page_size = page_size
        self.pages_per_seq = pages_per_seq
        self.max_len = max_len
        self.chunk_tokens = chunk_tokens
        self.window = window
        # sliding-window recycling rewrites remapped pages in place —
        # incompatible with sharing (same rule as the single-host engine)
        self.prefix_cache = bool(prefix_cache) and not window
        # prefix-cache counters, mirrored into EngineStats by the engine
        self.prefix_hits = 0
        self.prefix_misses = 0
        self.prefix_hit_tokens = 0
        self.prefix_evictions = 0
        self.cow_copies = 0
        # ---- live migration & in-flight dedup (PR 9) -------------------
        # digest -> admissions that hit it: the prefix-hotness signal the
        # cross-shard replication planner thresholds on
        self.digest_hits: Dict[bytes, int] = {}
        # digest -> rid of the live request currently prefilling it; a
        # queue head whose first MISS digest is pending defers (without
        # counting as page starvation) instead of duplicating the prefill
        self.pending_digest: Dict[bytes, int] = {}
        self.pending_by_rid: Dict[int, List[bytes]] = {}
        self.queue: List[Request] = []
        self.shards = [
            ShardState(free_pages=list(range(n_pages - 1, 0, -1)),
                       slots=[None] * slots_per_shard,
                       prefill_fifo=[],
                       chunk_next=[0] * slots_per_shard,
                       slot_pages=[{} for _ in range(slots_per_shard)],
                       slot_cap=[0] * slots_per_shard,
                       ref=np.zeros((n_pages,), np.int32))
            for _ in range(n_shards)]
        # ---- fault tolerance (PR 6) ----------------------------------------
        # placement mask, driven by serve/health's state machine: only
        # HEALTHY shards take new admissions (degraded/draining/dead/rejoining
        # shards are skipped, without touching their live slots)
        self.placeable: List[bool] = [True] * n_shards
        # pages stolen by page_squeeze faults, per shard, until restored
        self.stolen: List[List[int]] = [[] for _ in range(n_shards)]

    # ------------------------------------------------------------ reservation
    def _window_pages(self) -> int:
        return window_page_budget(self.window, self.page_size)

    def pages_for(self, plen: int, max_new: int) -> int:
        """Pages one request reserves at admission — the single-host chunked
        engine's math (engine.reserve_page_count, ONE shared copy): full
        span, or O(window) when a sliding window recycles pages forward."""
        return reserve_page_count(plen, max_new, max_len=self.max_len,
                                  page_size=self.page_size,
                                  window=self.window)

    @property
    def pages_in_use(self) -> int:
        return sum(s.pages_in_use for s in self.shards)

    def shard_pages_in_use(self) -> List[int]:
        return [s.pages_in_use for s in self.shards]

    # ------------------------------------- ref-counted page allocator (PR 8)
    def _unregister(self, s: ShardState, phys: int) -> None:
        h = s.page_hash.pop(phys, None)
        if h is not None and s.by_hash.get(h) == phys:
            del s.by_hash[h]

    def _alloc(self, s: ShardState) -> int:
        """One private page: pop the shard's free list, else evict its
        least-recently-used refcount-zero cached page."""
        if s.free_pages:
            p = s.free_pages.pop()
        else:
            p, _ = s.lru.popitem(last=False)     # oldest first
            self._unregister(s, p)
            self.prefix_evictions += 1
        s.ref[p] = 1
        s.pages_in_use += 1
        return p

    def _incref(self, s: ShardState, phys: int) -> None:
        if s.ref[phys] == 0:
            s.lru.pop(phys, None)    # back live: safe from eviction
            s.pages_in_use += 1
        s.ref[phys] += 1

    def _count_hit(self, s: ShardState, phys: int) -> None:
        """Bump the hotness counter of the digest behind a hit page — the
        signal `plan_prefix_replication` thresholds on."""
        h = s.page_hash.get(phys)
        if h is not None:
            self.digest_hits[h] = self.digest_hits.get(h, 0) + 1

    def _decref(self, s: ShardState, phys: int) -> None:
        s.ref[phys] -= 1
        assert s.ref[phys] >= 0, int(phys)
        if s.ref[phys] == 0:
            s.pages_in_use -= 1
            if self.prefix_cache and phys in s.page_hash:
                s.lru[phys] = None   # registered content parks in the LRU
            else:
                self._unregister(s, phys)
                s.free_pages.append(phys)

    def _hit_plan(self, s: ShardState, r: Request, lp, plen: int):
        """(hits, n_shared, cow_src, pinned) for placing `r` on shard `s`:
        the shard's cached run over the prompt, the share/COW split, and how
        many of those hit pages sit in the LRU (they leave the allocatable
        set the instant an admission increfs them)."""
        if not self.prefix_cache:
            return [], 0, None, 0
        hits = lookup_prefix_hits(s.by_hash, lp, self.page_size,
                                  seed=request_seed_digest(r.extras))
        n_shared, cow_src = prefix_share_plan(plen, hits, self.page_size)
        pinned = sum(1 for p in hits[:n_shared] if s.ref[p] == 0)
        if cow_src is not None and s.ref[cow_src] == 0:
            pinned += 1
        return hits, n_shared, cow_src, pinned

    def register_prefix(self, shard: int, slot: int, r: Request) -> None:
        """Content-register a fully-prefilled slot's full prompt pages in
        ITS shard's registry (engine calls this at finalize)."""
        self._clear_pending(r.rid)
        if not self.prefix_cache:
            return
        s = self.shards[shard]
        register_prefix_pages(s.slot_pages[slot], r.live_prompt(),
                              self.page_size, request_seed_digest(r.extras),
                              s.page_hash, s.by_hash)

    def _clear_pending(self, rid: int) -> None:
        """Drop a request's in-flight dedup claims — at finalize (the pages
        are registered now; waiters hit them) or at any release (the prefill
        died; waiters must stop deferring and prefill themselves)."""
        for d in self.pending_by_rid.pop(rid, ()):
            if self.pending_digest.get(d) == rid:
                del self.pending_digest[d]

    # -------------------------------------------------------------- placement
    def _eligible(self, need: int) -> Optional[int]:
        """Least-loaded PLACEABLE shard with a free slot and `need`
        allocatable (free + evictable-cached) pages."""
        best = None
        for i, s in enumerate(self.shards):
            if not self.placeable[i]:
                continue
            if s.allocatable() < need or None not in s.slots:
                continue
            busy = sum(r is not None for r in s.slots)
            key = (s.pages_in_use, busy, i)
            if best is None or key < best[0]:
                best = (key, i)
        return None if best is None else best[1]

    def admit(self) -> List[Placement]:
        """Admit queued requests FIFO onto CACHE-AWARE least-loaded shards.

        Placement prefers the shard already holding the longest cached run
        of the request's prompt (page ids are device-local, so sharing can
        only happen shard-locally), breaking ties by least load — the PR 5
        deterministic total order with cached_tokens prepended. Pages are
        reserved and mapped on return (shared hits ref-bumped, privates
        allocated); each Placement carries the COW clone for the engine to
        execute and the full-hit flag for the zero-chunk fast path. Stalls —
        without overtaking — when the head fits nowhere."""
        placed: List[Placement] = []
        pending_decref: List[Tuple[ShardState, int]] = []
        while self.queue:
            r = self.queue[0]
            # resumed requests (preempted / recovered off a dead shard) admit
            # on prompt + emitted tokens and the remaining budget; the page
            # need is invariant under resume (see engine._admit)
            lp = r.live_prompt()
            plen = lp.shape[0]
            rem = r.remaining_new()
            need = self.pages_for(plen, rem)
            best = None
            for i, s in enumerate(self.shards):
                if not self.placeable[i] or None not in s.slots:
                    continue
                hits, n_shared, cow_src, pinned = self._hit_plan(
                    s, r, lp, plen)
                if s.allocatable() - pinned < need - n_shared:
                    continue
                busy = sum(x is not None for x in s.slots)
                cached = (n_shared + (cow_src is not None)) * self.page_size
                key = (-cached, s.pages_in_use, busy, i)
                if best is None or key < best[0]:
                    best = (key, i, hits, n_shared, cow_src, cached)
            if best is None:
                break
            _, shard, hits, n_shared, cow_src, cached = best
            s = self.shards[shard]
            # in-flight dedup (PR 9): if the first page this request would
            # prefill is ALREADY being prefilled by a live request, defer —
            # once that prefill finalizes and registers, this one hits its
            # pages instead of duplicating the work. FIFO still holds
            # (nothing overtakes a deferred head), and the claim dies with
            # its owner (`_clear_pending` on release), so no deadlock.
            digs = None
            n_cand = plen // self.page_size if self.prefix_cache else 0
            if len(hits) < n_cand:
                digs = prefix_digests(lp, self.page_size, n_cand,
                                      request_seed_digest(r.extras))
                owner = self.pending_digest.get(digs[len(hits)])
                if owner is not None and owner != r.rid:
                    break
            slot = s.slots.index(None)
            shared = hits[:n_shared]
            # commit order: protect the hit pages FIRST (incref pulls them
            # out of the eviction set), then allocate privates. cow_src
            # stays pinned until the END of the admit wave — the engine
            # clones it before any of this wave's pages get written
            for p in shared:
                self._incref(s, p)
                self._count_hit(s, p)
            cow = None
            if cow_src is not None:
                self._incref(s, cow_src)
                self._count_hit(s, cow_src)
                pending_decref.append((s, cow_src))
            pages = [self._alloc(s) for _ in range(need - n_shared)]
            if cow_src is not None:
                cow = (cow_src, pages[0])
                self.cow_copies += 1
            mapping = {j: p for j, p in enumerate(shared)}
            for k, p in enumerate(pages):
                mapping[n_shared + k] = p
            s.slot_pages[slot] = mapping
            s.slot_cap[slot] = -(-min(self.max_len, plen + rem)
                                 // self.page_size)
            s.slots[slot] = r
            r.cached_prompt_tokens = cached
            if self.prefix_cache:
                if cached:
                    self.prefix_hits += 1
                    self.prefix_hit_tokens += cached
                else:
                    self.prefix_misses += 1
            full = cached >= plen
            s.chunk_next[slot] = cached
            if full:
                # whole prompt already pooled (shared run + COW'd tail):
                # no prefill chunks — register now, the engine finalizes
                # the slot straight from this placement
                self.register_prefix(shard, slot, r)
            else:
                s.prefill_fifo.append(slot)
                if digs is not None:
                    # claim the full pages this prefill will register, so
                    # concurrent identical first-misses coalesce onto it
                    mine = self.pending_by_rid.setdefault(r.rid, [])
                    for d in digs[len(hits):]:
                        if d not in self.pending_digest:
                            self.pending_digest[d] = r.rid
                            mine.append(d)
            self.queue.pop(0)
            placed.append(Placement(shard=shard, slot=slot, req=r,
                                    cached_tokens=cached, cow=cow,
                                    full_hit=full))
        for s, p in pending_decref:
            self._decref(s, p)
        return placed

    # ---------------------------------------------------------------- prefill
    def next_chunks(self) -> List[ChunkWork]:
        """One chunk of work per shard that has any (oldest slot first) —
        the per-shard interleaving: no shard's prefill costs another shard
        a tick."""
        work = []
        for i, s in enumerate(self.shards):
            if not s.prefill_fifo:
                continue
            slot = s.prefill_fifo[0]
            r = s.slots[slot]
            st = s.chunk_next[slot]
            plen = r.live_prompt().shape[0]
            if self.window and st:
                # recycle pages no chunk row >= st can still read; the cache
                # table row is still null, so this is host bookkeeping only
                self.recycle(i, slot, st)
            work.append(ChunkWork(
                shard=i, slot=slot, req=r, start=st,
                length=min(self.chunk_tokens, plen - st),
                final=st + self.chunk_tokens >= plen))
        return work

    def advance_chunk(self, w: ChunkWork) -> None:
        s = self.shards[w.shard]
        if w.final:
            s.prefill_fifo.pop(0)
        else:
            s.chunk_next[w.slot] = w.start + self.chunk_tokens

    def page_row(self, shard: int, slot: int):
        """The slot's (pages_per_seq,) LOCAL-physical page row (null page 0
        beyond the mapping) — what rides the chunk call and, once the slot is
        live, the device-local page table."""
        return page_row_of(self.shards[shard].slot_pages[slot],
                           self.pages_per_seq)

    # --------------------------------------------------------------- windowing
    def recycle(self, shard: int, slot: int, progress: int):
        """Free pages fully below `progress - window` — the single-host
        engine's recycle core (engine.recycle_dead_pages, ONE shared copy)
        against this shard's free list. Returns [(j_dead, j_new, phys)]
        remap and [j_dead] unmap events so the engine can mirror them into
        the device-local page table for live slots."""
        s = self.shards[shard]
        remaps, unmaps = recycle_dead_pages(
            s.slot_pages[slot], s.slot_cap[slot],
            self.page_size, self.window, progress)
        for _, phys in unmaps:
            # window pages are exclusively owned (prefix cache is off under
            # a sliding window) — the decref drops them straight to free
            self._decref(s, phys)
        return remaps, [j for j, _ in unmaps]

    # -------------------------------------------------------------- retirement
    def release(self, shard: int, slot: int) -> None:
        """Retire a slot at ANY lifecycle stage: drain its chunk queue and
        drop one reference per mapped page (the mid-prefill leak fix — a
        slot cancelled with chunks still queued must not keep its
        reservation). A shared page survives its releasing slot; it only
        returns to the free list (or parks in the LRU, if registered) at
        refcount zero."""
        s = self.shards[shard]
        if s.slots[slot] is not None:
            self._clear_pending(s.slots[slot].rid)
        s.slots[slot] = None
        if slot in s.prefill_fifo:
            s.prefill_fifo.remove(slot)
        s.chunk_next[slot] = 0
        freed = s.slot_pages[slot]
        if freed:
            for phys in freed.values():
                self._decref(s, phys)
            s.slot_pages[slot] = {}
        s.slot_cap[slot] = 0

    # ------------------------------------------- fault tolerance (PR 6)
    def steal_pages(self, shard: int, n: int) -> int:
        """page_squeeze fault: up to `n` pages vanish from the shard's FREE
        list, then from its refcount-zero cached LRU (capacity pressure
        reclaims the prefix cache before it blocks live work) — never from
        live reservations, stealing mapped pages would corrupt live KV.
        Returns pages actually taken."""
        s = self.shards[shard]
        take = min(n, s.allocatable())
        for _ in range(take):
            if s.free_pages:
                p = s.free_pages.pop()
            else:
                p, _ = s.lru.popitem(last=False)
                self._unregister(s, p)
                self.prefix_evictions += 1
            self.stolen[shard].append(p)
        return take

    def restore_pages(self, shard: int) -> int:
        """page_restore fault: every page stolen from the shard returns."""
        s = self.shards[shard]
        n = len(self.stolen[shard])
        s.free_pages.extend(self.stolen[shard])
        self.stolen[shard].clear()
        return n

    def drain_shard(self, shard: int) -> List[Request]:
        """Evacuate a draining/dead shard: release EVERY live slot (pages
        back to its free list, chunk queues drained) and hand the displaced
        requests back, oldest first, for re-admission elsewhere."""
        s = self.shards[shard]
        live = [(slot, r) for slot, r in enumerate(s.slots) if r is not None]
        for slot, _ in live:
            self.release(shard, slot)
        return [r for _, r in sorted(live, key=lambda t: t[1].rid)]

    def reset_shard(self, shard: int) -> None:
        """Rejoining shard: its pool comes back fresh — full free list, no
        mappings, no stolen stash (whatever a squeeze took died with the
        shard). Must only run on a drained shard."""
        s = self.shards[shard]
        assert all(r is None for r in s.slots), \
            f"reset of shard {shard} with live slots"
        s.free_pages = list(range(self.n_pages - 1, 0, -1))
        s.prefill_fifo = []
        s.chunk_next = [0] * self.slots_per_shard
        s.slot_pages = [{} for _ in range(self.slots_per_shard)]
        s.slot_cap = [0] * self.slots_per_shard
        s.pages_in_use = 0
        # the shard's pool bytes are gone — its prefix registry dies with it
        s.ref = np.zeros((self.n_pages,), np.int32)
        s.page_hash = {}
        s.by_hash = {}
        s.lru = OrderedDict()
        self.stolen[shard].clear()

    def requeue(self, reqs: List[Request]) -> None:
        """Re-enqueue displaced requests in rid order — each rejoins the
        FIFO exactly where its age puts it, ahead of anything younger."""
        for r in reqs:
            i = 0
            while i < len(self.queue) and self.queue[i].rid < r.rid:
                i += 1
            self.queue.insert(i, r)

    # ----------------------------------------- live page migration (PR 9)
    def migration_target(self, src_shard: int, slot: int,
                         placeable: Optional[List[bool]] = None
                         ) -> Optional[int]:
        """Least-loaded placeable shard (never the source) with a free slot
        and enough allocatable pages to host the slot's whole mapping —
        where a drained/rebalanced slot re-homes. None when nowhere fits
        (the caller falls back to PR 6's release + re-prefill replay)."""
        mask = self.placeable if placeable is None else placeable
        need = len(self.shards[src_shard].slot_pages[slot])
        best = None
        for i, s in enumerate(self.shards):
            if i == src_shard or not mask[i]:
                continue
            if None not in s.slots or s.allocatable() < need:
                continue
            busy = sum(r is not None for r in s.slots)
            key = (s.pages_in_use, busy, i)
            if best is None or key < best[0]:
                best = (key, i)
        return None if best is None else best[1]

    def migrate_slot(self, src_shard: int, slot: int, dst_shard: int
                     ) -> Tuple[int, List[Tuple[int, int]]]:
        """Re-home ONE live slot's host bookkeeping src -> dst atomically:
        a fresh destination page per mapped logical page, the page-table
        mapping / slot cap / chunk cursor / prefill-FIFO membership carried
        over, source references dropped (shared source pages survive via
        their other refs; registered ones park in the source LRU).

        A registered source page's digest re-registers on the destination
        (first registration wins, as everywhere) — the copy is byte-exact,
        so this is how a hot prefix becomes visible to placement on another
        shard. Returns (dst_slot, moves) with moves = [(src_phys,
        dst_phys)] in LOCAL page ids, for the engine's device move waves.
        The device copy must run before any later allocation can reuse the
        freed source pages (the engine executes it synchronously)."""
        assert dst_shard != src_shard, src_shard
        ss, ds = self.shards[src_shard], self.shards[dst_shard]
        r = ss.slots[slot]
        assert r is not None, (src_shard, slot)
        dst_slot = ds.slots.index(None)
        moves: List[Tuple[int, int]] = []
        mapping: Dict[int, int] = {}
        for j in sorted(ss.slot_pages[slot]):
            src_phys = ss.slot_pages[slot][j]
            dst_phys = self._alloc(ds)
            moves.append((src_phys, dst_phys))
            mapping[j] = dst_phys
            h = ss.page_hash.get(src_phys)
            if self.prefix_cache and h is not None \
                    and h not in ds.by_hash and dst_phys not in ds.page_hash:
                ds.page_hash[dst_phys] = h
                ds.by_hash[h] = dst_phys
        ds.slot_pages[dst_slot] = mapping
        ds.slot_cap[dst_slot] = ss.slot_cap[slot]
        ds.chunk_next[dst_slot] = ss.chunk_next[slot]
        ds.slots[dst_slot] = r
        if slot in ss.prefill_fifo:   # mid-prefill: chunking resumes on dst
            ss.prefill_fifo.remove(slot)
            ds.prefill_fifo.append(dst_slot)
        ss.slots[slot] = None
        ss.chunk_next[slot] = 0
        old = ss.slot_pages[slot]
        ss.slot_pages[slot] = {}
        ss.slot_cap[slot] = 0
        for phys in old.values():
            self._decref(ss, phys)
        return dst_slot, moves

    def replicate_page(self, src_shard: int, dst_shard: int, digest: bytes
                       ) -> Optional[Tuple[int, int]]:
        """Cross-shard prefix replication: allocate a destination page for
        `digest` (registered on the source shard), register it, and park it
        refcount-zero in the destination LRU — the admission that motivated
        the copy picks it up through the normal hit/incref path, and until
        then it is evictable like any cached page. Returns (src_phys,
        dst_phys) for the device move, or None if either side can't."""
        ss, ds = self.shards[src_shard], self.shards[dst_shard]
        src_phys = ss.by_hash.get(digest)
        if src_phys is None or digest in ds.by_hash \
                or ds.allocatable() == 0:
            return None
        dst_phys = self._alloc(ds)
        ds.page_hash[dst_phys] = digest
        ds.by_hash[digest] = dst_phys
        self._decref(ds, dst_phys)
        return src_phys, dst_phys

    def page_starved(self, need: int) -> bool:
        """True when the head fits nowhere but at least one placeable shard
        exists — preempting a young decoding slot there can unblock it
        (frees that slot AND its pages)."""
        if self._eligible(need) is not None:
            return False
        return any(self.placeable)

    def preempt_candidate(self, need: int, head_rid: int,
                          max_preemptions: int) -> Optional[Tuple[int, int]]:
        """The YOUNGEST (max rid) decoding slot on a placeable shard that is
        strictly younger than the head, under its preemption budget, and
        whose release leaves the shard able to take the head (its pages plus
        the shard's free list cover `need`). Strict rid ordering keeps
        progress monotone — no preemption livelock."""
        best = None
        for i, s in enumerate(self.shards):
            if not self.placeable[i]:
                continue
            for slot, r in enumerate(s.slots):
                if r is None or slot in s.prefill_fifo:
                    continue
                if r.rid <= head_rid or r.preemptions >= max_preemptions:
                    continue
                # only the victim's EXCLUSIVELY-owned pages (ref 1) become
                # allocatable at release; shared pages just drop a reference
                exclusive = sum(1 for p in s.slot_pages[slot].values()
                                if s.ref[p] == 1)
                if exclusive + s.allocatable() < need:
                    continue
                if best is None or r.rid > best[0]:
                    best = (r.rid, i, slot)
        return None if best is None else (best[1], best[2])

    def assert_accounting(self) -> None:
        """Ref-counted pool invariant under faults (PR 8): per shard, every
        non-null physical page is in EXACTLY one of {free list, live
        (mapped by >=1 slot), cached LRU, stolen stash} — so
        free + uniquely-mapped + cached + stolen == n_pages - 1 — the
        per-page mapping references (shared-weighted) equal the refcounts,
        and `pages_in_use` equals the unique live count."""
        for i, s in enumerate(self.shards):
            free, lru = set(s.free_pages), set(s.lru)
            live = {p for m in s.slot_pages for p in m.values()}
            stolen = set(self.stolen[i])
            assert len(free) == len(s.free_pages), (i, "free duplicates")
            groups = (free, lru, live, stolen)
            for gi, a in enumerate(groups):
                assert 0 not in a, (i, "null page leaked into the pool")
                for b in groups[gi + 1:]:
                    assert not (a & b), (i, free, lru, live, stolen)
            assert len(free) + len(lru) + len(live) + len(stolen) \
                == self.n_pages - 1, \
                (i, len(free), len(lru), len(live), len(stolen))
            refs = np.zeros_like(s.ref)
            for m in s.slot_pages:
                for p in m.values():
                    refs[p] += 1
            assert np.array_equal(refs, s.ref), (i, refs, s.ref)
            assert s.pages_in_use == len(live), (i, s.pages_in_use, len(live))
            for p in s.lru:
                assert p in s.page_hash, (i, p)

    def find(self, req: Request) -> Optional[Tuple[int, int]]:
        for i, s in enumerate(self.shards):
            for slot, r in enumerate(s.slots):
                if r is req:
                    return i, slot
        return None

    def assert_local(self) -> None:
        """Device-locality invariant: every mapped physical page id is a
        LOCAL id inside its own shard's pool — no table entry can ever name
        another device's page."""
        for i, s in enumerate(self.shards):
            for slot, m in enumerate(s.slot_pages):
                for j, p in m.items():
                    assert 0 < p < self.n_pages, (i, slot, j, p)
