"""Async shard scheduler: admission, placement and prefill interleaving for
the sharded serving engine (PR 5).

The single-host `ServeEngine` folds admission control into the engine tick:
one free list, one FIFO of mid-prefill slots, one chunk per tick. Sharded
serving over `make_production_mesh`'s data axis breaks that shape in three
ways, and this object is where the differences live:

  * **Per-shard free lists.** Every shard owns a private page pool (local
    page ids; page 0 is the shard's null page) — a request's reservation must
    come from ONE shard's pool so its page-table row stays device-local and
    `decode_attention`'s scalar-prefetch gathers never cross devices. The
    scheduler never mixes pages across shards.
  * **Least-loaded placement.** The queue head admits onto the shard with a
    free slot, enough free pages, and the least load (fewest pages in use,
    then fewest busy slots, then lowest shard id — a deterministic total
    order, so identical traffic schedules identically run-to-run). Admission
    stays FIFO: if no shard can take the head, nothing overtakes it.
  * **Interleaved prefill ticks.** Each shard advances AT MOST ONE chunk of
    its own oldest mid-prefill slot per engine tick, independently of every
    other shard — a 4k-token prompt admitted to shard 3 costs shard 3 a
    chunk per tick and costs shards 0-2 nothing, so one long prompt can
    never stall decode on another shard (the multi-chiplet analog of PR 4's
    head-of-line fix: chiplets prefill behind their own FCU queues while the
    others keep streaming decode traffic).

Token streams are schedule-independent (PR 4 pinned chunk/batch-composition
invariance), so none of these policies can change WHAT a request generates —
only when. That is what makes the sharded engine token-identical to the
single-host one under completely different admission orders.

Retirement — including mid-prefill retirement (`cancel`) — drains the slot's
chunk queue and returns EVERY reserved page to its shard's free list in one
step; the pool-accounting regression tests pin that no reservation survives
a retirement at any lifecycle stage (queued / mid-prefill / decoding).
"""

from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Tuple

from repro.serve.engine import (
    Request, page_row_of, recycle_dead_pages, reserve_page_count,
    window_page_budget)


@dataclasses.dataclass
class ShardState:
    """Host-side bookkeeping for one shard's slots and page pool."""
    free_pages: List[int]                 # LOCAL ids, 1..n_pages-1 (0 = null)
    slots: List[Optional[Request]]
    prefill_fifo: List[int]               # local slot ids mid-prefill, FIFO
    chunk_next: List[int]                 # next chunk start per local slot
    slot_pages: List[Dict[int, int]]      # logical page -> LOCAL physical
    slot_cap: List[int]                   # highest writable logical page (excl)
    pages_in_use: int = 0


@dataclasses.dataclass
class ChunkWork:
    """One shard's prefill work for this tick."""
    shard: int
    slot: int                             # local slot id
    req: Request
    start: int                            # chunk's first global position
    length: int                           # real rows in this chunk
    final: bool                           # last chunk — slot goes live after


class ShardScheduler:
    def __init__(self, *, n_shards: int, slots_per_shard: int, n_pages: int,
                 page_size: int, pages_per_seq: int, max_len: int,
                 chunk_tokens: int, window: int = 0):
        assert n_pages >= 2, n_pages     # local null page + ≥1 usable
        self.n_shards = n_shards
        self.slots_per_shard = slots_per_shard
        self.n_pages = n_pages           # per shard, incl. the local null page
        self.page_size = page_size
        self.pages_per_seq = pages_per_seq
        self.max_len = max_len
        self.chunk_tokens = chunk_tokens
        self.window = window
        self.queue: List[Request] = []
        self.shards = [
            ShardState(free_pages=list(range(n_pages - 1, 0, -1)),
                       slots=[None] * slots_per_shard,
                       prefill_fifo=[],
                       chunk_next=[0] * slots_per_shard,
                       slot_pages=[{} for _ in range(slots_per_shard)],
                       slot_cap=[0] * slots_per_shard)
            for _ in range(n_shards)]
        # ---- fault tolerance (PR 6) ----------------------------------------
        # placement mask, driven by serve/health's state machine: only
        # HEALTHY shards take new admissions (degraded/draining/dead/rejoining
        # shards are skipped, without touching their live slots)
        self.placeable: List[bool] = [True] * n_shards
        # pages stolen by page_squeeze faults, per shard, until restored
        self.stolen: List[List[int]] = [[] for _ in range(n_shards)]

    # ------------------------------------------------------------ reservation
    def _window_pages(self) -> int:
        return window_page_budget(self.window, self.page_size)

    def pages_for(self, plen: int, max_new: int) -> int:
        """Pages one request reserves at admission — the single-host chunked
        engine's math (engine.reserve_page_count, ONE shared copy): full
        span, or O(window) when a sliding window recycles pages forward."""
        return reserve_page_count(plen, max_new, max_len=self.max_len,
                                  page_size=self.page_size,
                                  window=self.window)

    @property
    def pages_in_use(self) -> int:
        return sum(s.pages_in_use for s in self.shards)

    def shard_pages_in_use(self) -> List[int]:
        return [s.pages_in_use for s in self.shards]

    # -------------------------------------------------------------- placement
    def _eligible(self, need: int) -> Optional[int]:
        """Least-loaded PLACEABLE shard with a free slot and `need` free
        pages."""
        best = None
        for i, s in enumerate(self.shards):
            if not self.placeable[i]:
                continue
            if len(s.free_pages) < need or None not in s.slots:
                continue
            busy = sum(r is not None for r in s.slots)
            key = (s.pages_in_use, busy, i)
            if best is None or key < best[0]:
                best = (key, i)
        return None if best is None else best[1]

    def admit(self) -> List[Tuple[int, int, Request]]:
        """Admit queued requests FIFO onto least-loaded shards.

        Returns [(shard, local_slot, request)] placements; pages are already
        reserved and mapped in `slot_pages` (logical page 0 upward — chunked
        prefill writes row 0 first; windowed slots recycle forward from
        there). Stalls — without overtaking — when the head fits nowhere."""
        placed = []
        while self.queue:
            r = self.queue[0]
            # resumed requests (preempted / recovered off a dead shard) admit
            # on prompt + emitted tokens and the remaining budget; the page
            # need is invariant under resume (see engine._admit)
            plen = r.live_prompt().shape[0]
            rem = r.remaining_new()
            need = self.pages_for(plen, rem)
            shard = self._eligible(need)
            if shard is None:
                break
            s = self.shards[shard]
            slot = s.slots.index(None)
            pages = [s.free_pages.pop() for _ in range(need)]
            s.slot_pages[slot] = {j: p for j, p in enumerate(pages)}
            s.slot_cap[slot] = -(-min(self.max_len, plen + rem)
                                 // self.page_size)
            s.pages_in_use += need
            s.slots[slot] = r
            s.chunk_next[slot] = 0
            s.prefill_fifo.append(slot)
            self.queue.pop(0)
            placed.append((shard, slot, r))
        return placed

    # ---------------------------------------------------------------- prefill
    def next_chunks(self) -> List[ChunkWork]:
        """One chunk of work per shard that has any (oldest slot first) —
        the per-shard interleaving: no shard's prefill costs another shard
        a tick."""
        work = []
        for i, s in enumerate(self.shards):
            if not s.prefill_fifo:
                continue
            slot = s.prefill_fifo[0]
            r = s.slots[slot]
            st = s.chunk_next[slot]
            plen = r.live_prompt().shape[0]
            if self.window and st:
                # recycle pages no chunk row >= st can still read; the cache
                # table row is still null, so this is host bookkeeping only
                self.recycle(i, slot, st)
            work.append(ChunkWork(
                shard=i, slot=slot, req=r, start=st,
                length=min(self.chunk_tokens, plen - st),
                final=st + self.chunk_tokens >= plen))
        return work

    def advance_chunk(self, w: ChunkWork) -> None:
        s = self.shards[w.shard]
        if w.final:
            s.prefill_fifo.pop(0)
        else:
            s.chunk_next[w.slot] = w.start + self.chunk_tokens

    def page_row(self, shard: int, slot: int):
        """The slot's (pages_per_seq,) LOCAL-physical page row (null page 0
        beyond the mapping) — what rides the chunk call and, once the slot is
        live, the device-local page table."""
        return page_row_of(self.shards[shard].slot_pages[slot],
                           self.pages_per_seq)

    # --------------------------------------------------------------- windowing
    def recycle(self, shard: int, slot: int, progress: int):
        """Free pages fully below `progress - window` — the single-host
        engine's recycle core (engine.recycle_dead_pages, ONE shared copy)
        against this shard's free list. Returns [(j_dead, j_new, phys)]
        remap and [j_dead] unmap events so the engine can mirror them into
        the device-local page table for live slots."""
        s = self.shards[shard]
        remaps, unmaps = recycle_dead_pages(
            s.slot_pages[slot], s.free_pages, s.slot_cap[slot],
            self.page_size, self.window, progress)
        s.pages_in_use -= len(unmaps)
        return remaps, unmaps

    # -------------------------------------------------------------- retirement
    def release(self, shard: int, slot: int) -> None:
        """Retire a slot at ANY lifecycle stage: drain its chunk queue and
        return every reserved page to the shard's free list (the mid-prefill
        leak fix — a slot cancelled with chunks still queued must not keep
        its reservation)."""
        s = self.shards[shard]
        s.slots[slot] = None
        if slot in s.prefill_fifo:
            s.prefill_fifo.remove(slot)
        s.chunk_next[slot] = 0
        freed = s.slot_pages[slot]
        if freed:
            s.free_pages.extend(freed.values())
            s.pages_in_use -= len(freed)
            s.slot_pages[slot] = {}
        s.slot_cap[slot] = 0

    # ------------------------------------------- fault tolerance (PR 6)
    def steal_pages(self, shard: int, n: int) -> int:
        """page_squeeze fault: up to `n` pages vanish from the shard's FREE
        list (never from live reservations — stealing mapped pages would
        corrupt live KV; squeezing free ones starves admission, which is the
        backpressure path under test). Returns pages actually taken."""
        s = self.shards[shard]
        take = min(n, len(s.free_pages))
        for _ in range(take):
            self.stolen[shard].append(s.free_pages.pop())
        return take

    def restore_pages(self, shard: int) -> int:
        """page_restore fault: every page stolen from the shard returns."""
        s = self.shards[shard]
        n = len(self.stolen[shard])
        s.free_pages.extend(self.stolen[shard])
        self.stolen[shard].clear()
        return n

    def drain_shard(self, shard: int) -> List[Request]:
        """Evacuate a draining/dead shard: release EVERY live slot (pages
        back to its free list, chunk queues drained) and hand the displaced
        requests back, oldest first, for re-admission elsewhere."""
        s = self.shards[shard]
        live = [(slot, r) for slot, r in enumerate(s.slots) if r is not None]
        for slot, _ in live:
            self.release(shard, slot)
        return [r for _, r in sorted(live, key=lambda t: t[1].rid)]

    def reset_shard(self, shard: int) -> None:
        """Rejoining shard: its pool comes back fresh — full free list, no
        mappings, no stolen stash (whatever a squeeze took died with the
        shard). Must only run on a drained shard."""
        s = self.shards[shard]
        assert all(r is None for r in s.slots), \
            f"reset of shard {shard} with live slots"
        s.free_pages = list(range(self.n_pages - 1, 0, -1))
        s.prefill_fifo = []
        s.chunk_next = [0] * self.slots_per_shard
        s.slot_pages = [{} for _ in range(self.slots_per_shard)]
        s.slot_cap = [0] * self.slots_per_shard
        s.pages_in_use = 0
        self.stolen[shard].clear()

    def requeue(self, reqs: List[Request]) -> None:
        """Re-enqueue displaced requests in rid order — each rejoins the
        FIFO exactly where its age puts it, ahead of anything younger."""
        for r in reqs:
            i = 0
            while i < len(self.queue) and self.queue[i].rid < r.rid:
                i += 1
            self.queue.insert(i, r)

    def page_starved(self, need: int) -> bool:
        """True when the head fits nowhere but at least one placeable shard
        exists — preempting a young decoding slot there can unblock it
        (frees that slot AND its pages)."""
        if self._eligible(need) is not None:
            return False
        return any(self.placeable)

    def preempt_candidate(self, need: int, head_rid: int,
                          max_preemptions: int) -> Optional[Tuple[int, int]]:
        """The YOUNGEST (max rid) decoding slot on a placeable shard that is
        strictly younger than the head, under its preemption budget, and
        whose release leaves the shard able to take the head (its pages plus
        the shard's free list cover `need`). Strict rid ordering keeps
        progress monotone — no preemption livelock."""
        best = None
        for i, s in enumerate(self.shards):
            if not self.placeable[i]:
                continue
            for slot, r in enumerate(s.slots):
                if r is None or slot in s.prefill_fifo:
                    continue
                if r.rid <= head_rid or r.preemptions >= max_preemptions:
                    continue
                if len(s.slot_pages[slot]) + len(s.free_pages) < need:
                    continue
                if best is None or r.rid > best[0]:
                    best = (r.rid, i, slot)
        return None if best is None else (best[1], best[2])

    def assert_accounting(self) -> None:
        """Pool-accounting invariant under faults: per shard,
        free + mapped + stolen == n_pages - 1 (page 0 is the null page) and
        `pages_in_use` matches the mappings exactly."""
        for i, s in enumerate(self.shards):
            mapped = sum(len(m) for m in s.slot_pages)
            assert mapped == s.pages_in_use, (i, mapped, s.pages_in_use)
            total = len(s.free_pages) + mapped + len(self.stolen[i])
            assert total == self.n_pages - 1, \
                (i, len(s.free_pages), mapped, len(self.stolen[i]))

    def find(self, req: Request) -> Optional[Tuple[int, int]]:
        for i, s in enumerate(self.shards):
            for slot, r in enumerate(s.slots):
                if r is req:
                    return i, slot
        return None

    def assert_local(self) -> None:
        """Device-locality invariant: every mapped physical page id is a
        LOCAL id inside its own shard's pool — no table entry can ever name
        another device's page."""
        for i, s in enumerate(self.shards):
            for slot, m in enumerate(s.slot_pages):
                for j, p in m.items():
                    assert 0 < p < self.n_pages, (i, slot, j, p)
