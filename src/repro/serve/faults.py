"""Deterministic fault injection for the serving stack (PR 6).

A `FaultPlan` is PURE DATA: a sorted tuple of `FaultEvent`s, each pinned to
an engine tick. The engine applies whatever events land on the current tick
at the tick boundary (before admission), so a plan replays bit-for-bit —
same plan + same traffic → the same event schedule, the same preemptions,
the same recoveries, and (the chaos-parity guarantee) the same emitted
tokens as the fault-free engine. Nothing in this module touches a clock or
an unseeded RNG.

Event kinds:
  * ``shard_death``  — the shard fails hard: every live slot it holds is
    recovered by re-prefill replay on a healthy shard (serve/health drives
    the state machine; serve/sharded performs the recovery) and the shard
    leaves placement until a ``shard_rejoin`` arrives.
  * ``shard_rejoin`` — the dead shard comes back: its free list resets and,
    after the health monitor's rejoin cooldown, it re-enters placement.
  * ``sensor_hot``   — a faulty/hot sensor reading: ``delta_c`` is added to
    the shard's predicted temperature (core/thermal's sensor extrapolation)
    for ``ticks`` ticks. Sustained hot readings walk the shard through
    DEGRADED → DRAINING. Unlike a death, a DRAINING shard's pool bytes are
    still alive, so its slots re-home by LIVE PAGE MIGRATION over the
    modeled UCIe link (serve/migration) — O(bytes), no re-prefill — with
    replay as the fallback when nothing can place them. This is the
    paper's §II sensor-driven load migration, at serving granularity.
  * ``page_squeeze`` — free-list exhaustion: up to ``pages`` pages vanish
    from the shard's free list (fragmentation / a co-tenant landing on the
    chiplet). Queued requests that can no longer reserve starve, which is
    what drives the engine's preemption-based backpressure.
  * ``page_restore`` — every page stolen from the shard so far returns.

The single-host engine honors the page events (its pool is "shard 0") and
ignores the shard-level ones; the sharded engine honors all five.
"""

from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Tuple

import numpy as np

KINDS = ("shard_death", "shard_rejoin", "sensor_hot",
         "page_squeeze", "page_restore")


@dataclasses.dataclass(frozen=True)
class FaultEvent:
    tick: int                  # engine tick the event fires on (1-based)
    kind: str                  # one of KINDS
    shard: int = 0
    pages: int = 0             # page_squeeze: pages to steal
    delta_c: float = 0.0       # sensor_hot: sensor bias in °C
    ticks: int = 0             # sensor_hot: bias duration in ticks

    def __post_init__(self):
        if self.kind not in KINDS:
            raise ValueError(f"unknown fault kind {self.kind!r}")


@dataclasses.dataclass(frozen=True)
class FaultPlan:
    """Replayable fault schedule. ``events`` is kept sorted by tick; the
    ``seed`` records provenance when the plan came from `chaos_plan`."""
    events: Tuple[FaultEvent, ...] = ()
    seed: Optional[int] = None

    def __post_init__(self):
        object.__setattr__(
            self, "events",
            tuple(sorted(self.events, key=lambda e: (e.tick, e.shard,
                                                     KINDS.index(e.kind)))))
        by_tick: Dict[int, List[FaultEvent]] = {}
        for e in self.events:
            by_tick.setdefault(e.tick, []).append(e)
        object.__setattr__(self, "_by_tick", by_tick)

    def events_at(self, tick: int) -> List[FaultEvent]:
        return self._by_tick.get(tick, [])

    @property
    def max_tick(self) -> int:
        return self.events[-1].tick if self.events else 0

    def counts(self) -> Dict[str, int]:
        out = {k: 0 for k in KINDS}
        for e in self.events:
            out[e.kind] += 1
        return out


def chaos_plan(seed: int, *, n_shards: int, n_ticks: int,
               deaths: int = 1, death_dwell: int = 8,
               squeezes: int = 3, squeeze_pages: int = 8,
               squeeze_dwell: int = 6,
               sensor_storms: int = 0, sensor_delta_c: float = 60.0,
               sensor_ticks: int = 6) -> FaultPlan:
    """Seeded chaos schedule: `deaths` death→rejoin pairs, `squeezes`
    page-steal→restore pairs and `sensor_storms` hot-sensor windows spread
    deterministically over ``n_ticks`` ticks.

    Pure function of its arguments — the same seed generates the same plan
    bit-for-bit (`FaultPlan` equality; tests pin it). At most ``n_shards-1``
    shards are ever dead at once, so the fleet always has somewhere to
    recover to."""
    if n_shards < 1:
        raise ValueError(f"n_shards must be >= 1, got {n_shards}")
    if deaths and n_shards < 2:
        raise ValueError("shard deaths need >= 2 shards to recover onto")
    rng = np.random.default_rng(seed)
    events: List[FaultEvent] = []
    dead_until: Dict[int, int] = {}        # shard -> rejoin tick

    def alive_at(tick: int) -> List[int]:
        return [s for s in range(n_shards)
                if not (s in dead_until and tick < dead_until[s])]

    for _ in range(deaths):
        t = int(rng.integers(2, max(3, n_ticks - death_dwell)))
        cands = [s for s in alive_at(t) if s in alive_at(t + death_dwell)]
        # keep a quorum: never kill the last-but-one live shard
        if len(cands) <= 1:
            continue
        shard = int(rng.choice(cands))
        events.append(FaultEvent(tick=t, kind="shard_death", shard=shard))
        events.append(FaultEvent(tick=t + death_dwell, kind="shard_rejoin",
                                 shard=shard))
        dead_until[shard] = t + death_dwell
    for _ in range(squeezes):
        t = int(rng.integers(2, max(3, n_ticks - squeeze_dwell)))
        shard = int(rng.integers(0, n_shards))
        events.append(FaultEvent(tick=t, kind="page_squeeze", shard=shard,
                                 pages=squeeze_pages))
        events.append(FaultEvent(tick=t + squeeze_dwell, kind="page_restore",
                                 shard=shard))
    for _ in range(sensor_storms):
        t = int(rng.integers(2, max(3, n_ticks - sensor_ticks)))
        shard = int(rng.integers(0, n_shards))
        events.append(FaultEvent(tick=t, kind="sensor_hot", shard=shard,
                                 delta_c=float(sensor_delta_c),
                                 ticks=sensor_ticks))
    return FaultPlan(events=tuple(events), seed=seed)
