"""Sharded multi-chiplet serving: slot- and page-partitioned engine (PR 5).

The paper's scale-out story (§II: dual NPU chiplets behind an AI-aware UCIe
interconnect) as a serving runtime: `ShardedServeEngine` partitions the
decode batch's slots AND the paged KV pool across a mesh axis (the
production mesh's 'data' axis — one shard per chiplet/device) via
`parallel/shmap.shard_map`, so the whole fleet decodes in ONE jitted global
step while every byte of KV traffic stays on the device that owns it.

Layout invariants (what makes this GSPMD-proof instead of GSPMD-hostile):
  * **Contiguous page ranges per device.** The global K/V pools are
    (L, n_shards · n_pages, page_size, KV, D), sharded on the page axis —
    each device physically owns pages [shard·n_pages, (shard+1)·n_pages).
    Inside `shard_map` a device sees only its local (L, n_pages, ...) pool.
  * **Device-local page tables.** Table entries are LOCAL page ids
    (0..n_pages-1; local page 0 is each shard's null page). A slot's pages
    are reserved from its own shard's free list only, so the decode kernel's
    scalar-prefetch gathers (kernels/decode_attention.paged_index_maps) and
    the chunk-prefill pool writes are local by construction — never a
    cross-device gather, which is exactly what the paged pool's scatter
    write pattern would otherwise force GSPMD to emit collectives for
    (ROADMAP: "a sharded pool wants pages partitioned by device with
    device-local tables").
  * **Tokens are the only per-step collective.** The global decode step runs
    per-shard decode attention + sampling under `shard_map` and all-gathers
    only the emitted (n_slots,) int32 tokens. Page tables and stream
    positions are HOST-authoritative (small int32 arrays fed in per tick),
    so there is no per-step cache sync at all and window-recycling needs no
    device-side remap programs.
  * **Weights are shard-stationary.** Params are replicated across the slot
    axis (the `serve_sharded` plan in parallel/sharding.py: the weight-
    stationary placement of `serve_ws` with the slot axis retired from every
    param rule — nothing is gathered per step). Intra-shard tensor
    parallelism over a 'model' axis inside shard_map needs manual
    collectives and is a recorded follow-on.

Admission runs through `serve/scheduler.ShardScheduler`: per-shard free
lists, least-loaded placement, and per-shard interleaved chunk prefill — a
long prompt admitted to one shard costs only that shard a chunk per tick, so
it can never stall decode on another shard.

Token parity: per-request token streams are schedule-independent (PR 4
pinned chunk-size/batch-composition invariance; sampling is keyed by
(request seed, token index)), so this engine is token-IDENTICAL to the
single-host `ServeEngine` for the same submissions — the equivalence
`tests/test_sharded_serve.py` pins on an 8-device CPU mesh for dense/moe ×
{f32, int8} KV, windowed configs, and mid-stream retirements.

Live page migration (PR 9): the one deliberate exception to "KV bytes never
cross devices". A shard_map'd move program (gather → all_gather → scatter of
whole physical pages) re-homes a live slot's pool-native bytes between
device-local partitions, so a DRAINING shard's work migrates at O(bytes) —
priced through `core/ucie.transfer`'s closed form, the SAME cost model the
simulator drains — instead of O(FLOPs) re-prefill replay (DEAD shards still
replay: their bytes are gone). The same primitive powers elastic
rebalancing (busy-gap moves + migration-instead-of-preemption) and
cross-shard replication of hot prefix pages; `serve/migration` owns the
planning policy, `ShardScheduler.migrate_slot` the atomic re-homing of
page-table rows, refcounts and registry entries. Migrated tokens stay
bit-exact; the only observable cost is the link hold before the slot's next
decode step.
"""

from __future__ import annotations

import dataclasses
import time
from typing import Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.analysis.sanitizer import register_entry_point
from repro.models.transformer import gather_pool_pages, set_pool_page
from repro.parallel.shmap import shard_map
from repro.serve.engine import (
    _KV_DTYPES, EngineOverloaded, EngineStats, Request)
from repro.serve.faults import FaultPlan
from repro.serve.health import (
    EVACUATED, Health, HealthConfig, ShardHealthMonitor)
from repro.serve.migration import (
    MigrationConfig, migration_cost, page_payload_bytes,
    plan_prefix_replication, plan_rebalance, plan_starvation_rescue)
from repro.serve.sampling import clamp_sample_params, sample_tokens
from repro.serve.scheduler import ShardScheduler


def _replicated_specs(tree):
    """Full-rank replicated PartitionSpecs matching a pytree of arrays."""
    return jax.tree.map(lambda x: P(*([None] * jnp.ndim(x))), tree)


class ShardedServeEngine:
    """Continuous batching over a device-partitioned paged KV pool.

    API mirrors `ServeEngine` (submit / step / run_to_completion / cancel /
    stats); `n_slots` is the GLOBAL decode batch (must divide by the mesh's
    shard count) and `n_pages` is the PER-SHARD pool size including each
    shard's local null page.
    """

    def __init__(self, model, *, mesh: Mesh, axis: str = "data",
                 n_slots: int = 4, max_len: int = 128, params=None,
                 page_size: int = 32, n_pages: Optional[int] = None,
                 wdtype: Optional[str] = None,
                 kv_dtype: Optional[str] = None,
                 chunk_pages: int = 2,
                 prefix_cache: Optional[bool] = None,
                 max_queue: Optional[int] = None,
                 ttl_ticks: Optional[int] = None,
                 preempt_after: int = 2,
                 max_preemptions: int = 3,
                 fault_plan: Optional[FaultPlan] = None,
                 health_cfg: Optional[HealthConfig] = None,
                 migration: bool = True,
                 migration_cfg: Optional[MigrationConfig] = None,
                 rebalance_threshold: Optional[int] = None):
        self.model = model
        self.cfg = model.cfg
        if self.cfg.family not in ("dense", "moe", "vlm"):
            raise ValueError(
                "ShardedServeEngine shards paged attention-family caches "
                f"(dense/moe/vlm), not {self.cfg.family!r} (encdec needs a "
                "sharded cross-cache paste — recorded follow-on)")
        if model.prefill_chunk is None:
            raise ValueError("sharded serving requires chunked prefill")
        if axis not in mesh.shape:
            raise ValueError(f"mesh has no {axis!r} axis: {dict(mesh.shape)}")
        for a, n in mesh.shape.items():
            if a != axis and n != 1:
                raise ValueError(
                    f"mesh axis {a!r} (size {n}) is unsupported: intra-shard "
                    "tensor parallelism inside the shard_map'd decode step "
                    "needs manual collectives (recorded follow-on) — shard "
                    f"slots over a 1-D {axis!r} mesh (launch/mesh."
                    "make_serve_mesh)")
        self.mesh, self.axis = mesh, axis
        self.n_shards = mesh.shape[axis]
        if n_slots % self.n_shards:
            raise ValueError(f"n_slots {n_slots} must divide over "
                             f"{self.n_shards} shards")
        self.n_slots = n_slots
        self.slots_per_shard = n_slots // self.n_shards
        if max_len % page_size:
            raise ValueError(f"max_len {max_len} % page_size {page_size} != 0")
        self.max_len = max_len
        self.page_size = page_size
        self.pages_per_seq = max_len // page_size

        if wdtype not in (None, "bf16", "int8"):
            raise ValueError(f"wdtype must be None/'bf16'/'int8', got {wdtype!r}")
        if wdtype == "int8":
            from repro.models.quantized import quantize_params
            params = quantize_params(params, self.cfg)
        elif wdtype == "bf16":
            params = jax.tree.map(
                lambda p: p.astype(jnp.bfloat16)
                if jnp.issubdtype(p.dtype, jnp.floating) else p, params)
        self.wdtype = wdtype
        if kv_dtype not in _KV_DTYPES:
            raise ValueError(f"unknown kv_dtype {kv_dtype!r}")
        self.kv_dtype = _KV_DTYPES[kv_dtype]
        # shard-stationary weights: placed by the serve_sharded plan (the
        # slot axis is retired from every param rule, so on a 1-D slot mesh
        # everything resolves to a replica per shard — one device_put at
        # init, never a per-step gather). Quantized pytrees ({int8_q, s}
        # leaves) no longer match the schema the plan maps over, and their
        # plan-resolved placement is replication anyway — place directly.
        from repro.parallel import sharding as sh
        if wdtype == "int8":
            param_specs = _replicated_specs(params)
        else:
            param_specs = sh.schema_pspecs(
                model.schema, mesh, sh.rules_for_plan("serve_sharded"))
        self.params = jax.device_put(params, sh.named(mesh, param_specs))
        self._param_specs = param_specs

        self._window = self.cfg.window or 0
        # windowed slots chunk one page at a time (the single-host invariant:
        # the ceil(window/page)+2 reservation must cover the chunk write-ahead)
        self.chunk_pages = 1 if self._window else max(1, int(chunk_pages))
        self.chunk_tokens = self.chunk_pages * page_size
        # per-shard pool: local null page + worst case for the shard's slots
        self.n_pages = (1 + self.slots_per_shard * self.pages_per_seq
                        if n_pages is None else n_pages)
        assert self.n_pages >= 2, self.n_pages

        # prefix cache (PR 8): per-shard ref-counted content registries;
        # default on, silently off under a sliding window (recycling
        # rewrites remapped pages in place — incompatible with sharing)
        self.prefix_cache = (not self._window) if prefix_cache is None \
            else (bool(prefix_cache) and not self._window)
        self._sched = ShardScheduler(
            n_shards=self.n_shards, slots_per_shard=self.slots_per_shard,
            n_pages=self.n_pages, page_size=page_size,
            pages_per_seq=self.pages_per_seq, max_len=max_len,
            chunk_tokens=self.chunk_tokens, window=self._window,
            prefix_cache=self.prefix_cache)

        self.stats = EngineStats()
        # ---- fault tolerance & backpressure (PR 6) -------------------------
        self.max_queue = max_queue
        self.ttl_ticks = ttl_ticks
        self.preempt_after = max(1, int(preempt_after))
        self.max_preemptions = max(0, int(max_preemptions))
        self.fault_plan = fault_plan
        # the health monitor (and its thermal/DVFS sensor integration) only
        # exists when fault injection or health tracking is requested — the
        # default engine path stays bit-identical to the pre-fault engine
        self._monitor = (ShardHealthMonitor(self.n_shards, health_cfg)
                         if fault_plan is not None or health_cfg is not None
                         else None)
        self._tick = 0               # engine tick counter (fault/TTL clock)
        self._starved = 0            # consecutive page-starved ticks
        self._any_ttl = ttl_ticks is not None
        self._recover_started: Dict[int, int] = {}  # rid -> requeue tick
        # ---- live page migration over UCIe (PR 9) --------------------------
        self._mig_cfg = migration_cfg or MigrationConfig()
        if rebalance_threshold is not None:
            self._mig_cfg = dataclasses.replace(
                self._mig_cfg, rebalance_threshold=int(rebalance_threshold))
        self._migration = bool(migration)
        # per-slot link hold: a migrated slot's pages are "on the wire" for
        # migration_ticks(bytes, UCIeConfig) engine ticks — it neither
        # decodes nor chunks until the modeled transfer lands
        self._hold = np.zeros((n_slots,), np.int32)
        self._resume_live = [False] * n_slots
        self._replica_hold: Optional[Tuple[int, int]] = None  # (rid, ticks)
        self.shard_tokens = [0] * self.n_shards
        self.shard_occupancy_sum = [0.0] * self.n_shards
        self._slots: List[Optional[Request]] = [None] * n_slots
        self._fresh = [False] * n_slots
        self._next_rid = 0
        # HOST-authoritative per-slot state, fed to the device programs each
        # tick (device-local LOCAL page ids; null rows for free/mid-prefill
        # slots so decode's garbage writes land on each shard's null page)
        self._page_table = np.zeros((n_slots, self.pages_per_seq), np.int32)
        self._pos = np.zeros((n_slots,), np.int32)
        self._active = np.zeros((n_slots,), bool)
        self._next_tok = np.zeros((n_slots, 1), np.int32)
        self._temp = np.zeros((n_slots,), np.float32)
        self._topk = np.zeros((n_slots,), np.int32)
        self._topp = np.ones((n_slots,), np.float32)
        self._sseed = np.zeros((n_slots,), np.int32)

        # ---- device-partitioned pools --------------------------------------
        abs_cache = model.cache_shape(
            n_slots, max_len, self.kv_dtype, page_size=page_size,
            n_pages=self.n_shards * self.n_pages)
        pool_keys = [k for k in abs_cache if k not in ("page_table", "pos")]
        ax = self.axis

        def _pool_spec(sds):
            # pools are (L, pages, ...) — pages partitioned over the shard
            # axis, each device owning one contiguous local range
            return P(None, ax, *([None] * (len(sds.shape) - 2)))

        self._pool_specs = {k: _pool_spec(abs_cache[k]) for k in pool_keys}
        self._pools = {
            k: jax.device_put(
                jnp.zeros(abs_cache[k].shape, abs_cache[k].dtype),
                NamedSharding(mesh, self._pool_specs[k]))
            for k in pool_keys}

        # ---- the jitted global programs ------------------------------------
        vocab = self.cfg.vocab_size
        pspecs = self._param_specs
        donate = {} if jax.default_backend() == "cpu" else \
            {"donate_argnums": (2,)}

        def _decode_core(params, tokens, pools, pt, pos):
            cache = dict(pools, page_table=pt, pos=pos)
            logits, new_cache = model.decode(params, {"tokens": tokens}, cache)
            return (logits[:, -1, :vocab],
                    {k: new_cache[k] for k in pools})

        def _decode_greedy(params, tokens, pools, pt, pos):
            self.stats.decode_compiles += 1     # trace time only
            lv, new_pools = _decode_core(params, tokens, pools, pt, pos)
            return jnp.argmax(lv, axis=-1).astype(jnp.int32), new_pools

        def _decode_sample(params, tokens, pools, pt, pos, sample):
            self.stats.decode_compiles += 1
            lv, new_pools = _decode_core(params, tokens, pools, pt, pos)
            toks = sample_tokens(
                lv.astype(jnp.float32),
                sample["temperature"], sample["top_k"], sample["top_p"],
                sample["seed"], sample["counter"])
            return toks, new_pools

        tok_spec = P(ax, None)
        pt_spec = P(ax, None)
        vec_spec = P(ax)
        sample_specs = {k: vec_spec for k in
                        ("temperature", "top_k", "top_p", "seed", "counter")}

        self._decode_jit = jax.jit(shard_map(
            _decode_greedy, mesh=mesh,
            in_specs=(pspecs, tok_spec, self._pool_specs, pt_spec, vec_spec),
            out_specs=(vec_spec, self._pool_specs)), **donate)
        self._decode_sample_jit = jax.jit(shard_map(
            _decode_sample, mesh=mesh,
            in_specs=(pspecs, tok_spec, self._pool_specs, pt_spec, vec_spec,
                      sample_specs),
            out_specs=(vec_spec, self._pool_specs)), **donate)

        def _chunk(params, batch, pools):
            self.stats.chunk_compiles += 1      # trace time only
            sub = {"tokens": batch["tokens"], "start": batch["start"],
                   "length": batch["length"],
                   "page_row": batch["page_row"][0]}
            if self.cfg.family == "vlm":
                sub["patch_rows"] = batch["patch_rows"]
                sub["n_patch"] = batch["n_patch"]
            new_cache = model.prefill_chunk(params, sub, dict(pools))
            return {k: new_cache[k] for k in pools}

        chunk_specs = {"tokens": P(ax, None), "start": vec_spec,
                       "length": vec_spec, "page_row": P(ax, None)}
        if self.cfg.family == "vlm":
            chunk_specs["patch_rows"] = P(ax, None, None)
            chunk_specs["n_patch"] = vec_spec
        self._chunk_specs = chunk_specs
        self._chunk_jit = jax.jit(shard_map(
            _chunk, mesh=mesh,
            in_specs=(pspecs, chunk_specs, self._pool_specs),
            out_specs=self._pool_specs), **donate)

        def _cow(pools, src, dst):
            # COW tail clone, one (src, dst) pair per shard, LOCAL page
            # ids. Shards with no clone this round pass src=dst=0: copying
            # the null page onto itself is a no-op by construction
            return {k: p.at[:, dst[0]].set(p[:, src[0]])
                    for k, p in pools.items()}

        cow_donate = {} if jax.default_backend() == "cpu" else \
            {"donate_argnums": (0,)}
        self._cow_jit = jax.jit(shard_map(
            _cow, mesh=mesh,
            in_specs=(self._pool_specs, vec_spec, vec_spec),
            out_specs=self._pool_specs), **cow_donate)

        # move_pool_pages (PR 9): one wave moves up to `wave_moves` pages
        # per shard between device-local pools. Each shard snapshots its
        # exports (outbox) BEFORE any write, the outboxes cross the mesh in
        # ONE all_gather — the modeled UCIe transfer — and each shard
        # scatters its imports into freshly-allocated local pages. Pools
        # move their NATIVE bytes: an int8 pool's int8 rows + f16 scales
        # are its block-compressed wire format (half the bf16 bytes), so
        # migrated pages stay bit-exact. Unused rows are 0 on both sides —
        # exporting and importing the null page are no-ops by contract.
        M = self._mig_cfg.wave_moves

        def _move(pools, out_idx, in_shard, in_slot, in_dst):
            ob = gather_pool_pages(pools, out_idx[0])
            gath = {k: jax.lax.all_gather(v, ax) for k, v in ob.items()}
            for m in range(M):
                rows = {k: gath[k][in_shard[0, m], :, in_slot[0, m]]
                        for k in gath}
                pools = set_pool_page(pools, in_dst[0, m], rows)
            return pools

        mspec = P(ax, None)
        self._move_jit = jax.jit(shard_map(
            _move, mesh=mesh,
            in_specs=(self._pool_specs, mspec, mspec, mspec, mspec),
            out_specs=self._pool_specs), **cow_donate)
        self._page_bytes = page_payload_bytes(self._pools)
        # retrace-sanitizer labels (analysis/sanitizer): the sharded engine
        # shares the single-host labels so COMPILE_BUDGETS apply unchanged,
        # plus "move" for the migration wave program
        register_entry_point("decode", self._decode_jit)
        register_entry_point("decode", self._decode_sample_jit)
        register_entry_point("chunk", self._chunk_jit)
        register_entry_point("move", self._move_jit)
        if self.prefix_cache:
            # Warm the COW clone at construction — its first use is the
            # first prefix-cache hit, which would otherwise stall every
            # shard on an XLA compile mid-serving (steady-state retrace
            # gate). All-null src=dst=0 is the documented no-op round.
            z = jnp.zeros((self.n_shards,), jnp.int32)
            self._pools = self._cow_jit(self._pools, z, z)

    # ------------------------------------------------------------- lifecycle
    def submit(self, prompt: np.ndarray, max_new_tokens: int = 16,
               extras: Optional[Dict[str, np.ndarray]] = None,
               sample_params: Optional[tuple] = None,
               seed: int = 0, ttl_ticks: Optional[int] = None) -> Request:
        """Queue a request — the single-host contract: malformed requests
        raise ValueError (nothing enqueued), a full queue raises
        EngineOverloaded (graceful backpressure)."""
        prompt = np.asarray(prompt, np.int32)
        if prompt.ndim != 1:
            raise ValueError(
                f"prompt must be a 1-D token array, got shape {prompt.shape}")
        if prompt.shape[0] < 1:
            raise ValueError("prompt must hold at least one token")
        if prompt.shape[0] > self.max_len:
            raise ValueError(
                f"prompt length {prompt.shape[0]} exceeds engine max_len "
                f"{self.max_len}")
        if max_new_tokens < 1:
            raise ValueError(
                f"max_new_tokens must be >= 1, got {max_new_tokens}")
        need = self._sched.pages_for(prompt.shape[0], max_new_tokens)
        if need > self.n_pages - 1:
            raise ValueError(f"request needs {need} pages; each shard's pool "
                             f"has {self.n_pages - 1}")
        if self.max_queue is not None \
                and len(self._sched.queue) >= self.max_queue:
            self.stats.rejected += 1
            raise EngineOverloaded(
                f"admission queue at cap ({self.max_queue}); retry later")
        temperature, top_k, top_p = 0.0, 0, 1.0
        if sample_params is not None:
            temperature, top_k, top_p = clamp_sample_params(*sample_params)
        self._next_rid += 1
        req = Request(rid=self._next_rid, prompt=prompt,
                      max_new_tokens=max_new_tokens, extras=extras,
                      temperature=temperature, top_k=top_k, top_p=top_p,
                      seed=int(seed), t_enqueue=time.time(),
                      submit_tick=self._tick, ttl_ticks=ttl_ticks)
        if ttl_ticks is not None:
            self._any_ttl = True
        self._sched.queue.append(req)
        return req

    def cancel(self, req: Request) -> None:
        """Retire a request at any stage: queued → dequeue; mid-prefill →
        drain its chunk queue and free every reserved page; decoding →
        release the slot. Pool accounting is exact in all three."""
        if req.done:
            return
        if req in self._sched.queue:
            self._sched.queue.remove(req)
        else:
            at = self._sched.find(req)
            if at is not None:
                self._release(at[0] * self.slots_per_shard + at[1])
        req.done = True
        req.t_done = time.time()

    def _gslot(self, shard: int, slot: int) -> int:
        return shard * self.slots_per_shard + slot

    def _release(self, g: int):
        shard, slot = divmod(g, self.slots_per_shard)
        self._sched.release(shard, slot)
        self._slots[g] = None
        self._active[g] = False
        self._fresh[g] = False
        self._page_table[g] = 0         # back on the shard's null page
        self._temp[g], self._topk[g] = 0.0, 0
        self._topp[g], self._sseed[g] = 1.0, 0
        self._hold[g] = 0
        self._resume_live[g] = False
        self.stats.pages_in_use = self._sched.pages_in_use

    def kv_cache_bytes(self) -> int:
        return sum(x.size * x.dtype.itemsize for x in self._pools.values())

    def assert_local_page_tables(self) -> None:
        """The zero-cross-device-reference invariant: every page-table entry
        is a LOCAL id addressing its own shard's pool partition."""
        self._sched.assert_local()
        assert int(self._page_table.max(initial=0)) < self.n_pages, \
            self._page_table.max()
        assert int(self._page_table.min(initial=0)) >= 0

    # ---------------------------------------------------------------- prefill
    def _prefill_tick(self) -> bool:
        work = self._sched.next_chunks()
        # held slots (mid-migration) don't chunk: their pages are on the wire
        work = [w for w in work
                if not self._hold[self._gslot(w.shard, w.slot)]]
        if not work:
            return False
        S, C = self.n_shards, self.chunk_tokens
        tokens = np.zeros((S, C), np.int32)
        start = np.zeros((S,), np.int32)
        length = np.zeros((S,), np.int32)
        page_rows = np.zeros((S, self.pages_per_seq), np.int32)
        batch = {"tokens": tokens, "start": start, "length": length,
                 "page_row": page_rows}
        if self.cfg.family == "vlm":
            batch["patch_rows"] = np.zeros((S, C, self.cfg.d_model),
                                           np.float32)
            batch["n_patch"] = np.zeros((S,), np.int32)
        for w in work:
            lp = w.req.live_prompt()   # resumed requests re-prefill emitted tokens
            tokens[w.shard, :w.length] = lp[w.start:w.start + w.length]
            start[w.shard] = w.start
            length[w.shard] = w.length
            page_rows[w.shard] = self._sched.page_row(w.shard, w.slot)
            if self.cfg.family == "vlm":
                pe = np.asarray((w.req.extras or {}).get(
                    "patch_embeds",
                    np.zeros((0, self.cfg.d_model), np.float32)))
                if w.start < pe.shape[0]:
                    m = min(C, pe.shape[0] - w.start)
                    batch["patch_rows"][w.shard, :m] = pe[w.start:w.start + m]
                batch["n_patch"][w.shard] = pe.shape[0]
        self._pools = self._chunk_jit(
            self.params, {k: jnp.asarray(v) for k, v in batch.items()},
            self._pools)
        self.stats.prefill_chunks += len(work)
        self.stats.prefill_pad_tokens += sum(C - w.length for w in work)
        for w in work:
            self._sched.advance_chunk(w)
            if w.final:
                self._sched.register_prefix(w.shard, w.slot, w.req)
                self._go_live(w.shard, w.slot, w.req)
        return True

    def _go_live(self, shard: int, slot: int, r) -> None:
        """Finalize a prefilled (or fully cache-hit) slot: stamp its
        DEVICE-LOCAL table row and replay position into the
        host-authoritative state."""
        g = self._gslot(shard, slot)
        lp = r.live_prompt()
        self._page_table[g] = self._sched.page_row(shard, slot)
        self._pos[g] = lp.shape[0] - 1
        self._next_tok[g, 0] = int(lp[-1])
        self._fresh[g] = True
        self._active[g] = True
        started = self._recover_started.pop(r.rid, None)
        if started is not None:   # recovered stream back live
            self.stats.recovery_ticks_sum += self._tick - started

    # ----------------------------------------------------------------- decode
    def _place(self, placements) -> None:
        cow_rounds: List[Dict[int, Tuple[int, int]]] = []
        for p in placements:
            g = self._gslot(p.shard, p.slot)
            r = p.req
            self._slots[g] = r
            self._active[g] = False
            self._fresh[g] = False
            self._temp[g], self._topk[g] = r.temperature, r.top_k
            self._topp[g], self._sseed[g] = r.top_p, r.seed
            self.stats.prefills += 1
            self.stats.prefill_tokens += (r.live_prompt().shape[0]
                                          - p.cached_tokens)
            if p.cow is not None:
                # one clone per shard per shard_map round; same-shard clones
                # spill to later rounds preserving placement order
                for rnd in cow_rounds:
                    if p.shard not in rnd:
                        rnd[p.shard] = p.cow
                        break
                else:
                    cow_rounds.append({p.shard: p.cow})
        for rnd in cow_rounds:
            src = np.zeros((self.n_shards,), np.int32)
            dst = np.zeros((self.n_shards,), np.int32)
            for shard, (s_loc, d_loc) in rnd.items():
                src[shard], dst[shard] = s_loc, d_loc
            self._pools = self._cow_jit(self._pools, jnp.asarray(src),
                                        jnp.asarray(dst))
        for p in placements:
            if p.full_hit:
                # every prompt page came from the cache: zero prefill
                # chunks, the slot goes live straight from placement
                self._go_live(p.shard, p.slot, p.req)
            if self._replica_hold is not None \
                    and p.req.rid == self._replica_hold[0]:
                # this admission rode freshly-replicated prefix pages:
                # charge it the modeled UCIe transfer before it proceeds
                g = self._gslot(p.shard, p.slot)
                self._hold[g] = self._replica_hold[1]
                if p.full_hit:
                    self._active[g] = False
                    self._page_table[g] = 0
                    self._resume_live[g] = True
                self._replica_hold = None

    def _sync_prefix_stats(self) -> None:
        sc = self._sched
        st = self.stats
        st.prefix_hits = sc.prefix_hits
        st.prefix_misses = sc.prefix_misses
        st.prefix_hit_tokens = sc.prefix_hit_tokens
        st.prefix_evictions = sc.prefix_evictions
        st.cow_copies = sc.cow_copies
        st.prefix_cached_pages = sum(len(s.lru) for s in sc.shards)

    def step(self) -> bool:
        """One engine tick: advance migration holds, apply scheduled
        faults, advance shard health (DRAINING evacuates by live page
        migration, DEAD by replay), expire TTLs, replicate a hot prefix for
        the queue head if one is remote, admit — rescuing a page-starved
        head by migrating a victim away before falling back to preemption —
        rebalance one busy-gap move, then per-shard chunk prefill and ONE
        global shard_map'd decode step."""
        self._tick += 1
        self._advance_holds()
        if self.fault_plan is not None:
            self._apply_faults()
        if self._monitor is not None:
            self._health_tick()
        if self._any_ttl:
            self._expire_ttl()
        if self._migration:
            self._replicate_prefix()
        self._place(self._sched.admit())
        rebalance = self._migration and self._mig_cfg.rebalance_threshold > 0
        if self._sched.queue:
            head = self._sched.queue[0]
            need = self._sched.pages_for(head.live_prompt().shape[0],
                                         head.remaining_new())
            if self._sched.page_starved(need):
                self._starved += 1
                if rebalance and self._rescue(need):
                    # migration-instead-of-preemption: the head unblocked
                    # without any decoded work being thrown away
                    self._place(self._sched.admit())
                    self._starved = 0
                elif self._starved >= self.preempt_after:
                    cand = self._sched.preempt_candidate(
                        need, head.rid, self.max_preemptions)
                    if cand is not None:
                        self._preempt(*cand)
                        self._place(self._sched.admit())
            else:
                self._starved = 0
        else:
            self._starved = 0
        if rebalance:
            self._rebalance_tick()
        self.stats.pages_in_use = self._sched.pages_in_use
        self.stats.peak_pages_in_use = max(self.stats.peak_pages_in_use,
                                           self.stats.pages_in_use)
        self._sync_prefix_stats()
        chunk_ran = self._prefill_tick()
        decoding = [g for g in range(self.n_slots) if self._active[g]]
        if not decoding:
            return chunk_ran
        args = (self.params, jnp.asarray(self._next_tok), self._pools,
                jnp.asarray(self._page_table), jnp.asarray(self._pos))
        if any(self._temp[g] > 0 for g in decoding):
            counter = np.asarray(
                [len(r.out_tokens) if r is not None else 0
                 for r in self._slots], np.int32)
            sample = {"temperature": jnp.asarray(self._temp),
                      "top_k": jnp.asarray(self._topk),
                      "top_p": jnp.asarray(self._topp),
                      "seed": jnp.asarray(self._sseed),
                      "counter": jnp.asarray(counter)}
            toks, self._pools = self._decode_sample_jit(*args, sample)
        else:
            toks, self._pools = self._decode_jit(*args)
        self.stats.decode_steps += 1
        self.stats.occupancy_sum += len(decoding) / self.n_slots
        for shard in range(self.n_shards):
            busy = sum(1 for g in decoding
                       if g // self.slots_per_shard == shard)
            self.shard_occupancy_sum[shard] += busy / self.slots_per_shard
        nxt = np.asarray(toks, np.int32)     # tokens: the ONLY per-step sync
        self._pos[self._active] += 1         # host-authoritative positions
        for g in decoding:
            r = self._slots[g]
            r.out_tokens.append(int(nxt[g]))
            self._next_tok[g, 0] = nxt[g]
            self.stats.tokens_out += 1
            self.shard_tokens[g // self.slots_per_shard] += 1
            if self._fresh[g]:
                if r.t_first_token is None:   # resumed slots keep the original
                    r.t_first_token = time.time()
                    r.first_token_tick = self._tick
                self._fresh[g] = False
            if len(r.out_tokens) >= r.max_new_tokens \
                    or int(self._pos[g]) >= self.max_len:
                r.done = True
                r.t_done = time.time()
                self.stats.record_request(r)
                self._release(g)
        if self._window:
            self._recycle_window_pages()
        return True

    def _recycle_window_pages(self):
        """Slide live slots' windows: scheduler bookkeeping + mirroring the
        remap/unmap events into the host-authoritative page table (the next
        decode tick sees the moved entries — same ordering as the
        single-host engine's post-decode recycling)."""
        for g in range(self.n_slots):
            if self._slots[g] is None or not self._active[g]:
                continue
            shard, slot = divmod(g, self.slots_per_shard)
            if not self._sched.shards[shard].slot_pages[slot]:
                continue
            remaps, unmaps = self._sched.recycle(shard, slot,
                                                 int(self._pos[g]))
            for j_dead, j_new, phys in remaps:
                self._page_table[g, j_dead] = 0
                self._page_table[g, j_new] = phys
            for j_dead in unmaps:
                self._page_table[g, j_dead] = 0
        self.stats.pages_in_use = self._sched.pages_in_use

    # ------------------------------------- live page migration (PR 9)
    def _advance_holds(self):
        """Count down per-slot migration holds; a slot whose hold expires
        (and whose request survived the wait) restamps its page-table row
        from the scheduler and resumes decoding — the link latency the
        `core/ucie` cost model charged is exactly how long it sat out."""
        for g in np.nonzero(self._hold > 0)[0]:
            self._hold[g] -= 1
            if self._hold[g] == 0 and self._resume_live[g] \
                    and self._slots[g] is not None:
                shard, slot = divmod(int(g), self.slots_per_shard)
                self._page_table[g] = self._sched.page_row(shard, slot)
                self._active[g] = True
                self._resume_live[g] = False

    def _device_move(self, moves) -> None:
        """Execute (src_shard, src_phys, dst_shard, dst_phys) page moves on
        device, batched into shard_map'd waves of at most `wave_moves`
        outgoing AND incoming pages per shard. Gather-before-scatter inside
        a wave (every shard snapshots its outbox before any write) and
        freshly-allocated destinations make waves order-independent."""
        M = self._mig_cfg.wave_moves
        S = self.n_shards
        i = 0
        while i < len(moves):
            out_idx = np.zeros((S, M), np.int32)
            in_shard = np.zeros((S, M), np.int32)
            in_slot = np.zeros((S, M), np.int32)
            in_dst = np.zeros((S, M), np.int32)
            out_n = [0] * S
            in_n = [0] * S
            while i < len(moves):
                ss, sp, ds, dp = moves[i]
                if out_n[ss] >= M or in_n[ds] >= M:
                    break
                out_idx[ss, out_n[ss]] = sp
                in_shard[ds, in_n[ds]] = ss
                in_slot[ds, in_n[ds]] = out_n[ss]
                in_dst[ds, in_n[ds]] = dp
                out_n[ss] += 1
                in_n[ds] += 1
                i += 1
            self._pools = self._move_jit(
                self._pools, jnp.asarray(out_idx), jnp.asarray(in_shard),
                jnp.asarray(in_slot), jnp.asarray(in_dst))

    def _migrate_slot(self, src_shard: int, src_slot: int, dst_shard: int,
                      *, count_recovery: bool = False) -> int:
        """Re-home one live slot: scheduler bookkeeping moves atomically
        (`migrate_slot`), the pages fly over the modeled UCIe link via the
        move program, and the destination slot sits held for the link's
        `migration_ticks` before its next decode step. Returns the hold."""
        g_src = self._gslot(src_shard, src_slot)
        r = self._slots[g_src]
        # a slot already on hold (migration/replica wait in flight) keeps
        # its pending go-live across a second move
        was_active = bool(self._active[g_src]) or self._resume_live[g_src]
        prior_hold = int(self._hold[g_src])
        dst_slot, page_moves = self._sched.migrate_slot(
            src_shard, src_slot, dst_shard)
        g_dst = self._gslot(dst_shard, dst_slot)
        self._device_move([(src_shard, sp, dst_shard, dp)
                           for sp, dp in page_moves])
        self._pos[g_dst] = self._pos[g_src]
        self._next_tok[g_dst, 0] = self._next_tok[g_src, 0]
        self._fresh[g_dst] = self._fresh[g_src]
        self._temp[g_dst], self._topk[g_dst] = \
            self._temp[g_src], self._topk[g_src]
        self._topp[g_dst], self._sseed[g_dst] = \
            self._topp[g_src], self._sseed[g_src]
        self._slots[g_dst], self._slots[g_src] = r, None
        self._temp[g_src], self._topk[g_src] = 0.0, 0
        self._topp[g_src], self._sseed[g_src] = 1.0, 0
        self._fresh[g_src] = False
        self._active[g_src] = self._active[g_dst] = False
        self._page_table[g_src] = 0     # back on the source's null page
        self._page_table[g_dst] = 0     # stamped when the hold expires
        self._resume_live[g_dst] = was_active
        self._resume_live[g_src] = False
        ticks, wire = migration_cost(
            len(page_moves) * self._page_bytes, self._mig_cfg)
        self._hold[g_dst] = max(ticks, prior_hold)
        self._hold[g_src] = 0
        self.stats.migrations += 1
        self.stats.migrated_pages += len(page_moves)
        self.stats.migrated_bytes_compressed += wire
        if count_recovery:
            self.stats.recoveries += 1
            self.stats.recovery_ticks_sum += ticks
        self.stats.pages_in_use = self._sched.pages_in_use
        return ticks

    def _movable(self, shard: int, slot: int) -> bool:
        """Planner veto: only settled decoding slots migrate for balance —
        never mid-prefill, never already on the wire."""
        g = self._gslot(shard, slot)
        return bool(self._active[g]) and self._hold[g] == 0

    def _rescue(self, need: int) -> bool:
        """Try migration-instead-of-preemption for a page-starved head."""
        plan = plan_starvation_rescue(self._sched, need,
                                      self._sched.placeable, self._movable)
        if plan is None:
            return False
        self._migrate_slot(*plan)
        self.stats.rebalance_events += 1
        return True

    def _rebalance_tick(self) -> None:
        """One elastic-balance move per tick when the busy-slot gap between
        shards exceeds the configured threshold."""
        plan = plan_rebalance(self._sched, self._mig_cfg.rebalance_threshold,
                              self._sched.placeable, self._movable)
        if plan is not None:
            self._migrate_slot(*plan)
            self.stats.rebalance_events += 1

    def _replicate_prefix(self) -> None:
        """Cross-shard prefix reuse for the queue head: copy a hot remote
        prefix run onto the shard admission will pick, as compressed-UCIe
        page moves instead of local re-prefill. The admission that rides
        the fresh replicas is charged the link time via a hold."""
        if not self._sched.queue or not self._sched.prefix_cache \
                or self._replica_hold is not None:
            return
        r = self._sched.queue[0]
        plan = plan_prefix_replication(self._sched, r, self._mig_cfg,
                                       self._sched.placeable)
        if plan is None:
            return
        src, dst, digests = plan
        moves = []
        for d in digests:
            mv = self._sched.replicate_page(src, dst, d)
            if mv is None:
                break
            moves.append((src, mv[0], dst, mv[1]))
        if not moves:
            return
        self._device_move(moves)
        ticks, wire = migration_cost(
            len(moves) * self._page_bytes, self._mig_cfg)
        self.stats.migrated_pages += len(moves)
        self.stats.migrated_bytes_compressed += wire
        self._replica_hold = (r.rid, ticks)
        self.stats.pages_in_use = self._sched.pages_in_use

    # ------------------------------------------- fault tolerance (PR 6)
    def _apply_faults(self):
        """Apply this tick's FaultPlan events — at the tick boundary, before
        health/admission, so a plan replays bit-for-bit."""
        for e in self.fault_plan.events_at(self._tick):
            if e.kind == "shard_death":
                if self._monitor.force_dead(e.shard):
                    self._recover_shard(e.shard)
            elif e.kind == "shard_rejoin":
                if self._monitor.begin_rejoin(e.shard):
                    # pool comes back fresh; placement resumes after the
                    # monitor's rejoin cooldown flips the shard HEALTHY
                    self._sched.reset_shard(e.shard)
            elif e.kind == "sensor_hot":
                self._monitor.inject_sensor(e.shard, e.delta_c, e.ticks)
            elif e.kind == "page_squeeze":
                self._sched.steal_pages(e.shard, e.pages)
            elif e.kind == "page_restore":
                self._sched.restore_pages(e.shard)
            self.stats.faults_injected += 1

    def _health_tick(self):
        """Advance the sensor-driven health machine one tick and react:
        shards entering DRAINING/DEAD get their live slots recovered, a
        drained shard that cooled resets its pool for rejoin, and the
        scheduler's placement mask tracks the monitor."""
        occ = np.zeros((self.n_shards,), np.float64)
        for shard in range(self.n_shards):
            base = shard * self.slots_per_shard
            occ[shard] = sum(
                1 for s in range(self.slots_per_shard)
                if self._slots[base + s] is not None) / self.slots_per_shard
        for shard, old, new in self._monitor.step(occ):
            if new in EVACUATED and old not in EVACUATED:
                # DRAINING pool bytes are still alive → live page migration;
                # DEAD bytes are gone → re-prefill replay is all there is
                self._recover_shard(shard,
                                    migrate=(new == Health.DRAINING))
            if new == Health.REJOINING and old == Health.DRAINING:
                self._sched.reset_shard(shard)
        self._sched.placeable = self._monitor.placeable()

    def _recover_shard(self, shard: int, migrate: bool = False):
        """Evacuate every live slot off a draining/dead shard.

        With `migrate=True` (DRAINING: the pool bytes are still alive) each
        slot first tries a live page migration — its physical pages move to
        a healthy shard over the modeled UCIe link at O(bytes), no prefill
        chunk is recomputed, and the stream resumes token-identically after
        the link hold. Slots that don't fit anywhere (or when migration is
        off / the shard is DEAD and its bytes are gone) fall back to PR 6's
        re-prefill replay: release, requeue in rid order, and chunk-prefill
        the live_prompt on whichever healthy shard admission picks.
        Schedule-independent KV rounding and (seed, token_index)-keyed
        sampling make BOTH paths token-exact with an uninterrupted twin."""
        base = shard * self.slots_per_shard
        remaining = []
        for s in range(self.slots_per_shard):
            g = base + s
            if self._slots[g] is None:
                continue
            if migrate and self._migration:
                placeable = (self._monitor.placeable()
                             if self._monitor is not None
                             else self._sched.placeable)
                dst = self._sched.migration_target(shard, s, placeable)
                if dst is not None:
                    self._migrate_slot(shard, s, dst, count_recovery=True)
                    continue
            remaining.append(s)
        displaced = []
        for s in remaining:
            g = base + s
            displaced.append(self._slots[g])
            self._release(g)
        if not displaced:
            return
        displaced.sort(key=lambda r: r.rid)
        self._sched.requeue(displaced)
        for r in displaced:
            self._recover_started.setdefault(r.rid, self._tick)
            self.stats.recoveries += 1
            self.stats.retries += 1

    def _preempt(self, shard: int, slot: int):
        """Evict one young decoding slot so the starving queue head can
        admit (see scheduler.preempt_candidate for the victim policy)."""
        g = self._gslot(shard, slot)
        victim = self._slots[g]
        victim.preemptions += 1
        self._release(g)
        self._sched.requeue([victim])
        self.stats.preemptions += 1
        self.stats.retries += 1
        self._starved = 0

    def _expire_ttl(self):
        """Retire queued and live requests past their TTL (ticks since
        submit), releasing pages/slots exactly like completion."""
        def expired(r: Request) -> bool:
            ttl = r.ttl_ticks if r.ttl_ticks is not None else self.ttl_ticks
            return ttl is not None and self._tick - r.submit_tick > ttl

        q = self._sched.queue
        for r in [x for x in q if expired(x)]:
            q.remove(r)
            r.done = True
            r.timed_out = True
            r.t_done = time.time()
            self.stats.timeouts += 1
        for g, r in enumerate(self._slots):
            if r is not None and expired(r):
                r.done = True
                r.timed_out = True
                r.t_done = time.time()
                self.stats.timeouts += 1
                self._release(g)

    def assert_pool_accounting(self) -> None:
        """Exact pool accounting under faults: per shard free + mapped +
        stolen == n_pages - 1, and every slot without a live request sits on
        the shard's null page row."""
        self._sched.assert_accounting()
        for g, r in enumerate(self._slots):
            if r is None:
                assert not self._page_table[g].any(), g

    def health_summary(self) -> Optional[Dict[str, object]]:
        return None if self._monitor is None else self._monitor.summary()

    def run_to_completion(self, max_ticks: int = 10_000) -> EngineStats:
        ticks = 0
        while (self._sched.queue
               or any(r is not None for r in self._slots)) \
                and ticks < max_ticks:
            self.step()
            ticks += 1
        return self.stats

    # ------------------------------------------------------------------ stats
    def shard_summary(self) -> Dict[str, float]:
        """Per-shard balance metrics for the bench's sharded section."""
        toks = self.shard_tokens
        mean = sum(toks) / max(1, len(toks))
        imb = (max(toks) - min(toks)) / mean if mean else 0.0
        return {"shard_tokens": list(toks),
                "occupancy_imbalance": imb,
                "shard_occupancy": [
                    s / self.stats.decode_steps if self.stats.decode_steps
                    else 0.0 for s in self.shard_occupancy_sum]}
