"""Runtime retrace / host-sync sanitizer for jitted entry points.

The serving stack's hot loops carry documented compile budgets: ONE chunk
compile total, O(log max_len) prefill compiles, at most two decode variants
(greedy + lazily-traced sampled), and ZERO retraces once traffic reaches
steady state. The contract linter (`analysis/contracts`) keeps the *code*
shaped so those hold; this module *measures* them at runtime:

  * `watch()` — context manager counting every XLA backend compile (via
    `jax.monitoring`'s `/jax/core/compile/backend_compile_duration` event),
    every jaxpr trace (cache miss), and every explicit device->host sync
    (`jax.device_get` + `np.asarray`/`np.array` of a jax Array) inside the
    region. The serve bench wraps its steady-state wave in one of these and
    det-gates `steady_state_retraces == 0`.
  * `register_entry_point(name, jitted_fn)` — engines label their jits
    ("decode", "chunk", "prefill", "paste", ...); compile counts per label
    come from each function's jit cache size, so they attribute exactly.
  * `compile_budget(decode=2, chunk=1, total=None)` — context manager that
    raises `CompileBudgetExceeded` when a label (or the global compile
    count) exceeds its declared budget. Usable directly in tests.

Registration holds weakrefs only — engines (and the params their jit
closures capture) die normally; dead entries are pruned on read.

Host-sync counting is explicit-conversion counting: numpy's C conversion
path doesn't consult Python-level hooks, so `watch()` temporarily wraps the
`np.asarray`/`np.array`/`np.ascontiguousarray` module attributes and
`jax.device_get`. That covers how this repo's host code materializes device
values; a sync smuggled through the buffer protocol directly is out of
scope (and R4 lints the known spellings).
"""

from __future__ import annotations

import contextlib
import dataclasses
import weakref
from typing import Dict, Iterator, List, Optional, Tuple

import jax
import numpy as np

BACKEND_COMPILE_EVENT = "/jax/core/compile/backend_compile_duration"
JAXPR_TRACE_EVENT = "/jax/core/compile/jaxpr_trace_duration"


class CompileBudgetExceeded(AssertionError):
    """A jitted entry point (or the watched region) blew its compile/sync
    budget. AssertionError subclass so plain pytest handling applies."""


@dataclasses.dataclass
class WatchLog:
    """Counters for one watched region (filled while active; entry-point
    deltas stamped at exit)."""
    compiles: int = 0        # XLA backend compiles anywhere in the process
    traces: int = 0          # jaxpr traces (cache misses, incl. jit-of-jit)
    host_syncs: int = 0      # explicit device->host materializations
    entry_compiles: Dict[str, int] = dataclasses.field(default_factory=dict)

    def summary(self) -> Dict[str, int]:
        d = {"compiles": self.compiles, "traces": self.traces,
             "host_syncs": self.host_syncs}
        d.update({f"{k}_compiles": v for k, v in
                  sorted(self.entry_compiles.items())})
        return d


_active: List[WatchLog] = []
_listener_installed = False


def _on_duration_event(event: str, duration: float, **kwargs) -> None:
    del duration, kwargs
    if event == BACKEND_COMPILE_EVENT:
        for log in _active:
            log.compiles += 1
    elif event == JAXPR_TRACE_EVENT:
        for log in _active:
            log.traces += 1


def _install_listener() -> None:
    # jax.monitoring has no unregister; install ONE process-wide listener
    # lazily and fan out to whatever watches are active (usually 0 or 1)
    global _listener_installed
    if not _listener_installed:
        jax.monitoring.register_event_duration_secs_listener(
            _on_duration_event)
        _listener_installed = True


# --------------------------------------------------------------------------
# named entry points


_entry_points: Dict[str, List[weakref.ref]] = {}


def register_entry_point(name: str, jitted_fn) -> None:
    """Label a jitted callable so `compile_budget(name=...)` can attribute
    compiles to it. Multiple functions may share a label (the greedy and
    sampled decode variants both register as "decode"); weakrefs only."""
    if not hasattr(jitted_fn, "_cache_size"):
        raise TypeError(f"{jitted_fn!r} has no _cache_size — pass the "
                        "jax.jit-wrapped function, not the python callable")
    _entry_points.setdefault(name, []).append(weakref.ref(jitted_fn))


def entry_cache_sizes() -> Dict[str, int]:
    """Live compiled-variant count per registered label (dead refs
    pruned). A label with only dead referents still reports 0 — a budget
    naming it stays valid across engine teardown."""
    out: Dict[str, int] = {}
    for name, refs in _entry_points.items():
        live = [r for r in refs if r() is not None]
        _entry_points[name] = live
        out[name] = sum(r()._cache_size() for r in live if r() is not None)
    return out


def registered_entry_points() -> Tuple[str, ...]:
    return tuple(sorted(_entry_points))


# --------------------------------------------------------------------------
# watch / budgets


@contextlib.contextmanager
def _count_host_syncs(log: WatchLog) -> Iterator[None]:
    orig_np = {name: getattr(np, name)
               for name in ("asarray", "array", "ascontiguousarray")}
    orig_get = jax.device_get

    def _wrap_np(fn):
        def wrapped(obj, *args, **kwargs):
            if isinstance(obj, jax.Array):
                log.host_syncs += 1
            return fn(obj, *args, **kwargs)
        return wrapped

    def _wrap_get(x):
        log.host_syncs += 1
        return orig_get(x)

    for name, fn in orig_np.items():
        setattr(np, name, _wrap_np(fn))
    jax.device_get = _wrap_get
    try:
        yield
    finally:
        for name, fn in orig_np.items():
            setattr(np, name, fn)
        jax.device_get = orig_get


@contextlib.contextmanager
def watch() -> Iterator[WatchLog]:
    """Count compiles / traces / explicit host syncs inside the region.
    Entry-point compile deltas are stamped on the log at exit."""
    _install_listener()
    log = WatchLog()
    before = entry_cache_sizes()
    _active.append(log)
    try:
        with _count_host_syncs(log):
            yield log
    finally:
        _active.remove(log)
        after = entry_cache_sizes()
        log.entry_compiles = {
            name: after.get(name, 0) - before.get(name, 0)
            for name in after}


@contextlib.contextmanager
def compile_budget(total: Optional[int] = None,
                   host_syncs: Optional[int] = None,
                   **entries: int) -> Iterator[WatchLog]:
    """Assert compile budgets over a region:

        with compile_budget(decode=2, chunk=1):
            ... build + run the engine ...

    Keyword budgets name registered entry points (their compile count in
    the region must stay <= the budget); `total` caps backend compiles
    process-wide; `host_syncs` caps explicit device->host pulls. Raises
    CompileBudgetExceeded listing every violation. Unknown labels raise
    ValueError at exit (catching typos — a misspelled label would otherwise
    pass vacuously); labels registered *inside* the region count."""
    with watch() as log:
        yield log
    known = set(entry_cache_sizes())
    unknown = sorted(set(entries) - known)
    if unknown:
        raise ValueError(
            f"compile_budget: unknown entry point(s) {unknown}; "
            f"registered: {sorted(known)}")
    violations = []
    for name, budget in sorted(entries.items()):
        got = log.entry_compiles.get(name, 0)
        if got > budget:
            violations.append(f"{name}: {got} compiles > budget {budget}")
    if total is not None and log.compiles > total:
        violations.append(f"total: {log.compiles} backend compiles > "
                          f"budget {total}")
    if host_syncs is not None and log.host_syncs > host_syncs:
        violations.append(f"host_syncs: {log.host_syncs} > budget "
                          f"{host_syncs}")
    if violations:
        raise CompileBudgetExceeded(
            "compile budget exceeded — " + "; ".join(violations) +
            " (a retrace in a hot loop means a shape/dtype leaked into "
            "trace context; see README 'Repo contracts & sanitizers')")
