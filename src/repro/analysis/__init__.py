"""Static analysis + runtime sanitizers for the repo's own contracts.

Two halves, one job — keep the invariants PRs 1-9 established from rotting
as the tree grows:

  * `contracts`  — an AST rule engine (R1..R7) over `src/` + `benchmarks/`:
    UCIe-cost isolation, attention-core unification, replay determinism,
    host authority, donation safety, pool-key genericity, Pallas hygiene.
    CLI: `python tools/check_contracts.py --strict`.
  * `sanitizer`  — runtime retrace / host-sync accounting for jitted entry
    points (`watch()`, `compile_budget()`), riding `jax.monitoring`'s
    compile events; the serve bench gates `steady_state_retraces == 0`
    through it.

`contracts` is pure stdlib (no jax import) so the lint gate runs anywhere;
`sanitizer` imports jax lazily at first use.
"""

from repro.analysis.contracts import (  # noqa: F401  (re-exports)
    Finding,
    Rule,
    RULES,
    rules_by_id,
    run_rules,
)
