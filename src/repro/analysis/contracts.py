"""Repo-contract linter: one AST rule engine for the serving stack's invariants.

Every rule here encodes a contract an earlier PR established and some test
used to guard with ad-hoc `inspect.getsource` + substring checks. The engine
replaces those greps with AST facts (identifiers, call sites, assignment
targets — never comments or docstrings), so a docstring *mentioning* FLIT
sizes doesn't trip the gate but code *re-deriving* them does.

Rules (id — invariant — origin):

  R1  ucie-cost-isolation      serve/* and benchmarks/* own NO link math:
                               no hard-coded bandwidth/FLIT/latency
                               constants, no direct `ucie.transfer` calls
                               outside the one sanctioned accounting wrapper
                               (`serve/migration.migration_cost`).      PR 9
  R2  attn-core-unification    `_project_qkv` / `apply_rope` call sites live
                               only in the attention core (`attn_block`),
                               the MLA plug-in, and the recurrent family's
                               local-attention block.                    PR 7
  R3  replay-determinism       fault/health/sampling/migration/scheduler
                               code is replay-deterministic: no wall clocks,
                               no stdlib `random`, no unseeded np RNG.   PR 6
  R4  host-authority           scheduler/planner code is numpy-only (tables
                               are host-authoritative); no serve module
                               blocks the tick loop on `jax.device_get` /
                               `.item()`.                                PR 5
  R5  donation-safety          a buffer passed to a `donate_argnums` jit is
                               dead — never read again in the same scope.
                                                                         PR 1
  R6  pool-key-genericity      the ("k", "v") pool-key tuple is spelled out
                               only where the pool layout is DEFINED
                               (`transformer._pools_of`/`cache_shape`/...)
                               — everything else iterates the cache's own
                               keys so MLA's ("k",) pool keeps working. PR 7
  R7  pallas-hygiene           Pallas kernel bodies and BlockSpec index maps
                               are pure: no prints, no host numpy, no
                               clocks, no global state.                  PR 1

Escape hatch: a finding is suppressed by `# contract: allow(R3)` on the
offending line or the line directly above — every use must carry a comment
justifying it (the CLI prints suppressed counts so silent rot is visible).
Per-rule structural allowlists (the sanctioned definition sites above) live
on the Rule itself.

Pure stdlib on purpose — the CI lint job needs no jax install.
"""

from __future__ import annotations

import ast
import dataclasses
import fnmatch
import pathlib
import re
from typing import Callable, Dict, Iterable, Iterator, List, Optional, Sequence, Set, Tuple

# --------------------------------------------------------------------------
# findings / rules


@dataclasses.dataclass(frozen=True)
class Finding:
    rule: str
    path: str          # repo-relative, forward slashes
    line: int
    message: str

    def __str__(self) -> str:
        return f"{self.rule} {self.path}:{self.line} — {self.message}"

    def as_dict(self) -> Dict[str, object]:
        return dataclasses.asdict(self)


@dataclasses.dataclass(frozen=True)
class Rule:
    """One contract. `check(module)` yields (node, message) pairs; the
    engine resolves lines, applies the structural `allow` list (path glob +
    enclosing-qualname glob) and the `# contract: allow(ID)` escape hatch."""
    id: str
    title: str
    rationale: str
    paths: Tuple[str, ...]                       # fnmatch globs the rule scans
    check: Callable[["Module"], Iterator[Tuple[ast.AST, str]]]
    allow: Tuple[Tuple[str, str], ...] = ()      # (path glob, qualname glob)


_ALLOW_RE = re.compile(r"#\s*contract:\s*allow\(([A-Za-z0-9_,\s]+)\)")


class Module:
    """One parsed file: AST + parent links + qualnames + allow-comments."""

    def __init__(self, root: pathlib.Path, path: pathlib.Path):
        self.rel = path.relative_to(root).as_posix()
        self.source = path.read_text()
        self.tree = ast.parse(self.source, filename=self.rel)
        self._parents: Dict[int, ast.AST] = {}
        for node in ast.walk(self.tree):
            for child in ast.iter_child_nodes(node):
                self._parents[id(child)] = node
        self.allow_lines: Dict[int, Set[str]] = {}
        for lineno, text in enumerate(self.source.splitlines(), start=1):
            m = _ALLOW_RE.search(text)
            if m:
                ids = {s.strip() for s in m.group(1).split(",") if s.strip()}
                self.allow_lines[lineno] = ids

    def parent(self, node: ast.AST) -> Optional[ast.AST]:
        return self._parents.get(id(node))

    def qualname(self, node: ast.AST) -> str:
        """Dotted chain of enclosing function/class defs ('' at module
        scope). A def's own name is included for its body AND signature."""
        parts: List[str] = []
        cur: Optional[ast.AST] = node
        while cur is not None:
            if isinstance(cur, (ast.FunctionDef, ast.AsyncFunctionDef,
                                ast.ClassDef)):
                parts.append(cur.name)
            cur = self.parent(cur)
        return ".".join(reversed(parts))

    def line_allowed(self, line: int, rule_id: str) -> bool:
        for ln in (line, line - 1):
            if rule_id in self.allow_lines.get(ln, ()):
                return True
        return False


# --------------------------------------------------------------------------
# small AST helpers


def dotted(node: ast.AST) -> Optional[str]:
    """'a.b.c' for a Name/Attribute chain, else None."""
    parts: List[str] = []
    while isinstance(node, ast.Attribute):
        parts.append(node.attr)
        node = node.value
    if isinstance(node, ast.Name):
        parts.append(node.id)
        return ".".join(reversed(parts))
    return None


def call_name(node: ast.Call) -> Optional[str]:
    """Last path segment of the callee ('f' for both f(...) and m.f(...))."""
    if isinstance(node.func, ast.Name):
        return node.func.id
    if isinstance(node.func, ast.Attribute):
        return node.func.attr
    return None


def _const_str_tuple(node: ast.AST) -> Optional[Tuple[str, ...]]:
    if isinstance(node, (ast.Tuple, ast.List)) and all(
            isinstance(e, ast.Constant) and isinstance(e.value, str)
            for e in node.elts):
        return tuple(e.value for e in node.elts)
    return None


def _has_numeric_literal(node: ast.AST) -> bool:
    return any(isinstance(n, ast.Constant) and isinstance(n.value, (int, float))
               and not isinstance(n.value, bool) for n in ast.walk(node))


# --------------------------------------------------------------------------
# R1 — UCIe cost isolation


_LINK_FIELDS = {"bandwidth_gbps", "latency_us", "pj_per_bit"}
_LINK_CONSTS = {"FLIT_BYTES", "HEADER_BYTES", "STREAM_BURST_FLITS"}
_LINK_NAME_TOKENS = ("gbps", "flit", "pj_per_bit")


def _check_ucie_isolation(mod: Module) -> Iterator[Tuple[ast.AST, str]]:
    # nodes inside a UCIeConfig(...) construction are sanctioned: building
    # the config that core/ucie prices with IS the one legitimate way to
    # name link parameters outside core/ucie
    sanctioned: Set[int] = set()
    for node in ast.walk(mod.tree):
        if isinstance(node, ast.Call) and (call_name(node) or "").endswith(
                "UCIeConfig"):
            for sub in ast.walk(node):
                sanctioned.add(id(sub))
    for node in ast.walk(mod.tree):
        if id(node) in sanctioned:
            continue
        if isinstance(node, ast.Attribute) and node.attr in _LINK_FIELDS:
            yield node, (f"link parameter `.{node.attr}` read outside "
                         "core/ucie — price the transfer through "
                         "`ucie.transfer` / `ucie.migration_ticks` instead")
        elif isinstance(node, ast.Name) and node.id in _LINK_CONSTS:
            yield node, (f"UCIe wire constant `{node.id}` used outside "
                         "core/ucie — the FLIT framing belongs to the one "
                         "quantitative link model")
        elif isinstance(node, ast.Call):
            d = dotted(node.func) or ""
            if d == "ucie.transfer" or d.endswith(".ucie.transfer"):
                yield node, ("direct `ucie.transfer` call — serving code "
                             "prices link cost through "
                             "`ucie.migration_ticks` (or the sanctioned "
                             "`migration_cost` wrapper)")
        elif isinstance(node, (ast.Assign, ast.AnnAssign)):
            targets = node.targets if isinstance(node, ast.Assign) \
                else [node.target]
            value = node.value
            if value is None or not _has_numeric_literal(value):
                continue
            for t in targets:
                name = (t.id if isinstance(t, ast.Name) else
                        t.attr if isinstance(t, ast.Attribute) else "")
                low = name.lower()
                if any(tok in low for tok in _LINK_NAME_TOKENS) or \
                        low.endswith("bandwidth") or "latency_us" in low:
                    yield node, (f"hard-coded link constant `{name}` — "
                                 "Chiplet-Actuary lesson: ONE quantitative "
                                 "cost model (core/ucie), not scattered "
                                 "constants")


# --------------------------------------------------------------------------
# R2 — attention-core unification


_ATTN_PRIMITIVES = {"_project_qkv", "apply_rope"}


def _check_attn_core(mod: Module) -> Iterator[Tuple[ast.AST, str]]:
    for node in ast.walk(mod.tree):
        if isinstance(node, ast.Call) and call_name(node) in _ATTN_PRIMITIVES:
            yield node, (f"`{call_name(node)}` call outside the attention "
                         "core — schedule wrappers reach projections only "
                         "through `attn_block(mode=...)` (PR 7 deleted the "
                         "mirrored QKV/rope bodies; don't grow them back)")
        elif isinstance(node, ast.ImportFrom):
            hit = [a.name for a in node.names if a.name in _ATTN_PRIMITIVES]
            if hit:
                yield node, (f"import of {', '.join(hit)} outside the "
                             "attention core / its plug-ins")


# --------------------------------------------------------------------------
# R3 — replay determinism


_SEEDED_NP_RANDOM = {"default_rng", "Generator", "SeedSequence"}


def _check_replay_determinism(mod: Module) -> Iterator[Tuple[ast.AST, str]]:
    why = ("fault/sampling/migration paths replay bit-for-bit from a seed — "
           "a wall clock or ambient RNG breaks `chaos_token_divergence == 0`")
    for node in ast.walk(mod.tree):
        if isinstance(node, ast.Import):
            for a in node.names:
                if a.name in ("time", "random", "datetime"):
                    yield node, f"`import {a.name}` in a replay-deterministic module — {why}"
        elif isinstance(node, ast.ImportFrom):
            if node.module in ("time", "random", "datetime"):
                yield node, f"`from {node.module} import ...` in a replay-deterministic module — {why}"
        elif isinstance(node, ast.Attribute):
            d = dotted(node) or ""
            if d.startswith("time.") or d.startswith("random."):
                yield node, f"`{d}` — {why}"
            elif d in ("datetime.now", "datetime.utcnow", "datetime.today") \
                    or d.startswith("datetime.datetime."):
                yield node, f"`{d}` — {why}"
            elif d.startswith("np.random.") or d.startswith("numpy.random."):
                leaf = d.rsplit(".", 1)[1]
                if leaf not in _SEEDED_NP_RANDOM:
                    yield node, (f"`{d}` draws from numpy's AMBIENT global "
                                 f"stream — {why}; use a seeded "
                                 "`np.random.default_rng(seed)`")
        elif isinstance(node, ast.Call):
            d = dotted(node.func) or ""
            if d.endswith("random.default_rng") and not node.args \
                    and not node.keywords:
                yield node, (f"`{d}()` without a seed is entropy-seeded — "
                             f"{why}")


# --------------------------------------------------------------------------
# R4 — host authority


_NUMPY_ONLY_FILES = {
    "src/repro/serve/scheduler.py",   # host-authoritative tables/free lists
    "src/repro/serve/migration.py",   # pure planner over scheduler views
}


def _check_host_authority(mod: Module) -> Iterator[Tuple[ast.AST, str]]:
    numpy_only = mod.rel in _NUMPY_ONLY_FILES
    for node in ast.walk(mod.tree):
        if numpy_only:
            if isinstance(node, ast.Import):
                for a in node.names:
                    if a.name == "jax" or a.name.startswith("jax."):
                        yield node, ("scheduler/planner code is HOST-"
                                     "authoritative: page tables and free "
                                     "lists are np arrays fed per tick — "
                                     "importing jax here invites per-tick "
                                     "device sync and retraces")
            elif isinstance(node, ast.ImportFrom):
                if node.module and (node.module == "jax"
                                    or node.module.startswith("jax.")):
                    yield node, ("scheduler/planner code is host-"
                                 "authoritative (numpy-only) — no jax "
                                 "imports")
            elif isinstance(node, ast.Name) and node.id == "jnp":
                yield node, ("`jnp` in host-authoritative planner code — "
                             "use `np`; device math belongs in the jitted "
                             "engine step")
        if isinstance(node, ast.Call):
            d = dotted(node.func) or ""
            if d == "jax.device_get":
                yield node, ("`jax.device_get` in the serving stack — the "
                             "tick loop keeps ONE host sync per step (the "
                             "emitted tokens); ad-hoc gets serialize the "
                             "pipeline")
            elif isinstance(node.func, ast.Attribute) \
                    and node.func.attr == "item" and not node.args:
                yield node, (".item() forces a device->host sync — pull "
                             "values through the step's one batched token "
                             "sync instead")


# --------------------------------------------------------------------------
# R5 — donation safety


def _donated_positions(call: ast.Call) -> Optional[Tuple[int, ...]]:
    """(positions,) if `call` is jax.jit(..., donate_argnums=<literal>)."""
    d = dotted(call.func) or ""
    if not (d == "jax.jit" or d.endswith(".jit") or d == "jit"):
        return None
    for kw in call.keywords:
        if kw.arg == "donate_argnums":
            v = kw.value
            if isinstance(v, ast.Constant) and isinstance(v.value, int):
                return (v.value,)
            if isinstance(v, (ast.Tuple, ast.List)) and all(
                    isinstance(e, ast.Constant) and isinstance(e.value, int)
                    for e in v.elts):
                return tuple(e.value for e in v.elts)
    return None


def _bound_name(target: ast.AST) -> Optional[str]:
    if isinstance(target, ast.Name):
        return target.id
    if isinstance(target, ast.Attribute) and \
            isinstance(target.value, ast.Name) and target.value.id == "self":
        return target.attr
    return None


def _stmt_reads(stmt: ast.stmt, skip: Set[int]) -> Iterator[ast.Name]:
    for n in ast.walk(stmt):
        if id(n) in skip:
            continue
        if isinstance(n, ast.Name) and isinstance(n.ctx, ast.Load):
            yield n


def _stmt_stores(stmt: ast.stmt) -> Set[str]:
    out: Set[str] = set()
    for n in ast.walk(stmt):
        if isinstance(n, ast.Name) and isinstance(n.ctx, (ast.Store,
                                                          ast.Del)):
            out.add(n.id)
    return out


def _flat_stmts(body: Sequence[ast.stmt]) -> Iterator[ast.stmt]:
    """Statements in source order, descending into compound bodies but NOT
    into nested function defs (their scope is analyzed separately)."""
    for stmt in body:
        yield stmt
        if isinstance(stmt, (ast.FunctionDef, ast.AsyncFunctionDef,
                             ast.ClassDef)):
            continue
        for field in ("body", "orelse", "finalbody"):
            yield from _flat_stmts(getattr(stmt, field, []) or [])
        for handler in getattr(stmt, "handlers", []) or []:
            yield from _flat_stmts(handler.body)


def _check_donation_safety(mod: Module) -> Iterator[Tuple[ast.AST, str]]:
    # pass 1: names bound to jax.jit(..., donate_argnums=<literal>)
    donated: Dict[str, Tuple[int, ...]] = {}
    for node in ast.walk(mod.tree):
        if isinstance(node, ast.Assign) and isinstance(node.value, ast.Call):
            pos = _donated_positions(node.value)
            if pos is None:
                continue
            for t in node.targets:
                name = _bound_name(t)
                if name:
                    donated[name] = pos
    if not donated:
        return
    # pass 2: per function scope, flag reads of a donated buffer after the
    # donating call (a donated buffer's storage is re-used by the output —
    # reading it afterwards returns garbage or raises on device)
    scopes = [n for n in ast.walk(mod.tree)
              if isinstance(n, (ast.FunctionDef, ast.AsyncFunctionDef))]
    scopes.append(mod.tree)  # module scope
    for scope in scopes:
        body = scope.body
        live: Dict[str, str] = {}      # donated var -> donating jit name
        for stmt in _flat_stmts(body):
            # donating calls in this statement
            marks: Dict[str, str] = {}
            call_arg_ids: Set[int] = set()
            for n in ast.walk(stmt):
                if not isinstance(n, ast.Call):
                    continue
                cname = _bound_name(n.func) if isinstance(
                    n.func, ast.Attribute) else (
                    n.func.id if isinstance(n.func, ast.Name) else None)
                if cname not in donated:
                    continue
                for i in donated[cname]:
                    if i < len(n.args) and isinstance(n.args[i], ast.Name):
                        marks[n.args[i].id] = cname
                        call_arg_ids.add(id(n.args[i]))
            # reads of already-donated buffers (the donating call's own
            # argument doesn't count)
            for name_node in _stmt_reads(stmt, call_arg_ids):
                if name_node.id in live:
                    yield name_node, (
                        f"`{name_node.id}` read after being donated to "
                        f"`{live[name_node.id]}` — donate_argnums hands the "
                        "buffer to XLA; rebind the result instead of "
                        "touching the dead operand")
            # stores kill both existing marks and this statement's own
            # (x = f(x) rebinds x to the result — safe)
            for stored in _stmt_stores(stmt):
                live.pop(stored, None)
                marks.pop(stored, None)
            live.update(marks)


# --------------------------------------------------------------------------
# R6 — pool-key genericity


def _check_pool_keys(mod: Module) -> Iterator[Tuple[ast.AST, str]]:
    for node in ast.walk(mod.tree):
        if _const_str_tuple(node) == ("k", "v"):
            yield node, ('literal ("k", "v") pool-key tuple — iterate the '
                         "cache's own pools (`transformer.pool_data_keys`) "
                         "so MLA's single ('k',) latent pool keeps working")


# --------------------------------------------------------------------------
# R7 — Pallas hygiene


_HOST_CALL_PREFIXES = ("np.", "numpy.", "time.", "random.", "jax.debug.")
_HOST_CALLS = {"print", "open", "input", "breakpoint", "device_get"}


def _kernel_bodies(mod: Module) -> Iterator[Tuple[ast.AST, str]]:
    """(function node, why-it's-a-kernel) for kernel bodies + index maps."""
    named: Dict[str, ast.FunctionDef] = {
        n.name: n for n in ast.walk(mod.tree)
        if isinstance(n, ast.FunctionDef)}
    seen: Set[int] = set()

    def emit(fn: ast.AST, kind: str):
        if id(fn) not in seen:
            seen.add(id(fn))
            yield fn, kind

    for name, fn in named.items():
        if name.endswith("_kernel"):
            yield from emit(fn, "kernel body")
    for node in ast.walk(mod.tree):
        if not isinstance(node, ast.Call):
            continue
        cn = call_name(node) or ""
        if cn == "pallas_call" and node.args:
            a = node.args[0]
            if isinstance(a, ast.Name) and a.id in named:
                yield from emit(named[a.id], "kernel body")
            elif isinstance(a, ast.Lambda):
                yield from emit(a, "kernel body")
        elif cn == "BlockSpec":
            for a in list(node.args) + [kw.value for kw in node.keywords]:
                if isinstance(a, ast.Lambda):
                    yield from emit(a, "BlockSpec index map")
                elif isinstance(a, ast.Name) and a.id in named:
                    yield from emit(named[a.id], "BlockSpec index map")


def _check_pallas_hygiene(mod: Module) -> Iterator[Tuple[ast.AST, str]]:
    for fn, kind in _kernel_bodies(mod):
        body = fn.body if isinstance(fn, (ast.FunctionDef,
                                          ast.AsyncFunctionDef)) else [fn]
        for node in (n for stmt in body for n in ast.walk(stmt)):
            if isinstance(node, (ast.Global, ast.Nonlocal)):
                yield node, (f"{kind} mutates enclosing scope — kernels and "
                             "index maps must be pure (they trace once and "
                             "replay on device)")
            elif isinstance(node, ast.Call):
                d = dotted(node.func) or ""
                leaf = call_name(node) or ""
                if leaf in _HOST_CALLS or any(
                        d.startswith(p) for p in _HOST_CALL_PREFIXES):
                    yield node, (f"host call `{d or leaf}` inside a {kind} "
                                 "— Python side effects don't exist on the "
                                 "device; they fire at trace time only and "
                                 "silently desync from execution")


# --------------------------------------------------------------------------
# the rule table


RULES: Tuple[Rule, ...] = (
    Rule(
        id="R1",
        title="UCIe cost isolation",
        rationale="ONE quantitative interconnect model (core/ucie.transfer) "
                  "prices every cross-chiplet byte — serving and benches "
                  "never re-derive link math (PR 9).",
        paths=("src/repro/serve/*.py", "benchmarks/*.py"),
        check=_check_ucie_isolation,
        allow=(
            # THE sanctioned accounting wrapper, numerically pinned by
            # tests/test_migration.py::test_ucie_single_call_path
            ("src/repro/serve/migration.py", "migration_cost"),
        ),
    ),
    Rule(
        id="R2",
        title="attention-core unification",
        rationale="QKV projection + rope run in exactly one place per "
                  "family; schedule wrappers call attn_block(mode=...) "
                  "(PR 7).",
        paths=("src/**/*.py",),
        check=_check_attn_core,
        allow=(
            # the definitions themselves
            ("src/repro/models/common.py", "*"),
            # THE core: attn_block owns all four execution modes (the
            # module-scope entry is its import of the primitives)
            ("src/repro/models/transformer.py", "attn_block"),
            ("src/repro/models/transformer.py", ""),
            # the MLA plug-in family (absorbed attention, own rope layout)
            ("src/repro/models/mla.py", "*"),
            # the recurrent family's windowed local attention — a different
            # primitive, not a decoder-core mirror
            ("src/repro/models/rglru.py", "*"),
        ),
    ),
    Rule(
        id="R3",
        title="replay determinism",
        rationale="chaos/migration parity gates replay a seeded plan "
                  "bit-for-bit; a clock or ambient RNG anywhere in these "
                  "modules breaks divergence==0 (PR 6).",
        paths=(
            "src/repro/serve/faults.py",
            "src/repro/serve/health.py",
            "src/repro/serve/sampling.py",
            "src/repro/serve/migration.py",
            "src/repro/serve/scheduler.py",
        ),
        check=_check_replay_determinism,
        allow=(),
    ),
    Rule(
        id="R4",
        title="host authority",
        rationale="page tables / free lists are host np state fed per tick; "
                  "planners stay numpy-only and the tick loop holds ONE "
                  "device sync per step (PR 5).",
        paths=("src/repro/serve/*.py",),
        check=_check_host_authority,
        allow=(),
    ),
    Rule(
        id="R5",
        title="donation safety",
        rationale="donate_argnums re-uses the operand's storage for the "
                  "output; reading a donated buffer afterwards is garbage "
                  "on TPU and only *happens* to work on CPU (PR 1).",
        paths=("src/**/*.py",),
        check=_check_donation_safety,
        allow=(),
    ),
    Rule(
        id="R6",
        title="pool-key genericity",
        rationale="cache pools are keyed per family — GQA ('k','v'), MLA "
                  "('k',); spelled-out key tuples outside the layout "
                  "definition silently skip MLA pools (PR 7).",
        paths=("src/**/*.py",),
        check=_check_pool_keys,
        allow=(
            # the layout-definition sites: the one place the key set is law
            ("src/repro/models/transformer.py", "_pools_of"),
            ("src/repro/models/transformer.py", "pool_data_keys"),
            ("src/repro/models/transformer.py", "cache_shape"),
            ("src/repro/models/transformer.py", "paged_kv_shapes"),
            # the checker that defines the forbidden pattern may spell it
            ("src/repro/analysis/contracts.py", "_check_pool_keys"),
        ),
    ),
    Rule(
        id="R7",
        title="Pallas hygiene",
        rationale="kernel bodies and BlockSpec index maps trace once and "
                  "replay on device — host calls/side effects silently "
                  "desync from execution (PR 1).",
        paths=("src/repro/kernels/*.py",),
        check=_check_pallas_hygiene,
        allow=(),
    ),
)


def rules_by_id(ids: Optional[Iterable[str]]) -> Tuple[Rule, ...]:
    if ids is None:
        return RULES
    ids = list(ids)
    by_id = {r.id: r for r in RULES}
    unknown = [i for i in ids if i not in by_id]
    if unknown:
        raise ValueError(f"unknown rule id(s) {unknown}; have "
                         f"{sorted(by_id)}")
    return tuple(by_id[i] for i in ids)


# --------------------------------------------------------------------------
# the engine


DEFAULT_SCAN = ("src/**/*.py", "benchmarks/*.py")


def _scan_files(root: pathlib.Path) -> List[pathlib.Path]:
    out: List[pathlib.Path] = []
    for glob in DEFAULT_SCAN:
        out.extend(p for p in sorted(root.glob(glob))
                   if "__pycache__" not in p.parts)
    return out


def _allowed_context(rule: Rule, rel: str, qual: str) -> bool:
    for path_glob, qual_glob in rule.allow:
        if not fnmatch.fnmatch(rel, path_glob):
            continue
        if qual_glob == "*" or fnmatch.fnmatch(qual, qual_glob) \
                or qual.startswith(qual_glob + "."):
            return True
    return False


def run_rules(root, rules: Optional[Sequence] = None,
              files: Optional[Sequence[pathlib.Path]] = None,
              collect_suppressed: Optional[List[Finding]] = None,
              ) -> List[Finding]:
    """Run the contract rules over the tree at `root`.

    `rules` — Rule objects or rule-id strings (default: all of RULES).
    `files` — explicit file list (default: DEFAULT_SCAN globs under root).
    `collect_suppressed` — optional sink for findings silenced by
    `# contract: allow(...)` comments, so callers can surface the count.
    Returns findings sorted by (path, line, rule).
    """
    root = pathlib.Path(root)
    if rules is not None and any(isinstance(r, str) for r in rules):
        rules = rules_by_id([r if isinstance(r, str) else r.id
                             for r in rules])
    rule_set: Sequence[Rule] = tuple(rules) if rules is not None else RULES
    findings: List[Finding] = []
    for path in (files if files is not None else _scan_files(root)):
        path = pathlib.Path(path)
        rel = path.relative_to(root).as_posix()
        applicable = [r for r in rule_set
                      if any(fnmatch.fnmatch(rel, g) for g in r.paths)]
        if not applicable:
            continue
        mod = Module(root, path)
        for rule in applicable:
            for node, message in rule.check(mod):
                line = getattr(node, "lineno", 1)
                if _allowed_context(rule, rel, mod.qualname(node)):
                    continue
                f = Finding(rule=rule.id, path=rel, line=line,
                            message=message)
                if mod.line_allowed(line, rule.id):
                    if collect_suppressed is not None:
                        collect_suppressed.append(f)
                    continue
                findings.append(f)
    findings.sort(key=lambda f: (f.path, f.line, f.rule))
    return findings
