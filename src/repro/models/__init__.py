"""Model zoo: the 10 assigned architectures behind one functional API."""

from repro.models.registry import ModelApi, build_model, input_specs, make_inputs
from repro.models.transformer import ExecOptions

__all__ = ["ExecOptions", "ModelApi", "build_model", "input_specs", "make_inputs"]
