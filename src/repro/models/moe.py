"""Mixture-of-Experts FFN — GShard/Mesh-TF grouped dispatch, GSPMD-friendly.

Tokens are reshaped into (G groups × group_size) and dispatched to experts
through one-hot dispatch/combine tensors built from a cumulative-sum position
assignment (capacity-bounded, dropped-token semantics, GShard [arXiv:2006.16668]).
Under the production mesh the groups dim shards over ('pod','data') and the
experts dim over 'model' (expert parallelism) when E divides the axis; the
expert contraction then reduces over 'model' exactly like a Megatron TP FFN.

This is the paper-analog layer: experts ↔ accelerator chiplets, the dispatch
einsum ↔ the UCIe die-to-die transfer, capacity ↔ link bandwidth budget.

Cost note: dispatch/combine einsums add ~group_size/(6·d_ff_expert) relative
FLOPs (≈6 % at gs=512, f=1408) — the accounting shows up in the roofline's
useful-flops ratio.
"""

from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp

from repro.models.common import ParamDef, act_fn, glu_act
from repro.models.quantized import qeinsum


def moe_schema(cfg, n_layers: int) -> dict:
    d, e, fe = cfg.d_model, cfg.n_experts, cfg.d_ff_expert
    L = n_layers
    sch = {
        "router": ParamDef((L, d, e), ("layers", "embed", None), scale=0.1),
        "w1": ParamDef((L, e, d, fe), ("layers", "experts", "embed", "ff")),
        "w3": ParamDef((L, e, d, fe), ("layers", "experts", "embed", "ff")),
        "w2": ParamDef((L, e, fe, d), ("layers", "experts", "ff", "embed")),
    }
    if cfg.d_ff_shared:
        fs = cfg.d_ff_shared
        sch["shared_w1"] = ParamDef((L, d, fs), ("layers", "embed", "ff"))
        sch["shared_w3"] = ParamDef((L, d, fs), ("layers", "embed", "ff"))
        sch["shared_w2"] = ParamDef((L, fs, d), ("layers", "ff", "embed"))
        sch["shared_gate"] = ParamDef((L, d, 1), ("layers", "embed", None), scale=0.1)
    return sch


def capacity(cfg, group_size: int) -> int:
    c = int(group_size * cfg.moe_top_k * cfg.capacity_factor / cfg.n_experts)
    return max(8, (c + 7) // 8 * 8)


def router_topk(logits: jnp.ndarray, top_k: int) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """softmax → top-k → renormalized combine gates. logits: (..., E)."""
    probs = jax.nn.softmax(logits.astype(jnp.float32), axis=-1)
    top_p, top_idx = jax.lax.top_k(probs, top_k)
    top_p = top_p / jnp.maximum(jnp.sum(top_p, axis=-1, keepdims=True), 1e-9)
    return top_p, top_idx


def make_dispatch(top_p, top_idx, n_experts: int, cap: int):
    """Build dispatch (G,S,E,C) bool-ish and combine (G,S,E,C) float tensors.

    top_p/top_idx: (G, S, K). Position-in-expert via cumulative sum over the
    flattened (S·K) assignment order (GShard §3.2); tokens past capacity drop.
    """
    g, s, k = top_idx.shape
    onehot = jax.nn.one_hot(top_idx, n_experts, dtype=jnp.float32)  # (G,S,K,E)
    flat = onehot.reshape(g, s * k, n_experts)
    pos = jnp.cumsum(flat, axis=1) - flat                            # 0-based
    pos = pos.reshape(g, s, k, n_experts)
    # position of the chosen expert per (token, k); dead entries → cap (dropped)
    pos_sel = jnp.sum(pos * onehot, axis=-1)                         # (G,S,K)
    within = pos_sel < cap
    # accumulate per-k outer products — never materialize a (G,S,K,E,C) tensor
    dispatch = jnp.zeros((g, s, n_experts, cap), jnp.float32)
    combine = jnp.zeros((g, s, n_experts, cap), jnp.float32)
    for j in range(k):
        e_oh = onehot[:, :, j, :]                                    # (G,S,E)
        c_oh = jax.nn.one_hot(pos_sel[:, :, j].astype(jnp.int32), cap,
                              dtype=jnp.float32)
        c_oh = c_oh * within[:, :, j, None]
        outer = jnp.einsum("gse,gsc->gsec", e_oh, c_oh)
        dispatch = dispatch + outer
        combine = combine + outer * top_p[:, :, j, None, None]
    return dispatch, combine


def moe_ffn(x: jnp.ndarray, p: dict, cfg, *, constrain=lambda t, *a: t):
    """x: (B, S, d) → (B, S, d). p holds this layer's slices of moe_schema."""
    b, s, d = x.shape
    e, k = cfg.n_experts, cfg.moe_top_k
    gs = min(cfg.moe_group, s)
    assert (b * s) % gs == 0, (b, s, gs)
    g = b * s // gs
    cap = capacity(cfg, gs)
    act = act_fn(glu_act(cfg.activation))

    xg = x.reshape(g, gs, d)
    # Weight-stationary decode: with one token per sequence the MoE
    # activations are KB-scale — replicate them across `data` so GSPMD never
    # re-gathers the GB-scale expert weights (measured 30 GB/step/device of
    # fp32 weight all-gathers on dbrx-132b × decode_32k; §Perf hillclimb #3).
    tok_b = None if s == 1 else "batchlike"
    xg = constrain(xg, tok_b, None, None)
    logits = jnp.einsum("gsd,de->gse", xg, p["router"].astype(x.dtype))
    top_p, top_idx = router_topk(logits, k)
    dispatch, combine = make_dispatch(top_p, top_idx, e, cap)
    dispatch = constrain(dispatch.astype(x.dtype), tok_b, None, "experts", None)
    combine = constrain(combine.astype(jnp.float32), tok_b, None, "experts", None)

    # --- dispatch: groups-sharded tokens → experts-sharded slots --------------
    xin = jnp.einsum("gsec,gsd->egcd", dispatch, xg)
    xin = constrain(xin, "experts", tok_b, None, None)
    # expert weights may be int8 (per-expert per-channel scales): qeinsum
    # vmaps the Pallas int8 matmul over the expert dim on TPU
    h = act(qeinsum("egcd,edf->egcf", xin, p["w1"])) \
        * qeinsum("egcd,edf->egcf", xin, p["w3"])
    h = constrain(h, "experts", tok_b, None, "ff")
    xout = qeinsum("egcf,efd->egcd", h, p["w2"])
    xout = constrain(xout, "experts", tok_b, None, None)
    y = jnp.einsum("gsec,egcd->gsd", combine.astype(jnp.float32),
                   xout.astype(jnp.float32)).astype(x.dtype)

    # --- shared experts (qwen2-moe), sigmoid-gated -----------------------------
    if "shared_w1" in p:
        hs = act(qeinsum("gsd,df->gsf", xg, p["shared_w1"])) \
            * qeinsum("gsd,df->gsf", xg, p["shared_w3"])
        ys = qeinsum("gsf,fd->gsd", hs, p["shared_w2"])
        gate = jax.nn.sigmoid(
            jnp.einsum("gsd,do->gso", xg, p["shared_gate"]).astype(jnp.float32))
        y = y + (ys.astype(jnp.float32) * gate).astype(x.dtype)

    return y.reshape(b, s, d)


def load_balance_loss(logits: jnp.ndarray, top_idx: jnp.ndarray, n_experts: int):
    """Switch-style aux loss: E · Σ_e f_e · p̄_e (for training integration)."""
    probs = jax.nn.softmax(logits.astype(jnp.float32), axis=-1)
    p_mean = jnp.mean(probs.reshape(-1, n_experts), axis=0)
    counts = jnp.mean(
        jax.nn.one_hot(top_idx.reshape(-1), n_experts, dtype=jnp.float32), axis=0)
    return n_experts * jnp.sum(p_mean * counts)
