"""MLA (multi-head latent attention, DeepSeek-V2) — the first attention
family plugged into the unified `attn_block` core.

Instead of per-head K/V rows, the cache holds ONE latent row per token:

    latent = [ rms_norm(x @ wkv_a)[:r] ; rope(x @ wkv_a)[r:] ]   (r + p wide)

with r = kv_lora_rank and p = qk_rope_dim. Keys and values are never
materialized per head at serve time — the "absorbed" formulation folds the
key up-projection `wk_b` into the query and the value up-projection `wv_b`
into the output:

    q_eff[h] = [ q_nope[h] @ wk_b[:, h, :].T ; rope(q_pe[h]) ]   (r + p wide)
    scores   = q_eff · latent  (== the uncompressed qk dot, scaled by
               (qk_nope_dim + qk_rope_dim)^-0.5)
    values   = latent[..., :r]            (shared across heads — MQA shape)
    out[h]   = (scores-weighted values) @ wv_b[:, h, :] @ wo[h]

so decode/chunk attention read ONE (r+p)-wide row per token with KV-head
dim 1 — the whole point: KV bytes/token shrink from 2·KV·D·itemsize to
(r+p)·itemsize, past what int8 GQA reaches (see README and bench_serve's
MLA section).

The family shares the GQA core's mode contract and cache write helpers
(`_write_row`/`_write_chunk`/`_round_rows` in models/transformer.py), so the
paged / int8 / chunked-prefill / sharded / fault-tolerant serving layers work
unchanged: they only ever see a cache dict with a "k" pool (plus "ks" scales
for int8). The Pallas kernels have no latent-row gather yet — `v_dim=` forces
the exact jnp reference path in models/attention.py (documented follow-on in
kernels/decode_attention.py / kernels/flash_attention.py).
"""

from __future__ import annotations

from typing import Any, Dict

import jax.numpy as jnp

from repro.models import attention as attn_mod
from repro.models.common import ParamDef, apply_rope, rms_norm
from repro.models.quantized import qeinsum


def mla_schema(cfg, L: int) -> Dict[str, Any]:
    """Per-layer MLA projections (layer-stacked, head-padded like GQA).

    wq (or the wq_a/q_norm/wq_b low-rank pair when q_lora_rank > 0) projects
    to per-head [qk_nope ; qk_rope] queries; wkv_a projects to the shared
    latent row; wk_b/wv_b are the absorbed key/value up-projections; wo maps
    per-head v_head_dim outputs back to d_model."""
    d, hp = cfg.d_model, cfg.n_heads_padded
    r, qk, vd = cfg.kv_lora_rank, cfg.mla_qk_dim, cfg.mla_v_dim
    assert r > 0 and cfg.qk_nope_dim > 0 and cfg.qk_rope_dim > 0, cfg.name
    sch: Dict[str, Any] = {}
    if cfg.q_lora_rank > 0:
        sch["wq_a"] = ParamDef((L, d, cfg.q_lora_rank),
                               ("layers", "embed", None))
        sch["q_norm"] = ParamDef((L, cfg.q_lora_rank), ("layers", None),
                                 init="ones")
        sch["wq_b"] = ParamDef((L, cfg.q_lora_rank, hp, qk),
                               ("layers", None, "heads", None))
    else:
        sch["wq"] = ParamDef((L, d, hp, qk), ("layers", "embed", "heads", None))
    sch["wkv_a"] = ParamDef((L, d, cfg.mla_latent_dim),
                            ("layers", "embed", None))
    sch["kv_norm"] = ParamDef((L, r), ("layers", None), init="ones")
    sch["wk_b"] = ParamDef((L, r, hp, cfg.qk_nope_dim),
                           ("layers", None, "heads", None))
    sch["wv_b"] = ParamDef((L, r, hp, vd), ("layers", None, "heads", None))
    sch["wo"] = ParamDef((L, hp, vd, d), ("layers", "heads", None, "embed"))
    return sch


def mla_attn_block(x, p, cfg, opts, *, positions, mode, cache=None,
                   kv_round=None, chunk=None, causal=True):
    """MLA self-attention under the `attn_block` mode contract.

    Same four modes, same return convention (out, new_cache_entry) — but the
    cache entry is a single latent pool under key "k" (with "ks" scales when
    int8), and decode/chunk attention pass the pool as BOTH k and v with
    `v_dim=kv_lora_rank` slicing values out of each row."""
    from repro.models.transformer import (
        _pool_entry, _round_rows, _write_chunk, _write_row, head_mask)
    r, pdim = cfg.kv_lora_rank, cfg.qk_rope_dim
    hp = cfg.n_heads_padded
    b = x.shape[0]

    # --- shared latent row: [rms_norm(compressed kv) ; rope(shared k_pe)] ---
    ckv = qeinsum("bsd,dr->bsr", x, p["wkv_a"])
    k_pe = apply_rope(ckv[..., None, r:], positions, theta=cfg.rope_theta)
    latent = jnp.concatenate(
        [rms_norm(ckv[..., :r], p["kv_norm"])[:, :, None, :], k_pe], axis=-1)

    # --- absorbed queries: (B, S, Hp, r + p) ---
    if "wq_a" in p:
        qc = rms_norm(qeinsum("bsd,dr->bsr", x, p["wq_a"]), p["q_norm"])
        q = qeinsum("bsr,rhk->bshk", qc, p["wq_b"])
    else:
        q = qeinsum("bsd,dhk->bshk", x, p["wq"])
    q_nope, q_pe = q[..., :cfg.qk_nope_dim], q[..., cfg.qk_nope_dim:]
    q_pe = apply_rope(q_pe, positions, theta=cfg.rope_theta)
    q_eff = jnp.concatenate(
        [jnp.einsum("bshn,rhn->bshr", q_nope, p["wk_b"]), q_pe], axis=-1)
    scale = cfg.mla_qk_dim ** -0.5  # the uncompressed qk width

    if mode in ("train", "prefill"):
        lat = latent if mode == "train" else _round_rows(latent, kv_round)
        o = attn_mod.attention(
            q_eff[:, :, None, :, :], lat, lat[..., :r],
            causal=causal, window=cfg.window, scale=scale,
            impl=opts.attn_impl, q_chunk=opts.q_chunk,
            kv_chunk=opts.kv_chunk, unroll=opts.unroll_scans)
        o = o[:, :, 0, :, :]
        new_cache = {"k": latent} if mode == "prefill" else None
    elif mode == "chunk":
        assert cache is not None and chunk is not None
        C = x.shape[1]
        pool, scales = _write_chunk(cache, "k", latent[0], chunk)
        o = attn_mod.chunk_attention_paged(
            q_eff.reshape(b, C, 1, hp, r + pdim), pool, pool,
            chunk["page_row"][None], chunk["start"],
            kv_len=chunk["start"] + chunk["length"],
            window=cfg.window, scale=scale, k_scale=scales, v_scale=scales,
            v_dim=r)
        o = o.reshape(b, C, hp, r)
        new_cache = _pool_entry(k=pool, ks=scales)
    else:  # decode
        assert cache is not None
        pos_b = positions.reshape(-1)
        page_table = cache.get("page_table")
        pool, scales = _write_row(cache, "k", latent, pos_b, page_table)
        o = attn_mod.decode_attention(
            q_eff.reshape(b, 1, 1, hp, r + pdim), pool, pool, pos_b + 1,
            window=cfg.window, scale=scale, page_table=page_table,
            k_scale=scales, v_scale=scales, v_dim=r)
        o = o.reshape(b, 1, hp, r)
        new_cache = _pool_entry(k=pool, ks=scales)

    # latent-space head outputs → per-head values → d_model
    o = o * head_mask(cfg, o.dtype)[None, None, :, None]
    heads = jnp.einsum("bshr,rhv->bshv", o, p["wv_b"])
    return qeinsum("bshv,hvd->bsd", heads, p["wo"]), new_cache
