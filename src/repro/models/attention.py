"""Attention: GQA/MQA/MHA with RoPE (full or partial), causal + sliding-window,
in three execution styles:

  reference_attention — naive einsum; oracle for tests and small smoke runs.
  chunked_attention   — flash-style online-softmax over (q-block, kv-block)
                        tiles in pure JAX. Peak memory is O(block²) instead of
                        O(S²); causal runs only the lower-triangular blocks
                        (python loop over q blocks → static, scan-free HLO that
                        GSPMD shards cleanly). This is the dry-run/training
                        path for the big shapes.
  decode_attention    — single-query attention against a KV cache.

Shapes: q (B, Sq, KV, G, D) where G = n_heads // n_kv_heads; k/v (B, Sk, KV, D).
"""

from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

NEG_INF = -1e30


def _mask_bias(q_pos, k_pos, *, causal: bool, window: int):
    """(Tq, Tk) additive bias from absolute positions."""
    diff = q_pos[:, None] - k_pos[None, :]
    ok = jnp.ones(diff.shape, bool)
    if causal:
        ok &= diff >= 0
    if window > 0:
        ok &= diff < window
    return jnp.where(ok, 0.0, NEG_INF).astype(jnp.float32)


def reference_attention(q, k, v, *, causal=True, window=0, scale=None,
                        q_offset=0, kv_len: Optional[jnp.ndarray] = None):
    """Oracle. q: (B,Sq,KV,G,D); k,v: (B,Sk,KV,D) → (B,Sq,KV,G,D)."""
    b, sq, nkv, g, d = q.shape
    sk = k.shape[1]
    scale = scale if scale is not None else d ** -0.5
    s = jnp.einsum("bqkgd,bskd->bkgqs", q.astype(jnp.float32),
                   k.astype(jnp.float32)) * scale
    q_pos = q_offset + jnp.arange(sq)
    k_pos = jnp.arange(sk)
    s = s + _mask_bias(q_pos, k_pos, causal=causal, window=window)
    if kv_len is not None:  # ragged validity (decode caches)
        s = jnp.where(k_pos[None, None, None, None, :] < kv_len[:, None, None, None, None],
                      s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    return jnp.einsum("bkgqs,bskd->bqkgd", p, v.astype(jnp.float32)).astype(q.dtype)


def _block_attn(q, k, v, bias, scale, m, l, acc):
    """One online-softmax tile update. q:(B,Tq,KV,G,D) k/v:(B,Tk,KV,D)."""
    s = jnp.einsum("bqkgd,bskd->bkgqs", q.astype(jnp.float32),
                   k.astype(jnp.float32)) * scale
    s = s + bias  # (Tq, Tk) broadcast
    m_new = jnp.maximum(m, jnp.max(s, axis=-1))
    p = jnp.exp(s - m_new[..., None])
    corr = jnp.exp(m - m_new)
    l_new = l * corr + jnp.sum(p, axis=-1)
    pv = jnp.einsum("bkgqs,bskd->bkgqd", p, v.astype(jnp.float32))
    acc_new = acc * corr[..., None] + pv
    return m_new, l_new, acc_new


def chunked_attention(q, k, v, *, causal=True, window=0, scale=None,
                      q_chunk=1024, kv_chunk=1024, unroll=False):
    """Flash-style attention. Python loop over q blocks; per block, a lax.scan
    over exactly the kv blocks that can contribute (causal → lower triangle;
    window → the trailing `window` band). FLOPs therefore match the masked
    ideal to within one block-row, not the 2× of a dense-masked einsum."""
    b, sq, nkv, g, d = q.shape
    sk = k.shape[1]
    dv = v.shape[-1]      # MLA values are the latent's leading slice: dv < d
    scale = scale if scale is not None else d ** -0.5
    q_chunk = min(q_chunk, sq)
    kv_chunk = min(kv_chunk, sk)
    assert sq % q_chunk == 0 and sk % kv_chunk == 0, (sq, q_chunk, sk, kv_chunk)
    n_q, n_kv = sq // q_chunk, sk // kv_chunk

    k_blocks = k.reshape(b, n_kv, kv_chunk, nkv, d)
    v_blocks = v.reshape(b, n_kv, kv_chunk, nkv, dv)

    outs = []
    for iq in range(n_q):
        qi = jax.lax.slice_in_dim(q, iq * q_chunk, (iq + 1) * q_chunk, axis=1)
        q_pos = iq * q_chunk + jnp.arange(q_chunk)
        # contributing kv block range (static)
        hi = n_kv if not causal else min(n_kv, ((iq + 1) * q_chunk + kv_chunk - 1) // kv_chunk)
        lo = 0
        if window > 0:
            lo = max(0, (iq * q_chunk - window) // kv_chunk)
        kb = k_blocks[:, lo:hi]
        vb = v_blocks[:, lo:hi]

        def body(carry, blk, q_pos=q_pos, qi=qi, lo=lo):
            m, l, acc, j = carry
            kj, vj = blk
            k_pos = (lo + j) * kv_chunk + jnp.arange(kv_chunk)
            diff = q_pos[:, None] - k_pos[None, :]
            ok = jnp.ones(diff.shape, bool)
            if causal:
                ok &= diff >= 0
            if window > 0:
                ok &= diff < window
            bias = jnp.where(ok, 0.0, NEG_INF)
            m, l, acc = _block_attn(qi, kj, vj, bias, scale, m, l, acc)
            return (m, l, acc, j + 1), None

        m0 = jnp.full((b, nkv, g, q_chunk), NEG_INF, jnp.float32)
        l0 = jnp.zeros((b, nkv, g, q_chunk), jnp.float32)
        a0 = jnp.zeros((b, nkv, g, q_chunk, dv), jnp.float32)
        from repro.models.common import scan_or_unroll
        (m, l, acc, _), _ = scan_or_unroll(
            body, (m0, l0, a0, jnp.int32(0)),
            (jnp.moveaxis(kb, 1, 0), jnp.moveaxis(vb, 1, 0)),
            unroll=unroll,
        )
        o = acc / jnp.maximum(l, 1e-30)[..., None]            # (B,KV,G,Tq,D)
        outs.append(jnp.transpose(o, (0, 3, 1, 2, 4)).astype(q.dtype))
    return jnp.concatenate(outs, axis=1) if len(outs) > 1 else outs[0]


def _pallas_decode_ok(q, k_cache, page_table=None) -> bool:
    """The Pallas decode kernel needs a TPU backend and a cache depth that
    tiles evenly; everything else falls back to the pure-jnp path."""
    if jax.default_backend() != "tpu":
        return False
    if jnp.issubdtype(k_cache.dtype, jnp.floating) and k_cache.dtype.itemsize == 1:
        return False  # fp8 caches: jnp path only (dense layout, CPU tests)
    # int8 pools tile at 32 sublanes (vs 16 for bf16): require 32-row pages
    sublane = 32 if k_cache.dtype == jnp.int8 else 16
    if page_table is not None:
        # auto-dispatch whenever a page is sublane-tileable for the storage
        # dtype; the serving default (32) qualifies for both — falling back
        # to the jnp path would densify the whole logical view per step,
        # re-buying the dense cache the pool exists to avoid. Smaller pages
        # (tests) still run via impl='pallas'.
        page_size = k_cache.shape[1]
        return page_size >= sublane and page_size % sublane == 0
    smax = k_cache.shape[1]
    return smax % min(128, smax) == 0 and smax >= 128


def decode_attention(q, k_cache, v_cache, cur_len, *, window=0, scale=None,
                     page_table=None, k_scale=None, v_scale=None,
                     v_dim: Optional[int] = None, impl: str = "auto"):
    """Single-position attention against a cache.

    q: (B,1,KV,G,D); caches: (B,Smax,KV,D); cur_len: () or (B,) int — number of
    valid cache positions (the new token's k/v must already be written).

    v_dim (MLA latent rows): the caller passes the SAME latent pool as both
    k_cache and v_cache, with keys q·D-wide and values only the leading
    `v_dim` columns of each row (models/mla.py absorbed layout). The jnp path
    slices after gather/dequant; the Pallas kernel has no latent-row gather
    yet, so v_dim forces the reference path (documented fallback —
    kernels/decode_attention.py).

    Paged layout (`page_table=` (B, pages_per_seq) int32): the caches are
    shared (n_pages, page_size, KV, D) page pools and each sequence's rows
    live at pool[page_table[b, j]] for logical page j. The jnp path below
    gathers the table back to a dense per-sequence view — exact, and the CPU
    oracle for the kernel — while the Pallas kernel gathers tile-by-tile
    through scalar prefetch and never materializes the dense view.

    INT8 caches (`k_scale`/`v_scale`): the caches hold int8 rows and the
    scales hold one f16 dequant factor per (position, kv head) — shaped like
    the caches minus the D dim. Dequant is `int8.astype(f32) * scale` — the
    jnp path materializes it on the gathered view (CPU oracle), the Pallas
    kernel fuses it into the K/V tile loads so the cache crosses HBM as int8.

    impl: 'auto' dispatches to the Pallas decode kernel
    (kernels/decode_attention) on TPU — the engine's decode step streams the
    cache through VMEM tiles instead of materializing masked scores over the
    whole Smax. 'pallas' forces the kernel (interpret mode off-TPU, used by
    the numerics tests); 'reference' forces the jnp path below.

    The caches stay in their storage dtype: fp32 accumulation happens inside
    the einsums (preferred_element_type), never as a materialized cast — a
    whole-cache fp32 copy would double the decode footprint (measured +15 GiB
    on gemma-7b × decode_32k; EXPERIMENTS.md §Perf).
    """
    assert (k_scale is None) == (v_scale is None)
    if v_dim is not None:
        impl = "reference"  # latent-row kernel gather is a follow-on
    if impl == "auto" and _pallas_decode_ok(q, k_cache, page_table):
        impl = "pallas"
    if impl == "pallas":
        from repro.kernels.decode_attention import (
            decode_attention as pallas_decode)
        return pallas_decode(
            q, k_cache, v_cache, cur_len, window=window,
            page_table=page_table, k_scale=k_scale, v_scale=v_scale,
            scale=None if scale is None else float(scale),
            interpret=jax.default_backend() != "tpu")
    b, _, nkv, g, d = q.shape
    if page_table is not None:
        # (n_pages, ps, KV, D)[(B, pp)] → (B, pp·ps, KV, D) dense view.
        # Null-page entries gather garbage rows, but they sit at logical
        # positions ≥ cur_len and are masked below like any dead row.
        k_cache = k_cache[page_table].reshape(b, -1, nkv, d)
        v_cache = v_cache[page_table].reshape(b, -1, nkv, d)
        if k_scale is not None:
            k_scale = k_scale[page_table].reshape(b, -1, nkv)
            v_scale = v_scale[page_table].reshape(b, -1, nkv)
    if k_scale is not None:
        from repro.models.quantized import dequantize_kv_rows
        k_cache = dequantize_kv_rows(k_cache, k_scale)
        v_cache = dequantize_kv_rows(v_cache, v_scale)
    if jnp.issubdtype(v_cache.dtype, jnp.floating) and v_cache.dtype.itemsize == 1:
        # fp8 storage (dense layout only): the softmax probs must not
        # round-trip through e5m2 below — upcast the gathered view once
        k_cache = k_cache.astype(jnp.float32)
        v_cache = v_cache.astype(jnp.float32)
    if v_dim is not None:
        v_cache = v_cache[..., :v_dim]
    smax = k_cache.shape[1]
    scale = scale if scale is not None else d ** -0.5
    s = jnp.einsum("bqkgd,bskd->bkgqs", q, k_cache,
                   preferred_element_type=jnp.float32) * scale
    pos = jnp.arange(smax)
    valid = pos[None, :] < jnp.reshape(cur_len, (-1, 1))        # (B, Smax)
    if window > 0:
        valid &= pos[None, :] >= (jnp.reshape(cur_len, (-1, 1)) - window)
    s = jnp.where(valid[:, None, None, None, :], s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    # kv_len == 0 means "no valid keys": emit zeros (matching the Pallas
    # kernel) instead of softmax's uniform mean over masked positions
    p = p * (jnp.reshape(cur_len, (-1, 1, 1, 1, 1)) > 0)
    o = jnp.einsum("bkgqs,bskd->bqkgd", p.astype(v_cache.dtype), v_cache,
                   preferred_element_type=jnp.float32)
    return o.astype(q.dtype)


def _pallas_chunk_ok(q, k_pool) -> bool:
    """Chunk-prefill kernel dispatch: TPU + sublane-tileable pages (32 rows
    for int8 pools, 16 for bf16) + a chunk the q-block tiles evenly."""
    if jax.default_backend() != "tpu":
        return False
    if jnp.issubdtype(k_pool.dtype, jnp.floating) and k_pool.dtype.itemsize == 1:
        return False  # fp8 pools: jnp path only
    sublane = 32 if k_pool.dtype == jnp.int8 else 16
    page_size = k_pool.shape[1]
    cq = q.shape[1]
    return (page_size >= sublane and page_size % sublane == 0
            and cq % min(128, cq) == 0)


def chunk_attention_paged(q, k_pool, v_pool, page_table, q_offset, *, kv_len,
                          window=0, scale=None, k_scale=None, v_scale=None,
                          v_dim: Optional[int] = None, impl: str = "auto"):
    """Chunk-prefill attention: a block of query rows against the page pool.

    q: (B, C, KV, G, D) — one fixed-size prefill chunk whose row i sits at
    global position q_offset[b] + i; k_pool/v_pool are the engine's shared
    (n_pages, page_size, KV, D) pools and page_table (B, pages_per_seq) maps
    the slot's logical pages onto them (null page 0 absorbs unmapped
    entries). kv_len (B,) is the LIVE length — q_offset + the chunk's real
    rows, which the caller must already have written to the pool — and masks
    stale pool rows beyond it; causality masks by global position, so chunk
    padding rows only ever produce garbage outputs, never garbage inputs.

    k_scale/v_scale: optional (n_pages, page_size, KV) scales for int8
    pools — the jnp path dequantizes the gathered view (CPU oracle), the
    Pallas kernel fuses dequant into its tile loads.

    v_dim (MLA latent rows): same single-pool convention as
    decode_attention — values are the leading v_dim columns of each latent
    row; forces the jnp reference path (kernel gather is a follow-on).

    impl: 'auto' dispatches to kernels/flash_attention.flash_attention_paged
    on TPU; 'pallas' forces the kernel (interpret off-TPU — tests);
    'reference' forces the jnp gather path below.
    """
    b, cq, nkv, g, d = q.shape
    assert (k_scale is None) == (v_scale is None)
    scale = scale if scale is not None else d ** -0.5
    if v_dim is not None:
        impl = "reference"
    if impl == "auto" and _pallas_chunk_ok(q, k_pool):
        impl = "pallas"
    if impl == "pallas":
        from repro.kernels.flash_attention import flash_attention_paged
        return flash_attention_paged(
            q, k_pool, v_pool, page_table, q_offset, kv_len,
            k_scale=k_scale, v_scale=v_scale, window=window,
            scale=float(scale), interpret=jax.default_backend() != "tpu")
    # reference: gather the table back to a dense logical view (CPU oracle)
    kd = k_pool[page_table].reshape(b, -1, nkv, d)
    vd = v_pool[page_table].reshape(b, -1, nkv, d)
    if k_scale is not None:
        from repro.models.quantized import dequantize_kv_rows
        kd = dequantize_kv_rows(kd, k_scale[page_table].reshape(b, -1, nkv))
        vd = dequantize_kv_rows(vd, v_scale[page_table].reshape(b, -1, nkv))
    if jnp.issubdtype(vd.dtype, jnp.floating) and vd.dtype.itemsize == 1:
        kd = kd.astype(jnp.float32)   # fp8 pools (see decode_attention)
        vd = vd.astype(jnp.float32)
    if v_dim is not None:
        vd = vd[..., :v_dim]
    smax = kd.shape[1]
    s = jnp.einsum("bqkgd,bskd->bkgqs", q, kd,
                   preferred_element_type=jnp.float32) * scale
    q_pos = jnp.reshape(q_offset, (-1, 1)) + jnp.arange(cq)[None, :]  # (B, C)
    k_pos = jnp.arange(smax)
    ok = k_pos[None, None, :] <= q_pos[:, :, None]                # causal
    ok &= k_pos[None, None, :] < jnp.reshape(kv_len, (-1, 1, 1))  # live rows
    if window > 0:
        ok &= q_pos[:, :, None] - k_pos[None, None, :] < window
    s = jnp.where(ok[:, None, None, :, :], s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bkgqs,bskd->bqkgd", p.astype(vd.dtype), vd,
                   preferred_element_type=jnp.float32)
    return o.astype(q.dtype)


def attention(q, k, v, *, causal=True, window=0, scale=None, impl="chunked",
              q_chunk=1024, kv_chunk=1024, unroll=False):
    if impl == "reference" or q.shape[1] <= max(256, q_chunk // 4):
        return reference_attention(q, k, v, causal=causal, window=window, scale=scale)
    return chunked_attention(q, k, v, causal=causal, window=window, scale=scale,
                             q_chunk=q_chunk, kv_chunk=kv_chunk, unroll=unroll)
