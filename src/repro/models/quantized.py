"""Weight-only INT8 quantization pass + quantization-aware einsum dispatch.

The paper's compute currency is INT8 — the dual NPU chiplets are specified at
15 TOPS INT8 each (§II) — and this module is what routes the serving decode
hot path onto that datapath:

  * `quantize_params` converts a params pytree's projection weights (QKV/O,
    FFN, MoE experts per expert, encdec self+cross) to symmetric int8 with a
    per-output-channel f32 scale, leaving embeddings, LM head, router,
    norms and biases in their original dtype (standard weight-only practice:
    those are either gathers, tiny, or routing-sensitive).
  * `qeinsum` is a drop-in for `jnp.einsum(eq, x, w)` at the projection call
    sites: plain arrays pass straight through (one isinstance check at trace
    time); quantized weights dispatch to the Pallas `kernels/int8_matmul`
    on TPU (int8 upcast in-register on the way into the MXU, f32
    accumulation, scale fused into the epilogue) and to a jnp dequant-matmul
    reference elsewhere — the CPU-exact oracle for the engine equivalence
    tests. MoE expert weights carry a leading expert dim shared with the
    activations; that pattern dispatches through `jax.vmap` of the same
    kernel (one grid batch dim per expert).
  * `quantize_kv_rows` is the KV-cache row quantizer shared by the dense and
    paged int8 KV write paths (models/transformer, models/encdec, the serve
    engine's paste programs): per-token-per-head symmetric int8 over the head
    dim, scale stored in f16 — the scale rides one value per (position, kv
    head), so the pool overhead is 2/(2·D) over bf16 and the quantized values
    are identical regardless of cache layout, which is what makes the paged
    int8 engine token-exact against the dense int8 oracle.

Quantized leaves are plain dicts `{"int8_q": int8, "s": f32}` (pytree-native:
they slice through the layer-stack lax.scan and ride jit donation unchanged).
`s` keeps the weight's rank with contraction dims reduced to 1, so any
consumer can rebroadcast it onto the matmul output.
"""

from __future__ import annotations

from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

_QKEY = "int8_q"

# family → {param key: contraction axes of the stacked weight}
# (axis 0 is always the layer stack; MoE expert weights contract over their
#  axis-2 `d` so the scale keeps the expert dim — per-expert channels.)
_ATTN_AXES = {"wq": (1,), "wk": (1,), "wv": (1,), "wo": (1, 2)}
# MLA (models/mla.py): the d_model-sized projections quantize like GQA's;
# the absorbed per-head up-projections wk_b/wv_b stay f32 — they ride plain
# einsums inside the latent attention math and their FLOPs/bytes are noise
# (r × Hp × head_dim vs d_model × Hp × head_dim).
_MLA_AXES = {"wq": (1,), "wq_a": (1,), "wq_b": (1,), "wkv_a": (1,),
             "wo": (1, 2)}
_FFN_AXES = {"w1": (1,), "w3": (1,), "w2": (1,)}
_MOE_AXES = {"w1": (2,), "w3": (2,), "w2": (2,),
             "shared_w1": (1,), "shared_w3": (1,), "shared_w2": (1,)}
_CROSS_AXES = {"c" + k: v for k, v in _ATTN_AXES.items()}


def is_quantized(w) -> bool:
    return isinstance(w, dict) and _QKEY in w


def quantize_weight_channelwise(w: jnp.ndarray,
                                axes: Tuple[int, ...]) -> Dict[str, jnp.ndarray]:
    """Symmetric int8 over `axes` (the contraction dims), keepdims f32 scale.

    One quantizer for every weight path: delegates to
    kernels/ref.quantize_channelwise_ref (which the 2-D QDQ helpers also
    use), packed as the pytree leaf `qeinsum` consumes."""
    from repro.kernels.ref import quantize_channelwise_ref
    q, s = quantize_channelwise_ref(w, axes)
    return {_QKEY: q, "s": s}


def _quantize_block(block: dict, axes_table: Dict[str, Tuple[int, ...]]) -> dict:
    return {k: (quantize_weight_channelwise(v, axes_table[k])
                if k in axes_table else v)
            for k, v in block.items()}


def quantize_params(params, cfg):
    """Weight-only int8 pass over an attention-family params pytree.

    dense/vlm: layer QKV/O + FFN.  moe: + experts (per expert) and shared
    experts; the router stays f32 (top-k selection is precision-sensitive and
    its FLOPs are noise).  encdec: encoder + decoder self- and cross-attention
    projections and FFNs.  Embeddings / LM head / norms / biases untouched.
    """
    fam = cfg.family
    if fam in ("dense", "moe", "vlm"):
        table = dict(_MLA_AXES if cfg.attn_kind == "mla" else _ATTN_AXES)
        table.update(_MOE_AXES if fam == "moe" else _FFN_AXES)
        return dict(params, layers=_quantize_block(params["layers"], table))
    if fam == "encdec":
        enc_table = dict(_ATTN_AXES, **_FFN_AXES)
        dec_table = dict(_ATTN_AXES, **_FFN_AXES, **_CROSS_AXES)
        return dict(params,
                    enc=_quantize_block(params["enc"], enc_table),
                    dec=_quantize_block(params["dec"], dec_table))
    raise ValueError(
        f"weight-only int8 applies to attention families, not {fam!r}")


# ---------------------------------------------------------------------------
# Quantization-aware einsum
# ---------------------------------------------------------------------------

def _parse(eq: str):
    lhs, out = eq.replace(" ", "").split("->")
    xs, ws = lhs.split(",")
    contract = [c for c in ws if c not in out]
    batch = [c for c in ws if c in xs and c in out]
    wout = [c for c in ws if c in out and c not in batch]
    return xs, ws, out, contract, batch, wout


def _scale_for_output(s: jnp.ndarray, ws: str, out: str, out_shape):
    """Rebroadcast a keepdims per-channel scale onto the einsum output."""
    w_letters = [c for c in out if c in ws]
    s2 = jnp.einsum(f"{ws}->{''.join(w_letters)}", s)  # squeeze+transpose
    shape = [out_shape[i] if out[i] in ws else 1 for i in range(len(out))]
    return s2.reshape(shape)


def _pallas_2d(x, q, s_flat, *, interpret: Optional[bool]):
    from repro.kernels import ops as kops
    kw = {} if interpret is None else {"interpret": interpret}
    return kops.int8_matmul(x, q, s_flat, **kw)


def qeinsum(eq: str, x: jnp.ndarray, w, *, impl: str = "auto",
            interpret: Optional[bool] = None) -> jnp.ndarray:
    """`jnp.einsum(eq, x, w)` where `w` may be a quantized `{int8_q, s}` leaf.

    impl: 'auto' uses the Pallas int8_matmul on TPU (jnp dequant-matmul
    elsewhere); 'pallas' forces the kernel (interpret mode off-TPU — tests);
    'jnp' forces the reference. The jnp path upcasts the int8 weight into the
    dot (XLA fuses the convert — the weight is never materialized in float)
    and applies the per-channel scale to the f32 accumulator, mirroring the
    kernel's epilogue.
    """
    if not is_quantized(w):
        return jnp.einsum(eq, x, w)
    q, s = w[_QKEY], w["s"]
    xs, ws, out, contract, batch, wout = _parse(eq)
    use_pallas = impl == "pallas" or (
        impl == "auto" and jax.default_backend() == "tpu")
    if use_pallas:
        got = _try_pallas(
            x, q, s, xs, ws, out, contract, batch, wout,
            interpret=interpret if interpret is not None
            else jax.default_backend() != "tpu")
        if got is not None:
            return got
    acc = jnp.einsum(eq, x, q.astype(x.dtype),
                     preferred_element_type=jnp.float32)
    acc = acc * _scale_for_output(s, ws, out, acc.shape)
    return acc.astype(x.dtype)


def _try_pallas(x, q, s, xs, ws, out, contract, batch, wout, *, interpret):
    """Reshape-to-2D dispatch onto kernels/int8_matmul; None when the einsum
    pattern or the block divisibility doesn't fit (caller falls back to jnp).

    Handled patterns (every projection call site in models/):
      no batch dim:  xs = <x-out><contract>, ws = <contract><wout>,
                     out = <x-out><wout>              (QKV/O, FFN, lm-style)
      one batch dim: the same with a shared leading letter on all three
                     operands — vmapped over it       (MoE expert weights)
    """
    c, b = "".join(contract), "".join(batch)
    if len(b) > 1 or not c:
        return None
    if b:
        if not (xs[0] == b and ws[0] == b and out[0] == b):
            return None
        xs, ws, out = xs[1:], ws[1:], out[1:]
    if not (xs.endswith(c) and ws[:len(c)] == c):
        return None
    x_out = xs[:len(xs) - len(c)]
    if ws[len(c):] != "".join(wout) or out != x_out + "".join(wout):
        return None

    from repro.kernels.int8_matmul import blocks_fit

    def dims(x_shape, q_size):
        m = k = 1
        for d in x_shape[:len(x_out)]:
            m *= d
        for d in x_shape[len(x_out):]:
            k *= d
        return m, q_size // k, k

    def flat_mm(xe, qe, se):
        m, n, k = dims(xe.shape, qe.size)
        out2 = _pallas_2d(xe.reshape(m, k), qe.reshape(k, n),
                          se.reshape(n), interpret=interpret)
        return out2.reshape(xe.shape[:len(x_out)] + qe.shape[len(c):])

    if not b:
        if not blocks_fit(*dims(x.shape, q.size)):
            return None     # kernel's clamped blocks don't tile this shape
        return flat_mm(x, q, s.reshape(q.shape[len(c):]))
    # batched (expert) path: shapes are uniform over the batch dim — check
    # divisibility on the slice shapes, then vmap the kernel (one leading
    # grid dim per expert)
    if not blocks_fit(*dims(x.shape[1:], q[0].size)):
        return None
    return jax.vmap(lambda xe, qe, se: flat_mm(
        xe, qe, se.reshape(qe.shape[len(c):])))(x, q, s)


# ---------------------------------------------------------------------------
# INT8 KV-cache row quantization (shared by dense + paged layouts)
# ---------------------------------------------------------------------------

SCALE_DTYPE = jnp.float16  # absmax/127 of unit-scale activations: range is
#                            tiny, mantissa (2^-11) is 8x below the int8 grid
#                            error, and a 2-byte scale keeps the int8 pool at
#                            (D+2)/(2D) of bf16 even at smoke head dims.


def quantize_kv_rows(kv: jnp.ndarray):
    """(..., D) K/V rows → (int8 rows, SCALE_DTYPE per-row scale (...,)).

    Per-token-per-head symmetric int8 over the head dim. The scale is rounded
    to storage dtype BEFORE the ints are computed against it, so
    `q * s` reconstructs within s/2 of the input no matter which layout
    (dense rows or page pool) stored the bytes — layout-independence is what
    the paged-vs-dense engine equivalence tests assert token-exactly.
    """
    kvf = kv.astype(jnp.float32)
    absmax = jnp.max(jnp.abs(kvf), axis=-1)
    # floor the SCALE (not the absmax) at an f16-representable value: an
    # all-zero row must quantize to (0, tiny) — a sub-f16 scale would store
    # as 0.0 and turn the next dequant-divide into NaN
    s = jnp.maximum(absmax / 127.0, 1e-6).astype(SCALE_DTYPE)
    sf = s.astype(jnp.float32)
    q = jnp.clip(jnp.round(kvf / sf[..., None]), -127, 127).astype(jnp.int8)
    return q, s


def dequantize_kv_rows(q: jnp.ndarray, s: jnp.ndarray,
                       dtype=jnp.float32) -> jnp.ndarray:
    """Exact inverse map used by BOTH the jnp reference attention path and
    (inlined) the Pallas kernel's tile loads: q.astype(f32) * s.astype(f32)."""
    return (q.astype(jnp.float32)
            * s.astype(jnp.float32)[..., None]).astype(dtype)


# ---------------------------------------------------------------------------
# Token-divergence quality guard (bench + tests)
# ---------------------------------------------------------------------------

def token_divergence(a, b) -> float:
    """1 - matching_prefix/len over two greedy token streams (0 = identical).

    Greedy decode amplifies any logit perturbation after the first flip, so
    the guard is on the PREFIX — the run of tokens the int8 engine reproduces
    before the first divergence — not positionwise equality after it.
    """
    n = max(len(a), len(b))
    if n == 0:
        return 0.0
    match = 0
    for ta, tb in zip(a, b):
        if ta != tb:
            break
        match += 1
    return 1.0 - match / n
