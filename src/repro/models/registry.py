"""Model registry — one uniform API over all 10 assigned architectures.

`build_model(cfg, opts)` returns a `ModelApi` whose members are plain
functions of (params, batch[, cache]) suitable for jax.jit with explicit
in/out shardings. `input_specs(cfg, shape)` produces ShapeDtypeStruct
stand-ins for every model input of an assigned (arch × shape) cell — the
dry-run lowers against these, allocating nothing.
"""

from __future__ import annotations

import dataclasses
import functools
from typing import Any, Callable, Dict, Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig, ShapeConfig
from repro.models import encdec, rglru, ssm, transformer
from repro.models.common import abstract_params, init_params
from repro.models.transformer import ExecOptions


@dataclasses.dataclass(frozen=True)
class ModelApi:
    cfg: ArchConfig
    opts: ExecOptions
    schema: Any
    train_loss: Callable   # (params, batch) -> (loss, metrics)
    prefill: Callable      # (params, batch) -> (logits, cache)
    decode: Callable       # (params, batch, cache) -> (logits, cache)
    cache_shape: Callable  # (batch, max_len, dtype) -> abstract cache pytree
    # Cache-only prefill (no LM-head) — serve-engine replay admissions
    # discard prefill logits; None for families without one.
    prefill_cache: Optional[Callable] = None
    # Chunked page-granular prefill: (params, batch, cache) -> cache, one
    # fixed-size chunk streamed into the paged KV pool (serve engine's
    # interleaved prefill). None for families without a paged cache.
    prefill_chunk: Optional[Callable] = None
    # encdec only: (params, batch) -> {'ck','cv'} — encoder + cross K/V,
    # computed once at admission for the chunked prefill path.
    prefill_cross: Optional[Callable] = None

    def init(self, key: jax.Array, dtype=None):
        return init_params(self.schema, key, dtype or _dt(self.cfg))

    def abstract(self, dtype=None):
        return abstract_params(self.schema, dtype or _dt(self.cfg))


def _dt(cfg: ArchConfig):
    return {"bfloat16": jnp.bfloat16, "float32": jnp.float32}[cfg.dtype]


def build_model(cfg: ArchConfig, opts: Optional[ExecOptions] = None) -> ModelApi:
    opts = opts or ExecOptions()
    fam = cfg.family
    if cfg.attn_kind == "mla":
        # MLA is an attention family, not a model family: it plugs into the
        # decoder-only stack via the unified attn_block core (models/mla.py)
        # and inherits every transformer entry point below unchanged.
        if fam not in ("dense", "moe", "vlm"):
            raise ValueError(
                f"attn_kind='mla' needs the decoder-only stack, got "
                f"family={fam!r} ({cfg.name})")
        if min(cfg.kv_lora_rank, cfg.qk_nope_dim, cfg.qk_rope_dim) <= 0:
            raise ValueError(
                f"mla config {cfg.name} must set kv_lora_rank/qk_nope_dim/"
                f"qk_rope_dim")
    if fam in ("dense", "moe", "vlm"):
        mod = transformer
        sch = transformer.schema(cfg)
        return ModelApi(
            cfg=cfg, opts=opts, schema=sch,
            train_loss=functools.partial(mod.train_loss, cfg=cfg, opts=opts),
            prefill=functools.partial(mod.prefill, cfg=cfg, opts=opts),
            decode=functools.partial(mod.decode_step, cfg=cfg, opts=opts),
            cache_shape=functools.partial(mod.cache_shape, cfg),
            prefill_cache=functools.partial(mod.prefill_cache, cfg=cfg,
                                            opts=opts),
            prefill_chunk=functools.partial(mod.prefill_chunk, cfg=cfg,
                                            opts=opts),
        )
    if fam == "ssm":
        sch = ssm.schema(cfg)
        return ModelApi(
            cfg=cfg, opts=opts, schema=sch,
            train_loss=functools.partial(ssm.train_loss, cfg=cfg, opts=opts),
            prefill=functools.partial(ssm.prefill, cfg=cfg, opts=opts),
            decode=functools.partial(ssm.decode_step, cfg=cfg, opts=opts),
            cache_shape=functools.partial(ssm.cache_shape, cfg),
        )
    if fam == "hybrid":
        sch = rglru.schema(cfg)

        def train_loss(params, batch):
            hidden, _ = rglru.forward(params, batch["tokens"], cfg, opts,
                                      mode="train")
            loss = transformer.chunked_ce_loss(
                hidden, transformer.lm_head_weights(params, cfg),
                batch["labels"], cfg, opts)
            return loss, {"loss": loss}

        def prefill(params, batch):
            hidden, states = rglru.forward(params, batch["tokens"], cfg, opts,
                                           mode="prefill")
            logits = jnp.einsum(
                "bsd,vd->bsv", hidden[:, -1:, :],
                transformer.lm_head_weights(params, cfg)).astype(jnp.float32)
            from repro.models.common import softcap
            logits = softcap(logits, cfg.logit_softcap)
            b, s = batch["tokens"].shape
            return logits, {"layers": states,
                            "pos": jnp.full((b,), s, jnp.int32)}

        def decode(params, batch, cache):
            pos = cache["pos"]
            hidden, states = rglru.forward(
                params, batch["tokens"], cfg, opts, mode="decode",
                cache=cache["layers"], positions=pos)
            logits = jnp.einsum(
                "bsd,vd->bsv", hidden,
                transformer.lm_head_weights(params, cfg)).astype(jnp.float32)
            from repro.models.common import softcap
            logits = softcap(logits, cfg.logit_softcap)
            return logits, {"layers": states, "pos": pos + 1}

        def cache_shape(batch, max_len, dtype=jnp.bfloat16):
            return {"layers": rglru.cache_shape(cfg, batch, max_len, dtype),
                    "pos": jax.ShapeDtypeStruct((batch,), jnp.int32)}

        return ModelApi(cfg=cfg, opts=opts, schema=sch, train_loss=train_loss,
                        prefill=prefill, decode=decode, cache_shape=cache_shape)
    if fam == "encdec":
        sch = encdec.schema(cfg)
        return ModelApi(
            cfg=cfg, opts=opts, schema=sch,
            train_loss=functools.partial(encdec.train_loss, cfg=cfg, opts=opts),
            prefill=functools.partial(encdec.prefill, cfg=cfg, opts=opts),
            decode=functools.partial(encdec.decode_step, cfg=cfg, opts=opts),
            cache_shape=functools.partial(encdec.cache_shape, cfg),
            prefill_cache=functools.partial(encdec.prefill_cache, cfg=cfg,
                                            opts=opts),
            prefill_chunk=functools.partial(encdec.prefill_chunk, cfg=cfg,
                                            opts=opts),
            prefill_cross=functools.partial(encdec.prefill_cross, cfg=cfg,
                                            opts=opts),
        )
    raise ValueError(f"unknown family {fam!r}")


# ---------------------------------------------------------------------------
# Dry-run input specs (ShapeDtypeStruct stand-ins, no allocation)
# ---------------------------------------------------------------------------

def input_specs(cfg: ArchConfig, shape: ShapeConfig,
                dtype=jnp.bfloat16) -> Dict[str, Any]:
    """Model inputs for one (arch × shape) cell.

    train:    {'tokens','labels'} (+ 'patch_embeds' vlm / 'frames' audio)
    prefill:  {'tokens'} (+ frontend stubs)
    decode:   {'tokens' (B,1)} — the cache comes via `ModelApi.cache_shape`.
    """
    b, s = shape.global_batch, shape.seq_len
    i32 = jnp.int32
    if shape.kind == "train":
        specs = {
            "tokens": jax.ShapeDtypeStruct((b, s), i32),
            "labels": jax.ShapeDtypeStruct((b, s), i32),
        }
        if cfg.family == "vlm":
            specs["patch_embeds"] = jax.ShapeDtypeStruct(
                (b, cfg.n_image_tokens, cfg.d_model), dtype)
        if cfg.family == "encdec":
            specs["frames"] = jax.ShapeDtypeStruct((b, s, cfg.d_model), dtype)
        return specs
    if shape.kind == "prefill":
        specs = {"tokens": jax.ShapeDtypeStruct((b, s), i32)}
        if cfg.family == "vlm":
            specs["patch_embeds"] = jax.ShapeDtypeStruct(
                (b, cfg.n_image_tokens, cfg.d_model), dtype)
        if cfg.family == "encdec":
            specs["frames"] = jax.ShapeDtypeStruct((b, cfg.cross_len, cfg.d_model),
                                                   dtype)
        return specs
    # decode: one new token against a seq_len-deep cache
    return {"tokens": jax.ShapeDtypeStruct((b, 1), i32)}


def make_inputs(cfg: ArchConfig, shape: ShapeConfig, key: jax.Array,
                dtype=jnp.bfloat16) -> Dict[str, jnp.ndarray]:
    """Concrete random inputs matching `input_specs` (smoke tests/examples)."""
    specs = input_specs(cfg, shape, dtype)
    out = {}
    for name, sds in specs.items():
        key, k = jax.random.split(key)
        if sds.dtype == jnp.int32:
            out[name] = jax.random.randint(k, sds.shape, 0, cfg.vocab_size,
                                           jnp.int32)
        else:
            out[name] = jax.random.normal(k, sds.shape, jnp.float32).astype(sds.dtype)
    return out
