"""RecurrentGemma / Griffin hybrid — RG-LRU recurrent blocks + local attention
in a (rec, rec, attn) pattern [arXiv:2402.19427].

The RG-LRU recurrence h_t = a_t·h_{t-1} + √(1−a_t²)·(i_t⊙x_t) with
a_t = exp(−c·softplus(Λ)·r_t) runs as a log-depth jax.lax.associative_scan
over the sequence (fp32). Local attention uses the shared chunked-attention
machinery with window=2048. Constant-size state (LRU h + window cache) →
this family runs the long_500k cell.

Layer pattern is heterogeneous, so params are stacked per block type
('rec' ×18, 'attn' ×8 for 26 layers) and the layer loop is a static python
unroll indexing those stacks; MLP + norms stack over all layers.
"""

from __future__ import annotations

from typing import Any, Dict, Optional

import jax
import jax.numpy as jnp

from repro.models import attention as attn_mod
from repro.models.common import (
    ParamDef, act_fn, apply_rope, causal_conv1d, glu_act, rms_norm,
)

LRU_C = 8.0


def _counts(cfg):
    pat = cfg.layer_pattern()
    return sum(1 for b in pat if b == "rec"), sum(1 for b in pat if b == "attn")


def schema(cfg) -> Dict[str, Any]:
    d, w, f = cfg.d_model, cfg.lru_width, cfg.d_ff
    h, kv, hd = cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    L, v, k = cfg.n_layers, cfg.padded_vocab, cfg.conv_kernel
    nr, na = _counts(cfg)
    ni = "zeros" if cfg.norm_plus_one else "ones"
    rec = {
        "in_x": ParamDef((nr, d, w), ("layers", "embed", "ff")),
        "in_gate": ParamDef((nr, d, w), ("layers", "embed", "ff")),
        "conv_w": ParamDef((nr, k, w), ("layers", None, "ff"), init="small_normal"),
        "gate_a_w": ParamDef((nr, w, w), ("layers", "embed", "ff"), scale=0.5),
        "gate_a_b": ParamDef((nr, w), ("layers", "ff"), init="zeros"),
        "gate_x_w": ParamDef((nr, w, w), ("layers", "embed", "ff"), scale=0.5),
        "gate_x_b": ParamDef((nr, w), ("layers", "ff"), init="zeros"),
        "lam": ParamDef((nr, w), ("layers", "ff"), init="ones"),
        "out": ParamDef((nr, w, d), ("layers", "ff", "embed")),
    }
    from repro.models.transformer import attn_schema
    att = attn_schema(cfg, na)
    mlp = {
        "t_norm": ParamDef((L, d), ("layers", None), init=ni),
        "m_norm": ParamDef((L, d), ("layers", None), init=ni),
        "w1": ParamDef((L, d, f), ("layers", "embed", "ff")),
        "w3": ParamDef((L, d, f), ("layers", "embed", "ff")),
        "w2": ParamDef((L, f, d), ("layers", "ff", "embed")),
    }
    return {
        "embed": ParamDef((v, d), ("vocab", "embed"), init="small_normal"),
        "final_norm": ParamDef((d,), (None,), init=ni),
        "rec": rec,
        "attn": att,
        "mlp": mlp,
    }


# ---------------------------------------------------------------------------
# RG-LRU
# ---------------------------------------------------------------------------

def _lru_gates(x, rp):
    """x: (B,S,w) → log-decay la (fp32), gated input gx (fp32)."""
    r = jax.nn.sigmoid(jnp.einsum("bsw,wv->bsv", x, rp["gate_a_w"])
                       .astype(jnp.float32) + rp["gate_a_b"].astype(jnp.float32))
    i = jax.nn.sigmoid(jnp.einsum("bsw,wv->bsv", x, rp["gate_x_w"])
                       .astype(jnp.float32) + rp["gate_x_b"].astype(jnp.float32))
    la = -LRU_C * jax.nn.softplus(rp["lam"].astype(jnp.float32)) * r
    a = jnp.exp(la)
    gx = jnp.sqrt(jnp.maximum(1.0 - a * a, 1e-12)) * i * x.astype(jnp.float32)
    return a, gx


def rg_lru_scan(a, gx, h0: Optional[jnp.ndarray] = None):
    """Associative linear recurrence h_t = a_t·h_{t-1} + gx_t over axis 1."""
    if h0 is not None:
        # fold the initial state into the first input
        gx = gx.at[:, 0].add(a[:, 0] * h0)

    def combine(c1, c2):
        a1, b1 = c1
        a2, b2 = c2
        return a1 * a2, a2 * b1 + b2

    av, bv = jax.lax.associative_scan(combine, (a, gx), axis=1)
    return bv  # (B,S,w) hidden states


def rg_lru_scan_chunked(a, gx, h0: Optional[jnp.ndarray] = None, *,
                        chunk: int = 256, unroll: bool = False):
    """Chunked linear recurrence: log-depth associative scan within chunks,
    lax.scan state carry across chunks.

    Differentiating a full-sequence associative_scan keeps O(log S) fp32
    (B,S,w) intermediates alive — measured 109 GiB/device on recurrentgemma
    train_4k (EXPERIMENTS.md §Perf). Chunking bounds the AD working set to
    O(B·chunk·w·log chunk) while staying numerically identical."""
    b, s, w = a.shape
    chunk = min(chunk, s)
    if s % chunk or s == chunk:
        return rg_lru_scan(a, gx, h0)
    nc = s // chunk
    ac = a.reshape(b, nc, chunk, w).transpose(1, 0, 2, 3)
    gc = gx.reshape(b, nc, chunk, w).transpose(1, 0, 2, 3)

    def body(h, xs):
        a_k, g_k = xs
        hs = rg_lru_scan(a_k, g_k, h0=h)
        return hs[:, -1], hs

    from repro.models.common import scan_or_unroll
    init = h0 if h0 is not None else jnp.zeros((b, w), a.dtype)
    _, ys = scan_or_unroll(body, init, (ac, gc), unroll=unroll)
    return ys.transpose(1, 0, 2, 3).reshape(b, s, w)


def rec_block_full(x, rp, cfg, constrain, unroll: bool = False):
    """Full-sequence recurrent block. Returns (out, state dict)."""
    gate = act_fn("gelu")(jnp.einsum("bsd,dw->bsw", x, rp["in_gate"]))
    xr = jnp.einsum("bsd,dw->bsw", x, rp["in_x"])
    xr, conv_state = causal_conv1d(xr, rp["conv_w"])
    xr = constrain(xr, "batchlike", None, "ff")
    a, gx = _lru_gates(xr, rp)
    h = rg_lru_scan_chunked(a, gx, chunk=cfg.ssm_chunk, unroll=unroll)
    y = (h.astype(x.dtype)) * gate
    out = jnp.einsum("bsw,wd->bsd", y, rp["out"])
    return out, {"h": h[:, -1].astype(jnp.float32), "conv": conv_state}


def rec_block_decode(x, rp, cfg, state):
    """One-step recurrent block. x: (B,1,d)."""
    gate = act_fn("gelu")(jnp.einsum("bsd,dw->bsw", x, rp["in_gate"]))
    xr = jnp.einsum("bsd,dw->bsw", x, rp["in_x"])
    xr, conv_state = causal_conv1d(xr, rp["conv_w"], state=state["conv"])
    a, gx = _lru_gates(xr, rp)
    h = a[:, 0] * state["h"] + gx[:, 0]
    y = h[:, None].astype(x.dtype) * gate
    out = jnp.einsum("bsw,wd->bsd", y, rp["out"])
    return out, {"h": h, "conv": conv_state}


# ---------------------------------------------------------------------------
# Local attention (MQA, window)
# ---------------------------------------------------------------------------

def attn_block_full(x, ap, cfg, opts, positions, want_cache):
    from repro.models.transformer import _expand_kv, head_mask
    c = opts.constrain
    q = jnp.einsum("bsd,dhk->bshk", x, ap["wq"])
    k = jnp.einsum("bsd,dhk->bshk", x, ap["wk"])
    v = jnp.einsum("bsd,dhk->bshk", x, ap["wv"])
    q = apply_rope(q, positions, theta=cfg.rope_theta)
    k = apply_rope(k, positions, theta=cfg.rope_theta)
    kx, vx = _expand_kv(k, v, cfg)
    qp = c(q[:, :, :, None, :], "batchlike", None, "heads_flat", None, None)
    kx = c(kx, "batchlike", None, "heads_flat", None)
    vx = c(vx, "batchlike", None, "heads_flat", None)
    o = attn_mod.attention(qp, kx, vx, causal=True, window=cfg.window,
                           scale=cfg.head_dim ** -0.5, impl=opts.attn_impl,
                           q_chunk=opts.q_chunk, kv_chunk=opts.kv_chunk,
                           unroll=opts.unroll_scans)
    o = o[:, :, :, 0, :] * head_mask(cfg, x.dtype)[None, None, :, None]
    out = jnp.einsum("bshk,hkd->bsd", o, ap["wo"])
    cache = None
    if want_cache:
        # keep the trailing `window` positions, ring-aligned (slot = pos % W)
        s = x.shape[1]
        w = cfg.window
        if s >= w:
            k_tail, v_tail = k[:, s - w:], v[:, s - w:]
            shift = s % w
            k_ring = jnp.roll(k_tail, shift, axis=1)
            v_ring = jnp.roll(v_tail, shift, axis=1)
        else:
            pad = [(0, 0), (0, w - s), (0, 0), (0, 0)]
            k_ring, v_ring = jnp.pad(k, pad), jnp.pad(v, pad)
        cache = {"k": k_ring, "v": v_ring}
    return out, cache


def attn_block_decode(x, ap, cfg, positions, cache):
    """x: (B,1,d); cache k/v: (B, window, KV, hd) ring; positions: (B,)."""
    b = x.shape[0]
    w = cfg.window
    q = jnp.einsum("bsd,dhk->bshk", x, ap["wq"])
    k = jnp.einsum("bsd,dhk->bshk", x, ap["wk"])
    v = jnp.einsum("bsd,dhk->bshk", x, ap["wv"])
    q = apply_rope(q, positions[:, None], theta=cfg.rope_theta)
    k = apply_rope(k, positions[:, None], theta=cfg.rope_theta)
    slot = positions % w
    onehot = (jnp.arange(w)[None, :] == slot[:, None])[:, :, None, None]
    oh = onehot.astype(cache["k"].dtype)
    k_cache = cache["k"] * (1 - oh) + oh * k.astype(cache["k"].dtype)
    v_cache = cache["v"] * (1 - oh) + oh * v.astype(cache["v"].dtype)
    kvp, gp = cfg.padded_kv_group
    qg = q.reshape(b, 1, kvp, gp, cfg.head_dim)
    valid_len = jnp.minimum(positions + 1, w)
    o = attn_mod.decode_attention(qg, k_cache, v_cache, valid_len,
                                  scale=cfg.head_dim ** -0.5)
    o = o.reshape(b, 1, cfg.n_heads_padded, cfg.head_dim)
    from repro.models.transformer import head_mask
    o = o * head_mask(cfg, o.dtype)[None, None, :, None]
    out = jnp.einsum("bshk,hkd->bsd", o, ap["wo"])
    return out, {"k": k_cache, "v": v_cache}


# ---------------------------------------------------------------------------
# Model
# ---------------------------------------------------------------------------

def _mlp(x, mp, cfg, constrain):
    act = act_fn(glu_act(cfg.activation))
    h = act(jnp.einsum("bsd,df->bsf", x, mp["w1"])) \
        * jnp.einsum("bsd,df->bsf", x, mp["w3"])
    h = constrain(h, "batchlike", None, "ff")
    return jnp.einsum("bsf,fd->bsd", h, mp["w2"])


def _slice(tree, i):
    return jax.tree.map(lambda t: t[i], tree)


def _one_layer(x, mp, kind, rp_or_ap, cfg, opts, positions, mode, lc):
    """Shared single-layer body (temporal block + MLP)."""
    c = opts.constrain
    xn = rms_norm(x, mp["t_norm"], plus_one=cfg.norm_plus_one)
    if kind == "rec":
        if mode == "decode":
            t_out, st = rec_block_decode(xn, rp_or_ap, cfg, lc)
        else:
            t_out, st = rec_block_full(xn, rp_or_ap, cfg, c,
                                       unroll=opts.unroll_scans)
            if mode != "prefill":
                st = None
    else:
        if mode == "decode":
            t_out, st = attn_block_decode(xn, rp_or_ap, cfg,
                                          positions.reshape(-1), lc)
        else:
            t_out, st = attn_block_full(xn, rp_or_ap, cfg, opts, positions,
                                        want_cache=(mode == "prefill"))
    x = x + t_out
    m = _mlp(rms_norm(x, mp["m_norm"], plus_one=cfg.norm_plus_one),
             mp, cfg, c)
    return x + m, st


def _forward_train_grouped(params, x, cfg, opts, positions):
    """Training path: lax.scan over whole (rec,rec,attn) pattern groups.

    The python-unrolled 26-layer graph leaves XLA's scheduler free to run
    every checkpointed layer's backward-recompute concurrently — measured
    109 GiB/device of simultaneous fp32 recompute residuals. A scan over
    pattern groups forces serial processing (peak = one group's working
    set); the trailing partial group unrolls."""
    from repro.models.transformer import remat_wrap
    pat = cfg.block_pattern
    plen = len(pat)
    n_rec_per = sum(1 for k in pat if k == "rec")
    n_att_per = plen - n_rec_per
    n_groups = cfg.n_layers // plen
    regroup = lambda t, n, per: t[: n * per].reshape(  # noqa: E731
        (n, per) + t.shape[1:])
    rec_g = jax.tree.map(lambda t: regroup(t, n_groups, n_rec_per),
                         params["rec"])
    att_g = jax.tree.map(lambda t: regroup(t, n_groups, n_att_per),
                         params["attn"])
    mlp_g = jax.tree.map(lambda t: regroup(t, n_groups, plen), params["mlp"])

    def group_body(h, xs):
        recp, attnp, mlpp = xs
        ri = ai = 0
        for j, kind in enumerate(pat):
            mp = _slice(mlpp, j)
            if kind == "rec":
                bp = _slice(recp, ri)
                ri += 1
            else:
                bp = _slice(attnp, ai)
                ai += 1
            h = opts.constrain(h, "batchlike", opts.seq_axis, None)
            h, _ = _one_layer(h, mp, kind, bp, cfg, opts, positions,
                              "train", None)
        return h, None

    from repro.models.common import scan_or_unroll
    x, _ = scan_or_unroll(remat_wrap(group_body, opts.remat), x,
                          (rec_g, att_g, mlp_g), unroll=opts.unroll_scans)
    # trailing partial group (26 = 8×3 + 2: two rec layers)
    ri, ai = n_groups * n_rec_per, n_groups * n_att_per
    for li in range(n_groups * plen, cfg.n_layers):
        kind = cfg.layer_pattern()[li]
        mp = _slice(params["mlp"], li)
        bp = _slice(params["rec"] if kind == "rec" else params["attn"],
                    ri if kind == "rec" else ai)
        ri, ai = ri + (kind == "rec"), ai + (kind == "attn")
        x = opts.constrain(x, "batchlike", opts.seq_axis, None)
        body = remat_wrap(
            lambda h, mp=mp, kind=kind, bp=bp: _one_layer(
                h, mp, kind, bp, cfg, opts, positions, "train", None),
            opts.remat)
        x, _ = body(x)
    return x


def forward(params, tokens, cfg, opts, *, mode="train", cache=None,
            positions=None):
    """mode: train | prefill | decode. Returns (hidden, new_cache list)."""
    from repro.models.transformer import embed_tokens, remat_wrap
    c = opts.constrain
    x = embed_tokens(params, tokens, cfg, opts)
    if positions is None:
        positions = jnp.arange(tokens.shape[1])[None, :]
    if mode == "train" and cfg.block_pattern \
            and cfg.n_layers >= 2 * len(cfg.block_pattern):
        x = _forward_train_grouped(params, x, cfg, opts, positions)
        x = rms_norm(x, params["final_norm"], plus_one=cfg.norm_plus_one)
        return x, []
    pat = cfg.layer_pattern()
    new_cache = []
    ri = ai = 0
    for li, kind in enumerate(pat):
        mp = _slice(params["mlp"], li)
        lc = None if cache is None else cache[li]

        def one_layer(x, mp=mp, li=li, kind=kind, ri=ri, ai=ai, lc=lc):
            xn = rms_norm(x, mp["t_norm"], plus_one=cfg.norm_plus_one)
            if kind == "rec":
                rp = _slice(params["rec"], ri)
                if mode == "decode":
                    t_out, st = rec_block_decode(xn, rp, cfg, lc)
                else:
                    t_out, st = rec_block_full(xn, rp, cfg, c,
                                               unroll=opts.unroll_scans)
                    if mode != "prefill":
                        st = None
            else:
                ap = _slice(params["attn"], ai)
                if mode == "decode":
                    t_out, st = attn_block_decode(
                        xn, ap, cfg, positions.reshape(-1), lc)
                else:
                    t_out, st = attn_block_full(
                        xn, ap, cfg, opts, positions, want_cache=(mode == "prefill"))
            x = x + t_out
            m = _mlp(rms_norm(x, mp["m_norm"], plus_one=cfg.norm_plus_one),
                     mp, cfg, c)
            return x + m, st

        if mode == "train" and opts.remat != "none":
            one_layer = remat_wrap(one_layer, opts.remat)
        # constrain OUTSIDE the checkpointed body: the remat-saved inter-layer
        # residual is then the SP-sharded bf16 tensor, not a replicated fp32
        # transient (the python-unrolled stack otherwise kept ~26 full fp32
        # activations alive — 109 GiB/device; EXPERIMENTS.md §Perf P0d)
        x = c(x, "batchlike", opts.seq_axis if mode == "train" else None, None)
        x, st = one_layer(x)
        new_cache.append(st)
        ri, ai = ri + (kind == "rec"), ai + (kind == "attn")
    x = rms_norm(x, params["final_norm"], plus_one=cfg.norm_plus_one)
    return x, new_cache


def cache_shape(cfg, batch: int, max_len: int, dtype=jnp.bfloat16):
    """Per-layer state list: rec {h, conv} / attn {k, v} (+ global pos)."""
    w, k = cfg.lru_width, cfg.conv_kernel
    win, kv, hd = cfg.window, cfg.kv_pad, cfg.head_dim
    out = []
    for kind in cfg.layer_pattern():
        if kind == "rec":
            out.append({
                "h": jax.ShapeDtypeStruct((batch, w), jnp.float32),
                "conv": jax.ShapeDtypeStruct((batch, k - 1, w), dtype),
            })
        else:
            out.append({
                "k": jax.ShapeDtypeStruct((batch, win, kv, hd), dtype),
                "v": jax.ShapeDtypeStruct((batch, win, kv, hd), dtype),
            })
    return out
