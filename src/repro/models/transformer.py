"""Decoder-only transformer — covers the dense, moe and vlm families.

Design notes (DESIGN.md §6):
  * layer-stacked params + lax.scan over layers (jax.checkpoint policy on the
    body) → HLO size O(1) in depth; 64-layer qwen2.5 compiles like 12 layers.
  * GQA executes with KV heads expanded to H and head-padded to a multiple of
    `head_pad` (the TP axis size): attention then shards over the flat head
    dim for every arch, including the 15/40/10-head ones that don't divide 16.
    Dead pad heads carry zeros; their wo rows don't exist, so outputs are exact.
  * sharded-vocab chunked cross-entropy: logits are never materialized beyond
    (B, ce_chunk, V) and the vocab dim stays sharded on `model`.
  * vlm (llava-next): precomputed anyres patch embeddings (frontend STUB)
    overwrite the leading n_image_tokens embedding positions.
"""

from __future__ import annotations

import dataclasses
import functools
from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.models import attention as attn_mod
from repro.models import moe as moe_mod
from repro.models.common import ParamDef, act_fn, apply_rope, glu_act, rms_norm, softcap
from repro.models.quantized import (
    SCALE_DTYPE, dequantize_kv_rows, qeinsum, quantize_kv_rows)


def _noop_constrain(x, *logical):
    return x


@dataclasses.dataclass(frozen=True)
class ExecOptions:
    """Execution-strategy knobs (everything performance, nothing semantic)."""
    attn_impl: str = "chunked"        # chunked | reference
    q_chunk: int = 1024
    kv_chunk: int = 1024
    ce_chunk: int = 512
    remat: str = "none"               # none | dots | full
    # Megatron-style sequence parallelism on the residual stream: the layer
    # carry is sharded seq→model, cutting saved-activation memory 16×; GSPMD
    # inserts the all-gather/reduce-scatter pair at the attention boundary.
    act_seq_shard: bool = False
    moe_group: Optional[int] = None   # override cfg.moe_group
    constrain: Callable = _noop_constrain
    # dry-run cost probes: statically unroll every internal lax.scan so
    # cost_analysis counts loop bodies exactly (see common.scan_or_unroll)
    unroll_scans: bool = False

    @property
    def seq_axis(self) -> Optional[str]:
        return "seq" if self.act_seq_shard else None


def remat_wrap(fn, policy: str):
    if policy == "none":
        return fn
    if policy == "dots":
        return jax.checkpoint(
            fn, policy=jax.checkpoint_policies.checkpoint_dots_with_no_batch_dims)
    return jax.checkpoint(fn)


# ---------------------------------------------------------------------------
# Schema
# ---------------------------------------------------------------------------

def attn_schema(cfg, L: int, prefix: str = "") -> Dict[str, Any]:
    """QKV/O projections with distribution-time head padding (ArchConfig.tp_pad).

    Dead heads are masked to zero contribution in `attn_block` — outputs are
    exactly the real-head model's, and dead slices receive zero gradient."""
    d, hd = cfg.d_model, cfg.head_dim
    hp, kvp = cfg.n_heads_padded, cfg.kv_pad
    sch = {
        prefix + "wq": ParamDef((L, d, hp, hd), ("layers", "embed", "heads", None)),
        prefix + "wk": ParamDef((L, d, kvp, hd), ("layers", "embed", "heads", None)),
        prefix + "wv": ParamDef((L, d, kvp, hd), ("layers", "embed", "heads", None)),
        prefix + "wo": ParamDef((L, hp, hd, d), ("layers", "heads", None, "embed")),
    }
    if cfg.qkv_bias and not prefix:
        sch["bq"] = ParamDef((L, hp, hd), ("layers", "heads", None), init="zeros")
        sch["bk"] = ParamDef((L, kvp, hd), ("layers", "heads", None), init="zeros")
        sch["bv"] = ParamDef((L, kvp, hd), ("layers", "heads", None), init="zeros")
    return sch


def head_mask(cfg, dtype=jnp.float32) -> jnp.ndarray:
    """(Hp,) — 1 for real heads (kv < n_kv_heads and g < q_per_kv), else 0."""
    kvp, gp = cfg.padded_kv_group
    kvi = jnp.arange(kvp * gp) // gp
    gi = jnp.arange(kvp * gp) % gp
    return ((kvi < cfg.n_kv_heads) & (gi < cfg.q_per_kv)).astype(dtype)


def schema(cfg) -> Dict[str, Any]:
    d, f = cfg.d_model, cfg.d_ff
    L, v = cfg.n_layers, cfg.padded_vocab
    norm_init = "zeros" if cfg.norm_plus_one else "ones"
    layers: Dict[str, Any] = {
        "attn_norm": ParamDef((L, d), ("layers", None), init=norm_init),
        "ffn_norm": ParamDef((L, d), ("layers", None), init=norm_init),
    }
    if cfg.attn_kind == "mla":
        from repro.models import mla as mla_mod
        layers.update(mla_mod.mla_schema(cfg, L))
    else:
        layers.update(attn_schema(cfg, L))
    if cfg.family == "moe":
        layers.update(moe_mod.moe_schema(cfg, L))
    else:
        layers["w1"] = ParamDef((L, d, f), ("layers", "embed", "ff"))
        layers["w3"] = ParamDef((L, d, f), ("layers", "embed", "ff"))
        layers["w2"] = ParamDef((L, f, d), ("layers", "ff", "embed"))
    sch = {
        "embed": ParamDef((v, d), ("vocab", "embed"), init="small_normal"),
        "final_norm": ParamDef((d,), (None,), init=norm_init),
        "layers": layers,
    }
    if not cfg.tie_embeddings:
        sch["lm_head"] = ParamDef((v, d), ("vocab", "embed"), init="small_normal")
    return sch


# ---------------------------------------------------------------------------
# Blocks
# ---------------------------------------------------------------------------

def _project_qkv(x, p, cfg, prefix=""):
    q = qeinsum("bsd,dhk->bshk", x, p[prefix + "wq"])
    k = qeinsum("bsd,dhk->bshk", x, p[prefix + "wk"])
    v = qeinsum("bsd,dhk->bshk", x, p[prefix + "wv"])
    if "bq" in p and not prefix:
        q, k, v = q + p["bq"], k + p["bk"], v + p["bv"]
    return q, k, v


def _expand_kv(k, v, cfg):
    """(B,S,KVp,D) → (B,S,Hp,D) by repeating each kv head g_pad times."""
    gp = cfg.g_pad
    if gp > 1:
        k = jnp.repeat(k, gp, axis=2)
        v = jnp.repeat(v, gp, axis=2)
    return k, v


def _round_rows(rows, kv_round):
    """Round one K/V (or MLA latent) tensor through the cache storage dtype.

    int8 takes the full quantize→dequantize round trip (the map the
    paste/decode/chunk write paths apply); any float storage dtype — bf16 or
    fp8 e5m2 — is a cast round trip with no scale tensors."""
    if kv_round is None:
        return rows
    if kv_round == jnp.int8:
        q, s = quantize_kv_rows(rows)
        return dequantize_kv_rows(q, s, rows.dtype)
    return rows.astype(kv_round).astype(rows.dtype)


def _round_kv(k, v, kv_round):
    """Round K/V through the cache storage dtype before attention.

    `kv_round` is the storage dtype (or None = lossless storage). Prefill
    attention must see the SAME values the cache will hold — otherwise a
    chunked prefill (which attends already-pasted pool rows) and a monolithic
    prefill (which would attend fresh activations) diverge numerically and
    the chunked-vs-oracle token-exactness breaks. This also makes prefill and
    decode numerics consistent: decode attention always reads stored rows.
    """
    return _round_rows(k, kv_round), _round_rows(v, kv_round)


def _pool_entry(**pools):
    """Updated-cache dict from write results, dropping absent scale pools."""
    return {key: val for key, val in pools.items() if val is not None}


def attn_block(x, p, cfg, opts: ExecOptions, *, positions,
               mode: str, cache: Optional[dict] = None, kv_round=None,
               chunk: Optional[dict] = None, causal: bool = True):
    """Self-attention — THE per-layer attention core. Returns
    (out, new_cache_entry).

    One body owns all four execution modes, for every attention family (GQA
    below; `cfg.attn_kind == 'mla'` dispatches to `models/mla.py`, which
    shares the same mode contract and write helpers):
      'train'   full attention over S positions; no cache emission (the layer
                scan carries nothing dead).
      'prefill' full attention; emits per-layer K/V rows for the engine's
                paste. Lossy caches attend the rounded values the cache will
                store (`_round_kv` / kv_round).
      'decode'  one position per sequence; writes the new row into the dense
                (B, Smax, KV, D) cache or the paged pool (via
                cache['page_table']) and attends the stored rows.
      'chunk'   chunked prefill (B=1): streams C rows into the paged pool
                through the slot's page row (`chunk=` dict with start (1,),
                length (1,), page_row (pages_per_seq,)) and runs chunk
                attention against the slot's live pages.
    `causal=False` (train/prefill only) serves the encdec encoder. int8
    storage is detected by the scale pools ('ks'/'vs') riding in `cache`;
    fp8 (e5m2) storage is a bare dtype cast, no scales.
    """
    if cfg.attn_kind == "mla":
        from repro.models import mla as mla_mod
        return mla_mod.mla_attn_block(
            x, p, cfg, opts, positions=positions, mode=mode, cache=cache,
            kv_round=kv_round, chunk=chunk, causal=causal)
    c = opts.constrain
    q, k, v = _project_qkv(x, p, cfg)
    q = apply_rope(q, positions, fraction=cfg.rope_fraction, theta=cfg.rope_theta)
    k = apply_rope(k, positions, fraction=cfg.rope_fraction, theta=cfg.rope_theta)
    scale = cfg.head_dim ** -0.5
    kvp, gp = cfg.padded_kv_group

    if mode in ("train", "prefill"):
        ka, va = (k, v) if mode == "train" else _round_kv(k, v, kv_round)
        kx, vx = _expand_kv(ka, va, cfg)
        qp = c(q[:, :, :, None, :], "batchlike", None, "heads_flat", None, None)
        kx = c(kx, "batchlike", None, "heads_flat", None)
        vx = c(vx, "batchlike", None, "heads_flat", None)
        o = attn_mod.attention(
            qp, kx, vx, causal=causal, window=cfg.window, scale=scale,
            impl=opts.attn_impl, q_chunk=opts.q_chunk, kv_chunk=opts.kv_chunk,
            unroll=opts.unroll_scans)
        o = o[:, :, :, 0, :]
        new_cache = {"k": k, "v": v} if mode == "prefill" else None
    elif mode == "chunk":
        assert cache is not None and chunk is not None
        b, C = x.shape[:2]
        pk, psk = _write_chunk(cache, "k", k[0], chunk)
        pv, psv = _write_chunk(cache, "v", v[0], chunk)
        qg = q.reshape(b, C, kvp, gp, cfg.head_dim)
        o = attn_mod.chunk_attention_paged(
            qg, pk, pv, chunk["page_row"][None], chunk["start"],
            kv_len=chunk["start"] + chunk["length"],
            window=cfg.window, scale=scale, k_scale=psk, v_scale=psv)
        o = o.reshape(b, C, cfg.n_heads_padded, cfg.head_dim)
        new_cache = _pool_entry(k=pk, v=pv, ks=psk, vs=psv)
    else:  # decode
        assert cache is not None
        b = x.shape[0]
        pos_b = positions.reshape(-1)             # (B,)
        page_table = cache.get("page_table")
        # write this step's k/v at each sequence position `pos_b`
        k_cache, k_scale = _write_row(cache, "k", k, pos_b, page_table)
        v_cache, v_scale = _write_row(cache, "v", v, pos_b, page_table)
        qg = q.reshape(b, 1, kvp, gp, cfg.head_dim)
        o = attn_mod.decode_attention(
            qg, k_cache, v_cache, pos_b + 1,
            window=cfg.window, scale=scale, page_table=page_table,
            k_scale=k_scale, v_scale=v_scale)
        o = o.reshape(b, 1, cfg.n_heads_padded, cfg.head_dim)
        new_cache = _pool_entry(k=k_cache, v=v_cache, ks=k_scale, vs=v_scale)

    o = o * head_mask(cfg, o.dtype)[None, None, :, None]
    out = qeinsum("bshk,hkd->bsd", o, p["wo"])
    return out, new_cache


def _write_cache(cache, kv_new, positions):
    """cache: (B, Smax, KV, D); kv_new: (B, 1, KV, D); positions: (B,).

    One-hot masked update — GSPMD-friendly on a sequence-sharded cache (no
    dynamic-slice cross-shard traffic; each shard updates only its slice)."""
    smax = cache.shape[1]
    onehot = (jnp.arange(smax)[None, :] == positions[:, None])  # (B, Smax)
    oh = onehot[:, :, None, None].astype(cache.dtype)
    return cache * (1 - oh) + oh * kv_new.astype(cache.dtype)


def _write_cache_q(cache, scales, kv_new, positions):
    """Dense int8 KV write: quantize the new (B,1,KV,D) row per (token, kv
    head) and masked-set both the int8 cache row and its f16 scale. Same
    one-hot masking as `_write_cache` but via `where` — int8 arithmetic has
    no exact multiply-by-mask. Returns (cache, scales)."""
    q, s = quantize_kv_rows(kv_new)                 # (B,1,KV,D) i8, (B,1,KV)
    smax = cache.shape[1]
    onehot = (jnp.arange(smax)[None, :] == positions[:, None])  # (B, Smax)
    new_c = jnp.where(onehot[:, :, None, None], q, cache)
    new_s = jnp.where(onehot[:, :, None], s, scales)
    return new_c, new_s


def _write_cache_paged(pool, kv_new, positions, page_table):
    """pool: (n_pages, ps, KV, D); kv_new: (B, 1, KV, D); positions: (B,);
    page_table: (B, pages_per_seq).

    Scatter each sequence's new row into pool[table[b, pos//ps], pos%ps].
    Live sequences own disjoint pages, so the scatter indices never collide;
    retired slots all point at the null page, whose rows are never attended
    to. Positions past the table's logical depth clamp (jnp gather semantics)
    onto the slot's last entry — the engine zeroes retired rows, so drift
    lands on the null page too. Single-host layout; the paged pool trades the
    one-hot update's GSPMD-friendliness for O(live tokens) memory."""
    ps = pool.shape[1]
    logical = jnp.minimum(positions // ps, page_table.shape[1] - 1)
    page = jnp.take_along_axis(page_table, logical[:, None], axis=1)[:, 0]
    return pool.at[page, positions % ps].set(
        kv_new[:, 0].astype(pool.dtype))


def _write_cache_paged_q(pool, spool, kv_new, positions, page_table):
    """Paged int8 KV write: same scatter as `_write_cache_paged`, with the
    row quantized first and its scale scattered into the (n_pages, ps, KV)
    scale pool. The quantized bytes are identical to the dense `_write_cache_q`
    path — layout-independence is what keeps paged int8 engines token-exact
    against the dense int8 oracle."""
    q, s = quantize_kv_rows(kv_new)
    ps = pool.shape[1]
    logical = jnp.minimum(positions // ps, page_table.shape[1] - 1)
    page = jnp.take_along_axis(page_table, logical[:, None], axis=1)[:, 0]
    return (pool.at[page, positions % ps].set(q[:, 0]),
            spool.at[page, positions % ps].set(s[:, 0]))


def _chunk_pages(pos, length, page_row, ps):
    """(page, row) scatter targets for a prefill chunk's K/V rows.

    pos: (C,) global positions start+i; rows past `length` (chunk padding)
    and positions past the table's logical depth route to the NULL page (0),
    so padding never touches reserved pages — the capacity edge where a
    prompt's last chunk exactly fills its final page stays clean."""
    logical = jnp.minimum(pos // ps, page_row.shape[0] - 1)
    real = jnp.arange(pos.shape[0]) < length
    page = jnp.where(real, page_row[logical], 0)
    return page, pos % ps


def _write_chunk_paged(pool, rows, start, length, page_row):
    """pool: (n_pages, ps, KV, D); rows: (C, KV, D) — stream one prefill
    chunk's K/V straight into the page pool at global positions start+i."""
    page, r = _chunk_pages(start + jnp.arange(rows.shape[0]), length,
                           page_row, pool.shape[1])
    return pool.at[page, r].set(rows.astype(pool.dtype))


def _write_chunk_paged_q(pool, spool, rows, start, length, page_row):
    """Paged int8 chunk write: same scatter as `_write_chunk_paged` with the
    rows quantized per (position, kv head) first — identical bytes to the
    dense/paged decode write paths, which is what keeps chunked int8 engines
    token-exact against the dense int8 oracle."""
    q, s = quantize_kv_rows(rows)
    page, r = _chunk_pages(start + jnp.arange(rows.shape[0]), length,
                           page_row, pool.shape[1])
    return pool.at[page, r].set(q), spool.at[page, r].set(s)


def _write_row(cache, key, kv_new, positions, page_table):
    """Write one decode row into `cache[key]` — dense or paged, any storage
    dtype. Returns (pool, scales-or-None). int8 storage is detected by the
    sibling scale pool `cache[key + 's']`; float storage (f32/bf16/fp8) is a
    bare cast on write."""
    if key + "s" in cache:
        if page_table is None:
            return _write_cache_q(cache[key], cache[key + "s"], kv_new,
                                  positions)
        return _write_cache_paged_q(cache[key], cache[key + "s"], kv_new,
                                    positions, page_table)
    if page_table is None:
        return _write_cache(cache[key], kv_new, positions), None
    return _write_cache_paged(cache[key], kv_new, positions, page_table), None


def _write_chunk(cache, key, rows, chunk):
    """Stream one prefill chunk's (C, KV, D) rows into the paged pool
    `cache[key]` at global positions start+i. Returns (pool, scales-or-None);
    same int8 detection as `_write_row`."""
    start, length = chunk["start"][0], chunk["length"][0]
    if key + "s" in cache:
        return _write_chunk_paged_q(cache[key], cache[key + "s"], rows,
                                    start, length, chunk["page_row"])
    return _write_chunk_paged(cache[key], rows, start, length,
                              chunk["page_row"]), None


_POOL_KEYS = ("k", "v", "ks", "vs")


def _pools_of(cache):
    """The layer-stacked K/V pools present in a cache — family-agnostic:
    GQA carries k/v (+ int8 scale pools), MLA a single latent pool."""
    return {key: cache[key] for key in _POOL_KEYS if key in cache}


def pool_data_keys(cache) -> Tuple[str, ...]:
    """Base (unscaled) pool keys present in a cache or prefill dict —
    ("k", "v") for GQA, ("k",) for MLA's single latent pool. THE way
    engine code iterates pools (contract R6): a spelled-out key tuple at a
    call site silently skips pools the family doesn't have."""
    return tuple(key for key in ("k", "v") if key in cache)


def copy_pool_page(cache, src, dst):
    """Copy-on-write page clone: duplicate physical page `src`'s rows into
    `dst` across every pool in the cache (k/v, int8 scale pools, MLA's
    single latent pool — whatever `_POOL_KEYS` members are present), all
    layers at once.

    The prefix cache (PR 8) uses this when a new request's prompt fully
    covers a cached page that its replay decode step will overwrite (the
    page containing position plen-1): instead of recomputing that page's
    K/V with one more prefill chunk, the engine clones the cached bytes
    into a private page and maps THAT — the shared original stays
    read-only. Pages are schedule-independent bytes (`_round_kv`), so the
    clone is exactly what a cold prefill would have produced."""
    c = dict(cache)
    for key in _POOL_KEYS:
        if key in c:
            c[key] = c[key].at[:, dst].set(c[key][:, src])
    return c


def gather_pool_pages(cache, page_ids):
    """Snapshot physical pages `page_ids` ((M,) int32) out of every pool in
    the cache: {key: (L, M, page_size, ...)} — the migration outbox.

    Mesh-free on purpose: `serve/sharded` wraps this in shard_map with the
    pool's page axis device-local, all_gathers the outboxes, and scatters
    with `set_pool_page`. Because the gather snapshots BEFORE any scatter
    runs, a page may be both exported and overwritten in the same move wave.
    Pool-native bytes move as-is — an int8 pool's int8 rows + f16 scale rows
    ARE its block-compressed wire format (half the bf16 bytes), and decode's
    fused dequant is the receive-side decompress — so migrated pages are
    bit-exact under the schedule-independent KV rounding contract."""
    return {key: jnp.take(cache[key], page_ids, axis=1)
            for key in _POOL_KEYS if key in cache}


def set_pool_page(cache, dst, rows):
    """Write one gathered page (`rows`: {key: (L, page_size, ...)}, e.g. an
    all_gathered `gather_pool_pages` outbox sliced to one move) into local
    physical page `dst` across every pool. `dst` may be a traced scalar;
    dst == 0 lands on the null page, which absorbs garbage by contract."""
    c = dict(cache)
    for key in _POOL_KEYS:
        if key in c:
            c[key] = c[key].at[:, dst].set(rows[key])
    return c


def prefill_chunk(params, batch, cache, cfg, opts: ExecOptions):
    """One fixed-size chunk of page-granular prefill (PR 4).

    Computes the chunk's K/V, streams them into the shared page pool through
    the slot's page row, and runs chunk attention against the slot's live
    pages (earlier chunks + this one) — so a long prompt prefills in
    ceil(plen/C) bounded-latency steps interleaved with the decode batch,
    with one compile total (C is fixed) instead of one per bucket.

    batch:
      tokens   (1, C) int32 — chunk tokens, zero-padded past `length`
      start    (1,)   int32 — global position of tokens[:, 0]
      length   (1,)   int32 — real rows in this chunk
      page_row (pages_per_seq,) int32 — slot's physical page per logical
               page (null page 0 beyond the reservation)
      patch_rows/n_patch (vlm) — patch-embedding rows overlapping the chunk

    Only the K/V pools (and int8 scale pools) change: the slot's page_table
    row and `pos` are stamped by the engine AFTER the last chunk, so
    mid-prefill slots stay invisible to the batched decode step (its garbage
    writes for them land on the null page — the idle-slot-drift guard).

    `start` need not be 0 for a slot's FIRST chunk: the prefix cache (PR 8)
    resumes prefill mid-prompt after cached pages. The chunk's attention
    gathers the slot's whole live span [0, start+length) through `page_row`
    — shared cached pages included — while its writes only ever target
    logical pages >= start // page_size (start is page-aligned on resume),
    so shared pages are read-only by construction. Schedule-independent KV
    rounding guarantees the cached pages hold byte-identical values to the
    cold prefill this replaces, which is what keeps cache-hit admissions
    token-exact.

    The scan body is a thin wrapper over `layer_fn(mode='chunk')` — the
    per-layer math lives ONCE in `attn_block`, so every execution path
    (train/prefill/decode/chunk, every attention family) inherits any
    layer-math change from the same body.
    """
    tokens = batch["tokens"]
    start, length = batch["start"], batch["length"]
    b, C = tokens.shape
    positions = start[:, None] + jnp.arange(C)[None, :]
    x = embed_tokens(params, tokens, cfg, opts)
    if cfg.family == "vlm" and "patch_rows" in batch:
        in_patch = (positions < batch["n_patch"][:, None])[..., None]
        x = jnp.where(in_patch, batch["patch_rows"].astype(x.dtype), x)
    chunk = {"start": start, "length": length, "page_row": batch["page_row"]}
    dyn = functools.partial(jax.lax.dynamic_index_in_dim, axis=0,
                            keepdims=False)

    def body(carry, xs):
        h, pools = carry
        lp, i = xs
        layer_cache = {key: dyn(val, i) for key, val in pools.items()}
        h, new_cache = layer_fn(h, lp, cfg, opts, positions=positions,
                                mode="chunk", cache=layer_cache, chunk=chunk)
        pools = {key: jax.lax.dynamic_update_index_in_dim(
            val, new_cache[key], i, 0) for key, val in pools.items()}
        return (h, pools), None

    from repro.models.common import scan_or_unroll
    (_, pools), _ = scan_or_unroll(
        body, (x, _pools_of(cache)),
        (params["layers"], jnp.arange(cfg.n_layers)),
        unroll=opts.unroll_scans)
    return dict(cache, **pools)


def dense_ffn(x, p, cfg, opts: ExecOptions):
    c = opts.constrain
    act = act_fn(glu_act(cfg.activation))
    h = act(qeinsum("bsd,df->bsf", x, p["w1"])) \
        * qeinsum("bsd,df->bsf", x, p["w3"])
    h = c(h, "batchlike", None, "ff")
    return qeinsum("bsf,fd->bsd", h, p["w2"])


def layer_fn(x, lp, cfg, opts: ExecOptions, *, positions, mode,
             cache: Optional[dict] = None, kv_round=None, chunk=None):
    c = opts.constrain
    x = c(x, "batchlike", opts.seq_axis, None)
    a, new_cache = attn_block(
        rms_norm(x, lp["attn_norm"], plus_one=cfg.norm_plus_one),
        lp, cfg, opts, positions=positions, mode=mode, cache=cache,
        kv_round=kv_round, chunk=chunk)
    x = x + a
    h = rms_norm(x, lp["ffn_norm"], plus_one=cfg.norm_plus_one)
    if cfg.family == "moe":
        f = moe_mod.moe_ffn(h, lp, _maybe_group(cfg, opts), constrain=c)
    else:
        f = dense_ffn(h, lp, cfg, opts)
    return x + f, new_cache


def _maybe_group(cfg, opts):
    if opts.moe_group and opts.moe_group != cfg.moe_group:
        return dataclasses.replace(cfg, moe_group=opts.moe_group)
    return cfg


# ---------------------------------------------------------------------------
# Embedding / loss
# ---------------------------------------------------------------------------

def embed_tokens(params, tokens, cfg, opts, patch_embeds=None):
    x = jnp.take(params["embed"], tokens, axis=0)
    if cfg.embed_scale:
        x = (x.astype(jnp.float32) * (cfg.d_model ** 0.5)).astype(x.dtype)
    if patch_embeds is not None:  # vlm stub: overwrite leading image positions
        p = patch_embeds.shape[1]
        x = jnp.concatenate([patch_embeds.astype(x.dtype), x[:, p:]], axis=1)
    return opts.constrain(x, "batchlike", None, None)


def lm_head_weights(params, cfg):
    return params.get("lm_head", params["embed"])


def chunked_ce_loss(hidden, emb, labels, cfg, opts: ExecOptions):
    """Σ CE over sequence chunks; vocab stays sharded; fp32 logsumexp."""
    hidden = opts.constrain(hidden, "batchlike", None, None)
    b, s, d = hidden.shape
    chunk = min(opts.ce_chunk, s)
    assert s % chunk == 0
    n = s // chunk
    hc = hidden.reshape(b, n, chunk, d).transpose(1, 0, 2, 3)
    yc = labels.reshape(b, n, chunk).transpose(1, 0, 2)

    def body(carry, xs):
        h, y = xs
        logits = jnp.einsum("bsd,vd->bsv", h, emb).astype(jnp.float32)
        logits = softcap(logits, cfg.logit_softcap)
        logits = opts.constrain(logits, "batchlike", None, "vocab")
        lse = jax.nn.logsumexp(logits, axis=-1)
        oh = jax.nn.one_hot(jnp.maximum(y, 0), logits.shape[-1],
                            dtype=logits.dtype)
        ll = jnp.sum(logits * oh, axis=-1)
        w = (y >= 0).astype(jnp.float32)
        loss, cnt = carry
        return (loss + jnp.sum(w * (lse - ll)), cnt + jnp.sum(w)), None

    from repro.models.common import scan_or_unroll
    (loss, cnt), _ = scan_or_unroll(
        remat_wrap(body, "full" if opts.remat != "none" else "none"),
        (jnp.float32(0.0), jnp.float32(0.0)), (hc, yc),
        unroll=opts.unroll_scans)
    return loss / jnp.maximum(cnt, 1.0)


# ---------------------------------------------------------------------------
# Model entry points
# ---------------------------------------------------------------------------

def _stack_scan(params, x, cfg, opts, *, positions, mode, cache=None,
                kv_round=None):
    """lax.scan over stacked layers. cache (if given) is stacked on axis 0."""
    lp = params["layers"]

    def body(h, xs):
        layer_params, layer_cache = xs
        h, new_cache = layer_fn(h, layer_params, cfg, opts,
                                positions=positions, mode=mode,
                                cache=layer_cache, kv_round=kv_round)
        return h, new_cache

    from repro.models.common import scan_or_unroll
    body = remat_wrap(body, opts.remat)
    x, new_cache = scan_or_unroll(body, x, (lp, cache),
                                  unroll=opts.unroll_scans)
    return x, new_cache


def forward_hidden(params, tokens, cfg, opts, *, patch_embeds=None,
                   mode="train", kv_round=None):
    b, s = tokens.shape
    x = embed_tokens(params, tokens, cfg, opts, patch_embeds)
    positions = jnp.arange(s)[None, :]
    x, cache = _stack_scan(params, x, cfg, opts, positions=positions,
                           mode=mode, kv_round=kv_round)
    return rms_norm(x, params["final_norm"], plus_one=cfg.norm_plus_one), cache


def _kv_round_of(batch):
    """Storage dtype of a lossy KV cache, from the serving engine's zero-size
    `kv_round` batch marker (absent = lossless storage, attend fresh K/V)."""
    marker = batch.get("kv_round")
    return None if marker is None else marker.dtype


def train_loss(params, batch, cfg, opts: ExecOptions):
    hidden, _ = forward_hidden(params, batch["tokens"], cfg, opts,
                               patch_embeds=batch.get("patch_embeds"),
                               mode="train")
    labels = batch["labels"]
    if cfg.family == "vlm" and "patch_embeds" in batch:
        p = batch["patch_embeds"].shape[1]
        mask = jnp.arange(labels.shape[1])[None, :] >= p
        labels = jnp.where(mask, labels, -1)
    loss = chunked_ce_loss(hidden, lm_head_weights(params, cfg), labels, cfg, opts)
    return loss, {"loss": loss}


def prefill_cache(params, batch, cfg, opts: ExecOptions):
    """Cache-only prefill: skips the LM-head projection.

    The serve engine's replay admission discards prefill logits (the first
    output token comes from replaying the last prompt token through the
    decode step), so this variant avoids a d_model×vocab matmul per admitted
    request on the serving hot path."""
    _, kv = forward_hidden(params, batch["tokens"], cfg, opts,
                           patch_embeds=batch.get("patch_embeds"),
                           mode="prefill", kv_round=_kv_round_of(batch))
    b, s = batch["tokens"].shape
    return dict(kv, pos=jnp.full((b,), s, jnp.int32))


def prefill(params, batch, cfg, opts: ExecOptions):
    """Returns (last-position logits, cache dict)."""
    hidden, kv = forward_hidden(params, batch["tokens"], cfg, opts,
                                patch_embeds=batch.get("patch_embeds"),
                                mode="prefill", kv_round=_kv_round_of(batch))
    last = hidden[:, -1:, :]
    logits = jnp.einsum("bsd,vd->bsv", last, lm_head_weights(params, cfg))
    logits = softcap(logits.astype(jnp.float32), cfg.logit_softcap)
    b, s = batch["tokens"].shape
    return logits, dict(kv, pos=jnp.full((b,), s, jnp.int32))


def decode_step(params, batch, cache, cfg, opts: ExecOptions):
    """One token step. batch: {'tokens': (B,1)}; cache from prefill/init.

    The layer-stacked KV cache rides the scan CARRY and is updated in place
    with dynamic-update-slice — streaming it through scan xs/ys instead
    double-buffers the whole cache as temps (measured +14 GiB/device on
    gemma-7b × decode_32k; EXPERIMENTS.md §Perf P0c)."""
    tokens = batch["tokens"]
    positions = cache["pos"]                      # (B,) next position to write
    page_table = cache.get("page_table")          # read-only within the step
    x = embed_tokens(params, tokens, cfg, opts)
    dyn = functools.partial(jax.lax.dynamic_index_in_dim, axis=0,
                            keepdims=False)

    def body(carry, xs):
        h, pools = carry
        lp, i = xs
        layer_cache = {key: dyn(val, i) for key, val in pools.items()}
        if page_table is not None:
            layer_cache["page_table"] = page_table
        h, new_cache = layer_fn(h, lp, cfg, opts,
                                positions=positions[:, None], mode="decode",
                                cache=layer_cache)
        pools = {key: jax.lax.dynamic_update_index_in_dim(
            val, new_cache[key], i, 0) for key, val in pools.items()}
        return (h, pools), None

    from repro.models.common import scan_or_unroll
    (x, pools), _ = scan_or_unroll(
        body, (x, _pools_of(cache)),
        (params["layers"], jnp.arange(cfg.n_layers)),
        unroll=opts.unroll_scans)
    x = rms_norm(x, params["final_norm"], plus_one=cfg.norm_plus_one)
    logits = jnp.einsum("bsd,vd->bsv", x, lm_head_weights(params, cfg))
    logits = softcap(logits.astype(jnp.float32), cfg.logit_softcap)
    new_cache = dict(pools, pos=positions + 1)
    if page_table is not None:
        new_cache["page_table"] = page_table
    return logits, new_cache


def paged_kv_shapes(L: int, batch: int, max_len: int, kv: int, hd: int,
                    dtype, page_size: int, n_pages: Optional[int],
                    keys: Tuple[str, ...] = ("k", "v")):
    """Shared paged-pool sizing contract (transformer + encdec cache_shape):
    (L, n_pages, page_size, KV, D) pools + a (B, max_len // page_size) page
    table. `keys` names the pools — ('k', 'v') for GQA, ('k',) for MLA's
    single latent pool. Physical page 0 is reserved by the serving engine as
    the null page, so `n_pages` defaults to one more than the dense worst
    case (callers size it down to expected live tokens)."""
    assert max_len % page_size == 0, (max_len, page_size)
    pages_per_seq = max_len // page_size
    if n_pages is None:
        n_pages = 1 + batch * pages_per_seq
    shapes = {
        key: jax.ShapeDtypeStruct((L, n_pages, page_size, kv, hd), dtype)
        for key in keys}
    shapes["page_table"] = jax.ShapeDtypeStruct((batch, pages_per_seq),
                                                jnp.int32)
    shapes["pos"] = jax.ShapeDtypeStruct((batch,), jnp.int32)
    if dtype == jnp.int8:   # per-row (token × kv-head) dequant scales
        for key in keys:
            shapes[key + "s"] = jax.ShapeDtypeStruct(
                (L, n_pages, page_size, kv), SCALE_DTYPE)
    return shapes


def cache_shape(cfg, batch: int, max_len: int, dtype=jnp.bfloat16, *,
                page_size: Optional[int] = None,
                n_pages: Optional[int] = None):
    """Abstract KV-cache pytree (stacked over layers).

    GQA: kv_pad heads × head_dim K and V rows. MLA (cfg.attn_kind='mla'):
    ONE latent pool of (kv_lora_rank + qk_rope_dim)-wide rows, KV-head dim 1
    — the per-token bytes the latent family exists to shrink.
    Dense (default): per-slot (L, B, max_len, KV, D) rows.
    Paged (`page_size=`): shared page pools — see `paged_kv_shapes`.
    dtype=jnp.int8 (either layout): rows stored int8 plus per-row f16 dequant
    scale tensors ('ks'/'vs') — the serving engine's kv_dtype='int8' layout.
    dtype=jnp.float8_e5m2: bare fp8 rows, no scale tensors (dense layout;
    the engine keeps paged fp8 pools a follow-on)."""
    L = cfg.n_layers
    if cfg.attn_kind == "mla":
        kv, hd, keys = 1, cfg.kv_lora_rank + cfg.qk_rope_dim, ("k",)
    else:
        kv, hd, keys = cfg.kv_pad, cfg.head_dim, ("k", "v")
    if page_size is None:
        shapes = {
            key: jax.ShapeDtypeStruct((L, batch, max_len, kv, hd), dtype)
            for key in keys}
        shapes["pos"] = jax.ShapeDtypeStruct((batch,), jnp.int32)
        if dtype == jnp.int8:
            for key in keys:
                shapes[key + "s"] = jax.ShapeDtypeStruct(
                    (L, batch, max_len, kv), SCALE_DTYPE)
        return shapes
    return paged_kv_shapes(L, batch, max_len, kv, hd, dtype, page_size,
                           n_pages, keys)
