"""Mamba-2 — SSD (state-space duality) blocks [arXiv:2405.21060].

Chunked SSD: within a chunk the recurrence is computed as a (masked,
decay-weighted) attention-like quadratic; across chunks a recurrent state
(B, H, P, N) carries via lax.scan. This is the TPU-native adaptation of the
paper's chunk-parallel algorithm — block sizes chosen so the per-chunk
working set (T×T attention tile + state) lives in VMEM-scale memory.

Shapes: x (B,S,H,P) with H = d_inner/head_dim heads (48 for mamba2-780m,
sharding 3-per-chip over the 16-way model axis); B/C projections are shared
across heads (n_groups=1), state size N = 128. Decode is an O(1) update →
this family runs the long_500k cell.
"""

from __future__ import annotations

from typing import Any, Dict

import jax
import jax.numpy as jnp

from repro.models.common import ParamDef, causal_conv1d, rms_norm


def schema(cfg) -> Dict[str, Any]:
    d, di, n, h = cfg.d_model, cfg.d_inner, cfg.ssm_state, cfg.ssm_heads
    L, v, k = cfg.n_layers, cfg.padded_vocab, cfg.conv_kernel
    layers = {
        "norm": ParamDef((L, d), ("layers", None), init="ones"),
        "in_z": ParamDef((L, d, di), ("layers", "embed", "ff")),
        "in_x": ParamDef((L, d, di), ("layers", "embed", "ff")),
        "in_b": ParamDef((L, d, n), ("layers", "embed", None)),
        "in_c": ParamDef((L, d, n), ("layers", "embed", None)),
        "in_dt": ParamDef((L, d, h), ("layers", "embed", "heads")),
        "conv_x": ParamDef((L, k, di), ("layers", None, "ff"), init="small_normal"),
        "conv_b": ParamDef((L, k, n), ("layers", None, None), init="small_normal"),
        "conv_c": ParamDef((L, k, n), ("layers", None, None), init="small_normal"),
        "dt_bias": ParamDef((L, h), ("layers", "heads"), init="zeros"),
        "a_log": ParamDef((L, h), ("layers", "heads"), init="zeros"),
        "skip_d": ParamDef((L, h), ("layers", "heads"), init="ones"),
        "gate_norm": ParamDef((L, di), ("layers", "ff"), init="ones"),
        "out": ParamDef((L, di, d), ("layers", "ff", "embed")),
    }
    sch = {
        "embed": ParamDef((v, d), ("vocab", "embed"), init="small_normal"),
        "final_norm": ParamDef((d,), (None,), init="ones"),
        "layers": layers,
    }
    if not cfg.tie_embeddings:
        sch["lm_head"] = ParamDef((v, d), ("vocab", "embed"), init="small_normal")
    return sch


def _ssd_chunked(xh, bt, ct, dt, a, cfg, h0, constrain, unroll=False):
    """Chunk-parallel SSD.

    xh: (B,S,H,P); bt/ct: (B,S,N); dt: (B,S,H) (post-softplus); a: (H,) < 0.
    h0: initial state (B,H,P,N) or None. Returns (y (B,S,H,P), h_final).
    """
    b, s, h, p = xh.shape
    n = bt.shape[-1]
    t = min(cfg.ssm_chunk, s)
    assert s % t == 0
    nc = s // t

    xc = xh.reshape(b, nc, t, h, p).transpose(1, 0, 2, 3, 4)
    bc = bt.reshape(b, nc, t, n).transpose(1, 0, 2, 3)
    cc = ct.reshape(b, nc, t, n).transpose(1, 0, 2, 3)
    dc = dt.reshape(b, nc, t, h).transpose(1, 0, 2, 3)

    af = a.astype(jnp.float32)

    def chunk_fn(hprev, xs):
        xk, bk, ck, dk = xs                       # (B,T,H,P) (B,T,N) (B,T,H)
        dkf = dk.astype(jnp.float32)
        la = dkf * af                             # log decay per step (B,T,H)
        lcum = jnp.cumsum(la, axis=1)             # inclusive
        # intra-chunk quadratic: att[i,j] = C_i·B_j · exp(lcum_i - lcum_j) · dt_j, i≥j
        scores = jnp.einsum("bin,bjn->bij", ck.astype(jnp.float32),
                            bk.astype(jnp.float32))
        decay = lcum[:, :, None, :] - lcum[:, None, :, :]     # (B,T,T,H)
        causal = jnp.tril(jnp.ones((t, t), bool))
        w = jnp.where(causal[None, :, :, None], jnp.exp(decay), 0.0)
        att = scores[:, :, :, None] * w * dkf[:, None, :, :]  # (B,T,T,H)
        y = jnp.einsum("bijh,bjhp->bihp", att, xk.astype(jnp.float32))
        # inter-chunk: contribution of carried state
        y = y + jnp.einsum("bin,bhpn->bihp", ck.astype(jnp.float32), hprev) \
            * jnp.exp(lcum)[:, :, :, None]
        # state update: h_new = exp(l_T)·h_prev + Σ_j exp(l_T - l_j)·dt_j·B_j⊗x_j
        ltot = lcum[:, -1:, :]                                # (B,1,H)
        wj = jnp.exp(ltot - lcum) * dkf                        # (B,T,H)
        s_chunk = jnp.einsum("bjn,bjh,bjhp->bhpn", bk.astype(jnp.float32),
                             wj, xk.astype(jnp.float32))
        hnew = jnp.exp(ltot[:, 0, :])[:, :, None, None] * hprev + s_chunk
        return hnew, y.astype(xh.dtype)

    if h0 is None:
        h0 = jnp.zeros((b, h, p, n), jnp.float32)
    from repro.models.common import scan_or_unroll
    hf, ys = scan_or_unroll(chunk_fn, h0, (xc, bc, cc, dc), unroll=unroll)
    y = ys.transpose(1, 0, 2, 3, 4).reshape(b, s, h, p)
    return y, hf


def _layer_inputs(x, lp, cfg, conv_state=None):
    """Projections + causal conv + activations for one layer."""
    z = jnp.einsum("bsd,de->bse", x, lp["in_z"])
    xi = jnp.einsum("bsd,de->bse", x, lp["in_x"])
    bt = jnp.einsum("bsd,dn->bsn", x, lp["in_b"])
    ct = jnp.einsum("bsd,dn->bsn", x, lp["in_c"])
    dt_raw = jnp.einsum("bsd,dh->bsh", x, lp["in_dt"])
    cs = {} if conv_state is None else conv_state
    xi, cs_x = causal_conv1d(xi, lp["conv_x"], state=cs.get("x"))
    bt, cs_b = causal_conv1d(bt, lp["conv_b"], state=cs.get("b"))
    ct, cs_c = causal_conv1d(ct, lp["conv_c"], state=cs.get("c"))
    xi, bt, ct = jax.nn.silu(xi), jax.nn.silu(bt), jax.nn.silu(ct)
    dt = jax.nn.softplus(dt_raw.astype(jnp.float32)
                         + lp["dt_bias"].astype(jnp.float32))
    return z, xi, bt, ct, dt, {"x": cs_x, "b": cs_b, "c": cs_c}


def _finish(y, z, xi, lp, cfg):
    """Skip connection + gated RMSNorm + out projection."""
    b, s, _ = z.shape
    h, p = cfg.ssm_heads, cfg.ssm_head_dim
    y = y + xi.reshape(b, s, h, p) * lp["skip_d"][None, None, :, None].astype(y.dtype)
    y = y.reshape(b, s, h * p)
    y = rms_norm(y * jax.nn.silu(z.astype(jnp.float32)).astype(y.dtype),
                 lp["gate_norm"])
    return jnp.einsum("bse,ed->bsd", y, lp["out"])


def layer_full(x, lp, cfg, constrain, unroll=False):
    """Full-sequence SSD layer (train / prefill). Returns (out, state)."""
    b, s, d = x.shape
    h, p = cfg.ssm_heads, cfg.ssm_head_dim
    xn = rms_norm(x, lp["norm"])
    z, xi, bt, ct, dt, conv_state = _layer_inputs(xn, lp, cfg)
    a = -jnp.exp(lp["a_log"].astype(jnp.float32))
    xh = constrain(xi.reshape(b, s, h, p), "batchlike", None, "heads", None)
    y, hf = _ssd_chunked(xh, bt, ct, dt, a, cfg, None, constrain, unroll)
    out = _finish(y, z, xi, lp, cfg)
    return x + out, {"h": hf, "conv": conv_state, }


def layer_decode(x, lp, cfg, state):
    """Single-step recurrence. x: (B,1,d); state {'h': (B,H,P,N), 'conv': ...}."""
    b = x.shape[0]
    h, p = cfg.ssm_heads, cfg.ssm_head_dim
    xn = rms_norm(x, lp["norm"])
    z, xi, bt, ct, dt, conv_state = _layer_inputs(xn, lp, cfg, state["conv"])
    a = -jnp.exp(lp["a_log"].astype(jnp.float32))
    xh = xi.reshape(b, 1, h, p).astype(jnp.float32)
    decay = jnp.exp(dt[:, 0, :] * a)                      # (B,H)
    hnew = decay[:, :, None, None] * state["h"] + jnp.einsum(
        "bn,bh,bhp->bhpn", bt[:, 0].astype(jnp.float32), dt[:, 0], xh[:, 0])
    y = jnp.einsum("bn,bhpn->bhp", ct[:, 0].astype(jnp.float32), hnew)
    y = y[:, None].astype(x.dtype)                        # (B,1,H,P)
    out = _finish(y, z, xi, lp, cfg)
    return x + out, {"h": hnew, "conv": conv_state}


# ---------------------------------------------------------------------------
# Model entry points (layer-stacked scan, same contract as transformer.py)
# ---------------------------------------------------------------------------

def _forward_full(params, tokens, cfg, opts, *, mode):
    from repro.models.transformer import embed_tokens, remat_wrap
    x = embed_tokens(params, tokens, cfg, opts)

    def body(h, lp):
        h = opts.constrain(h, "batchlike", opts.seq_axis, None)
        h, st = layer_full(h, lp, cfg, opts.constrain, opts.unroll_scans)
        return h, (st if mode == "prefill" else None)

    from repro.models.common import scan_or_unroll
    x, states = scan_or_unroll(
        remat_wrap(body, opts.remat if mode == "train" else "none"),
        x, params["layers"], unroll=opts.unroll_scans)
    return rms_norm(x, params["final_norm"]), states


def train_loss(params, batch, cfg, opts):
    from repro.models.transformer import chunked_ce_loss, lm_head_weights
    hidden, _ = _forward_full(params, batch["tokens"], cfg, opts, mode="train")
    loss = chunked_ce_loss(hidden, lm_head_weights(params, cfg),
                           batch["labels"], cfg, opts)
    return loss, {"loss": loss}


def prefill(params, batch, cfg, opts):
    from repro.models.transformer import lm_head_weights
    hidden, states = _forward_full(params, batch["tokens"], cfg, opts,
                                   mode="prefill")
    logits = jnp.einsum("bsd,vd->bsv", hidden[:, -1:, :],
                        lm_head_weights(params, cfg)).astype(jnp.float32)
    b, s = batch["tokens"].shape
    cache = dict(states, pos=jnp.full((b,), s, jnp.int32))
    return logits, cache


def decode_step(params, batch, cache, cfg, opts):
    from repro.models.transformer import embed_tokens, lm_head_weights
    x = embed_tokens(params, batch["tokens"], cfg, opts)
    kv = {"h": cache["h"], "conv": cache["conv"]}

    def body(h, xs):
        lp, st = xs
        h, st = layer_decode(h, lp, cfg, st)
        return h, st

    from repro.models.common import scan_or_unroll
    x, new_states = scan_or_unroll(body, x, (params["layers"], kv),
                                   unroll=opts.unroll_scans)
    x = rms_norm(x, params["final_norm"])
    logits = jnp.einsum("bsd,vd->bsv", x,
                        lm_head_weights(params, cfg)).astype(jnp.float32)
    new_cache = dict(new_states, pos=cache["pos"] + 1)
    return logits, new_cache


def cache_shape(cfg, batch: int, max_len: int, dtype=jnp.bfloat16):
    """SSM state is O(1) in context length — max_len only bounds positions."""
    L, h, p, n = cfg.n_layers, cfg.ssm_heads, cfg.ssm_head_dim, cfg.ssm_state
    di, k = cfg.d_inner, cfg.conv_kernel
    ns = cfg.ssm_state
    return {
        "h": jax.ShapeDtypeStruct((L, batch, h, p, n), jnp.float32),
        "conv": {
            "x": jax.ShapeDtypeStruct((L, batch, k - 1, di), dtype),
            "b": jax.ShapeDtypeStruct((L, batch, k - 1, ns), dtype),
            "c": jax.ShapeDtypeStruct((L, batch, k - 1, ns), dtype),
        },
        "pos": jax.ShapeDtypeStruct((batch,), jnp.int32),
    }
