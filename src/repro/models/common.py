"""Shared model substrate: param schemas with logical sharding axes, norms,
activations, rotary embeddings.

Parameters are declared as a *schema* (a pytree of `ParamDef`), from which we
derive (a) materialized params via `init_params`, (b) abstract shapes via
`eval_shape`, and (c) `PartitionSpec`s via `parallel.sharding.schema_pspecs`.
Logical axis names (not mesh axes) are attached at declaration; the mesh
mapping + divisibility rule lives in `repro.parallel.sharding`.
"""

from __future__ import annotations

import dataclasses
import math
from typing import Callable, Optional, Tuple

import jax
import jax.numpy as jnp


# --------------------------------------------------------------------------
# Param schema
# --------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class ParamDef:
    """Declaration of one parameter tensor.

    logical: one name per dim, drawn from the vocabulary in
    `repro.parallel.sharding.DEFAULT_RULES` ('embed', 'heads', 'ff', 'vocab',
    'experts', 'batchlike', None, ...). 'layers' marks a stacked-layer dim.
    """

    shape: Tuple[int, ...]
    logical: Tuple[Optional[str], ...]
    init: str = "normal"       # normal | zeros | ones | small_normal
    scale: float = 1.0         # fan-in scaling applied on top of init

    def __post_init__(self):
        assert len(self.shape) == len(self.logical), (self.shape, self.logical)


def _init_one(key: jax.Array, d: ParamDef, dtype) -> jnp.ndarray:
    if d.init == "zeros":
        return jnp.zeros(d.shape, dtype)
    if d.init == "ones":
        return jnp.ones(d.shape, dtype)
    fan_in = d.shape[-2] if len(d.shape) >= 2 else d.shape[-1]
    std = d.scale / math.sqrt(max(fan_in, 1))
    if d.init == "small_normal":
        std = 0.02 * d.scale
    return (std * jax.random.normal(key, d.shape, jnp.float32)).astype(dtype)


def is_schema_leaf(x) -> bool:
    return isinstance(x, ParamDef)


def init_params(schema, key: jax.Array, dtype=jnp.bfloat16):
    """Materialize a schema into a params pytree (same structure)."""
    leaves, treedef = jax.tree.flatten(schema, is_leaf=is_schema_leaf)
    keys = jax.random.split(key, len(leaves))
    return jax.tree.unflatten(
        treedef, [_init_one(k, d, dtype) for k, d in zip(keys, leaves)]
    )


def abstract_params(schema, dtype=jnp.bfloat16):
    """ShapeDtypeStruct pytree for a schema — no allocation (dry-run path)."""
    return jax.tree.map(
        lambda d: jax.ShapeDtypeStruct(d.shape, dtype),
        schema,
        is_leaf=is_schema_leaf,
    )


def param_count(schema) -> int:
    return sum(
        math.prod(d.shape)
        for d in jax.tree.leaves(schema, is_leaf=is_schema_leaf)
    )


# --------------------------------------------------------------------------
# Norms / activations
# --------------------------------------------------------------------------

def rms_norm(x: jnp.ndarray, weight: jnp.ndarray, *, eps: float = 1e-6,
             plus_one: bool = False) -> jnp.ndarray:
    """RMSNorm in fp32 (mixed-precision-sensitive long reduction)."""
    xf = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(xf), axis=-1, keepdims=True)
    y = xf * jax.lax.rsqrt(var + eps)
    w = weight.astype(jnp.float32)
    if plus_one:  # gemma / recurrentgemma convention: weight is (1 + w)
        w = 1.0 + w
    return (y * w).astype(x.dtype)


def layer_norm(x: jnp.ndarray, weight: jnp.ndarray, bias: jnp.ndarray,
               *, eps: float = 1e-5) -> jnp.ndarray:
    xf = x.astype(jnp.float32)
    mu = jnp.mean(xf, axis=-1, keepdims=True)
    var = jnp.var(xf, axis=-1, keepdims=True)
    y = (xf - mu) * jax.lax.rsqrt(var + eps)
    return (y * weight.astype(jnp.float32) + bias.astype(jnp.float32)).astype(x.dtype)


def act_fn(name: str) -> Callable[[jnp.ndarray], jnp.ndarray]:
    return {
        "silu": jax.nn.silu,
        "gelu": lambda x: jax.nn.gelu(x, approximate=True),
        "relu": jax.nn.relu,
    }[name]


def glu_act(name: str):
    """GLU family: (gate_act, uses_glu). swiglu→silu, geglu→gelu."""
    return {"swiglu": "silu", "geglu": "gelu"}[name]


def softcap(x: jnp.ndarray, cap: float) -> jnp.ndarray:
    """Soft logit capping (gemma/recurrentgemma): cap*tanh(x/cap)."""
    if cap <= 0:
        return x
    return (cap * jnp.tanh(x.astype(jnp.float32) / cap)).astype(x.dtype)


# --------------------------------------------------------------------------
# Rotary position embeddings
# --------------------------------------------------------------------------

def rope_frequencies(head_dim: int, fraction: float, theta: float) -> jnp.ndarray:
    """Inverse frequencies for the rotated sub-dimension (fraction of head_dim)."""
    rot = int(head_dim * fraction)
    rot -= rot % 2
    return 1.0 / (theta ** (jnp.arange(0, rot, 2, dtype=jnp.float32) / rot))


def apply_rope(x: jnp.ndarray, positions: jnp.ndarray, *, fraction: float = 1.0,
               theta: float = 1e4) -> jnp.ndarray:
    """Apply RoPE over the final dim.

    x: (..., S, n_heads, head_dim); positions: broadcastable to (..., S).
    fraction < 1 rotates only the leading `fraction` of head dims
    (ChatGLM's 2D/partial rotary); the remainder passes through.
    """
    head_dim = x.shape[-1]
    inv = rope_frequencies(head_dim, fraction, theta)          # (rot/2,)
    rot = inv.shape[0] * 2
    angles = positions[..., None].astype(jnp.float32) * inv    # (..., S, rot/2)
    cos = jnp.cos(angles)[..., None, :]                        # (..., S, 1, rot/2)
    sin = jnp.sin(angles)[..., None, :]
    x_rot, x_pass = x[..., :rot], x[..., rot:]
    x1, x2 = x_rot[..., 0::2], x_rot[..., 1::2]
    xf1, xf2 = x1.astype(jnp.float32), x2.astype(jnp.float32)
    o1 = xf1 * cos - xf2 * sin
    o2 = xf2 * cos + xf1 * sin
    out = jnp.stack([o1, o2], axis=-1).reshape(x_rot.shape).astype(x.dtype)
    return jnp.concatenate([out, x_pass], axis=-1) if rot < head_dim else out


# --------------------------------------------------------------------------
# Depthwise causal conv1d (mamba2 / RG-LRU temporal conv)
# --------------------------------------------------------------------------

def causal_conv1d(x: jnp.ndarray, w: jnp.ndarray, *,
                  state: Optional[jnp.ndarray] = None):
    """Depthwise causal 1-D conv.

    x: (B, S, C); w: (K, C). Returns (y, new_state) where state is the last
    K-1 inputs (B, K-1, C) for streaming decode.
    """
    k = w.shape[0]
    if state is None:
        pad = jnp.zeros((x.shape[0], k - 1, x.shape[2]), x.dtype)
    else:
        pad = state.astype(x.dtype)
    xp = jnp.concatenate([pad, x], axis=1)                     # (B, S+K-1, C)
    y = jnp.zeros_like(x, dtype=jnp.float32)
    for i in range(k):  # K is tiny (4); unrolled shifted adds beat conv lowering
        y = y + xp[:, i : i + x.shape[1]].astype(jnp.float32) * w[i].astype(jnp.float32)
    new_state = xp[:, -(k - 1):] if k > 1 else jnp.zeros((x.shape[0], 0, x.shape[2]), x.dtype)
    return y.astype(x.dtype), new_state


# --------------------------------------------------------------------------
# Scan-or-unroll: XLA's cost analysis counts a while-loop body ONCE, not
# trip_count times. The dry-run therefore lowers small "probe" programs with
# every internal lax.scan statically unrolled (exact flops/bytes/collectives)
# and combines them analytically; the real deliverable program still scans.
# --------------------------------------------------------------------------

def scan_or_unroll(body, init, xs, *, unroll: bool, length=None):
    """Drop-in for jax.lax.scan(body, init, xs) with optional static unroll."""
    import jax as _jax
    import jax.numpy as _jnp

    if not unroll:
        return _jax.lax.scan(body, init, xs, length=length)
    if length is None:
        length = len(_jax.tree.leaves(xs)[0])
    carry = init
    ys = []
    for i in range(length):
        xi = _jax.tree.map(lambda t: t[i], xs) if xs is not None else None
        carry, y = body(carry, xi)
        ys.append(y)
    if ys and ys[0] is not None:
        ys = _jax.tree.map(lambda *ts: _jnp.stack(ts), *ys)
    else:
        ys = None
    return carry, ys
