"""Encoder-decoder transformer (seamless-m4t-medium backbone).

The audio frontend is a STUB per the assignment: the encoder consumes
precomputed frame embeddings (B, S_enc, d_model). 12-layer bidirectional
encoder + 12-layer causal decoder with per-layer cross-attention. Decode
shapes grow the decoder self-attention cache; cross-attention K/V are
computed once at prefill and cached.
"""

from __future__ import annotations

from typing import Any, Dict

import jax
import jax.numpy as jnp

from repro.models import attention as attn_mod
from repro.models.common import ParamDef, act_fn, glu_act, rms_norm
from repro.models.quantized import SCALE_DTYPE, qeinsum
from repro.models.transformer import (
    ExecOptions, _expand_kv, _kv_round_of, _pools_of, attn_block, attn_schema,
    chunked_ce_loss, embed_tokens, head_mask, lm_head_weights,
    paged_kv_shapes, remat_wrap,
)


def _ffn_params(L, d, f):
    return {
        "w1": ParamDef((L, d, f), ("layers", "embed", "ff")),
        "w3": ParamDef((L, d, f), ("layers", "embed", "ff")),
        "w2": ParamDef((L, f, d), ("layers", "ff", "embed")),
    }


def schema(cfg) -> Dict[str, Any]:
    d, h, kv, hd, f = (cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.head_dim,
                       cfg.d_ff)
    Le, Ld, v = cfg.n_enc_layers, cfg.n_dec_layers, cfg.padded_vocab
    enc = {"attn_norm": ParamDef((Le, d), ("layers", None), init="ones"),
           "ffn_norm": ParamDef((Le, d), ("layers", None), init="ones")}
    enc.update(attn_schema(cfg, Le))
    enc.update(_ffn_params(Le, d, f))
    dec = {"attn_norm": ParamDef((Ld, d), ("layers", None), init="ones"),
           "cross_norm": ParamDef((Ld, d), ("layers", None), init="ones"),
           "ffn_norm": ParamDef((Ld, d), ("layers", None), init="ones")}
    dec.update(attn_schema(cfg, Ld))
    dec.update(attn_schema(cfg, Ld, prefix="c"))
    dec.update(_ffn_params(Ld, d, f))
    return {
        "embed": ParamDef((v, d), ("vocab", "embed"), init="small_normal"),
        "enc_norm": ParamDef((d,), (None,), init="ones"),
        "final_norm": ParamDef((d,), (None,), init="ones"),
        "enc": enc,
        "dec": dec,
    }


def _cross_attn_full(x, p, cfg, opts, enc_out, kv_round=None):
    """Full cross attention (train/prefill). Returns (out, (ck, cv)).

    `kv_round` (prefill with a lossy cross cache, i.e. kv_dtype='bf16' —
    int8 pools keep the cross cache f32) rounds the attended ck/cv through
    the storage dtype, so the monolithic prefill sees the same cross K/V
    the decode steps and the chunked prefill read back from the cache."""
    c = opts.constrain
    q = qeinsum("bsd,dhk->bshk", x, p["cwq"])
    ck = qeinsum("bsd,dhk->bshk", enc_out, p["cwk"])
    cv = qeinsum("bsd,dhk->bshk", enc_out, p["cwv"])
    ka, va = (ck, cv) if kv_round is None else (
        ck.astype(kv_round).astype(ck.dtype),
        cv.astype(kv_round).astype(cv.dtype))
    kx, vx = _expand_kv(ka, va, cfg)
    qp = c(q[:, :, :, None, :], "batchlike", None, "heads_flat", None, None)
    o = attn_mod.attention(qp, kx, vx, causal=False, scale=cfg.head_dim ** -0.5,
                           impl=opts.attn_impl, q_chunk=opts.q_chunk,
                           kv_chunk=opts.kv_chunk, unroll=opts.unroll_scans)
    o = o[:, :, :, 0, :] * head_mask(cfg, x.dtype)[None, None, :, None]
    return qeinsum("bshk,hkd->bsd", o, p["cwo"]), (ck, cv)


def _cross_attn_cached(x, p, cfg, opts, cache, mode):
    """Cross-attention against the slot's cached ck/cv rows — 'decode' runs
    the single-query kernel at the fixed cross depth; 'chunk' runs full
    non-causal attention over the chunk's C rows. (Cross-attention is NOT
    part of the shared self-attention core: it has no rope, no causal mask
    and no cache writes — only the projections below.)"""
    b = x.shape[0]
    kvp, gp = cfg.padded_kv_group
    hm = head_mask(cfg, x.dtype)[None, None, :, None]
    scale = cfg.head_dim ** -0.5
    cq = qeinsum("bsd,dhk->bshk", x, p["cwq"])
    if mode == "decode":
        cqg = cq.reshape(b, 1, kvp, gp, cfg.head_dim)
        se = cache["ck"].shape[1]
        co = attn_mod.decode_attention(cqg, cache["ck"], cache["cv"],
                                       jnp.full((b,), se, jnp.int32),
                                       scale=scale)
        co = co.reshape(b, 1, cfg.n_heads_padded, cfg.head_dim)
    else:  # chunk
        ckx, cvx = _expand_kv(cache["ck"].astype(x.dtype),
                              cache["cv"].astype(x.dtype), cfg)
        qp = cq[:, :, :, None, :]
        co = attn_mod.attention(qp, ckx, cvx, causal=False, scale=scale,
                                impl=opts.attn_impl, q_chunk=opts.q_chunk,
                                kv_chunk=opts.kv_chunk,
                                unroll=opts.unroll_scans)
        co = co[:, :, :, 0, :]
    return qeinsum("bshk,hkd->bsd", co * hm, p["cwo"])


def encode(params, frames, cfg, opts: ExecOptions):
    x = opts.constrain(frames, "batchlike", None, None)
    positions = jnp.arange(frames.shape[1])[None, :]

    def body(h, lp):
        h = opts.constrain(h, "batchlike", opts.seq_axis, None)
        a, _ = attn_block(rms_norm(h, lp["attn_norm"]), lp, cfg, opts,
                          positions=positions, mode="train", causal=False)
        h = h + a
        hn = rms_norm(h, lp["ffn_norm"])
        act = act_fn(glu_act(cfg.activation))
        ff = act(qeinsum("bsd,df->bsf", hn, lp["w1"])) \
            * qeinsum("bsd,df->bsf", hn, lp["w3"])
        ff = opts.constrain(ff, "batchlike", None, "ff")
        return h + qeinsum("bsf,fd->bsd", ff, lp["w2"]), None

    from repro.models.common import scan_or_unroll
    x, _ = scan_or_unroll(remat_wrap(body, opts.remat), x, params["enc"],
                          unroll=opts.unroll_scans)
    return rms_norm(x, params["enc_norm"])


def _dec_layer(h, lp, cfg, opts, positions, enc_out, mode, cache,
               kv_round=None, chunk=None):
    c = opts.constrain
    if mode != "decode":
        h = c(h, "batchlike", opts.seq_axis, None)
    # decoder self-attention IS the unified core (transformer.attn_block) —
    # QKV/rope/round/write/attend land there exactly once for every mode.
    # The decoder prefill with a lossy (bf16/int8) KV cache attends the
    # values the cache will store (transformer._round_kv); encdec rope is the
    # full-fraction default, so the shared rope call is identical.
    a, new_cache = attn_block(rms_norm(h, lp["attn_norm"]), lp, cfg, opts,
                              positions=positions, mode=mode, cache=cache,
                              kv_round=kv_round if mode == "prefill" else None,
                              chunk=chunk)
    h = h + a
    xn = rms_norm(h, lp["cross_norm"])
    if mode in ("train", "prefill"):
        # the cross CACHE stays f32 under int8 KV (cache_shape), so only a
        # bf16 kv_round actually rounds the cross attention inputs
        cross_round = kv_round if (mode == "prefill"
                                   and kv_round is not None
                                   and kv_round != jnp.int8) else None
        ca, (ck, cv) = _cross_attn_full(xn, lp, cfg, opts, enc_out,
                                        kv_round=cross_round)
        if mode == "prefill":
            new_cache = dict(new_cache, ck=ck, cv=cv)
    else:  # decode / chunk: read the slot's cached cross K/V
        ca = _cross_attn_cached(xn, lp, cfg, opts, cache, mode)
    h = h + ca
    hn = rms_norm(h, lp["ffn_norm"])
    act = act_fn(glu_act(cfg.activation))
    ff = act(qeinsum("bsd,df->bsf", hn, lp["w1"])) \
        * qeinsum("bsd,df->bsf", hn, lp["w3"])
    ff = c(ff, "batchlike", None, "ff")
    return h + qeinsum("bsf,fd->bsd", ff, lp["w2"]), new_cache


def decode_stack(params, tokens, cfg, opts, enc_out, *, mode, cache=None,
                 positions=None, kv_round=None):
    x = embed_tokens(params, tokens, cfg, opts)
    if positions is None:
        positions = jnp.arange(tokens.shape[1])[None, :]

    def body(h, xs):
        lp, lc = xs
        return _dec_layer(h, lp, cfg, opts, positions, enc_out, mode, lc,
                          kv_round)

    from repro.models.common import scan_or_unroll
    x, new_cache = scan_or_unroll(
        remat_wrap(body, opts.remat if mode == "train" else "none"),
        x, (params["dec"], cache), unroll=opts.unroll_scans)
    return rms_norm(x, params["final_norm"]), new_cache


def train_loss(params, batch, cfg, opts: ExecOptions):
    enc_out = encode(params, batch["frames"], cfg, opts)
    hidden, _ = decode_stack(params, batch["tokens"], cfg, opts, enc_out,
                             mode="train")
    loss = chunked_ce_loss(hidden, lm_head_weights(params, cfg),
                           batch["labels"], cfg, opts)
    return loss, {"loss": loss}


def prefill_cache(params, batch, cfg, opts: ExecOptions):
    """Cache-only prefill (no LM-head) for the serve engine's replay path."""
    enc_out = encode(params, batch["frames"], cfg, opts)
    _, cache = decode_stack(params, batch["tokens"], cfg, opts, enc_out,
                            mode="prefill", kv_round=_kv_round_of(batch))
    b, s = batch["tokens"].shape
    return dict(cache, pos=jnp.full((b,), s, jnp.int32))


def prefill_cross(params, batch, cfg, opts: ExecOptions):
    """Encoder + per-layer cross K/V only — the admission-time half of a
    CHUNKED encdec prefill. The decoder's cross K/V depend on the frames
    alone (written once, read every step), so the engine computes them once
    per request, pastes them into the slot's dense cross cache, and the
    per-tick `prefill_chunk` calls read them back — the encoder never stalls
    the decode batch more than once per request."""
    enc_out = encode(params, batch["frames"], cfg, opts)

    def body(_, lp):
        ck = qeinsum("bsd,dhk->bshk", enc_out, lp["cwk"])
        cv = qeinsum("bsd,dhk->bshk", enc_out, lp["cwv"])
        return None, (ck, cv)

    from repro.models.common import scan_or_unroll
    _, (ck, cv) = scan_or_unroll(body, None, params["dec"],
                                 unroll=opts.unroll_scans)
    return {"ck": ck, "cv": cv}          # (L, B, S_enc, KVp, D)


def prefill_chunk(params, batch, cache, cfg, opts: ExecOptions):
    """One fixed-size chunk of paged decoder prefill (see
    transformer.prefill_chunk for the contract). Cross-attention reads the
    slot's dense cross K/V, pasted at admission by `prefill_cross`; batch
    additionally carries `slot` () int32 to address them."""
    tokens = batch["tokens"]
    start, length = batch["start"], batch["length"]
    slot = batch["slot"]
    b, C = tokens.shape
    positions = start[:, None] + jnp.arange(C)[None, :]
    x = embed_tokens(params, tokens, cfg, opts)
    ck_s = jax.lax.dynamic_index_in_dim(cache["ck"], slot, 1, keepdims=True)
    cv_s = jax.lax.dynamic_index_in_dim(cache["cv"], slot, 1, keepdims=True)
    chunk = {"start": start, "length": length, "page_row": batch["page_row"]}

    def dyn(t, i):
        return jax.lax.dynamic_index_in_dim(t, i, 0, keepdims=False)

    def body(carry, xs):
        h, pools = carry
        lp, ck, cv, i = xs                       # ck/cv: (1, S_enc, KVp, D)
        layer_cache = {key: dyn(val, i) for key, val in pools.items()}
        layer_cache["ck"], layer_cache["cv"] = ck, cv
        h, new_cache = _dec_layer(h, lp, cfg, opts, positions, None, "chunk",
                                  layer_cache, chunk=chunk)
        pools = {key: jax.lax.dynamic_update_index_in_dim(
            val, new_cache[key], i, 0) for key, val in pools.items()}
        return (h, pools), None

    from repro.models.common import scan_or_unroll
    (_, pools), _ = scan_or_unroll(
        body, (x, _pools_of(cache)),
        (params["dec"], ck_s, cv_s, jnp.arange(cfg.n_dec_layers)),
        unroll=opts.unroll_scans)
    return dict(cache, **pools)


def prefill(params, batch, cfg, opts: ExecOptions):
    enc_out = encode(params, batch["frames"], cfg, opts)
    hidden, cache = decode_stack(params, batch["tokens"], cfg, opts, enc_out,
                                 mode="prefill", kv_round=_kv_round_of(batch))
    logits = jnp.einsum("bsd,vd->bsv", hidden[:, -1:, :],
                        lm_head_weights(params, cfg)).astype(jnp.float32)
    b, s = batch["tokens"].shape
    cache = dict(cache, pos=jnp.full((b,), s, jnp.int32))
    return logits, cache


def decode_step(params, batch, cache, cfg, opts: ExecOptions):
    """Self KV rides the scan carry (in-place DUS); cross K/V are read-only
    xs (no ys re-emission) — avoids double-buffering either cache."""
    positions = cache["pos"]
    page_table = cache.get("page_table")
    x = embed_tokens(params, batch["tokens"], cfg, opts)

    def dyn(t, i):
        return jax.lax.dynamic_index_in_dim(t, i, 0, keepdims=False)

    def body(carry, xs):
        h, pools = carry
        lp, ck, cv, i = xs
        layer_cache = {key: dyn(val, i) for key, val in pools.items()}
        layer_cache["ck"], layer_cache["cv"] = ck, cv
        if page_table is not None:
            layer_cache["page_table"] = page_table
        h, new_cache = _dec_layer(h, lp, cfg, opts, positions[:, None],
                                  None, "decode", layer_cache)
        pools = {key: jax.lax.dynamic_update_index_in_dim(
            val, new_cache[key], i, 0) for key, val in pools.items()}
        return (h, pools), None

    from repro.models.common import scan_or_unroll
    (x, pools), _ = scan_or_unroll(
        body, (x, _pools_of(cache)),
        (params["dec"], cache["ck"], cache["cv"],
         jnp.arange(cfg.n_dec_layers)),
        unroll=opts.unroll_scans)
    x = rms_norm(x, params["final_norm"])
    logits = jnp.einsum("bsd,vd->bsv", x,
                        lm_head_weights(params, cfg)).astype(jnp.float32)
    new_cache = dict(pools, ck=cache["ck"], cv=cache["cv"],
                     pos=positions + 1)
    if page_table is not None:
        new_cache["page_table"] = page_table
    return logits, new_cache


def cache_shape(cfg, batch: int, max_len: int, dtype=jnp.bfloat16, *,
                page_size=None, n_pages=None):
    """Self-attention K/V go paged when `page_size` is given (shared sizing
    contract: transformer.paged_kv_shapes); cross K/V stay dense per slot —
    they are written once at prefill at a fixed (cross_len) depth, so paging
    would buy nothing and cost a second table. dtype=jnp.int8 quantizes the
    self-attention K/V only (plus 'ks'/'vs' f16 row scales); cross K/V keep
    f32 — written once, read every step, and a second dequant operand per
    layer would buy back ~cross_len/max_len of the savings at best."""
    L, kv, hd, se = cfg.n_dec_layers, cfg.kv_pad, cfg.head_dim, cfg.cross_len
    cross_dtype = jnp.float32 if dtype == jnp.int8 else dtype
    cross = {
        "ck": jax.ShapeDtypeStruct((L, batch, se, kv, hd), cross_dtype),
        "cv": jax.ShapeDtypeStruct((L, batch, se, kv, hd), cross_dtype),
    }
    if page_size is None:
        self_kv = {
            "k": jax.ShapeDtypeStruct((L, batch, max_len, kv, hd), dtype),
            "v": jax.ShapeDtypeStruct((L, batch, max_len, kv, hd), dtype),
            "pos": jax.ShapeDtypeStruct((batch,), jnp.int32),
        }
        if dtype == jnp.int8:
            for key in ("ks", "vs"):
                self_kv[key] = jax.ShapeDtypeStruct(
                    (L, batch, max_len, kv), SCALE_DTYPE)
    else:
        self_kv = paged_kv_shapes(L, batch, max_len, kv, hd, dtype,
                                  page_size, n_pages)
    return {**self_kv, **cross}
