"""Encoder-decoder transformer (seamless-m4t-medium backbone).

The audio frontend is a STUB per the assignment: the encoder consumes
precomputed frame embeddings (B, S_enc, d_model). 12-layer bidirectional
encoder + 12-layer causal decoder with per-layer cross-attention. Decode
shapes grow the decoder self-attention cache; cross-attention K/V are
computed once at prefill and cached.
"""

from __future__ import annotations

from typing import Any, Dict

import jax
import jax.numpy as jnp

from repro.models import attention as attn_mod
from repro.models.common import ParamDef, act_fn, apply_rope, glu_act, rms_norm
from repro.models.quantized import SCALE_DTYPE, qeinsum
from repro.models.transformer import (
    ExecOptions, _expand_kv, _kv_round_of, _round_kv, _write_cache,
    _write_cache_paged, _write_cache_paged_q, _write_cache_q,
    _write_chunk_paged, _write_chunk_paged_q, attn_schema, chunked_ce_loss,
    embed_tokens, head_mask, lm_head_weights, paged_kv_shapes, remat_wrap,
)


def _ffn_params(L, d, f):
    return {
        "w1": ParamDef((L, d, f), ("layers", "embed", "ff")),
        "w3": ParamDef((L, d, f), ("layers", "embed", "ff")),
        "w2": ParamDef((L, f, d), ("layers", "ff", "embed")),
    }


def schema(cfg) -> Dict[str, Any]:
    d, h, kv, hd, f = (cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.head_dim,
                       cfg.d_ff)
    Le, Ld, v = cfg.n_enc_layers, cfg.n_dec_layers, cfg.padded_vocab
    enc = {"attn_norm": ParamDef((Le, d), ("layers", None), init="ones"),
           "ffn_norm": ParamDef((Le, d), ("layers", None), init="ones")}
    enc.update(attn_schema(cfg, Le))
    enc.update(_ffn_params(Le, d, f))
    dec = {"attn_norm": ParamDef((Ld, d), ("layers", None), init="ones"),
           "cross_norm": ParamDef((Ld, d), ("layers", None), init="ones"),
           "ffn_norm": ParamDef((Ld, d), ("layers", None), init="ones")}
    dec.update(attn_schema(cfg, Ld))
    dec.update(attn_schema(cfg, Ld, prefix="c"))
    dec.update(_ffn_params(Ld, d, f))
    return {
        "embed": ParamDef((v, d), ("vocab", "embed"), init="small_normal"),
        "enc_norm": ParamDef((d,), (None,), init="ones"),
        "final_norm": ParamDef((d,), (None,), init="ones"),
        "enc": enc,
        "dec": dec,
    }


def _self_attn(x, p, cfg, opts, positions, *, causal, prefix="", kv_round=None):
    c = opts.constrain
    q = qeinsum("bsd,dhk->bshk", x, p[prefix + "wq"])
    k = qeinsum("bsd,dhk->bshk", x, p[prefix + "wk"])
    v = qeinsum("bsd,dhk->bshk", x, p[prefix + "wv"])
    q = apply_rope(q, positions, theta=cfg.rope_theta)
    k = apply_rope(k, positions, theta=cfg.rope_theta)
    # decoder prefill with a lossy (bf16/int8) KV cache attends the values
    # the cache will store (see transformer._round_kv); encoder K/V are
    # never cached, so the encoder passes kv_round=None
    ka, va = _round_kv(k, v, kv_round)
    kx, vx = _expand_kv(ka, va, cfg)
    qp = c(q[:, :, :, None, :], "batchlike", None, "heads_flat", None, None)
    kx = c(kx, "batchlike", None, "heads_flat", None)
    vx = c(vx, "batchlike", None, "heads_flat", None)
    o = attn_mod.attention(qp, kx, vx, causal=causal, scale=cfg.head_dim ** -0.5,
                           impl=opts.attn_impl, q_chunk=opts.q_chunk,
                           kv_chunk=opts.kv_chunk, unroll=opts.unroll_scans)
    o = o[:, :, :, 0, :] * head_mask(cfg, x.dtype)[None, None, :, None]
    return qeinsum("bshk,hkd->bsd", o, p[prefix + "wo"]), (k, v)


def _cross_attn_full(x, p, cfg, opts, enc_out, kv_round=None):
    """Full cross attention (train/prefill). Returns (out, (ck, cv)).

    `kv_round` (prefill with a lossy cross cache, i.e. kv_dtype='bf16' —
    int8 pools keep the cross cache f32) rounds the attended ck/cv through
    the storage dtype, so the monolithic prefill sees the same cross K/V
    the decode steps and the chunked prefill read back from the cache."""
    c = opts.constrain
    q = qeinsum("bsd,dhk->bshk", x, p["cwq"])
    ck = qeinsum("bsd,dhk->bshk", enc_out, p["cwk"])
    cv = qeinsum("bsd,dhk->bshk", enc_out, p["cwv"])
    ka, va = (ck, cv) if kv_round is None else (
        ck.astype(kv_round).astype(ck.dtype),
        cv.astype(kv_round).astype(cv.dtype))
    kx, vx = _expand_kv(ka, va, cfg)
    qp = c(q[:, :, :, None, :], "batchlike", None, "heads_flat", None, None)
    o = attn_mod.attention(qp, kx, vx, causal=False, scale=cfg.head_dim ** -0.5,
                           impl=opts.attn_impl, q_chunk=opts.q_chunk,
                           kv_chunk=opts.kv_chunk, unroll=opts.unroll_scans)
    o = o[:, :, :, 0, :] * head_mask(cfg, x.dtype)[None, None, :, None]
    return qeinsum("bshk,hkd->bsd", o, p["cwo"]), (ck, cv)


def encode(params, frames, cfg, opts: ExecOptions):
    x = opts.constrain(frames, "batchlike", None, None)
    positions = jnp.arange(frames.shape[1])[None, :]

    def body(h, lp):
        h = opts.constrain(h, "batchlike", opts.seq_axis, None)
        a, _ = _self_attn(rms_norm(h, lp["attn_norm"]), lp, cfg, opts,
                          positions, causal=False)
        h = h + a
        hn = rms_norm(h, lp["ffn_norm"])
        act = act_fn(glu_act(cfg.activation))
        ff = act(qeinsum("bsd,df->bsf", hn, lp["w1"])) \
            * qeinsum("bsd,df->bsf", hn, lp["w3"])
        ff = opts.constrain(ff, "batchlike", None, "ff")
        return h + qeinsum("bsf,fd->bsd", ff, lp["w2"]), None

    from repro.models.common import scan_or_unroll
    x, _ = scan_or_unroll(remat_wrap(body, opts.remat), x, params["enc"],
                          unroll=opts.unroll_scans)
    return rms_norm(x, params["enc_norm"])


def _dec_layer(h, lp, cfg, opts, positions, enc_out, mode, cache,
               kv_round=None):
    c = opts.constrain
    if mode != "decode":
        h = c(h, "batchlike", opts.seq_axis, None)
    act = act_fn(glu_act(cfg.activation))
    if mode in ("train", "prefill"):
        a, (k, v) = _self_attn(rms_norm(h, lp["attn_norm"]), lp, cfg, opts,
                               positions, causal=True,
                               kv_round=kv_round if mode == "prefill"
                               else None)
        h = h + a
        # the cross CACHE stays f32 under int8 KV (cache_shape), so only a
        # bf16 kv_round actually rounds the cross attention inputs
        cross_round = kv_round if (mode == "prefill"
                                   and kv_round is not None
                                   and kv_round != jnp.int8) else None
        ca, (ck, cv) = _cross_attn_full(rms_norm(h, lp["cross_norm"]), lp, cfg,
                                        opts, enc_out, kv_round=cross_round)
        h = h + ca
        new_cache = None
        if mode == "prefill":
            new_cache = {"k": k, "v": v, "ck": ck, "cv": cv}
    else:  # decode
        b = h.shape[0]
        pos_b = positions.reshape(-1)
        xn = rms_norm(h, lp["attn_norm"])
        q = qeinsum("bsd,dhk->bshk", xn, lp["wq"])
        k = qeinsum("bsd,dhk->bshk", xn, lp["wk"])
        v = qeinsum("bsd,dhk->bshk", xn, lp["wv"])
        q = apply_rope(q, positions, theta=cfg.rope_theta)
        k = apply_rope(k, positions, theta=cfg.rope_theta)
        page_table = cache.get("page_table")
        int8_kv = "ks" in cache         # self-KV only; cross K/V stay dense
        k_scale = v_scale = None
        if page_table is None:
            if int8_kv:
                k_cache, k_scale = _write_cache_q(
                    cache["k"], cache["ks"], k, pos_b)
                v_cache, v_scale = _write_cache_q(
                    cache["v"], cache["vs"], v, pos_b)
            else:
                k_cache = _write_cache(cache["k"], k, pos_b)
                v_cache = _write_cache(cache["v"], v, pos_b)
        else:
            if int8_kv:
                k_cache, k_scale = _write_cache_paged_q(
                    cache["k"], cache["ks"], k, pos_b, page_table)
                v_cache, v_scale = _write_cache_paged_q(
                    cache["v"], cache["vs"], v, pos_b, page_table)
            else:
                k_cache = _write_cache_paged(cache["k"], k, pos_b, page_table)
                v_cache = _write_cache_paged(cache["v"], v, pos_b, page_table)
        kvp, gp = cfg.padded_kv_group
        hm = head_mask(cfg, h.dtype)[None, None, :, None]
        qg = q.reshape(b, 1, kvp, gp, cfg.head_dim)
        o = attn_mod.decode_attention(qg, k_cache, v_cache, pos_b + 1,
                                      scale=cfg.head_dim ** -0.5,
                                      page_table=page_table,
                                      k_scale=k_scale, v_scale=v_scale)
        o = o.reshape(b, 1, cfg.n_heads_padded, cfg.head_dim) * hm
        h = h + qeinsum("bshk,hkd->bsd", o, lp["wo"])
        xn = rms_norm(h, lp["cross_norm"])
        cq = qeinsum("bsd,dhk->bshk", xn, lp["cwq"])
        cqg = cq.reshape(b, 1, kvp, gp, cfg.head_dim)
        se = cache["ck"].shape[1]
        co = attn_mod.decode_attention(cqg, cache["ck"], cache["cv"],
                                       jnp.full((b,), se, jnp.int32),
                                       scale=cfg.head_dim ** -0.5)
        co = co.reshape(b, 1, cfg.n_heads_padded, cfg.head_dim) * hm
        h = h + qeinsum("bshk,hkd->bsd", co, lp["cwo"])
        new_cache = {"k": k_cache, "v": v_cache}
        if int8_kv:
            new_cache["ks"], new_cache["vs"] = k_scale, v_scale
    hn = rms_norm(h, lp["ffn_norm"])
    ff = act(qeinsum("bsd,df->bsf", hn, lp["w1"])) \
        * qeinsum("bsd,df->bsf", hn, lp["w3"])
    ff = c(ff, "batchlike", None, "ff")
    return h + qeinsum("bsf,fd->bsd", ff, lp["w2"]), new_cache


def decode_stack(params, tokens, cfg, opts, enc_out, *, mode, cache=None,
                 positions=None, kv_round=None):
    x = embed_tokens(params, tokens, cfg, opts)
    if positions is None:
        positions = jnp.arange(tokens.shape[1])[None, :]

    def body(h, xs):
        lp, lc = xs
        return _dec_layer(h, lp, cfg, opts, positions, enc_out, mode, lc,
                          kv_round)

    from repro.models.common import scan_or_unroll
    x, new_cache = scan_or_unroll(
        remat_wrap(body, opts.remat if mode == "train" else "none"),
        x, (params["dec"], cache), unroll=opts.unroll_scans)
    return rms_norm(x, params["final_norm"]), new_cache


def train_loss(params, batch, cfg, opts: ExecOptions):
    enc_out = encode(params, batch["frames"], cfg, opts)
    hidden, _ = decode_stack(params, batch["tokens"], cfg, opts, enc_out,
                             mode="train")
    loss = chunked_ce_loss(hidden, lm_head_weights(params, cfg),
                           batch["labels"], cfg, opts)
    return loss, {"loss": loss}


def prefill_cache(params, batch, cfg, opts: ExecOptions):
    """Cache-only prefill (no LM-head) for the serve engine's replay path."""
    enc_out = encode(params, batch["frames"], cfg, opts)
    _, cache = decode_stack(params, batch["tokens"], cfg, opts, enc_out,
                            mode="prefill", kv_round=_kv_round_of(batch))
    b, s = batch["tokens"].shape
    return dict(cache, pos=jnp.full((b,), s, jnp.int32))


def prefill_cross(params, batch, cfg, opts: ExecOptions):
    """Encoder + per-layer cross K/V only — the admission-time half of a
    CHUNKED encdec prefill. The decoder's cross K/V depend on the frames
    alone (written once, read every step), so the engine computes them once
    per request, pastes them into the slot's dense cross cache, and the
    per-tick `prefill_chunk` calls read them back — the encoder never stalls
    the decode batch more than once per request."""
    enc_out = encode(params, batch["frames"], cfg, opts)

    def body(_, lp):
        ck = qeinsum("bsd,dhk->bshk", enc_out, lp["cwk"])
        cv = qeinsum("bsd,dhk->bshk", enc_out, lp["cwv"])
        return None, (ck, cv)

    from repro.models.common import scan_or_unroll
    _, (ck, cv) = scan_or_unroll(body, None, params["dec"],
                                 unroll=opts.unroll_scans)
    return {"ck": ck, "cv": cv}          # (L, B, S_enc, KVp, D)


def prefill_chunk(params, batch, cache, cfg, opts: ExecOptions):
    """One fixed-size chunk of paged decoder prefill (see
    transformer.prefill_chunk for the contract). Cross-attention reads the
    slot's dense cross K/V, pasted at admission by `prefill_cross`; batch
    additionally carries `slot` () int32 to address them."""
    tokens = batch["tokens"]
    start, length = batch["start"], batch["length"]
    page_row = batch["page_row"]
    slot = batch["slot"]
    int8_kv = "ks" in cache
    b, C = tokens.shape
    positions = start[:, None] + jnp.arange(C)[None, :]
    x = embed_tokens(params, tokens, cfg, opts)
    ck_s = jax.lax.dynamic_index_in_dim(cache["ck"], slot, 1, keepdims=True)
    cv_s = jax.lax.dynamic_index_in_dim(cache["cv"], slot, 1, keepdims=True)
    kvp, gp = cfg.padded_kv_group
    hm = head_mask(cfg, x.dtype)[None, None, :, None]
    act = act_fn(glu_act(cfg.activation))
    scale = cfg.head_dim ** -0.5

    def dyn(t, i):
        return jax.lax.dynamic_index_in_dim(t, i, 0, keepdims=False)

    def body(carry, xs):
        (h, kc, vc, ksc, vsc) = carry if int8_kv else (*carry, None, None)
        lp, ck, cv, i = xs                       # ck/cv: (1, S_enc, KVp, D)
        xn = rms_norm(h, lp["attn_norm"])
        q = qeinsum("bsd,dhk->bshk", xn, lp["wq"])
        k = qeinsum("bsd,dhk->bshk", xn, lp["wk"])
        v = qeinsum("bsd,dhk->bshk", xn, lp["wv"])
        q = apply_rope(q, positions, theta=cfg.rope_theta)
        k = apply_rope(k, positions, theta=cfg.rope_theta)
        pk, pv = dyn(kc, i), dyn(vc, i)
        if int8_kv:
            psk, psv = dyn(ksc, i), dyn(vsc, i)
            pk, psk = _write_chunk_paged_q(pk, psk, k[0], start[0], length[0],
                                           page_row)
            pv, psv = _write_chunk_paged_q(pv, psv, v[0], start[0], length[0],
                                           page_row)
        else:
            pk = _write_chunk_paged(pk, k[0], start[0], length[0], page_row)
            pv = _write_chunk_paged(pv, v[0], start[0], length[0], page_row)
        qg = q.reshape(b, C, kvp, gp, cfg.head_dim)
        o = attn_mod.chunk_attention_paged(
            qg, pk, pv, page_row[None], start, kv_len=start + length,
            scale=scale,
            k_scale=psk if int8_kv else None,
            v_scale=psv if int8_kv else None)
        o = o.reshape(b, C, cfg.n_heads_padded, cfg.head_dim) * hm
        h = h + qeinsum("bshk,hkd->bsd", o, lp["wo"])
        xn = rms_norm(h, lp["cross_norm"])
        cq = qeinsum("bsd,dhk->bshk", xn, lp["cwq"])
        ckx, cvx = _expand_kv(ck.astype(x.dtype), cv.astype(x.dtype), cfg)
        qp = cq[:, :, :, None, :]
        co = attn_mod.attention(qp, ckx, cvx, causal=False, scale=scale,
                                impl=opts.attn_impl, q_chunk=opts.q_chunk,
                                kv_chunk=opts.kv_chunk,
                                unroll=opts.unroll_scans)
        co = co[:, :, :, 0, :] * hm
        h = h + qeinsum("bshk,hkd->bsd", co, lp["cwo"])
        hn = rms_norm(h, lp["ffn_norm"])
        ff = act(qeinsum("bsd,df->bsf", hn, lp["w1"])) \
            * qeinsum("bsd,df->bsf", hn, lp["w3"])
        h = h + qeinsum("bsf,fd->bsd", ff, lp["w2"])
        kc = jax.lax.dynamic_update_index_in_dim(kc, pk, i, 0)
        vc = jax.lax.dynamic_update_index_in_dim(vc, pv, i, 0)
        if int8_kv:
            ksc = jax.lax.dynamic_update_index_in_dim(ksc, psk, i, 0)
            vsc = jax.lax.dynamic_update_index_in_dim(vsc, psv, i, 0)
            return (h, kc, vc, ksc, vsc), None
        return (h, kc, vc), None

    from repro.models.common import scan_or_unroll
    init = (x, cache["k"], cache["v"])
    if int8_kv:
        init = init + (cache["ks"], cache["vs"])
    carry, _ = scan_or_unroll(
        body, init, (params["dec"], ck_s, cv_s, jnp.arange(cfg.n_dec_layers)),
        unroll=opts.unroll_scans)
    new_cache = dict(cache, k=carry[1], v=carry[2])
    if int8_kv:
        new_cache["ks"], new_cache["vs"] = carry[3], carry[4]
    return new_cache


def prefill(params, batch, cfg, opts: ExecOptions):
    enc_out = encode(params, batch["frames"], cfg, opts)
    hidden, cache = decode_stack(params, batch["tokens"], cfg, opts, enc_out,
                                 mode="prefill", kv_round=_kv_round_of(batch))
    logits = jnp.einsum("bsd,vd->bsv", hidden[:, -1:, :],
                        lm_head_weights(params, cfg)).astype(jnp.float32)
    b, s = batch["tokens"].shape
    cache = dict(cache, pos=jnp.full((b,), s, jnp.int32))
    return logits, cache


def decode_step(params, batch, cache, cfg, opts: ExecOptions):
    """Self KV rides the scan carry (in-place DUS); cross K/V are read-only
    xs (no ys re-emission) — avoids double-buffering either cache."""
    positions = cache["pos"]
    page_table = cache.get("page_table")
    int8_kv = "ks" in cache
    x = embed_tokens(params, batch["tokens"], cfg, opts)

    def dyn(t, i):
        return jax.lax.dynamic_index_in_dim(t, i, 0, keepdims=False)

    def body(carry, xs):
        (h, kc, vc, ksc, vsc) = carry if int8_kv else (*carry, None, None)
        lp, ck, cv, i = xs
        layer_cache = {"k": dyn(kc, i), "v": dyn(vc, i), "ck": ck, "cv": cv}
        if int8_kv:
            layer_cache["ks"], layer_cache["vs"] = dyn(ksc, i), dyn(vsc, i)
        if page_table is not None:
            layer_cache["page_table"] = page_table
        h, new_cache = _dec_layer(h, lp, cfg, opts, positions[:, None],
                                  None, "decode", layer_cache)
        kc = jax.lax.dynamic_update_index_in_dim(kc, new_cache["k"], i, 0)
        vc = jax.lax.dynamic_update_index_in_dim(vc, new_cache["v"], i, 0)
        if int8_kv:
            ksc = jax.lax.dynamic_update_index_in_dim(ksc, new_cache["ks"], i, 0)
            vsc = jax.lax.dynamic_update_index_in_dim(vsc, new_cache["vs"], i, 0)
            return (h, kc, vc, ksc, vsc), None
        return (h, kc, vc), None

    from repro.models.common import scan_or_unroll
    init = (x, cache["k"], cache["v"])
    if int8_kv:
        init = init + (cache["ks"], cache["vs"])
    carry, _ = scan_or_unroll(
        body, init,
        (params["dec"], cache["ck"], cache["cv"],
         jnp.arange(cfg.n_dec_layers)),
        unroll=opts.unroll_scans)
    x, kc, vc = carry[:3]
    x = rms_norm(x, params["final_norm"])
    logits = jnp.einsum("bsd,vd->bsv", x,
                        lm_head_weights(params, cfg)).astype(jnp.float32)
    new_cache = {"k": kc, "v": vc, "ck": cache["ck"], "cv": cache["cv"],
                 "pos": positions + 1}
    if int8_kv:
        new_cache["ks"], new_cache["vs"] = carry[3], carry[4]
    if page_table is not None:
        new_cache["page_table"] = page_table
    return logits, new_cache


def cache_shape(cfg, batch: int, max_len: int, dtype=jnp.bfloat16, *,
                page_size=None, n_pages=None):
    """Self-attention K/V go paged when `page_size` is given (shared sizing
    contract: transformer.paged_kv_shapes); cross K/V stay dense per slot —
    they are written once at prefill at a fixed (cross_len) depth, so paging
    would buy nothing and cost a second table. dtype=jnp.int8 quantizes the
    self-attention K/V only (plus 'ks'/'vs' f16 row scales); cross K/V keep
    f32 — written once, read every step, and a second dequant operand per
    layer would buy back ~cross_len/max_len of the savings at best."""
    L, kv, hd, se = cfg.n_dec_layers, cfg.kv_pad, cfg.head_dim, cfg.cross_len
    cross_dtype = jnp.float32 if dtype == jnp.int8 else dtype
    cross = {
        "ck": jax.ShapeDtypeStruct((L, batch, se, kv, hd), cross_dtype),
        "cv": jax.ShapeDtypeStruct((L, batch, se, kv, hd), cross_dtype),
    }
    if page_size is None:
        self_kv = {
            "k": jax.ShapeDtypeStruct((L, batch, max_len, kv, hd), dtype),
            "v": jax.ShapeDtypeStruct((L, batch, max_len, kv, hd), dtype),
            "pos": jax.ShapeDtypeStruct((batch,), jnp.int32),
        }
        if dtype == jnp.int8:
            for key in ("ks", "vs"):
                self_kv[key] = jax.ShapeDtypeStruct(
                    (L, batch, max_len, kv), SCALE_DTYPE)
    else:
        self_kv = paged_kv_shapes(L, batch, max_len, kv, hd, dtype,
                                  page_size, n_pages)
    return {**self_kv, **cross}
