"""I1 — Adaptive cross-chiplet DVFS controller (paper §II).

Per-chiplet voltage islands driven by on-chip regulators permit nanosecond-scale
P-state changes [16,17]; the controller below is therefore evaluated every
simulator tick. It implements the paper's mechanism:

  1. *Workload-phase prediction*: an EMA of each chiplet's load demand predicts
     the next phase.
  2. *Per-chiplet P-state selection*: the lowest voltage/frequency level whose
     throughput covers the predicted demand.
  3. *Cross-chiplet power redistribution*: if the selected states exceed the SoC
     power budget, the controller downgrades the least-loaded chiplets first;
     if there is headroom, the most-loaded chiplets are boosted (this is the
     "redistributes power through fine-grained voltage islands" behaviour and
     the source of the AI-optimized scenario's clock boost in the closed-form
     model).

Pure JAX — usable inside `lax.scan`, `vmap`, and differentiable w.r.t. the
continuous config parameters.
"""

from __future__ import annotations

import dataclasses
from typing import Tuple

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class DVFSConfig:
    """P-state table + controller gains.

    `voltages`/`freqs` are normalized to the nominal operating point (1.0, 1.0).
    Dynamic power scales ~ v^2 * f; throughput scales ~ f.
    """

    voltages: Tuple[float, ...] = (0.70, 0.76, 0.82, 0.88, 0.94, 1.00, 1.05, 1.10)
    freqs: Tuple[float, ...] = (0.50, 0.60, 0.70, 0.80, 0.90, 1.00, 1.05, 1.10)
    ema_decay: float = 0.8          # phase-prediction smoothing
    power_budget_mw: float = 1100.0  # SoC-level budget the controller enforces
    guard_band: float = 0.05         # demand margin when picking a P-state
    adaptive: bool = True            # False = fixed nominal state (basic chiplet)

    @property
    def n_levels(self) -> int:
        return len(self.voltages)

    def tables(self) -> Tuple[jnp.ndarray, jnp.ndarray]:
        return (
            jnp.asarray(self.voltages, jnp.float32),
            jnp.asarray(self.freqs, jnp.float32),
        )


@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass
class DVFSState:
    level: jnp.ndarray       # (n_chiplets,) int32 current P-state index
    load_ema: jnp.ndarray    # (n_chiplets,) f32 predicted normalized demand
    energy_mj: jnp.ndarray   # () f32 accumulated dynamic energy

    def tree_flatten(self):
        return ((self.level, self.load_ema, self.energy_mj), None)

    @classmethod
    def tree_unflatten(cls, aux, children):
        del aux
        return cls(*children)


def uniform_power_model(n_chiplets: int, peak_dyn_mw: float = 400.0,
                        static_mw: float = 40.0
                        ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Per-chiplet power-model arrays for a fleet of identical NPU chiplets.

    serve/health feeds per-shard serving occupancy through the controller
    with this model (one NPU chiplet per shard), so simulated chiplets
    heat — and boost — with real serving load."""
    return (jnp.full((n_chiplets,), peak_dyn_mw, jnp.float32),
            jnp.full((n_chiplets,), static_mw, jnp.float32))


def init_state(n_chiplets: int, cfg: DVFSConfig) -> DVFSState:
    # pure-python argmin: the P-state table is static config, and staging it
    # through jnp would make init_state unusable inside jit/vmap
    nominal = min(range(len(cfg.freqs)), key=lambda i: abs(cfg.freqs[i] - 1.0))
    return DVFSState(
        level=jnp.full((n_chiplets,), nominal, jnp.int32),
        load_ema=jnp.zeros((n_chiplets,), jnp.float32),
        energy_mj=jnp.zeros((), jnp.float32),
    )


def _chiplet_power(
    level: jnp.ndarray,
    util: jnp.ndarray,
    peak_dyn_mw: jnp.ndarray,
    static_mw: jnp.ndarray,
    volts: jnp.ndarray,
    freqs: jnp.ndarray,
) -> jnp.ndarray:
    v = volts[level]
    f = freqs[level]
    return static_mw + peak_dyn_mw * util * v * v * f


def step(
    state: DVFSState,
    load_demand: jnp.ndarray,
    cfg: DVFSConfig,
    peak_dyn_mw: jnp.ndarray,
    static_mw: jnp.ndarray,
    tick_ms: float,
) -> Tuple[DVFSState, Tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray]]:
    """One controller tick.

    Args:
      load_demand: (n_chiplets,) normalized demand in [0, +inf) — fraction of
        nominal-clock throughput each chiplet must deliver this tick.
      peak_dyn_mw / static_mw: (n_chiplets,) power model per chiplet.

    Returns (new_state, (freq_scale, power_mw, util)) each of shape (n_chiplets,).
    """
    volts, freqs = cfg.tables()
    # `adaptive` may be a traced 0/1 array (vmapped design sweeps) or a plain
    # bool; both P-state policies are computed branchlessly and selected.
    adaptive = jnp.asarray(cfg.adaptive, bool)
    ema = cfg.ema_decay * state.load_ema + (1.0 - cfg.ema_decay) * load_demand
    predicted = ema * (1.0 + cfg.guard_band)

    # Minimal level whose frequency covers predicted demand: freqs is
    # sorted ascending, so take argmax of the first True. Non-adaptive
    # controllers hold the fixed nominal P-state instead.
    ok = freqs[None, :] >= jnp.minimum(predicted, freqs[-1])[:, None]
    level = jnp.where(adaptive,
                      jnp.argmax(ok, axis=-1).astype(jnp.int32), state.level)

    util = jnp.clip(load_demand / jnp.maximum(freqs[level], 1e-6), 0.0, 1.0)
    power = _chiplet_power(level, util, peak_dyn_mw, static_mw, volts, freqs)

    # --- cross-chiplet redistribution (adaptive controllers only) -----------
    total = jnp.sum(power)
    over = total > cfg.power_budget_mw
    # Over budget: scale every chiplet's dynamic-power knob v²·f so the
    # fleet lands on the budget, biased so idle chiplets give up levels
    # first (idle_rank shrinks their target further). g-table is
    # monotone in level, so the target picks a level directly — the
    # ns-scale regulators (paper §II) make per-tick re-leveling realistic.
    g = volts * volts * freqs                       # (n_levels,) ascending
    static_total = jnp.sum(static_mw)
    dyn_total = jnp.maximum(total - static_total, 1e-6)
    scale_dyn = jnp.clip(
        (cfg.power_budget_mw - static_total) / dyn_total, 0.05, 1.0)
    idle_rank = 1.0 - jnp.clip(ema, 0.0, 1.0)
    per_chip_scale = scale_dyn * (1.0 - 0.5 * idle_rank)
    g_target = g[level] * per_chip_scale
    ok_g = g[None, :] <= g_target[:, None]
    level_budget = jnp.maximum(
        jnp.sum(ok_g.astype(jnp.int32), axis=-1) - 1, 0)
    # Boost: spend headroom on the busiest chiplets (paper's AI-optimized
    # latency win). Budget fraction unused -> up to +1 level for loaded dies.
    headroom = jnp.clip(1.0 - total / cfg.power_budget_mw, 0.0, 1.0)
    up = jnp.where(
        (~over) & (ema > 0.7) & (headroom > 0.08),
        1,
        0,
    ).astype(jnp.int32)
    redist = jnp.where(over, jnp.minimum(level, level_budget), level + up)
    redist = jnp.clip(redist, 0, cfg.n_levels - 1)
    level = jnp.where(adaptive, redist, level)
    util = jnp.clip(load_demand / jnp.maximum(freqs[level], 1e-6), 0.0, 1.0)
    power = _chiplet_power(level, util, peak_dyn_mw, static_mw, volts, freqs)

    new_state = DVFSState(
        level=level,
        load_ema=ema,
        energy_mj=state.energy_mj + jnp.sum(power) * tick_ms / 1000.0,
    )
    return new_state, (freqs[level], power, util)
