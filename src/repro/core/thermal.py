"""I4 — RC thermal network + sensor-driven predictive load migration (paper §II).

Each chiplet is one RC node (Cauer-style compact model, cf. HotSpot [14]):

    C_i dT_i/dt = P_i - (T_i - T_amb)/R_i + sum_j G_ij (T_j - T_i)

with G the interposer lateral-coupling conductance matrix. Forward-Euler
integration per simulator tick (ticks are 0.1 ms, far below the thermal time
constants R*C ~ 10-100 ms, so Euler is stable and accurate).

The paper's predictive policy: per-chiplet sensors extrapolate T over a horizon
h; when an NPU's *predicted* temperature crosses T_migrate, a fraction of its
load shifts to the cooler NPU chiplet *before* any derating is needed.
Reactive designs instead clip the clock once T crosses T_throttle.
"""

from __future__ import annotations

import dataclasses
from typing import Tuple

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class ThermalConfig:
    r_k_per_w: Tuple[float, ...]       # per-chiplet junction->ambient resistance
    c_j_per_k: Tuple[float, ...]       # per-chiplet thermal capacitance
    coupling_w_per_k: float = 0.05     # lateral interposer conductance (uniform)
    t_ambient_c: float = 45.0
    t_throttle_c: float = 95.0         # reactive derating point
    t_critical_c: float = 105.0
    t_migrate_c: float = 88.0          # predictive migration point
    predict_horizon_ms: float = 5.0
    migrate_fraction: float = 0.25     # load moved per migration event
    predictive: bool = True            # False = reactive throttling only

    def arrays(self) -> Tuple[jnp.ndarray, jnp.ndarray]:
        return (
            jnp.asarray(self.r_k_per_w, jnp.float32),
            jnp.asarray(self.c_j_per_k, jnp.float32),
        )


@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass
class ThermalState:
    temp_c: jnp.ndarray        # (n_chiplets,)
    migrations: jnp.ndarray    # () int32 cumulative migration events
    throttle_ticks: jnp.ndarray  # () int32 ticks spent derated

    def tree_flatten(self):
        return ((self.temp_c, self.migrations, self.throttle_ticks), None)

    @classmethod
    def tree_unflatten(cls, aux, children):
        del aux
        return cls(*children)


def init_state(cfg: ThermalConfig) -> ThermalState:
    n = len(cfg.r_k_per_w)
    return ThermalState(
        temp_c=jnp.full((n,), cfg.t_ambient_c, jnp.float32),
        migrations=jnp.zeros((), jnp.int32),
        throttle_ticks=jnp.zeros((), jnp.int32),
    )


def _dTdt(temp: jnp.ndarray, power_w: jnp.ndarray, cfg: ThermalConfig) -> jnp.ndarray:
    r, c = cfg.arrays()
    n = temp.shape[0]
    # Uniform lateral coupling: each pair exchanges G*(Tj - Ti).
    lateral = cfg.coupling_w_per_k * (jnp.sum(temp) - n * temp)
    return (power_w - (temp - cfg.t_ambient_c) / r + lateral) / c


def predict(state: ThermalState, power_mw: jnp.ndarray, cfg: ThermalConfig,
            tick_ms: float) -> jnp.ndarray:
    """Per-chiplet SENSOR reading: next-tick temperature plus the linear
    extrapolation over the predictive horizon — the value the paper's
    migration policy (and serve/health's shard state machine) act on,
    exposed separately from `step` so a serving-side health monitor can
    read the sensors without advancing the RC state."""
    deriv = _dTdt(state.temp_c, power_mw / 1e3, cfg)
    temp = state.temp_c + deriv * (tick_ms / 1e3)
    return temp + deriv * (cfg.predict_horizon_ms / 1e3)


def step(
    state: ThermalState,
    power_mw: jnp.ndarray,
    npu_mask: jnp.ndarray,
    npu_load: jnp.ndarray,
    cfg: ThermalConfig,
    tick_ms: float,
) -> Tuple[ThermalState, Tuple[jnp.ndarray, jnp.ndarray]]:
    """One thermal tick.

    Args:
      power_mw: (n,) per-chiplet power this tick.
      npu_mask: (n,) bool — which chiplets are NPUs (migration candidates).
      npu_load: (n,) current normalized load per chiplet (NPUs carry the AI work).

    Returns (state, (clock_scale, new_npu_load)):
      clock_scale: (n,) thermal derating multiplier in (0, 1];
      new_npu_load: (n,) load after any predictive migration.
    """
    dt_s = tick_ms / 1e3
    deriv = _dTdt(state.temp_c, power_mw / 1e3, cfg)
    temp = state.temp_c + deriv * dt_s

    # --- predictive migration (I4) -------------------------------------------
    predicted = temp + deriv * (cfg.predict_horizon_ms / 1e3)
    hot = npu_mask & (predicted > cfg.t_migrate_c) & (npu_load > 0.0)
    any_hot = jnp.any(hot) & jnp.asarray(cfg.predictive)
    # Donor: hottest loaded NPU. Receiver: coolest NPU (can be same if only one).
    npu_temp = jnp.where(npu_mask, predicted, -jnp.inf)
    donor = jnp.argmax(jnp.where(hot, npu_temp, -jnp.inf))
    recv_temp = jnp.where(npu_mask, predicted, jnp.inf)
    receiver = jnp.argmin(recv_temp)
    do_migrate = any_hot & (receiver != donor)
    moved = jnp.where(do_migrate, npu_load[donor] * cfg.migrate_fraction, 0.0)
    new_load = npu_load.at[donor].add(-moved).at[receiver].add(moved)

    # --- reactive derating (what basic/poor integration fall back to) --------
    over = jnp.clip(
        (temp - cfg.t_throttle_c) / (cfg.t_critical_c - cfg.t_throttle_c),
        0.0,
        1.0,
    )
    clock_scale = 1.0 - 0.5 * over  # linear derate, floor at 0.5x
    throttled = jnp.any(over > 0.0)

    return (
        ThermalState(
            temp_c=temp,
            migrations=state.migrations + do_migrate.astype(jnp.int32),
            throttle_ticks=state.throttle_ticks + throttled.astype(jnp.int32),
        ),
        (clock_scale, new_load),
    )
