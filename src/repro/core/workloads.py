"""AI workload models — Table II of the paper.

Each workload is an edge-inference task characterized by:
  base_compute_ms    — single-image NPU compute time at nominal clock
  input_size_mb      — activation payload moved across the die-to-die link per image
  complexity_factor  — architecture complexity multiplier on compute time
  batch_efficiency   — how well throughput amortizes with batch (1.0 = perfect)
  gops_per_inference — operations per inference used by the paper's TOPS/W metric
                       (the paper normalizes to 1 GOP for MobileNetV2; see DESIGN.md §2)
"""

from __future__ import annotations

import dataclasses
from typing import Dict

import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class Workload:
    name: str
    base_compute_ms: float
    input_size_mb: float
    complexity_factor: float
    batch_efficiency: float
    gops_per_inference: float = 1.0
    realtime_deadline_ms: float = 5.0  # the paper's sub-5 ms requirement

    def as_vector(self) -> jnp.ndarray:
        return jnp.array(
            [
                self.base_compute_ms,
                self.input_size_mb,
                self.complexity_factor,
                self.batch_efficiency,
                self.gops_per_inference,
            ],
            dtype=jnp.float32,
        )


MOBILENET_V2 = Workload(
    name="mobilenetv2",
    base_compute_ms=3.5,
    input_size_mb=0.57,
    complexity_factor=0.8,
    batch_efficiency=0.85,
    gops_per_inference=1.0,
)

RESNET_50 = Workload(
    name="resnet50",
    base_compute_ms=12.0,
    input_size_mb=0.57,
    complexity_factor=1.2,
    batch_efficiency=0.90,
    # ResNet-50 is ~4.1 GMACs ≈ 8.2 GOPs; the paper's TOPS/W figure is only
    # quoted for MobileNetV2 so this constant never enters a paper-claim check.
    gops_per_inference=8.2,
)

REALTIME_VIDEO = Workload(
    name="realtime_video",
    base_compute_ms=2.0,
    input_size_mb=0.30,
    complexity_factor=1.0,
    batch_efficiency=0.70,
    gops_per_inference=0.6,
)

WORKLOADS: Dict[str, Workload] = {
    w.name: w for w in (MOBILENET_V2, RESNET_50, REALTIME_VIDEO)
}

WORKLOAD_ORDER = ("mobilenetv2", "resnet50", "realtime_video")


def get_workload(name: str) -> Workload:
    try:
        return WORKLOADS[name]
    except KeyError as e:
        raise KeyError(
            f"unknown workload {name!r}; available: {sorted(WORKLOADS)}"
        ) from e
