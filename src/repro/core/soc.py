"""Time-stepped chiplet SoC simulator — composes I1 (DVFS), I2 (UCIe),
I3 (security), I4 (thermal/migration) over the paper's floorplan.

The paper's SoC (Fig 1): on a 30x30 mm interposer,
  * 5x5 mm  7 nm RISC-V CPU chiplet (custom vector extensions)
  * 2x 6x4 mm 5 nm NPU chiplets, 15 TOPS INT8 each
  * 16 GB HBM3 stack (819 GB/s)
  * 7x3 mm I/O + power-management chiplet
  * 3x2 mm security controller

`simulate()` runs a `lax.scan` over fixed ticks (default 0.1 ms): requests
arrive, their activations cross the UCIe link (compressed/streamed per
scenario, AEAD-sealed per the security config), the CPU dispatches work across
the two NPUs, the DVFS controller retunes per-chiplet P-states, and the RC
thermal network integrates — migrating load off a hot NPU when the predictor
fires. The closed-form model (perf_model.py) is the calibrated summary of this
machine; tests assert the two agree on steady-state throughput.
"""

from __future__ import annotations

import dataclasses
from typing import Dict, Tuple

import jax
import jax.numpy as jnp

from repro.core import dvfs as dvfs_mod
from repro.core import thermal as thermal_mod
from repro.core import ucie as ucie_mod
from repro.core.perf_model import ALPHA
from repro.core.scenarios import Scenario
from repro.core.security import SecurityConfig, aead_overhead, attestation_latency_us
from repro.core.workloads import Workload


@dataclasses.dataclass(frozen=True)
class ChipletSpec:
    name: str
    kind: str                  # cpu | npu | mem | io | sec
    area_mm2: float
    peak_dyn_mw: float
    static_mw: float
    r_k_per_w: float
    c_j_per_k: float


def paper_floorplan(scenario: Scenario) -> Tuple[ChipletSpec, ...]:
    """The paper's five-chiplet SoC, with the scenario's power envelope split
    across dies (NPUs dominate; ratios follow the floorplan areas and node
    maturity). Static share follows Table I's static_power_ratio."""
    p0 = scenario.base_power_mw
    st = scenario.static_power_ratio
    # dynamic-share split: cpu .20, npu .30 each, mem .12, io .06, sec .02
    shares = {"cpu": 0.20, "npu0": 0.30, "npu1": 0.30, "hbm": 0.12, "io": 0.06,
              "sec": 0.02}
    dyn = p0 * (1.0 - st)
    stat = p0 * st
    mk = lambda n, k, a, r, c: ChipletSpec(  # noqa: E731
        n, k, a, dyn * shares[n], stat * shares[n], r, c
    )
    return (
        mk("cpu", "cpu", 25.0, 9.0, 0.9),
        mk("npu0", "npu", 24.0, 8.0, 0.8),
        mk("npu1", "npu", 24.0, 8.0, 0.8),
        mk("hbm", "mem", 121.0, 6.0, 3.0),
        mk("io", "io", 21.0, 12.0, 0.7),
        mk("sec", "sec", 6.0, 20.0, 0.3),
    )


@dataclasses.dataclass(frozen=True)
class SoCConfig:
    scenario: Scenario
    chiplets: Tuple[ChipletSpec, ...]
    ucie: ucie_mod.UCIeConfig
    dvfs: dvfs_mod.DVFSConfig
    thermal: thermal_mod.ThermalConfig
    security: SecurityConfig
    tick_ms: float = 0.1


def build_soc(scenario: Scenario, *, security: bool = True) -> SoCConfig:
    chiplets = paper_floorplan(scenario)
    bw = scenario.link_bandwidth_gbps
    mono = scenario.is_monolithic
    return SoCConfig(
        scenario=scenario,
        chiplets=chiplets,
        ucie=ucie_mod.UCIeConfig(
            bandwidth_gbps=1e6 if mono else bw,
            latency_us=scenario.link_latency_us,
            streaming=scenario.prefetch_overlap,
            compression_ratio=scenario.compression_ratio,
        ),
        dvfs=dvfs_mod.DVFSConfig(
            power_budget_mw=scenario.base_power_mw,
            adaptive=scenario.dvfs_adaptive,
        ),
        thermal=thermal_mod.ThermalConfig(
            r_k_per_w=tuple(c.r_k_per_w for c in chiplets),
            c_j_per_k=tuple(c.c_j_per_k for c in chiplets),
            predictive=scenario.dvfs_adaptive,
        ),
        security=SecurityConfig(enabled=security and not mono),
        tick_ms=0.1,
    )


@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass
class SimState:
    dvfs: dvfs_mod.DVFSState
    thermal: thermal_mod.ThermalState
    link: ucie_mod.LinkState
    npu_queue_ms: jnp.ndarray     # (n_chiplets,) work queued per die (NPU slots used)
    staged_images: jnp.ndarray    # () images whose activations crossed the link
    completed: jnp.ndarray        # () f32 images finished
    busy_ms: jnp.ndarray          # () cumulative NPU busy time
    energy_mj: jnp.ndarray        # () total SoC energy
    queue_integral: jnp.ndarray   # () sum of queue depth (Little's-law latency)

    def tree_flatten(self):
        return (
            tuple(getattr(self, f.name) for f in dataclasses.fields(self)),
            None,
        )

    @classmethod
    def tree_unflatten(cls, aux, children):
        del aux
        return cls(*children)


def _init_state(soc: SoCConfig) -> SimState:
    n = len(soc.chiplets)
    z = jnp.zeros((), jnp.float32)
    return SimState(
        dvfs=dvfs_mod.init_state(n, soc.dvfs),
        thermal=thermal_mod.init_state(soc.thermal),
        link=ucie_mod.init_link(),
        npu_queue_ms=jnp.zeros((n,), jnp.float32),
        staged_images=z,
        completed=z,
        busy_ms=z,
        energy_mj=z,
        queue_integral=z,
    )


def simulate(
    soc: SoCConfig,
    workload: Workload,
    *,
    arrival_rate_ips: float,
    duration_ms: float = 200.0,
) -> Dict[str, jnp.ndarray]:
    """Run the SoC against a steady request stream; return summary metrics."""
    sc = soc.scenario
    n = len(soc.chiplets)
    npu_mask = jnp.asarray([c.kind == "npu" for c in soc.chiplets])
    n_npu = int(npu_mask.sum())
    peak_dyn = jnp.asarray([c.peak_dyn_mw for c in soc.chiplets], jnp.float32)
    static = jnp.asarray([c.static_mw for c in soc.chiplets], jnp.float32)

    # Per-image NPU compute cost at nominal clock (same calibration as the
    # closed-form model; ALPHA folds ISA/runtime overheads into NPU-ms).
    img_ms = ALPHA * workload.base_compute_ms * workload.complexity_factor \
        * sc.efficiency_factor
    img_bytes = workload.input_size_mb * 1e6
    ticks = int(round(duration_ms / soc.tick_ms))
    arrivals_per_tick = arrival_rate_ips * soc.tick_ms / 1e3

    def tick_fn(state: SimState, _):
        # --- I2/I3: activations cross the UCIe link (AEAD-sealed) ------------
        payload = arrivals_per_tick * img_bytes
        link, (drained, occupancy) = ucie_mod.link_tick(
            state.link, payload, soc.ucie, soc.tick_ms
        )
        aead_t, aead_e = aead_overhead(payload, soc.security)
        # protocol overhead stretches effective service (Table I column)
        staged = state.staged_images + drained / jnp.maximum(
            img_bytes * soc.ucie.compression_ratio
            / ucie_mod.protocol_efficiency(jnp.asarray(1.0 if soc.ucie.streaming else 0.0)),
            1.0,
        ) / sc.protocol_overhead

        # --- CPU dispatch: stage ready images onto the shorter NPU queue -----
        ready = staged - state.completed - (
            jnp.sum(state.npu_queue_ms * npu_mask) / img_ms
        )
        ready = jnp.maximum(ready, 0.0)
        npu_q = state.npu_queue_ms
        # split across NPUs inversely to queue depth
        qd = jnp.where(npu_mask, npu_q, jnp.inf)
        inv = jnp.where(npu_mask, 1.0 / (1.0 + qd), 0.0)
        frac = inv / jnp.maximum(jnp.sum(inv), 1e-9)
        npu_q = npu_q + frac * ready * img_ms

        # --- I1: DVFS picks per-chiplet P-states ------------------------------
        demand = jnp.where(
            npu_mask,
            jnp.clip(npu_q / (n_npu * img_ms), 0.0, 1.2),
            occupancy * (~npu_mask),
        )
        dvfs_state, (freq, power_mw, util) = dvfs_mod.step(
            state.dvfs, demand, soc.dvfs, peak_dyn, static, soc.tick_ms
        )

        # --- I4: thermal integrate + predictive migration ---------------------
        thermal_state, (clock, npu_q) = thermal_mod.step(
            state.thermal, power_mw, npu_mask, npu_q, soc.thermal, soc.tick_ms
        )

        # --- service ----------------------------------------------------------
        service = jnp.where(npu_mask, soc.tick_ms * freq * clock, 0.0)
        done_ms = jnp.minimum(npu_q, service)
        npu_q = npu_q - done_ms
        completed = state.completed + jnp.sum(done_ms) / img_ms
        busy = state.busy_ms + jnp.sum(done_ms)

        energy = (
            state.energy_mj
            + jnp.sum(power_mw) * soc.tick_ms / 1e3
            + aead_e
        )
        queue_integral = state.queue_integral + jnp.sum(npu_q) / img_ms

        new_state = SimState(
            dvfs=dvfs_state,
            thermal=thermal_state,
            link=link,
            npu_queue_ms=npu_q,
            staged_images=staged,
            completed=completed,
            busy_ms=busy,
            energy_mj=energy,
            queue_integral=queue_integral,
        )
        obs = (jnp.max(thermal_state.temp_c), jnp.sum(power_mw))
        return new_state, obs

    state0 = _init_state(soc)
    final, (temps, powers) = jax.lax.scan(tick_fn, state0, None, length=ticks)

    dur_s = duration_ms / 1e3
    throughput = final.completed / dur_s
    avg_queue = final.queue_integral / ticks
    # Little's law + link/attestation offsets for end-to-end latency.
    latency_ms = (
        jnp.where(throughput > 0, avg_queue / (throughput / 1e3), 0.0)
        + img_ms
        + (0.0 if sc.prefetch_overlap else ucie_mod.transfer(
            jnp.asarray(img_bytes, jnp.float32), soc.ucie)[0] / 1e3)
    )
    return {
        "throughput_ips": throughput,
        "latency_ms": latency_ms,
        "avg_power_mw": jnp.mean(powers),
        "peak_temp_c": jnp.max(temps),
        "energy_mj": final.energy_mj,
        "energy_mj_per_inf": final.energy_mj / jnp.maximum(final.completed, 1.0),
        "migrations": final.thermal.migrations,
        "throttle_ticks": final.thermal.throttle_ticks,
        "attestation_us": attestation_latency_us(n, soc.security),
        "completed": final.completed,
        "npu_utilization": final.busy_ms / (n_npu * duration_ms),
    }
