"""Time-stepped chiplet SoC simulator — composes I1 (DVFS), I2 (UCIe),
I3 (security), I4 (thermal/migration) over the paper's floorplan.

The paper's SoC (Fig 1): on a 30x30 mm interposer,
  * 5x5 mm  7 nm RISC-V CPU chiplet (custom vector extensions)
  * 2x 6x4 mm 5 nm NPU chiplets, 15 TOPS INT8 each
  * 16 GB HBM3 stack (819 GB/s)
  * 7x3 mm I/O + power-management chiplet
  * 3x2 mm security controller

`simulate()` runs a `lax.scan` over fixed ticks (default 0.1 ms): requests
arrive, their activations cross the UCIe link (compressed/streamed per
scenario, AEAD-sealed per the security config), the CPU dispatches work across
the two NPUs, the DVFS controller retunes per-chiplet P-states, and the RC
thermal network integrates — migrating load off a hot NPU when the predictor
fires. The closed-form model (perf_model.py) is the calibrated summary of this
machine; tests assert the two agree on steady-state throughput.
"""

from __future__ import annotations

import dataclasses
import functools
from typing import Dict, Sequence, Tuple

import jax
import jax.numpy as jnp

from repro.core import dvfs as dvfs_mod
from repro.core import thermal as thermal_mod
from repro.core import ucie as ucie_mod
from repro.core.perf_model import ALPHA
from repro.core.scenarios import Scenario
from repro.core.security import SecurityConfig, aead_overhead, attestation_latency_us
from repro.core.workloads import Workload


@dataclasses.dataclass(frozen=True)
class ChipletSpec:
    name: str
    kind: str                  # cpu | npu | mem | io | sec
    area_mm2: float
    peak_dyn_mw: float
    static_mw: float
    r_k_per_w: float
    c_j_per_k: float


def paper_floorplan(scenario: Scenario) -> Tuple[ChipletSpec, ...]:
    """The paper's five-chiplet SoC, with the scenario's power envelope split
    across dies (NPUs dominate; ratios follow the floorplan areas and node
    maturity). Static share follows Table I's static_power_ratio."""
    p0 = scenario.base_power_mw
    st = scenario.static_power_ratio
    # dynamic-share split: cpu .20, npu .30 each, mem .12, io .06, sec .02
    shares = {"cpu": 0.20, "npu0": 0.30, "npu1": 0.30, "hbm": 0.12, "io": 0.06,
              "sec": 0.02}
    dyn = p0 * (1.0 - st)
    stat = p0 * st
    mk = lambda n, k, a, r, c: ChipletSpec(  # noqa: E731
        n, k, a, dyn * shares[n], stat * shares[n], r, c
    )
    return (
        mk("cpu", "cpu", 25.0, 9.0, 0.9),
        mk("npu0", "npu", 24.0, 8.0, 0.8),
        mk("npu1", "npu", 24.0, 8.0, 0.8),
        mk("hbm", "mem", 121.0, 6.0, 3.0),
        mk("io", "io", 21.0, 12.0, 0.7),
        mk("sec", "sec", 6.0, 20.0, 0.3),
    )


@dataclasses.dataclass(frozen=True)
class SoCConfig:
    scenario: Scenario
    chiplets: Tuple[ChipletSpec, ...]
    ucie: ucie_mod.UCIeConfig
    dvfs: dvfs_mod.DVFSConfig
    thermal: thermal_mod.ThermalConfig
    security: SecurityConfig
    tick_ms: float = 0.1


def build_soc(scenario: Scenario, *, security: bool = True) -> SoCConfig:
    chiplets = paper_floorplan(scenario)
    bw = scenario.link_bandwidth_gbps
    mono = scenario.is_monolithic
    return SoCConfig(
        scenario=scenario,
        chiplets=chiplets,
        ucie=ucie_mod.UCIeConfig(
            bandwidth_gbps=1e6 if mono else bw,
            latency_us=scenario.link_latency_us,
            streaming=scenario.prefetch_overlap,
            compression_ratio=scenario.compression_ratio,
        ),
        dvfs=dvfs_mod.DVFSConfig(
            power_budget_mw=scenario.base_power_mw,
            adaptive=scenario.dvfs_adaptive,
        ),
        thermal=thermal_mod.ThermalConfig(
            r_k_per_w=tuple(c.r_k_per_w for c in chiplets),
            c_j_per_k=tuple(c.c_j_per_k for c in chiplets),
            predictive=scenario.dvfs_adaptive,
        ),
        security=SecurityConfig(enabled=security and not mono),
        tick_ms=0.1,
    )


@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass
class SimState:
    dvfs: dvfs_mod.DVFSState
    thermal: thermal_mod.ThermalState
    link: ucie_mod.LinkState
    npu_queue_ms: jnp.ndarray     # (n_chiplets,) work queued per die (NPU slots used)
    staged_images: jnp.ndarray    # () images whose activations crossed the link
    completed: jnp.ndarray        # () f32 images finished
    busy_ms: jnp.ndarray          # () cumulative NPU busy time
    energy_mj: jnp.ndarray        # () total SoC energy
    queue_integral: jnp.ndarray   # () sum of queue depth (Little's-law latency)

    def tree_flatten(self):
        return (
            tuple(getattr(self, f.name) for f in dataclasses.fields(self)),
            None,
        )

    @classmethod
    def tree_unflatten(cls, aux, children):
        del aux
        return cls(*children)


# ---------------------------------------------------------------------------
# Vmappable parameter encoding
# ---------------------------------------------------------------------------

@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass
class SoCParams:
    """The numeric leaves of one SoC design point.

    Everything `simulate` reads from Python objects (ChipletSpec fields,
    scenario scalars, I1–I4 feature flags) lifted into arrays, so the
    time-stepped simulator becomes a pure function of (SoCParams, arrival
    rate) and `jax.vmap` sweeps whole design spaces in one compiled program
    (the Chiplet-Gym / Chiplet Actuary use case). Boolean mechanisms are
    0/1 floats consumed branchlessly downstream.
    """

    peak_dyn_mw: jnp.ndarray        # (n_chiplets,)
    static_mw: jnp.ndarray          # (n_chiplets,)
    r_k_per_w: jnp.ndarray          # (n_chiplets,)
    c_j_per_k: jnp.ndarray          # (n_chiplets,)
    ucie_bandwidth_gbps: jnp.ndarray
    ucie_latency_us: jnp.ndarray
    ucie_streaming: jnp.ndarray     # 0/1
    ucie_compression_ratio: jnp.ndarray
    dvfs_budget_mw: jnp.ndarray
    dvfs_adaptive: jnp.ndarray      # 0/1
    thermal_predictive: jnp.ndarray  # 0/1
    sec_enabled: jnp.ndarray        # 0/1
    efficiency_factor: jnp.ndarray
    protocol_overhead: jnp.ndarray
    prefetch_overlap: jnp.ndarray   # 0/1

    def tree_flatten(self):
        return (
            tuple(getattr(self, f.name) for f in dataclasses.fields(self)),
            None,
        )

    @classmethod
    def tree_unflatten(cls, aux, children):
        del aux
        return cls(*children)


def soc_params(soc: SoCConfig) -> SoCParams:
    """Lift a SoCConfig's Python-side reads into the array encoding."""
    f32 = lambda x: jnp.asarray(x, jnp.float32)  # noqa: E731
    sc = soc.scenario
    return SoCParams(
        peak_dyn_mw=f32([c.peak_dyn_mw for c in soc.chiplets]),
        static_mw=f32([c.static_mw for c in soc.chiplets]),
        r_k_per_w=f32([c.r_k_per_w for c in soc.chiplets]),
        c_j_per_k=f32([c.c_j_per_k for c in soc.chiplets]),
        ucie_bandwidth_gbps=f32(soc.ucie.bandwidth_gbps),
        ucie_latency_us=f32(soc.ucie.latency_us),
        ucie_streaming=f32(soc.ucie.streaming),
        ucie_compression_ratio=f32(soc.ucie.compression_ratio),
        dvfs_budget_mw=f32(soc.dvfs.power_budget_mw),
        dvfs_adaptive=f32(soc.dvfs.adaptive),
        thermal_predictive=f32(soc.thermal.predictive),
        sec_enabled=f32(soc.security.enabled),
        efficiency_factor=f32(sc.efficiency_factor),
        protocol_overhead=f32(sc.protocol_overhead),
        prefetch_overlap=f32(sc.prefetch_overlap),
    )


StaticConfigs = Tuple[ucie_mod.UCIeConfig, dvfs_mod.DVFSConfig,
                      thermal_mod.ThermalConfig, SecurityConfig]


def _static_residual(soc: SoCConfig) -> StaticConfigs:
    """The sub-config fields `soc_params` does NOT lift (P-state tables,
    link energy constants, thermal trip points, AEAD costs, ...), with the
    lifted fields normalized out. Hashable — keys the sweep jit cache and
    re-seeds `_configs_from_params` so custom configs are honored."""
    return (
        dataclasses.replace(soc.ucie, bandwidth_gbps=0.0, latency_us=0.0,
                            streaming=False, compression_ratio=0.0),
        dataclasses.replace(soc.dvfs, power_budget_mw=0.0, adaptive=False),
        dataclasses.replace(soc.thermal, r_k_per_w=(), c_j_per_k=(),
                            predictive=False),
        dataclasses.replace(soc.security, enabled=False),
    )


def _configs_from_params(p: SoCParams, static: StaticConfigs):
    """Reconstruct the I1–I4 config objects: (possibly traced) lifted leaves
    over the static residual's remaining fields."""
    ucie_s, dvfs_s, thermal_s, sec_s = static
    ucie = dataclasses.replace(
        ucie_s,
        bandwidth_gbps=p.ucie_bandwidth_gbps,
        latency_us=p.ucie_latency_us,
        streaming=p.ucie_streaming > 0.5,
        compression_ratio=p.ucie_compression_ratio,
    )
    dvfs = dataclasses.replace(
        dvfs_s,
        power_budget_mw=p.dvfs_budget_mw,
        adaptive=p.dvfs_adaptive > 0.5,
    )
    thermal = dataclasses.replace(
        thermal_s,
        r_k_per_w=p.r_k_per_w,
        c_j_per_k=p.c_j_per_k,
        predictive=p.thermal_predictive > 0.5,
    )
    security = dataclasses.replace(sec_s, enabled=p.sec_enabled > 0.5)
    return ucie, dvfs, thermal, security


def _simulate_params(
    p: SoCParams,
    arrival_rate_ips: jnp.ndarray,
    *,
    workload: Workload,
    npu_mask: Tuple[bool, ...],
    static: StaticConfigs,
    ticks: int,
    tick_ms: float,
) -> Dict[str, jnp.ndarray]:
    """Pure-array core of `simulate` — safe under jit/vmap/grad.

    One design point, one arrival rate; `simulate` wraps it for the
    SoCConfig API and `simulate_batch` vmaps it over stacked SoCParams ×
    arrival-rate grids. `npu_mask` and `static` (the non-lifted config
    fields) are static — floorplan topology and e.g. P-state tables are
    structural, not swept.
    """
    ucie_cfg, dvfs_cfg, thermal_cfg, sec_cfg = _configs_from_params(p, static)
    n = p.peak_dyn_mw.shape[0]
    n_npu = sum(npu_mask)
    npu_mask = jnp.asarray(npu_mask)
    duration_ms = ticks * tick_ms

    # Per-image NPU compute cost at nominal clock (same calibration as the
    # closed-form model; ALPHA folds ISA/runtime overheads into NPU-ms).
    img_ms = ALPHA * workload.base_compute_ms * workload.complexity_factor \
        * p.efficiency_factor
    img_bytes = workload.input_size_mb * 1e6
    arrivals_per_tick = arrival_rate_ips * tick_ms / 1e3

    def tick_fn(state: SimState, _):
        # --- I2/I3: activations cross the UCIe link (AEAD-sealed) ------------
        payload = arrivals_per_tick * img_bytes
        link, (drained, occupancy) = ucie_mod.link_tick(
            state.link, payload, ucie_cfg, tick_ms
        )
        aead_t, aead_e = aead_overhead(payload, sec_cfg)
        # protocol overhead stretches effective service (Table I column)
        staged = state.staged_images + drained / jnp.maximum(
            img_bytes * p.ucie_compression_ratio
            / ucie_mod.protocol_efficiency(p.ucie_streaming),
            1.0,
        ) / p.protocol_overhead

        # --- CPU dispatch: stage ready images onto the shorter NPU queue -----
        ready = staged - state.completed - (
            jnp.sum(state.npu_queue_ms * npu_mask) / img_ms
        )
        ready = jnp.maximum(ready, 0.0)
        npu_q = state.npu_queue_ms
        # split across NPUs inversely to queue depth
        qd = jnp.where(npu_mask, npu_q, jnp.inf)
        inv = jnp.where(npu_mask, 1.0 / (1.0 + qd), 0.0)
        frac = inv / jnp.maximum(jnp.sum(inv), 1e-9)
        npu_q = npu_q + frac * ready * img_ms

        # --- I1: DVFS picks per-chiplet P-states ------------------------------
        demand = jnp.where(
            npu_mask,
            jnp.clip(npu_q / (n_npu * img_ms), 0.0, 1.2),
            occupancy * (~npu_mask),
        )
        dvfs_state, (freq, power_mw, util) = dvfs_mod.step(
            state.dvfs, demand, dvfs_cfg, p.peak_dyn_mw, p.static_mw, tick_ms
        )

        # --- I4: thermal integrate + predictive migration ---------------------
        thermal_state, (clock, npu_q) = thermal_mod.step(
            state.thermal, power_mw, npu_mask, npu_q, thermal_cfg, tick_ms
        )

        # --- service ----------------------------------------------------------
        service = jnp.where(npu_mask, tick_ms * freq * clock, 0.0)
        done_ms = jnp.minimum(npu_q, service)
        npu_q = npu_q - done_ms
        completed = state.completed + jnp.sum(done_ms) / img_ms
        busy = state.busy_ms + jnp.sum(done_ms)

        energy = (
            state.energy_mj
            + jnp.sum(power_mw) * tick_ms / 1e3
            + aead_e
        )
        queue_integral = state.queue_integral + jnp.sum(npu_q) / img_ms

        new_state = SimState(
            dvfs=dvfs_state,
            thermal=thermal_state,
            link=link,
            npu_queue_ms=npu_q,
            staged_images=staged,
            completed=completed,
            busy_ms=busy,
            energy_mj=energy,
            queue_integral=queue_integral,
        )
        obs = (jnp.max(thermal_state.temp_c), jnp.sum(power_mw))
        return new_state, obs

    state0 = SimState(
        dvfs=dvfs_mod.init_state(n, dvfs_cfg),
        thermal=thermal_mod.init_state(thermal_cfg),
        link=ucie_mod.init_link(),
        npu_queue_ms=jnp.zeros((n,), jnp.float32),
        staged_images=jnp.zeros((), jnp.float32),
        completed=jnp.zeros((), jnp.float32),
        busy_ms=jnp.zeros((), jnp.float32),
        energy_mj=jnp.zeros((), jnp.float32),
        queue_integral=jnp.zeros((), jnp.float32),
    )
    final, (temps, powers) = jax.lax.scan(tick_fn, state0, None, length=ticks)

    dur_s = duration_ms / 1e3
    throughput = final.completed / dur_s
    avg_queue = final.queue_integral / ticks
    # Little's law + link/attestation offsets for end-to-end latency. A
    # stalled design (zero throughput) reports inf, not 0 — sweeps must never
    # rank it best.
    latency_ms = (
        jnp.where(throughput > 0,
                  avg_queue * 1e3 / jnp.maximum(throughput, 1e-30),
                  jnp.inf)
        + img_ms
        + jnp.where(p.prefetch_overlap > 0.5, 0.0, ucie_mod.transfer(
            jnp.asarray(img_bytes, jnp.float32), ucie_cfg)[0] / 1e3)
    )
    return {
        "throughput_ips": throughput,
        "latency_ms": latency_ms,
        "avg_power_mw": jnp.mean(powers),
        "peak_temp_c": jnp.max(temps),
        "energy_mj": final.energy_mj,
        "energy_mj_per_inf": final.energy_mj / jnp.maximum(final.completed, 1.0),
        "migrations": final.thermal.migrations,
        "throttle_ticks": final.thermal.throttle_ticks,
        "attestation_us": attestation_latency_us(n, sec_cfg),
        "completed": final.completed,
        "npu_utilization": final.busy_ms / (n_npu * duration_ms),
    }


def _npu_mask(soc: SoCConfig) -> Tuple[bool, ...]:
    return tuple(c.kind == "npu" for c in soc.chiplets)


def simulate(
    soc: SoCConfig,
    workload: Workload,
    *,
    arrival_rate_ips: float,
    duration_ms: float = 200.0,
) -> Dict[str, jnp.ndarray]:
    """Run the SoC against a steady request stream; return summary metrics."""
    ticks = int(round(duration_ms / soc.tick_ms))
    return _simulate_params(
        soc_params(soc),
        jnp.asarray(arrival_rate_ips, jnp.float32),
        workload=workload,
        npu_mask=_npu_mask(soc),
        static=_static_residual(soc),
        ticks=ticks,
        tick_ms=soc.tick_ms,
    )


def simulate_batch(
    socs: Sequence[SoCConfig],
    workload: Workload,
    arrival_rates_ips,
    *,
    duration_ms: float = 200.0,
) -> Dict[str, jnp.ndarray]:
    """Sweep scenarios × arrival rates as ONE compiled program.

    vmaps `_simulate_params` over stacked `SoCParams` (outer axis) and the
    arrival-rate grid (inner axis): the full design-space evaluation — every
    integration scenario at every load point — lowers to a single jitted
    call instead of a Python loop of per-point `lax.scan` compilations.

    Args:
      socs: SoC design points; must share floorplan topology (chiplet kinds)
        and tick size — parameters may differ arbitrarily.
      workload: the (static) workload model applied at every grid point.
      arrival_rates_ips: (R,) request rates to sweep.

    Returns the `simulate` metrics dict with every leaf shaped
    (len(socs), R). `latency_ms` is inf wherever a design stalls.
    """
    socs = list(socs)
    assert socs, "simulate_batch needs at least one SoCConfig"
    kinds = tuple(c.kind for c in socs[0].chiplets)
    static = _static_residual(socs[0])
    for s in socs[1:]:
        assert tuple(c.kind for c in s.chiplets) == kinds, \
            "simulate_batch requires a shared floorplan topology"
        assert s.tick_ms == socs[0].tick_ms
        assert _static_residual(s) == static, \
            "simulate_batch sweeps only the lifted SoCParams fields; " \
            "non-lifted config fields (P-state tables, trip points, link " \
            "energy, AEAD costs) must match across designs"
    stacked = jax.tree.map(lambda *xs: jnp.stack(xs),
                           *[soc_params(s) for s in socs])
    rates = jnp.asarray(arrival_rates_ips, jnp.float32).reshape(-1)
    ticks = int(round(duration_ms / socs[0].tick_ms))
    fn = _batch_fn(workload, _npu_mask(socs[0]), static, ticks,
                   socs[0].tick_ms)
    return fn(stacked, rates)


@functools.lru_cache(maxsize=None)
def _batch_fn(workload: Workload, npu_mask: Tuple[bool, ...],
              static: StaticConfigs, ticks: int, tick_ms: float):
    """Compile the scenario×rate sweep once per static configuration —
    repeat `simulate_batch` calls (search loops, benches) hit the jit cache."""
    core = functools.partial(
        _simulate_params,
        workload=workload,
        npu_mask=npu_mask,
        static=static,
        ticks=ticks,
        tick_ms=tick_ms,
    )
    return jax.jit(jax.vmap(jax.vmap(core, in_axes=(None, 0)),
                            in_axes=(0, None)))
