"""Chiplet-aware execution planner.

The paper's system-level thesis — pick the integration/orchestration strategy
from an analytical cost model instead of reacting at runtime — applied to the
TPU-pod framework: given a compiled cell's roofline terms, decide which
optimizations to enable (the "AI-optimized" configuration of this framework).

Used by `launch/roofline.py` for reporting and by `train/governor.py` /
`serve/engine.py` to auto-select the optimized path.
"""

from __future__ import annotations

import dataclasses
from typing import Dict, Optional

# TPU v5e-class hardware constants (per chip), per the assignment brief.
PEAK_FLOPS_BF16 = 197e12       # FLOP/s
PEAK_FLOPS_INT8 = 394e12       # FLOP/s (2x bf16 on the MXU)
HBM_BW = 819e9                 # bytes/s  (same figure as the paper's HBM3 stack)
ICI_BW = 50e9                  # bytes/s per link


@dataclasses.dataclass(frozen=True)
class RooflineTerms:
    """Three-term roofline for one compiled (arch x shape x mesh) cell."""

    flops: float               # total HLO FLOPs for one step
    hbm_bytes: float           # total HLO bytes accessed
    collective_bytes: float    # summed collective operand bytes
    chips: int
    model_flops: float = 0.0   # 6*N*D / 6*N_active*D / 2*N*D (analytic)

    @property
    def compute_s(self) -> float:
        return self.flops / (self.chips * PEAK_FLOPS_BF16)

    @property
    def memory_s(self) -> float:
        return self.hbm_bytes / (self.chips * HBM_BW)

    @property
    def collective_s(self) -> float:
        return self.collective_bytes / (self.chips * ICI_BW)

    @property
    def bound_s(self) -> float:
        return max(self.compute_s, self.memory_s, self.collective_s)

    @property
    def dominant(self) -> str:
        terms = {
            "compute": self.compute_s,
            "memory": self.memory_s,
            "collective": self.collective_s,
        }
        return max(terms, key=terms.get)  # type: ignore[arg-type]

    @property
    def useful_flops_ratio(self) -> float:
        """MODEL_FLOPS / HLO_FLOPs — how much compiled compute is 'useful'.

        <1 flags remat recompute / redundancy; >1 flags fused or rematerialized
        estimates (or analytic undercount, e.g. attention FLOPs not in 6ND).
        """
        return self.model_flops / self.flops if self.flops else 0.0

    @property
    def roofline_fraction(self) -> float:
        """useful-time / bound-time: fraction of the roofline achieved if the
        step runs exactly at its dominant bound."""
        if self.model_flops <= 0:
            return 0.0
        ideal_s = self.model_flops / (self.chips * PEAK_FLOPS_BF16)
        return min(1.0, ideal_s / self.bound_s) if self.bound_s else 0.0

    def summary(self) -> Dict[str, float]:
        return {
            "compute_s": self.compute_s,
            "memory_s": self.memory_s,
            "collective_s": self.collective_s,
            "dominant": self.dominant,  # type: ignore[dict-item]
            "useful_flops_ratio": self.useful_flops_ratio,
            "roofline_fraction": self.roofline_fraction,
        }


@dataclasses.dataclass(frozen=True)
class PlanDecision:
    """Which 'AI-optimized' features the planner turns on, and why."""

    compress_grads: bool
    int8_weights: bool
    remat_policy: str          # none | dots | full
    reason: str

    def as_dict(self) -> Dict[str, object]:
        return dataclasses.asdict(self)


def plan(
    terms: RooflineTerms,
    *,
    is_training: bool,
    hbm_per_chip_bytes: float = 16e9,
    resident_bytes_per_chip: Optional[float] = None,
) -> PlanDecision:
    """Pick the optimized configuration from the dominant roofline term.

    Mirrors the paper's scenario choice: 'basic chiplet' = everything off;
    'AI-optimized' = the features that attack the measured bottleneck.
    """
    dom = terms.dominant
    compress = bool(is_training and dom == "collective")
    int8 = bool(not is_training and dom == "memory")

    if resident_bytes_per_chip is None:
        remat = "dots" if is_training else "none"
        fit_note = ""
    else:
        frac = resident_bytes_per_chip / hbm_per_chip_bytes
        if not is_training:
            remat = "none"
        elif frac > 0.9:
            remat = "full"
        elif frac > 0.5:
            remat = "dots"
        else:
            remat = "none"
        fit_note = f"; residency {frac:.0%} of HBM"

    reason = (
        f"dominant={dom} "
        f"(compute {terms.compute_s:.3e}s, memory {terms.memory_s:.3e}s, "
        f"collective {terms.collective_s:.3e}s){fit_note}"
    )
    return PlanDecision(
        compress_grads=compress,
        int8_weights=int8,
        remat_policy=remat,
        reason=reason,
    )
