"""Core — the paper's contribution: chiplet SoC models and orchestration.

Faithful layer (paper §II-§V):
  scenarios / workloads    Table I / Table II
  perf_model               reconstructed closed-form simulator (Table III, Fig 2)
  dvfs / ucie / thermal / security   innovations I1-I4
  soc                      time-stepped lax.scan SoC simulator

Beyond-paper layer:
  planner                  roofline-driven plan selection for the TPU framework
"""

from repro.core.perf_model import PerfResult, predict, predict_grid, predict_noisy
from repro.core.planner import PlanDecision, RooflineTerms, plan
from repro.core.scenarios import (
    AI_OPTIMIZED,
    BASIC_CHIPLET,
    MONOLITHIC,
    POOR_INTEGRATION,
    SCENARIO_ORDER,
    SCENARIOS,
    Scenario,
    get_scenario,
)
from repro.core.soc import (
    SoCConfig,
    SoCParams,
    build_soc,
    simulate,
    simulate_batch,
    soc_params,
)
from repro.core.workloads import (
    MOBILENET_V2,
    REALTIME_VIDEO,
    RESNET_50,
    WORKLOAD_ORDER,
    WORKLOADS,
    Workload,
    get_workload,
)

__all__ = [
    "AI_OPTIMIZED",
    "BASIC_CHIPLET",
    "MOBILENET_V2",
    "MONOLITHIC",
    "POOR_INTEGRATION",
    "PerfResult",
    "PlanDecision",
    "REALTIME_VIDEO",
    "RESNET_50",
    "RooflineTerms",
    "SCENARIOS",
    "SCENARIO_ORDER",
    "Scenario",
    "SoCConfig",
    "SoCParams",
    "WORKLOADS",
    "WORKLOAD_ORDER",
    "Workload",
    "build_soc",
    "get_scenario",
    "get_workload",
    "plan",
    "predict",
    "predict_grid",
    "predict_noisy",
    "simulate",
    "simulate_batch",
    "soc_params",
]
