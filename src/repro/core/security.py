"""I3 — Distributed chiplet security: AuthenTree-style tree MPC attestation [19].

Two layers:

1. A *cost model* (pure JAX) for the latency/energy the security fabric adds:
   boot-time attestation walks a binary tree of chiplets with one MPC round per
   level (depth = ceil(log2 n)); steady-state traffic pays per-message AEAD
   cost on every UCIe transfer. Used by the time-stepped SoC simulator.

2. A *functional* attestation implementation (pure Python, hashlib) used for
   real artifacts in this framework: a Merkle tree over per-chiplet identity
   digests with HMAC-sealed roots. `train/checkpoint.py` reuses it to seal
   checkpoint shards (the practical analogue of multi-vendor chiplet trust:
   shards written by many hosts, verified on restore).
"""

from __future__ import annotations

import dataclasses
import hashlib
import hmac as _hmac
import math
from typing import Dict, List, Sequence, Tuple

import jax.numpy as jnp

# ---------------------------------------------------------------------------
# 1. Cost model (JAX)
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class SecurityConfig:
    enabled: bool = True
    mpc_round_us: float = 3.0        # one tree-level multi-party round
    aead_us_per_kb: float = 0.04     # AES-GCM line-rate engine cost
    aead_pj_per_byte: float = 2.0
    reattest_period_ms: float = 100.0  # periodic re-attestation


def attestation_latency_us(n_chiplets: int, cfg: SecurityConfig) -> jnp.ndarray:
    """Boot attestation latency: one MPC round per tree level.

    AuthenTree's tree topology gives O(log n) rounds vs O(n) for a centralized
    root-of-trust chain — the paper's scalability argument.

    `cfg.enabled` may be a traced 0/1 array (vmapped sweeps) or a plain bool;
    the cost is gated branchlessly.
    """
    depth = max(1, math.ceil(math.log2(max(n_chiplets, 2))))
    en = jnp.asarray(cfg.enabled, jnp.float32)
    return en * jnp.asarray(depth * cfg.mpc_round_us, jnp.float32)


def centralized_attestation_latency_us(
    n_chiplets: int, cfg: SecurityConfig
) -> jnp.ndarray:
    """The baseline the paper argues against: serial chain through one RoT."""
    en = jnp.asarray(cfg.enabled, jnp.float32)
    return en * jnp.asarray(n_chiplets * cfg.mpc_round_us, jnp.float32)


def aead_overhead(
    payload_bytes: jnp.ndarray, cfg: SecurityConfig
) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """(time_us, energy_mj) for authenticated encryption of one transfer.

    Branchless in `cfg.enabled` so the whole cost model vmaps over designs."""
    en = jnp.asarray(cfg.enabled, jnp.float32)
    p = jnp.asarray(payload_bytes, jnp.float32)
    t = en * p / 1024.0 * cfg.aead_us_per_kb
    e = en * p * cfg.aead_pj_per_byte * 1e-9
    return t, e


# ---------------------------------------------------------------------------
# 2. Functional Merkle attestation (Python, used for checkpoint integrity)
# ---------------------------------------------------------------------------


def _h(data: bytes) -> bytes:
    return hashlib.sha256(data).digest()


def leaf_digest(name: str, payload: bytes) -> bytes:
    """Identity digest of one 'chiplet' (or checkpoint shard)."""
    return _h(b"leaf:" + name.encode() + b":" + _h(payload))


def merkle_root(leaves: Sequence[bytes]) -> bytes:
    """Root of a binary Merkle tree (odd nodes promoted)."""
    if not leaves:
        return _h(b"empty")
    level: List[bytes] = list(leaves)
    while len(level) > 1:
        nxt = []
        for i in range(0, len(level) - 1, 2):
            nxt.append(_h(b"node:" + level[i] + level[i + 1]))
        if len(level) % 2:
            nxt.append(level[-1])
        level = nxt
    return level[0]


def merkle_proof(leaves: Sequence[bytes], index: int) -> List[Tuple[bool, bytes]]:
    """Inclusion proof for leaf `index`: list of (sibling_is_right, digest)."""
    proof: List[Tuple[bool, bytes]] = []
    level = list(leaves)
    idx = index
    while len(level) > 1:
        nxt = []
        for i in range(0, len(level) - 1, 2):
            nxt.append(_h(b"node:" + level[i] + level[i + 1]))
        if len(level) % 2:
            nxt.append(level[-1])
        sib = idx ^ 1
        if sib < len(level) and sib != idx:
            proof.append((sib > idx, level[sib]))
        idx //= 2
        level = nxt
    return proof


def verify_proof(
    leaf: bytes, proof: Sequence[Tuple[bool, bytes]], root: bytes
) -> bool:
    node = leaf
    for sibling_is_right, sib in proof:
        node = _h(b"node:" + (node + sib if sibling_is_right else sib + node))
    return _hmac.compare_digest(node, root)


def seal(root: bytes, key: bytes) -> bytes:
    """HMAC seal over the Merkle root (session key from the MPC handshake)."""
    return _hmac.new(key, b"seal:" + root, hashlib.sha256).digest()


def verify_seal(root: bytes, key: bytes, tag: bytes) -> bool:
    return _hmac.compare_digest(seal(root, key), tag)


def attest_manifest(payloads: Dict[str, bytes], key: bytes) -> Dict[str, str]:
    """Build a sealed attestation manifest over named payloads."""
    names = sorted(payloads)
    leaves = [leaf_digest(n, payloads[n]) for n in names]
    root = merkle_root(leaves)
    return {
        "names": ",".join(names),
        "root": root.hex(),
        "seal": seal(root, key).hex(),
    }


def verify_manifest(
    payloads: Dict[str, bytes], key: bytes, manifest: Dict[str, str]
) -> bool:
    names = sorted(payloads)
    if ",".join(names) != manifest["names"]:
        return False
    leaves = [leaf_digest(n, payloads[n]) for n in names]
    root = merkle_root(leaves)
    if root.hex() != manifest["root"]:
        return False
    return verify_seal(root, key, bytes.fromhex(manifest["seal"]))
