"""Closed-form chiplet SoC performance model (the paper's evaluation methodology).

Reconstruction of the paper's Python simulator from its Tables I-III (the paper
does not publish equations; DESIGN.md §2 derives and validates this model).

Per (scenario s, workload w, batch b):

    T_compute(b) = alpha * C_w * chi_w * eps_s * (1 + (b-1)*eta_w) / (clock*boost)
    T_comm(b)    = (ell_s/1000 + 8*S_w*b*cr_s/B_s) * rho_s          [ms]
    T_total(b)   = T_compute(b) + [no prefetch overlap] * T_comm(b)
    u(b)         = 1 - (1-u0)/b                      (NPU duty cycle)
    clock        = min(1, tau_s/u(b)) unless predictive migration holds it at 1
    P(b)         = P0_s*v_s^2*(sigma_s + (1-sigma_s)*u(b)*clock) + Pc_s*T_comm(b)
    thpt         = 1000*b/T_total ;  TOPS/W = thpt*GOP/P ;  E = P*T_total/b

Two constants are calibrated once against the Monolithic row of Table III
(DESIGN.md §2): ALPHA (compute scale) and BASE_UTIL (batch-1 duty cycle). The
AI-optimized scenario's extra mechanisms (prefetch overlap, compression,
DVFS power-headroom boost, migration-backed thermal headroom) are the paper's
§II innovations I1/I2/I4 and are controlled by flags on the Scenario.

Everything is pure JAX: jit-, vmap- and grad-compatible. The design-space
explorer vmaps `predict_vec` over thousands of candidate scenario vectors and
can differentiate the model w.r.t. design parameters.
"""

from __future__ import annotations

import dataclasses
from typing import Sequence

import jax
import jax.numpy as jnp

from repro.core.scenarios import Scenario
from repro.core.workloads import Workload

# Calibrated once on the Monolithic batch-1 MobileNetV2 row (4.7 ms):
#   ALPHA = 4.7 / (3.5 * 0.8)
ALPHA = 1.6785714285714286
# Calibrated batch-1 NPU duty cycle (power model; fits all 4 scenario rows):
BASE_UTIL = 0.75
# DVFS boost engages fully once power headroom reaches this fraction (I1).
DVFS_HEADROOM_FULL = 0.10
# Predictive thermal management (I4) adds migration headroom on top of the
# throttle threshold: load shifts to the second NPU chiplet before derating.
MIGRATION_HEADROOM = 0.25


@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass
class PerfResult:
    """Model outputs; every field is a jnp array of the broadcast batch shape."""

    latency_ms: jnp.ndarray       # end-to-end per-batch latency
    throughput_ips: jnp.ndarray   # images (inferences) per second
    power_mw: jnp.ndarray         # average power draw
    tops_per_w: jnp.ndarray       # paper's efficiency metric
    energy_mj: jnp.ndarray        # energy per inference, millijoule
    utilization: jnp.ndarray      # NPU duty cycle u(b)
    clock_scale: jnp.ndarray      # thermal derating factor (1 = no throttle)
    t_compute_ms: jnp.ndarray
    t_comm_ms: jnp.ndarray        # raw (pre-overlap) transfer time
    realtime_ok: jnp.ndarray      # bool: per-image latency meets the deadline

    def tree_flatten(self):
        return (
            tuple(getattr(self, f.name) for f in dataclasses.fields(self)),
            None,
        )

    @classmethod
    def tree_unflatten(cls, aux, children):
        del aux
        return cls(*children)


def predict_vec(
    scen_vec: jnp.ndarray,
    work_vec: jnp.ndarray,
    batch_size: jnp.ndarray,
    *,
    alpha: float = ALPHA,
    base_util: float = BASE_UTIL,
    realtime_deadline_ms: float = 5.0,
) -> PerfResult:
    """Vector-encoded model (for vmapped DSE). See Scenario.as_vector for layout."""
    (ell, bw, p0, pc, eps, tau, sigma, v, rho, overlap, cr, boost_max) = [
        scen_vec[i] for i in range(12)
    ]
    c, s_mb, chi, eta, gops = [work_vec[i] for i in range(5)]
    b = jnp.asarray(batch_size, jnp.float32)

    # --- utilization & thermal derating (I4) ---------------------------------
    u = 1.0 - (1.0 - base_util) / b
    # Predictive migration raises the effective throttle ceiling (AI-optimized
    # keeps clock=1 while a reactive design derates once u exceeds tau).
    tau_eff = tau + MIGRATION_HEADROOM * (boost_max > 0.0)
    clock = jnp.minimum(1.0, tau_eff / jnp.maximum(u, 1e-6))

    # --- communication (I2) ---------------------------------------------------
    t_comm = (ell / 1000.0 + 8.0 * s_mb * b * cr / bw) * rho  # ms

    # --- power (pre-boost, to derive DVFS headroom non-self-referentially) ---
    p_nominal = p0 * v**2 * (sigma + (1.0 - sigma) * u * clock) + pc * t_comm
    headroom = 1.0 - p_nominal / (p0 * v**2)
    boost = 1.0 + boost_max * jnp.clip(headroom / DVFS_HEADROOM_FULL, 0.0, 1.0)

    # --- compute --------------------------------------------------------------
    t_compute = alpha * c * chi * eps * (1.0 + (b - 1.0) * eta) / (clock * boost)
    t_total = t_compute + (1.0 - overlap) * t_comm

    thpt = 1000.0 * b / t_total
    power = p_nominal  # boost spends the headroom; envelope unchanged
    tops_per_w = (thpt * gops * 1e9) / (power / 1000.0) / 1e12
    energy_mj = power * t_total / b / 1000.0  # mW*ms = uJ; /1000 = mJ
    per_image_ms = t_total / b

    return PerfResult(
        latency_ms=t_total,
        throughput_ips=thpt,
        power_mw=power,
        tops_per_w=tops_per_w,
        energy_mj=energy_mj,
        utilization=u,
        clock_scale=clock,
        t_compute_ms=t_compute,
        t_comm_ms=t_comm,
        realtime_ok=per_image_ms <= realtime_deadline_ms,
    )


def predict(
    scenario: Scenario,
    workload: Workload,
    batch_size: int | jnp.ndarray = 1,
    *,
    alpha: float = ALPHA,
    base_util: float = BASE_UTIL,
) -> PerfResult:
    """Typed front-end over `predict_vec`."""
    return predict_vec(
        scenario.as_vector(),
        workload.as_vector(),
        jnp.asarray(batch_size, jnp.float32),
        alpha=alpha,
        base_util=base_util,
        realtime_deadline_ms=workload.realtime_deadline_ms,
    )


def predict_grid(
    scenarios: Sequence[Scenario],
    workloads: Sequence[Workload],
    batch_sizes: Sequence[int],
) -> PerfResult:
    """Full (n_scenarios, n_workloads, n_batches) grid in one vmapped call."""
    sv = jnp.stack([s.as_vector() for s in scenarios])          # (S, 12)
    wv = jnp.stack([w.as_vector() for w in workloads])          # (W, 5)
    bs = jnp.asarray(batch_sizes, jnp.float32)                  # (B,)
    fn = jax.vmap(  # over scenarios
        jax.vmap(  # over workloads
            jax.vmap(predict_vec, in_axes=(None, None, 0)),  # over batches
            in_axes=(None, 0, None),
        ),
        in_axes=(0, None, None),
    )
    return fn(sv, wv, bs)


def predict_noisy(
    key: jax.Array,
    scenario: Scenario,
    workload: Workload,
    batch_size: int = 1,
    *,
    n_runs: int = 32,
    noise_frac: float = 0.05,
) -> PerfResult:
    """Monte-Carlo runs with multiplicative gaussian measurement noise.

    The paper reports single-run numbers with +/-0.2-0.3 ms spread; this models
    that spread so tests can assert reproduction within the paper's own bars.
    """
    base = predict(scenario, workload, batch_size)
    eps_lat, eps_pow = jax.random.normal(key, (2, n_runs))
    lat = base.latency_ms * (1.0 + noise_frac * eps_lat)
    pow_ = base.power_mw * (1.0 + noise_frac * eps_pow)
    b = jnp.asarray(batch_size, jnp.float32)
    thpt = 1000.0 * b / lat
    return dataclasses.replace(
        base,
        latency_ms=lat,
        power_mw=pow_,
        throughput_ips=thpt,
        tops_per_w=(thpt * workload.gops_per_inference * 1e9)
        / (pow_ / 1000.0)
        / 1e12,
        energy_mj=pow_ * lat / b / 1000.0,
        realtime_ok=(lat / b) <= workload.realtime_deadline_ms,
    )
