"""I2 — AI-aware UCIe die-to-die link model (paper §II).

UCIe moves data in 64-byte FLITs with per-FLIT protocol overhead (CRC, header,
retry) [18]. The paper's extensions:

  * *streaming FLITs*  — header cost amortized over a burst instead of per FLIT,
  * *compression-aware transfers* — payload compressed before the link
    (activation/weight streams compress well at INT8),
  * *predictive prefetching* — transfers issued ahead of the consuming kernel so
    they overlap compute (modeled by the scheduler in soc.py, and by the
    `prefetch_overlap` flag in the closed-form model).

`transfer()` is the closed-form per-message cost; `LinkState`/`link_tick` give
the queued, bandwidth-limited behaviour for the time-stepped simulator.
"""

from __future__ import annotations

import dataclasses
from typing import Tuple

import jax
import jax.numpy as jnp

FLIT_BYTES = 64.0           # UCIe flit payload granularity
HEADER_BYTES = 8.0          # per-flit protocol bytes (CRC+hdr, raw mode)
STREAM_BURST_FLITS = 64.0   # streaming mode amortizes one header per burst


@dataclasses.dataclass(frozen=True)
class UCIeConfig:
    bandwidth_gbps: float = 24.0      # per-direction link bandwidth
    latency_us: float = 0.8           # one-way link latency
    streaming: bool = True            # streaming-FLIT extension
    compression_ratio: float = 0.75   # effective payload ratio (1.0 = off)
    compression_us_per_kb: float = 0.002  # (de)compression engine cost
    pj_per_bit: float = 0.5           # link energy

    def as_vector(self) -> jnp.ndarray:
        # jnp.stack (not jnp.array) so fields may be traced scalars — the
        # vmapped design sweeps hold per-candidate link parameters.
        return jnp.stack([
            jnp.asarray(self.bandwidth_gbps, jnp.float32),
            jnp.asarray(self.latency_us, jnp.float32),
            jnp.asarray(self.streaming, jnp.float32),
            jnp.asarray(self.compression_ratio, jnp.float32),
            jnp.asarray(self.compression_us_per_kb, jnp.float32),
            jnp.asarray(self.pj_per_bit, jnp.float32),
        ])


def protocol_efficiency(streaming: jnp.ndarray) -> jnp.ndarray:
    """Payload bytes / wire bytes."""
    per_flit_hdr = jnp.where(
        streaming > 0.5, HEADER_BYTES / STREAM_BURST_FLITS, HEADER_BYTES
    )
    return FLIT_BYTES / (FLIT_BYTES + per_flit_hdr)


def transfer(
    payload_bytes: jnp.ndarray,
    cfg: UCIeConfig | jnp.ndarray,
) -> Tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray]:
    """Closed-form cost of one message.

    Returns (time_us, energy_mj, wire_bytes). Differentiable; `cfg` may be a
    UCIeConfig or its `as_vector()` encoding (for vmapped sweeps).
    """
    vec = cfg.as_vector() if isinstance(cfg, UCIeConfig) else cfg
    bw_gbps, lat_us, streaming, cr, comp_us_kb, pj_bit = (vec[i] for i in range(6))

    compressed = payload_bytes * cr
    n_flits = jnp.ceil(compressed / FLIT_BYTES)
    eff = protocol_efficiency(streaming)
    wire_bytes = n_flits * FLIT_BYTES / eff
    t_wire_us = wire_bytes * 8.0 / (bw_gbps * 1e3)  # Gbps = bits/ns -> us
    t_comp_us = jnp.where(
        cr < 1.0, (payload_bytes / 1024.0) * comp_us_kb, 0.0
    )
    time_us = lat_us + t_wire_us + t_comp_us
    energy_mj = wire_bytes * 8.0 * pj_bit * 1e-9  # pJ/bit -> mJ
    return time_us, energy_mj, wire_bytes


def migration_ticks(
    payload_bytes: float,
    cfg: UCIeConfig | jnp.ndarray,
    *,
    tick_us: float,
) -> int:
    """Engine ticks one KV page-migration transfer occupies the link.

    This is THE coupling point between the serving stack and the interconnect
    model: `serve/migration` charges a migrated slot this many ticks of decode
    delay, and the number comes from the very same `transfer()` closed form
    the time-stepped simulator drains through `link_tick`. A guard test pins
    that no serving module re-derives link math outside this call path.
    """
    t_us, _, _ = transfer(jnp.asarray(payload_bytes, jnp.float32), cfg)
    return max(1, int(-(-float(t_us) // float(tick_us))))


@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass
class LinkState:
    """Bandwidth-limited FIFO queue for the time-stepped simulator."""

    queued_bytes: jnp.ndarray    # () f32 wire bytes waiting
    wire_bytes_total: jnp.ndarray
    energy_mj: jnp.ndarray

    def tree_flatten(self):
        return (
            (self.queued_bytes, self.wire_bytes_total, self.energy_mj),
            None,
        )

    @classmethod
    def tree_unflatten(cls, aux, children):
        del aux
        return cls(*children)


def init_link() -> LinkState:
    z = jnp.zeros((), jnp.float32)
    return LinkState(queued_bytes=z, wire_bytes_total=z, energy_mj=z)


def link_tick(
    state: LinkState,
    new_payload_bytes: jnp.ndarray,
    cfg: UCIeConfig,
    tick_ms: float,
) -> Tuple[LinkState, Tuple[jnp.ndarray, jnp.ndarray]]:
    """Enqueue `new_payload_bytes`, drain at link bandwidth for one tick.

    Returns (state, (drained_bytes, occupancy)) where occupancy in [0,1] is the
    fraction of the tick the link was busy (drives comm power in soc.py).
    """
    _, energy_mj, wire = transfer(new_payload_bytes, cfg)
    queued = state.queued_bytes + wire
    capacity = cfg.bandwidth_gbps * 1e9 / 8.0 * (tick_ms / 1e3)  # bytes/tick
    drained = jnp.minimum(queued, capacity)
    occupancy = drained / jnp.maximum(capacity, 1e-9)
    return (
        LinkState(
            queued_bytes=queued - drained,
            wire_bytes_total=state.wire_bytes_total + wire,
            energy_mj=state.energy_mj + energy_mj,
        ),
        (drained, occupancy),
    )
