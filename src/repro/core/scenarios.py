"""Integration scenarios — Table I of the paper, as typed configs.

Each scenario describes one way of integrating the SoC's chiplets:
  monolithic       — single large die, no die-to-die links (the yield-limited baseline)
  basic_chiplet    — naive 2.5D chiplet integration over UCIe 1.x-class links
  ai_optimized     — the paper's proposal: UCIe 2.0 + streaming FLITs + prefetch +
                     compression-aware transfers + adaptive DVFS (innovations I1+I2)
  poor_integration — pathological integration (slow links, high protocol overhead)

All parameters are the paper's Table I values verbatim. The three `ai_*` feature
flags encode the paper's §II mechanisms that the AI-optimized scenario enables;
they are what the reconstructed model uses to explain the Table III deltas (see
DESIGN.md §2).
"""

from __future__ import annotations

import dataclasses
import math
from typing import Dict, Tuple

import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class Scenario:
    """One integration scenario (a row of Table I)."""

    name: str
    # -- Table I columns ------------------------------------------------------
    link_latency_us: float        # die-to-die link latency (one-way), microseconds
    link_bandwidth_gbps: float    # die-to-die bandwidth, Gbit/s (inf for monolithic)
    base_power_mw: float          # SoC base (max dynamic+static) power envelope, mW
    comm_power_mw_per_ms: float   # incremental link power per ms of transfer, mW/ms
    efficiency_factor: float      # compute-time multiplier (<1 = faster silicon)
    throttle_threshold: float     # sustained-utilization level that triggers derating
    static_power_ratio: float     # fraction of base power that is static/leakage
    voltage_scale: float          # supply scaling vs nominal (power ~ v^2)
    protocol_overhead: float      # transfer-time multiplier from the link protocol
    # -- paper §II mechanism flags (I1/I2) ------------------------------------
    prefetch_overlap: bool = False    # I2: predictive prefetch hides T_comm
    compression_ratio: float = 1.0    # I2: effective payload ratio (<1 = compressed)
    dvfs_adaptive: bool = False       # I1: power-headroom clock boost enabled
    dvfs_boost_max: float = 0.0       # I1: max fractional clock boost (e.g. 0.032)

    @property
    def is_monolithic(self) -> bool:
        return math.isinf(self.link_bandwidth_gbps)

    def as_vector(self) -> jnp.ndarray:
        """Numeric encoding for vmapped design-space sweeps (see planner/DSE)."""
        bw = 1e9 if self.is_monolithic else self.link_bandwidth_gbps
        return jnp.array(
            [
                self.link_latency_us,
                bw,
                self.base_power_mw,
                self.comm_power_mw_per_ms,
                self.efficiency_factor,
                self.throttle_threshold,
                self.static_power_ratio,
                self.voltage_scale,
                self.protocol_overhead,
                1.0 if self.prefetch_overlap else 0.0,
                self.compression_ratio,
                self.dvfs_boost_max if self.dvfs_adaptive else 0.0,
            ],
            dtype=jnp.float32,
        )

    @staticmethod
    def vector_fields() -> Tuple[str, ...]:
        return (
            "link_latency_us",
            "link_bandwidth_gbps",
            "base_power_mw",
            "comm_power_mw_per_ms",
            "efficiency_factor",
            "throttle_threshold",
            "static_power_ratio",
            "voltage_scale",
            "protocol_overhead",
            "prefetch_overlap",
            "compression_ratio",
            "dvfs_boost",
        )


MONOLITHIC = Scenario(
    name="monolithic",
    link_latency_us=0.0,
    link_bandwidth_gbps=math.inf,
    base_power_mw=1500.0,
    comm_power_mw_per_ms=0.0,
    efficiency_factor=1.00,
    throttle_threshold=0.95,
    static_power_ratio=0.40,
    voltage_scale=1.00,
    protocol_overhead=1.0,  # '—' in Table I: no die-to-die protocol
)

BASIC_CHIPLET = Scenario(
    name="basic_chiplet",
    link_latency_us=1.5,
    link_bandwidth_gbps=16.0,
    base_power_mw=1200.0,
    comm_power_mw_per_ms=35.0,
    efficiency_factor=0.95,
    throttle_threshold=0.85,
    static_power_ratio=0.45,
    voltage_scale=1.00,
    protocol_overhead=1.15,
)

AI_OPTIMIZED = Scenario(
    name="ai_optimized",
    link_latency_us=0.8,
    link_bandwidth_gbps=24.0,
    base_power_mw=1100.0,
    comm_power_mw_per_ms=25.0,
    efficiency_factor=0.90,
    throttle_threshold=0.80,
    static_power_ratio=0.42,
    voltage_scale=0.95,
    protocol_overhead=1.08,
    # Paper §II: streaming FLITs + predictive prefetching + compression-aware
    # transfers (I2) and adaptive cross-chiplet DVFS (I1).
    prefetch_overlap=True,
    compression_ratio=0.75,
    dvfs_adaptive=True,
    dvfs_boost_max=0.032,
)

POOR_INTEGRATION = Scenario(
    name="poor_integration",
    link_latency_us=8.0,
    link_bandwidth_gbps=8.0,
    base_power_mw=1800.0,
    comm_power_mw_per_ms=80.0,
    efficiency_factor=1.10,
    throttle_threshold=1.00,
    static_power_ratio=0.50,
    voltage_scale=1.05,
    protocol_overhead=1.25,
)

SCENARIOS: Dict[str, Scenario] = {
    s.name: s
    for s in (MONOLITHIC, BASIC_CHIPLET, AI_OPTIMIZED, POOR_INTEGRATION)
}

# Order used throughout benchmarks/plots (matches the paper's tables).
SCENARIO_ORDER = ("monolithic", "basic_chiplet", "ai_optimized", "poor_integration")


def get_scenario(name: str) -> Scenario:
    try:
        return SCENARIOS[name]
    except KeyError as e:
        raise KeyError(
            f"unknown scenario {name!r}; available: {sorted(SCENARIOS)}"
        ) from e
