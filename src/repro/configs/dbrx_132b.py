"""dbrx-132b [moe] — 16 experts top-4, fine-grained. [hf:databricks/dbrx-base; unverified]

40L d_model=6144 48H (GQA kv=8) d_ff=10752 vocab=100352, MoE 16e top-4.
Experts shard 1-per-chip-group over the 16-way `model` axis (EP) and FSDP
over `data` on d_model; the most representative cell for the paper's
"modular acceleration" thesis (experts ↔ chiplets, dispatch ↔ UCIe).
"""

from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="dbrx-132b",
    family="moe",
    n_layers=40,
    d_model=6144,
    n_heads=48,
    n_kv_heads=8,
    head_dim=128,
    d_ff=10752,
    d_ff_expert=10752,
    vocab_size=100352,
    n_experts=16,
    moe_top_k=4,
    activation="swiglu",
    rope_theta=5e5,
    capacity_factor=1.25,
)
