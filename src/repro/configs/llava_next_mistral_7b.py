"""llava-next-mistral-7b [vlm] — anyres tiling.
[hf:llava-hf/llava-v1.6-mistral-7b-hf; unverified]

Mistral-7B backbone: 32L d_model=4096 32H (GQA kv=8) d_ff=14336 vocab=32000.
The modality frontend is a STUB per the assignment: `input_specs()` provides
precomputed patch embeddings (B, n_image_tokens, d_model) — anyres tiling of
up to 5 tiles × 576 patches = 2880 image tokens — which the backbone merges
into the leading token positions before the decoder stack.
"""

from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="llava-next-mistral-7b",
    family="vlm",
    n_layers=32,
    d_model=4096,
    n_heads=32,
    n_kv_heads=8,
    head_dim=128,
    d_ff=14336,
    vocab_size=32000,
    activation="swiglu",
    rope_theta=1e6,
    n_image_tokens=2880,
)
