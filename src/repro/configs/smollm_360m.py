"""smollm-360m [dense] — llama-arch small. [hf:HuggingFaceTB/SmolLM-135M; hf]

32L d_model=960 15H (GQA kv=5) d_ff=2560 vocab=49152. head_dim = 960/15 = 64.
15 heads / 5 kv-heads are not divisible by the 16-way model axis → the
divisibility rule replicates head dims on `model` and TP comes from d_ff
(2560/16 = 160) and vocab (49152/16 = 3072). See DESIGN.md §6.
"""

from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="smollm-360m",
    family="dense",
    n_layers=32,
    d_model=960,
    n_heads=15,
    n_kv_heads=5,
    head_dim=64,
    d_ff=2560,
    vocab_size=49152,
    activation="swiglu",
    rope_theta=1e4,
    tie_embeddings=True,
)
