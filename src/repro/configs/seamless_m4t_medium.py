"""seamless-m4t-medium [audio] — enc-dec, multimodal. [arXiv:2308.11596; hf]

12L encoder + 12L decoder, d_model=1024 16H (kv=16) d_ff=4096 vocab=256206,
head_dim=64. The audio frontend is a STUB: `input_specs()` provides
precomputed frame embeddings (B, S, d_model) for the encoder. Decode shapes
grow the *decoder self-attention* cache to seq_len; cross-attention reads a
fixed-length (cross_len) encoder memory. vocab padded to 256256 (×256).
"""

from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="seamless-m4t-medium",
    family="encdec",
    n_layers=24,            # 12 enc + 12 dec
    n_enc_layers=12,
    n_dec_layers=12,
    d_model=1024,
    n_heads=16,
    n_kv_heads=16,
    head_dim=64,
    d_ff=4096,
    vocab_size=256206,
    activation="geglu",     # seamless uses GELU FFN; GLU variant keeps 3-matrix FFN uniform
    audio_frontend=True,
    cross_len=4096,
)
