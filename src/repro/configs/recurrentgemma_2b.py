"""recurrentgemma-2b [hybrid] — RG-LRU + local attn, 1:2. [arXiv:2402.19427; hf]

26L d_model=2560 10H (MQA kv=1) d_ff=7680 vocab=256000, head_dim=256,
lru_width=2560, local-attention window 2048, pattern (rec, rec, attn).
Constant-size state (LRU h + 2048-token window cache) → runs long_500k.
"""

from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="recurrentgemma-2b",
    family="hybrid",
    n_layers=26,
    d_model=2560,
    n_heads=10,
    n_kv_heads=1,
    head_dim=256,
    d_ff=7680,
    vocab_size=256000,
    activation="geglu",
    block_pattern=("rec", "rec", "attn"),
    lru_width=2560,
    window=2048,
    rope_theta=1e4,
    tie_embeddings=True,
    embed_scale=True,
    norm_plus_one=True,
    logit_softcap=30.0,
)
