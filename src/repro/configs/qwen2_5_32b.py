"""qwen2.5-32b [dense] — GQA, QKV bias. [hf:Qwen/Qwen2.5-0.5B; hf]

64L d_model=5120 40H (GQA kv=8) d_ff=27648 vocab=152064, head_dim=128.
Largest dense arch: layer-stacked lax.scan keeps HLO size O(1) in depth.
40 heads not divisible by model=16 → heads replicated on `model`; TP comes
from d_ff (27648/16 = 1728) and vocab (152064/16 = 9504).
"""

from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="qwen2.5-32b",
    family="dense",
    n_layers=64,
    d_model=5120,
    n_heads=40,
    n_kv_heads=8,
    head_dim=128,
    d_ff=27648,
    vocab_size=152064,
    activation="swiglu",
    qkv_bias=True,
    rope_theta=1e6,
)
