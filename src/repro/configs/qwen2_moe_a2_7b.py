"""qwen2-moe-a2.7b [moe] — 4 shared + 60 routed top-4. [hf:Qwen/Qwen1.5-MoE-A2.7B; hf]

24L d_model=2048 16H (GQA kv=16) d_ff=1408 (per expert) vocab=151936,
MoE 60e top-4 + shared expert of 4×1408 = 5632 (sigmoid-gated).
60 experts are not divisible by the 16-way model axis → expert dim is
replicated and TP comes from d_ff_expert (1408/16 = 88); documented
trade-off in DESIGN.md §5.
"""

from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="qwen2-moe-a2.7b",
    family="moe",
    n_layers=24,
    d_model=2048,
    n_heads=16,
    n_kv_heads=16,
    head_dim=128,
    d_ff=1408,
    d_ff_expert=1408,
    d_ff_shared=5632,
    vocab_size=151936,
    n_experts=60,
    moe_top_k=4,
    activation="swiglu",
    qkv_bias=True,
    rope_theta=1e6,
    capacity_factor=1.25,
)
