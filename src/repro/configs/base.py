"""Architecture + input-shape configuration.

One `ArchConfig` per assigned architecture (exact public-literature configs),
plus the four assigned input shapes. `smoke()` derives a reduced same-family
config for CPU tests; the full configs are exercised only via the dry-run.
"""

from __future__ import annotations

import dataclasses
from typing import Tuple


@dataclasses.dataclass(frozen=True)
class ArchConfig:
    name: str
    family: str                 # dense | moe | ssm | hybrid | encdec | vlm
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    head_dim: int
    d_ff: int
    vocab_size: int
    activation: str = "swiglu"          # swiglu | geglu
    qkv_bias: bool = False
    rope_theta: float = 1e4
    rope_fraction: float = 1.0          # chatglm: 0.5 (2D/partial rotary)
    tie_embeddings: bool = False
    embed_scale: bool = False           # gemma: embeddings × sqrt(d_model)
    logit_softcap: float = 0.0
    norm_plus_one: bool = False         # gemma-style (1+w) RMSNorm weights
    # --- MoE ---
    n_experts: int = 0
    moe_top_k: int = 0
    d_ff_expert: int = 0
    d_ff_shared: int = 0                # qwen2-moe: 4 shared experts (fused)
    capacity_factor: float = 1.25
    moe_group: int = 512                # GShard group size (tokens)
    # --- SSM (mamba2 / SSD) ---
    ssm_state: int = 0
    ssm_head_dim: int = 64
    ssm_expand: int = 2
    ssm_chunk: int = 256
    conv_kernel: int = 4
    # --- hybrid (recurrentgemma / Griffin) ---
    block_pattern: Tuple[str, ...] = () # e.g. ('rec','rec','attn')
    lru_width: int = 0
    window: int = 0                     # sliding-window size for local attn
    # --- attention family (deepseek-v2 MLA latent-KV) ---
    # attn_kind='mla' caches ONE (kv_lora_rank + qk_rope_dim)-wide latent row
    # per token instead of per-head K/V (models/mla.py); 'gqa' is the default
    # per-head path. q_lora_rank=0 keeps the direct query projection.
    attn_kind: str = "gqa"              # gqa | mla
    q_lora_rank: int = 0
    kv_lora_rank: int = 0
    qk_nope_dim: int = 0
    qk_rope_dim: int = 0
    v_head_dim: int = 0                 # 0 → head_dim
    # --- enc-dec (seamless) ---
    n_enc_layers: int = 0
    n_dec_layers: int = 0
    cross_len: int = 4096               # encoder length used by decode shapes
    # --- modality frontends (STUBS: precomputed embeddings) ---
    n_image_tokens: int = 0             # vlm: anyres patch tokens per sample
    audio_frontend: bool = False        # encoder consumes (B,S,d) frames
    # --- numerics / distribution-time padding ---
    dtype: str = "bfloat16"
    vocab_round: int = 256              # pad vocab up for even sharding
    # Pad attention heads so (kv_pad × g_pad) is a multiple of the TP axis.
    # Dead heads are hard-masked to zero contribution (exact outputs, zero
    # grads); without this, archs whose head counts don't divide 16 (smollm
    # 15H, qwen2.5 40H, recurrentgemma 10H) would replicate their projections
    # and attention across the whole model axis. Set to the model-axis size
    # by the launcher; 1 (no padding) for smoke tests.
    tp_pad: int = 1

    # ---------------------------------------------------------------------
    @property
    def padded_vocab(self) -> int:
        r = self.vocab_round
        return ((self.vocab_size + r - 1) // r) * r

    @property
    def q_per_kv(self) -> int:
        return self.n_heads // max(self.n_kv_heads, 1)

    @property
    def padded_kv_group(self) -> Tuple[int, int]:
        """(kv_pad, g_pad): smallest GQA-aligned padding with
        kv_pad·g_pad ≡ 0 (mod tp_pad)."""
        kv, g, m = self.n_kv_heads, self.q_per_kv, self.tp_pad
        best = None
        for kvp in range(kv, kv + m + 1):
            for gp in range(g, g + m + 1):
                if (kvp * gp) % m == 0 and kvp * gp >= self.n_heads:
                    if best is None or kvp * gp < best[0] * best[1] or (
                            kvp * gp == best[0] * best[1] and kvp == kv):
                        if best is None or kvp * gp < best[0] * best[1]:
                            best = (kvp, gp)
                        elif kvp == kv and best[0] != kv:
                            best = (kvp, gp)
        assert best is not None
        return best

    @property
    def kv_pad(self) -> int:
        return self.padded_kv_group[0]

    @property
    def g_pad(self) -> int:
        return self.padded_kv_group[1]

    @property
    def n_heads_padded(self) -> int:
        kvp, gp = self.padded_kv_group
        return kvp * gp

    @property
    def mla_latent_dim(self) -> int:
        """Width of the single cached MLA row: compressed KV + shared rope."""
        return self.kv_lora_rank + self.qk_rope_dim

    @property
    def mla_qk_dim(self) -> int:
        return self.qk_nope_dim + self.qk_rope_dim

    @property
    def mla_v_dim(self) -> int:
        return self.v_head_dim or self.head_dim

    @property
    def d_inner(self) -> int:           # mamba2
        return self.ssm_expand * self.d_model

    @property
    def ssm_heads(self) -> int:
        return self.d_inner // self.ssm_head_dim

    @property
    def sub_quadratic(self) -> bool:
        """Can decode a 524288-token context in O(1)/O(window) state?"""
        return self.family in ("ssm", "hybrid")

    @property
    def has_decode(self) -> bool:
        return True  # no encoder-only archs in the assignment

    def layer_pattern(self) -> Tuple[str, ...]:
        if not self.block_pattern:
            return ("attn",) * self.n_layers
        p = self.block_pattern
        return tuple(p[i % len(p)] for i in range(self.n_layers))

    def param_count_analytic(self) -> int:
        """6·N·D-style N (total params), analytic."""
        d, f, v = self.d_model, self.d_ff, self.padded_vocab
        h, kv, hd = self.n_heads, self.n_kv_heads, self.head_dim
        emb = v * d * (1 if self.tie_embeddings else 2)
        if self.family == "ssm":
            di, n = self.d_inner, self.ssm_state
            per = d * (2 * di + 2 * n + self.ssm_heads) + di * d \
                + self.conv_kernel * (di + 2 * n) + 3 * self.ssm_heads + di
            return emb + self.n_layers * per
        attn = d * (h * hd) + 2 * d * (kv * hd) + (h * hd) * d
        glu = 3 * d * f
        per = attn + glu
        if self.family == "moe":
            per = attn + self.n_experts * 3 * d * self.d_ff_expert \
                + 3 * d * self.d_ff_shared + d * self.n_experts
        if self.family == "hybrid":
            n_rec = sum(1 for b in self.layer_pattern() if b == "rec")
            n_att = self.n_layers - n_rec
            w = self.lru_width
            rec = 2 * d * w + w * d + self.conv_kernel * w + 4 * w
            return emb + n_rec * (rec + glu) + n_att * (attn + glu)
        if self.family == "encdec":
            enc = self.n_enc_layers * (attn + glu)
            dec = self.n_dec_layers * (2 * attn + glu)
            return emb + enc + dec
        return emb + self.n_layers * per

    def active_param_count(self) -> int:
        """Active params per token (== total except MoE routes top-k)."""
        if self.family != "moe":
            return self.param_count_analytic()
        d = self.d_model
        h, kv, hd = self.n_heads, self.n_kv_heads, self.head_dim
        attn = d * (h * hd) + 2 * d * (kv * hd) + (h * hd) * d
        act = attn + self.moe_top_k * 3 * d * self.d_ff_expert \
            + 3 * d * self.d_ff_shared + d * self.n_experts
        emb = self.padded_vocab * d * (1 if self.tie_embeddings else 2)
        return emb + self.n_layers * act

    def smoke(self) -> "ArchConfig":
        """Reduced same-family config for CPU smoke tests."""
        updates = dict(
            name=self.name + "-smoke",
            n_layers=min(self.n_layers, 2),
            d_model=128,
            n_heads=4 if self.n_heads % 2 == 0 else 5,
            n_kv_heads=min(self.n_kv_heads, 2) if self.n_heads % 2 == 0 else 1,
            head_dim=32 if self.head_dim != 256 else 64,
            d_ff=256,
            vocab_size=512,
            dtype="float32",
            moe_group=64,
        )
        if self.family == "moe":
            updates.update(n_experts=min(self.n_experts, 8),
                           moe_top_k=min(self.moe_top_k, 2),
                           d_ff_expert=64,
                           d_ff_shared=128 if self.d_ff_shared else 0)
        if self.family == "ssm":
            updates.update(ssm_state=16, ssm_head_dim=16, ssm_chunk=32,
                           n_heads=1, n_kv_heads=1)
        if self.family == "hybrid":
            updates.update(lru_width=128, window=64, n_layers=3,
                           n_heads=4, n_kv_heads=1, head_dim=32)
        if self.family == "encdec":
            updates.update(n_enc_layers=2, n_dec_layers=2, cross_len=32,
                           n_heads=4, n_kv_heads=4, head_dim=32)
        if self.family == "vlm":
            updates.update(n_image_tokens=8, n_kv_heads=2)
        if self.attn_kind == "mla":
            updates.update(kv_lora_rank=32, qk_nope_dim=32, qk_rope_dim=16,
                           v_head_dim=32,
                           q_lora_rank=16 if self.q_lora_rank else 0)
        return dataclasses.replace(self, **updates)


@dataclasses.dataclass(frozen=True)
class ShapeConfig:
    name: str
    kind: str          # train | prefill | decode
    seq_len: int
    global_batch: int

    @property
    def is_train(self) -> bool:
        return self.kind == "train"


SHAPES = {
    "train_4k":    ShapeConfig("train_4k", "train", 4096, 256),
    "prefill_32k": ShapeConfig("prefill_32k", "prefill", 32768, 32),
    "decode_32k":  ShapeConfig("decode_32k", "decode", 32768, 128),
    "long_500k":   ShapeConfig("long_500k", "decode", 524288, 1),
}
SHAPE_ORDER = ("train_4k", "prefill_32k", "decode_32k", "long_500k")


def cell_is_runnable(cfg: ArchConfig, shape: ShapeConfig) -> Tuple[bool, str]:
    """long_500k needs sub-quadratic decode (assignment brief)."""
    if shape.name == "long_500k" and not cfg.sub_quadratic:
        return False, ("skipped: pure full-attention arch — a 524288-token dense "
                       "KV cache cannot be decoded sub-quadratically (DESIGN.md §5)")
    return True, ""
