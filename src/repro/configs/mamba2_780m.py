"""mamba2-780m [ssm] — SSD (state-space duality). [arXiv:2405.21060; unverified]

48L d_model=1536, attention-free, d_ff=0, vocab=50280, ssm_state=128.
d_inner = 2·d = 3072, head_dim 64 → 48 SSD heads (48/16 = 3 on `model`).
Decode is an O(1) state update → runs the long_500k cell.
vocab 50280 is padded to 50432 (×256) for even 16-way sharding.
"""

from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="mamba2-780m",
    family="ssm",
    n_layers=48,
    d_model=1536,
    n_heads=1,            # attn-free; SSD heads derive from d_inner/ssm_head_dim
    n_kv_heads=1,
    head_dim=64,
    d_ff=0,
    vocab_size=50280,
    ssm_state=128,
    ssm_head_dim=64,
    ssm_expand=2,
    ssm_chunk=256,
    conv_kernel=4,
    tie_embeddings=True,
)
