"""deepseek-v2-lite [moe + MLA] — the first `attn_kind='mla'` arch.
[hf:deepseek-ai/DeepSeek-V2-Lite; arXiv:2405.04434]

27L d_model=2048 16H, MLA latent-KV: kv_lora_rank=512, qk 128 nope + 64
rope, v_head_dim=128 — so the cache holds ONE 576-wide latent row per token
(1152 B/token/layer bf16) instead of 16 K+V head pairs (131072 B: a 113×
shrink before int8 even enters). V2-Lite keeps the direct query projection
(q_lora_rank=0; the 236B V2 uses q_lora_rank=1536). MoE: 64 routed top-6 +
2 shared experts (2×1408 = 2816), first layer dense in the real model —
simplified here to all-MoE like the other moe archs.

`smoke()` scales the MLA dims down with the rest (base.ArchConfig.smoke),
keeping attn_kind='mla' so CPU tests exercise the latent path end to end.
"""

from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="deepseek-v2-lite",
    family="moe",
    n_layers=27,
    d_model=2048,
    n_heads=16,
    n_kv_heads=16,
    head_dim=128,
    d_ff=10944,
    d_ff_expert=1408,
    d_ff_shared=2816,
    vocab_size=102400,
    n_experts=64,
    moe_top_k=6,
    activation="swiglu",
    rope_theta=1e4,
    attn_kind="mla",
    q_lora_rank=0,
    kv_lora_rank=512,
    qk_nope_dim=128,
    qk_rope_dim=64,
    v_head_dim=128,
)
