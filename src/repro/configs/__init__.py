"""Architecture registry: the 10 assigned architectures × their shape sets."""

from __future__ import annotations

import importlib
from typing import Dict

from repro.configs.base import (
    SHAPES,
    SHAPE_ORDER,
    ArchConfig,
    ShapeConfig,
    cell_is_runnable,
)

_MODULES = {
    "llava-next-mistral-7b": "repro.configs.llava_next_mistral_7b",
    "mamba2-780m": "repro.configs.mamba2_780m",
    "gemma-7b": "repro.configs.gemma_7b",
    "qwen2.5-32b": "repro.configs.qwen2_5_32b",
    "smollm-360m": "repro.configs.smollm_360m",
    "chatglm3-6b": "repro.configs.chatglm3_6b",
    "seamless-m4t-medium": "repro.configs.seamless_m4t_medium",
    "recurrentgemma-2b": "repro.configs.recurrentgemma_2b",
    "dbrx-132b": "repro.configs.dbrx_132b",
    "qwen2-moe-a2.7b": "repro.configs.qwen2_moe_a2_7b",
}

ARCH_ORDER = tuple(_MODULES)

# Post-assignment archs: resolvable via get_config but outside ARCH_ORDER —
# the assignment's 10×4 dry-run/roofline grid stays fixed.
_EXTRA_MODULES = {
    "deepseek-v2-lite": "repro.configs.deepseek_v2_lite",  # MLA latent-KV
}
_MODULES = {**_MODULES, **_EXTRA_MODULES}


def get_config(name: str) -> ArchConfig:
    if name not in _MODULES:
        raise KeyError(f"unknown arch {name!r}; available: {sorted(_MODULES)}")
    return importlib.import_module(_MODULES[name]).CONFIG


def all_configs() -> Dict[str, ArchConfig]:
    return {n: get_config(n) for n in ARCH_ORDER}


def all_cells():
    """Every (arch, shape) cell with its runnability verdict — 40 total."""
    out = []
    for a in ARCH_ORDER:
        cfg = get_config(a)
        for s in SHAPE_ORDER:
            ok, why = cell_is_runnable(cfg, SHAPES[s])
            out.append((a, s, ok, why))
    return out


__all__ = [
    "ARCH_ORDER",
    "ArchConfig",
    "SHAPES",
    "SHAPE_ORDER",
    "ShapeConfig",
    "all_cells",
    "all_configs",
    "cell_is_runnable",
    "get_config",
]
