"""gemma-7b [dense] — GeGLU, head_dim=256. [arXiv:2403.08295; hf]

28L d_model=3072 16H (GQA kv=16, i.e. MHA on 7b; MQA is the 2b variant)
d_ff=24576 vocab=256000. Embeddings scaled by sqrt(d_model), tied lm head,
(1+w) RMSNorm. The 256k vocab makes the sharded-vocab chunked CE essential
(full logits at train_4k would be 256·4096·256000·2B ≈ 537 GB).
"""

from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="gemma-7b",
    family="dense",
    n_layers=28,
    d_model=3072,
    n_heads=16,
    n_kv_heads=16,
    head_dim=256,
    d_ff=24576,
    vocab_size=256000,
    activation="geglu",
    rope_theta=1e4,
    tie_embeddings=True,
    embed_scale=True,
    norm_plus_one=True,
)
