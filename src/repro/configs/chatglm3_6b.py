"""chatglm3-6b [dense] — RoPE 2d (partial rotary), GQA kv=2. [arXiv:2406.12793; hf]

28L d_model=4096 32H (GQA kv=2) d_ff=13696 vocab=65024, head_dim=128.
ChatGLM applies rotary to half of each head dim (rope_fraction=0.5); the
other half passes through unrotated.
"""

from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="chatglm3-6b",
    family="dense",
    n_layers=28,
    d_model=4096,
    n_heads=32,
    n_kv_heads=2,
    head_dim=128,
    d_ff=13696,
    vocab_size=65024,
    activation="swiglu",
    qkv_bias=True,
    rope_fraction=0.5,
    rope_theta=1e4,
)
