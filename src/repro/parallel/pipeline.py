"""Pipeline parallelism (PP): GPipe-style microbatch schedule over a mesh
axis via shard_map + lax.ppermute.

The paper analogue is I2's *streaming FLITs*: instead of moving a whole
activation tensor and waiting, microbatches stream through a chain of stages
with each hop overlapping the next stage's compute — the die-to-die
streaming discipline at pod scale. Used as an optional plan for the 'pod'
axis (stage = pod) and validated against the sequential reference in
tests/test_pipeline.py.

Schedule: classic GPipe fill-compute-drain over n_micro ≥ n_stage
microbatches; bubbles = (n_stage-1)/(n_micro + n_stage - 1).
"""

from __future__ import annotations

from typing import Callable

import jax
import jax.numpy as jnp


def pipeline_forward(stage_fn: Callable, x_micro: jnp.ndarray,
                     stage_params, axis_name: str):
    """Run inside shard_map: each device holds ONE stage's params.

    stage_fn(params, x) → x (same shape). x_micro: (n_micro, mb, ...) —
    identical on every stage (only stage 0's values are consumed).
    Returns (n_micro, mb, ...) outputs valid on the LAST stage.
    """
    from repro.parallel.shmap import axis_size
    n_stage = axis_size(axis_name)
    stage = jax.lax.axis_index(axis_name)
    n_micro = x_micro.shape[0]
    n_ticks = n_micro + n_stage - 1
    perm = [(i, i + 1) for i in range(n_stage - 1)]   # chain, not a ring

    buf = jnp.zeros_like(x_micro)                      # collected outputs
    carry = jnp.zeros_like(x_micro[0])                 # inter-stage activation

    def tick(state, t):
        carry, buf = state
        # stage 0 ingests microbatch t (when in range)
        mb_idx = jnp.clip(t, 0, n_micro - 1)
        x_in = jax.lax.dynamic_index_in_dim(x_micro, mb_idx, 0, False)
        x = jnp.where(stage == 0, x_in, carry)
        y = stage_fn(stage_params, x)
        # last stage stores microbatch (t - n_stage + 1) when valid
        out_idx = t - (n_stage - 1)
        valid = jnp.logical_and(stage == n_stage - 1, out_idx >= 0)
        store = jnp.clip(out_idx, 0, n_micro - 1)
        buf = jax.lax.cond(
            valid,
            lambda b: jax.lax.dynamic_update_index_in_dim(b, y, store, 0),
            lambda b: b, buf)
        # stream the activation down the chain (FLIT hop)
        carry = jax.lax.ppermute(y, axis_name, perm)
        return (carry, buf), None

    (carry, buf), _ = jax.lax.scan(tick, (carry, buf),
                                   jnp.arange(n_ticks))
    return buf


def run_pipeline(mesh, stage_fn: Callable, params_stacked, x: jnp.ndarray,
                 n_micro: int, axis_name: str = "stage"):
    """Host-side wrapper: params_stacked (n_stage, ...), x (batch, ...).

    Splits the batch into microbatches, shard_maps the schedule, and returns
    outputs gathered from the last stage (broadcast to all for convenience).
    """
    from jax.sharding import PartitionSpec as P
    n_stage = mesh.shape[axis_name]
    assert x.shape[0] % n_micro == 0
    xm = x.reshape(n_micro, x.shape[0] // n_micro, *x.shape[1:])

    def fn(params, xm):
        local = jax.tree.map(lambda t: t[0], params)   # drop the stage dim
        out = pipeline_forward(stage_fn, xm, local, axis_name)
        # broadcast the last stage's result to every stage (masked psum)
        stage = jax.lax.axis_index(axis_name)
        masked = jnp.where(stage == n_stage - 1, out, jnp.zeros_like(out))
        return jax.lax.psum(masked, axis_name)

    spec_p = jax.tree.map(lambda _: P(axis_name), params_stacked)
    from repro.parallel.shmap import shard_map
    out = jax.jit(shard_map(
        fn, mesh=mesh, in_specs=(spec_p, P()), out_specs=P(),
        check_vma=False))(params_stacked, xm)
    return out.reshape(x.shape[0], *out.shape[2:])


def bubble_fraction(n_stage: int, n_micro: int) -> float:
    return (n_stage - 1) / (n_micro + n_stage - 1)
