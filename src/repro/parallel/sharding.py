"""Logical-axis → mesh-axis sharding rules (DP/FSDP/TP/EP/SP).

The rule table maps logical names used in model schemas to mesh axes; the
divisibility rule shards a dim only when the axis size divides it, otherwise
it backs off (tuple rules try progressively smaller axis subsets, then
replicate). This handles the awkward head/expert counts (15, 40, 10, 60)
without GSPMD padding surprises — the affected tensor replicates on that
axis and TP comes from a different dim (DESIGN.md §6).
"""

from __future__ import annotations

from typing import Any, Dict, Optional, Sequence, Tuple, Union

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.models.common import is_schema_leaf

Axis = Union[str, Tuple[str, ...], None]

# Logical-axis vocabulary. 'batchlike' folds pod-DP and data-DP together.
DEFAULT_RULES: Dict[str, Tuple[Axis, ...]] = {
    # name: candidates tried in order (first divisible wins)
    "batchlike": (("pod", "data"), "data", None),
    "embed": ("data", None),          # FSDP / ZeRO-3 on the feature dim
    "vocab": ("model", None),
    "heads": ("model", None),
    "heads_flat": ("model", None),    # expanded+padded flat attention heads
    "kv_or_seq": ("model", None),     # decode caches: kv heads if divisible
    "seq": ("model", None),           # sequence parallelism (decode caches)
    "ff": ("model", None),
    "experts": ("model", None),
    "layers": (None,),
}

# Alternative execution plans (the hillclimb/planner lever). 'dp_heavy'
# retires the TP axis and spends the whole mesh on data parallelism — right
# when the model is far too small for 16-way TP (e.g. smollm-360m: TP-sharded
# layers leave <1.5 M params/chip and the per-layer TP collectives dwarf the
# compute). Params FSDP over data; batch over every axis.
PLAN_RULES: Dict[str, Dict[str, Tuple[Axis, ...]]] = {
    "tp16": DEFAULT_RULES,
    "dp_heavy": {
        "batchlike": (("pod", "data", "model"), ("data", "model"),
                      ("pod", "data"), "data", None),
        "embed": ("data", None),
        "vocab": ("model", None),     # CE logits still shard the vocab
        "heads": (None,),
        "heads_flat": (None,),
        "kv_or_seq": (None,),
        "seq": (None,),
        "ff": (None,),
        "experts": (None,),
        "layers": (None,),
    },
    # Weight-stationary decode: keep weights fully sharded (ff/expert dims
    # over 'data' instead of FSDP on d_model) so decode steps move the tiny
    # activations through psums instead of all-gathering GB-scale weights
    # every step (measured 32.8 GB/step/dev of weight gathers on dbrx-132b ×
    # decode_32k under the training layout).
    "serve_ws": {
        "batchlike": (("pod", "data"), "data", None),
        "embed": (None,),
        "vocab": ("model", None),
        "heads": ("model", None),
        "heads_flat": ("model", None),
        "kv_or_seq": ("model", None),
        "seq": ("model", None),
        "ff": ("data", None),
        "experts": ("model", None),
        "layers": (None,),
    },
    # Sharded serving engine (serve/sharded.py): the data axis partitions
    # SLOTS and KV pages (device-local page tables under shard_map), so it is
    # retired from every param rule — weights are shard-stationary replicas
    # on that axis (serve_ws minus its ff→data entry: per-step weight traffic
    # stays zero, which was serve_ws's point). 'model'-axis entries survive
    # for meshes that carry a TP axis, but intra-shard TP inside the
    # shard_map'd decode step needs manual collectives — recorded follow-on.
    "serve_sharded": {
        "batchlike": ("data", None),
        "embed": (None,),
        "vocab": ("model", None),
        "heads": ("model", None),
        "heads_flat": ("model", None),
        "kv_or_seq": ("model", None),
        "seq": ("model", None),
        "ff": (None,),
        "experts": ("model", None),
        "layers": (None,),
    },
}


def rules_for_plan(plan: str) -> Dict[str, Tuple[Axis, ...]]:
    return PLAN_RULES[plan]


def _axes_in_mesh(axis: Axis, mesh: Mesh) -> Optional[Axis]:
    if axis is None:
        return None
    if isinstance(axis, str):
        return axis if axis in mesh.shape else None
    present = tuple(a for a in axis if a in mesh.shape)
    if not present:
        return None
    # single-axis tuples normalize to the bare name: P(("data",),) and
    # P("data") are semantically equal but compare unequal on older jax
    return present[0] if len(present) == 1 else present


def _axis_size(axis: Axis, mesh: Mesh) -> int:
    if axis is None:
        return 1
    if isinstance(axis, str):
        return mesh.shape[axis]
    size = 1
    for a in axis:
        size *= mesh.shape[a]
    return size


def resolve_dim(name: Optional[str], size: int, mesh: Mesh,
                rules: Optional[Dict] = None) -> Axis:
    """Pick the first rule candidate whose axis size divides `size`."""
    if name is None:
        return None
    rules = rules or DEFAULT_RULES
    for cand in rules[name]:
        cand = _axes_in_mesh(cand, mesh)
        if cand is None:
            continue
        if size % _axis_size(cand, mesh) == 0:
            return cand
    return None


def spec_for(shape: Sequence[int], logical: Sequence[Optional[str]],
             mesh: Mesh, rules: Optional[Dict] = None) -> P:
    used = set()
    parts = []
    for size, name in zip(shape, logical):
        ax = resolve_dim(name, size, mesh, rules)
        # one mesh axis may shard only one dim of a tensor
        flat = (ax,) if isinstance(ax, str) else (ax or ())
        if any(a in used for a in flat):
            ax = None
        else:
            used.update(flat)
        parts.append(ax)
    return P(*parts)


def schema_pspecs(schema, mesh: Mesh, rules: Optional[Dict] = None):
    """PartitionSpec pytree matching a param schema."""
    return jax.tree.map(
        lambda d: spec_for(d.shape, d.logical, mesh, rules),
        schema, is_leaf=is_schema_leaf)


def schema_shardings(schema, mesh: Mesh, rules: Optional[Dict] = None):
    return jax.tree.map(lambda s: NamedSharding(mesh, s),
                        schema_pspecs(schema, mesh, rules))


def make_constrain(mesh: Mesh, rules: Optional[Dict] = None):
    """Activation-sharding hook passed to models as ExecOptions.constrain."""

    def constrain(x, *logical):
        if len(logical) != x.ndim:
            return x
        spec = spec_for(x.shape, logical, mesh, rules)
        return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, spec))

    return constrain


# ---------------------------------------------------------------------------
# Batch / cache shardings
# ---------------------------------------------------------------------------

def batch_pspecs(batch_abstract, mesh: Mesh, rules=None) -> Any:
    """Shard every input on its leading (batch) dim; rest replicated."""

    def one(sds):
        lead = resolve_dim("batchlike", sds.shape[0], mesh, rules) \
            if sds.ndim else None
        return P(lead, *([None] * (sds.ndim - 1)))

    return jax.tree.map(one, batch_abstract)


def cache_pspecs(cfg, cache_abstract, mesh: Mesh, rules=None) -> Any:
    """Decode/prefill cache shardings.

    KV tensors (L, B, S, KV, D): batch → ('pod','data'); KV-heads → 'model'
    when divisible, else the sequence dim → 'model' (flash-decoding-style
    split-K; GSPMD reduces the softmax over the sharded S with tiny
    collectives). States (SSM/LRU) shard their width dims on 'model'.
    """
    model_size = mesh.shape.get("model", 1)

    def _model_free(bax) -> bool:
        flat = (bax,) if isinstance(bax, str) else (bax or ())
        return "model" not in flat

    def _kv_ax(bax, n: int, rule_name: str):
        ax = resolve_dim(rule_name, n, mesh, rules)
        return ax if (ax is not None and _model_free(bax)
                      and n % model_size == 0) else None

    def one(path, sds):
        names = [p.key for p in path if hasattr(p, "key")]
        nm = names[-1] if names else ""
        shp = sds.shape
        if nm == "pos":
            return P(resolve_dim("batchlike", shp[0], mesh, rules))
        if nm in ("k", "v", "ck", "cv"):
            if len(shp) == 5:      # (L, B, S, KV, D) stacked over layers
                b, s, kv = shp[1], shp[2], shp[3]
                bax = resolve_dim("batchlike", b, mesh, rules)
                if _kv_ax(bax, kv, "kv_or_seq"):
                    return P(None, bax, None, "model", None)
                if _kv_ax(bax, s, "seq"):
                    return P(None, bax, "model", None, None)
                return P(None, bax, None, None, None)
            if len(shp) == 4:      # (B, W, KV, D) per-layer ring (hybrid)
                b, w, kv = shp[0], shp[1], shp[2]
                bax = resolve_dim("batchlike", b, mesh, rules)
                if _kv_ax(bax, kv, "kv_or_seq"):
                    return P(bax, None, "model", None)
                if _kv_ax(bax, w, "seq"):
                    return P(bax, "model", None, None)
                return P(bax, None, None, None)
        if nm == "h":
            bax = resolve_dim("batchlike", shp[-4] if len(shp) > 3 else shp[0],
                              mesh, rules)
            if len(shp) == 5:      # ssm (L,B,H,P,N)
                hax = "model" if (shp[2] % model_size == 0
                                  and _model_free(bax)) else None
                return P(None, bax, hax, None, None)
            if len(shp) == 2:      # lru (B, width)
                bax = resolve_dim("batchlike", shp[0], mesh, rules)
                wax = "model" if (shp[1] % model_size == 0
                                  and _model_free(bax)) else None
                return P(bax, wax)
        if nm in ("x", "b", "c") and len(shp) == 4:  # ssm conv (L,B,K-1,C)
            bax = resolve_dim("batchlike", shp[1], mesh, rules)
            cax = "model" if (shp[3] % model_size == 0
                              and _model_free(bax)) else None
            return P(None, bax, None, cax)
        if len(shp) == 3 and nm == "conv":           # (B, K-1, C)
            bax = resolve_dim("batchlike", shp[0], mesh, rules)
            cax = "model" if (shp[2] % model_size == 0
                              and _model_free(bax)) else None
            return P(bax, None, cax)
        # default: shard dim0 batch-like if divisible
        bax = resolve_dim("batchlike", shp[0], mesh, rules) if sds.ndim else None
        return P(bax, *([None] * (sds.ndim - 1)))

    return jax.tree_util.tree_map_with_path(one, cache_abstract)


def logits_pspec(mesh: Mesh, batch: int, vocab: int, rules=None) -> P:
    bax = resolve_dim("batchlike", batch, mesh, rules)
    vax = resolve_dim("vocab", vocab, mesh, rules)
    flat = (vax,) if isinstance(vax, str) else (vax or ())
    used = (bax,) if isinstance(bax, str) else (bax or ())
    if any(a in used for a in flat):
        vax = None
    return P(bax, None, vax)


def named(mesh: Mesh, tree):
    return jax.tree.map(lambda s: NamedSharding(mesh, s), tree,
                        is_leaf=lambda x: isinstance(x, P))
