"""shard_map across jax versions: `jax.shard_map(..., check_vma=)` on new
jax, `jax.experimental.shard_map.shard_map(..., check_rep=)` on older."""

from __future__ import annotations

import jax

if hasattr(jax, "shard_map"):
    def shard_map(f, *, mesh, in_specs, out_specs, check_vma: bool = False):
        return jax.shard_map(f, mesh=mesh, in_specs=in_specs,
                             out_specs=out_specs, check_vma=check_vma)
else:
    from jax.experimental.shard_map import shard_map as _shard_map

    def shard_map(f, *, mesh, in_specs, out_specs, check_vma: bool = False):
        return _shard_map(f, mesh=mesh, in_specs=in_specs,
                          out_specs=out_specs, check_rep=check_vma)


def axis_size(axis_name) -> int:
    """Static mapped-axis size inside shard_map, across jax versions."""
    if hasattr(jax.lax, "axis_size"):
        return jax.lax.axis_size(axis_name)
    return jax.core.axis_frame(axis_name)
