"""Pallas TPU kernels: blockwise symmetric int8 quantize / dequantize.

The paper's I2 "compression-aware UCIe transfers" adapted to ICI: gradients
are block-quantized to int8 (+f32 scale per block) before crossing the
data-parallel axis, quartering the collective payload; the error-feedback
loop lives in `repro.train.compression`. Block size 256 keeps the absmax
reduction a single VPU pass per tile; both kernels are 1-D grids over
blocks with whole-block VMEM tiles.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _quant_kernel(x_ref, q_ref, s_ref):
    x = x_ref[...].astype(jnp.float32)              # (rows, block)
    absmax = jnp.max(jnp.abs(x), axis=1)
    scale = jnp.maximum(absmax, 1e-12) / 127.0
    q = jnp.clip(jnp.round(x / scale[:, None]), -127, 127)
    q_ref[...] = q.astype(jnp.int8)
    s_ref[...] = scale


def _dequant_kernel(q_ref, s_ref, x_ref):
    x_ref[...] = (q_ref[...].astype(jnp.float32)
                  * s_ref[...][:, None]).astype(x_ref.dtype)


@functools.partial(jax.jit, static_argnames=("block", "rows_per_tile",
                                             "interpret"))
def quantize_blocks(x2d: jnp.ndarray, *, block: int = 256,
                    rows_per_tile: int = 8, interpret: bool = False):
    """x2d: (n_blocks, block) f32/bf16 → (int8 blocks, f32 scales)."""
    nb, bl = x2d.shape
    assert bl == block
    rows = min(rows_per_tile, nb)
    assert nb % rows == 0
    return pl.pallas_call(
        _quant_kernel,
        grid=(nb // rows,),
        in_specs=[pl.BlockSpec((rows, block), lambda i: (i, 0))],
        out_specs=[pl.BlockSpec((rows, block), lambda i: (i, 0)),
                   pl.BlockSpec((rows,), lambda i: (i,))],
        out_shape=[jax.ShapeDtypeStruct((nb, block), jnp.int8),
                   jax.ShapeDtypeStruct((nb,), jnp.float32)],
        interpret=interpret,
    )(x2d)


@functools.partial(jax.jit, static_argnames=("rows_per_tile", "interpret",
                                             "out_dtype"))
def dequantize_blocks(q: jnp.ndarray, scales: jnp.ndarray, *,
                      rows_per_tile: int = 8, out_dtype=jnp.float32,
                      interpret: bool = False):
    nb, block = q.shape
    rows = min(rows_per_tile, nb)
    assert nb % rows == 0
    return pl.pallas_call(
        _dequant_kernel,
        grid=(nb // rows,),
        in_specs=[pl.BlockSpec((rows, block), lambda i: (i, 0)),
                  pl.BlockSpec((rows,), lambda i: (i,))],
        out_specs=pl.BlockSpec((rows, block), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((nb, block), out_dtype),
        interpret=interpret,
    )(q, scales)
