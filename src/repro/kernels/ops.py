"""jit'd public wrappers for the Pallas kernels.

`interpret` defaults to True off-TPU so every call path works (and is
validated) on CPU; on TPU the compiled kernels run natively.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.kernels import ref as ref_mod
from repro.kernels.flash_attention import flash_attention as _flash
from repro.kernels.int8_matmul import int8_matmul as _int8_mm
from repro.kernels.quantize import dequantize_blocks as _deq
from repro.kernels.quantize import quantize_blocks as _quant


def _interpret_default() -> bool:
    return jax.default_backend() != "tpu"


def int8_matmul(x, w_q, scales, **kw):
    kw.setdefault("interpret", _interpret_default())
    return _int8_mm(x, w_q, scales, **kw)


def quantize_weight(w):
    """Per-output-channel int8 weight quantization (serving load path)."""
    return ref_mod.quantize_weight_ref(w)


def flash_attention(q, k, v, **kw):
    kw.setdefault("interpret", _interpret_default())
    return _flash(q, k, v, **kw)


def quantize_blocks(x, *, block: int = 256, **kw):
    """Any-shape tensor → (int8 blocks, scales, orig_size). Pads the flat
    size to a whole number of (rows_per_tile × block) grid tiles."""
    kw.setdefault("interpret", _interpret_default())
    rows = kw.get("rows_per_tile", 8)
    flat = x.reshape(-1)
    n = flat.shape[0]
    pad = (-n) % (block * rows)
    if pad:
        flat = jnp.pad(flat, (0, pad))
    q, s = _quant(flat.reshape(-1, block), block=block, **kw)
    return q, s, n


def dequantize_blocks(q, scales, n, shape, dtype=jnp.float32, **kw):
    kw.setdefault("interpret", _interpret_default())
    flat = _deq(q, scales, out_dtype=dtype, **kw).reshape(-1)
    return flat[:n].reshape(shape)
