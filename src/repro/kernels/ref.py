"""Pure-jnp oracles for every Pallas kernel (the allclose targets)."""

from __future__ import annotations

import jax
import jax.numpy as jnp


def int8_matmul_ref(x: jnp.ndarray, w_q: jnp.ndarray,
                    scales: jnp.ndarray) -> jnp.ndarray:
    """x (M,K) float; w_q (K,N) int8; scales (N,) f32 per-out-channel."""
    acc = jnp.dot(x.astype(jnp.float32), w_q.astype(jnp.float32))
    return (acc * scales[None, :]).astype(x.dtype)


def quantize_channelwise_ref(w: jnp.ndarray, axes):
    """Symmetric int8 over `axes` (the contraction dims), keepdims f32 scale.

    THE weight quantizer: the serving wdtype='int8' pass
    (models/quantized.quantize_params) and the 2-D QDQ path below both call
    this, so a numerics tweak (clip range, scale floor) lands everywhere."""
    wf = w.astype(jnp.float32)
    absmax = jnp.max(jnp.abs(wf), axis=axes, keepdims=True)
    scale = jnp.maximum(absmax, 1e-8) / 127.0
    q = jnp.clip(jnp.round(wf / scale), -127, 127).astype(jnp.int8)
    return q, scale


def quantize_weight_ref(w: jnp.ndarray):
    """Symmetric per-output-channel int8 weight quantization. w (K,N)."""
    q, scale = quantize_channelwise_ref(w, (0,))
    return q, scale[0]


def flash_attention_ref(q, k, v, *, causal=True, window=0, scale=None):
    """q/k/v: (B, H, S, D) → (B, H, S, D). fp32 softmax oracle."""
    d = q.shape[-1]
    scale = scale if scale is not None else d ** -0.5
    s = jnp.einsum("bhqd,bhkd->bhqk", q.astype(jnp.float32),
                   k.astype(jnp.float32)) * scale
    sq, sk = q.shape[2], k.shape[2]
    diff = jnp.arange(sq)[:, None] - jnp.arange(sk)[None, :]
    ok = jnp.ones((sq, sk), bool)
    if causal:
        ok &= diff >= 0
    if window > 0:
        ok &= diff < window
    s = jnp.where(ok[None, None], s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    return jnp.einsum("bhqk,bhkd->bhqd", p,
                      v.astype(jnp.float32)).astype(q.dtype)


def quantize_blocks_ref(x: jnp.ndarray, block: int = 256):
    """Flatten x, pad to a block multiple, symmetric per-block int8.

    Returns (q (n_blocks, block) int8, scales (n_blocks,) f32, orig_size)."""
    flat = x.astype(jnp.float32).reshape(-1)
    n = flat.shape[0]
    pad = (-n) % block
    flat = jnp.pad(flat, (0, pad))
    blocks = flat.reshape(-1, block)
    absmax = jnp.max(jnp.abs(blocks), axis=1)
    scale = jnp.maximum(absmax, 1e-12) / 127.0
    q = jnp.clip(jnp.round(blocks / scale[:, None]), -127, 127).astype(jnp.int8)
    return q, scale, n


def dequantize_blocks_ref(q: jnp.ndarray, scales: jnp.ndarray, n: int,
                          shape, dtype=jnp.float32):
    flat = (q.astype(jnp.float32) * scales[:, None]).reshape(-1)[:n]
    return flat.reshape(shape).astype(dtype)
