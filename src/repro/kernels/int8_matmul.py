"""Pallas TPU kernel: weight-only INT8 × bf16 matmul with per-channel scales.

The paper's NPU chiplets are 15 TOPS INT8 (§II); this is that datapath on the
MXU: int8 weights are upcast in-register on the way into the systolic array,
accumulation is fp32 in a VMEM scratch tile, and the per-output-channel scale
is fused into the epilogue. Block sizes are MXU-aligned (multiples of 128 on
M/N; 512 on K keeps the (bm·bk + bk·bn + bm·bn) working set ≈ 1.4 MiB of
VMEM at the 128×512×128 default — well inside the ~16 MiB/core budget while
deep enough to amortize the accumulate loop).

Grid: (M/bm, N/bn, K/bk), K innermost ('arbitrary') so the fp32 accumulator
tile lives across the K sweep; M/N are 'parallel'.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.pltpu_compat import CompilerParams

# default (bm, bn, bk) — dispatch predicates (models/quantized.qeinsum) use
# these to decide kernel eligibility, so they live here with the kernel
DEFAULT_BLOCKS = (128, 128, 512)


def blocks_fit(m: int, n: int, k: int) -> bool:
    """True iff (m, n, k) tile evenly under the clamped default blocks
    (bm/bn/bk = min(default, dim)) — the kernel's shape contract."""
    bm, bn, bk = DEFAULT_BLOCKS
    return (m % min(bm, m) == 0 and n % min(bn, n) == 0
            and k % min(bk, k) == 0)


def _kernel(x_ref, w_ref, s_ref, o_ref, acc_ref, *, n_k: int):
    @pl.when(pl.program_id(2) == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    x = x_ref[...]                                  # (bm, bk) bf16
    w = w_ref[...].astype(jnp.bfloat16)             # (bk, bn) int8 → bf16 (MXU)
    acc_ref[...] += jax.lax.dot(x, w, preferred_element_type=jnp.float32)

    @pl.when(pl.program_id(2) == n_k - 1)
    def _done():
        o_ref[...] = (acc_ref[...] * s_ref[...][None, :]).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("bm", "bn", "bk", "interpret"))
def int8_matmul(x: jnp.ndarray, w_q: jnp.ndarray, scales: jnp.ndarray,
                *, bm: int = DEFAULT_BLOCKS[0], bn: int = DEFAULT_BLOCKS[1],
                bk: int = DEFAULT_BLOCKS[2],
                interpret: bool = False) -> jnp.ndarray:
    """x (M,K) bf16/f32 · w_q (K,N) int8 · scales (N,) f32 → (M,N) x.dtype."""
    m, k = x.shape
    k2, n = w_q.shape
    assert k == k2 and scales.shape == (n,), (x.shape, w_q.shape, scales.shape)
    bm, bn, bk = min(bm, m), min(bn, n), min(bk, k)
    assert m % bm == 0 and n % bn == 0 and k % bk == 0, (m, n, k, bm, bn, bk)
    n_k = k // bk
    grid = (m // bm, n // bn, n_k)
    return pl.pallas_call(
        functools.partial(_kernel, n_k=n_k),
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, h: (i, h)),
            pl.BlockSpec((bk, bn), lambda i, j, h: (h, j)),
            pl.BlockSpec((bn,), lambda i, j, h: (j,)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, h: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), x.dtype),
        scratch_shapes=[pltpu.VMEM((bm, bn), jnp.float32)],
        interpret=interpret,
        compiler_params=CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
    )(x, w_q, scales)
