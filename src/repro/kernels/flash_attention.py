"""Pallas TPU kernels: blockwise online-softmax attention (causal + window).

VMEM tiling: (block_q × D) query tile resident; K/V stream through in
(block_k × D) tiles along the innermost grid dim; the m/l/acc running
statistics live in VMEM scratch across the K sweep (FlashAttention-2
schedule adapted to the MXU: both matmuls per tile are 128-aligned).
The causal/window structure prunes dead tiles via `pl.when` on block
indices, so the kernel does ~half the tiles of a dense-masked pass.

Layout: q/k/v (B, H, S, D) — B·H is the embarrassingly-parallel leading
grid dim; q blocks next; k blocks innermost ('arbitrary').

`flash_attention_paged` is the CHUNK-PREFILL variant (PR 4): a fixed-size
chunk of query rows at global positions q_offset+i attends against the
serving engine's shared KV page POOLS through a scalar-prefetched page
table (the same gather convention as kernels/decode_attention — the index
maps are shared via `paged_index_maps`). `kv_len` is the live length (rows
the prompt has actually written), so stale pool rows and chunk padding are
masked exactly like the decode kernel's ragged prefix. Optional
k_scale/v_scale operands fuse int8 dequant into the tile loads, giving the
int8 KV pool a chunked prefill path with no densify/cast step.

NOT YET COVERED — MLA latent rows: `v_dim=` chunk attention (one latent
pool as both K and V — see kernels/decode_attention's note) runs the exact
jnp reference path in models/attention.chunk_attention_paged; the
kernel-side latent gather is a recorded follow-on.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.pltpu_compat import NEG_INF, CompilerParams
from repro.kernels.decode_attention import paged_index_maps


def _kernel(q_ref, k_ref, v_ref, o_ref, m_ref, l_ref, acc_ref, *,
            scale: float, causal: bool, window: int, block_q: int,
            block_k: int, n_k: int):
    iq = pl.program_id(1)
    ik = pl.program_id(2)

    @pl.when(ik == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q_pos = iq * block_q + jax.lax.broadcasted_iota(jnp.int32, (block_q, block_k), 0)
    k_pos = ik * block_k + jax.lax.broadcasted_iota(jnp.int32, (block_q, block_k), 1)

    # tile liveness: any (q,k) pair in range?
    live = True
    if causal:
        live = jnp.logical_and(live, iq * block_q + block_q - 1 >= ik * block_k)
    if window > 0:
        live = jnp.logical_and(
            live, iq * block_q <= ik * block_k + block_k - 1 + window)

    @pl.when(live)
    def _tile():
        q = q_ref[0, ...].astype(jnp.float32)       # (block_q, D)
        k = k_ref[0, ...].astype(jnp.float32)       # (block_k, D)
        s = jax.lax.dot_general(
            q, k, (((1,), (1,)), ((), ())),
            preferred_element_type=jnp.float32) * scale
        ok = jnp.ones((block_q, block_k), jnp.bool_)
        if causal:
            ok &= q_pos >= k_pos
        if window > 0:
            ok &= q_pos - k_pos < window
        s = jnp.where(ok, s, NEG_INF)
        m_prev = m_ref[...]
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=1, keepdims=True))
        p = jnp.exp(s - m_new)
        corr = jnp.exp(m_prev - m_new)
        l_ref[...] = l_ref[...] * corr + jnp.sum(p, axis=1, keepdims=True)
        m_ref[...] = m_new
        v = v_ref[0, ...].astype(jnp.float32)
        acc_ref[...] = acc_ref[...] * corr + jax.lax.dot(
            p.astype(v.dtype), v, preferred_element_type=jnp.float32)

    @pl.when(ik == n_k - 1)
    def _done():
        o_ref[0, ...] = (acc_ref[...]
                         / jnp.maximum(l_ref[...], 1e-30)).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=(
    "causal", "window", "scale", "block_q", "block_k", "interpret"))
def flash_attention(q, k, v, *, causal: bool = True, window: int = 0,
                    scale=None, block_q: int = 128, block_k: int = 128,
                    interpret: bool = False):
    """q/k/v: (B, H, S, D) → (B, H, S, D)."""
    b, h, sq, d = q.shape
    sk = k.shape[2]
    scale = float(scale if scale is not None else d ** -0.5)
    block_q = min(block_q, sq)
    block_k = min(block_k, sk)
    assert sq % block_q == 0 and sk % block_k == 0
    n_k = sk // block_k
    grid = (b * h, sq // block_q, n_k)
    qf = q.reshape(b * h, sq, d)
    kf = k.reshape(b * h, sk, d)
    vf = v.reshape(b * h, sk, d)
    out = pl.pallas_call(
        functools.partial(_kernel, scale=scale, causal=causal, window=window,
                          block_q=block_q, block_k=block_k, n_k=n_k),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, block_q, d), lambda g, i, j: (g, i, 0)),
            pl.BlockSpec((1, block_k, d), lambda g, i, j: (g, j, 0)),
            pl.BlockSpec((1, block_k, d), lambda g, i, j: (g, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, block_q, d), lambda g, i, j: (g, i, 0)),
        out_shape=jax.ShapeDtypeStruct((b * h, sq, d), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((block_q, 1), jnp.float32),   # m
            pltpu.VMEM((block_q, 1), jnp.float32),   # l
            pltpu.VMEM((block_q, d), jnp.float32),   # acc
        ],
        interpret=interpret,
        compiler_params=CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
    )(qf, kf, vf)
    return out.reshape(b, h, sq, d)


# ---------------------------------------------------------------------------
# Chunk-prefill attention against the paged KV pool
# ---------------------------------------------------------------------------

def _paged_kernel(off_ref, kvlen_ref, pt_ref, *refs, scale: float,
                  window: int, block_q: int, block_k: int, n_k: int,
                  quantized: bool):
    """One (q-block × k-block) online-softmax tile of chunk prefill.

    Ref order after the scalar prefetch (q_offset, kv_len, page_table):
    inputs (q, k, v[, ks, vs]), output (o), scratch (m, l, acc). The page
    table is consumed by the K/V index_maps — the body only sees positions.
    """
    refs = list(refs)
    q_ref, k_ref, v_ref = refs[0], refs[1], refs[2]
    ks_ref, vs_ref = (refs[3], refs[4]) if quantized else (None, None)
    o_ref, m_ref, l_ref, acc_ref = refs[-4], refs[-3], refs[-2], refs[-1]
    ib = pl.program_id(0)
    iq = pl.program_id(2)
    ik = pl.program_id(3)
    kvlen = kvlen_ref[ib]
    q_lo = off_ref[ib] + iq * block_q          # global position of q row 0

    @pl.when(ik == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    # tile liveness: below the live prefix AND not strictly above the causal
    # frontier of the block's last q row AND (windowed) not entirely below
    # the first q row's window floor
    live = jnp.logical_and(ik * block_k < kvlen,
                           ik * block_k <= q_lo + block_q - 1)
    if window > 0:
        live = jnp.logical_and(live, ik * block_k + block_k > q_lo - window)

    @pl.when(live)
    def _tile():
        q = q_ref[0, 0].astype(jnp.float32)             # (block_q, D)
        k = k_ref[0, :, 0, :].astype(jnp.float32)       # (block_k, D)
        if ks_ref is not None:                          # fused int8 dequant
            k = k * ks_ref[0, :, 0].astype(jnp.float32)[:, None]
        s = jax.lax.dot_general(
            q, k, (((1,), (1,)), ((), ())),
            preferred_element_type=jnp.float32) * scale
        q_pos = q_lo + jax.lax.broadcasted_iota(
            jnp.int32, (block_q, block_k), 0)
        k_pos = ik * block_k + jax.lax.broadcasted_iota(
            jnp.int32, (block_q, block_k), 1)
        ok = jnp.logical_and(k_pos <= q_pos, k_pos < kvlen)
        if window > 0:
            ok = jnp.logical_and(ok, q_pos - k_pos < window)
        s = jnp.where(ok, s, NEG_INF)
        m_prev = m_ref[...]
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=1, keepdims=True))
        # rows with no valid key yet keep m == NEG_INF; NEG_INF is finite, so
        # exp(s - m) would be exp(0)=1 for their masked entries — zero them
        p = jnp.where(ok, jnp.exp(s - m_new), 0.0)
        corr = jnp.exp(m_prev - m_new)
        l_ref[...] = l_ref[...] * corr + jnp.sum(p, axis=1, keepdims=True)
        m_ref[...] = m_new
        v = v_ref[0, :, 0, :].astype(jnp.float32)
        if vs_ref is not None:
            v = v * vs_ref[0, :, 0].astype(jnp.float32)[:, None]
        acc_ref[...] = acc_ref[...] * corr + jax.lax.dot(
            p, v, preferred_element_type=jnp.float32)

    @pl.when(ik == n_k - 1)
    def _done():
        o_ref[0, 0] = (acc_ref[...]
                       / jnp.maximum(l_ref[...], 1e-30)).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=(
    "window", "scale", "block_q", "block_k", "interpret"))
def flash_attention_paged(q, k_pool, v_pool, page_table, q_offset, kv_len, *,
                          k_scale=None, v_scale=None, window: int = 0,
                          scale=None, block_q: int = 128,
                          block_k: "int | None" = None,
                          interpret: bool = False):
    """Chunk-prefill attention through the page table.

    Args:
      q:          (B, C, KV, G, D) — one fixed-size prefill chunk of queries;
                  row i sits at global position q_offset[b] + i.
      k_pool/v_pool: shared (n_pages, page_size, KV, D) page pools.
      page_table: (B, pages_per_seq) int32 — the slot's physical page per
                  logical page (null page 0 for unmapped entries).
      q_offset:   (B,) int32 — global position of the chunk's first row.
      kv_len:     (B,) int32 — live rows (this chunk's K/V already written).
      k_scale/v_scale: optional (n_pages, page_size, KV) int8 dequant scales.
      window:     sliding-window size (0 = full causal).

    Returns (B, C, KV, G, D) in q.dtype, fp32 accumulation throughout.
    """
    b, cq, nkv, g, d = q.shape
    assert (k_scale is None) == (v_scale is None)
    quantized = k_scale is not None
    scale = float(scale if scale is not None else d ** -0.5)
    page_size = k_pool.shape[1]
    pages_per_seq = page_table.shape[1]
    block_k = page_size if block_k is None else min(block_k, page_size)
    assert page_size % block_k == 0, (page_size, block_k)
    bpp = page_size // block_k
    n_k = pages_per_seq * bpp
    block_q = min(block_q, cq)
    assert cq % block_q == 0, (cq, block_q)
    h = nkv * g
    q_offset = jnp.asarray(q_offset, jnp.int32).reshape(b)
    kv_len = jnp.broadcast_to(jnp.asarray(kv_len, jnp.int32).reshape(-1), (b,))
    page_table = jnp.asarray(page_table, jnp.int32)
    qf = jnp.moveaxis(q.reshape(b, cq, h, d), 1, 2)    # (B, H, C, D)

    q_spec = pl.BlockSpec((1, 1, block_q, d),
                          lambda ib, ih, iq, ik, *_: (ib, ih, iq, 0))
    out_spec = pl.BlockSpec((1, 1, block_q, d),
                            lambda ib, ih, iq, ik, *_: (ib, ih, iq, 0))
    kv_map, s_map = paged_index_maps(bpp, n_prefetch=3, g=g)
    kv_spec = pl.BlockSpec((1, block_k, 1, d), kv_map)
    in_specs = [q_spec, kv_spec, kv_spec]
    operands = [qf, k_pool, v_pool]
    if quantized:
        s_spec = pl.BlockSpec((1, block_k, 1), s_map)
        in_specs += [s_spec, s_spec]
        operands += [k_scale, v_scale]
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=3,
        grid=(b, h, cq // block_q, n_k),
        in_specs=in_specs,
        out_specs=out_spec,
        scratch_shapes=[
            pltpu.VMEM((block_q, 1), jnp.float32),   # m
            pltpu.VMEM((block_q, 1), jnp.float32),   # l
            pltpu.VMEM((block_q, d), jnp.float32),   # acc
        ],
    )
    out = pl.pallas_call(
        functools.partial(_paged_kernel, scale=scale, window=window,
                          block_q=block_q, block_k=block_k, n_k=n_k,
                          quantized=quantized),
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((b, h, cq, d), q.dtype),
        interpret=interpret,
        compiler_params=CompilerParams(
            dimension_semantics=("parallel", "parallel", "parallel",
                                 "arbitrary")),
    )(q_offset, kv_len, page_table, *operands)
    return jnp.moveaxis(out, 1, 2).reshape(b, cq, nkv, g, d)
