"""Pallas TPU kernel: blockwise online-softmax attention (causal + window).

VMEM tiling: (block_q × D) query tile resident; K/V stream through in
(block_k × D) tiles along the innermost grid dim; the m/l/acc running
statistics live in VMEM scratch across the K sweep (FlashAttention-2
schedule adapted to the MXU: both matmuls per tile are 128-aligned).
The causal/window structure prunes dead tiles via `pl.when` on block
indices, so the kernel does ~half the tiles of a dense-masked pass.

Layout: q/k/v (B, H, S, D) — B·H is the embarrassingly-parallel leading
grid dim; q blocks next; k blocks innermost ('arbitrary').
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.pltpu_compat import NEG_INF, CompilerParams


def _kernel(q_ref, k_ref, v_ref, o_ref, m_ref, l_ref, acc_ref, *,
            scale: float, causal: bool, window: int, block_q: int,
            block_k: int, n_k: int):
    iq = pl.program_id(1)
    ik = pl.program_id(2)

    @pl.when(ik == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q_pos = iq * block_q + jax.lax.broadcasted_iota(jnp.int32, (block_q, block_k), 0)
    k_pos = ik * block_k + jax.lax.broadcasted_iota(jnp.int32, (block_q, block_k), 1)

    # tile liveness: any (q,k) pair in range?
    live = True
    if causal:
        live = jnp.logical_and(live, iq * block_q + block_q - 1 >= ik * block_k)
    if window > 0:
        live = jnp.logical_and(
            live, iq * block_q <= ik * block_k + block_k - 1 + window)

    @pl.when(live)
    def _tile():
        q = q_ref[0, ...].astype(jnp.float32)       # (block_q, D)
        k = k_ref[0, ...].astype(jnp.float32)       # (block_k, D)
        s = jax.lax.dot_general(
            q, k, (((1,), (1,)), ((), ())),
            preferred_element_type=jnp.float32) * scale
        ok = jnp.ones((block_q, block_k), jnp.bool_)
        if causal:
            ok &= q_pos >= k_pos
        if window > 0:
            ok &= q_pos - k_pos < window
        s = jnp.where(ok, s, NEG_INF)
        m_prev = m_ref[...]
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=1, keepdims=True))
        p = jnp.exp(s - m_new)
        corr = jnp.exp(m_prev - m_new)
        l_ref[...] = l_ref[...] * corr + jnp.sum(p, axis=1, keepdims=True)
        m_ref[...] = m_new
        v = v_ref[0, ...].astype(jnp.float32)
        acc_ref[...] = acc_ref[...] * corr + jax.lax.dot(
            p.astype(v.dtype), v, preferred_element_type=jnp.float32)

    @pl.when(ik == n_k - 1)
    def _done():
        o_ref[0, ...] = (acc_ref[...]
                         / jnp.maximum(l_ref[...], 1e-30)).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=(
    "causal", "window", "scale", "block_q", "block_k", "interpret"))
def flash_attention(q, k, v, *, causal: bool = True, window: int = 0,
                    scale=None, block_q: int = 128, block_k: int = 128,
                    interpret: bool = False):
    """q/k/v: (B, H, S, D) → (B, H, S, D)."""
    b, h, sq, d = q.shape
    sk = k.shape[2]
    scale = float(scale if scale is not None else d ** -0.5)
    block_q = min(block_q, sq)
    block_k = min(block_k, sk)
    assert sq % block_q == 0 and sk % block_k == 0
    n_k = sk // block_k
    grid = (b * h, sq // block_q, n_k)
    qf = q.reshape(b * h, sq, d)
    kf = k.reshape(b * h, sk, d)
    vf = v.reshape(b * h, sk, d)
    out = pl.pallas_call(
        functools.partial(_kernel, scale=scale, causal=causal, window=window,
                          block_q=block_q, block_k=block_k, n_k=n_k),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, block_q, d), lambda g, i, j: (g, i, 0)),
            pl.BlockSpec((1, block_k, d), lambda g, i, j: (g, j, 0)),
            pl.BlockSpec((1, block_k, d), lambda g, i, j: (g, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, block_q, d), lambda g, i, j: (g, i, 0)),
        out_shape=jax.ShapeDtypeStruct((b * h, sq, d), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((block_q, 1), jnp.float32),   # m
            pltpu.VMEM((block_q, 1), jnp.float32),   # l
            pltpu.VMEM((block_q, d), jnp.float32),   # acc
        ],
        interpret=interpret,
        compiler_params=CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
    )(qf, kf, vf)
    return out.reshape(b, h, sq, d)
