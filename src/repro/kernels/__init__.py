"""Pallas TPU kernels for the compute hot-spots (validated interpret=True):

  int8_matmul      — the paper's 15 TOPS INT8 NPU datapath on the MXU
  flash_attention  — blockwise online-softmax attention (prefill hot-spot)
  quantize         — I2 compression-aware transfer payloads (gradient sync)

Each has a pure-jnp oracle in ref.py; ops.py holds the jit'd wrappers.
"""

from repro.kernels import ops, ref

__all__ = ["ops", "ref"]
