"""Shared Pallas-TPU version shims + kernel constants.

jax renamed `pltpu.TPUCompilerParams` → `pltpu.CompilerParams`; every kernel
imports the alias from here so a future rename is a one-line fix.
"""

from __future__ import annotations

from jax.experimental.pallas import tpu as pltpu

CompilerParams = getattr(pltpu, "CompilerParams", None) \
    or pltpu.TPUCompilerParams

NEG_INF = -1e30
