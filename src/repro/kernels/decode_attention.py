"""Pallas TPU kernel: single-query (decode) attention against a KV cache.

The decode hot loop issues one query per sequence against a (B, Smax, KV, D)
cache where only the first `kv_len[b]` rows are valid. This kernel streams the
cache through VMEM in (block_k × D) tiles with FlashAttention-style online
softmax, so the cache is read once from HBM and never materialized, copied, or
cast wholesale (the failure mode the pure-jnp path risks on long contexts).

GQA is native: the query tile is the (G, D) group of query heads that shares
one KV head, so both matmuls per tile are (G×D)·(D×block_k) and
(G×block_k)·(block_k×D) — MXU work proportional to real heads only.

Grid: (B, KV, n_k) — batch and kv-head are embarrassingly parallel; the
k-block sweep is innermost ('arbitrary') so the m/l/acc running statistics
live in VMEM scratch across it. `kv_len` rides scalar prefetch (SMEM), which
lets `pl.when` skip tiles that lie entirely beyond the valid prefix (or, with
a sliding window, before it): a 4k-deep cache at kv_len=300 runs 3 tiles, not
32.

Paged mode (`page_table=`): the caches are shared page POOLS of shape
(n_pages, page_size, KV, D) — no batch dim — and a (B, pages_per_seq) int32
page table maps each sequence's logical k-blocks to physical pages. The table
rides scalar prefetch alongside `kv_len`, so the K/V BlockSpec index_maps
gather tiles *through* it: tile ik of sequence ib streams from physical page
`page_table[ib, ik // blocks_per_page]`. Logical positions (and therefore the
kv_len / sliding-window masks and the `pl.when` tile-liveness skip) are
unchanged — a dead logical page costs one skipped `pl.when` body, and the
serving engine points unmapped table entries at a reserved null page so the
prefetch DMA always has a valid source.

INT8 mode (`k_scale=`/`v_scale=`): the caches/pools store int8 rows and the
scales hold one f16 factor per (position, kv head) — cache shape minus D.
The scale tiles ride as VMEM operands right next to their K/V tiles (same
index_map, so the paged gather walks the page table once for both), and
dequant `int8 → f32 × scale` is fused into the tile load feeding the MXU —
the cache crosses HBM at 1 byte/element + 2/D scale overhead, which is what
halves decode HBM traffic vs the bf16 pool (the tokens/s bound at batch ≤
n_slots). The paper's NPUs are 15 TOPS INT8 (§II); this is the KV half of
that datapath (kernels/int8_matmul is the weight half).

`interpret=True` runs the same kernel on CPU — the tests' numerics oracle is
`models.attention`'s reference path.

NOT YET COVERED — MLA latent rows (models/mla.py): the latent family passes
ONE (kv_lora_rank + qk_rope_dim)-wide pool as both K and V with values the
leading kv_lora_rank columns of each row (`v_dim=` in
models/attention.decode_attention). A kernel-side latent gather would load
each row once and slice V in-register; until then `v_dim` forces the exact
jnp reference path, which is the CPU oracle anyway. fp8 (e5m2) caches
likewise stay on the jnp path (dense layout only — see serve/engine).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.pltpu_compat import NEG_INF, CompilerParams


def paged_index_maps(bpp: int, *, n_prefetch: int, g: int = 1):
    """(kv_map, s_map) BlockSpec index_map factories for page-pool gathers.

    Shared by the decode kernel below and the chunk-prefill kernel in
    kernels/flash_attention: both stream K/V (and int8 scale) tiles out of a
    (n_pages, page_size, ...) pool through a scalar-prefetched page table.

    Grid convention: (batch, head, [q-block,] k-block) with the K-BLOCK INDEX
    LAST among grid dims and the PAGE TABLE LAST among the `n_prefetch`
    scalar-prefetch refs. `bpp` is k-blocks per page; `g` divides a flattened
    query-head grid index down to its KV head (1 when the grid already runs
    over KV heads, as in the decode kernel).

    Device-locality contract (sharded serving, PR 5): the table values these
    maps read become DMA source pages, so every entry must address the pool
    operand THIS kernel instance was handed. Under the sharded engine the
    global pool is partitioned page-wise across the mesh's data axis and the
    kernel runs inside shard_map — each shard's table holds LOCAL ids into
    its own (n_pages, page_size, ...) partition (shard-local null page 0
    included), so the scalar-prefetch gather can never name another device's
    page. Feeding a GLOBAL page id here would index past the local pool —
    keep tables device-local (serve/scheduler reserves pages per shard and
    ShardedServeEngine.assert_local_page_tables pins the invariant)."""

    def kv_map(ib, ih, *rest):
        ik, pt_ref = rest[len(rest) - n_prefetch - 1], rest[-1]
        return pt_ref[ib, ik // bpp], ik % bpp, ih // g, 0

    def s_map(ib, ih, *rest):
        ik, pt_ref = rest[len(rest) - n_prefetch - 1], rest[-1]
        return pt_ref[ib, ik // bpp], ik % bpp, ih // g

    return kv_map, s_map


def _body(kvlen_ref, q_ref, k_ref, v_ref, ks_ref, vs_ref, o_ref,
          m_ref, l_ref, acc_ref, *, scale: float, window: int, block_k: int,
          n_k: int):
    """Online-softmax tile update, shared by all four (paged × int8) kernel
    layouts — position-based, so it is blind to where the tile bytes came
    from and whether they were dequantized on the way in."""
    b = pl.program_id(0)
    ik = pl.program_id(2)
    kvlen = kvlen_ref[b]

    @pl.when(ik == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    # tile liveness: any k position in [ik·bk, ik·bk+bk) ∩ valid range?
    live = ik * block_k < kvlen
    if window > 0:
        live = jnp.logical_and(live,
                               ik * block_k + block_k - 1 >= kvlen - window)

    @pl.when(live)
    def _tile():
        q = q_ref[0, 0].astype(jnp.float32)             # (G, D)
        k = k_ref[0, :, 0, :].astype(jnp.float32)       # (block_k, D)
        if ks_ref is not None:                          # fused dequant
            k = k * ks_ref[0, :, 0].astype(jnp.float32)[:, None]
        s = jax.lax.dot_general(
            q, k, (((1,), (1,)), ((), ())),
            preferred_element_type=jnp.float32) * scale  # (G, block_k)
        k_pos = ik * block_k + jax.lax.broadcasted_iota(
            jnp.int32, s.shape, 1)
        ok = k_pos < kvlen
        if window > 0:
            ok = jnp.logical_and(ok, k_pos >= kvlen - window)
        s = jnp.where(ok, s, NEG_INF)
        m_prev = m_ref[...]
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=1, keepdims=True))
        p = jnp.exp(s - m_new)
        corr = jnp.exp(m_prev - m_new)
        l_ref[...] = l_ref[...] * corr + jnp.sum(p, axis=1, keepdims=True)
        m_ref[...] = m_new
        v = v_ref[0, :, 0, :].astype(jnp.float32)       # (block_k, D)
        if vs_ref is not None:
            v = v * vs_ref[0, :, 0].astype(jnp.float32)[:, None]
        acc_ref[...] = acc_ref[...] * corr + jax.lax.dot(
            p, v, preferred_element_type=jnp.float32)

    @pl.when(ik == n_k - 1)
    def _done():
        o_ref[0, 0] = (acc_ref[...]
                       / jnp.maximum(l_ref[...], 1e-30)).astype(o_ref.dtype)


def _make_kernel(*, paged: bool, quantized: bool, **kw):
    """Ref order: scalar-prefetch (kvlen[, page_table]), inputs
    (q, k, v[, ks, vs]), output (o), scratch (m, l, acc). The page table is
    consumed by the K/V (and scale) index_maps — the gather happens in the
    prefetch DMA — so the body never sees it."""

    def kernel(*refs):
        refs = list(refs)
        kvlen_ref = refs.pop(0)
        if paged:
            refs.pop(0)                     # pt_ref: index_map-only
        q_ref, k_ref, v_ref = refs[0], refs[1], refs[2]
        ks_ref, vs_ref = (refs[3], refs[4]) if quantized else (None, None)
        o_ref, m_ref, l_ref, acc_ref = refs[-4], refs[-3], refs[-2], refs[-1]
        _body(kvlen_ref, q_ref, k_ref, v_ref, ks_ref, vs_ref, o_ref,
              m_ref, l_ref, acc_ref, **kw)

    return kernel


@functools.partial(jax.jit, static_argnames=(
    "window", "scale", "block_k", "interpret"))
def decode_attention(q, k_cache, v_cache, kv_len, *, page_table=None,
                     k_scale=None, v_scale=None,
                     window: int = 0, scale=None, block_k: int = 128,
                     interpret: bool = False):
    """Single-position attention against a ragged-valid KV cache.

    Args:
      q:        (B, 1, KV, G, D) — one query position, grouped query heads.
      k_cache:  (B, Smax, KV, D) storage-dtype cache (never upcast wholesale);
                with `page_table`, a shared (n_pages, page_size, KV, D) pool.
      v_cache:  same layout as k_cache.
      kv_len:   () or (B,) int — number of valid cache rows per sequence
                (this step's k/v must already be written).
      page_table: optional (B, pages_per_seq) int32 — physical page of each
                sequence's logical page; logical depth is pages_per_seq ×
                page_size. Unmapped entries must point at a valid (null) page.
      k_scale/v_scale: optional per-row dequant scales for int8 caches —
                cache shape minus the D dim ((B, Smax, KV) dense,
                (n_pages, page_size, KV) paged). Dequant is fused into the
                tile loads; both must be given together.
      window:   sliding-window size (0 = full attention over the valid prefix).
      scale:    logit scale; defaults to D**-0.5.

    Returns (B, 1, KV, G, D) in q.dtype, fp32 accumulation throughout.
    """
    b, sq, nkv, g, d = q.shape
    assert sq == 1, f"decode kernel takes one query position, got {sq}"
    assert (k_scale is None) == (v_scale is None)
    quantized = k_scale is not None
    scale = float(scale if scale is not None else d ** -0.5)
    kv_len = jnp.broadcast_to(
        jnp.asarray(kv_len, jnp.int32).reshape(-1), (b,))
    qf = q.reshape(b, nkv, g, d)
    scratch_shapes = [
        pltpu.VMEM((g, 1), jnp.float32),   # m
        pltpu.VMEM((g, 1), jnp.float32),   # l
        pltpu.VMEM((g, d), jnp.float32),   # acc
    ]
    q_spec = pl.BlockSpec((1, 1, g, d), lambda ib, ih, ik, *_: (ib, ih, 0, 0))
    out_spec = pl.BlockSpec((1, 1, g, d), lambda ib, ih, ik, *_: (ib, ih, 0, 0))

    if page_table is None:
        smax = k_cache.shape[1]
        block_k = min(block_k, smax)
        assert smax % block_k == 0, (smax, block_k)
        n_k = smax // block_k
        kv_spec = pl.BlockSpec((1, block_k, 1, d),
                               lambda ib, ih, ik, *_: (ib, ik, ih, 0))
        in_specs = [q_spec, kv_spec, kv_spec]
        operands = [qf, k_cache, v_cache]
        if quantized:
            s_spec = pl.BlockSpec((1, block_k, 1),
                                  lambda ib, ih, ik, *_: (ib, ik, ih))
            in_specs += [s_spec, s_spec]
            operands += [k_scale, v_scale]
        grid_spec = pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=1,
            grid=(b, nkv, n_k),
            in_specs=in_specs,
            out_specs=out_spec,
            scratch_shapes=scratch_shapes,
        )
        out = pl.pallas_call(
            _make_kernel(paged=False, quantized=quantized, scale=scale,
                         window=window, block_k=block_k, n_k=n_k),
            grid_spec=grid_spec,
            out_shape=jax.ShapeDtypeStruct((b, nkv, g, d), q.dtype),
            interpret=interpret,
            compiler_params=CompilerParams(
                dimension_semantics=("parallel", "parallel", "arbitrary")),
        )(kv_len, qf, *operands[1:])
        return out.reshape(b, 1, nkv, g, d)

    # ------------------------------------------------------------- paged path
    page_size = k_cache.shape[1]
    pages_per_seq = page_table.shape[1]
    assert page_table.shape[0] == b, (page_table.shape, b)
    block_k = min(block_k, page_size)
    assert page_size % block_k == 0, (page_size, block_k)
    bpp = page_size // block_k              # k-blocks per page
    n_k = pages_per_seq * bpp               # logical k-block sweep
    page_table = jnp.asarray(page_table, jnp.int32)

    # physical page of each tile's logical page via prefetch; the scale tile
    # gathers through the same table entry as its K/V tile
    kv_map, s_map = paged_index_maps(bpp, n_prefetch=2)

    kv_spec = pl.BlockSpec((1, block_k, 1, d), kv_map)
    in_specs = [q_spec, kv_spec, kv_spec]
    operands = [k_cache, v_cache]
    if quantized:
        s_spec = pl.BlockSpec((1, block_k, 1), s_map)
        in_specs += [s_spec, s_spec]
        operands += [k_scale, v_scale]
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=(b, nkv, n_k),
        in_specs=in_specs,
        out_specs=out_spec,
        scratch_shapes=scratch_shapes,
    )
    out = pl.pallas_call(
        _make_kernel(paged=True, quantized=quantized, scale=scale,
                     window=window, block_k=block_k, n_k=n_k),
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((b, nkv, g, d), q.dtype),
        interpret=interpret,
        compiler_params=CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
    )(kv_len, page_table, qf, *operands)
    return out.reshape(b, 1, nkv, g, d)
