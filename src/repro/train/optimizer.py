"""AdamW + schedules + global-norm clipping — pure-pytree, optax-free.

Optimizer state shards exactly like its params (FSDP): the state tree mirrors
the param tree, so `schema_pspecs` applies verbatim.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Dict

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class OptimizerConfig:
    peak_lr: float = 3e-4
    end_lr_frac: float = 0.1
    warmup_steps: int = 100
    total_steps: int = 10_000
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: float = 1.0
    # grad dtype crossing the DP reduction; 'int8' engages the paper-analog
    # compression-aware sync (train/compression.py)
    grad_sync_dtype: str = "bf16"


def lr_at(cfg: OptimizerConfig, step: jnp.ndarray) -> jnp.ndarray:
    """Linear warmup → cosine decay to end_lr_frac·peak."""
    step = step.astype(jnp.float32)
    warm = cfg.peak_lr * step / max(cfg.warmup_steps, 1)
    t = jnp.clip((step - cfg.warmup_steps)
                 / max(cfg.total_steps - cfg.warmup_steps, 1), 0.0, 1.0)
    cos = cfg.end_lr_frac + (1 - cfg.end_lr_frac) * 0.5 * (1 + jnp.cos(jnp.pi * t))
    return jnp.where(step < cfg.warmup_steps, warm, cfg.peak_lr * cos)


def init_opt_state(params) -> Dict[str, Any]:
    zeros = lambda p: jnp.zeros(p.shape, jnp.float32)  # noqa: E731
    return {
        "m": jax.tree.map(zeros, params),
        "v": jax.tree.map(zeros, params),
        "step": jnp.zeros((), jnp.int32),
    }


def global_norm(tree) -> jnp.ndarray:
    return jnp.sqrt(sum(jnp.sum(jnp.square(g.astype(jnp.float32)))
                        for g in jax.tree.leaves(tree)))


def clip_by_global_norm(grads, max_norm: float):
    norm = global_norm(grads)
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(norm, 1e-9))
    return jax.tree.map(lambda g: (g.astype(jnp.float32) * scale), grads), norm


def adamw_update(params, grads, opt_state, cfg: OptimizerConfig):
    """One AdamW step. grads may be any float dtype; math is fp32."""
    step = opt_state["step"] + 1
    lr = lr_at(cfg, step)
    b1, b2 = cfg.b1, cfg.b2
    bc1 = 1.0 - b1 ** step.astype(jnp.float32)
    bc2 = 1.0 - b2 ** step.astype(jnp.float32)

    def upd(p, g, m, v):
        gf = g.astype(jnp.float32)
        m_new = b1 * m + (1 - b1) * gf
        v_new = b2 * v + (1 - b2) * jnp.square(gf)
        mhat = m_new / bc1
        vhat = v_new / bc2
        delta = mhat / (jnp.sqrt(vhat) + cfg.eps) + cfg.weight_decay * p.astype(jnp.float32)
        return (p.astype(jnp.float32) - lr * delta).astype(p.dtype), m_new, v_new

    flat_p, td = jax.tree.flatten(params)
    flat_g = jax.tree.leaves(grads)
    flat_m = jax.tree.leaves(opt_state["m"])
    flat_v = jax.tree.leaves(opt_state["v"])
    out = [upd(p, g, m, v) for p, g, m, v in zip(flat_p, flat_g, flat_m, flat_v)]
    new_p = jax.tree.unflatten(td, [o[0] for o in out])
    new_m = jax.tree.unflatten(td, [o[1] for o in out])
    new_v = jax.tree.unflatten(td, [o[2] for o in out])
    return new_p, {"m": new_m, "v": new_v, "step": step}, lr
