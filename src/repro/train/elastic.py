"""Elastic runtime: heartbeats, straggler governor, failure handling.

Paper tie-in (I4): the SoC migrates load off a hot NPU chiplet before it
throttles, driven by sensor prediction. At pod scale the "sensors" are
per-step telemetry (step walltime, per-host heartbeat age) and "migration"
is (a) re-balancing work away from stragglers and (b) elastic re-shard from
the latest checkpoint when a host is declared dead.

Everything here is deliberately dependency-free and unit-testable: the
policies are pure functions over telemetry dataclasses; `launch/train.py`
wires them to the real loop.
"""

from __future__ import annotations

import dataclasses
import math
import time
from typing import Dict, List, Optional, Sequence, Tuple


@dataclasses.dataclass
class HostState:
    host_id: int
    last_heartbeat: float
    step_times_s: List[float] = dataclasses.field(default_factory=list)
    alive: bool = True

    def record_step(self, t: float, now: Optional[float] = None):
        self.step_times_s.append(t)
        if len(self.step_times_s) > 64:
            self.step_times_s.pop(0)
        self.last_heartbeat = now if now is not None else time.time()


@dataclasses.dataclass(frozen=True)
class ElasticPolicy:
    heartbeat_timeout_s: float = 60.0
    straggler_ratio: float = 1.5      # step time vs fleet median
    straggler_patience: int = 8       # consecutive slow steps before action
    min_hosts: int = 1


class HeartbeatRegistry:
    """Failure detector: hosts that stop heartbeating are declared dead."""

    def __init__(self, n_hosts: int, policy: ElasticPolicy = ElasticPolicy()):
        now = time.time()
        self.hosts = {i: HostState(i, now) for i in range(n_hosts)}
        self.policy = policy

    def beat(self, host_id: int, step_time_s: Optional[float] = None,
             now: Optional[float] = None):
        h = self.hosts[host_id]
        now = now if now is not None else time.time()
        h.last_heartbeat = now
        if step_time_s is not None:
            h.record_step(step_time_s, now)

    def dead_hosts(self, now: Optional[float] = None) -> List[int]:
        now = now if now is not None else time.time()
        return [i for i, h in self.hosts.items()
                if h.alive and now - h.last_heartbeat > self.policy.heartbeat_timeout_s]

    def mark_dead(self, host_id: int):
        self.hosts[host_id].alive = False

    def alive_count(self) -> int:
        return sum(h.alive for h in self.hosts.values())


def median(xs: Sequence[float]) -> float:
    s = sorted(xs)
    n = len(s)
    return s[n // 2] if n % 2 else 0.5 * (s[n // 2 - 1] + s[n // 2])


def detect_stragglers(registry: HeartbeatRegistry) -> List[int]:
    """I4 'sensor-driven prediction': hosts persistently slower than the
    fleet median by straggler_ratio."""
    p = registry.policy
    recents = {i: h.step_times_s[-p.straggler_patience:]
               for i, h in registry.hosts.items()
               if h.alive and len(h.step_times_s) >= p.straggler_patience}
    if len(recents) < 2:
        return []
    med = median([median(v) for v in recents.values()])
    if med <= 0:
        return []
    return [i for i, v in recents.items()
            if all(t > p.straggler_ratio * med for t in v)]


@dataclasses.dataclass(frozen=True)
class MigrationDecision:
    kind: str                 # none | rebalance | reshard
    drop_hosts: Tuple[int, ...] = ()
    reason: str = ""


def plan_migration(registry: HeartbeatRegistry,
                   now: Optional[float] = None) -> MigrationDecision:
    """The I4 policy: dead host → elastic reshard; persistent straggler →
    rebalance (drop it from the data-parallel group until it recovers)."""
    dead = registry.dead_hosts(now)
    if dead:
        if registry.alive_count() - len(dead) < registry.policy.min_hosts:
            return MigrationDecision(
                "none", reason=f"hosts {dead} dead but below min_hosts")
        return MigrationDecision("reshard", tuple(dead),
                                 f"heartbeat timeout on hosts {dead}")
    slow = detect_stragglers(registry)
    if slow:
        return MigrationDecision("rebalance", tuple(slow),
                                 f"stragglers {slow} > "
                                 f"{registry.policy.straggler_ratio}× median")
    return MigrationDecision("none")


def elastic_mesh_shape(n_devices: int, model_parallel: int) -> Tuple[int, int]:
    """Largest (data, model) grid on the surviving devices (model fixed)."""
    assert n_devices >= model_parallel
    data = n_devices // model_parallel
    return data, model_parallel


def rebalanced_batch_split(global_batch: int, weights: Dict[int, float]
                           ) -> Dict[int, int]:
    """Work-proportional microbatch split (straggler gets less), summing to
    the global batch. weights: host → relative speed (1/median step time)."""
    total = sum(weights.values())
    raw = {h: global_batch * w / total for h, w in weights.items()}
    out = {h: int(math.floor(r)) for h, r in raw.items()}
    rem = global_batch - sum(out.values())
    for h, _ in sorted(raw.items(), key=lambda kv: kv[1] - math.floor(kv[1]),
                       reverse=True)[:rem]:
        out[h] += 1
    return out
