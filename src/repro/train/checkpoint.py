"""Fault-tolerant checkpointing (deliverable: checkpoint/restart at scale).

Design (DESIGN.md §6):
  * content-addressed shards: each leaf is saved as an .npy blob whose sha256
    goes into a manifest; the manifest carries a Merkle-style root hash over
    the sorted leaf hashes — the practical analogue of the paper's I3
    AuthenTree attestation (tamper/corruption detection on restore).
  * atomic publish: write to step_<N>.tmp/, fsync, rename — a crashed writer
    never corrupts the latest checkpoint.
  * retention-k garbage collection.
  * ELASTIC restore: arrays are saved in logical (global) layout, so a
    checkpoint written on a 256-chip mesh restores onto any mesh —
    `restore(..., shardings=...)` places shards for the *new* topology
    (device-loss → re-shard onto fewer hosts and keep training).
"""

from __future__ import annotations

import dataclasses
import hashlib
import json
import os
import pathlib
import shutil
import time
from typing import Any, Dict, Optional

import jax
import numpy as np


def _leaf_paths(tree) -> Dict[str, Any]:
    flat = jax.tree_util.tree_flatten_with_path(tree)[0]
    out = {}
    for path, leaf in flat:
        key = "/".join(str(getattr(p, "key", getattr(p, "idx", p)))
                       for p in path)
        out[key] = leaf
    return out


def _tree_unflatten_like(template, values: Dict[str, Any]):
    flat, treedef = jax.tree_util.tree_flatten_with_path(template)
    leaves = []
    for path, _ in flat:
        key = "/".join(str(getattr(p, "key", getattr(p, "idx", p)))
                       for p in path)
        leaves.append(values[key])
    return jax.tree_util.tree_unflatten(treedef, leaves)


def _sha256(path: pathlib.Path) -> str:
    h = hashlib.sha256()
    with open(path, "rb") as f:
        for chunk in iter(lambda: f.read(1 << 20), b""):
            h.update(chunk)
    return h.hexdigest()


@dataclasses.dataclass
class CheckpointManager:
    directory: str
    keep: int = 3

    def __post_init__(self):
        self.dir = pathlib.Path(self.directory)
        self.dir.mkdir(parents=True, exist_ok=True)

    # ------------------------------------------------------------------ save
    def save(self, step: int, tree, extra: Optional[Dict] = None) -> str:
        tmp = self.dir / f"step_{step:08d}.tmp"
        final = self.dir / f"step_{step:08d}"
        if tmp.exists():
            shutil.rmtree(tmp)
        tmp.mkdir(parents=True)
        leaves = _leaf_paths(tree)
        manifest = {"step": step, "time": time.time(), "extra": extra or {},
                    "leaves": {}}
        for key, leaf in sorted(leaves.items()):
            arr = np.asarray(jax.device_get(leaf))
            fname = key.replace("/", "__") + ".npy"
            np.save(tmp / fname, arr)
            manifest["leaves"][key] = {
                "file": fname, "shape": list(arr.shape), "dtype": str(arr.dtype),
                "sha256": _sha256(tmp / fname),
            }
        # AuthenTree-style root: hash over sorted leaf hashes
        root = hashlib.sha256()
        for key in sorted(manifest["leaves"]):
            root.update(manifest["leaves"][key]["sha256"].encode())
        manifest["root_hash"] = root.hexdigest()
        (tmp / "manifest.json").write_text(json.dumps(manifest, indent=1))
        os.replace(tmp, final)          # atomic publish
        self._gc()
        return str(final)

    # --------------------------------------------------------------- restore
    def latest_step(self) -> Optional[int]:
        steps = sorted(int(p.name.split("_")[1]) for p in self.dir.iterdir()
                       if p.is_dir() and p.name.startswith("step_")
                       and not p.name.endswith(".tmp"))
        return steps[-1] if steps else None

    def restore(self, template, step: Optional[int] = None,
                shardings=None, verify: bool = True):
        """Load onto the CURRENT topology. `shardings` (same pytree structure)
        re-places each global array — elastic re-shard on mesh change."""
        step = step if step is not None else self.latest_step()
        if step is None:
            raise FileNotFoundError(f"no checkpoint in {self.dir}")
        d = self.dir / f"step_{step:08d}"
        manifest = json.loads((d / "manifest.json").read_text())
        if verify:
            self.verify(step)
        sh_map = _leaf_paths(shardings) if shardings is not None else None
        values = {}
        for key, meta in manifest["leaves"].items():
            arr = np.load(d / meta["file"])
            if sh_map is not None and key in sh_map and sh_map[key] is not None:
                values[key] = jax.device_put(arr, sh_map[key])
            else:
                values[key] = jax.numpy.asarray(arr)
        return _tree_unflatten_like(template, values), manifest

    # ---------------------------------------------------------------- verify
    def verify(self, step: int) -> bool:
        """I3 analogue: recompute every leaf hash + the root; raise on tamper."""
        d = self.dir / f"step_{step:08d}"
        manifest = json.loads((d / "manifest.json").read_text())
        root = hashlib.sha256()
        for key in sorted(manifest["leaves"]):
            meta = manifest["leaves"][key]
            got = _sha256(d / meta["file"])
            if got != meta["sha256"]:
                raise IOError(
                    f"checkpoint integrity failure: leaf {key!r} hash mismatch "
                    f"(expected {meta['sha256'][:12]}…, got {got[:12]}…)")
            root.update(meta["sha256"].encode())
        if root.hexdigest() != manifest["root_hash"]:
            raise IOError("checkpoint integrity failure: root hash mismatch")
        return True

    def _gc(self):
        steps = sorted(p for p in self.dir.iterdir()
                       if p.is_dir() and p.name.startswith("step_")
                       and not p.name.endswith(".tmp"))
        for old in steps[:-self.keep]:
            shutil.rmtree(old)
