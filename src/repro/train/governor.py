"""Power-aware step governor — the paper's I1 adaptive DVFS, reinterpreted.

TPUs expose no voltage knobs, so the controller that survives is the
*decision layer*: given simulated power/thermal telemetry from the faithful
core model (`core.dvfs`, `core.thermal`) and the roofline terms of the
current configuration, pick the execution knobs (microbatch count, remat
policy, compression) exactly the way the SoC's DVFS governor picks P-states.

This closes the loop between the paper's contribution (core/) and the
framework: `core.planner.plan()` supplies the bottleneck verdict; the
governor turns it into ExecOptions overrides.
"""

from __future__ import annotations

import dataclasses
from typing import Dict, Optional

from repro.core.planner import PlanDecision, RooflineTerms, plan


@dataclasses.dataclass(frozen=True)
class GovernorState:
    power_budget_w: float = 300.0     # per-host envelope (analytic)
    headroom_ema: float = 0.0
    steps: int = 0


def step_governor(state: GovernorState, *, simulated_power_w: float,
                  alpha: float = 0.1) -> GovernorState:
    """EMA of power headroom — the I1 'workload phase predictor'."""
    headroom = max(0.0, 1.0 - simulated_power_w / state.power_budget_w)
    ema = (1 - alpha) * state.headroom_ema + alpha * headroom
    return dataclasses.replace(state, headroom_ema=ema, steps=state.steps + 1)


def overrides_from_plan(decision: PlanDecision,
                        state: Optional[GovernorState] = None) -> Dict:
    """PlanDecision → ExecOptions/step overrides (the 'P-state')."""
    out: Dict = {"remat": decision.remat_policy}
    if decision.compress_grads:
        out["grad_compression"] = "int8"
    if decision.int8_weights:
        out["weight_quant"] = "int8"
    if state is not None and state.headroom_ema > 0.25:
        # plenty of headroom → spend it on throughput (fewer microbatches)
        out["n_micro_bias"] = -1
    return out


def govern(terms: RooflineTerms, *, is_training: bool,
           resident_bytes_per_chip: Optional[float] = None,
           state: Optional[GovernorState] = None) -> Dict:
    """One-call: roofline terms → overrides dict."""
    decision = plan(terms, is_training=is_training,
                    resident_bytes_per_chip=resident_bytes_per_chip)
    return overrides_from_plan(decision, state)
