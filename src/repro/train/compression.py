"""Compression-aware gradient synchronization (paper innovation I2 → ICI).

The paper's UCIe extension compresses die-to-die payloads; the pod-scale
analogue compresses the *data-parallel gradient reduction*: gradients are
block-quantized to int8 (+f32 per-block scales ≈ 4.03× payload reduction)
with an **error-feedback** residual [Seide et al. 2014; 1-bit Adam lineage]
so the quantization error is re-injected next step and convergence is
preserved.

Two integration points:
  * `compress_decompress(grads, state)` — in-graph QDQ + error feedback;
    composes with any reduction (used by the default GSPMD train step, and
    the honest-traffic variant below).
  * `compressed_ring_allreduce(x, axis)` — a shard_map ring all-reduce whose
    ppermute payloads really are int8: the HLO collective bytes drop ~4×,
    which is how the hillclimb variant moves the collective roofline term.
"""

from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp

from repro.kernels import ops as kops


def init_error_state(params) -> Any:
    return jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)


def quantize_leaf(g: jnp.ndarray, block: int = 256):
    q, s, n = kops.quantize_blocks(g.astype(jnp.float32), block=block)
    return q, s, n


def dequantize_leaf(q, s, n, shape, block: int = 256):
    return kops.dequantize_blocks(q, s, n, shape, dtype=jnp.float32)


def compress_decompress(grads, error_state=None, *, block: int = 256):
    """Quantize-dequantize each gradient leaf with error feedback.

    Returns (grads_hat, new_error_state). Used as `grad_transform` in the
    train step: the reduction then carries int8-precision values.
    """
    leaves, treedef = jax.tree.flatten(grads)
    err_leaves = (jax.tree.leaves(error_state) if error_state is not None
                  else [jnp.zeros_like(l, dtype=jnp.float32) for l in leaves])
    new_g, new_e = [], []
    for g, e in zip(leaves, err_leaves):
        gf = g.astype(jnp.float32) + e
        q, s, n = quantize_leaf(gf, block)
        ghat = dequantize_leaf(q, s, n, gf.shape, block)
        new_g.append(ghat.astype(g.dtype))
        new_e.append(gf - ghat)
    return jax.tree.unflatten(treedef, new_g), jax.tree.unflatten(treedef, new_e)


def _ring_allreduce_int8(x: jnp.ndarray, axis_name: str, block: int = 256):
    """Inside shard_map: reduce-scatter + all-gather ring where every hop
    moves int8 blocks + f32 scales instead of f32 values."""
    from repro.parallel.shmap import axis_size
    n = axis_size(axis_name)
    if n == 1:
        return x
    me = jax.lax.axis_index(axis_name)                 # traced device index
    perm = [(i, (i + 1) % n) for i in range(n)]

    def chunk_at(chunks, idx):
        return jax.lax.dynamic_index_in_dim(chunks, idx % n, 0,
                                            keepdims=False)

    # pad flat so it splits into n equal chunks of whole blocks
    flat = x.astype(jnp.float32).reshape(-1)
    size = flat.shape[0]
    chunk = -(-size // n)
    chunk = -(-chunk // block) * block
    flat = jnp.pad(flat, (0, chunk * n - size))
    chunks = flat.reshape(n, chunk)

    # --- reduce-scatter phase ------------------------------------------------
    # step t: device i sends its partial of chunk (i+1-t), receives the
    # partial of chunk (i-t) and adds its own copy. After n-1 steps device i
    # owns the FULL reduction of chunk (i+2) mod n.
    acc = chunk_at(chunks, me + 1)
    for step in range(n - 1):
        q, s, _ = kops.quantize_blocks(acc, block=block)
        q = jax.lax.ppermute(q, axis_name, perm)
        s = jax.lax.ppermute(s, axis_name, perm)
        recv = kops.dequantize_blocks(q, s, chunk, (chunk,))
        acc = chunk_at(chunks, me - step) + recv
    # --- all-gather phase ------------------------------------------------------
    # relative slot r holds absolute chunk (me+2+r) mod n; slots are STATIC:
    # own → r=0; after `step+1` hops we hold device (me-1-step)'s chunk,
    # absolute (me+1-step) → r = n-1-step.
    rel = [None] * n
    q, s, _ = kops.quantize_blocks(acc, block=block)
    rel[0] = kops.dequantize_blocks(q, s, chunk, (chunk,))
    cur_q, cur_s = q, s
    for step in range(n - 1):
        cur_q = jax.lax.ppermute(cur_q, axis_name, perm)
        cur_s = jax.lax.ppermute(cur_s, axis_name, perm)
        rel[n - 1 - step] = kops.dequantize_blocks(cur_q, cur_s, chunk,
                                                   (chunk,))
    stacked = jnp.stack(rel)                           # (n, chunk), relative
    absolute = jnp.roll(stacked, me + 2, axis=0)       # abs p at index p
    full = absolute.reshape(-1)[:size]
    return full.reshape(x.shape).astype(x.dtype)


def compressed_ring_allreduce(x: jnp.ndarray, axis_name: str,
                              block: int = 256) -> jnp.ndarray:
    """Public entry — call inside shard_map over `axis_name`."""
    return _ring_allreduce_int8(x, axis_name, block)


def payload_ratio(shape, block: int = 256) -> float:
    """Compressed/uncompressed byte ratio for one f32 tensor."""
    import math
    n = math.prod(shape)
    blocks = -(-n // block)
    return (blocks * block * 1 + blocks * 4) / (n * 4)
