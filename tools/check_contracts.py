#!/usr/bin/env python
"""Repo-contract linter CLI — AST rules over src/ + benchmarks/.

    PYTHONPATH=src python tools/check_contracts.py [--strict] [--json]
                                                   [--rules R1,R3] [--root .]

Exit status: 0 clean (or findings without --strict), 1 findings under
--strict, 2 usage error. `--list-rules` prints the rule table (id, title,
scope, rationale) and exits.

Pure stdlib + repro.analysis.contracts (itself stdlib-only): the CI
`contracts` job needs no jax install.
"""

from __future__ import annotations

import argparse
import json
import pathlib
import sys

REPO_ROOT = pathlib.Path(__file__).resolve().parents[1]
sys.path.insert(0, str(REPO_ROOT / "src"))

from repro.analysis import contracts  # noqa: E402


def main() -> int:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--root", type=pathlib.Path, default=REPO_ROOT,
                    help="repo root to scan (default: this checkout)")
    ap.add_argument("--rules", default=None,
                    help="comma-separated rule ids (default: all)")
    ap.add_argument("--strict", action="store_true",
                    help="exit 1 if any finding survives")
    ap.add_argument("--json", action="store_true",
                    help="machine-readable output")
    ap.add_argument("--list-rules", action="store_true")
    args = ap.parse_args()

    if args.list_rules:
        for r in contracts.RULES:
            print(f"{r.id}  {r.title}")
            print(f"    scope: {', '.join(r.paths)}")
            print(f"    {r.rationale}")
        return 0

    rule_ids = args.rules.split(",") if args.rules else None
    try:
        rules = contracts.rules_by_id(rule_ids)
    except ValueError as e:
        print(f"error: {e}", file=sys.stderr)
        return 2

    suppressed: list = []
    findings = contracts.run_rules(args.root, rules=rules,
                                   collect_suppressed=suppressed)

    if args.json:
        print(json.dumps({
            "root": str(args.root),
            "rules": [r.id for r in rules],
            "findings": [f.as_dict() for f in findings],
            "suppressed": [f.as_dict() for f in suppressed],
        }, indent=2))
    else:
        for f in findings:
            print(f)
        for f in suppressed:
            print(f"suppressed({f.rule}) {f.path}:{f.line}")
        print(f"contracts: {len(rules)} rules, {len(findings)} finding(s), "
              f"{len(suppressed)} suppressed")

    return 1 if (findings and args.strict) else 0


if __name__ == "__main__":
    sys.exit(main())
