"""Benchmark regression gate: fresh BENCH_*.json vs committed baselines.

Run: python -m benchmarks.compare --baseline <dir> --new <dir> [--tol 0.10]

Each BENCH_<section>.json is a flat {metric: number} dict (benchmarks/run.py
--json). Only metrics named in GATES are gated — everything else is
informational (absolute latencies wobble on shared CI runners; throughputs
and wall-times are what the roadmap tracks PR-over-PR). A gated metric fails
when it regresses by more than --tol in its bad direction:

    higher-is-better (tokens/s)  : new < (1 - tol) * baseline
    lower-is-better  (wall-time) : new > (1 + tol) * baseline

Metrics present only in the new snapshot pass (they become the next
baseline); gated metrics missing from the new snapshot fail — a deleted
number is a silent regression.

Absolute metrics (tokens/s, wall-seconds) only compare meaningfully when the
baseline was captured on the same runner class as the new run, so they are
enforced only when the snapshots' `env_id` fingerprints match (they report
informationally otherwise) — refresh the committed BENCH_*.json from a CI
run's bench-json artifact to arm them in CI. Same-run ratios
(bucketing_speedup, paged_kv_shrink) cancel machine speed and are enforced
unconditionally.
"""

from __future__ import annotations

import argparse
import json
import pathlib
import sys

# section -> {metric: 'higher' | 'lower'}
GATES = {
    "serve": {
        "fast_tokens_per_s": "higher",
        "decode_tokens_per_s": "higher",
        "paged_longctx_tokens_per_s": "higher",
        "paged_kv_shrink": "lower",          # pool / dense memory ratio
        "bucketing_speedup": "higher",       # same-run ratio, machine-free
    },
    "soc": {
        "sweep_wall_s": "lower",
    },
    "kernels": {
        "decode_attention_us": "lower",
    },
}

# machine-speed-free metrics: enforced even across runner classes
RATIO_METRICS = {"paged_kv_shrink", "bucketing_speedup"}


def load(d: pathlib.Path, section: str):
    p = d / f"BENCH_{section}.json"
    return json.loads(p.read_text()) if p.exists() else None


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--baseline", required=True, type=pathlib.Path)
    ap.add_argument("--new", required=True, type=pathlib.Path)
    ap.add_argument("--tol", type=float, default=0.10,
                    help="allowed fractional regression (default 10%%)")
    args = ap.parse_args()

    failures = []
    for section, gates in GATES.items():
        base = load(args.baseline, section)
        new = load(args.new, section)
        if base is None:
            print(f"compare,{section},no_baseline,skipped")
            continue
        if new is None:
            failures.append(f"{section}: BENCH_{section}.json not produced")
            continue
        same_env = base.get("env_id") is not None \
            and base.get("env_id") == new.get("env_id")
        for metric, direction in gates.items():
            if metric not in base:
                print(f"compare,{section},{metric},new_metric,pass")
                continue
            if metric not in new:
                failures.append(f"{section}.{metric}: missing from new run")
                continue
            b, n = float(base[metric]), float(new[metric])
            if direction == "higher":
                ok = n >= (1.0 - args.tol) * b
                delta = (n / b - 1.0) if b else 0.0
            else:
                ok = n <= (1.0 + args.tol) * b
                delta = (n / b - 1.0) if b else 0.0
            enforced = same_env or metric in RATIO_METRICS
            status = "pass" if ok else (
                "FAIL" if enforced else "env_mismatch_info")
            print(f"compare,{section},{metric},base={b:.4g},new={n:.4g},"
                  f"delta={delta:+.1%},{status}")
            if not ok and enforced:
                failures.append(
                    f"{section}.{metric}: {b:.4g} -> {n:.4g} "
                    f"({delta:+.1%}, {direction}-is-better, tol {args.tol:.0%})")

    if failures:
        print("\nREGRESSIONS:\n  " + "\n  ".join(failures))
        return 1
    print("\nall gated benchmarks within tolerance")
    return 0


if __name__ == "__main__":
    sys.exit(main())
