"""Benchmark regression gate: fresh BENCH_*.json vs committed baselines.

Run: python -m benchmarks.compare --baseline <dir> --new <dir> [--tol 0.10]

Each BENCH_<section>.json is a flat {metric: number} dict (benchmarks/run.py
--json). Only metrics named in GATES are gated — everything else is
informational (absolute latencies wobble on shared CI runners; throughputs
and wall-times are what the roadmap tracks PR-over-PR). Each gated metric
carries its OWN tolerance — tight on deterministic same-run ratios (memory
shrinks are exact byte math; a 5% drift there is a real layout change),
loose on wall-clock metrics that inherit shared-runner scheduler noise. A
gated metric fails when it regresses by more than its tolerance in its bad
direction:

    higher-is-better (tokens/s)  : new < (1 - tol) * baseline
    lower-is-better  (wall-time) : new > (1 + tol) * baseline

`--tol X` overrides every per-metric tolerance (escape hatch for local
comparisons across very different machines); omit it to use the table.

Metrics present only in the new snapshot pass (they become the next
baseline); gated metrics missing from the new snapshot fail — a deleted
number is a silent regression.

Absolute metrics (tokens/s, wall-seconds) only compare meaningfully when the
baseline was captured on the same runner class as the new run, so they are
enforced only when the snapshots' `env_id` fingerprints match (they report
informationally otherwise) — refresh the committed BENCH_*.json from a CI
run's bench-json artifact to arm them in CI. Same-run ratios
(bucketing_speedup, paged_kv_shrink, int8_kv_shrink,
int8_vs_f32_decode_ratio) cancel machine speed and are enforced
unconditionally.
"""

from __future__ import annotations

import argparse
import json
import pathlib
import sys

# section -> {metric: ('higher' | 'lower', tolerance)}
GATES = {
    "serve": {
        # wall-clock tokens/s: shared runners swing these ±20% run-to-run
        # even with the bench's best-window measurement — gate loosely
        "fast_tokens_per_s": ("higher", 0.25),
        "decode_tokens_per_s": ("higher", 0.25),
        "paged_longctx_tokens_per_s": ("higher", 0.25),
        "int8_decode_tokens_per_s": ("higher", 0.25),
        "paged_kv_shrink": ("lower", 0.05),   # pool / dense memory ratio:
        "int8_kv_shrink": ("lower", 0.05),    # deterministic byte math
        # same-run ratio, machine-free in expectation — but its two legs
        # include compile time, so shared-runner noise still moves it ±13%
        "bucketing_speedup": ("higher", 0.15),
        # same-run but dequant work makes the CPU reference path noisy; the
        # TPU kernels are the real datapath, so gate loosely here
        "int8_vs_f32_decode_ratio": ("higher", 0.35),
        # chunked prefill (PR 4): stall ticks and pad waste are DETERMINISTIC
        # tick/token counts on fixed traffic — any increase is a scheduler
        # regression (stall must stay 0: the one-chunk-per-tick invariant)
        "chunked_prefill_stall_ticks": ("lower", 0.0),
        "chunked_pad_waste": ("lower", 0.05),
        "chunked_mixed_tokens_per_s": ("higher", 0.25),
        "sampled_tokens_per_s": ("higher", 0.25),
        # greedy int8-vs-f32 prefix divergence: deterministic on a fixed
        # runner/jax build (env-gated), drifts only if quantization quality
        # actually moves
        "int8_token_divergence": ("lower", 0.25),
    },
    "soc": {
        "sweep_wall_s": ("lower", 0.20),
    },
    "kernels": {
        "decode_attention_us": ("lower", 0.25),
    },
}

# machine-speed-free metrics: enforced even across runner classes
RATIO_METRICS = {"paged_kv_shrink", "bucketing_speedup", "int8_kv_shrink",
                 "int8_vs_f32_decode_ratio", "chunked_prefill_stall_ticks",
                 "chunked_pad_waste"}

# absolute slack on top of the fractional tolerance, for metrics whose
# baseline can legitimately be 0.0 (a multiplicative gate at b=0 would fail
# on ANY nonzero value): divergence may move by this much regardless of b
ABS_SLACK = {"int8_token_divergence": 0.05,
             # stall ticks baseline IS 0 for the chunked engine — any
             # half-tick of slack only exists to let the multiplicative
             # form evaluate; an increase to >= 1 tick still fails
             "chunked_prefill_stall_ticks": 0.5,
             "chunked_pad_waste": 0.02}


def load(d: pathlib.Path, section: str):
    p = d / f"BENCH_{section}.json"
    return json.loads(p.read_text()) if p.exists() else None


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--baseline", required=True, type=pathlib.Path)
    ap.add_argument("--new", required=True, type=pathlib.Path)
    ap.add_argument("--tol", type=float, default=None,
                    help="override every per-metric tolerance (default: use "
                         "the GATES table)")
    args = ap.parse_args()

    failures = []
    for section, gates in GATES.items():
        base = load(args.baseline, section)
        new = load(args.new, section)
        if base is None:
            print(f"compare,{section},no_baseline,skipped")
            continue
        if new is None:
            failures.append(f"{section}: BENCH_{section}.json not produced")
            continue
        same_env = base.get("env_id") is not None \
            and base.get("env_id") == new.get("env_id")
        for metric, (direction, tol) in gates.items():
            if args.tol is not None:
                tol = args.tol
            if metric not in base:
                print(f"compare,{section},{metric},new_metric,pass")
                continue
            if metric not in new:
                failures.append(f"{section}.{metric}: missing from new run")
                continue
            b, n = float(base[metric]), float(new[metric])
            slack = ABS_SLACK.get(metric, 0.0)
            if direction == "higher":
                ok = n >= (1.0 - tol) * b - slack
            else:
                ok = n <= (1.0 + tol) * b + slack
            delta_s = f"{n / b - 1.0:+.1%}" if b else f"{n - b:+.4g}abs"
            enforced = same_env or metric in RATIO_METRICS
            status = "pass" if ok else (
                "FAIL" if enforced else "env_mismatch_info")
            print(f"compare,{section},{metric},base={b:.4g},new={n:.4g},"
                  f"delta={delta_s},tol={tol:.0%},{status}")
            if not ok and enforced:
                failures.append(
                    f"{section}.{metric}: {b:.4g} -> {n:.4g} "
                    f"({delta_s}, {direction}-is-better, tol {tol:.0%})")

    if failures:
        print("\nREGRESSIONS:\n  " + "\n  ".join(failures))
        return 1
    print("\nall gated benchmarks within tolerance")
    return 0


if __name__ == "__main__":
    sys.exit(main())
